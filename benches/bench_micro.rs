//! Hot-path micro-benches used by the §Perf optimization pass
//! (EXPERIMENTS.md §Perf): the L3 coordinator primitives that run between
//! every pair of HLO executions, plus block-execution dispatch on both
//! paths. criterion is not vendored offline; testutil::Bencher prints
//! comparable summary lines.
//!
//! Usage: cargo bench --bench bench_micro [-- <filter>]

use std::path::Path;
use std::sync::Arc;

use fastcache_dit::cache::AffineFit;
use fastcache_dit::config::{FastCacheConfig, PolicyKind, Variant};
use fastcache_dit::model::{native, DitModel};
use fastcache_dit::rng::Rng;
use fastcache_dit::runtime::{ArtifactStore, Client};
use fastcache_dit::scheduler::{DenoiseEngine, GenRequest};
use fastcache_dit::tensor::Tensor;
use fastcache_dit::testutil::Bencher;
use fastcache_dit::tokens;

fn rnd(seed: u64, shape: &[usize]) -> Tensor {
    let mut r = Rng::new(seed);
    Tensor::new(r.normal_vec(shape.iter().product(), 1.0), shape)
}

fn main() {
    let filter: Option<String> = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"));
    let want = |name: &str| filter.as_deref().map_or(true, |f| name.contains(f));
    let b = Bencher::from_env();

    let d = 288; // dit-xl width
    let h = rnd(1, &[64, d]);
    let hp = rnd(2, &[64, d]);

    if want("delta_rel") {
        b.bench("L3/delta_rel 64x288", || {
            std::hint::black_box(native::delta_rel(&h, &hp));
        });
    }
    if want("saliency") {
        b.bench("L3/saliency 64x288", || {
            std::hint::black_box(native::saliency(&h, &hp));
        });
    }
    if want("partition") {
        b.bench("L3/partition+pad 64x288", || {
            let p = tokens::partition(&h, &hp, 0.05);
            std::hint::black_box(tokens::pad_to_bucket(&p));
        });
    }
    if want("affine") {
        let mut fit = AffineFit::new(d, 0.98);
        fit.update(&h, &hp);
        b.bench("L3/affine_fit.update 64x288", || {
            let mut f2 = fit.clone();
            f2.update(&h, &hp);
            std::hint::black_box(f2);
        });
        b.bench("L3/affine_fit.apply 64x288", || {
            std::hint::black_box(fit.apply(&h));
        });
    }
    if want("knn") {
        b.bench("L3/knn_density k=5 64x288", || {
            std::hint::black_box(tokens::knn_density(&h, 5));
        });
    }
    if want("merge") {
        let scores = vec![1.0f32; 64];
        b.bench("L3/local_ctm 64->32", || {
            std::hint::black_box(tokens::local_ctm(&h, &scores, 32));
        });
    }
    if want("block_native") {
        let m = DitModel::native(Variant::Xl, 1);
        let hb = rnd(3, &[1, 64, 288]);
        let c = rnd(4, &[1, 288]);
        b.bench("L2-native/block dit-xl 64 tok", || {
            std::hint::black_box(m.block(0, &hb, &c).unwrap());
        });
        let hb16 = rnd(5, &[1, 16, 288]);
        b.bench("L2-native/block dit-xl 16 tok", || {
            std::hint::black_box(m.block(0, &hb16, &c).unwrap());
        });
    }
    if want("block_hlo") && Path::new("artifacts/manifest.txt").exists() {
        let client = Arc::new(Client::cpu().unwrap());
        let store = Arc::new(ArtifactStore::open(Path::new("artifacts")).unwrap());
        let m = DitModel::load(client, store, Variant::Xl, 1).unwrap();
        let hb = rnd(3, &[1, 64, 288]);
        let c = rnd(4, &[1, 288]);
        // Warm the executable cache before timing dispatch.
        let _ = m.block(0, &hb, &c).unwrap();
        b.bench("L1+runtime/block HLO dit-xl 64 tok", || {
            std::hint::black_box(m.block(0, &hb, &c).unwrap());
        });
        let hb16 = rnd(5, &[1, 16, 288]);
        let _ = m.block(0, &hb16, &c).unwrap();
        b.bench("L1+runtime/block HLO dit-xl 16 tok", || {
            std::hint::black_box(m.block(0, &hb16, &c).unwrap());
        });
        let w = rnd(6, &[288, 288]);
        let bias = rnd(7, &[288]);
        let _ = m.linear_approx_full(&hb, &w, &bias).unwrap();
        b.bench("L1+runtime/linear_approx HLO (pallas)", || {
            std::hint::black_box(m.linear_approx_full(&hb, &w, &bias).unwrap());
        });
    }
    if want("e2e") {
        let m = DitModel::native(Variant::B, 1);
        b.bench("E2E-native/fastcache dit-b 10 steps", || {
            let mut eng = DenoiseEngine::new(&m, FastCacheConfig::default());
            std::hint::black_box(eng.generate(&GenRequest::builder(0, 42).steps(10).build().unwrap()).unwrap());
        });
        b.bench("E2E-native/nocache dit-b 10 steps", || {
            let mut eng =
                DenoiseEngine::new(&m, FastCacheConfig::with_policy(PolicyKind::NoCache));
            std::hint::black_box(eng.generate(&GenRequest::builder(0, 42).steps(10).build().unwrap()).unwrap());
        });
    }
}
