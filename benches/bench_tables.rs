//! Regenerates every table and figure of the paper's evaluation
//! (FastCache, Liu et al. 2025) on the scaled serving substrate.
//!
//! Usage:
//!   cargo bench --bench bench_tables            # all tables + figures
//!   cargo bench --bench bench_tables -- table1  # one experiment
//!   BENCH_FULL=1 cargo bench ...                # paper-faithful sizes
//!   BENCH_SMOKE=1 cargo bench -- serving sharding warmstart obs  # CI smoke
//!
//! The serving, sharding, and warmstart tables also land as
//! bench_out/BENCH_*.json (uploaded as a CI artifact by
//! scripts/bench_smoke.sh).
//!
//! Absolute numbers differ from the paper (CPU PJRT substrate, latent
//! FID proxies — see DESIGN.md §2); the reproduced signal is each table's
//! SHAPE: who wins, by roughly what factor, where crossovers fall.
//! Outputs are recorded in EXPERIMENTS.md.

use fastcache_dit::config::{FastCacheConfig, ModelConfig, PolicyKind, Variant, C_IN};
use fastcache_dit::experiments::{
    baseline_policies, eval_policies, eval_serving, eval_sharding, eval_video, eval_warmstart,
    EvalConfig, ShardingEval, WarmstartEval,
};
use fastcache_dit::metrics::report::{f1, pct, Table};
use fastcache_dit::model::kernels::{attention_streaming, attention_streaming_t, Act};
use fastcache_dit::model::{native, DitModel, ScratchArena, WeightBank};
use fastcache_dit::rng::Rng;
use fastcache_dit::scheduler::DenoiseEngine;
use fastcache_dit::tensor::Tensor;
use fastcache_dit::testutil::{oracle, Bencher};
use fastcache_dit::workload::{MotionProfile, WorkloadGen};

fn model(v: Variant) -> DitModel {
    // Benches run the native execution path: the HLO path is numerically
    // identical (rust/tests/runtime_roundtrip.rs) and the relative timings
    // are what the tables report. serve_batch (examples/) is the HLO-path
    // end-to-end driver.
    DitModel::native(v, 0xD17)
}

fn quick(v: Variant) -> EvalConfig {
    EvalConfig::quick(v)
}

fn fc(policy: PolicyKind) -> FastCacheConfig {
    FastCacheConfig::with_policy(policy)
}

/// CI smoke mode (scripts/bench_smoke.sh): tiny sizes, same tables.
fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").as_deref() == Ok("1")
}

/// Persist a table's rows as `bench_out/BENCH_<name>.json` so CI can
/// upload them and the perf trajectory accumulates per-PR.
fn write_json(name: &str, rows_json: Vec<String>) {
    std::fs::create_dir_all("bench_out").ok();
    let path = format!("bench_out/BENCH_{name}.json");
    let body = format!("{{\"table\":\"{name}\",\"rows\":[{}]}}\n", rows_json.join(","));
    match std::fs::write(&path, body) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn std_headers() -> Vec<&'static str> {
    vec!["Method", "FID↓", "t-FID↓", "Time (ms)↓", "Mem (MiB)↓", "Speedup↑"]
}

fn push_std_row(t: &mut Table, row: &fastcache_dit::experiments::EvalRow) {
    t.row(&[
        row.label.clone(),
        format!("{:.3}", row.fid),
        format!("{:.3}", row.tfid),
        format!("{:.0}", row.time_ms),
        f1(row.mem_mib),
        format!("{:+.1}%", row.speedup_pct()),
    ]);
}

/// Table 1 / Table 12: comparison with acceleration baselines.
fn table1(full_variants: bool) {
    let variants: &[Variant] = if full_variants { &Variant::ALL } else { &[Variant::Xl] };
    for &v in variants {
        let m = model(v);
        let rows = eval_policies(&m, &baseline_policies(), &quick(v)).unwrap();
        let mut t = Table::new(
            &format!("Table 1/12 — baselines on {} (paper Tab. 1 & 12)", v.paper_name()),
            &std_headers(),
        );
        for r in &rows {
            push_std_row(&mut t, r);
        }
        println!("{}", t.render());
    }
}

/// Table 2 / Table 9: ablation of STR / SC / MB.
fn table2() {
    let combos: [(&str, bool, bool, bool); 5] = [
        ("X X X (no modules)", false, false, false),
        ("STR _ MB", true, false, true),
        ("_ SC MB", false, true, true),
        ("STR SC _", true, true, false),
        ("STR SC MB (full)", true, true, true),
    ];
    for v in [Variant::L, Variant::Xl] {
        let m = model(v);
        let policies: Vec<(String, FastCacheConfig)> = combos
            .iter()
            .map(|(label, str_, sc, mb)| {
                let mut c = fc(PolicyKind::FastCache);
                c.enable_str = *str_;
                c.enable_sc = *sc;
                c.enable_mb = *mb;
                if !*str_ && !*sc {
                    // no skipping machinery at all == NoCache row
                    c = fc(PolicyKind::NoCache);
                }
                (label.to_string(), c)
            })
            .collect();
        let rows = eval_policies(&m, &policies, &quick(v)).unwrap();
        let mut t = Table::new(
            &format!("Table 2/9 — module ablation on {} (paper Tab. 2 & 9)", v.paper_name()),
            &["STR/SC/MB", "Time (ms)↓", "Mem (MiB)↓", "FID↓", "Skip↑"],
        );
        for r in &rows {
            t.row(&[
                r.label.clone(),
                format!("{:.0}", r.time_ms),
                f1(r.mem_mib),
                format!("{:.3}", r.fid),
                pct(r.skip_ratio),
            ]);
        }
        println!("{}", t.render());
    }
}

/// Table 3: cross-model scaling, FBCache vs FastCache on B/S.
fn table3() {
    let mut t = Table::new(
        "Table 3 — cross-model scaling (paper Tab. 3)",
        &["Model", "Method", "FID↓", "Time (ms)↓", "Speedup↑"],
    );
    for v in [Variant::B, Variant::S] {
        let m = model(v);
        let policies = vec![
            ("FBCache".to_string(), fc(PolicyKind::FbCache)),
            ("FastCache".to_string(), fc(PolicyKind::FastCache)),
        ];
        let rows = eval_policies(&m, &policies, &quick(v)).unwrap();
        for r in &rows {
            t.row(&[
                v.paper_name().to_string(),
                r.label.clone(),
                format!("{:.3}", r.fid),
                format!("{:.0}", r.time_ms),
                format!("{:+.1}%", r.speedup_pct()),
            ]);
        }
    }
    println!("{}", t.render());
}

/// Table 5: detailed FBCache vs FastCache across all variants.
fn table5() {
    let mut t = Table::new(
        "Table 5 — static/dynamic ratios across variants (paper Tab. 5)",
        &["Model", "Method", "Static↑", "Dynamic↓", "Time (ms)↓", "Speedup↑", "FID↓", "t-FID↓"],
    );
    for v in Variant::ALL {
        let m = model(v);
        let policies = vec![
            ("FBCache".to_string(), fc(PolicyKind::FbCache)),
            ("FastCache".to_string(), fc(PolicyKind::FastCache)),
        ];
        let rows = eval_policies(&m, &policies, &quick(v)).unwrap();
        for r in &rows {
            t.row(&[
                v.paper_name().to_string(),
                r.label.clone(),
                pct(r.static_ratio),
                pct(1.0 - r.static_ratio),
                format!("{:.0}", r.time_ms),
                format!("{:+.1}%", r.speedup_pct()),
                format!("{:.3}", r.fid),
                format!("{:.3}", r.tfid),
            ]);
        }
    }
    println!("{}", t.render());
}

/// Table 6: threshold robustness (FBCache rdt sweep vs FastCache τ_s sweep).
fn table6() {
    let v = Variant::Xl;
    let m = model(v);
    let mut policies: Vec<(String, FastCacheConfig)> = Vec::new();
    for rdt in [0.20, 0.25, 0.30] {
        let mut c = fc(PolicyKind::FbCache);
        c.fb_rdt = rdt;
        policies.push((format!("FBCache rdt={rdt}"), c));
    }
    for tau in [0.02, 0.03, 0.04, 0.05] {
        let mut c = fc(PolicyKind::FastCache);
        c.tau_s = tau;
        policies.push((format!("FastCache tau_s={tau}"), c));
    }
    let rows = eval_policies(&m, &policies, &quick(v)).unwrap();
    let base_fb = rows.iter().find(|r| r.label.contains("0.2")).unwrap().fid;
    let base_fast = rows.iter().find(|r| r.label.contains("0.02")).unwrap().fid;
    let base_clip_fb = rows.iter().find(|r| r.label.contains("0.2")).unwrap().clip;
    let base_clip_fast = rows.iter().find(|r| r.label.contains("0.02")).unwrap().clip;
    let mut t = Table::new(
        "Table 6 — threshold robustness (paper Tab. 6)",
        &["Config", "Speedup↑", "FID↓", "|ΔFID|", "CLIP↑", "ΔCLIP"],
    );
    for r in &rows {
        let (bf, bc) = if r.label.starts_with("FBCache") {
            (base_fb, base_clip_fb)
        } else {
            (base_fast, base_clip_fast)
        };
        t.row(&[
            r.label.clone(),
            format!("{:.2}", r.speedup),
            format!("{:.3}", r.fid),
            format!("+{:.3}", (r.fid - bf).abs()),
            f1(r.clip),
            format!("{:+.2}", r.clip - bc),
        ]);
    }
    println!("{}", t.render());
}

/// Table 7: T2I settings — three (backbone, workload) pairs standing in for
/// DeepFloyd / SD1.5 / SDXL (substitution: DESIGN.md §2).
fn table7() {
    let settings: [(&str, Variant, MotionProfile); 3] = [
        ("DeepFloyd-T2I/MS-COCO (≈DiT-L calm)", Variant::L, MotionProfile::CALM),
        ("SD-1.5/MS-COCO (≈DiT-B mixed)", Variant::B, MotionProfile::MIXED),
        ("SDXL/DrawBench (≈DiT-XL stormy)", Variant::Xl, MotionProfile::STORMY),
    ];
    let mut t = Table::new(
        "Table 7 — text-to-image settings (paper Tab. 7)",
        &["Setting", "Method", "CLIP↑", "Time (ms)↓", "Speedup↑"],
    );
    for (name, v, profile) in settings {
        let m = model(v);
        let mut ecfg = quick(v);
        ecfg.profile = profile;
        let policies = vec![
            ("TeaCache".to_string(), fc(PolicyKind::TeaCache)),
            ("FBCache".to_string(), fc(PolicyKind::FbCache)),
            ("AdaCache".to_string(), fc(PolicyKind::AdaCache)),
            ("FastCache".to_string(), fc(PolicyKind::FastCache)),
        ];
        let rows = eval_policies(&m, &policies, &ecfg).unwrap();
        for r in &rows {
            t.row(&[
                name.to_string(),
                r.label.clone(),
                f1(r.clip),
                format!("{:.0}", r.time_ms),
                format!("{:+.1}%", r.speedup_pct()),
            ]);
        }
    }
    println!("{}", t.render());
}

/// Table 8: video generation (VD-DiT ≈ dit-b/l over frame clips).
fn table8() {
    let full = std::env::var("BENCH_FULL").as_deref() == Ok("1");
    let (frames, steps) = if full { (16, 50) } else { (6, 12) };
    let mut t = Table::new(
        "Table 8 — video generation (paper Tab. 8)",
        &["Model", "FastCache", "FVD↓", "Time (ms)↓", "Mem (MiB)↓", "Speedup↑"],
    );
    for v in [Variant::B, Variant::L] {
        let m = model(v);
        for (on, policy) in [(false, PolicyKind::NoCache), (true, PolicyKind::FastCache)] {
            let (row, fvd) =
                eval_video(&m, &fc(policy), frames, steps, MotionProfile::MIXED, 0xF1).unwrap();
            t.row(&[
                format!("VD-{}", v.paper_name()),
                if on { "yes" } else { "no" }.to_string(),
                format!("{:.3}", fvd),
                format!("{:.0}", row.time_ms),
                f1(row.mem_mib),
                format!("{:+.1}%", row.speedup_pct()),
            ]);
        }
    }
    println!("{}", t.render());
}

/// Table 10: Learning-to-Cache threshold trade-off.
fn table10() {
    let v = Variant::Xl;
    let m = model(v);
    let mut policies: Vec<(String, FastCacheConfig)> =
        vec![("No Cache".to_string(), fc(PolicyKind::NoCache))];
    for thr in [0.10, 0.15] {
        let mut c = fc(PolicyKind::L2C);
        c.l2c_threshold = thr;
        policies.push((format!("Learning-to-Cache thr={thr}"), c));
    }
    policies.push(("FBCache".to_string(), fc(PolicyKind::FbCache)));
    policies.push(("FastCache (Ours)".to_string(), fc(PolicyKind::FastCache)));
    let rows = eval_policies(&m, &policies, &quick(v)).unwrap();
    let mut t = Table::new("Table 10 — L2C trade-off (paper Tab. 10)", &std_headers());
    for r in &rows {
        push_std_row(&mut t, r);
    }
    println!("{}", t.render());
}

/// Table 11: composition with (simulated) quantization — bf16-rounded
/// weights. Quality cost of quantization is measured; the time column on
/// this substrate is ~unchanged (XLA CPU has no bf16 fast path), which we
/// report honestly; memory halves for weights.
fn table11() {
    let v = Variant::Xl;
    let mut t = Table::new(
        "Table 11 — composition with quantization (paper Tab. 11)",
        &["FastCache", "Quant", "FID↓", "t-FID↓", "Time (ms)↓", "Mem (MiB)↓"],
    );
    for (fc_on, quant) in [(false, false), (true, false), (true, true)] {
        let mut m = model(v);
        if quant {
            quantize_model(&mut m);
        }
        let policies = vec![(
            "row".to_string(),
            if fc_on { fc(PolicyKind::FastCache) } else { fc(PolicyKind::NoCache) },
        )];
        let rows = eval_policies(&m, &policies, &quick(v)).unwrap();
        let r = &rows[0];
        // bf16 deployment stores weights at half width.
        let weight_mib = m.weight_bytes() as f64 / (1 << 20) as f64;
        let mem = if quant { r.mem_mib - weight_mib * 0.5 } else { r.mem_mib };
        t.row(&[
            if fc_on { "Yes" } else { "No" }.to_string(),
            if quant { "Yes" } else { "No" }.to_string(),
            format!("{:.3}", r.fid),
            format!("{:.3}", r.tfid),
            format!("{:.0}", r.time_ms),
            f1(mem),
        ]);
    }
    println!("{}", t.render());
}

/// Round every weight to bf16 precision (simulated quantized deployment).
/// Mutates the row-major bank in place, then repacks so the native
/// kernels serve the quantized values (the packed layout is a snapshot).
fn quantize_model(m: &mut DitModel) {
    let to_bf16 = |t: &mut Tensor| {
        for v in t.data_mut().iter_mut() {
            *v = f32::from_bits(v.to_bits() & 0xFFFF_0000);
        }
    };
    for b in m.bank.blocks.iter_mut() {
        to_bf16(&mut b.wqkv);
        to_bf16(&mut b.bqkv);
        to_bf16(&mut b.wo);
        to_bf16(&mut b.bo);
        to_bf16(&mut b.w1);
        to_bf16(&mut b.b1);
        to_bf16(&mut b.w2);
        to_bf16(&mut b.b2);
        to_bf16(&mut b.wmod);
        to_bf16(&mut b.bmod);
    }
    to_bf16(&mut m.bank.embed.w);
    to_bf16(&mut m.bank.temb.w1);
    to_bf16(&mut m.bank.temb.w2);
    to_bf16(&mut m.bank.final_.wmod);
    to_bf16(&mut m.bank.final_.wout);
    m.repack();
}

/// Table 13: speed-quality trade-off at matched operating points.
fn table13() {
    let v = Variant::Xl;
    let m = model(v);
    let mut fb_cons = fc(PolicyKind::FbCache);
    fb_cons.fb_rdt = 0.04;
    let mut fast_cons = fc(PolicyKind::FastCache);
    fast_cons.tau_delta0 = 0.08;
    let policies = vec![
        ("[similar speedup] FBCache".to_string(), fc(PolicyKind::FbCache)),
        ("[similar speedup] FastCache".to_string(), fc(PolicyKind::FastCache)),
        ("[similar FID] FBCache rdt=0.04".to_string(), fb_cons),
        ("[similar FID] FastCache d0=0.08".to_string(), fast_cons),
    ];
    let rows = eval_policies(&m, &policies, &quick(v)).unwrap();
    let mut t = Table::new(
        "Table 13 — speed-quality trade-off (paper Tab. 13)",
        &["Comparison", "Speedup↑", "FID↓", "CLIP↑", "Mem (MiB)↓"],
    );
    for r in &rows {
        t.row(&[
            r.label.clone(),
            format!("{:.2}", r.speedup),
            format!("{:.3}", r.fid),
            f1(r.clip),
            f1(r.mem_mib),
        ]);
    }
    println!("{}", t.render());
}

/// Table 14: robustness across guidance scale and step count.
fn table14() {
    let full = std::env::var("BENCH_FULL").as_deref() == Ok("1");
    let steps_grid: [usize; 3] = if full { [25, 50, 100] } else { [10, 20, 40] };
    let mut t = Table::new(
        "Table 14 — guidance × steps robustness (paper Tab. 14)",
        &["Model", "Guidance", "Steps", "FID↓", "Time (ms)↓", "Speedup↑"],
    );
    for v in [Variant::B, Variant::L] {
        let m = model(v);
        for (g, steps) in [(3.0f32, steps_grid[0]), (7.5, steps_grid[1]), (15.0, steps_grid[2])] {
            let mut ecfg = quick(v);
            ecfg.steps = steps;
            ecfg.requests = ecfg.requests.min(8);
            ecfg.guidance = g;
            let policies = vec![("FastCache".to_string(), fc(PolicyKind::FastCache))];
            let rows = eval_policies(&m, &policies, &ecfg).unwrap();
            let r = &rows[0];
            t.row(&[
                v.paper_name().to_string(),
                format!("{g}"),
                format!("{steps}"),
                format!("{:.3}", r.fid),
                format!("{:.0}", r.time_ms),
                format!("{:+.1}%", r.speedup_pct()),
            ]);
        }
    }
    println!("{}", t.render());
}

/// Table 15: kNN K ablation for token merging.
fn table15() {
    let v = Variant::Xl;
    let m = model(v);
    let mut t = Table::new(
        "Table 15 — kNN K ablation (paper Tab. 15)",
        &["K", "FID↓", "t-FID↓", "Time (ms)↓", "Speedup↑", "Token Reduction↑"],
    );
    for k in [3usize, 5, 7, 10] {
        let mut c = fc(PolicyKind::FastCache);
        c.enable_merge = true;
        c.knn_k = k;
        c.merge_target = 32;
        let policies = vec![(format!("K={k}"), c)];
        let rows = eval_policies(&m, &policies, &quick(v)).unwrap();
        let r = &rows[0];
        t.row(&[
            format!("{k}"),
            format!("{:.3}", r.fid),
            format!("{:.3}", r.tfid),
            format!("{:.0}", r.time_ms),
            format!("{:+.1}%", r.speedup_pct()),
            pct(r.static_ratio),
        ]);
    }
    println!("{}", t.render());
}

/// Kernels: old-vs-new microbench of the native compute layer — the
/// retained scalar oracle (`testutil::oracle`, the pre-PR-4 forward)
/// against the packed/fused/streaming kernels, per variant, at the
/// acceptance shape n = 256 (n = 64 in CI smoke). Wall-ns per call plus
/// the speedup ratio; the block_forward row on DiT-S is the ≥3×
/// acceptance criterion. Rows land in bench_out/BENCH_kernels.json so
/// the trajectory accumulates per PR.
fn kernels() {
    let n = if smoke() { 64 } else { 256 };
    let variants: &[Variant] =
        if smoke() { &[Variant::S, Variant::Xl] } else { &Variant::ALL };
    let b = Bencher::from_env();
    let mut t = Table::new(
        &format!("Kernels — scalar oracle vs packed/fused/streaming (n = {n})"),
        &["Variant", "Op", "Old (ns)↓", "New (ns)↓", "Speedup↑"],
    );
    let mut json_rows = Vec::new();
    for &v in variants {
        let cfg = ModelConfig::of(v);
        let bank = WeightBank::generate(cfg, 0xD17);
        let d = cfg.d;
        let mut rng = Rng::new(0xBE7C);
        let h = Tensor::new(rng.normal_vec(n * d, 1.0), &[n, d]);
        let c = rng.normal_vec(d, 1.0);
        let x = rng.normal_vec(n * d, 1.0);
        let mut arena = ScratchArena::new();
        let mut out = vec![0.0f32; n * d];
        let mut qkv_buf = vec![0.0f32; n * 3 * d];
        let w = &bank.blocks[0];
        let pw = &bank.packed.blocks[0];
        // Warm the arena so the timed path is the steady state.
        native::block_forward_slice(h.data(), n, &c, &cfg, pw, &mut arena, &mut out);

        let mut row = |op: &str, old_ms: f64, new_ms: f64| {
            let (old_ns, new_ns) = (old_ms * 1e6, new_ms * 1e6);
            let ratio = old_ns / new_ns.max(1e-9);
            t.row(&[
                v.paper_name().to_string(),
                op.to_string(),
                format!("{old_ns:.0}"),
                format!("{new_ns:.0}"),
                format!("{ratio:.2}x"),
            ]);
            json_rows.push(format!(
                "{{\"variant\":\"{}\",\"op\":\"{op}\",\"n\":{n},\"old_ns\":{old_ns:.1},\
                 \"new_ns\":{new_ns:.1},\"speedup\":{ratio:.3}}}",
                v.key()
            ));
        };

        let old = b.bench(&format!("kernels/{v}/block_forward/oracle"), || {
            std::hint::black_box(oracle::block_forward(&h, &c, &cfg, w));
        });
        let new = b.bench(&format!("kernels/{v}/block_forward/packed"), || {
            native::block_forward_slice(h.data(), n, &c, &cfg, pw, &mut arena, &mut out);
            std::hint::black_box(&out);
        });
        row("block_forward", old.mean_ms, new.mean_ms);

        // Attention: oracle takes split q/k/v; the streaming kernel reads
        // the fused buffer directly (that indexing IS part of the win).
        let q = rng.normal_vec(n * d, 1.0);
        let k = rng.normal_vec(n * d, 1.0);
        let vv = rng.normal_vec(n * d, 1.0);
        for r in 0..n {
            qkv_buf[r * 3 * d..r * 3 * d + d].copy_from_slice(&q[r * d..(r + 1) * d]);
            qkv_buf[r * 3 * d + d..r * 3 * d + 2 * d].copy_from_slice(&k[r * d..(r + 1) * d]);
            qkv_buf[r * 3 * d + 2 * d..r * 3 * d + 3 * d]
                .copy_from_slice(&vv[r * d..(r + 1) * d]);
        }
        let old = b.bench(&format!("kernels/{v}/attention/oracle"), || {
            std::hint::black_box(oracle::attention(&q, &k, &vv, n, cfg.heads, d));
        });
        let new = b.bench(&format!("kernels/{v}/attention/streaming"), || {
            attention_streaming(&qkv_buf, n, cfg.heads, d, &mut out);
            std::hint::black_box(&out);
        });
        row("attention", old.mean_ms, new.mean_ms);

        // The mlp-up matmul [D, 4D] — the biggest single GEMM of a block.
        let mut mm_out = vec![0.0f32; n * pw.w1.m()];
        let old = b.bench(&format!("kernels/{v}/matmul/oracle"), || {
            std::hint::black_box(oracle::matmul_bias(&x, &w.w1, Some(&w.b1), n));
        });
        let new = b.bench(&format!("kernels/{v}/matmul/packed"), || {
            pw.w1.forward(&x, n, Act::None, &mut mm_out);
            std::hint::black_box(&mm_out);
        });
        row("matmul", old.mean_ms, new.mean_ms);

        // Per-lever rows: each speed lever benched against its own
        // baseline so the per-PR JSON trajectory tracks them
        // independently. All three levers are runtime-selectable; the
        // lanes and threaded paths are bit-identical to scalar serial
        // (rust/tests/threaded_parity.rs, kernel_parity.rs).
        let scalar = b.bench(&format!("kernels/{v}/matmul/scalar"), || {
            pw.w1.forward_kernel(&x, n, Act::None, &mut mm_out, false);
            std::hint::black_box(&mm_out);
        });
        let lanes = b.bench(&format!("kernels/{v}/matmul/lanes"), || {
            pw.w1.forward_kernel(&x, n, Act::None, &mut mm_out, true);
            std::hint::black_box(&mm_out);
        });
        row("matmul_simd", scalar.mean_ms, lanes.mean_ms);

        let threads =
            std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1).min(4);
        let serial = b.bench(&format!("kernels/{v}/matmul/serial"), || {
            pw.w1.forward_t(&x, n, Act::None, &mut mm_out, 1);
            std::hint::black_box(&mm_out);
        });
        let par = b.bench(&format!("kernels/{v}/matmul/threads{threads}"), || {
            pw.w1.forward_t(&x, n, Act::None, &mut mm_out, threads);
            std::hint::black_box(&mm_out);
        });
        row("matmul_threaded", serial.mean_ms, par.mean_ms);

        let serial = b.bench(&format!("kernels/{v}/attention/serial"), || {
            attention_streaming_t(&qkv_buf, n, cfg.heads, d, &mut out, 1);
            std::hint::black_box(&out);
        });
        let par = b.bench(&format!("kernels/{v}/attention/threads{threads}"), || {
            attention_streaming_t(&qkv_buf, n, cfg.heads, d, &mut out, threads);
            std::hint::black_box(&out);
        });
        row("attention_threaded", serial.mean_ms, par.mean_ms);

        let mut arena_t = ScratchArena::new();
        arena_t.set_threads(threads);
        native::block_forward_slice(h.data(), n, &c, &cfg, pw, &mut arena_t, &mut out);
        let serial = b.bench(&format!("kernels/{v}/block_forward/serial"), || {
            native::block_forward_slice(h.data(), n, &c, &cfg, pw, &mut arena, &mut out);
            std::hint::black_box(&out);
        });
        let par = b.bench(&format!("kernels/{v}/block_forward/threads{threads}"), || {
            native::block_forward_slice(h.data(), n, &c, &cfg, pw, &mut arena_t, &mut out);
            std::hint::black_box(&out);
        });
        row("block_threaded", serial.mean_ms, par.mean_ms);

        let mut qb = pw.clone();
        qb.quantize_int8();
        let q1 = &qb.int8.as_ref().unwrap().w1;
        let f32_ms = b.bench(&format!("kernels/{v}/matmul/f32"), || {
            pw.w1.forward(&x, n, Act::None, &mut mm_out);
            std::hint::black_box(&mm_out);
        });
        let int8_ms = b.bench(&format!("kernels/{v}/matmul/int8"), || {
            q1.forward(&x, n, Act::None, &mut mm_out);
            std::hint::black_box(&mm_out);
        });
        row("matmul_int8", f32_ms.mean_ms, int8_ms.mean_ms);

        // Quality row: relative L2 drift of a full block under int8
        // panels — the FID-proxy column for the quantization lever. The
        // `_err`-suffixed field matches neither compare direction, so
        // bench_compare.sh reports it without gating on it.
        let mut q_out = vec![0.0f32; n * d];
        native::block_forward_slice(h.data(), n, &c, &cfg, pw, &mut arena, &mut out);
        native::block_forward_slice(h.data(), n, &c, &cfg, &qb, &mut arena, &mut q_out);
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (a, bq) in out.iter().zip(q_out.iter()) {
            num += f64::from(a - bq).powi(2);
            den += f64::from(*a).powi(2);
        }
        let rel_err = (num / den.max(1e-30)).sqrt();
        t.row(&[
            v.paper_name().to_string(),
            "block_int8".to_string(),
            "-".to_string(),
            "-".to_string(),
            format!("rel err {rel_err:.4}"),
        ]);
        json_rows.push(format!(
            "{{\"variant\":\"{}\",\"op\":\"block_int8\",\"n\":{n},\"int8_rel_err\":{rel_err:.6}}}",
            v.key()
        ));
    }
    println!("{}", t.render());
    write_json("kernels", json_rows);
}

/// Serving: continuous batching over the unified lane stepper. Shows that
/// STR- and merge-enabled configs batch (occupancy > 1) — the old worker
/// served exactly these configs request-at-a-time — and makes the padded
/// B=4 slot overhead visible.
fn serving() {
    let full = std::env::var("BENCH_FULL").as_deref() == Ok("1");
    let (requests, steps) = if smoke() {
        (6, 4)
    } else if full {
        (24, 20)
    } else {
        (12, 8)
    };
    let mut no_str = fc(PolicyKind::FastCache);
    no_str.enable_str = false;
    let with_str = fc(PolicyKind::FastCache); // STR on by default
    let mut with_merge = fc(PolicyKind::FastCache);
    with_merge.enable_str = false;
    with_merge.enable_merge = true;
    with_merge.merge_target = 32;
    let configs = vec![
        ("No Cache".to_string(), fc(PolicyKind::NoCache)),
        ("FastCache (no STR)".to_string(), no_str),
        ("FastCache + STR".to_string(), with_str),
        ("FastCache + merge".to_string(), with_merge),
    ];
    let rows = eval_serving(Variant::S, &configs, requests, steps, 4).unwrap();
    let mut t = Table::new(
        "Serving — continuous batching over the lane stepper",
        &[
            "Config",
            "req/s↑",
            "p50 (ms)↓",
            "p95 (ms)↓",
            "Occupancy↑",
            "Adm p50 (ms)↓",
            "Padded GFLOP↓",
        ],
    );
    for r in &rows {
        t.row(&[
            r.label.clone(),
            format!("{:.2}", r.rps),
            format!("{:.0}", r.p50_ms),
            format!("{:.0}", r.p95_ms),
            format!("{:.2}", r.occupancy),
            format!("{:.1}", r.admission_p50_ms),
            format!("{:.3}", r.padded_gflops),
        ]);
    }
    println!("{}", t.render());
    write_json(
        "serving",
        rows.iter()
            .map(|r| {
                format!(
                    "{{\"label\":\"{}\",\"rps\":{:.4},\"p50_ms\":{:.2},\"p95_ms\":{:.2},\
                     \"occupancy\":{:.3},\"admission_p50_ms\":{:.2},\"padded_gflops\":{:.4}}}",
                    r.label, r.rps, r.p50_ms, r.p95_ms, r.occupancy, r.admission_p50_ms,
                    r.padded_gflops
                )
            })
            .collect(),
    );
}

/// Sharding: the same synthetic burst (with a deadline-tagged SLA slice)
/// served at workers ∈ {1, 2, 4}. The signal is aggregate throughput vs
/// worker count (non-decreasing on multi-core hosts), the deadline-hit
/// rate, and how least-predicted-load routing spread the burst.
fn sharding() {
    let mut e = ShardingEval::quick(Variant::S);
    if smoke() {
        e.requests = 8;
        e.steps = 4;
    }
    let fc = fc(PolicyKind::FastCache);
    let rows = eval_sharding(&fc, &e).unwrap();
    let mut t = Table::new(
        "Sharding — multi-worker serving, SLA-aware admission",
        &[
            "Workers",
            "req/s↑",
            "p50 (ms)↓",
            "p95 (ms)↓",
            "Occupancy↑",
            "Deadline hit↑",
            "Padded GFLOP↓",
            "Per-shard completed",
        ],
    );
    for r in &rows {
        t.row(&[
            format!("{}", r.workers),
            format!("{:.2}", r.rps),
            format!("{:.0}", r.p50_ms),
            format!("{:.0}", r.p95_ms),
            format!("{:.2}", r.occupancy),
            r.deadline_hit_rate.map(pct).unwrap_or_else(|| "n/a".to_string()),
            format!("{:.3}", r.padded_gflops),
            format!("{:?}", r.shard_completed),
        ]);
    }
    println!("{}", t.render());
    write_json(
        "sharding",
        rows.iter()
            .map(|r| {
                format!(
                    "{{\"workers\":{},\"completed\":{},\"wall_s\":{:.4},\"rps\":{:.4},\
                     \"p50_ms\":{:.2},\"p95_ms\":{:.2},\"occupancy\":{:.3},\
                     \"deadline_hit_rate\":{},\"padded_gflops\":{:.4},\"shard_completed\":{:?}}}",
                    r.workers,
                    r.completed,
                    r.wall_s,
                    r.rps,
                    r.p50_ms,
                    r.p95_ms,
                    r.occupancy,
                    r.deadline_hit_rate
                        .map(|v| format!("{v:.4}"))
                        .unwrap_or_else(|| "null".to_string()),
                    r.padded_gflops,
                    r.shard_completed
                )
            })
            .collect(),
    );
}

/// Warm start: the same fixed-seed burst served cold (empty store) vs
/// warm (store populated by the first burst) for the headline policy and
/// the calibration-hungry L2C baseline. The signal: warm lanes execute
/// fewer FLOPs per step at χ²-bounded fidelity, with store hit/miss/
/// eviction counts and stored-bytes ≤ budget reported per phase.
fn warmstart() {
    let mut e = WarmstartEval::quick(Variant::S);
    if smoke() {
        e.requests = 4;
        e.steps = 8;
    }
    let mut t = Table::new(
        "Warm start — cross-request store, cold vs warm bursts",
        &[
            "Policy",
            "Phase",
            "GFLOP/step↓",
            "FLOPs ratio↓",
            "Skip↑",
            "FID↓",
            "Warm lanes",
            "Hit rate↑",
            "Evict",
            "Store KiB (≤ budget)",
        ],
    );
    let mut json_rows = Vec::new();
    for policy in [PolicyKind::FastCache, PolicyKind::L2C] {
        let rows = eval_warmstart(&fc(policy), &e).unwrap();
        for r in &rows {
            assert!(
                r.store.used_bytes <= r.store.budget_bytes,
                "store exceeded its byte budget"
            );
            t.row(&[
                policy.name().to_string(),
                r.phase.clone(),
                format!("{:.3}", r.flops_per_step_g),
                pct(r.flops_ratio),
                pct(r.skip_ratio),
                format!("{:.3}", r.fid),
                format!("{}", r.warm_admissions),
                pct(r.store.hit_rate()),
                format!("{}", r.store.evictions),
                format!(
                    "{:.1} / {:.0}",
                    r.store.used_bytes as f64 / 1024.0,
                    r.store.budget_bytes as f64 / 1024.0
                ),
            ]);
            json_rows.push(format!(
                "{{\"policy\":\"{}\",\"phase\":\"{}\",\"gflop_per_step\":{:.5},\
                 \"flops_ratio\":{:.4},\"skip_ratio\":{:.4},\"fid\":{:.4},\
                 \"warm_admissions\":{},\"warm_layers\":{},\"hits\":{},\"misses\":{},\
                 \"inserts\":{},\"evictions\":{},\"used_bytes\":{},\"budget_bytes\":{}}}",
                policy.name(),
                r.phase,
                r.flops_per_step_g,
                r.flops_ratio,
                r.skip_ratio,
                r.fid,
                r.warm_admissions,
                r.warm_layers,
                r.store.hits,
                r.store.misses,
                r.store.inserts,
                r.store.evictions,
                r.store.used_bytes,
                r.store.budget_bytes
            ));
        }
    }
    println!("{}", t.render());
    write_json("warmstart", json_rows);
}

/// Observability overhead guard: the same fixed-seed burst served with
/// the registry alone (recorder off — the default) vs the flight
/// recorder tracing every lane (rate 1.0, the worst case). The registry
/// is always on, so the delta between the two rows IS the recorder's
/// marginal cost; production sample rates trace a fraction of lanes and
/// pay proportionally less. Methodology: docs/OBSERVABILITY.md.
fn obs() {
    use fastcache_dit::config::ServerConfig;
    use fastcache_dit::server::Server;
    let full = std::env::var("BENCH_FULL").as_deref() == Ok("1");
    let (requests, steps) = if smoke() {
        (6, 4)
    } else if full {
        (24, 20)
    } else {
        (12, 8)
    };
    let mut t = Table::new(
        "Observability — registry only vs flight recorder at rate 1.0",
        &["Config", "req/s↑", "lane-steps/s", "Trace events", "Overhead vs base"],
    );
    let mut json_rows = Vec::new();
    let mut base_rps = 0.0f64;
    for (label, rate) in [("registry only (default)", 0.0f64), ("recorder rate=1.0", 1.0)] {
        let scfg = ServerConfig {
            variant: Variant::S,
            steps,
            workers: 1,
            max_batch: 4,
            trace_sample_rate: rate,
            ..ServerConfig::default()
        };
        let mut cfg = fc(PolicyKind::FastCache);
        cfg.enable_str = false;
        let server = Server::start(scfg, cfg, || Ok(DitModel::native(Variant::S, 0xD17)));
        let recorder = server.recorder();
        let mut wl = WorkloadGen::new(0x0B5);
        let reqs = wl.image_set(requests, steps, MotionProfile::MIXED);
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> =
            reqs.iter().map(|r| server.submit_blocking(r).expect("submit")).collect();
        for rx in rxs {
            rx.wait();
        }
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        server.shutdown();
        let rps = requests as f64 / wall;
        let sps = (requests * steps) as f64 / wall;
        let events = recorder.as_deref().map(|r| r.len() as u64 + r.dropped()).unwrap_or(0);
        let overhead = if base_rps > 0.0 { 1.0 - rps / base_rps } else { 0.0 };
        if rate == 0.0 {
            base_rps = rps;
        }
        t.row(&[
            label.to_string(),
            format!("{rps:.2}"),
            format!("{sps:.1}"),
            format!("{events}"),
            if rate == 0.0 { "baseline".to_string() } else { format!("{:+.1}%", overhead * 100.0) },
        ]);
        json_rows.push(format!(
            "{{\"label\":\"{label}\",\"rps\":{rps:.4},\"lane_steps_per_s\":{sps:.3},\
             \"trace_events\":{events},\"overhead_frac\":{overhead:.4}}}"
        ));
    }
    println!("{}", t.render());
    write_json("obs", json_rows);
}

/// Robustness: the same fixed-seed deadline burst served five ways —
/// clean, under an armed fault plan (one kernel panic mid-flight plus
/// delayed queue pops), with the degrade ladder on under deliberately
/// tight deadlines, and a clean-vs-flap pair with the shard supervisor
/// armed. The signal: a panic costs exactly the faulted request
/// (internal_errors = 1, siblings complete), sheds and internal errors
/// stay visible in the deadline-hit denominator, degradation converts
/// would-be sheds into completed-but-degraded lanes with the rung count
/// on the record, an armed-but-idle supervisor costs nothing, and a
/// flapping kernel costs exactly one supervised restart with every
/// non-poisoned sibling completing. Methodology: docs/ROBUSTNESS.md.
fn robustness() {
    use fastcache_dit::api::{ErrorCode, Outcome};
    use fastcache_dit::config::ServerConfig;
    use fastcache_dit::scheduler::GenRequest;
    use fastcache_dit::server::Server;
    let (requests, steps) = if smoke() { (6u64, 6usize) } else { (12, 10) };
    // (label, fault plan, degrade ladder, per-request deadline ms,
    // shard_restart_after, expected supervised restarts). The generous
    // deadline keeps non-ladder rows about fault cost, not timing; the
    // tight one exists to push lanes onto the ladder. The last two rows
    // are the supervisor pair: same burst, supervisor armed, with and
    // without a flap plan (two typed panics inside one 30s window).
    let configs: [(&str, Option<&str>, bool, f64, usize, u64); 5] = [
        ("clean (faults off)", None, false, 300_000.0, 0, 0),
        (
            "fault plan armed",
            Some("panic step=2 layer=1 req=3; popdelay ms=5 count=2"),
            false,
            300_000.0,
            0,
            0,
        ),
        ("degrade ladder, tight deadlines", None, true, 40.0, 0, 0),
        ("supervisor armed, clean", None, false, 300_000.0, 2, 0),
        (
            "flap plan, supervised restart",
            Some("panic step=1 layer=0 req=1; panic step=2 layer=0 req=2"),
            false,
            300_000.0,
            2,
            1,
        ),
    ];
    let mut t = Table::new(
        "Robustness — fault containment, degradation, self-healing",
        &[
            "Config",
            "req/s↑",
            "Completed",
            "Internal",
            "Shed",
            "Degraded lanes",
            "Rungs",
            "Restarts",
            "Deadline hit",
        ],
    );
    let mut json_rows = Vec::new();
    for (label, plan, degrade, deadline_ms, restart_after, want_restarts) in configs {
        let scfg = ServerConfig {
            variant: Variant::S,
            steps,
            workers: 1,
            max_batch: 4,
            fault_plan: plan.map(str::to_string),
            degrade,
            shard_restart_after: restart_after,
            ..ServerConfig::default()
        };
        let mut cfg = fc(PolicyKind::FastCache);
        cfg.enable_str = false;
        let server = Server::start(scfg, cfg, || Ok(DitModel::native(Variant::S, 0xD17)));
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..requests)
            .map(|i| {
                let req = GenRequest::builder(i, i ^ 0xB0B)
                    .steps(steps)
                    .deadline_ms(deadline_ms)
                    .build()
                    .unwrap();
                server.submit_blocking(&req).expect("submit")
            })
            .collect();
        let (mut completed, mut internal, mut shed, mut degraded) = (0u64, 0u64, 0u64, 0u64);
        for rx in rxs {
            match rx.wait() {
                Outcome::Completed(resp) => {
                    completed += 1;
                    degraded += u64::from(resp.result.degraded);
                }
                Outcome::Rejected(rej) if rej.code == ErrorCode::Internal => internal += 1,
                Outcome::Rejected(_) => shed += 1,
            }
        }
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let report = server.shutdown();
        assert_eq!(report.internal_errors, internal, "report must agree with outcomes");
        assert_eq!(report.degraded_lanes, degraded, "report must agree with outcomes");
        assert_eq!(
            report.shard_restarts, want_restarts,
            "supervised restart count must match the plan ({label})"
        );
        let rps = completed as f64 / wall;
        let hit = report.deadline_hit_rate();
        t.row(&[
            label.to_string(),
            format!("{rps:.2}"),
            format!("{completed}"),
            format!("{internal}"),
            format!("{shed}"),
            format!("{degraded}"),
            format!("{}", report.degrade_rungs),
            format!("{}", report.shard_restarts),
            hit.map(pct).unwrap_or_else(|| "n/a".to_string()),
        ]);
        json_rows.push(format!(
            "{{\"label\":\"{label}\",\"rps\":{rps:.4},\"completed\":{completed},\
             \"internal_errors\":{internal},\"shed\":{shed},\"degraded_lanes\":{degraded},\
             \"degrade_rungs\":{},\"shard_restarts\":{},\"deadline_hit_rate\":{}}}",
            report.degrade_rungs,
            report.shard_restarts,
            hit.map(|v| format!("{v:.4}")).unwrap_or_else(|| "null".to_string())
        ));
    }
    println!("{}", t.render());
    write_json("robustness", json_rows);
}

/// Figure 1: derivative-magnitude heatmap, high- vs low-motion content.
fn fig1() {
    let v = Variant::B;
    let m = model(v);
    for (name, profile) in [
        ("HIGH-motion clip", MotionProfile::STORMY),
        ("LOW-motion clip", MotionProfile::CALM),
    ] {
        let mut wl = WorkloadGen::new(0xF16);
        let req = wl.image_request(16, profile);
        let c = fc(PolicyKind::FastCache);
        let mut eng = DenoiseEngine::new(&m, c);
        let r = eng.generate(&req).unwrap();
        let motion_rate: f64 = r
            .records
            .iter()
            .map(|rec| rec.motion_tokens as f64 / rec.n_tokens as f64)
            .sum::<f64>()
            / r.records.len() as f64;
        println!(
            "## Figure 1 — {name}: mean motion-token rate {:.1}% (|∂h/∂t| map)",
            motion_rate * 100.0
        );
        let turb = req.turbulence.as_ref().unwrap();
        for row in 0..8 {
            let mut line = String::new();
            for col in 0..8 {
                let tok = row * 8 + col;
                line.push(if turb.tokens.contains(&tok) { '#' } else { '.' });
                line.push(' ');
            }
            println!("  {line}");
        }
        println!(
            "  (# = injected motion region => recompute; . = static => cached)\n  cache skip ratio {:.1}%, static token ratio {:.1}%\n",
            r.skip_ratio() * 100.0,
            r.static_ratio() * 100.0
        );
    }
}

/// Figure 3: α sweep — caching ratio vs FID.
fn fig3() {
    let v = Variant::L;
    let m = model(v);
    let mut policies: Vec<(String, FastCacheConfig)> = Vec::new();
    for alpha in [0.01, 0.02, 0.05, 0.08, 0.10] {
        let mut c = fc(PolicyKind::FastCache);
        c.alpha = alpha;
        policies.push((format!("alpha={alpha}"), c));
    }
    let rows = eval_policies(&m, &policies, &quick(v)).unwrap();
    let mut t = Table::new(
        "Figure 3 — α sensitivity (paper Fig. 3)",
        &["alpha", "Caching ratio↑", "FID↓", "Speedup↑"],
    );
    for r in &rows {
        t.row(&[
            r.label.replace("alpha=", ""),
            pct(r.skip_ratio),
            format!("{:.3}", r.fid),
            format!("{:.2}", r.speedup),
        ]);
    }
    println!("{}", t.render());
}

/// Figure 4: qualitative — dump PGM latents with and without FastCache.
fn fig4() {
    let v = Variant::B;
    let m = model(v);
    let mut wl = WorkloadGen::new(0xF46);
    let req = wl.image_request(20, MotionProfile::MIXED);
    std::fs::create_dir_all("bench_out").ok();
    let mut base: Option<Tensor> = None;
    let mut diff = 0.0f32;
    for (tag, policy) in [("original", PolicyKind::NoCache), ("fastcache", PolicyKind::FastCache)] {
        let mut eng = DenoiseEngine::new(&m, fc(policy));
        let r = eng.generate(&req).unwrap();
        for ch in 0..C_IN {
            let path = format!("bench_out/fig4_{tag}_ch{ch}.pgm");
            let mut s = String::from("P2\n8 8\n255\n");
            let data = r.latent.data();
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for i in 0..64 {
                lo = lo.min(data[i * C_IN + ch]);
                hi = hi.max(data[i * C_IN + ch]);
            }
            for row in 0..8 {
                for col in 0..8 {
                    let vraw = data[(row * 8 + col) * C_IN + ch];
                    let px = ((vraw - lo) / (hi - lo).max(1e-6) * 255.0) as i32;
                    s.push_str(&format!("{px} "));
                }
                s.push('\n');
            }
            std::fs::write(&path, s).unwrap();
        }
        if let Some(b) = &base {
            diff = r.latent.max_abs_diff(b);
        } else {
            base = Some(r.latent.clone());
        }
        println!("Figure 4 — wrote bench_out/fig4_{tag}_ch*.pgm");
    }
    println!(
        "Figure 4 — max |original − fastcache| latent deviation: {diff:.4} (structure preserved)\n"
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with("--")).collect();
    let all = args.is_empty() || args.iter().any(|a| a == "all");
    let want = |name: &str| all || args.iter().any(|a| a == name);
    let t0 = std::time::Instant::now();

    if want("table1") {
        table1(false);
    }
    if want("table12") {
        table1(true);
    }
    if want("table2") || want("table9") {
        table2();
    }
    if want("table3") {
        table3();
    }
    if want("table5") {
        table5();
    }
    if want("table6") {
        table6();
    }
    if want("table7") {
        table7();
    }
    if want("table8") {
        table8();
    }
    if want("table10") {
        table10();
    }
    if want("table11") {
        table11();
    }
    if want("table13") {
        table13();
    }
    if want("table14") {
        table14();
    }
    if want("table15") {
        table15();
    }
    if want("kernels") {
        kernels();
    }
    if want("serving") {
        serving();
    }
    if want("sharding") {
        sharding();
    }
    if want("warmstart") {
        warmstart();
    }
    if want("obs") {
        obs();
    }
    if want("robustness") {
        robustness();
    }
    if want("fig1") {
        fig1();
    }
    if want("fig3") {
        fig3();
    }
    if want("fig4") {
        fig4();
    }
    eprintln!("bench_tables done in {:.1}s", t0.elapsed().as_secs_f64());
}
