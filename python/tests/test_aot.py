"""AOT path: lowering to HLO text works, manifest format is stable, and the
lowered computation's HLO text contains an ENTRY the Rust parser accepts."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, model


def test_to_hlo_text_roundtrippable_header():
    lowered, _ = aot.lower_temb(configs.CONFIGS["s"], 1)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text


@pytest.mark.parametrize("cname", ["s"])
def test_lower_block_param_count(cname):
    cfg = configs.CONFIGS[cname]
    lowered, args = aot.lower_block(cfg, 16, 1)
    # h, c + 10 block params
    assert len(args) == 2 + len(model.BLOCK_PARAM_NAMES)
    text = aot.to_hlo_text(lowered)
    # every parameter must appear in the entry computation
    assert text.count("parameter(") >= len(args)


def test_artifact_plan_names_unique_and_complete():
    names = [n for n, _ in aot.artifact_plan(["s", "b", "l", "xl"])]
    assert len(names) == len(set(names))
    # per config: 3 bucket blocks + 1 batched block + 2 temb + 2 final
    #             + 2 embed + 1 linear + 1 saliency + 1 knn = 13
    assert len(names) == 4 * 13
    for c in ["s", "b", "l", "xl"]:
        assert f"block_{c}_n64_b1" in names
        assert f"block_{c}_n64_b4" in names
        assert f"block_{c}_n16_b1" in names
        assert f"linear_approx_{c}_n64_b1" in names


def test_fmt_shape():
    s = jax.ShapeDtypeStruct((1, 64, 96), jnp.float32)
    assert aot.fmt_shape(s) == "f32[1,64,96]"
    s0 = jax.ShapeDtypeStruct((4,), jnp.float32)
    assert aot.fmt_shape(s0) == "f32[4]"


def test_lowered_block_executes_like_model():
    """Execute the lowered stablehlo via jax and compare to model fn —
    guards against lowering changing semantics."""
    cfg = configs.CONFIGS["s"]
    d = cfg["d"]
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 16)
    h = jax.random.normal(ks[0], (1, 16, d))
    c = jax.random.normal(ks[1], (1, d))
    params = []
    for i, sh in enumerate(model.block_param_shapes(d)):
        params.append(jax.random.normal(ks[2 + i], sh) * 0.05)
    want = model.block_forward(h, c, cfg["heads"], *params)
    heads = cfg["heads"]
    got = jax.jit(lambda hh, cc, *p: model.block_forward(hh, cc, heads, *p))(h, c, *params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_manifest_generation(tmp_path):
    """Run the real main() on the smallest config into a temp dir."""
    import sys
    from unittest import mock

    out = tmp_path / "artifacts"
    argv = ["aot", "--out-dir", str(out), "--configs", "s"]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    art_lines = [l for l in manifest if l.startswith("artifact ")]
    assert len(art_lines) == 13
    for line in art_lines:
        name = line.split()[1]
        assert (out / f"{name}.hlo.txt").exists()
        assert "params" in line
