"""L2 correctness: DiT block / temb / final / embed shapes, adaLN-zero
invariants, and vmapped batching consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model


@pytest.fixture(scope="module")
def params_s():
    return model.init_params(jax.random.PRNGKey(0), "s")


def rnd(seed, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale


@pytest.mark.parametrize("cname", list(configs.CONFIGS))
def test_shapes_per_config(cname):
    cfg = configs.CONFIGS[cname]
    d, heads = cfg["d"], cfg["heads"]
    temb, blocks, final = model.init_params(jax.random.PRNGKey(1), cname)
    assert len(blocks) == cfg["layers"]
    h = rnd(2, (1, configs.N_TOKENS, d))
    t = jnp.array([7.0])
    c = model.temb_forward(t, *temb)
    assert c.shape == (1, d)
    h2 = model.block_forward(h, c, heads, *blocks[0])
    assert h2.shape == h.shape
    out = model.final_forward(h2, c, *final)
    assert out.shape == (1, configs.N_TOKENS, configs.C_IN)


def test_adaln_zero_block_is_identity_at_init(params_s):
    """adaLN-zero: modulation weights start at zero => gates are zero =>
    the block is the identity function at init (the DiT init invariant)."""
    _, blocks, _ = params_s
    h = rnd(3, (1, 64, 96))
    c = rnd(4, (1, 96))
    out = model.block_forward(h, c, 3, *blocks[0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(h), rtol=1e-5, atol=1e-5)


def test_block_nonidentity_with_nonzero_mod(params_s):
    _, blocks, _ = params_s
    params = list(blocks[0])
    params[8] = rnd(5, params[8].shape, scale=0.02)  # wmod
    h = rnd(6, (1, 64, 96))
    c = rnd(7, (1, 96))
    out = model.block_forward(h, c, 3, *params)
    assert float(jnp.abs(out - h).max()) > 1e-4


def test_block_vmap_consistency(params_s):
    """Batched forward == per-example forwards stacked."""
    _, blocks, _ = params_s
    params = list(blocks[0])
    params[8] = rnd(8, params[8].shape, scale=0.02)
    h = rnd(9, (3, 64, 96))
    c = rnd(10, (3, 96))
    batched = model.block_forward(h, c, 3, *params)
    singles = jnp.stack(
        [model.block_forward(h[i : i + 1], c[i : i + 1], 3, *params)[0] for i in range(3)]
    )
    np.testing.assert_allclose(np.asarray(batched), np.asarray(singles), rtol=1e-5, atol=1e-5)


def test_layer_norm_is_normalized():
    x = rnd(11, (4, 64, 96), scale=3.0) + 2.0
    y = model.layer_norm(x)
    mu = np.asarray(jnp.mean(y, axis=-1))
    sd = np.asarray(jnp.std(y, axis=-1))
    np.testing.assert_allclose(mu, np.zeros_like(mu), atol=1e-4)
    np.testing.assert_allclose(sd, np.ones_like(sd), atol=1e-3)


def test_timestep_embedding_distinct_and_bounded():
    t = jnp.array([0.0, 1.0, 10.0, 100.0, 999.0])
    e = model.timestep_embedding(t, 96)
    assert e.shape == (5, 96)
    assert float(jnp.abs(e).max()) <= 1.0 + 1e-6
    # distinct timesteps -> distinct embeddings
    d = np.asarray(jnp.sum((e[:, None] - e[None, :]) ** 2, -1))
    off = d[~np.eye(5, dtype=bool)]
    assert (off > 1e-3).all()


def test_temb_deterministic(params_s):
    temb, _, _ = params_s
    t = jnp.array([13.0])
    a = model.temb_forward(t, *temb)
    b = model.temb_forward(t, *temb)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_embed_forward_shapes():
    x = rnd(12, (2, 64, configs.C_IN))
    w = rnd(13, (configs.C_IN, 96))
    b = rnd(14, (96,))
    e = model.embed_forward(x, w, b)
    assert e.shape == (2, 64, 96)
    np.testing.assert_allclose(
        np.asarray(e), np.asarray(x @ w + b), rtol=1e-5, atol=1e-5
    )


def test_full_dit_forward_finite(params_s):
    temb, blocks, final = params_s
    # randomize modulation so blocks actually do work
    blocks = [
        tuple(p if i != 8 else rnd(20 + j, p.shape, scale=0.02) for i, p in enumerate(bp))
        for j, bp in enumerate(blocks)
    ]
    h = rnd(15, (1, 64, 96))
    out = model.dit_forward(h, jnp.array([25.0]), 3, temb, blocks, final)
    assert out.shape == (1, 64, configs.C_IN)
    assert bool(jnp.isfinite(out).all())


def test_param_shape_tables_consistent():
    for cname, cfg in configs.CONFIGS.items():
        d = cfg["d"]
        shapes = model.block_param_shapes(d)
        assert len(shapes) == len(model.BLOCK_PARAM_NAMES)
        assert shapes[0] == (d, 3 * d)
        assert shapes[-2] == (d, 6 * d)
        assert cfg["d"] % cfg["heads"] == 0, cname
