"""L1 correctness: every Pallas kernel (interpret=True) vs its pure-jnp
oracle in ref.py, swept over shapes and dtypes with hypothesis.

This is the core numerical signal of the compile path: if these pass, the
HLO the Rust runtime executes computes what the paper's equations say.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    attention,
    knn_density,
    linear_approx,
    pairwise_sqdist,
    saliency,
)
from compile.kernels import ref

SHAPE_N = st.sampled_from([1, 4, 16, 33, 64])
SHAPE_D = st.sampled_from([8, 96, 100, 192, 288])
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])


def rng_array(seed, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# saliency
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=SHAPE_N, d=SHAPE_D, dtype=DTYPES, seed=st.integers(0, 2**16))
def test_saliency_matches_ref(n, d, dtype, seed):
    x = rng_array(seed, (n, d), dtype)
    p = rng_array(seed + 1, (n, d), dtype)
    got = saliency(x, p)
    want = ref.saliency_ref(x, p)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * d)


def test_saliency_zero_for_identical_states():
    x = rng_array(0, (64, 96))
    np.testing.assert_allclose(saliency(x, x), np.zeros(64), atol=0.0)


def test_saliency_scales_quadratically():
    x = rng_array(1, (16, 32))
    p = jnp.zeros_like(x)
    s1 = saliency(x, p)
    s2 = saliency(2.0 * x, p)
    np.testing.assert_allclose(s2, 4.0 * s1, rtol=1e-5)


def test_saliency_detects_single_moving_token():
    x = rng_array(2, (64, 96))
    p = x.at[17].add(3.0)
    s = np.asarray(saliency(x, p))
    assert s.argmax() == 17
    assert s[17] > 10 * np.delete(s, 17).max() if np.delete(s, 17).max() > 0 else True


# ---------------------------------------------------------------------------
# linear_approx
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=SHAPE_N, d=st.sampled_from([8, 96, 128, 288]), seed=st.integers(0, 2**16))
def test_linear_approx_matches_ref(n, d, seed):
    h = rng_array(seed, (n, d))
    w = rng_array(seed + 1, (d, d), scale=d ** -0.5)
    b = rng_array(seed + 2, (d,))
    got = linear_approx(h, w, b)
    want = ref.linear_approx_ref(h, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_linear_approx_rectangular():
    h = rng_array(3, (32, 96))
    w = rng_array(4, (96, 192), scale=0.1)
    b = rng_array(5, (192,))
    np.testing.assert_allclose(
        linear_approx(h, w, b), ref.linear_approx_ref(h, w, b), rtol=1e-4, atol=1e-4
    )


def test_linear_approx_identity_weights():
    h = rng_array(6, (64, 96))
    w = jnp.eye(96)
    b = jnp.zeros(96)
    np.testing.assert_allclose(linear_approx(h, w, b), h, rtol=1e-6, atol=1e-6)


def test_linear_approx_bias_only():
    h = jnp.zeros((16, 32))
    w = jnp.zeros((32, 32))
    b = rng_array(7, (32,))
    got = np.asarray(linear_approx(h, w, b))
    np.testing.assert_allclose(got, np.broadcast_to(np.asarray(b), (16, 32)), rtol=1e-6)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    h=st.sampled_from([1, 3, 9]),
    n=st.sampled_from([4, 16, 64]),
    dh=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(h, n, dh, seed):
    q = rng_array(seed, (h, n, dh))
    k = rng_array(seed + 1, (h, n, dh))
    v = rng_array(seed + 2, (h, n, dh))
    np.testing.assert_allclose(
        attention(q, k, v), ref.attention_ref(q, k, v), rtol=1e-4, atol=1e-5
    )


def test_attention_rows_are_convex_combinations():
    """softmax rows sum to 1 => output within [min(v), max(v)] per dim."""
    q = rng_array(10, (2, 16, 8), scale=5.0)
    k = rng_array(11, (2, 16, 8), scale=5.0)
    v = rng_array(12, (2, 16, 8))
    out = np.asarray(attention(q, k, v))
    vmin = np.asarray(v).min(axis=1, keepdims=True) - 1e-5
    vmax = np.asarray(v).max(axis=1, keepdims=True) + 1e-5
    assert (out >= vmin).all() and (out <= vmax).all()


def test_attention_uniform_when_keys_identical():
    """Identical keys => uniform attention => output = mean of V rows."""
    q = rng_array(13, (1, 8, 4))
    k = jnp.broadcast_to(rng_array(14, (1, 1, 4)), (1, 8, 4))
    v = rng_array(15, (1, 8, 4))
    out = np.asarray(attention(q, k, v))
    want = np.broadcast_to(np.asarray(v).mean(axis=1, keepdims=True), out.shape)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


def test_attention_numerically_stable_large_logits():
    q = rng_array(16, (1, 8, 4), scale=100.0)
    k = rng_array(17, (1, 8, 4), scale=100.0)
    v = rng_array(18, (1, 8, 4))
    out = np.asarray(attention(q, k, v))
    assert np.isfinite(out).all()


# ---------------------------------------------------------------------------
# knn density / pairwise distances
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([4, 16, 64]), d=st.sampled_from([8, 96, 288]), seed=st.integers(0, 2**16))
def test_pairwise_sqdist_matches_ref(n, d, seed):
    x = rng_array(seed, (n, d))
    np.testing.assert_allclose(
        pairwise_sqdist(x), ref.pairwise_sqdist_ref(x), rtol=1e-4, atol=1e-3
    )


def test_pairwise_sqdist_diagonal_zero_and_symmetric():
    x = rng_array(20, (32, 48))
    d2 = np.asarray(pairwise_sqdist(x))
    np.testing.assert_allclose(np.diag(d2), np.zeros(32), atol=1e-3)
    np.testing.assert_allclose(d2, d2.T, rtol=1e-4, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([8, 64]), k=st.sampled_from([1, 3, 5, 7]), seed=st.integers(0, 2**16))
def test_knn_density_matches_ref(n, k, seed):
    x = rng_array(seed, (n, 32))
    np.testing.assert_allclose(
        knn_density(x, k), ref.knn_density_ref(x, k), rtol=1e-4, atol=1e-5
    )


def test_knn_density_in_unit_interval():
    # exp(-mean kNN distance) in [0, 1]; underflows to 0 for far tokens.
    x = rng_array(21, (64, 96))
    rho = np.asarray(knn_density(x, 5))
    assert (rho >= 0).all() and (rho <= 1.0 + 1e-6).all()


def test_knn_density_cluster_center_is_densest():
    """A tight cluster + one far outlier: outlier has the lowest density."""
    x = np.array(rng_array(22, (16, 8), scale=0.01))
    x[0] += 50.0
    rho = np.asarray(knn_density(jnp.asarray(x), 3))
    assert rho.argmin() == 0
