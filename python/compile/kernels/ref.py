"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: pytest asserts each Pallas kernel
(interpret=True) against these references across shapes and dtypes
(hypothesis sweeps in python/tests/test_kernels.py). They are also the
numerical spec for the Rust native fallbacks in rust/src/model/native.rs
(tested with the same seeds and tolerances on the Rust side).
"""

import jax
import jax.numpy as jnp


def saliency_ref(x_t, x_prev):
    """Token-wise temporal saliency S_t = ||x_t - x_{t-1}||_2^2  (paper Eq. 1).

    x_t, x_prev: [N, D] -> [N]
    """
    d = (x_t - x_prev).astype(jnp.float32)
    return jnp.sum(d * d, axis=-1)


def linear_approx_ref(h, w, b):
    """Learnable linear approximation H W + b  (paper Eq. 3 / Eq. 6).

    h: [N, D], w: [D, D], b: [D] -> [N, D]
    """
    return (h.astype(jnp.float32) @ w.astype(jnp.float32)) + b.astype(jnp.float32)


def attention_ref(q, k, v):
    """Multi-head attention, heads batched on the leading axis.

    q, k, v: [H, N, dh] -> [H, N, dh]
    """
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum("hnd,hmd->hnm", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hnm,hmd->hnd", p, v.astype(jnp.float32))


def pairwise_sqdist_ref(x):
    """Pairwise squared L2 distances. x: [N, D] -> [N, N]."""
    x = x.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return jnp.maximum(d2, 0.0)


def knn_density_ref(x, k):
    """Spatial kNN density rho_sp (paper Eq. 10), self excluded.

    rho_i = exp(-(1/K) * sum_{j in kNN(i)} ||x_i - x_j||^2).
    x: [N, D] -> [N]
    """
    d2 = pairwise_sqdist_ref(x)
    n = x.shape[0]
    d2 = d2 + jnp.eye(n, dtype=jnp.float32) * jnp.float32(1e30)
    neg_topk, _ = jax.lax.top_k(-d2, k)  # k smallest distances per row
    mean_k = -jnp.mean(neg_topk, axis=-1)
    return jnp.exp(-mean_k)


def delta_rel_ref(h, h_prev):
    """Relative Frobenius change delta_{t,l}  (paper Eq. 4).

    h, h_prev: [N, D] -> scalar
    """
    num = jnp.linalg.norm((h - h_prev).astype(jnp.float32))
    den = jnp.linalg.norm(h_prev.astype(jnp.float32))
    return num / jnp.maximum(den, 1e-12)
