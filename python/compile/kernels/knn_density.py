"""Pallas kernel: pairwise squared distances for kNN spatial density
(paper Eq. 10, the token-merging importance score).

rho_sp,i = exp(-(1/K) * sum_{j in kNN(i)} ||h_i - h_j||^2)

The FLOPs hot-spot is the N x N distance matrix (an MXU-friendly
-2 X X^T + row/col squared-norm rank-1 update); the kernel computes row
tiles of it against the full token set, with the D contraction on the MXU.
Top-k selection is a tiny O(N K) data-dependent step that stays in jnp
(lax.top_k) — selection is not MXU work and would serialize a Pallas kernel.

VMEM per grid step: (BN*D + N*D + BN*N) * 4B, e.g. at dit-xl
(16*288 + 64*288 + 16*64) * 4B ≈ 95 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sqdist_kernel(xr_ref, xc_ref, o_ref):
    xr = xr_ref[...].astype(jnp.float32)  # [BN, D]
    xc = xc_ref[...].astype(jnp.float32)  # [N, D]
    cross = jnp.dot(xr, xc.T, preferred_element_type=jnp.float32)
    sq_r = jnp.sum(xr * xr, axis=-1, keepdims=True)
    sq_c = jnp.sum(xc * xc, axis=-1, keepdims=True).T
    o_ref[...] = jnp.maximum(sq_r + sq_c - 2.0 * cross, 0.0)


def _row_tile(n: int) -> int:
    for cand in (16, 8, 4, 2, 1):
        if n % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=())
def pairwise_sqdist(x):
    """Pairwise squared L2 distances. x: [N, D] -> [N, N] (f32)."""
    n, d = x.shape
    bn = _row_tile(n)
    return pl.pallas_call(
        _sqdist_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(x, x)


@functools.partial(jax.jit, static_argnames=("k",))
def knn_density(x, k: int):
    """Spatial kNN density rho_sp, self excluded. x: [N, D] -> [N]."""
    n = x.shape[0]
    d2 = pairwise_sqdist(x)
    d2 = d2 + jnp.eye(n, dtype=jnp.float32) * jnp.float32(1e30)
    neg_topk, _ = jax.lax.top_k(-d2, k)
    return jnp.exp(jnp.mean(neg_topk, axis=-1))
