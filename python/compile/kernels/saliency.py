"""Pallas kernel: token-wise temporal saliency (paper Eq. 1).

S_t^{(i)} = || x_t^{(i)} - x_{t-1}^{(i)} ||_2^2        i = 1..N

Hardware adaptation (CUDA -> TPU thinking): the paper computes this with an
elementwise CUDA kernel + per-token reduction through shared memory. Here the
subtract-square-reduce is fused into ONE VMEM pass: a grid over token tiles,
each tile (BLOCK_N, D) streamed HBM->VMEM once, reduced on the VPU with no
(N, D) temporary written back to HBM. VMEM footprint per grid step:
BLOCK_N * D * 4 bytes (e.g. 32 * 288 * 4 = 36 KiB at dit-xl), far under the
~16 MiB VMEM budget, so the kernel is purely bandwidth-bound — one read of
each input, one write of the [N] output.

interpret=True everywhere: CPU PJRT cannot execute Mosaic custom-calls; the
interpreter path lowers to plain HLO so the Rust runtime can run it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _saliency_kernel(x_ref, p_ref, o_ref):
    d = x_ref[...].astype(jnp.float32) - p_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(d * d, axis=-1)


def _pick_block_n(n: int) -> int:
    for cand in (32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=())
def saliency(x_t, x_prev):
    """Token-wise saliency. x_t, x_prev: [N, D] -> [N] (f32)."""
    n, d = x_t.shape
    block_n = _pick_block_n(n)
    return pl.pallas_call(
        _saliency_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x_t, x_prev)
