"""L1 Pallas kernels (interpret=True) + pure-jnp oracles (ref.py).

Import surface used by model.py and the tests:
    saliency, linear_approx, attention, pairwise_sqdist, knn_density
"""

from .attention import attention
from .knn_density import knn_density, pairwise_sqdist
from .linear_approx import linear_approx
from .saliency import saliency

__all__ = [
    "attention",
    "knn_density",
    "pairwise_sqdist",
    "linear_approx",
    "saliency",
]
