"""Pallas kernel: blocked multi-head attention for the DiT block.

Hardware adaptation: the paper's DiT baseline uses CUDA flash-attention
(threadblock-tiled softmax(QK^T)V with shared-memory K/V tiles). The TPU
rethink: grid over heads, each grid step holds one head's full (N, dh) Q, K,
V in VMEM (N=64, dh=32 -> 3 * 8 KiB) plus the (N, N) logits tile (16 KiB) —
the whole head fits comfortably, so no online-softmax streaming is needed at
serving resolution; the QK^T and PV contractions both feed the MXU. For
larger N the BlockSpec splits queries into q-tiles (second grid axis) while
K/V stay resident, which is exactly the flash-attention schedule expressed
as a Pallas BlockSpec instead of a threadblock loop.

Numerically this is standard max-subtracted softmax in f32.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0].astype(jnp.float32)  # [BQ, dh]
    k = k_ref[0].astype(jnp.float32)  # [N, dh]
    v = v_ref[0].astype(jnp.float32)  # [N, dh]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def _q_tile(n: int) -> int:
    for cand in (64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=())
def attention(q, k, v):
    """softmax(QK^T/sqrt(dh)) V per head. q,k,v: [H, N, dh] -> [H, N, dh]."""
    h, n, dh = q.shape
    bq = _q_tile(n)
    return pl.pallas_call(
        _attn_kernel,
        grid=(h, n // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, n, dh), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n, dh), jnp.float32),
        interpret=True,
    )(q, k, v)
