"""Pallas kernel: learnable linear approximation H W + b (paper Eq. 3 / 6).

This is the compute path that replaces a skipped transformer block for
static tokens (Eq. 3) and for statistically-cached blocks (Eq. 6).

Hardware adaptation: the paper runs a cuBLAS GEMM per skipped block. On TPU
the same operation targets the MXU systolic array: a (BM, BK) x (BK, BN)
tiled matmul with an f32 accumulator tile held in VMEM across the K loop
(grid order (m, n, k) with k innermost so the output tile is revisited, the
canonical Pallas accumulation pattern). Tiles are capped at 128 — the MXU
native dimension — and shrink to the actual D for the small serving configs.
VMEM per step: (BM*BK + BK*BN + BM*BN) * 4B <= 3 * 128^2 * 4B = 192 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(h_ref, w_ref, b_ref, o_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.broadcast_to(b_ref[...].astype(jnp.float32), o_ref.shape)

    o_ref[...] += jnp.dot(
        h_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _tile(dim: int, cap: int = 128) -> int:
    for cand in (cap, 64, 32, 16, 8, 4, 2, 1):
        if cand <= cap and dim % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=())
def linear_approx(h, w, b):
    """H W + b. h: [N, D], w: [D, Dout], b: [Dout] -> [N, Dout] (f32)."""
    n, d = h.shape
    d2, dout = w.shape
    assert d == d2, (d, d2)
    bm, bk, bn = _tile(n), _tile(d), _tile(dout)
    k_steps = d // bk
    kernel = functools.partial(_matmul_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(n // bm, dout // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, dout), jnp.float32),
        interpret=True,
    )(h, w, b)
