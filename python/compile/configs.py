"""Model-variant table, mirrored exactly by rust/src/config/model.rs.

The four DiT variants of the paper (DiT-S/2..XL/2) scaled for single-core
CPU PJRT execution: same depth *ratios* and adaLN-zero block structure, a
uniform head_dim of 32, and a fixed 8x8 latent grid (N=64 tokens, 4 latent
channels — the Stable-Diffusion-VAE latent layout the paper uses).

Shape buckets: the serving coordinator pads motion-token sets to the next
bucket so every executable has a static shape (vLLM-style bucketing).
"""

# name -> (layers, hidden dim D, attention heads)
CONFIGS = {
    "s": dict(layers=3, d=96, heads=3),
    "b": dict(layers=6, d=192, heads=6),
    "l": dict(layers=12, d=256, heads=8),
    "xl": dict(layers=14, d=288, heads=9),
}

N_TOKENS = 64          # 8x8 latent patches
C_IN = 4               # latent channels
MLP_RATIO = 4
TOKEN_BUCKETS = (16, 32, 64)   # token-count buckets for reduced paths
BATCH_SIZES = (1, 4)           # compiled batch sizes for full-N serving


def head_dim(cfg: dict) -> int:
    assert cfg["d"] % cfg["heads"] == 0
    return cfg["d"] // cfg["heads"]
