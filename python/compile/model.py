"""L2: DiT forward pieces in JAX, calling the L1 Pallas kernels.

Mirrors the Meta DiT (Peebles & Xie 2023) block exactly in structure —
adaLN-zero conditioning, pre-LN MHA + pre-LN MLP with gated residuals —
at the serving-scale dims of configs.CONFIGS.

These functions are the AOT units: aot.py lowers each one, per model config
and shape bucket, to HLO text that the Rust coordinator loads at startup.
Weights are FUNCTION PARAMETERS, not constants — one compiled block
executable serves every layer of a model (the Rust side passes per-layer
weight Literals). That is the key serving-framework decision: dit-xl needs
one block compile, not 14.

All functions take a leading batch axis B; per-example math is vmapped so
batched serving (B=4 artifacts) reuses the identical per-example graph.
"""

import jax
import jax.numpy as jnp

from . import configs
from .kernels import attention, linear_approx, saliency

# ---------------------------------------------------------------------------
# Weight pytree layout (order matters: it is the Rust-side calling convention)
# ---------------------------------------------------------------------------

BLOCK_PARAM_NAMES = (
    "wqkv",   # [D, 3D]
    "bqkv",   # [3D]
    "wo",     # [D, D]
    "bo",     # [D]
    "w1",     # [D, 4D]  MLP in
    "b1",     # [4D]
    "w2",     # [4D, D]  MLP out
    "b2",     # [D]
    "wmod",   # [D, 6D]  adaLN modulation
    "bmod",   # [6D]
)

TEMB_PARAM_NAMES = ("w1", "b1", "w2", "b2")          # [D,D],[D],[D,D],[D]
FINAL_PARAM_NAMES = ("wmod", "bmod", "wout", "bout")  # [D,2D],[2D],[D,C],[C]


def block_param_shapes(d: int):
    """Shapes of the per-layer block weights, in calling-convention order."""
    return (
        (d, 3 * d), (3 * d,),
        (d, d), (d,),
        (d, configs.MLP_RATIO * d), (configs.MLP_RATIO * d,),
        (configs.MLP_RATIO * d, d), (d,),
        (d, 6 * d), (6 * d,),
    )


def temb_param_shapes(d: int):
    return ((d, d), (d,), (d, d), (d,))


def final_param_shapes(d: int, c: int = configs.C_IN):
    return ((d, 2 * d), (2 * d,), (d, c), (c,))


# ---------------------------------------------------------------------------
# Primitive pieces
# ---------------------------------------------------------------------------

def layer_norm(x, eps: float = 1e-6):
    """Parameter-free LayerNorm (DiT uses elementwise_affine=False under adaLN)."""
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def timestep_embedding(t, d: int, max_period: float = 10000.0):
    """Sinusoidal timestep embedding. t: [B] -> [B, d]."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


# ---------------------------------------------------------------------------
# AOT units
# ---------------------------------------------------------------------------

def temb_forward(t, w1, b1, w2, b2):
    """Timestep -> conditioning embedding. t: [B] -> [B, D].

    sinusoidal(D) -> Linear -> SiLU -> Linear, as in the DiT TimestepEmbedder.
    """
    d = w1.shape[0]
    e = timestep_embedding(t, d)
    e = jax.nn.silu(e @ w1 + b1)
    return e @ w2 + b2


def _block_one(h, c, heads, wqkv, bqkv, wo, bo, w1, b1, w2, b2, wmod, bmod):
    """adaLN-zero DiT block for ONE example. h: [N, D], c: [D] -> [N, D]."""
    n, d = h.shape
    dh = d // heads
    mod = jax.nn.silu(c) @ wmod + bmod                       # [6D]
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6)

    # Attention branch (L1 Pallas kernel does the softmax(QK^T)V hot-spot).
    x = layer_norm(h) * (1.0 + sc1) + sh1
    qkv = x @ wqkv + bqkv                                    # [N, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    to_heads = lambda y: y.reshape(n, heads, dh).transpose(1, 0, 2)
    a = attention(to_heads(q), to_heads(k), to_heads(v))     # [H, N, dh]
    a = a.transpose(1, 0, 2).reshape(n, d)
    h = h + g1 * (a @ wo + bo)

    # MLP branch.
    x = layer_norm(h) * (1.0 + sc2) + sh2
    h = h + g2 * (jax.nn.gelu(x @ w1 + b1) @ w2 + b2)
    return h


def block_forward(h, c, heads: int, *params):
    """One DiT block, batched. h: [B, N, D], c: [B, D] -> [B, N, D]."""
    f = lambda hh, cc: _block_one(hh, cc, heads, *params)
    return jax.vmap(f)(h, c)


def embed_forward(x, wemb, bemb):
    """Patch/latent embedding: [B, N, C] @ [C, D] + [D] -> [B, N, D]."""
    return x @ wemb + bemb


def final_forward(h, c, wmod, bmod, wout, bout):
    """DiT final layer: adaLN -> linear to latent channels.

    h: [B, N, D], c: [B, D] -> [B, N, C].
    """
    def one(hh, cc):
        mod = jax.nn.silu(cc) @ wmod + bmod
        sh, sc = jnp.split(mod, 2)
        x = layer_norm(hh) * (1.0 + sc) + sh
        return x @ wout + bout
    return jax.vmap(one)(h, c)


def linear_approx_forward(h, w, b):
    """Learnable linear substitute for a skipped block (paper Eq. 3/6).

    h: [B, N, D] -> [B, N, D], via the L1 Pallas tiled matmul.
    """
    return jax.vmap(lambda hh: linear_approx(hh, w, b))(h)


def saliency_forward(x_t, x_prev):
    """Batched token saliency (paper Eq. 1). [B, N, D] x2 -> [B, N]."""
    return jax.vmap(saliency)(x_t, x_prev)


# ---------------------------------------------------------------------------
# Whole-model reference (used by tests and by aot self-check; NOT an AOT unit
# — the Rust coordinator owns the layer loop so it can make cache decisions
# between blocks)
# ---------------------------------------------------------------------------

def dit_forward(h, t, heads: int, temb_params, block_params_list, final_params):
    """Full DiT forward: embed t, run L blocks, final projection."""
    c = temb_forward(t, *temb_params)
    for bp in block_params_list:
        h = block_forward(h, c, heads, *bp)
    return final_forward(h, c, *final_params)


def init_params(key, cfg_name: str):
    """Seeded init of a full variant's weights (tests / self-check only —
    the serving weights are generated Rust-side with the same layout)."""
    cfg = configs.CONFIGS[cfg_name]
    d, nl = cfg["d"], cfg["layers"]

    def dense(k, shape, scale=None):
        fan_in = shape[0] if len(shape) == 2 else shape[0]
        s = scale if scale is not None else (1.0 / jnp.sqrt(jnp.float32(fan_in)))
        return jax.random.normal(k, shape, jnp.float32) * s

    keys = jax.random.split(key, 3 + nl)
    temb = tuple(
        dense(kk, sh) if len(sh) == 2 else jnp.zeros(sh, jnp.float32)
        for kk, sh in zip(jax.random.split(keys[0], 4), temb_param_shapes(d))
    )
    blocks = []
    for i in range(nl):
        bks = jax.random.split(keys[3 + i], len(BLOCK_PARAM_NAMES))
        params = []
        for kk, name, sh in zip(bks, BLOCK_PARAM_NAMES, block_param_shapes(d)):
            if len(sh) == 1:
                params.append(jnp.zeros(sh, jnp.float32))
            elif name == "wmod":
                # adaLN-zero: gates start at zero => identity block at init.
                params.append(jnp.zeros(sh, jnp.float32))
            else:
                params.append(dense(kk, sh))
        blocks.append(tuple(params))
    fks = jax.random.split(keys[1], 4)
    final = tuple(
        dense(kk, sh) if len(sh) == 2 else jnp.zeros(sh, jnp.float32)
        for kk, sh in zip(fks, final_param_shapes(d))
    )
    return temb, blocks, final
