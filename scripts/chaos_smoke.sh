#!/usr/bin/env bash
# Chaos smoke (docs/ROBUSTNESS.md): boot the network door with an armed
# fault plan — a kernel panic mid-request, a socket reset at the door,
# and a corrupted warm-store snapshot on the next boot — and assert the
# containment story end to end over a real socket:
#   * the panicked request answers a typed Internal; its siblings and the
#     server survive and keep serving,
#   * a client with --retries rides out the injected connection reset,
#   * the drain stays graceful and loses zero admitted responses,
#   * the corrupted snapshot degrades the next boot to a cold store
#     (logged, non-fatal) instead of killing it.
# CI runs exactly this (see .github/workflows/ci.yml, job chaos-smoke).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "chaos_smoke: cargo not found on PATH — install a Rust toolchain (rustup) first" >&2
    exit 1
fi

cargo build --release

BIN=target/release/fastcache-serve
OUT=$(mktemp -d)
SERVER_PID=""
cleanup() {
    [ -n "$SERVER_PID" ] && kill "$SERVER_PID" 2>/dev/null || true
    rm -rf "$OUT"
}
trap cleanup EXIT

SNAP="$OUT/warm.fcws"

# --- boot 1: fault plan armed — one panic at (step 2, layer 0) of
# request id 2, and a reset of the 2nd accepted connection. Warm store
# on, snapshotted to disk at drain.
mkfifo "$OUT/ctl"
"$BIN" serve --native --model s --steps 6 --listen 127.0.0.1:0 --net-max-conns 8 \
    --warm-start --warm-snapshot "$SNAP" \
    --fault-plan "panic step=2 layer=0 req=2; sockreset conn=2" \
    < "$OUT/ctl" > "$OUT/server.log" 2>&1 &
SERVER_PID=$!
exec 9>"$OUT/ctl"

for _ in $(seq 1 100); do
    grep -q "^listening on " "$OUT/server.log" && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "chaos_smoke: server died during startup" >&2
        cat "$OUT/server.log" >&2
        exit 1
    fi
    sleep 0.1
done
ADDR=$(sed -n 's/^listening on //p' "$OUT/server.log" | head -n1)
if [ -z "$ADDR" ]; then
    echo "chaos_smoke: no 'listening on' line after 10s" >&2
    cat "$OUT/server.log" >&2
    exit 1
fi
echo "chaos_smoke: door is up on $ADDR (fault plan armed)"

# --- panic containment: 4 requests on connection 1; request id 2 hits
# the injected panic and must come back as a typed Internal rejection
# while its 3 siblings complete on the same, still-alive server.
"$BIN" client --connect "$ADDR" --requests 4 --steps 6 > "$OUT/panic.log" 2>&1
grep -q "REJECTED (internal" "$OUT/panic.log"
grep -q "client done: 3/4 completed" "$OUT/panic.log"
if ! kill -0 "$SERVER_PID" 2>/dev/null; then
    echo "chaos_smoke: server died on an injected panic — containment failed" >&2
    cat "$OUT/server.log" >&2
    exit 1
fi
echo "chaos_smoke: panic containment OK (1 Internal, 3/4 siblings completed, server alive)"

# --- socket-reset retry: the plan resets the 2nd accepted connection;
# a client with a retry budget must absorb it and complete on the next
# accept. (Without --retries this client would die on connect.)
"$BIN" client --connect "$ADDR" --requests 2 --steps 6 --retries 2 \
    > "$OUT/retry.log" 2>&1
grep -q "client done: 2/2 completed" "$OUT/retry.log"
echo "chaos_smoke: injected connection reset absorbed by --retries 2 (2/2 completed)"

# --- graceful drain under an armed plan: report printed, Internal
# accounted, snapshot saved, exit 0.
echo drain >&9
exec 9>&-
if ! wait "$SERVER_PID"; then
    echo "chaos_smoke: server exited non-zero after drain" >&2
    cat "$OUT/server.log" >&2
    exit 1
fi
SERVER_PID=""
grep -q "draining..." "$OUT/server.log"
grep -q "faults: 1 requests answered Internal" "$OUT/server.log"
grep -q "warm store: saved" "$OUT/server.log"
[ -f "$SNAP" ] || { echo "chaos_smoke: snapshot file missing after drain" >&2; exit 1; }
echo "chaos_smoke: graceful drain OK (Internal accounted, snapshot saved)"

# --- boot 2: the plan corrupts the snapshot bytes on load. The server
# must log the rejection, start cold, and still serve — corruption is
# never fatal.
mkfifo "$OUT/ctl2"
"$BIN" serve --native --model s --steps 6 --listen 127.0.0.1:0 --net-max-conns 8 \
    --warm-start --warm-snapshot "$SNAP" --degrade \
    --fault-plan "snapcorrupt mode=bitflip" \
    < "$OUT/ctl2" > "$OUT/server2.log" 2>&1 &
SERVER_PID=$!
exec 9>"$OUT/ctl2"

for _ in $(seq 1 100); do
    grep -q "^listening on " "$OUT/server2.log" && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "chaos_smoke: server 2 died during startup — snapshot corruption was fatal" >&2
        cat "$OUT/server2.log" >&2
        exit 1
    fi
    sleep 0.1
done
ADDR=$(sed -n 's/^listening on //p' "$OUT/server2.log" | head -n1)
grep -q "starting cold" "$OUT/server2.log"
echo "chaos_smoke: corrupted snapshot degraded to a cold start (non-fatal)"

"$BIN" client --connect "$ADDR" --requests 2 --steps 6 > "$OUT/cold.log" 2>&1
grep -q "client done: 2/2 completed" "$OUT/cold.log"
echo drain >&9
exec 9>&-
if ! wait "$SERVER_PID"; then
    echo "chaos_smoke: server 2 exited non-zero after drain" >&2
    cat "$OUT/server2.log" >&2
    exit 1
fi
SERVER_PID=""
echo "chaos_smoke: cold-start server served traffic and drained cleanly"
echo "chaos_smoke: OK"
