#!/usr/bin/env bash
# Chaos smoke (docs/ROBUSTNESS.md): boot the network door with an armed
# fault plan — a kernel panic mid-request, a socket reset at the door,
# and a corrupted warm-store snapshot on the next boot — and assert the
# containment story end to end over a real socket:
#   * the panicked request answers a typed Internal; its siblings and the
#     server survive and keep serving,
#   * a client with --retries rides out the injected connection reset,
#   * the drain stays graceful and loses zero admitted responses,
#   * the corrupted snapshot degrades the next boot to a cold store
#     (logged, non-fatal) instead of killing it,
#   * a flapping kernel (two typed panics inside the window) triggers
#     exactly ONE supervised shard restart while the sibling requests
#     complete — and the restart is visible on the wire via
#     `health --connect`,
#   * a stalled step is caught by the stuck-step watchdog: the health
#     probe sees the shard leave Healthy (Unhealthy/Restarting), then
#     recover to Healthy with `restarts 1`, and the wedged request
#     still completes after the supervised restart.
# CI runs exactly this (see .github/workflows/ci.yml, job chaos-smoke).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "chaos_smoke: cargo not found on PATH — install a Rust toolchain (rustup) first" >&2
    exit 1
fi

cargo build --release

BIN=target/release/fastcache-serve
OUT=$(mktemp -d)
SERVER_PID=""
cleanup() {
    [ -n "$SERVER_PID" ] && kill "$SERVER_PID" 2>/dev/null || true
    rm -rf "$OUT"
}
trap cleanup EXIT

SNAP="$OUT/warm.fcws"

# --- boot 1: fault plan armed — one panic at (step 2, layer 0) of
# request id 2, and a reset of the 2nd accepted connection. Warm store
# on, snapshotted to disk at drain.
mkfifo "$OUT/ctl"
"$BIN" serve --native --model s --steps 6 --listen 127.0.0.1:0 --net-max-conns 8 \
    --warm-start --warm-snapshot "$SNAP" \
    --fault-plan "panic step=2 layer=0 req=2; sockreset conn=2" \
    < "$OUT/ctl" > "$OUT/server.log" 2>&1 &
SERVER_PID=$!
exec 9>"$OUT/ctl"

for _ in $(seq 1 100); do
    grep -q "^listening on " "$OUT/server.log" && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "chaos_smoke: server died during startup" >&2
        cat "$OUT/server.log" >&2
        exit 1
    fi
    sleep 0.1
done
ADDR=$(sed -n 's/^listening on //p' "$OUT/server.log" | head -n1)
if [ -z "$ADDR" ]; then
    echo "chaos_smoke: no 'listening on' line after 10s" >&2
    cat "$OUT/server.log" >&2
    exit 1
fi
echo "chaos_smoke: door is up on $ADDR (fault plan armed)"

# --- panic containment: 4 requests on connection 1; request id 2 hits
# the injected panic and must come back as a typed Internal rejection
# while its 3 siblings complete on the same, still-alive server.
"$BIN" client --connect "$ADDR" --requests 4 --steps 6 > "$OUT/panic.log" 2>&1
grep -q "REJECTED (internal" "$OUT/panic.log"
grep -q "client done: 3/4 completed" "$OUT/panic.log"
if ! kill -0 "$SERVER_PID" 2>/dev/null; then
    echo "chaos_smoke: server died on an injected panic — containment failed" >&2
    cat "$OUT/server.log" >&2
    exit 1
fi
echo "chaos_smoke: panic containment OK (1 Internal, 3/4 siblings completed, server alive)"

# --- socket-reset retry: the plan resets the 2nd accepted connection;
# a client with a retry budget must absorb it and complete on the next
# accept. (Without --retries this client would die on connect.)
"$BIN" client --connect "$ADDR" --requests 2 --steps 6 --retries 2 \
    > "$OUT/retry.log" 2>&1
grep -q "client done: 2/2 completed" "$OUT/retry.log"
echo "chaos_smoke: injected connection reset absorbed by --retries 2 (2/2 completed)"

# --- graceful drain under an armed plan: report printed, Internal
# accounted, snapshot saved, exit 0.
echo drain >&9
exec 9>&-
if ! wait "$SERVER_PID"; then
    echo "chaos_smoke: server exited non-zero after drain" >&2
    cat "$OUT/server.log" >&2
    exit 1
fi
SERVER_PID=""
grep -q "draining..." "$OUT/server.log"
grep -q "faults: 1 requests answered Internal" "$OUT/server.log"
grep -q "warm store: saved" "$OUT/server.log"
[ -f "$SNAP" ] || { echo "chaos_smoke: snapshot file missing after drain" >&2; exit 1; }
echo "chaos_smoke: graceful drain OK (Internal accounted, snapshot saved)"

# --- boot 2: the plan corrupts the snapshot bytes on load. The server
# must log the rejection, start cold, and still serve — corruption is
# never fatal.
mkfifo "$OUT/ctl2"
"$BIN" serve --native --model s --steps 6 --listen 127.0.0.1:0 --net-max-conns 8 \
    --warm-start --warm-snapshot "$SNAP" --degrade \
    --fault-plan "snapcorrupt mode=bitflip" \
    < "$OUT/ctl2" > "$OUT/server2.log" 2>&1 &
SERVER_PID=$!
exec 9>"$OUT/ctl2"

for _ in $(seq 1 100); do
    grep -q "^listening on " "$OUT/server2.log" && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "chaos_smoke: server 2 died during startup — snapshot corruption was fatal" >&2
        cat "$OUT/server2.log" >&2
        exit 1
    fi
    sleep 0.1
done
ADDR=$(sed -n 's/^listening on //p' "$OUT/server2.log" | head -n1)
grep -q "starting cold" "$OUT/server2.log"
echo "chaos_smoke: corrupted snapshot degraded to a cold start (non-fatal)"

"$BIN" client --connect "$ADDR" --requests 2 --steps 6 > "$OUT/cold.log" 2>&1
grep -q "client done: 2/2 completed" "$OUT/cold.log"
echo drain >&9
exec 9>&-
if ! wait "$SERVER_PID"; then
    echo "chaos_smoke: server 2 exited non-zero after drain" >&2
    cat "$OUT/server2.log" >&2
    exit 1
fi
SERVER_PID=""
echo "chaos_smoke: cold-start server served traffic and drained cleanly"

# --- boot 3: flap control. Two typed panics on two different requests
# land in one shard's 30s window; --shard-restart-after 2 must order
# exactly ONE supervised restart, the two offenders answer Internal,
# and the two surviving siblings complete through the restart.
mkfifo "$OUT/ctl3"
"$BIN" serve --native --model s --steps 6 --listen 127.0.0.1:0 --net-max-conns 8 \
    --workers 1 --shard-restart-after 2 \
    --fault-plan "panic step=1 layer=0 req=1; panic step=2 layer=0 req=2" \
    < "$OUT/ctl3" > "$OUT/server3.log" 2>&1 &
SERVER_PID=$!
exec 9>"$OUT/ctl3"

for _ in $(seq 1 100); do
    grep -q "^listening on " "$OUT/server3.log" && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "chaos_smoke: server 3 died during startup" >&2
        cat "$OUT/server3.log" >&2
        exit 1
    fi
    sleep 0.1
done
ADDR=$(sed -n 's/^listening on //p' "$OUT/server3.log" | head -n1)
echo "chaos_smoke: door 3 is up on $ADDR (flap plan armed, restart-after 2)"

"$BIN" client --connect "$ADDR" --requests 4 --steps 6 > "$OUT/flap.log" 2>&1
[ "$(grep -c "REJECTED (internal" "$OUT/flap.log")" -eq 2 ] || {
    echo "chaos_smoke: expected exactly 2 Internal rejections under the flap plan" >&2
    cat "$OUT/flap.log" >&2
    exit 1
}
grep -q "client done: 2/4 completed" "$OUT/flap.log"
# The restart is never silent: the wire liveness probe reports it while
# the server is still serving (and all shards are Healthy again).
"$BIN" health --connect "$ADDR" > "$OUT/health_flap.log" 2>&1 || {
    echo "chaos_smoke: health probe reported not-ready after the flap restart" >&2
    cat "$OUT/health_flap.log" >&2
    exit 1
}
grep -q "restarts 1" "$OUT/health_flap.log"
grep -q "shard 0: Healthy" "$OUT/health_flap.log"
echo drain >&9
exec 9>&-
if ! wait "$SERVER_PID"; then
    echo "chaos_smoke: server 3 exited non-zero after drain" >&2
    cat "$OUT/server3.log" >&2
    exit 1
fi
SERVER_PID=""
grep -q "supervisor: 1 supervised shard restart" "$OUT/server3.log"
grep -q "faults: 2 requests answered Internal" "$OUT/server3.log"
echo "chaos_smoke: flap control OK (exactly 1 supervised restart, siblings completed, visible on the wire)"

# --- boot 4: stuck-step watchdog. A 3s busy-wait stall at step 2 wedges
# the only shard; with --step-stall-ms 300 the watchdog must flag it
# (health probe sees a non-Healthy state), escalate to a supervised
# restart, and the wedged request must still complete after replay.
mkfifo "$OUT/ctl4"
"$BIN" serve --native --model s --steps 6 --listen 127.0.0.1:0 --net-max-conns 8 \
    --workers 1 --step-stall-ms 300 \
    --fault-plan "stall step=2 ms=3000" \
    < "$OUT/ctl4" > "$OUT/server4.log" 2>&1 &
SERVER_PID=$!
exec 9>"$OUT/ctl4"

for _ in $(seq 1 100); do
    grep -q "^listening on " "$OUT/server4.log" && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "chaos_smoke: server 4 died during startup" >&2
        cat "$OUT/server4.log" >&2
        exit 1
    fi
    sleep 0.1
done
ADDR=$(sed -n 's/^listening on //p' "$OUT/server4.log" | head -n1)
echo "chaos_smoke: door 4 is up on $ADDR (stall plan armed, watchdog at 300ms)"

"$BIN" client --connect "$ADDR" --requests 1 --steps 6 > "$OUT/stall.log" 2>&1 &
CLIENT_PID=$!

# While the step is wedged the probe must see the shard leave Healthy
# (Unhealthy once flagged, Restarting once the shard consumes the
# escalation) — a watchdog nobody can observe is no watchdog.
SAW_SICK=""
for _ in $(seq 1 60); do
    "$BIN" health --connect "$ADDR" > "$OUT/health_sick.log" 2>&1 || true
    if grep -qE "shard 0: (Unhealthy|Restarting)" "$OUT/health_sick.log"; then
        SAW_SICK=1
        break
    fi
    sleep 0.1
done
[ -n "$SAW_SICK" ] || {
    echo "chaos_smoke: health probe never saw the stalled shard leave Healthy" >&2
    cat "$OUT/health_sick.log" >&2
    exit 1
}
echo "chaos_smoke: watchdog flagged the stalled shard (probe saw $(sed -n 's/.*shard 0: //p' "$OUT/health_sick.log" | head -n1))"

# ...and recovery: the supervised restart completes, the probe goes
# green again (exit 0 requires every shard Healthy) with restarts 1.
RECOVERED=""
for _ in $(seq 1 100); do
    if "$BIN" health --connect "$ADDR" > "$OUT/health_ok.log" 2>&1 \
        && grep -q "restarts 1" "$OUT/health_ok.log"; then
        RECOVERED=1
        break
    fi
    sleep 0.1
done
[ -n "$RECOVERED" ] || {
    echo "chaos_smoke: stalled shard never recovered to Healthy with restarts 1" >&2
    cat "$OUT/health_ok.log" >&2
    exit 1
}
if ! wait "$CLIENT_PID"; then
    echo "chaos_smoke: client on the stalled server failed" >&2
    cat "$OUT/stall.log" >&2
    exit 1
fi
grep -q "client done: 1/1 completed" "$OUT/stall.log"
echo drain >&9
exec 9>&-
if ! wait "$SERVER_PID"; then
    echo "chaos_smoke: server 4 exited non-zero after drain" >&2
    cat "$OUT/server4.log" >&2
    exit 1
fi
SERVER_PID=""
grep -q "supervisor: 1 supervised shard restart" "$OUT/server4.log"
echo "chaos_smoke: watchdog OK (stall flagged on the wire, recovered to Healthy, request completed)"
echo "chaos_smoke: OK"
