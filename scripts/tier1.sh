#!/usr/bin/env bash
# Tier-1 gate (ROADMAP.md): release build + lint + test suite + formatting.
# Run from anywhere; it cd's to the repo root. CI runs exactly this.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found on PATH — install a Rust toolchain (rustup) first" >&2
    exit 1
fi

# Optional cargo feature set for the build/lint/test legs, e.g.
# TIER1_FEATURES="--features simd" — CI runs the gate once per feature
# combination (see .github/workflows/ci.yml). Formatting is
# feature-independent and runs once, unconditionally.
FEATURES=${TIER1_FEATURES:-}

# shellcheck disable=SC2086  # FEATURES is intentionally word-split
cargo build --release $FEATURES

# Lint gate: every target (lib, bins, tests, benches, examples), warnings
# are errors. Skipped only where the clippy component itself is absent
# (some minimal toolchains); CI always installs it, so the gate is real
# there.
if cargo clippy --version >/dev/null 2>&1; then
    # shellcheck disable=SC2086
    cargo clippy --all-targets $FEATURES -- -D warnings
else
    echo "tier1: WARNING — clippy not installed, lint gate skipped (rustup component add clippy)" >&2
fi

# shellcheck disable=SC2086
cargo test -q $FEATURES
cargo fmt --check
echo "tier1: OK"
