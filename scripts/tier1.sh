#!/usr/bin/env bash
# Tier-1 gate (ROADMAP.md): release build + test suite + formatting.
# Run from anywhere; it cd's to the repo root. CI runs exactly this.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found on PATH — install a Rust toolchain (rustup) first" >&2
    exit 1
fi

cargo build --release
cargo test -q
cargo fmt --check
echo "tier1: OK"
