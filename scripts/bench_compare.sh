#!/usr/bin/env bash
# Compare freshly-emitted bench tables (bench_out/BENCH_<table>.json,
# written by `cargo bench --bench bench_tables`) against the most recent
# committed snapshot in bench_history/ and WARN when any metric regressed
# by more than 20%. Warn-only by design: wall-clock tables on shared CI
# runners are noisy, so a regression here flags a PR for a human look
# instead of failing the build. Exit code is always 0 unless the
# comparison itself cannot run sanely.
set -euo pipefail
cd "$(dirname "$0")/.."

THRESHOLD="${BENCH_COMPARE_THRESHOLD:-0.20}"

if ! command -v python3 >/dev/null 2>&1; then
    echo "bench_compare: python3 not found — skipping comparison"
    exit 0
fi

baseline=$(ls bench_history/BENCH_*.json 2>/dev/null | sort -V | tail -n1 || true)
if [ -z "${baseline}" ]; then
    echo "bench_compare: no committed baseline under bench_history/ — nothing to compare"
    exit 0
fi

python3 - "$baseline" "$THRESHOLD" <<'EOF'
import glob
import json
import sys

baseline_path, threshold = sys.argv[1], float(sys.argv[2])
base = json.load(open(baseline_path))
if base.get("provisional"):
    print(f"bench_compare: baseline {baseline_path} is provisional "
          "(authored without a toolchain) — comparisons skipped until a "
          "real snapshot is committed")
    sys.exit(0)
base_tables = base.get("tables", {})
if not any(rows for rows in base_tables.values() if isinstance(rows, list)):
    print(f"bench_compare: baseline {baseline_path} has no measured rows "
          "(empty tables) — nothing to compare against until a populated "
          "snapshot is committed")
    sys.exit(0)

# Metric direction by field-name convention: *_ns / *_ms / *gflop* /
# flops_ratio are lower-is-better; rps / occupancy / speedup / hit
# counters are higher-is-better. Identity fields pair up rows.
LOWER = ("_ns", "_ms", "gflop", "flops_ratio", "gflop_per_step")
HIGHER = ("rps", "occupancy", "speedup", "hit")
IDENT = ("label", "variant", "op", "workers", "phase", "policy", "n")


def direction(field):
    # old_* columns are the frozen scalar-oracle baseline of the kernels
    # table — pure runner noise, never a trajectory metric (the module
    # header says "Do NOT optimize" it). Compare new_* and ratios only.
    if field.startswith("old_"):
        return None
    if any(field.endswith(s) or s in field for s in LOWER):
        return "lower"
    if any(field == s or field.startswith(s) for s in HIGHER):
        return "higher"
    return None


def ident(row):
    return tuple((k, row[k]) for k in IDENT if k in row)


warned = 0
compared = 0
for path in sorted(glob.glob("bench_out/BENCH_*.json")):
    try:
        doc = json.load(open(path))
    except (ValueError, OSError):
        continue
    if not isinstance(doc, dict):
        continue
    name = doc.get("table")
    if name is None or name not in base_tables:
        continue
    base_rows = {ident(r): r for r in base_tables[name] if isinstance(r, dict)}
    for row in doc.get("rows", []):
        if not isinstance(row, dict):
            continue
        ref = base_rows.get(ident(row))
        if ref is None:
            continue
        for field, new in row.items():
            d = direction(field)
            if d is None or not isinstance(new, (int, float)):
                continue
            old = ref.get(field)
            if not isinstance(old, (int, float)) or old <= 0:
                continue
            compared += 1
            ratio = new / old
            regressed = ratio > 1 + threshold if d == "lower" else ratio < 1 - threshold
            if regressed:
                warned += 1
                print(f"bench_compare: WARNING {name} {dict(ident(row))} "
                      f"{field}: {old:.4g} -> {new:.4g} "
                      f"({(ratio - 1) * 100:+.1f}%, {d}-is-better)")

print(f"bench_compare: {compared} metrics compared against "
      f"{baseline_path}, {warned} regression warning(s) "
      f"(threshold {threshold:.0%})")
EOF
