#!/usr/bin/env bash
# Observability smoke (docs/OBSERVABILITY.md): boot `fastcache-serve
# serve --listen` with the flight recorder at sample rate 1.0 and a
# trace dump path, drive traffic over the wire, scrape the live registry
# mid-flight with `fastcache-serve stats`, then drain and validate the
# Chrome trace dump is well-formed JSON with the expected event kinds.
# CI runs exactly this (see .github/workflows/ci.yml, job obs-smoke).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "obs_smoke: cargo not found on PATH — install a Rust toolchain (rustup) first" >&2
    exit 1
fi

cargo build --release

BIN=target/release/fastcache-serve
OUT=$(mktemp -d)
SERVER_PID=""
cleanup() {
    [ -n "$SERVER_PID" ] && kill "$SERVER_PID" 2>/dev/null || true
    rm -rf "$OUT"
}
trap cleanup EXIT

# --- boot: recorder on for every lane, periodic scrape to stderr,
# Chrome trace dumped at drain. Stdin is a held-open fifo so we control
# when the drain happens.
mkfifo "$OUT/ctl"
"$BIN" serve --native --model s --steps 6 --listen 127.0.0.1:0 --net-max-conns 8 \
    --trace-sample-rate 1.0 --trace-out "$OUT/trace.json" --stats-every 1 \
    < "$OUT/ctl" > "$OUT/server.log" 2> "$OUT/server.err" &
SERVER_PID=$!
exec 9>"$OUT/ctl"

for _ in $(seq 1 100); do
    grep -q "^listening on " "$OUT/server.log" && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "obs_smoke: server died during startup" >&2
        cat "$OUT/server.log" "$OUT/server.err" >&2
        exit 1
    fi
    sleep 0.1
done
ADDR=$(sed -n 's/^listening on //p' "$OUT/server.log" | head -n1)
if [ -z "$ADDR" ]; then
    echo "obs_smoke: no 'listening on' line after 10s" >&2
    cat "$OUT/server.log" "$OUT/server.err" >&2
    exit 1
fi
echo "obs_smoke: door is up on $ADDR"

# --- an idle scrape answers with a complete, all-zero-traffic registry.
"$BIN" stats --connect "$ADDR" > "$OUT/stats_idle.log"
grep -Eq "^server\.completed +counter +0$" "$OUT/stats_idle.log"
grep -Eq "^cache\.decisions_compute +counter +0$" "$OUT/stats_idle.log"
echo "obs_smoke: idle scrape OK"

# --- traffic, then a live scrape: counters must show exactly what was
# served, and the decision counters must cover the full steps x layers
# grid (model s = 3 layers, 6 steps, 4 requests => 72 decisions).
"$BIN" client --connect "$ADDR" --requests 4 --steps 6 > "$OUT/client.log" 2>&1
grep -q "client done: 4/4 completed" "$OUT/client.log"
"$BIN" stats --connect "$ADDR" > "$OUT/stats_live.log"
grep -Eq "^server\.completed +counter +4$" "$OUT/stats_live.log"
grep -Eq "^net\.reqs_completed +counter +4$" "$OUT/stats_live.log"
if command -v python3 >/dev/null 2>&1; then
    python3 - "$OUT/stats_live.log" <<'EOF'
import sys
vals = {}
for line in open(sys.argv[1]):
    parts = line.split()
    if len(parts) >= 3 and parts[1] in ("counter", "gauge"):
        vals[parts[0]] = int(parts[2])
dec = sum(vals[k] for k in
          ("cache.decisions_compute", "cache.decisions_approx", "cache.decisions_reuse"))
want = 4 * 6 * 3  # requests x steps x layers (model s)
assert dec == want, f"decision grid {dec} != {want}"
assert vals["server.lane_steps"] == 4 * 6, vals["server.lane_steps"]
print(f"obs_smoke: decision grid reconciles ({dec} decisions)")
EOF
fi
echo "obs_smoke: live scrape OK"

# --- drain: the periodic ticker must have fired at least once, and the
# trace dump must be valid Chrome trace_event JSON carrying decision,
# partition-or-stage, and span events.
echo drain >&9
exec 9>&-
if ! wait "$SERVER_PID"; then
    echo "obs_smoke: server exited non-zero after drain" >&2
    cat "$OUT/server.log" "$OUT/server.err" >&2
    exit 1
fi
SERVER_PID=""
grep -q -- "--- stats ---" "$OUT/server.err"
grep -q "^trace: " "$OUT/server.log"
[ -s "$OUT/trace.json" ]
if command -v python3 >/dev/null 2>&1; then
    python3 - "$OUT/trace.json" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
events = doc["traceEvents"]
assert events, "trace dump is empty"
names = {e["name"] for e in events}
phases = {e["ph"] for e in events}
assert any(n.startswith("decision:") for n in names), names
assert "queue_wait" in names or "step" in names, names
assert "i" in phases and "X" in phases, phases
print(f"obs_smoke: trace dump OK ({len(events)} events)")
EOF
fi
echo "obs_smoke: graceful drain + trace dump OK"
echo "obs_smoke: OK"
