#!/usr/bin/env bash
# Network front-door smoke (docs/PROTOCOL.md): boot `fastcache-serve
# serve --listen` on an ephemeral port, drive it with the built-in
# client over a real socket — happy path, deadline sheds, graceful
# drain — and assert on both sides' logs. CI runs exactly this (see
# .github/workflows/ci.yml, job net-smoke).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "net_smoke: cargo not found on PATH — install a Rust toolchain (rustup) first" >&2
    exit 1
fi

cargo build --release

BIN=target/release/fastcache-serve
OUT=$(mktemp -d)
SERVER_PID=""
cleanup() {
    [ -n "$SERVER_PID" ] && kill "$SERVER_PID" 2>/dev/null || true
    rm -rf "$OUT"
}
trap cleanup EXIT

# --- boot: ephemeral port; stdin is a fifo we hold open so we can send
# the "drain" line later (EOF would drain immediately).
mkfifo "$OUT/ctl"
"$BIN" serve --native --model s --steps 6 --listen 127.0.0.1:0 --net-max-conns 8 \
    < "$OUT/ctl" > "$OUT/server.log" 2>&1 &
SERVER_PID=$!
exec 9>"$OUT/ctl"

for _ in $(seq 1 100); do
    grep -q "^listening on " "$OUT/server.log" && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "net_smoke: server died during startup" >&2
        cat "$OUT/server.log" >&2
        exit 1
    fi
    sleep 0.1
done
ADDR=$(sed -n 's/^listening on //p' "$OUT/server.log" | head -n1)
if [ -z "$ADDR" ]; then
    echo "net_smoke: no 'listening on' line after 10s" >&2
    cat "$OUT/server.log" >&2
    exit 1
fi
echo "net_smoke: door is up on $ADDR"

# --- happy path: every request completes over the wire, with per-step
# progress frames streaming back.
"$BIN" client --connect "$ADDR" --requests 4 --steps 6 --progress \
    > "$OUT/happy.log" 2>&1
grep -q "client done: 4/4 completed" "$OUT/happy.log"
grep -q "progress frames" "$OUT/happy.log"
echo "net_smoke: happy path OK (4/4 completed with progress)"

# --- deadline sheds: a 0 ms budget is expired by the time any job pops
# from the queue, so every tagged request must come back as a typed shed
# — over the wire, as a Shed frame.
"$BIN" client --connect "$ADDR" --requests 3 --steps 6 \
    --deadline-every 1 --deadline-ms 0 > "$OUT/shed.log" 2>&1
grep -q "SHED after" "$OUT/shed.log"
grep -q "client done: 0/3 completed" "$OUT/shed.log"
echo "net_smoke: deadline shed path OK (3/3 shed)"

# --- graceful drain: one line on stdin; the server must drain, print
# its report (including the door counters), and exit 0.
echo drain >&9
exec 9>&-
if ! wait "$SERVER_PID"; then
    echo "net_smoke: server exited non-zero after drain" >&2
    cat "$OUT/server.log" >&2
    exit 1
fi
SERVER_PID=""
grep -q "draining..." "$OUT/server.log"
grep -q "conns accepted" "$OUT/server.log"
grep -q "^SLA: " "$OUT/server.log"
grep -q ", 3 shed" "$OUT/server.log"
echo "net_smoke: graceful drain OK"
echo "net_smoke: OK"
