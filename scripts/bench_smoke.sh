#!/usr/bin/env bash
# Bench smoke (CI): run the kernels + serving + sharding + warmstart +
# obs + robustness tables of bench_tables at tiny sizes and leave the rendered tables plus
# machine-readable bench_out/BENCH_*.json behind for the workflow-artifact
# upload, so the perf trajectory (kernel old-vs-new ratios, occupancy,
# the cold-vs-warm FLOPs/step win, store hit rate) accumulates per-PR.
# The kernels table carries one row per speed lever — scalar-vs-lanes
# (matmul_simd), 1-vs-N intra-op threads (matmul/attention/
# block_threaded), f32-vs-int8 (matmul_int8) — plus the block_int8
# quality row, whose int8_rel_err field is informational (the _err
# suffix matches no compare direction, so bench_compare never gates on
# it).
#
# Also folds every table into bench_out/BENCH_history_snapshot.json —
# commit that file as bench_history/BENCH_<pr>.json to extend the
# in-repo trajectory that scripts/bench_compare.sh checks regressions
# against.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "bench_smoke: cargo not found on PATH" >&2
    exit 1
fi

mkdir -p bench_out
BENCH_SMOKE=1 cargo bench --bench bench_tables -- kernels serving sharding warmstart obs robustness \
    | tee bench_out/BENCH_smoke_tables.txt

# Fold the per-table JSON rows into one committable snapshot.
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'EOF'
import glob, json
tables = {}
for path in sorted(glob.glob("bench_out/BENCH_*.json")):
    try:
        doc = json.load(open(path))
    except (ValueError, OSError):
        continue
    if isinstance(doc, dict) and "table" in doc:
        tables[doc["table"]] = doc.get("rows", [])
snap = {"provisional": False, "tables": tables}
with open("bench_out/BENCH_history_snapshot.json", "w") as f:
    json.dump(snap, f, indent=1)
    f.write("\n")
print("bench_smoke: wrote bench_out/BENCH_history_snapshot.json "
      f"({len(tables)} tables) — commit as bench_history/BENCH_<pr>.json")
EOF
fi

# Warn (never fail) when a table regressed >20% vs the last committed
# snapshot under bench_history/.
./scripts/bench_compare.sh || true

echo "bench_smoke: emitted artifacts:"
ls -l bench_out/BENCH_*
