#!/usr/bin/env bash
# Bench smoke (CI): run the serving + sharding + warmstart tables of
# bench_tables at tiny sizes and leave the rendered tables plus
# machine-readable bench_out/BENCH_*.json behind for the workflow-artifact
# upload, so the perf trajectory (including the cold-vs-warm FLOPs/step
# win and store hit rate per PR) accumulates per-PR.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "bench_smoke: cargo not found on PATH" >&2
    exit 1
fi

mkdir -p bench_out
BENCH_SMOKE=1 cargo bench --bench bench_tables -- serving sharding warmstart \
    | tee bench_out/BENCH_smoke_tables.txt

echo "bench_smoke: emitted artifacts:"
ls -l bench_out/BENCH_*
