//! Long-horizon video generation (paper §5.1's 32/64-frame stress test):
//! renders a clip frame-by-frame with FastCache, showing how the motion
//! region keeps being recomputed while the shared background caches —
//! the "Cache the Background, Recompute the Motion" principle.
//!
//!   cargo run --release --example video_gen [--frames 8] [--steps 15]
//!   [--motion calm|mixed|stormy] [--native]

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};
use fastcache_dit::config::{Args, FastCacheConfig, PolicyKind, Variant};
use fastcache_dit::experiments::eval_video;
use fastcache_dit::model::DitModel;
use fastcache_dit::runtime::{ArtifactStore, Client};
use fastcache_dit::scheduler::DenoiseEngine;
use fastcache_dit::workload::{MotionProfile, WorkloadGen};

fn main() -> Result<()> {
    let args = Args::parse().map_err(anyhow::Error::msg)?;
    let frames: usize = args.parse_num("frames", 8).map_err(anyhow::Error::msg)?;
    let steps: usize = args.parse_num("steps", 15).map_err(anyhow::Error::msg)?;
    let profile = match args.get_or("motion", "mixed") {
        "calm" => MotionProfile::CALM,
        "stormy" => MotionProfile::STORMY,
        _ => MotionProfile::MIXED,
    };
    let variant = Variant::parse(args.get_or("model", "b")).context("bad --model")?;

    let model = if args.flag("native") || !Path::new("artifacts/manifest.txt").exists() {
        println!("(native execution path)");
        DitModel::native(variant, 0xD17)
    } else {
        let client = Arc::new(Client::cpu()?);
        let store = Arc::new(ArtifactStore::open(Path::new("artifacts"))?);
        DitModel::load(client, store, variant, 0xD17)?
    };

    println!(
        "video: {} frames x {} steps on {} (motion={:?})\n",
        frames, steps, variant.paper_name(), profile
    );

    // Frame-by-frame with per-frame cache stats.
    let mut wl = WorkloadGen::new(0x71DE0);
    let clip = wl.video_clip(frames, steps, profile);
    let fc = FastCacheConfig::default();
    let mut eng = DenoiseEngine::new(&model, fc.clone());
    let mut total_ms = 0.0;
    for (f, req) in clip.iter().enumerate() {
        let r = eng.generate(req)?;
        total_ms += r.wall_ms;
        let motion_rate: f64 = r
            .records
            .iter()
            .map(|rec| rec.motion_tokens as f64 / rec.n_tokens as f64)
            .sum::<f64>()
            / r.records.len() as f64;
        println!(
            "  frame {f:>2}: {:>8.1} ms | skip {:>5.1}% | motion tokens {:>5.1}% | flops {:>5.1}%",
            r.wall_ms,
            r.skip_ratio() * 100.0,
            motion_rate * 100.0,
            r.flops_ratio() * 100.0
        );
    }
    println!("\nclip total: {total_ms:.1} ms");

    // FVD-proxy + speedup vs full compute on the same clip.
    let (row, fvd) = eval_video(&model, &fc, frames, steps, profile, 0x71DE0)?;
    let (_, fvd0) = eval_video(
        &model,
        &FastCacheConfig::with_policy(PolicyKind::NoCache),
        frames,
        steps,
        profile,
        0x71DE0,
    )?;
    println!(
        "FVD-proxy: fastcache {fvd:.3} (nocache reference {fvd0:.3}), speedup +{:.1}%",
        row.speedup_pct()
    );
    Ok(())
}
