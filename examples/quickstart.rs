//! Quickstart: load the AOT artifacts into a PJRT CPU client, spin up a
//! DiT-S model, and generate one image latent with FastCache on — the
//! minimal end-to-end tour of the public API.
//!
//! Without artifacts (or with --native) it falls back to the
//! numerically-equivalent native execution path, so CI can smoke-run the
//! example before the Python toolchain has produced any artifacts.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use anyhow::Result;
use fastcache_dit::config::{Args, FastCacheConfig, Variant};
use fastcache_dit::model::DitModel;
use fastcache_dit::runtime::{ArtifactStore, Client};
use fastcache_dit::scheduler::{DenoiseEngine, GenRequest};

/// The HLO path: PJRT CPU client + compiled artifact store + device
/// weight upload. Fails when the runtime or artifacts are unavailable.
fn load_hlo_model() -> Result<DitModel> {
    // 1. PJRT CPU client + compiled artifact store (HLO text -> executable).
    let client = Arc::new(Client::cpu()?);
    println!("PJRT platform: {}", client.platform());
    let store = Arc::new(ArtifactStore::open(std::path::Path::new("artifacts"))?);
    println!("artifacts loaded: {} programs available", store.names().count());

    // 2. A servable model: weights generated (seeded) and uploaded once.
    DitModel::load(client, store, Variant::S, 0xD17)
}

fn main() -> Result<()> {
    let args = Args::parse().map_err(anyhow::Error::msg)?;
    let model = if args.flag("native") {
        println!("--native: using the pure-Rust execution path");
        DitModel::native(Variant::S, 0xD17)
    } else {
        match load_hlo_model() {
            Ok(m) => m,
            Err(e) => {
                println!("HLO path unavailable ({e:#}); falling back to native execution");
                DitModel::native(Variant::S, 0xD17)
            }
        }
    };
    println!(
        "model {} — {} layers, d={}, {:.1}M params",
        model.cfg.variant.paper_name(),
        model.cfg.layers,
        model.cfg.d,
        model.cfg.param_count() as f64 / 1e6
    );

    // 3. FastCache engine with the paper's default knobs (α=0.05, τ_s=0.05,
    //    γ=0.5, STR+SC+MB all on).
    let fc = FastCacheConfig::default();
    let mut engine = DenoiseEngine::new(&model, fc);

    // 4. Generate.
    let req = GenRequest::builder(0, 42).steps(25).build().unwrap();
    let out = engine.generate(&req)?;
    println!(
        "generated latent {:?} in {:.1} ms",
        out.latent.shape(),
        out.wall_ms
    );
    println!(
        "cache behaviour: {} computed / {} approximated / {} reused block-sites \
         ({:.1}% skipped, {:.1}% of FLOPs executed)",
        out.computed,
        out.approximated,
        out.reused,
        out.skip_ratio() * 100.0,
        out.flops_ratio() * 100.0
    );
    if let Some(meter) = model.meter() {
        println!(
            "device memory: live {:.1} MiB, peak {:.1} MiB",
            meter.live_bytes() as f64 / (1 << 20) as f64,
            meter.peak_bytes() as f64 / (1 << 20) as f64
        );
    }
    Ok(())
}
