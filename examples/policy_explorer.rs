//! Policy explorer: run every cache policy on the same workload and print
//! the quality/efficiency frontier — the interactive companion to the
//! paper's Table 1 for trying custom knobs.
//!
//!   cargo run --release --example policy_explorer [--model l] [--steps 20]
//!   [--requests 8] [--alpha 0.05] [--tau-s 0.05] [--gamma 0.5]

use anyhow::{Context, Result};
use fastcache_dit::config::{Args, FastCacheConfig, PolicyKind, Variant};
use fastcache_dit::experiments::{eval_policies, EvalConfig};
use fastcache_dit::metrics::report::{f1, f2, pct, Table};
use fastcache_dit::model::DitModel;
use fastcache_dit::workload::MotionProfile;

fn main() -> Result<()> {
    let args = Args::parse().map_err(anyhow::Error::msg)?;
    let variant = Variant::parse(args.get_or("model", "l")).context("bad --model")?;
    let model = DitModel::native(variant, 0xD17);

    let mut ecfg = EvalConfig::quick(variant);
    ecfg.steps = args.parse_num("steps", ecfg.steps).map_err(anyhow::Error::msg)?;
    ecfg.requests = args.parse_num("requests", ecfg.requests).map_err(anyhow::Error::msg)?;
    ecfg.profile = match args.get_or("motion", "mixed") {
        "calm" => MotionProfile::CALM,
        "stormy" => MotionProfile::STORMY,
        _ => MotionProfile::MIXED,
    };

    let mut policies: Vec<(String, FastCacheConfig)> = Vec::new();
    for kind in PolicyKind::ALL {
        let mut c = FastCacheConfig::with_policy(kind);
        if kind == PolicyKind::FastCache {
            c.alpha = args.parse_num("alpha", c.alpha).map_err(anyhow::Error::msg)?;
            c.tau_s = args.parse_num("tau-s", c.tau_s).map_err(anyhow::Error::msg)?;
            c.gamma = args.parse_num("gamma", c.gamma).map_err(anyhow::Error::msg)?;
        }
        policies.push((kind.paper_name().to_string(), c));
    }

    println!(
        "exploring {} policies on {} ({} requests x {} steps, motion {:?})\n",
        policies.len(),
        variant.paper_name(),
        ecfg.requests,
        ecfg.steps,
        ecfg.profile
    );
    let rows = eval_policies(&model, &policies, &ecfg)?;
    let mut t = Table::new(
        "Policy frontier",
        &["Method", "FID↓", "t-FID↓", "CLIP↑", "Time (ms)↓", "Mem (MiB)↓", "Skip↑", "Speedup↑"],
    );
    for r in &rows {
        t.row(&[
            r.label.clone(),
            f2(r.fid),
            f2(r.tfid),
            f1(r.clip),
            format!("{:.0}", r.time_ms),
            f1(r.mem_mib),
            pct(r.skip_ratio),
            format!("{:.2}x", r.speedup),
        ]);
    }
    println!("{}", t.render());
    println!("(FID/t-FID are Fréchet proxies vs the NoCache reference — see DESIGN.md §2)");
    Ok(())
}
