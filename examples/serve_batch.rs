//! END-TO-END VALIDATION DRIVER (DESIGN.md / EXPERIMENTS.md §E2E): load a
//! small real model through the full AOT path (JAX+Pallas → HLO text →
//! PJRT), start the sharded continuous-batching server, serve a request
//! workload, and report latency/throughput/occupancy with FastCache on vs
//! off — proving all three layers compose on the serving hot path. With
//! the unified lane stepper, STR-enabled configs batch too (the third row
//! used to fall back to single-request serving).
//!
//! When the AOT artifacts are absent (or with --native), the driver falls
//! back to the numerically-equivalent native execution path so CI can
//! smoke-run it without the Python toolchain.
//!
//!   make artifacts && cargo run --release --example serve_batch
//!   [--model s] [--requests 12] [--steps 20] [--workers 2]
//!   [--policy fastcache|nocache] [--native]

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};
use fastcache_dit::config::{Args, FastCacheConfig, PolicyKind, ServerConfig, Variant};
use fastcache_dit::model::DitModel;
use fastcache_dit::runtime::{ArtifactStore, Client};
use fastcache_dit::server::Server;
use fastcache_dit::workload::{MotionProfile, WorkloadGen};

fn main() -> Result<()> {
    let args = Args::parse().map_err(anyhow::Error::msg)?;
    let variant = Variant::parse(args.get_or("model", "l")).context("bad --model")?;
    let requests: usize = args.parse_num("requests", 8).map_err(anyhow::Error::msg)?;
    let steps: usize = args.parse_num("steps", 20).map_err(anyhow::Error::msg)?;
    let workers: usize = args.parse_num("workers", 1).map_err(anyhow::Error::msg)?;
    let native = args.flag("native") || !Path::new("artifacts/manifest.txt").exists();
    // (policy, enable STR). STR buckets run per-lane inside the unified
    // stepper while full-token Compute sites still batch through the B=4
    // artifact — the third row shows STR batching, not a fallback.
    let policies: Vec<(PolicyKind, bool)> = match args.get("policy") {
        Some(p) => vec![(PolicyKind::parse(p).context("bad --policy")?, false)],
        None => vec![
            (PolicyKind::NoCache, false),
            (PolicyKind::FastCache, false),
            (PolicyKind::FastCache, true),
        ],
    };

    println!("=== serve_batch: end-to-end driver over the AOT/PJRT path ===");
    println!(
        "model {} | {requests} requests x {steps} steps | {workers} worker shard(s) | {} path",
        variant.paper_name(),
        if native { "native (no artifacts)" } else { "HLO/PJRT" }
    );
    println!();

    let mut summary = Vec::new();
    for (policy, str_on) in policies {
        let scfg = ServerConfig {
            variant,
            steps,
            max_batch: 4,
            workers,
            ..ServerConfig::default()
        };
        scfg.validate().map_err(anyhow::Error::msg)?;
        let mut fc = FastCacheConfig::with_policy(policy);
        fc.enable_str = str_on;

        let server = Server::start(scfg, fc, move || {
            if native {
                return Ok(DitModel::native(variant, 0xD17));
            }
            let client = Arc::new(Client::cpu()?);
            let store = Arc::new(ArtifactStore::open(Path::new("artifacts"))?);
            let model = DitModel::load(client, store, variant, 0xD17)?;
            Ok(model)
        });

        let mut wl = WorkloadGen::new(0x5EED);
        let reqs = wl.image_set(requests, steps, MotionProfile::MIXED);
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = reqs
            .iter()
            .map(|r| server.submit_blocking(r).expect("submit"))
            .collect();
        let mut skip_sum = 0.0;
        for rx in rxs {
            // No deadlines in this workload, so every outcome completes.
            let resp = rx.wait().completed();
            skip_sum += resp.result.skip_ratio();
        }
        let wall = t0.elapsed().as_secs_f64();
        let report = server.shutdown();
        println!(
            "policy {:<14} | wall {:>6.2}s | {:>5.2} req/s | p50 {:>7.0} ms | p95 {:>7.0} ms | \
             occupancy {:>4.2} | adm p50 {:>5.1} ms | padded {:>6.3} GFLOP | mean skip {:>5.1}%",
            format!("{}{}", policy.name(), if str_on { "+STR" } else { "" }),
            wall,
            report.completed as f64 / wall,
            report.e2e.percentile(50.0),
            report.e2e.percentile(95.0),
            report.occupancy(),
            report.admission_wait.percentile(50.0),
            report.padded_flops as f64 / 1e9,
            skip_sum / requests as f64 * 100.0,
        );
        summary.push((policy, wall));
    }
    if summary.len() >= 2 {
        let best = summary.iter().skip(1).map(|s| s.1).fold(f64::INFINITY, f64::min);
        let speedup = summary[0].1 / best;
        println!(
            "\nFastCache end-to-end serving speedup vs NoCache: {speedup:.2}x \
             (paper DiT-XL/2: 1.74x; shape reproduced — caching wins on wall-clock \
             with bounded quality loss, see EXPERIMENTS.md)"
        );
    }
    Ok(())
}
