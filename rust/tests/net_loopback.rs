//! Loopback integration for the network front door: a real TCP socket on
//! 127.0.0.1, the framed protocol end to end, and the three acceptance
//! properties — bit-identical parity with in-process serving, door-level
//! shedding that shows up in the SLA accounting, and a graceful drain
//! that loses zero admitted responses.

use std::collections::BTreeMap;

use fastcache_dit::api::{ErrorCode, Event, GenClient, Outcome};
use fastcache_dit::config::{FastCacheConfig, PolicyKind, ServerConfig, Variant};
use fastcache_dit::model::DitModel;
use fastcache_dit::net::proto::{self, Frame};
use fastcache_dit::net::{NetClient, NetServer, VERSION};
use fastcache_dit::obs::SeriesValue;
use fastcache_dit::scheduler::GenRequest;
use fastcache_dit::server::Server;
use fastcache_dit::tensor::Tensor;
use fastcache_dit::workload::{MotionProfile, WorkloadGen};

fn native_server(max_batch: usize, queue_depth: usize) -> Server {
    let scfg = ServerConfig { max_batch, queue_depth, workers: 1, ..ServerConfig::default() };
    let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
    fc.enable_str = false;
    Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 5)))
}

fn start_door(max_batch: usize, queue_depth: usize, max_conns: usize) -> NetServer {
    NetServer::start(native_server(max_batch, queue_depth), "127.0.0.1:0", max_conns)
        .expect("bind loopback")
}

#[test]
fn loopback_latents_are_bit_identical_to_in_process_submits() {
    let mut wl = WorkloadGen::new(0x10B4);
    let reqs = wl.image_set(4, 6, MotionProfile::MIXED);

    // In-process reference latents, keyed by request id.
    let server = native_server(2, 64);
    let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r).expect("submit")).collect();
    let mut reference: BTreeMap<u64, Tensor> = BTreeMap::new();
    for rx in rxs {
        let resp = rx.wait().completed();
        reference.insert(resp.result.id, resp.result.latent);
    }
    server.shutdown();

    // The same requests over the socket, against an identically-seeded
    // server. Latents are f32 bit patterns on the wire, so they must
    // come back without a single bit of drift.
    let door = start_door(2, 64, 4);
    let client = NetClient::connect(door.local_addr()).expect("connect");
    let rxs: Vec<_> = reqs.iter().map(|r| client.submit(r).expect("submit")).collect();
    for rx in rxs {
        let resp = rx.wait().completed();
        let want = &reference[&resp.result.id];
        assert_eq!(resp.result.latent.shape(), want.shape());
        let a: Vec<u32> = want.data().iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = resp.result.latent.data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "req {}: socket latent differs from in-process", resp.result.id);
        assert!(resp.e2e_ms >= 0.0);
    }
    client.close();
    let report = door.shutdown();
    assert_eq!(report.completed, 4);
    let net = report.net.expect("door stats folded into the report");
    assert_eq!(net.reqs_submitted, 4);
    assert_eq!(net.reqs_completed, 4);
    assert_eq!(net.conns_accepted, 1);
    assert_eq!(net.conns_door_shed, 0);
    assert!(net.bytes_in > 0 && net.bytes_out > 0);
}

#[test]
fn streaming_submission_delivers_progress_ticks_over_the_socket() {
    let door = start_door(1, 16, 2);
    let client = NetClient::connect(door.local_addr()).expect("connect");
    let steps = 5;
    let req = GenRequest::builder(1, 0xFEED).steps(steps).build().unwrap();
    let rx = client.submit_streaming(&req).expect("submit");
    let mut ticks = Vec::new();
    let outcome = loop {
        match rx.recv_event() {
            Some(Event::Progress(p)) => {
                assert_eq!(p.id, 1);
                assert_eq!(p.total, steps as u32);
                ticks.push(p.step);
            }
            Some(Event::Done(outcome)) => break outcome,
            None => panic!("stream ended without a terminal event"),
        }
    };
    assert_eq!(ticks.len(), steps, "one progress frame per denoise step");
    assert!(ticks.windows(2).all(|w| w[0] < w[1]), "ticks not increasing: {ticks:?}");
    assert_eq!(*ticks.last().unwrap(), steps as u32);
    outcome.completed();

    // A plain submit on the same connection stays tick-free.
    let quiet = GenRequest::builder(2, 0xFEED).steps(3).build().unwrap();
    let rx = client.submit(&quiet).expect("submit");
    match rx.recv_event() {
        Some(Event::Done(outcome)) => {
            outcome.completed();
        }
        other => panic!("expected an immediate terminal event, got {other:?}"),
    }
    client.close();
    door.shutdown();
}

#[test]
fn over_budget_connections_are_shed_at_the_door() {
    let door = start_door(1, 16, 1);
    let first = NetClient::connect(door.local_addr()).expect("first connection fits");
    // The budget is 1: the second connection must be answered with a
    // typed Busy before it costs a connection thread.
    let second = NetClient::connect(door.local_addr());
    let rej = second.err().expect("second connection must be refused");
    assert_eq!(rej.code, ErrorCode::Busy, "door refusal must be Busy, got {rej:?}");
    first.close();
    let report = door.shutdown();
    let net = report.net.expect("net stats");
    assert_eq!(net.conns_accepted, 1);
    assert_eq!(net.conns_door_shed, 1);
}

#[test]
fn queue_full_door_sheds_are_sla_misses_in_the_report() {
    // A deliberately tiny server (1 lane, queue depth 1) and a burst of
    // deadline-tagged requests fired as fast as the socket carries them:
    // most must be refused at the door with Busy, and every one of those
    // refusals must LOWER deadline_hit_rate() — shedding at the door is
    // not allowed to make the SLA numbers look better.
    let door = start_door(1, 1, 2);
    let client = NetClient::connect(door.local_addr()).expect("connect");
    let n = 32u64;
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let req = GenRequest::builder(i, i ^ 0xD00D)
                .steps(6)
                .deadline_ms(300_000.0)
                .build()
                .unwrap();
            client.submit(&req).expect("wire submit itself never refuses")
        })
        .collect();
    let mut completed = 0u64;
    let mut busy = 0u64;
    for rx in rxs {
        match rx.wait() {
            Outcome::Completed(_) => completed += 1,
            Outcome::Rejected(rej) if rej.code == ErrorCode::Busy => busy += 1,
            Outcome::Rejected(rej) => panic!("unexpected rejection: {rej:?}"),
        }
    }
    assert_eq!(completed + busy, n, "every request gets exactly one terminal outcome");
    assert!(busy > 0, "queue depth 1 cannot absorb a {n}-request burst");
    client.close();

    let report = door.shutdown();
    assert_eq!(report.completed, completed);
    assert_eq!(report.door_sheds, busy, "deadline-tagged door refusals must be counted");
    let net = report.net.expect("net stats");
    assert_eq!(net.reqs_door_shed, busy);
    assert_eq!(net.door_sheds_deadline, busy);
    // All served jobs met the 5-minute budget, so the rate is exactly
    // served / (served + door_sheds) — strictly below 1.
    let rate = report.deadline_hit_rate().expect("deadline traffic present");
    assert!(rate < 1.0, "door sheds must lower the hit rate, got {rate}");
    let want = report.deadline_hits as f64
        / (report.deadline_jobs + report.deadline_sheds + report.door_sheds) as f64;
    assert!((rate - want).abs() < 1e-12);
}

#[test]
fn graceful_drain_finishes_every_admitted_lane_with_zero_lost_responses() {
    let door = start_door(2, 16, 2);
    let client = NetClient::connect(door.local_addr()).expect("connect");
    let rxs: Vec<_> = (0..4u64)
        .map(|i| {
            let req = GenRequest::builder(i, i).steps(8).build().unwrap();
            client.submit_streaming(&req).expect("submit")
        })
        .collect();
    // Wait for every request's first progress tick — proof it was
    // admitted and its lane is running — THEN drain mid-flight. Shutdown
    // must block until every admitted lane finished and its terminal
    // frame flushed: the client-side streams all resolve to Completed.
    for rx in &rxs {
        match rx.recv_event() {
            Some(Event::Progress(_)) => {}
            other => panic!("expected a first progress tick, got {other:?}"),
        }
    }
    let report = door.shutdown();
    for rx in rxs {
        let resp = rx.wait().completed();
        assert!(resp.result.latent.data().iter().all(|v| v.is_finite()));
    }
    assert_eq!(report.completed, 4, "drain lost admitted work");
    let net = report.net.expect("net stats");
    assert_eq!(net.reqs_completed, 4, "every admitted response must reach the wire");
    drop(client);
}

#[test]
fn traced_lanes_reconcile_with_the_registry_over_the_wire() {
    // Sample rate 1.0: every lane is traced. The acceptance property —
    // per-lane Decision events, the registry's cache counters, and the
    // wire-scraped series must all describe the same steps × layers
    // decision grid.
    let scfg = ServerConfig {
        max_batch: 2,
        queue_depth: 64,
        workers: 1,
        trace_sample_rate: 1.0,
        ..ServerConfig::default()
    };
    let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
    fc.enable_str = false;
    let server = Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 5)));
    // Grab the handles BEFORE the door consumes the server — they are
    // Arcs into the live plane, valid for the server's whole life.
    let registry = server.registry();
    let recorder = server.recorder().expect("sample rate 1.0 creates the recorder");
    let door = NetServer::start(server, "127.0.0.1:0", 4).expect("bind loopback");
    let client = NetClient::connect(door.local_addr()).expect("connect");

    let n_req = 3u64;
    let steps = 4usize;
    let rxs: Vec<_> = (0..n_req)
        .map(|i| {
            let req = GenRequest::builder(i, i ^ 0xAB).steps(steps).build().unwrap();
            client.submit(&req).expect("submit")
        })
        .collect();
    for rx in rxs {
        rx.wait().completed();
    }

    // Live mid-connection scrape: one Stats frame on the same socket the
    // submits used, answered from the registry without a drain.
    let series = client.stats().expect("stats scrape");
    let get = |name: &str| -> u64 {
        match &series.iter().find(|s| s.name == name).unwrap_or_else(|| panic!("{name}")).value
        {
            SeriesValue::Counter(v) | SeriesValue::Gauge(v) => *v,
            other => panic!("{name}: unexpected series kind {other:?}"),
        }
    };
    assert_eq!(get("server.completed"), n_req);
    assert_eq!(get("net.reqs_submitted"), n_req);
    assert_eq!(get("net.reqs_completed"), n_req);
    assert!(get("net.bytes_in") > 0 && get("net.bytes_out") > 0);

    let layers = fastcache_dit::config::ModelConfig::of(Variant::S).layers as u64;
    let dec = registry.decision_totals();
    assert_eq!(
        dec.iter().sum::<u64>(),
        n_req * steps as u64 * layers,
        "one cache decision per (request, step, layer)"
    );
    assert_eq!(
        get("cache.decisions_compute") + get("cache.decisions_approx")
            + get("cache.decisions_reuse"),
        dec.iter().sum::<u64>(),
        "wire scrape must agree with the in-process registry"
    );
    assert_eq!(
        recorder.decision_counts(),
        dec,
        "every counted decision must also be a recorded event at rate 1.0"
    );

    client.close();
    let report = door.shutdown();
    assert_eq!(report.completed, n_req);
    // The shutdown report is a final snapshot of the same registry.
    assert_eq!(report.net.expect("net stats").reqs_completed, n_req);
}

#[test]
fn stats_scrapes_interleave_with_in_flight_requests() {
    let door = start_door(1, 16, 2);
    let client = NetClient::connect(door.local_addr()).expect("connect");
    // Scrape an idle server: all traffic counters are zero, but the
    // series set itself is complete and well-formed.
    let idle = client.stats().expect("idle scrape");
    let completed = |series: &[fastcache_dit::obs::Series]| -> u64 {
        series
            .iter()
            .find_map(|s| match (&s.name[..], &s.value) {
                ("server.completed", SeriesValue::Counter(v)) => Some(*v),
                _ => None,
            })
            .expect("server.completed series present")
    };
    assert_eq!(completed(&idle), 0);

    // Interleave: submit, scrape while the lane may still be running,
    // then wait — the scrape must neither block nor corrupt the stream.
    let req = GenRequest::builder(1, 0xCAFE).steps(4).build().unwrap();
    let rx = client.submit(&req).expect("submit");
    let _mid = client.stats().expect("mid-flight scrape");
    rx.wait().completed();
    let after = client.stats().expect("post-completion scrape");
    assert_eq!(completed(&after), 1);
    client.close();
    door.shutdown();
}

#[test]
fn injected_socket_resets_are_survived_by_bounded_connect_retries() {
    // A fault plan that resets the first two accepted connections, before
    // they cost a budget slot. A budget-less connect takes the first reset
    // on the chin; a retrying connect absorbs the second and lands on the
    // third, healthy accept — and the surviving connection serves traffic.
    let scfg = ServerConfig {
        max_batch: 1,
        queue_depth: 16,
        workers: 1,
        fault_plan: Some("sockreset conn=1; sockreset conn=2".into()),
        ..ServerConfig::default()
    };
    let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
    fc.enable_str = false;
    let server = Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 5)));
    let door = NetServer::start(server, "127.0.0.1:0", 4).expect("bind loopback");

    let rej = NetClient::connect(door.local_addr())
        .err()
        .expect("first connection must be reset by the plan");
    assert_eq!(rej.code, ErrorCode::Closed, "injected reset must surface as Closed, got {rej:?}");

    let client = NetClient::connect_with_retries(door.local_addr(), 2)
        .expect("one retry must outlast the remaining injected reset");
    let req = GenRequest::builder(1, 0xF00D).steps(3).build().unwrap();
    let resp = client.generate(&req).completed();
    assert!(resp.result.latent.data().iter().all(|v| v.is_finite()));
    client.close();
    door.shutdown();
}

#[test]
fn poisoned_resubmit_is_rejected_at_both_doors() {
    let poisoned_scfg = || ServerConfig {
        max_batch: 1,
        queue_depth: 16,
        workers: 1,
        poison_after: 1,
        fault_plan: Some("panic step=1 layer=0 req=7".into()),
        ..ServerConfig::default()
    };
    let poisoned_server = || {
        let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        fc.enable_str = false;
        Server::start(poisoned_scfg(), fc, || Ok(DitModel::native(Variant::S, 5)))
    };

    // Door 1, in-process: the first submission of req 7 panics in-kernel
    // and is quarantined (typed Internal). That files the strike that
    // blocklists the id, so the resubmit is refused AT ADMISSION — no
    // queue slot, no lane, a typed Poisoned rejection.
    let server = poisoned_server();
    let rx = server.submit(&GenRequest::builder(7, 70).steps(4).build().unwrap()).unwrap();
    match rx.wait() {
        Outcome::Rejected(rej) => assert_eq!(rej.code, ErrorCode::Internal),
        other => panic!("expected quarantine, got {other:?}"),
    }
    let rej = server
        .submit(&GenRequest::builder(7, 70).steps(4).build().unwrap())
        .err()
        .expect("blocklisted resubmit must be refused at admission");
    assert_eq!(rej.code, ErrorCode::Poisoned);
    assert_eq!(rej.id, 7);
    assert!(rej.detail.contains("blocklisted"), "detail must say why: {}", rej.detail);
    // An innocent request with a different id sails through.
    let ok = server.submit(&GenRequest::builder(8, 71).steps(2).build().unwrap()).unwrap();
    ok.wait().completed();
    let report = server.shutdown();
    assert_eq!(report.blocklisted, 1);
    assert_eq!(report.poisoned_rejections, 1);
    assert_eq!(report.internal_errors, 1);

    // Door 2, over a real socket: same sequence through the front door.
    // The refusal arrives as an Error frame carrying the Poisoned code,
    // and — because the resubmit was deadline-tagged — it counts against
    // the SLA hit rate.
    let door =
        NetServer::start(poisoned_server(), "127.0.0.1:0", 2).expect("bind loopback");
    let client = NetClient::connect(door.local_addr()).expect("connect");
    let rx = client.submit(&GenRequest::builder(7, 70).steps(4).build().unwrap()).unwrap();
    match rx.wait() {
        Outcome::Rejected(rej) => assert_eq!(rej.code, ErrorCode::Internal),
        other => panic!("expected quarantine over the wire, got {other:?}"),
    }
    let resubmit =
        GenRequest::builder(7, 70).steps(4).deadline_ms(120_000.0).build().unwrap();
    let rx = client.submit(&resubmit).expect("wire submit itself does not refuse");
    match rx.wait() {
        Outcome::Rejected(rej) => {
            assert_eq!(rej.code, ErrorCode::Poisoned, "wire code must round-trip: {rej:?}");
            assert_eq!(rej.id, 7);
        }
        other => panic!("expected Poisoned over the wire, got {other:?}"),
    }
    // The blocklist is visible on the wire too: one Health frame.
    let health = client.health().expect("health probe");
    assert!(!health.draining);
    assert_eq!(health.blocklisted, 1);
    assert_eq!(health.restarts, 0);
    assert_eq!(health.shards.len(), 1);
    client.close();
    let report = door.shutdown();
    assert_eq!(report.blocklisted, 1);
    assert_eq!(report.poisoned_rejections, 1);
    assert_eq!(report.poisoned_sheds, 1, "deadline-tagged poisoned refusal is an SLA event");
    assert_eq!(
        report.deadline_hit_rate(),
        Some(0.0),
        "the poisoned refusal must count as an SLA miss, not vanish"
    );
}

#[test]
fn health_probe_answers_on_a_healthy_and_a_draining_door() {
    let door = start_door(1, 16, 2);
    let client = NetClient::connect(door.local_addr()).expect("connect");
    // Healthy, idle server: every shard reports state 0, nothing counted.
    let body = client.health().expect("idle health probe");
    assert!(!body.draining);
    assert_eq!(body.restarts, 0);
    assert_eq!(body.blocklisted, 0);
    assert_eq!(body.shards.len(), 1);
    assert_eq!(body.shards[0], (0, 0), "idle shard must report Healthy (code 0)");
    // Probes interleave with traffic on the same connection.
    let req = GenRequest::builder(1, 0xBEEF).steps(4).build().unwrap();
    let rx = client.submit(&req).expect("submit");
    let _mid = client.health().expect("mid-flight health probe");
    rx.wait().completed();
    client.close();
    door.shutdown();
}

#[test]
fn a_dead_peer_resolves_pending_streams_to_closed_promptly() {
    use std::io::Write;
    // A hand-rolled door that handshakes, accepts one Submit, and dies
    // without answering — the wire-level version of "the worker behind
    // this request is gone". The pending stream must degrade to a typed
    // Closed rejection addressed to the request, not hang.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let peer = std::thread::spawn(move || {
        let (mut sock, _) = listener.accept().expect("accept");
        match proto::read_frame(&mut sock).expect("read Hello") {
            Some((Frame::Hello { version }, _)) => assert_eq!(version, VERSION),
            other => panic!("expected Hello, got {other:?}"),
        }
        sock.write_all(&proto::encode(&Frame::HelloAck { version: VERSION })).unwrap();
        match proto::read_frame(&mut sock).expect("read Submit") {
            Some((Frame::Submit { req, .. }, _)) => assert_eq!(req.id, 7),
            other => panic!("expected Submit, got {other:?}"),
        }
        drop(sock);
    });

    let client = NetClient::connect(addr).expect("connect");
    let req = GenRequest::builder(7, 7).steps(4).build().unwrap();
    let rx = client.submit(&req).expect("submit");
    match rx.wait() {
        Outcome::Rejected(rej) => {
            assert_eq!(rej.code, ErrorCode::Closed, "dead peer must surface as Closed, got {rej:?}");
            assert_eq!(rej.id, 7, "the rejection must be addressed to the orphaned request");
        }
        other => panic!("expected Rejected(Closed), got {other:?}"),
    }
    peer.join().unwrap();
}

#[test]
fn malformed_submit_gets_typed_error_and_the_connection_survives() {
    use std::io::Write;
    let door = start_door(1, 16, 2);

    // Speak the protocol by hand so we can send what NetClient refuses to
    // build: a structurally valid Submit whose request is invalid
    // (steps = 0).
    let mut sock = std::net::TcpStream::connect(door.local_addr()).expect("connect");
    sock.write_all(&proto::encode(&Frame::Hello { version: VERSION })).unwrap();
    match proto::read_frame(&mut sock).unwrap() {
        Some((Frame::HelloAck { version }, _)) => assert_eq!(version, VERSION),
        other => panic!("expected HelloAck, got {other:?}"),
    }

    let mut body = vec![0x02u8]; // T_SUBMIT
    body.extend_from_slice(&9u64.to_le_bytes()); // id
    body.extend_from_slice(&1u64.to_le_bytes()); // seed
    body.extend_from_slice(&2u64.to_le_bytes()); // cond_seed
    body.extend_from_slice(&7.5f32.to_le_bytes()); // guidance
    body.extend_from_slice(&0u32.to_le_bytes()); // steps = 0: invalid
    body.extend_from_slice(&[0, 0, 0, 0]); // no deadline/turb/init, no progress
    let mut frame = (body.len() as u32).to_le_bytes().to_vec();
    frame.extend_from_slice(&body);
    sock.write_all(&frame).unwrap();

    match proto::read_frame(&mut sock).unwrap() {
        Some((Frame::Error { id, code, .. }, _)) => {
            assert_eq!(id, 9, "rejection must be addressed to the bad request");
            assert_eq!(code, ErrorCode::BadRequest.code());
        }
        other => panic!("expected a typed Error frame, got {other:?}"),
    }

    // The stream is still frame-delimited: a valid Submit on the same
    // connection completes normally (Partial chunks, then Completed).
    let req = GenRequest::builder(10, 3).steps(2).build().unwrap();
    sock.write_all(&proto::encode(&Frame::Submit { req, progress: false })).unwrap();
    let mut values = 0usize;
    loop {
        match proto::read_frame(&mut sock).unwrap() {
            Some((Frame::Partial { id, values: chunk, .. }, _)) => {
                assert_eq!(id, 10);
                values += chunk.len();
            }
            Some((Frame::Completed(c), _)) => {
                assert_eq!(c.id, 10);
                let want: usize = c.shape.iter().map(|&d| d as usize).product();
                assert_eq!(values, want, "partial chunks must cover the whole latent");
                break;
            }
            other => panic!("expected Partial/Completed, got {other:?}"),
        }
    }
    sock.write_all(&proto::encode(&Frame::Goodbye)).unwrap();
    match proto::read_frame(&mut sock).unwrap() {
        Some((Frame::Goodbye, _)) | None => {}
        other => panic!("expected Goodbye or EOF, got {other:?}"),
    }
    drop(sock);
    door.shutdown();
}
