//! Serving-layer integration: queue → continuous-batching worker → lane
//! stepper → response, over the native execution path (fast) plus one
//! HLO-backed smoke test when artifacts are present.

use std::path::Path;
use std::sync::Arc;

use fastcache_dit::config::{FastCacheConfig, PolicyKind, ServerConfig, Variant};
use fastcache_dit::metrics::FidAccumulator;
use fastcache_dit::model::DitModel;
use fastcache_dit::runtime::{ArtifactStore, Client};
use fastcache_dit::scheduler::{DenoiseEngine, GenRequest};
use fastcache_dit::server::Server;
use fastcache_dit::workload::{MotionProfile, WorkloadGen};

fn native_server(policy: PolicyKind, max_batch: usize) -> Server {
    let mut scfg = ServerConfig::default();
    scfg.max_batch = max_batch;
    scfg.queue_depth = 64;
    let mut fc = FastCacheConfig::with_policy(policy);
    fc.enable_str = false;
    Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 5)))
}

#[test]
fn throughput_improves_with_caching() {
    // Same workload, NoCache vs FastCache: cached serving must complete
    // faster in wall time (on identical hardware and requests).
    let mut wl = WorkloadGen::new(1);
    let reqs = wl.image_set(6, 12, MotionProfile::CALM);

    let mut walls = Vec::new();
    for policy in [PolicyKind::NoCache, PolicyKind::FastCache] {
        let server = native_server(policy, 2);
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = reqs
            .iter()
            .map(|r| server.submit(r.clone()).expect("submit"))
            .collect();
        for rx in rxs {
            rx.recv().expect("response");
        }
        walls.push(t0.elapsed().as_secs_f64());
        let report = server.shutdown();
        assert_eq!(report.completed, 6);
    }
    assert!(
        walls[1] < walls[0],
        "fastcache serving ({:.3}s) not faster than nocache ({:.3}s)",
        walls[1],
        walls[0]
    );
}

#[test]
fn str_enabled_serving_batches_and_matches_single_request() {
    // The config the paper actually evaluates (FastCache with STR on) used
    // to be gated out of batching entirely. It must now batch AND return
    // the same numerics as a solo engine run.
    let mut scfg = ServerConfig::default();
    scfg.max_batch = 4;
    scfg.queue_depth = 64;
    let fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
    assert!(fc.enable_str);
    let server = Server::start(scfg, fc.clone(), || Ok(DitModel::native(Variant::S, 5)));

    let mut wl = WorkloadGen::new(8);
    let reqs = wl.image_set(8, 6, MotionProfile::MIXED);
    let rxs: Vec<_> = reqs
        .iter()
        .map(|r| (r.clone(), server.submit(r.clone()).expect("submit")))
        .collect();
    let model = DitModel::native(Variant::S, 5);
    for (req, rx) in rxs {
        let resp = rx.recv().expect("response");
        let mut eng = DenoiseEngine::new(&model, fc.clone());
        let solo = eng.generate(&req).expect("solo generate");
        let md = resp.result.latent.max_abs_diff(&solo.latent);
        assert!(md < 1e-4, "req {}: served vs solo diff {md}", req.id);
    }
    let report = server.shutdown();
    assert_eq!(report.completed, 8);
    assert!(
        report.mean_batch_size() > 1.0,
        "STR serving did not batch: occupancy {}",
        report.mean_batch_size()
    );
}

#[test]
fn responses_match_request_ids_under_batching() {
    let server = native_server(PolicyKind::FastCache, 4);
    let mut wl = WorkloadGen::new(2);
    let reqs = wl.image_set(9, 6, MotionProfile::MIXED);
    let rxs: Vec<_> = reqs
        .iter()
        .map(|r| (r.id, server.submit(r.clone()).unwrap()))
        .collect();
    for (id, rx) in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.result.id, id, "response routed to wrong request");
    }
    server.shutdown();
}

#[test]
fn quality_reference_is_self_consistent() {
    // The FID-proxy of a policy against itself (same seeds) is ~0; against
    // a different-seed NoCache set it is small but positive.
    let model = DitModel::native(Variant::S, 5);
    let fc = FastCacheConfig::with_policy(PolicyKind::NoCache);
    let mut wl = WorkloadGen::new(3);
    let reqs = wl.image_set(16, 8, MotionProfile::MIXED);
    let mut eng = DenoiseEngine::new(&model, fc);
    let mut a = FidAccumulator::new();
    let mut b = FidAccumulator::new();
    for r in &reqs {
        let out = eng.generate(r).unwrap();
        a.push_latent(&out.latent);
        b.push_latent(&out.latent);
    }
    assert!(a.distance_to(&b) < 1e-9);
}

#[test]
fn cached_policies_rank_by_quality() {
    // More aggressive reuse => further from the NoCache reference. This is
    // the core ordering every paper table relies on: FastCache (learnable
    // approx + blending) must beat plain whole-step reuse (StaticCache).
    let model = DitModel::native(Variant::S, 5);
    let mut wl = WorkloadGen::new(4);
    let reqs = wl.image_set(24, 10, MotionProfile::MIXED);

    let mut reference = FidAccumulator::new();
    {
        let mut eng =
            DenoiseEngine::new(&model, FastCacheConfig::with_policy(PolicyKind::NoCache));
        for r in &reqs {
            reference.push_latent(&eng.generate(r).unwrap().latent);
        }
    }
    let fid_of = |policy: PolicyKind| -> f64 {
        let mut acc = FidAccumulator::new();
        let mut eng = DenoiseEngine::new(&model, FastCacheConfig::with_policy(policy));
        for r in &reqs {
            acc.push_latent(&eng.generate(r).unwrap().latent);
        }
        acc.distance_to(&reference)
    };
    let fast = fid_of(PolicyKind::FastCache);
    let stat = fid_of(PolicyKind::StaticCache);
    assert!(
        fast < stat,
        "FastCache FID-proxy {fast} should beat StaticCache {stat}"
    );
}

#[test]
fn hlo_server_smoke() {
    if !Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let mut scfg = ServerConfig::default();
    scfg.max_batch = 2;
    scfg.steps = 4;
    let fc = FastCacheConfig::default();
    let server = Server::start(scfg, fc, || {
        let client = Arc::new(Client::cpu()?);
        let store = Arc::new(ArtifactStore::open(Path::new("artifacts"))?);
        DitModel::load(client, store, Variant::S, 5)
    });
    let mut wl = WorkloadGen::new(6);
    let reqs = wl.image_set(3, 4, MotionProfile::MIXED);
    let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone()).unwrap()).collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.result.latent.data().iter().all(|v| v.is_finite()));
    }
    let report = server.shutdown();
    assert_eq!(report.completed, 3);
}
