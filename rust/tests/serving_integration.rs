//! Serving-layer integration: dispatcher → per-shard SLA-aware queue →
//! continuous-batching shard worker → lane stepper → response, over the
//! native execution path (fast) plus one HLO-backed smoke test when
//! artifacts are present.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use fastcache_dit::config::{FastCacheConfig, PolicyKind, ServerConfig, Variant};
use fastcache_dit::metrics::FidAccumulator;
use fastcache_dit::model::DitModel;
use fastcache_dit::runtime::{ArtifactStore, Client};
use fastcache_dit::scheduler::{DenoiseEngine, GenRequest};
use fastcache_dit::api::ErrorCode;
use fastcache_dit::server::Server;
use fastcache_dit::tensor::Tensor;
use fastcache_dit::workload::{MotionProfile, WorkloadGen};

fn native_server(policy: PolicyKind, max_batch: usize) -> Server {
    native_server_sharded(policy, max_batch, 1)
}

fn native_server_sharded(policy: PolicyKind, max_batch: usize, workers: usize) -> Server {
    let scfg = ServerConfig { max_batch, queue_depth: 64, workers, ..ServerConfig::default() };
    let mut fc = FastCacheConfig::with_policy(policy);
    fc.enable_str = false;
    Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 5)))
}

#[test]
fn throughput_improves_with_caching() {
    // Same workload, NoCache vs FastCache: cached serving must complete
    // faster in wall time (on identical hardware and requests).
    let mut wl = WorkloadGen::new(1);
    let reqs = wl.image_set(6, 12, MotionProfile::CALM);

    let mut walls = Vec::new();
    for policy in [PolicyKind::NoCache, PolicyKind::FastCache] {
        let server = native_server(policy, 2);
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = reqs
            .iter()
            .map(|r| server.submit(r).expect("submit"))
            .collect();
        for rx in rxs {
            rx.wait().completed();
        }
        walls.push(t0.elapsed().as_secs_f64());
        let report = server.shutdown();
        assert_eq!(report.completed, 6);
    }
    assert!(
        walls[1] < walls[0],
        "fastcache serving ({:.3}s) not faster than nocache ({:.3}s)",
        walls[1],
        walls[0]
    );
}

#[test]
fn str_enabled_serving_batches_and_matches_single_request() {
    // The config the paper actually evaluates (FastCache with STR on) used
    // to be gated out of batching entirely. It must now batch AND return
    // the same numerics as a solo engine run.
    let scfg = ServerConfig { max_batch: 4, queue_depth: 64, ..ServerConfig::default() };
    let fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
    assert!(fc.enable_str);
    let server = Server::start(scfg, fc.clone(), || Ok(DitModel::native(Variant::S, 5)));

    let mut wl = WorkloadGen::new(8);
    let reqs = wl.image_set(8, 6, MotionProfile::MIXED);
    let rxs: Vec<_> = reqs
        .iter()
        .map(|r| (r.clone(), server.submit(r).expect("submit")))
        .collect();
    let model = DitModel::native(Variant::S, 5);
    for (req, rx) in rxs {
        let resp = rx.wait().completed();
        let mut eng = DenoiseEngine::new(&model, fc.clone());
        let solo = eng.generate(&req).expect("solo generate");
        let md = resp.result.latent.max_abs_diff(&solo.latent);
        assert!(md < 1e-4, "req {}: served vs solo diff {md}", req.id);
    }
    let report = server.shutdown();
    assert_eq!(report.completed, 8);
    assert!(
        report.mean_batch_size() > 1.0,
        "STR serving did not batch: occupancy {}",
        report.mean_batch_size()
    );
}

#[test]
fn responses_match_request_ids_under_batching() {
    let server = native_server(PolicyKind::FastCache, 4);
    let mut wl = WorkloadGen::new(2);
    let reqs = wl.image_set(9, 6, MotionProfile::MIXED);
    let rxs: Vec<_> = reqs
        .iter()
        .map(|r| (r.id, server.submit(r).unwrap()))
        .collect();
    for (id, rx) in rxs {
        let resp = rx.wait().completed();
        assert_eq!(resp.result.id, id, "response routed to wrong request");
    }
    server.shutdown();
}

/// Serve one fixed-seed burst at a given worker count; latents keyed by
/// request id.
fn serve_burst(workers: usize, reqs: &[GenRequest]) -> BTreeMap<u64, Tensor> {
    let server = native_server_sharded(PolicyKind::FastCache, 4, workers);
    let rxs: Vec<_> = reqs
        .iter()
        .map(|r| (r.id, server.submit_blocking(r).expect("submit")))
        .collect();
    let mut out = BTreeMap::new();
    for (id, rx) in rxs {
        let resp = rx.wait().completed();
        assert_eq!(resp.result.id, id);
        out.insert(id, resp.result.latent);
    }
    let report = server.shutdown();
    assert_eq!(report.completed, reqs.len() as u64);
    assert_eq!(report.shards.len(), workers);
    out
}

#[test]
fn fixed_seed_latents_are_bit_identical_across_worker_counts() {
    // Lanes are numerically independent (the native batched block loops
    // per example; STR buckets, DDIM, turbulence RNG are all per-lane),
    // so how the dispatcher shards a fixed-seed burst must not change a
    // single bit of any latent: workers=1 and workers=4 agree exactly.
    let mut wl = WorkloadGen::new(77);
    let reqs = wl.image_set(8, 6, MotionProfile::MIXED);
    let solo = serve_burst(1, &reqs);
    let sharded = serve_burst(4, &reqs);
    assert_eq!(solo.len(), sharded.len());
    for (id, latent) in &solo {
        let other = &sharded[id];
        assert_eq!(
            latent.data(),
            other.data(),
            "req {id}: workers=1 vs workers=4 latents diverge (max diff {})",
            latent.max_abs_diff(other)
        );
    }
}

#[test]
fn sharded_deadline_traffic_is_tracked_per_class() {
    // A burst with a deadline-tagged slice through a 2-shard server: the
    // per-class accounting must cover every request exactly once, and a
    // generous budget must be met.
    let server = native_server_sharded(PolicyKind::FastCache, 2, 2);
    let mut wl = WorkloadGen::new(9);
    let reqs: Vec<GenRequest> = wl
        .image_set(8, 5, MotionProfile::MIXED)
        .into_iter()
        .enumerate()
        .map(|(i, r)| if i % 2 == 0 { r.into_builder().deadline_ms(300_000.0).build().unwrap() } else { r })
        .collect();
    let rxs: Vec<_> = reqs
        .iter()
        .map(|r| (r.deadline_ms.is_some(), server.submit_blocking(r).unwrap()))
        .collect();
    for (tagged, rx) in rxs {
        let resp = rx.wait().completed();
        assert_eq!(resp.deadline_met.is_some(), tagged);
    }
    let report = server.shutdown();
    assert_eq!(report.completed, 8);
    assert_eq!(report.deadline_jobs, 4);
    assert_eq!(report.best_effort_jobs, 4);
    assert_eq!(report.deadline_hit_rate(), Some(1.0), "5-minute budget must be met");
    let by_shard: u64 = report.shards.iter().map(|s| s.deadline_jobs + s.best_effort_jobs).sum();
    assert_eq!(by_shard, 8, "per-shard class counts must cover the burst");
}

#[test]
fn backpressure_and_shutdown_error_paths() {
    // QueueFull: a saturated bounded queue pushes back instead of
    // buffering unboundedly...
    let scfg = ServerConfig { max_batch: 1, queue_depth: 1, ..ServerConfig::default() };
    let mut fc = FastCacheConfig::with_policy(PolicyKind::NoCache);
    fc.enable_str = false;
    let server = Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 5)));
    let mut accepted = Vec::new();
    let mut saw_full = false;
    for i in 0..64 {
        match server.submit(&GenRequest::builder(i, i).steps(6).build().unwrap()) {
            Ok(rx) => accepted.push(rx),
            Err(rej) if rej.code == ErrorCode::Busy => {
                saw_full = true;
                break;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(saw_full, "bounded queue never reported Busy");
    for rx in accepted {
        rx.wait().completed();
    }
    // ...and once the server is shut down, the queues report Closed (the
    // owning handle is consumed by shutdown, so exercise the shard queue
    // directly).
    server.shutdown();
    let q = fastcache_dit::server::JobQueue::new(4);
    q.close();
    let (tx, _rx) = std::sync::mpsc::channel();
    let job = fastcache_dit::server::Job {
        req: GenRequest::builder(0, 0).steps(2).build().unwrap(),
        resp: tx,
        submitted: std::time::Instant::now(),
        cost: 1,
        progress: false,
    };
    match q.push(job) {
        fastcache_dit::server::queue::Push::Closed(_) => {}
        _ => panic!("closed queue must reject submissions with Closed"),
    }
}

#[test]
fn warm_start_flag_with_empty_store_matches_warm_start_off_exactly() {
    // The warm-start subsystem's determinism contract: enabling the flag
    // changes NOTHING until the store actually holds data. One request
    // per server (so nothing retires-and-publishes before admission): the
    // warm server consults an empty store (all misses) and must produce a
    // bit-identical latent to the cold server.
    let req = GenRequest::builder(0, 1234).steps(8).build().unwrap();
    let run = |warm: bool| -> Tensor {
        let scfg = ServerConfig { max_batch: 2, queue_depth: 8, ..ServerConfig::default() };
        let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        fc.warm_start = warm;
        fc.fit_min_updates = 4; // same gate both sides — it is store-independent
        let server = Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 5)));
        let rx = server.submit(&req).expect("submit");
        let latent = rx.wait().completed().result.latent;
        let report = server.shutdown();
        if warm {
            let stats = report.store.expect("warm server reports its store");
            assert_eq!(stats.hits, 0, "empty store cannot hit");
            assert_eq!(report.warm_admissions, 0);
        } else {
            assert!(report.store.is_none());
        }
        latent
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(
        off.data(),
        on.data(),
        "warm-start on (empty store) vs off diverged: max diff {}",
        off.max_abs_diff(&on)
    );
}

#[test]
fn warm_started_second_burst_is_cheaper_at_bounded_quality() {
    // Fleet behavior across server restarts: burst 1 populates a caller-
    // owned store; burst 2 (a NEW server sharing the store) warm-starts,
    // executes fewer FLOPs, and stays within the quality envelope of the
    // same χ² bound.
    use fastcache_dit::store::WarmStore;
    let scfg = ServerConfig { max_batch: 8, queue_depth: 16, ..ServerConfig::default() };
    let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
    fc.enable_str = false;
    fc.warm_start = true;
    fc.fit_min_updates = 5;
    fc.tau_delta0 = 1.0;
    let store = Arc::new(WarmStore::new(scfg.warm_budget_bytes, 1));

    let mut wl = WorkloadGen::new(31);
    let reqs = wl.image_set(4, 10, MotionProfile::MIXED);
    let burst = |expect_warm: bool| -> (u64, Vec<Tensor>) {
        let store = Some(Arc::clone(&store));
        // Fingerprint contract: factory seed == scfg.weight_seed.
        let seed = scfg.weight_seed;
        let server = Server::start_with_store(scfg.clone(), fc.clone(), store, move || {
            Ok(DitModel::native(Variant::S, seed))
        });
        let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r).unwrap()).collect();
        let mut flops = 0;
        let mut latents = Vec::new();
        for rx in rxs {
            let resp = rx.wait().completed();
            assert_eq!(resp.result.warm_layers > 0, expect_warm);
            flops += resp.result.flops_done;
            latents.push(resp.result.latent);
        }
        let report = server.shutdown();
        let stats = report.store.expect("store stats");
        assert!(stats.used_bytes <= stats.budget_bytes, "budget invariant broke");
        (flops, latents)
    };
    let (cold_flops, cold_latents) = burst(false);
    let (warm_flops, warm_latents) = burst(true);
    assert!(
        warm_flops < cold_flops,
        "warm burst must be cheaper: {warm_flops} vs {cold_flops}"
    );
    // Quality envelope: warm latents stay close to the cold rendering of
    // the same seeds (both are χ²-bounded approximations of the same
    // trajectory).
    for (c, w) in cold_latents.iter().zip(&warm_latents) {
        assert!(w.data().iter().all(|v| v.is_finite()));
        let rel = {
            let diff: f64 = c
                .data()
                .iter()
                .zip(w.data())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            let base: f64 =
                c.data().iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
            diff / base.max(1e-9)
        };
        assert!(rel < 0.5, "warm latent drifted {rel} from cold rendering");
    }
}

#[test]
fn quality_reference_is_self_consistent() {
    // The FID-proxy of a policy against itself (same seeds) is ~0; against
    // a different-seed NoCache set it is small but positive.
    let model = DitModel::native(Variant::S, 5);
    let fc = FastCacheConfig::with_policy(PolicyKind::NoCache);
    let mut wl = WorkloadGen::new(3);
    let reqs = wl.image_set(16, 8, MotionProfile::MIXED);
    let mut eng = DenoiseEngine::new(&model, fc);
    let mut a = FidAccumulator::new();
    let mut b = FidAccumulator::new();
    for r in &reqs {
        let out = eng.generate(r).unwrap();
        a.push_latent(&out.latent);
        b.push_latent(&out.latent);
    }
    assert!(a.distance_to(&b) < 1e-9);
}

#[test]
fn cached_policies_rank_by_quality() {
    // More aggressive reuse => further from the NoCache reference. This is
    // the core ordering every paper table relies on: FastCache (learnable
    // approx + blending) must beat plain whole-step reuse (StaticCache).
    let model = DitModel::native(Variant::S, 5);
    let mut wl = WorkloadGen::new(4);
    let reqs = wl.image_set(24, 10, MotionProfile::MIXED);

    let mut reference = FidAccumulator::new();
    {
        let mut eng =
            DenoiseEngine::new(&model, FastCacheConfig::with_policy(PolicyKind::NoCache));
        for r in &reqs {
            reference.push_latent(&eng.generate(r).unwrap().latent);
        }
    }
    let fid_of = |policy: PolicyKind| -> f64 {
        let mut acc = FidAccumulator::new();
        let mut eng = DenoiseEngine::new(&model, FastCacheConfig::with_policy(policy));
        for r in &reqs {
            acc.push_latent(&eng.generate(r).unwrap().latent);
        }
        acc.distance_to(&reference)
    };
    let fast = fid_of(PolicyKind::FastCache);
    let stat = fid_of(PolicyKind::StaticCache);
    assert!(
        fast < stat,
        "FastCache FID-proxy {fast} should beat StaticCache {stat}"
    );
}

#[test]
fn hlo_server_smoke() {
    if !Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let scfg = ServerConfig { max_batch: 2, steps: 4, ..ServerConfig::default() };
    let fc = FastCacheConfig::default();
    let server = Server::start(scfg, fc, || {
        let client = Arc::new(Client::cpu()?);
        let store = Arc::new(ArtifactStore::open(Path::new("artifacts"))?);
        DitModel::load(client, store, Variant::S, 5)
    });
    let mut wl = WorkloadGen::new(6);
    let reqs = wl.image_set(3, 4, MotionProfile::MIXED);
    let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r).unwrap()).collect();
    for rx in rxs {
        let resp = rx.wait().completed();
        assert!(resp.result.latent.data().iter().all(|v| v.is_finite()));
    }
    let report = server.shutdown();
    assert_eq!(report.completed, 3);
}
