//! Wire-codec properties (docs/PROTOCOL.md): every frame type round-trips
//! bit-exactly through encode → decode, ragged latent lengths chunk and
//! reassemble losslessly, and hostile inputs — truncations at every byte
//! boundary, bad magic, unknown types, oversized lengths, lying counts,
//! invalid UTF-8, semantically bad requests — are rejected with typed
//! errors, never a panic or an unbounded allocation.

use fastcache_dit::api::{ErrorCode, Progress};
use fastcache_dit::config::{C_IN, N_TOKENS};
use fastcache_dit::net::proto::{
    self, decode_slice, encode, partial_frames, read_frame, Completed, PARTIAL_CHUNK_F32,
};
use fastcache_dit::net::{Frame, HealthBody, ProtoError, MAX_FRAME_LEN, VERSION};
use fastcache_dit::obs::{HistSummary, Series, SeriesValue};
use fastcache_dit::rng::Rng;
use fastcache_dit::scheduler::{GenRequest, Turbulence};
use fastcache_dit::tensor::Tensor;

fn sample_completed(id: u64, deadline_met: Option<bool>) -> Completed {
    Completed {
        id,
        shape: vec![N_TOKENS as u32, C_IN as u32],
        queued_ms: 12.25,
        e2e_ms: 340.5,
        deadline_met,
        wall_ms: 328.25,
        computed: 100,
        approximated: 40,
        reused: 9,
        token_sites_computed: 12_345,
        token_sites_total: 20_000,
        flops_done: 1 << 33,
        flops_full: 1 << 34,
        flops_padded: 123,
        cache_bytes_peak: 4096,
        warm_layers: 3,
        degraded: id % 2 == 1,
        degrade_rungs: if id % 2 == 1 { 2 } else { 0 },
    }
}

/// One of every frame type, several with ragged payload sizes.
fn sample_frames() -> Vec<Frame> {
    let mut rng = Rng::new(0xF4A3);
    let full = GenRequest::builder(42, 7)
        .cond_seed(99)
        .guidance(3.25)
        .steps(12)
        .deadline_ms(1500.0)
        .turbulence(Turbulence { tokens: vec![0, 5, 63], amp: 0.5, seed: 11 })
        .init_latent(Tensor::new(rng.normal_vec(N_TOKENS * C_IN, 1.0), &[N_TOKENS, C_IN]))
        .build()
        .unwrap();
    let mut frames = vec![
        Frame::Hello { version: VERSION },
        Frame::HelloAck { version: 7 },
        Frame::Submit { req: GenRequest::builder(1, 2).build().unwrap(), progress: false },
        Frame::Submit { req: full, progress: true },
        Frame::Goodbye,
        Frame::Progress(Progress { id: u64::MAX, step: 3, total: 50 }),
        Frame::Completed(sample_completed(1, None)),
        Frame::Completed(sample_completed(2, Some(true))),
        Frame::Completed(sample_completed(3, Some(false))),
        Frame::Shed { id: 8, waited_ms: 1234.5, deadline_ms: 1000.0 },
        Frame::Error { id: 0, code: ErrorCode::Busy.code(), detail: String::new() },
        Frame::Error { id: 9, code: 0xBEEF, detail: "unknown codes round-trip raw".into() },
        Frame::Error {
            id: 7,
            code: ErrorCode::Poisoned.code(),
            detail: "request 7 blocklisted after 2 typed quarantines".into(),
        },
        Frame::Stats,
        Frame::Health,
        // Liveness replies: an empty single-shard door, a draining door
        // with every health state plus an unknown forward-compat code,
        // and counter edges.
        Frame::HealthReply(HealthBody {
            draining: false,
            restarts: 0,
            blocklisted: 0,
            shards: vec![(0, 0)],
        }),
        Frame::HealthReply(HealthBody {
            draining: true,
            restarts: u64::MAX,
            blocklisted: 3,
            shards: vec![(0, 0), (1, 1), (2, 2), (3, 3), (u32::MAX, 0xEE)],
        }),
        Frame::HealthReply(HealthBody {
            draining: false,
            restarts: 1,
            blocklisted: 0,
            shards: Vec::new(),
        }),
        // An empty scrape and one exercising every series kind, plus the
        // edges: empty name, zero count, zero values.
        Frame::StatsReply(Vec::new()),
        Frame::StatsReply(vec![
            Series { name: "server.completed".into(), value: SeriesValue::Counter(u64::MAX) },
            Series { name: String::new(), value: SeriesValue::Counter(0) },
            Series { name: "server.scratch_bytes".into(), value: SeriesValue::Gauge(1 << 20) },
            Series {
                name: "latency.e2e_ms".into(),
                value: SeriesValue::Hist(HistSummary {
                    count: 12,
                    mean_ms: 41.5,
                    p50_ms: 38.0,
                    p95_ms: 92.25,
                    p99_ms: 140.5,
                    max_ms: 151.0,
                }),
            },
            Series {
                name: "latency.admission_ms".into(),
                value: SeriesValue::Hist(HistSummary {
                    count: 0,
                    mean_ms: 0.0,
                    p50_ms: 0.0,
                    p95_ms: 0.0,
                    p99_ms: 0.0,
                    max_ms: 0.0,
                }),
            },
        ]),
    ];
    for n in [0usize, 1, 3, 1000] {
        frames.push(Frame::Partial {
            id: n as u64,
            offset: 16,
            total: 64 * 1024,
            values: rng.normal_vec(n, 2.0),
        });
    }
    frames
}

#[test]
fn every_frame_type_round_trips_exactly() {
    for frame in sample_frames() {
        let buf = encode(&frame);
        let (back, consumed) = decode_slice(&buf)
            .unwrap_or_else(|e| panic!("decode failed for {frame:?}: {e}"));
        assert_eq!(consumed, buf.len(), "partial consume for {frame:?}");
        assert_eq!(back, frame);
        // The streaming reader agrees with the slice decoder.
        let mut cursor = std::io::Cursor::new(buf.clone());
        let (streamed, n) = read_frame(&mut cursor).unwrap().expect("frame expected");
        assert_eq!(streamed, frame);
        assert_eq!(n, buf.len());
    }
}

#[test]
fn ragged_latents_chunk_and_reassemble_bit_identically() {
    let mut rng = Rng::new(0xC0FFEE);
    for n in [0usize, 1, PARTIAL_CHUNK_F32 - 1, PARTIAL_CHUNK_F32, PARTIAL_CHUNK_F32 + 1, 3 * PARTIAL_CHUNK_F32 + 7] {
        let values = rng.normal_vec(n, 1.0);
        let frames = partial_frames(77, &values);
        assert!(!frames.is_empty(), "even empty latents ship one chunk");
        let mut got: Vec<f32> = Vec::new();
        for f in &frames {
            let buf = encode(f);
            match decode_slice(&buf).unwrap().0 {
                Frame::Partial { id, offset, total, values: chunk } => {
                    assert_eq!(id, 77);
                    assert_eq!(total as usize, n);
                    assert_eq!(offset as usize, got.len(), "chunks must be in offset order");
                    assert!(chunk.len() <= PARTIAL_CHUNK_F32);
                    got.extend_from_slice(&chunk);
                }
                other => panic!("expected Partial, got {other:?}"),
            }
        }
        // Bit-identical: compare IEEE-754 bit patterns, not float equality.
        let a: Vec<u32> = values.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "n={n} latent did not survive chunking");
    }
}

#[test]
fn truncation_at_every_boundary_is_a_typed_error() {
    for frame in sample_frames() {
        let buf = encode(&frame);
        for cut in 0..buf.len() {
            match decode_slice(&buf[..cut]) {
                Err(ProtoError::Truncated) => {}
                other => panic!("cut at {cut}/{} of {frame:?}: expected Truncated, got {other:?}", buf.len()),
            }
        }
    }
}

#[test]
fn streaming_reader_distinguishes_clean_eof_from_mid_frame_eof() {
    let buf = encode(&Frame::Goodbye);
    // Clean EOF at a frame boundary: None, not an error.
    let mut empty = std::io::Cursor::new(Vec::<u8>::new());
    assert!(read_frame(&mut empty).unwrap().is_none());
    // EOF inside the header and inside the body: Truncated.
    for cut in 1..buf.len() {
        let mut cursor = std::io::Cursor::new(buf[..cut].to_vec());
        match read_frame(&mut cursor) {
            Err(ProtoError::Truncated) => {}
            other => panic!("cut at {cut}: expected Truncated, got {other:?}"),
        }
    }
}

#[test]
fn hostile_inputs_are_rejected_without_panic() {
    // Oversized length prefix: rejected from 4 bytes, before any body.
    let mut oversized = (MAX_FRAME_LEN + 1).to_le_bytes().to_vec();
    oversized.extend_from_slice(&[0u8; 16]);
    assert!(matches!(decode_slice(&oversized), Err(ProtoError::Oversized { .. })));
    let mut cursor = std::io::Cursor::new(oversized);
    assert!(matches!(read_frame(&mut cursor), Err(ProtoError::Oversized { .. })));

    // Zero-length frame (no type byte).
    assert!(matches!(decode_slice(&0u32.to_le_bytes()), Err(ProtoError::Malformed(_))));

    // Unknown type byte.
    let unknown = [1u32.to_le_bytes().as_slice(), &[0x7F]].concat();
    assert!(matches!(decode_slice(&unknown), Err(ProtoError::UnknownType(0x7F))));

    // Bad magic in a Hello.
    let mut hello = encode(&Frame::Hello { version: VERSION });
    hello[5] ^= 0xFF;
    assert!(matches!(decode_slice(&hello), Err(ProtoError::BadMagic(_))));

    // Trailing bytes after a complete payload.
    let mut trailing = encode(&Frame::Goodbye);
    trailing[0..4].copy_from_slice(&2u32.to_le_bytes());
    trailing.push(0xAA);
    assert!(matches!(decode_slice(&trailing), Err(ProtoError::Malformed(_))));

    // A Partial whose count field lies about the payload: rejected by the
    // count-vs-remaining check before any allocation happens.
    let mut lying = encode(&Frame::Partial { id: 1, offset: 0, total: 4, values: vec![1.0] });
    let count_at = 4 + 1 + 8 + 4 + 4; // len, type, id, offset, total
    lying[count_at..count_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(decode_slice(&lying), Err(ProtoError::Malformed(_))));

    // A HealthReply whose shard count lies about the payload: same
    // pre-allocation guard as Partial.
    let mut lying_health = encode(&Frame::HealthReply(HealthBody {
        draining: false,
        restarts: 0,
        blocklisted: 0,
        shards: vec![(0, 0)],
    }));
    let count_at = 4 + 1 + 1 + 8 + 8; // len, type, draining, restarts, blocklisted
    lying_health[count_at..count_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(decode_slice(&lying_health), Err(ProtoError::Malformed(_))));

    // Invalid UTF-8 in an Error detail.
    let mut bad_utf8 = encode(&Frame::Error { id: 1, code: 1, detail: "ab".into() });
    let detail_at = bad_utf8.len() - 2;
    bad_utf8[detail_at] = 0xFF;
    bad_utf8[detail_at + 1] = 0xFE;
    assert!(matches!(decode_slice(&bad_utf8), Err(ProtoError::Malformed(_))));
}

/// Hand-build a structurally valid Submit payload with chosen field
/// values (the builder refuses to construct invalid requests, so hostile
/// Submits must be forged at the byte level).
fn forge_submit(steps: u32, guidance: f32, deadline: Option<f64>) -> Vec<u8> {
    let mut body = vec![0x02u8]; // T_SUBMIT
    body.extend_from_slice(&1u64.to_le_bytes()); // id
    body.extend_from_slice(&2u64.to_le_bytes()); // seed
    body.extend_from_slice(&3u64.to_le_bytes()); // cond_seed
    body.extend_from_slice(&guidance.to_le_bytes());
    body.extend_from_slice(&steps.to_le_bytes());
    match deadline {
        Some(ms) => {
            body.push(1);
            body.extend_from_slice(&ms.to_le_bytes());
        }
        None => body.push(0),
    }
    body.push(0); // no turbulence
    body.push(0); // no init latent
    body.push(0); // progress off
    let mut buf = (body.len() as u32).to_le_bytes().to_vec();
    buf.extend_from_slice(&body);
    buf
}

#[test]
fn forged_invalid_submits_get_the_in_process_validation_rejection() {
    // Sanity: a forged VALID submit decodes.
    let ok = forge_submit(10, 7.5, Some(100.0));
    assert!(matches!(decode_slice(&ok), Ok((Frame::Submit { .. }, _))));

    // steps = 0, NaN guidance, NaN deadline: each rejected as the typed
    // BadRequest an in-process builder call would produce.
    for bytes in [
        forge_submit(0, 7.5, None),
        forge_submit(10, f32::NAN, None),
        forge_submit(10, 7.5, Some(f64::NAN)),
        forge_submit(10, 7.5, Some(-5.0)),
    ] {
        match decode_slice(&bytes) {
            Err(ProtoError::BadRequest(rej)) => {
                assert_eq!(rej.code, ErrorCode::BadRequest);
                assert_eq!(rej.id, 1, "rejection must carry the request id");
            }
            other => panic!("expected BadRequest, got {other:?}"),
        }
    }
}

#[test]
fn oversized_detail_strings_clamp_instead_of_breaking_framing() {
    let detail = "x".repeat(u16::MAX as usize + 500);
    let buf = encode(&Frame::Error { id: 4, code: 2, detail });
    match decode_slice(&buf).unwrap().0 {
        Frame::Error { detail, .. } => assert_eq!(detail.len(), u16::MAX as usize),
        other => panic!("expected Error, got {other:?}"),
    }
}

#[test]
fn completed_reassembly_validates_shape_against_values() {
    let c = sample_completed(5, Some(true));
    let want: usize = c.shape.iter().map(|&d| d as usize).product();
    let resp = c.clone().into_response(vec![0.5; want]).expect("matching length");
    assert_eq!(resp.result.latent.shape(), [N_TOKENS, C_IN]);
    assert_eq!(resp.deadline_met, Some(true));
    assert!(matches!(c.into_response(vec![0.5; want - 1]), Err(ProtoError::Malformed(_))));
}

#[test]
fn version_is_stable_and_request_response_spaces_are_disjoint() {
    // v4 added the Health/HealthReply liveness pair and the Poisoned
    // error code (docs/PROTOCOL.md).
    assert_eq!(VERSION, 4);
    assert_eq!(proto::MAGIC, u32::from_le_bytes(*b"FCP1"));
    // Request frames encode type bytes < 0x80, responses >= 0x80.
    for frame in sample_frames() {
        let ty = encode(&frame)[4];
        let is_request = matches!(
            frame,
            Frame::Hello { .. }
                | Frame::Submit { .. }
                | Frame::Goodbye
                | Frame::Stats
                | Frame::Health
        );
        assert_eq!(ty < 0x80, is_request, "type byte space violated for {frame:?}");
    }
}

#[test]
fn stats_reply_with_unknown_series_kind_is_malformed_not_a_panic() {
    let buf = encode(&Frame::StatsReply(vec![Series {
        name: "x".into(),
        value: SeriesValue::Counter(7),
    }]));
    // Payload layout: len(4) type(1) count(4) name_len(2) name(1) kind(1)…
    let kind_at = 4 + 1 + 4 + 2 + 1;
    let mut bad = buf.clone();
    bad[kind_at] = 0x7F;
    assert!(matches!(decode_slice(&bad), Err(ProtoError::Malformed(_))));
    // A lying series count is caught by the pre-allocation guard.
    let mut lying = buf;
    lying[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(decode_slice(&lying), Err(ProtoError::Malformed(_))));
}
