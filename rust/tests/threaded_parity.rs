//! Determinism tests for the intra-op threaded kernels: every `_t` entry
//! point (and the arena-driven block/final paths) must be BIT-IDENTICAL
//! to its serial form for any thread count. This is the contract that
//! lets `--threads` be a pure wall-time knob — served latents never
//! depend on how many cores the host happened to grant.
//!
//! Why bit-identity is achievable at all: the row partition hands each
//! worker whole MR/MQ-aligned row blocks, and no kernel's per-row (or
//! per-query) accumulation ever reads another row's state — so
//! regrouping rows across workers reorders nothing within any one
//! output element.
//!
//! Shapes cover n ∈ {1, 7, 64, 256} (including ragged tails that leave
//! some workers with short or empty chunks) × threads ∈ {1, 2, 4}.

use fastcache_dit::config::{ModelConfig, Variant};
use fastcache_dit::model::kernels::{self, Act, PackedLinear, ScratchArena};
use fastcache_dit::model::{native, WeightBank};
use fastcache_dit::rng::Rng;
use fastcache_dit::tensor::Tensor;

const SHAPES: [usize; 4] = [1, 7, 64, 256];
const THREADS: [usize; 3] = [1, 2, 4];

fn rnd(seed: u64, len: usize) -> Vec<f32> {
    Rng::new(seed).normal_vec(len, 1.0)
}

#[test]
fn threaded_packed_matmuls_bit_identical_to_serial() {
    let cfg = ModelConfig::of(Variant::S);
    let bank = WeightBank::generate(cfg, 0xD17);
    let w = &bank.blocks[0];
    // qkv [D, 3D] and mlp-up [D, 4D]: ragged and aligned output tiles.
    for p in [
        PackedLinear::pack(&w.wqkv, Some(&w.bqkv)),
        PackedLinear::pack(&w.w1, Some(&w.b1)),
    ] {
        for &n in &SHAPES {
            let x = rnd(100 + n as u64, n * p.k());
            let gate = rnd(101, p.m());
            let mut serial = vec![0.0f32; n * p.m()];
            p.forward(&x, n, Act::Gelu, &mut serial);
            let mut serial_gated = rnd(102, n * p.m());
            p.forward_add_gated(&x, n, &gate, &mut serial_gated);
            for &t in &THREADS {
                let mut got = vec![0.0f32; n * p.m()];
                p.forward_t(&x, n, Act::Gelu, &mut got, t);
                assert_eq!(serial, got, "forward_t n={n} threads={t} diverged");
                let mut got_gated = rnd(102, n * p.m());
                p.forward_add_gated_t(&x, n, &gate, &mut got_gated, t);
                assert_eq!(
                    serial_gated, got_gated,
                    "forward_add_gated_t n={n} threads={t} diverged"
                );
            }
        }
    }
}

#[test]
fn threaded_sparse_entry_bit_identical_with_zero_rows() {
    // STR-style inputs: random rows zeroed out. The per-row zero
    // short-circuit must survive any partition of rows across workers.
    let cfg = ModelConfig::of(Variant::S);
    let bank = WeightBank::generate(cfg, 0xD17);
    let p = PackedLinear::pack(&bank.blocks[0].w1, Some(&bank.blocks[0].b1));
    for &n in &SHAPES {
        let mut x = rnd(110 + n as u64, n * cfg.d);
        let mut rng = Rng::new(n as u64);
        for r in 0..n {
            if rng.uniform() < 0.5 {
                x[r * cfg.d..(r + 1) * cfg.d].fill(0.0);
            }
        }
        let mut serial = vec![0.0f32; n * p.m()];
        p.forward_sparse(&x, n, Act::Gelu, &mut serial);
        for &t in &THREADS {
            let mut got = vec![0.0f32; n * p.m()];
            p.forward_sparse_t(&x, n, Act::Gelu, &mut got, t);
            assert_eq!(serial, got, "forward_sparse_t n={n} threads={t} diverged");
        }
    }
}

#[test]
fn threaded_layernorm_and_attention_bit_identical_to_serial() {
    let cfg = ModelConfig::of(Variant::S);
    let d = cfg.d;
    for &n in &SHAPES {
        let x = rnd(120 + n as u64, n * d);
        let shift = rnd(121, d);
        let scale = rnd(122, d);
        let mut ln_serial = vec![0.0f32; n * d];
        kernels::layernorm_mod(&x, n, d, &shift, &scale, &mut ln_serial);
        let qkv = rnd(123 + n as u64, n * 3 * d);
        let mut at_serial = vec![0.0f32; n * d];
        kernels::attention_streaming(&qkv, n, cfg.heads, d, &mut at_serial);
        for &t in &THREADS {
            let mut ln = rnd(124, n * d); // stale scratch must be wiped
            kernels::layernorm_mod_t(&x, n, d, &shift, &scale, &mut ln, t);
            assert_eq!(ln_serial, ln, "layernorm_mod_t n={n} threads={t} diverged");
            let mut at = rnd(125, n * d);
            kernels::attention_streaming_t(&qkv, n, cfg.heads, d, &mut at, t);
            assert_eq!(at_serial, at, "attention_streaming_t n={n} threads={t} diverged");
        }
    }
}

#[test]
fn threaded_arena_block_and_final_bit_identical_to_serial() {
    // The production route: LaneStepper sets the arena's thread count
    // once and every block/final call inherits it. Serial and threaded
    // arenas must produce byte-for-byte the same tensors.
    let cfg = ModelConfig::of(Variant::S);
    let bank = WeightBank::generate(cfg, 0xD17);
    let mut serial_arena = ScratchArena::new();
    for &n in &SHAPES {
        let h = Tensor::new(rnd(130 + n as u64, n * cfg.d), &[n, cfg.d]);
        let c = rnd(131, cfg.d);
        let want = native::block_forward(&h, &c, &cfg, &bank.packed.blocks[0], &mut serial_arena);
        let mut fwant = vec![0.0f32; n * cfg.c_in];
        native::final_forward_slice(
            h.data(),
            n,
            &c,
            &bank.packed.final_,
            &mut serial_arena,
            &mut fwant,
        );
        for &t in &THREADS {
            let mut arena = ScratchArena::new();
            arena.set_threads(t);
            let got = native::block_forward(&h, &c, &cfg, &bank.packed.blocks[0], &mut arena);
            assert_eq!(
                want.data(),
                got.data(),
                "block n={n} threads={t} diverged from serial"
            );
            let mut fgot = vec![0.0f32; n * cfg.c_in];
            native::final_forward_slice(
                h.data(),
                n,
                &c,
                &bank.packed.final_,
                &mut arena,
                &mut fgot,
            );
            assert_eq!(fwant, fgot, "final n={n} threads={t} diverged from serial");
        }
    }
}
