//! Property tests pinning the packed/fused/streaming kernels
//! (`model::kernels` + `model::native`) to the retained scalar oracle
//! (`testutil::oracle` — the pre-kernel implementation, moved there
//! verbatim).
//!
//! Contract split:
//! - Matmuls (packed, sparse-row entry, runtime-weight) are BIT-LEVEL
//!   parity: same k-ascending accumulation order as the oracle, the old
//!   `x == 0.0` skip only ever added exact zeros.
//! - The fused LayerNorm+adaLN is bit-level parity (identical
//!   arithmetic, one pass).
//! - Attention (and therefore the whole block) is TOLERANCE parity: the
//!   streaming softmax changes float-summation order only.
//!
//! Shapes cover n ∈ {1, 7, 64, 256} and every model variant (the full n
//! grid runs on DiT-S; the larger variants run the sub-quadratic sizes
//! so the debug-mode test suite stays fast).

use fastcache_dit::config::{ModelConfig, Variant};
use fastcache_dit::model::kernels::{self, Act, PackedLinear, ScratchArena};
use fastcache_dit::model::{native, WeightBank};
use fastcache_dit::rng::Rng;
use fastcache_dit::testutil::oracle;
use fastcache_dit::tensor::Tensor;

const SHAPES_FULL: [usize; 4] = [1, 7, 64, 256];
const SHAPES_SMALL: [usize; 3] = [1, 7, 64];

fn rnd(seed: u64, len: usize) -> Vec<f32> {
    Rng::new(seed).normal_vec(len, 1.0)
}

fn rnd_t(seed: u64, shape: &[usize]) -> Tensor {
    Tensor::new(rnd(seed, shape.iter().product()), shape)
}

fn shapes_for(v: Variant) -> &'static [usize] {
    // Full grid (incl. the n=256 acceptance shape) on DiT-S; the wider
    // variants skip the quadratic-attention size to keep debug-mode
    // `cargo test` tractable.
    if v == Variant::S {
        &SHAPES_FULL
    } else {
        &SHAPES_SMALL
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn packed_matmul_bit_parity_with_oracle_across_variants() {
    for v in Variant::ALL {
        let cfg = ModelConfig::of(v);
        let bank = WeightBank::generate(cfg, 0xD17);
        let w = &bank.blocks[0];
        for &n in shapes_for(v) {
            let x = rnd(10 + n as u64, n * cfg.d);
            // qkv [D, 3D] and mlp-up [D, 4D] exercise ragged/aligned tiles.
            for (t, b, p) in [
                (&w.wqkv, &w.bqkv, PackedLinear::pack(&w.wqkv, Some(&w.bqkv))),
                (&w.w1, &w.b1, PackedLinear::pack(&w.w1, Some(&w.b1))),
            ] {
                let want = oracle::matmul_bias(&x, t, Some(b), n);
                let mut got = vec![0.0f32; n * p.m()];
                p.forward(&x, n, Act::None, &mut got);
                let md = max_abs_diff(&got, &want);
                assert!(md < 1e-6, "{v} n={n}: packed matmul diff {md}");
            }
        }
    }
}

#[test]
fn sparse_row_entry_matches_dense_with_zeros() {
    // The STR contract: a gather-free caller may zero static rows and
    // use the sparse entry point; the result must be exactly what the
    // dense kernel produces on the same zero-padded input.
    let cfg = ModelConfig::of(Variant::S);
    let bank = WeightBank::generate(cfg, 0xD17);
    let p = PackedLinear::pack(&bank.blocks[0].w1, Some(&bank.blocks[0].b1));
    for &n in &SHAPES_FULL {
        let mut x = rnd(77 + n as u64, n * cfg.d);
        let mut rng = Rng::new(n as u64);
        for r in 0..n {
            if rng.uniform() < 0.5 {
                x[r * cfg.d..(r + 1) * cfg.d].fill(0.0);
            }
        }
        let mut dense = vec![0.0f32; n * p.m()];
        p.forward(&x, n, Act::Gelu, &mut dense);
        let mut sparse = vec![0.0f32; n * p.m()];
        p.forward_sparse(&x, n, Act::Gelu, &mut sparse);
        assert_eq!(dense, sparse, "n={n}: sparse-row entry diverged from dense");
    }
}

#[test]
fn fused_layernorm_adaln_bit_parity() {
    for v in Variant::ALL {
        let d = ModelConfig::of(v).d;
        for &n in shapes_for(v) {
            let x = rnd(31 + n as u64, n * d);
            let shift = rnd(32, d);
            let scale = rnd(33, d);
            let mut fused = vec![0.0f32; n * d];
            kernels::layernorm_mod(&x, n, d, &shift, &scale, &mut fused);
            let mut seq = x.clone();
            oracle::layer_norm(&mut seq, d);
            for row in seq.chunks_mut(d) {
                for (j, vv) in row.iter_mut().enumerate() {
                    *vv = *vv * (1.0 + scale[j]) + shift[j];
                }
            }
            assert_eq!(fused, seq, "{v} n={n}: fused LN+adaLN drifted");
        }
    }
}

#[test]
fn streaming_attention_tolerance_parity() {
    for v in Variant::ALL {
        let cfg = ModelConfig::of(v);
        let d = cfg.d;
        for &n in shapes_for(v) {
            let q = rnd(41 + n as u64, n * d);
            let k = rnd(42 + n as u64, n * d);
            let vv = rnd(43 + n as u64, n * d);
            let mut qkv = vec![0.0f32; n * 3 * d];
            for r in 0..n {
                qkv[r * 3 * d..r * 3 * d + d].copy_from_slice(&q[r * d..(r + 1) * d]);
                qkv[r * 3 * d + d..r * 3 * d + 2 * d].copy_from_slice(&k[r * d..(r + 1) * d]);
                qkv[r * 3 * d + 2 * d..r * 3 * d + 3 * d].copy_from_slice(&vv[r * d..(r + 1) * d]);
            }
            let mut got = rnd(44, n * d); // stale scratch must be wiped
            kernels::attention_streaming(&qkv, n, cfg.heads, d, &mut got);
            let want = oracle::attention(&q, &k, &vv, n, cfg.heads, d);
            let md = max_abs_diff(&got, &want);
            assert!(md < 1e-4, "{v} n={n}: attention diff {md}");
        }
    }
}

#[test]
fn fused_block_tolerance_parity_across_variants_and_shapes() {
    // The headline kernel: fused block vs the scalar oracle block, every
    // variant, every layer's distinct weights exercised via layer 0 and
    // the last layer (depth-dependent modulation scales).
    let mut arena = ScratchArena::new();
    for v in Variant::ALL {
        let cfg = ModelConfig::of(v);
        let bank = WeightBank::generate(cfg, 0xD17);
        for &n in shapes_for(v) {
            let h = rnd_t(50 + n as u64, &[n, cfg.d]);
            let c = rnd(51, cfg.d);
            for l in [0, cfg.layers - 1] {
                let got =
                    native::block_forward(&h, &c, &cfg, &bank.packed.blocks[l], &mut arena);
                let want = oracle::block_forward(&h, &c, &cfg, &bank.blocks[l]);
                let md = got.max_abs_diff(&want);
                assert!(md < 1e-3, "{v} n={n} layer={l}: block diff {md}");
            }
        }
    }
}

#[test]
fn temb_embed_final_parity() {
    let mut arena = ScratchArena::new();
    for v in Variant::ALL {
        let cfg = ModelConfig::of(v);
        let bank = WeightBank::generate(cfg, 0xD17);
        // temb: packed (fused SiLU epilogue) is bit-parity.
        for t in [0.0f32, 17.5, 500.0, 999.0] {
            let got = native::temb_forward(t, &bank.packed.temb);
            let want = oracle::temb_forward(t, &bank.temb);
            let md = max_abs_diff(&got, &want);
            assert!(md < 1e-6, "{v} t={t}: temb diff {md}");
        }
        for &n in shapes_for(v) {
            // embed.
            let x = rnd_t(60 + n as u64, &[n, cfg.c_in]);
            let mut got = vec![0.0f32; n * cfg.d];
            native::embed_forward_slice(x.data(), n, &bank.packed.embed, &mut got);
            let want = oracle::embed_forward(&x, &bank.embed);
            let md = max_abs_diff(&got, want.data());
            assert!(md < 1e-6, "{v} n={n}: embed diff {md}");
            // final (fused adaLN).
            let h = rnd_t(61 + n as u64, &[n, cfg.d]);
            let c = rnd(62, cfg.d);
            let mut fgot = vec![0.0f32; n * cfg.c_in];
            native::final_forward_slice(h.data(), n, &c, &bank.packed.final_, &mut arena, &mut fgot);
            let fwant = oracle::final_forward(&h, &c, &bank.final_);
            let fmd = max_abs_diff(&fgot, fwant.data());
            assert!(fmd < 1e-6, "{v} n={n}: final diff {fmd}");
        }
    }
}

#[test]
fn lane_kernel_bit_parity_with_scalar_and_oracle() {
    // The explicit-f32x8 inner loop keeps per-element summation order
    // (separate mul then add, never fused), so it is bit-exact against
    // both the scalar inner loop and the oracle — whichever way the
    // `simd` feature sets the compiled default.
    let cfg = ModelConfig::of(Variant::S);
    let bank = WeightBank::generate(cfg, 0xD17);
    let w = &bank.blocks[0];
    let p = PackedLinear::pack(&w.w1, Some(&w.b1));
    for &n in &SHAPES_FULL {
        let x = rnd(90 + n as u64, n * cfg.d);
        let mut scalar = vec![0.0f32; n * p.m()];
        p.forward_kernel(&x, n, Act::Gelu, &mut scalar, false);
        let mut lanes = vec![0.0f32; n * p.m()];
        p.forward_kernel(&x, n, Act::Gelu, &mut lanes, true);
        assert_eq!(scalar, lanes, "n={n}: lane inner loop is not bit-identical");
        // And against the oracle (Act::None so the oracle comparison is
        // the raw matmul).
        let mut raw = vec![0.0f32; n * p.m()];
        p.forward_kernel(&x, n, Act::None, &mut raw, true);
        let want = oracle::matmul_bias(&x, &w.w1, Some(&w.b1), n);
        let md = max_abs_diff(&raw, &want);
        assert!(md < 1e-6, "n={n}: lane kernel drifted from oracle by {md}");
    }
}

#[test]
fn int8_quantized_block_is_a_bounded_tolerance_tier() {
    // The int8 path is the one deliberate NON-bit-exact tier: per-tile
    // symmetric weight scales + per-row activation scales bound the
    // block-level drift, and the tier is strictly opt-in — a fresh bank
    // serves pure f32.
    let mut arena = ScratchArena::new();
    let cfg = ModelConfig::of(Variant::S);
    let bank = WeightBank::generate(cfg, 0xD17);
    assert!(
        bank.packed.blocks.iter().all(|b| b.int8.is_none()),
        "int8 must be opt-in: a fresh bank carries no quantized panels"
    );
    let mut qbank = bank.clone();
    qbank.quantize_int8();
    for &n in &SHAPES_SMALL {
        let h = rnd_t(95 + n as u64, &[n, cfg.d]);
        let c = rnd(96, cfg.d);
        let f32_out = native::block_forward(&h, &c, &cfg, &bank.packed.blocks[0], &mut arena);
        let q_out = native::block_forward(&h, &c, &cfg, &qbank.packed.blocks[0], &mut arena);
        let md = f32_out.max_abs_diff(&q_out);
        assert!(md > 0.0, "n={n}: int8 block is bit-identical — quantization never engaged");
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (a, b) in f32_out.data().iter().zip(q_out.data()) {
            num += f64::from(a - b).powi(2);
            den += f64::from(*a).powi(2);
        }
        let rel = (num / den.max(1e-30)).sqrt();
        assert!(rel < 0.05, "n={n}: int8 block rel L2 {rel} beyond the 5% tier");
    }
}

#[test]
fn block_kernel_is_deterministic_across_arena_reuse() {
    // The same input through a dirty arena (after unrelated shapes) must
    // be bit-identical — stale scratch never leaks into results. This is
    // what makes the serving parity guarantees (workers=1 vs 4, batched
    // vs single) survive the arena rework.
    let cfg = ModelConfig::of(Variant::S);
    let bank = WeightBank::generate(cfg, 3);
    let h = rnd_t(70, &[64, cfg.d]);
    let c = rnd(71, cfg.d);
    let mut a1 = ScratchArena::new();
    let clean = native::block_forward(&h, &c, &cfg, &bank.packed.blocks[0], &mut a1);
    let mut a2 = ScratchArena::new();
    for &n in &[256usize, 1, 33] {
        let hx = rnd_t(72 + n as u64, &[n, cfg.d]);
        let _ = native::block_forward(&hx, &c, &cfg, &bank.packed.blocks[1], &mut a2);
    }
    let dirty = native::block_forward(&h, &c, &cfg, &bank.packed.blocks[0], &mut a2);
    assert_eq!(clean.data(), dirty.data(), "arena reuse changed the result");
}
