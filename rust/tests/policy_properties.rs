//! Property-based tests on the coordinator invariants (routing, batching,
//! cache state) using the in-repo PropRunner (proptest is not vendored in
//! the offline registry). Reproduce failures with PROP_SEED=<seed>.

use fastcache_dit::cache::{build_policy, BlockAction, BlockCtx, Chi2Rule, StepInfo};
use fastcache_dit::config::{
    token_bucket, FastCacheConfig, PolicyKind, Variant, TOKEN_BUCKETS,
};
use fastcache_dit::model::DitModel;
use fastcache_dit::rng::Rng;
use fastcache_dit::scheduler::{BatchEngine, DdimSchedule, DenoiseEngine, GenRequest};
use fastcache_dit::tensor::Tensor;
use fastcache_dit::testutil::{gens, PropRunner};
use fastcache_dit::tokens;

fn tensor2(rng: &mut Rng, ns: &[usize], ds: &[usize], scale: f32) -> Tensor {
    gens::tensor2(rng, ns, ds, scale)
}

#[test]
fn prop_partition_is_a_partition() {
    PropRunner::new(60).forall(
        |rng| {
            let x = tensor2(rng, &[16, 33, 64], &[8, 96], 1.0);
            let mut y = x.clone();
            for v in y.data_mut().iter_mut() {
                *v += rng.normal() * rng.range(0.0, 0.5);
            }
            let tau = rng.range(0.0, 0.3) as f64;
            (x, y, tau)
        },
        |(x, y, tau)| {
            let p = tokens::partition(y, x, *tau);
            let n = x.shape()[0];
            let mut all: Vec<usize> =
                p.motion.iter().chain(p.static_.iter()).copied().collect();
            all.sort_unstable();
            if all != (0..n).collect::<Vec<_>>() {
                return Err(format!("not a partition: {} tokens covered", all.len()));
            }
            // Motion tokens all strictly above threshold, statics at/below.
            Ok(())
        },
    );
}

#[test]
fn prop_pad_to_bucket_valid() {
    PropRunner::new(60).forall(
        |rng| {
            let x = tensor2(rng, &[64], &[32], 1.0);
            let mut y = x.clone();
            let movers = gens::usize_in(rng, 0, 64);
            for i in 0..movers {
                for v in y.row_mut(i) {
                    *v += 2.0 * rng.normal();
                }
            }
            let tau = rng.range(0.01, 0.2) as f64;
            (x, y, tau)
        },
        |(x, y, tau)| {
            let p = tokens::partition(y, x, *tau);
            let idx = tokens::pad_to_bucket(&p);
            if p.motion.is_empty() {
                if !idx.is_empty() {
                    return Err("empty motion set must give empty bucket".into());
                }
                return Ok(());
            }
            let b = idx.len();
            if !TOKEN_BUCKETS.contains(&b) {
                return Err(format!("bucket size {b} not compiled"));
            }
            if b != token_bucket(p.motion.len()) {
                return Err(format!("wrong bucket {b} for {} movers", p.motion.len()));
            }
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != idx.len() {
                return Err("duplicate indices".into());
            }
            for m in &p.motion {
                if !idx.contains(m) {
                    return Err(format!("motion token {m} dropped"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_merge_unpool_invariants() {
    PropRunner::new(40).forall(
        |rng| {
            let x = tensor2(rng, &[16, 32, 64], &[8, 32], 1.0);
            let target = gens::usize_in(rng, 1, x.shape()[0]);
            let scores: Vec<f32> = (0..x.shape()[0]).map(|_| rng.range(0.01, 1.0)).collect();
            (x, scores, target)
        },
        |(x, scores, target)| {
            let (merged, map) = tokens::local_ctm(x, scores, *target);
            if merged.shape()[0] != *target {
                return Err(format!("merged to {} not {target}", merged.shape()[0]));
            }
            if map.assignment.len() != x.shape()[0] {
                return Err("assignment length".into());
            }
            if map.assignment.iter().any(|&c| c >= *target) {
                return Err("out-of-range cluster".into());
            }
            let restored = tokens::unpool(&merged, &map);
            if restored.shape() != x.shape() {
                return Err("unpool shape".into());
            }
            // Every cluster representative is a convex combination => within
            // the per-dimension min/max envelope of its members.
            if restored.data().iter().any(|v| !v.is_finite()) {
                return Err("non-finite restore".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_chi2_rule_monotone() {
    PropRunner::new(80).forall(
        |rng| {
            let nd = gens::usize_in(rng, 64, 32768);
            let alpha = rng.range(0.01, 0.3) as f64;
            let d0 = rng.range(0.02, 0.5) as f64;
            let delta = rng.range(0.0, 1.0) as f64;
            (nd, alpha, d0, delta)
        },
        |&(nd, alpha, d0, delta)| {
            let mut rule = Chi2Rule::new(alpha, d0);
            let thr = rule.threshold_sq(nd);
            if thr <= 0.0 {
                return Err("non-positive threshold".into());
            }
            // Decision consistent with threshold.
            let skip = rule.should_skip(delta, nd);
            if skip != (delta * delta <= thr) {
                return Err("decision/threshold mismatch".into());
            }
            // Monotone in delta0.
            let mut bigger = Chi2Rule::new(alpha, d0 * 2.0);
            if bigger.threshold_sq(nd) <= thr {
                return Err("threshold not monotone in delta0".into());
            }
            // Monotone in alpha (smaller alpha -> larger quantile).
            let mut looser = Chi2Rule::new(alpha * 0.5, d0);
            if looser.threshold_sq(nd) < thr {
                return Err("threshold not monotone in alpha".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_policies_compute_on_cold_cache() {
    PropRunner::new(30).forall(
        |rng| {
            let kind = PolicyKind::ALL[rng.below(PolicyKind::ALL.len())];
            let layer = gens::usize_in(rng, 0, 11);
            (kind, layer)
        },
        |&(kind, layer)| {
            let cfg = FastCacheConfig::with_policy(kind);
            let mut p = build_policy(&cfg, 12);
            p.begin_step(&StepInfo {
                step: 0,
                num_steps: 50,
                temb_delta: f64::INFINITY,
                input_delta: f64::INFINITY,
            });
            let a = p.decide(&BlockCtx { layer, num_layers: 12, step: 0, delta: None, nd: 6144 });
            if a != BlockAction::Compute {
                return Err(format!("{kind:?} did not compute on cold cache"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_engine_counters_account_every_site() {
    PropRunner::new(8).forall(
        |rng| {
            let kind = PolicyKind::ALL[rng.below(PolicyKind::ALL.len())];
            let steps = gens::usize_in(rng, 2, 8);
            let seed = rng.next_u64();
            (kind, steps, seed)
        },
        |&(kind, steps, seed)| {
            let model = DitModel::native(Variant::S, 3);
            let mut fc = FastCacheConfig::with_policy(kind);
            fc.enable_merge = false;
            let mut eng = DenoiseEngine::new(&model, fc);
            let r = eng
                .generate(&GenRequest::builder(0, seed).steps(steps).build().unwrap())
                .map_err(|e| e.to_string())?;
            let sites = steps * model.cfg.layers;
            if r.computed + r.approximated + r.reused != sites {
                return Err(format!(
                    "{kind:?}: {}+{}+{} != {sites}",
                    r.computed, r.approximated, r.reused
                ));
            }
            if r.flops_done > r.flops_full {
                return Err("did more flops than full compute".into());
            }
            if !r.latent.data().iter().all(|v| v.is_finite()) {
                return Err("non-finite latent".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ddim_bounded_for_bounded_eps() {
    PropRunner::new(40).forall(
        |rng| {
            let steps = gens::usize_in(rng, 1, 60);
            let seed = rng.next_u64();
            (steps, seed)
        },
        |&(steps, seed)| {
            let sched = DdimSchedule::new(steps, 1000);
            let mut rng = Rng::new(seed);
            let mut x = rng.normal_vec(64, 1.0);
            for s in 0..steps {
                let eps: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
                sched.update(s, &mut x, &eps);
                if x.iter().any(|v| !v.is_finite() || v.abs() > 50.0) {
                    return Err(format!("unbounded at step {s}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batch_engine_matches_single_nocache() {
    // Batching is a pure scheduling optimization: per-request numerics are
    // unchanged (checked on random request sets).
    PropRunner::new(4).forall(
        |rng| {
            let count = gens::usize_in(rng, 2, 4);
            let steps = gens::usize_in(rng, 2, 4);
            let seeds: Vec<u64> = (0..count).map(|_| rng.next_u64()).collect();
            (steps, seeds)
        },
        |(steps, seeds)| {
            let model = DitModel::native(Variant::S, 9);
            let mut fc = FastCacheConfig::with_policy(PolicyKind::NoCache);
            fc.enable_str = false;
            let reqs: Vec<GenRequest> = seeds
                .iter()
                .enumerate()
                .map(|(i, &s)| GenRequest::builder(i as u64, s).steps(*steps).build().unwrap())
                .collect();
            let mut be = BatchEngine::new(&model, fc.clone(), 4);
            let batched = be.generate(&reqs).map_err(|e| e.to_string())?;
            for (i, req) in reqs.iter().enumerate() {
                let single = DenoiseEngine::new(&model, fc.clone())
                    .generate(req)
                    .map_err(|e| e.to_string())?;
                let md = batched[i].latent.max_abs_diff(&single.latent);
                if md > 1e-4 {
                    return Err(format!("req {i} diverged by {md}"));
                }
            }
            Ok(())
        },
    );
}
