//! The cross-layer integration signal: execute the AOT HLO artifacts
//! (python/jax/pallas → HLO text → PJRT) and the native Rust math on
//! IDENTICAL weights, and assert the numerics agree. If these pass, the
//! three layers implement the same model.
//!
//! Requires `make artifacts` to have run; every test skips gracefully when
//! artifacts are absent so `cargo test` stays green in a fresh checkout.

use std::path::Path;
use std::sync::Arc;

use fastcache_dit::config::{FastCacheConfig, PolicyKind, Variant, C_IN};
use fastcache_dit::model::{DitModel, ExecMode};
use fastcache_dit::rng::Rng;
use fastcache_dit::runtime::{ArtifactStore, Client};
use fastcache_dit::scheduler::{DenoiseEngine, GenRequest};
use fastcache_dit::tensor::Tensor;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

fn hlo_model(variant: Variant, seed: u64) -> Option<DitModel> {
    let dir = artifacts_dir()?;
    let client = Arc::new(Client::cpu().expect("PJRT CPU client"));
    let store = Arc::new(ArtifactStore::open(dir).expect("manifest"));
    Some(DitModel::load(client, store, variant, seed).expect("model load"))
}

fn rnd(seed: u64, shape: &[usize], scale: f32) -> Tensor {
    let mut r = Rng::new(seed);
    Tensor::new(r.normal_vec(shape.iter().product(), scale), shape)
}

fn assert_close(a: &Tensor, b: &Tensor, tol: f32, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape mismatch");
    let md = a.max_abs_diff(b);
    assert!(md < tol, "{what}: max abs diff {md} > {tol}");
}

#[test]
fn hlo_temb_matches_native() {
    let Some(hlo) = hlo_model(Variant::S, 11) else { return };
    let nat = DitModel::native(Variant::S, 11);
    for t in [0.0f32, 17.5, 500.0, 999.0] {
        let a = hlo.temb(&[t]).unwrap();
        let b = nat.temb(&[t]).unwrap();
        assert_close(&a, &b, 1e-3, &format!("temb(t={t})"));
    }
}

#[test]
fn hlo_embed_matches_native() {
    let Some(hlo) = hlo_model(Variant::S, 11) else { return };
    let nat = DitModel::native(Variant::S, 11);
    let x = rnd(1, &[1, 64, C_IN], 1.0);
    let a = hlo.embed(&x).unwrap();
    let b = nat.embed(&x).unwrap();
    assert_close(&a, &b, 1e-3, "embed");
}

#[test]
fn hlo_block_matches_native_all_buckets() {
    let Some(hlo) = hlo_model(Variant::S, 11) else { return };
    let nat = DitModel::native(Variant::S, 11);
    let c = rnd(2, &[1, 96], 1.0);
    for n in [16usize, 32, 64] {
        let h = rnd(3 + n as u64, &[1, n, 96], 1.0);
        for layer in 0..nat.cfg.layers {
            let a = hlo.block(layer, &h, &c).unwrap();
            let b = nat.block(layer, &h, &c).unwrap();
            assert_close(&a, &b, 5e-3, &format!("block l={layer} n={n}"));
        }
    }
}

#[test]
fn hlo_block_batched_matches_native() {
    let Some(hlo) = hlo_model(Variant::S, 11) else { return };
    let nat = DitModel::native(Variant::S, 11);
    let h = rnd(5, &[4, 64, 96], 1.0);
    let c = rnd(6, &[4, 96], 1.0);
    let a = hlo.block(0, &h, &c).unwrap();
    let b = nat.block(0, &h, &c).unwrap();
    assert_close(&a, &b, 5e-3, "block b=4");
}

#[test]
fn hlo_final_matches_native() {
    let Some(hlo) = hlo_model(Variant::S, 11) else { return };
    let nat = DitModel::native(Variant::S, 11);
    let h = rnd(7, &[1, 64, 96], 1.0);
    let c = rnd(8, &[1, 96], 1.0);
    let a = hlo.final_layer(&h, &c).unwrap();
    let b = nat.final_layer(&h, &c).unwrap();
    assert_close(&a, &b, 1e-3, "final");
}

#[test]
fn hlo_linear_approx_matches_native() {
    // This is the Pallas tiled-matmul kernel executing through PJRT.
    let Some(hlo) = hlo_model(Variant::S, 11) else { return };
    let nat = DitModel::native(Variant::S, 11);
    let h = rnd(9, &[1, 64, 96], 1.0);
    let w = rnd(10, &[96, 96], 0.1);
    let b = rnd(11, &[96], 1.0);
    let a = hlo.linear_approx_full(&h, &w, &b).unwrap();
    let nb = nat.linear_approx_full(&h, &w, &b).unwrap();
    assert_close(&a, &nb, 1e-3, "linear_approx (pallas)");
}

#[test]
fn hlo_generation_close_to_native_generation() {
    // Full end-to-end: same request through the HLO path and the native
    // path must land on (nearly) the same latent.
    let Some(hlo) = hlo_model(Variant::S, 23) else { return };
    assert_eq!(hlo.mode, ExecMode::Hlo);
    let nat = DitModel::native(Variant::S, 23);
    let fc = FastCacheConfig::with_policy(PolicyKind::NoCache);
    let req = GenRequest::builder(1, 42).steps(8).build().unwrap();
    let a = DenoiseEngine::new(&hlo, fc.clone()).generate(&req).unwrap();
    let b = DenoiseEngine::new(&nat, fc).generate(&req).unwrap();
    let md = a.latent.max_abs_diff(&b.latent);
    assert!(md < 0.05, "end-to-end latent diff {md}");
}

#[test]
fn hlo_fastcache_generation_finite_and_skipping() {
    let Some(hlo) = hlo_model(Variant::S, 29) else { return };
    let fc = FastCacheConfig::default();
    let r = DenoiseEngine::new(&hlo, fc)
        .generate(&GenRequest::builder(2, 77).steps(12).build().unwrap())
        .unwrap();
    assert!(r.latent.data().iter().all(|v| v.is_finite()));
    assert!(r.approximated > 0, "fastcache never approximated on HLO path");
    let meter = hlo.meter().unwrap();
    assert!(meter.peak_bytes() > 0);
}
