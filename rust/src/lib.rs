//! # FastCache-DiT
//!
//! A diffusion-transformer *serving* framework reproducing
//! **FastCache: Fast Caching for Diffusion Transformer Through Learnable
//! Linear Approximation** (Liu et al., 2025) in the three-layer
//! Rust + JAX + Pallas architecture:
//!
//! - **L3 (this crate)** — request router, dynamic batcher, denoise
//!   scheduler, and the paper's χ²-gated hidden-state cache with learnable
//!   linear approximation, plus every baseline policy the paper compares
//!   against (FBCache, TeaCache, AdaCache, Learning-to-Cache, PAB-static).
//! - **L2 (python/compile/model.py)** — the DiT block/temb/final forward in
//!   JAX, AOT-lowered to HLO text artifacts.
//! - **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots (attention, linear approximation, saliency, kNN density).
//!
//! Python never runs at serving time: the `xla` crate loads the HLO
//! artifacts into a PJRT CPU client and this crate owns every loop.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

// The `simd` cargo feature selects the explicit f32x8 microkernel path
// (rust/src/model/kernels.rs); the manifest is supplied by the build
// harness, so rustc's check-cfg may not list the feature — allow the
// cfg probe instead of hard-coding a feature list here.
#![allow(unexpected_cfgs)]

pub mod api;
pub mod cache;
pub mod config;
pub mod experiments;
pub mod faults;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod stats;
pub mod store;
pub mod tensor;
pub mod testutil;
pub mod tokens;
pub mod workload;

pub use config::{FastCacheConfig, ModelConfig, PolicyKind, ServerConfig, Variant};
pub use tensor::Tensor;

/// Crate version (matches Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
