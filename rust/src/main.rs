//! `fastcache-serve` — the L3 leader binary.
//!
//! Subcommands:
//!   info                         — platform + artifact + model summary
//!   generate [opts]              — run N requests through one engine
//!   serve [opts]                 — start the batching server, replay a
//!                                  synthetic workload, report latency /
//!                                  throughput / quality
//!   serve --listen HOST:PORT     — same server behind the framed-socket
//!                                  front door (port 0 picks an ephemeral
//!                                  port; "drain" or EOF on stdin drains)
//!   client --connect HOST:PORT   — built-in remote client driving the
//!                                  same workload over the wire
//!   stats --connect HOST:PORT    — scrape a running front door's live
//!                                  telemetry registry (one Stats frame)
//!   health --connect HOST:PORT   — probe a running front door's per-shard
//!                                  liveness (one Health frame; answered
//!                                  even while the server drains)
//!
//! Common options: --model s|b|l|xl  --policy fastcache|fbcache|...
//!   --steps N --requests N --alpha A --tau-s T --gamma G --max-batch B
//!   --workers W --threads T --int8 --queue-depth Q --artifacts DIR
//!   --seed S --motion calm|mixed|stormy --native
//!
//! --threads T runs each shard's kernels on T intra-op worker threads
//! (token-dimension split, bit-identical results; workers × threads is
//! clamped to the host's cores). --int8 serves the four big block
//! matmuls from int8 panels (opt-in; quality delta tracked by
//! `bench_tables kernels`).
//!
//! Serve-only: --deadline-every K --deadline-ms D tag every K-th request
//! with an SLA deadline of D ms; the sharded server admits tagged jobs
//! ahead of best-effort ones, sheds jobs whose deadline expired while
//! queued, and reports the deadline-hit rate.
//!
//! Warm start: --warm-start enables the cross-request store (lanes adopt
//! converged affine fits / calibration profiles from previously served
//! traffic and publish theirs back), --warm-budget-mib N bounds it, and
//! --fit-min-updates K gates Approx on fit convergence.
//!
//! Observability (docs/OBSERVABILITY.md): --stats-every S prints a live
//! registry scrape to stderr every S seconds; --trace-sample-rate R
//! turns on the flight recorder for fraction R of lanes, and
//! --trace-out PATH dumps the recorded events at drain (.json = Chrome
//! trace_event for chrome://tracing / Perfetto, otherwise NDJSON).
//!
//! Robustness (docs/ROBUSTNESS.md): --degrade walks deadline-doomed
//! lanes down the degrade ladder instead of shedding them
//! (--degrade-rungs 1..=3 bounds the descent); --warm-snapshot PATH
//! restores the warm store before serving and saves it at drain;
//! --fault-plan "SPEC; SPEC" arms the deterministic chaos harness
//! (kernel panics, queue-pop delays, socket resets, snapshot
//! corruption, seeded step stalls); client-side --retries N retries
//! Busy rejections and connect failures with deterministic backoff.
//!
//! Self-healing (docs/ROBUSTNESS.md): --shard-restart-after N restarts a
//! shard that quarantines N batches inside the flap window (survivors
//! replayed bit-exactly); --poison-after K blocklists a request id after
//! K typed quarantines (rejected with error code Poisoned at both
//! doors); --step-stall-ms D arms the stuck-step watchdog (a shard whose
//! step heartbeat stalls > D ms has its queue shed honestly and is
//! restarted); --warm-snapshot-every S saves the warm store atomically
//! every S seconds in addition to the snapshot at drain.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use fastcache_dit::cache::state::CacheCounters;
use fastcache_dit::config::{Args, FastCacheConfig, PolicyKind, ServerConfig, Variant};
use fastcache_dit::metrics::{clip_display, clip_proxy, FidAccumulator};
use fastcache_dit::model::DitModel;
use fastcache_dit::runtime::{ArtifactStore, Client};
use fastcache_dit::scheduler::DenoiseEngine;
use fastcache_dit::server::Server;
use fastcache_dit::workload::{MotionProfile, WorkloadGen};

fn parse_common(args: &Args) -> Result<(Variant, FastCacheConfig, ServerConfig)> {
    // Config file first (if any), CLI options override.
    let mut file_fc = FastCacheConfig::default();
    let mut file_scfg = ServerConfig::default();
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading --config {path}"))?;
        let doc = fastcache_dit::config::toml::TomlDoc::parse(&text)
            .map_err(anyhow::Error::msg)?;
        fastcache_dit::config::toml::apply(&doc, &mut file_fc, &mut file_scfg)
            .map_err(anyhow::Error::msg)?;
    }

    let variant = Variant::parse(args.get_or("model", file_scfg.variant.key()))
        .context("bad --model (want s|b|l|xl)")?;
    let policy = PolicyKind::parse(args.get_or("policy", file_fc.policy.name()))
        .context("bad --policy")?;
    let mut fc = FastCacheConfig { policy, ..file_fc };
    fc.alpha = args.parse_num("alpha", fc.alpha).map_err(anyhow::Error::msg)?;
    fc.tau_s = args.parse_num("tau-s", fc.tau_s).map_err(anyhow::Error::msg)?;
    fc.gamma = args.parse_num("gamma", fc.gamma).map_err(anyhow::Error::msg)?;
    fc.knn_k = args.parse_num("knn-k", fc.knn_k).map_err(anyhow::Error::msg)?;
    if args.flag("no-str") {
        fc.enable_str = false;
    }
    if args.flag("no-sc") {
        fc.enable_sc = false;
    }
    if args.flag("no-mb") {
        fc.enable_mb = false;
    }
    if args.flag("merge") {
        fc.enable_merge = true;
    }
    if args.flag("warm-start") {
        fc.warm_start = true;
    }
    fc.fit_min_updates =
        args.parse_num("fit-min-updates", fc.fit_min_updates).map_err(anyhow::Error::msg)?;
    fc.validate().map_err(anyhow::Error::msg)?;

    let mut scfg = file_scfg;
    scfg.variant = variant;
    scfg.steps = args.parse_num("steps", scfg.steps).map_err(anyhow::Error::msg)?;
    scfg.guidance = args.parse_num("guidance", scfg.guidance).map_err(anyhow::Error::msg)?;
    scfg.max_batch = args.parse_num("max-batch", scfg.max_batch).map_err(anyhow::Error::msg)?;
    scfg.queue_depth =
        args.parse_num("queue-depth", scfg.queue_depth).map_err(anyhow::Error::msg)?;
    scfg.workers = args.parse_num("workers", scfg.workers).map_err(anyhow::Error::msg)?;
    scfg.threads = args.parse_num("threads", scfg.threads).map_err(anyhow::Error::msg)?;
    if args.flag("int8") {
        scfg.int8 = true;
    }
    scfg.artifacts_dir = args.get_or("artifacts", "artifacts").to_string();
    scfg.weight_seed = args.parse_num("seed", scfg.weight_seed).map_err(anyhow::Error::msg)?;
    let warm_mib: usize = args
        .parse_num("warm-budget-mib", scfg.warm_budget_bytes >> 20)
        .map_err(anyhow::Error::msg)?;
    scfg.warm_budget_bytes = warm_mib << 20;
    if let Some(addr) = args.get("listen") {
        scfg.listen = Some(addr.to_string());
    }
    scfg.net_max_conns =
        args.parse_num("net-max-conns", scfg.net_max_conns).map_err(anyhow::Error::msg)?;
    scfg.trace_sample_rate =
        args.parse_num("trace-sample-rate", scfg.trace_sample_rate).map_err(anyhow::Error::msg)?;
    if let Some(path) = args.get("trace-out") {
        scfg.trace_out = Some(path.to_string());
    }
    scfg.stats_every =
        args.parse_num("stats-every", scfg.stats_every).map_err(anyhow::Error::msg)?;
    if let Some(plan) = args.get("fault-plan") {
        scfg.fault_plan = Some(plan.to_string());
    }
    if args.flag("degrade") {
        scfg.degrade = true;
    }
    scfg.degrade_rungs =
        args.parse_num("degrade-rungs", scfg.degrade_rungs).map_err(anyhow::Error::msg)?;
    if let Some(path) = args.get("warm-snapshot") {
        scfg.warm_snapshot = Some(path.to_string());
    }
    scfg.warm_snapshot_every = args
        .parse_num("warm-snapshot-every", scfg.warm_snapshot_every)
        .map_err(anyhow::Error::msg)?;
    scfg.shard_restart_after = args
        .parse_num("shard-restart-after", scfg.shard_restart_after)
        .map_err(anyhow::Error::msg)?;
    scfg.poison_after =
        args.parse_num("poison-after", scfg.poison_after).map_err(anyhow::Error::msg)?;
    scfg.step_stall_ms =
        args.parse_num("step-stall-ms", scfg.step_stall_ms).map_err(anyhow::Error::msg)?;
    scfg.validate().map_err(anyhow::Error::msg)?;
    Ok((variant, fc, scfg))
}

fn load_model(scfg: &ServerConfig, native: bool) -> Result<DitModel> {
    if native {
        return Ok(DitModel::native(scfg.variant, scfg.weight_seed));
    }
    let client = Arc::new(Client::cpu()?);
    let store = Arc::new(ArtifactStore::open(std::path::Path::new(&scfg.artifacts_dir))?);
    DitModel::load(client, store, scfg.variant, scfg.weight_seed)
}

fn motion_profile(name: &str) -> Result<MotionProfile> {
    Ok(match name {
        "calm" => MotionProfile::CALM,
        "mixed" => MotionProfile::MIXED,
        "stormy" => MotionProfile::STORMY,
        other => bail!("bad --motion {other} (want calm|mixed|stormy)"),
    })
}

fn cmd_info(args: &Args) -> Result<()> {
    let (_, _, scfg) = parse_common(args)?;
    println!("fastcache-dit v{}", fastcache_dit::version());
    match Client::cpu() {
        Ok(c) => println!("PJRT platform: {}", c.platform()),
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
    match ArtifactStore::open(std::path::Path::new(&scfg.artifacts_dir)) {
        Ok(store) => {
            let mut names: Vec<&str> = store.names().collect();
            names.sort();
            println!("artifacts ({}): {}", names.len(), scfg.artifacts_dir);
            println!("variants: {:?}", store.variants());
        }
        Err(e) => println!("artifacts: {e:#}"),
    }
    for v in Variant::ALL {
        let cfg = fastcache_dit::config::ModelConfig::of(v);
        println!(
            "  {:<9} layers={:<3} d={:<4} heads={:<2} params={:.1}M",
            cfg.variant.paper_name(),
            cfg.layers,
            cfg.d,
            cfg.heads,
            cfg.param_count() as f64 / 1e6
        );
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let (variant, fc, scfg) = parse_common(args)?;
    let n_req: usize = args.parse_num("requests", 4).map_err(anyhow::Error::msg)?;
    let profile = motion_profile(args.get_or("motion", "mixed"))?;
    let model = load_model(&scfg, args.flag("native"))?;
    println!(
        "model {} ({} layers, d={}), policy {}, {} steps, {} requests",
        variant.paper_name(),
        model.cfg.layers,
        model.cfg.d,
        fc.policy,
        scfg.steps,
        n_req
    );

    let mut wl = WorkloadGen::new(scfg.weight_seed ^ 0x77);
    let reqs = wl.image_set(n_req, scfg.steps, profile);
    let mut eng = DenoiseEngine::new(&model, fc);
    let mut counters = CacheCounters::default();
    let mut fid = FidAccumulator::new();
    let mut total_ms = 0.0;
    for req in &reqs {
        let r = eng.generate(req)?;
        counters.computed += r.computed;
        counters.approximated += r.approximated;
        counters.reused += r.reused;
        total_ms += r.wall_ms;
        fid.push_latent(&r.latent);
        let clip = clip_display(clip_proxy(&model, &r.latent, &r.cond));
        println!(
            "  req {:>3}: {:>8.1} ms  skip={:>5.1}%  static={:>5.1}%  flops={:>5.1}%  clip={:.1}",
            r.id,
            r.wall_ms,
            r.skip_ratio() * 100.0,
            r.static_ratio() * 100.0,
            r.flops_ratio() * 100.0,
            clip
        );
    }
    println!(
        "total {:.1} ms | sites computed {} approximated {} reused {} (skip {:.1}%)",
        total_ms,
        counters.computed,
        counters.approximated,
        counters.reused,
        counters.skip_ratio() * 100.0
    );
    if let Some(meter) = model.meter() {
        println!(
            "device memory: live {:.1} MiB, peak {:.1} MiB",
            meter.live_bytes() as f64 / (1 << 20) as f64,
            meter.peak_bytes() as f64 / (1 << 20) as f64
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (variant, fc, scfg) = parse_common(args)?;
    let n_req: usize = args.parse_num("requests", 16).map_err(anyhow::Error::msg)?;
    let profile = motion_profile(args.get_or("motion", "mixed"))?;
    let deadline_every: usize =
        args.parse_num("deadline-every", 0).map_err(anyhow::Error::msg)?;
    let deadline_ms: f64 =
        args.parse_num("deadline-ms", 60_000.0).map_err(anyhow::Error::msg)?;
    let native = args.flag("native");
    println!(
        "serving {} with policy {} (workers={}, threads={}/shard, max_batch={}/shard, queue_depth={}, steps={}{})",
        variant.paper_name(),
        fc.policy,
        scfg.workers,
        scfg.threads,
        scfg.max_batch,
        scfg.queue_depth,
        scfg.steps,
        if scfg.int8 { ", int8" } else { "" }
    );

    let scfg2 = scfg.clone();
    let server = Server::start(scfg.clone(), fc, move || load_model(&scfg2, native));
    // Grab the observability handles before anything consumes the server:
    // both outlive it (Arc), so the drain path can still dump the trace
    // and the ticker keeps scraping while the front door owns the server.
    let registry = server.registry();
    let recorder = server.recorder();
    let ticker = spawn_stats_ticker(&registry, scfg.stats_every);

    // Network mode: instead of replaying a synthetic workload in-process,
    // open the front door and serve remote clients until stdin closes (or
    // a "drain" line arrives), then drain gracefully.
    if let Some(addr) = &scfg.listen {
        let net = fastcache_dit::net::NetServer::start(server, addr.as_str(), scfg.net_max_conns)
            .with_context(|| format!("binding --listen {addr}"))?;
        println!("listening on {}", net.local_addr());
        use std::io::BufRead;
        let stdin = std::io::stdin();
        for line in stdin.lock().lines() {
            let line = line.unwrap_or_default();
            let line = line.trim();
            if line.is_empty() || line == "drain" || line == "quit" {
                break;
            }
        }
        println!("draining...");
        let report = net.shutdown();
        stop_stats_ticker(ticker);
        print_report(&report);
        dump_trace(recorder.as_deref(), scfg.trace_out.as_deref())?;
        return Ok(());
    }

    let mut wl = WorkloadGen::new(scfg.weight_seed ^ 0x5EED);
    let reqs = wl.image_set(n_req, scfg.steps, profile);
    let mut pending = Vec::new();
    for (i, req) in reqs.into_iter().enumerate() {
        let req = if deadline_every > 0 && i % deadline_every == 0 {
            req.into_builder().deadline_ms(deadline_ms).build().unwrap()
        } else {
            req
        };
        match server.submit_blocking(&req) {
            Ok(rx) => pending.push(rx),
            Err(e) => bail!("submit failed: {e}"),
        }
    }
    for rx in pending {
        print_outcome(&rx.wait());
    }
    let report = server.shutdown();
    stop_stats_ticker(ticker);
    print_report(&report);
    dump_trace(recorder.as_deref(), scfg.trace_out.as_deref())?;
    Ok(())
}

/// Periodic registry scrape to stderr (stdout carries the serve report).
/// Returns `None` when the ticker is disabled (`stats_every == 0`).
type StatsTicker = (std::sync::mpsc::Sender<()>, std::thread::JoinHandle<()>);

fn spawn_stats_ticker(
    registry: &Arc<fastcache_dit::obs::Registry>,
    every_s: f64,
) -> Option<StatsTicker> {
    if every_s <= 0.0 {
        return None;
    }
    let reg = Arc::clone(registry);
    let every = std::time::Duration::from_secs_f64(every_s);
    let (stop_tx, stop_rx) = std::sync::mpsc::channel::<()>();
    let handle = std::thread::Builder::new()
        .name("fastcache-stats".into())
        .spawn(move || {
            // recv_timeout doubles as the tick clock: a disconnect (the
            // sender dropped at drain) ends the loop immediately instead
            // of sleeping out the last period.
            while stop_rx.recv_timeout(every)
                == Err(std::sync::mpsc::RecvTimeoutError::Timeout)
            {
                eprint!("--- stats ---\n{}", reg.render_text());
            }
        })
        .expect("spawning stats ticker");
    Some((stop_tx, handle))
}

fn stop_stats_ticker(ticker: Option<StatsTicker>) {
    if let Some((stop_tx, handle)) = ticker {
        drop(stop_tx);
        let _ = handle.join();
    }
}

/// Dump the flight recorder's ring at drain: `.json` selects Chrome
/// `trace_event` format, anything else NDJSON. No-op unless both a
/// recorder and an output path exist.
fn dump_trace(
    recorder: Option<&fastcache_dit::obs::FlightRecorder>,
    path: Option<&str>,
) -> Result<()> {
    let (Some(rec), Some(path)) = (recorder, path) else {
        return Ok(());
    };
    let body =
        if path.ends_with(".json") { rec.to_chrome_trace() } else { rec.to_ndjson() };
    std::fs::write(path, body).with_context(|| format!("writing --trace-out {path}"))?;
    println!("trace: {} events ({} dropped) -> {path}", rec.len(), rec.dropped());
    Ok(())
}

/// Print one terminal outcome in the per-request report format shared by
/// `serve` (in-process replay) and `client` (over the wire).
fn print_outcome(outcome: &fastcache_dit::api::Outcome) {
    use fastcache_dit::api::{ErrorCode, Outcome};
    match outcome {
        Outcome::Completed(resp) => {
            let sla = match resp.deadline_met {
                Some(true) => "  [SLA hit]",
                Some(false) => "  [SLA MISS]",
                None => "",
            };
            let warm = if resp.result.warm_layers > 0 { "  [warm]" } else { "" };
            let degraded = if resp.result.degraded {
                format!("  [degraded x{}]", resp.result.degrade_rungs)
            } else {
                String::new()
            };
            println!(
                "  req {:>3}: e2e {:>8.1} ms (queued {:>7.1} ms)  skip={:>5.1}%{sla}{warm}{degraded}",
                resp.result.id,
                resp.e2e_ms,
                resp.queued_ms,
                resp.result.skip_ratio() * 100.0
            );
        }
        Outcome::Rejected(rej) if rej.code == ErrorCode::Expired => {
            println!(
                "  req {:>3}: SHED after {:>7.1} ms queued (deadline {:.0} ms already passed)",
                rej.id, rej.waited_ms, rej.deadline_ms
            );
        }
        Outcome::Rejected(rej) => {
            println!("  req {:>3}: REJECTED ({}): {}", rej.id, rej.code, rej.detail);
        }
    }
}

fn print_report(report: &fastcache_dit::server::ServerReport) {
    let pcts = report.e2e.percentiles(&[50.0, 95.0]);
    println!(
        "served {} requests in {:.2}s — {:.2} req/s, occupancy {:.2}, intra-op threads {}, p50 {:.0} ms, p95 {:.0} ms",
        report.completed,
        report.wall_s,
        report.throughput_rps(),
        report.mean_batch_size(),
        report.threads,
        pcts[0],
        pcts[1]
    );
    if let Some(rate) = report.deadline_hit_rate() {
        println!(
            "SLA: {}/{} deadline-tagged jobs within budget ({:.1}%), {} best-effort, {} shed",
            report.deadline_hits,
            report.deadline_jobs,
            rate * 100.0,
            report.best_effort_jobs,
            report.deadline_sheds
        );
    } else if report.deadline_sheds > 0 {
        println!(
            "SLA: {} deadline-tagged jobs shed (expired while queued)",
            report.deadline_sheds
        );
    }
    if report.door_sheds > 0 {
        println!("SLA: {} deadline-tagged requests shed at the door", report.door_sheds);
    }
    if report.degraded_lanes > 0 {
        println!(
            "degrade: {} lanes walked the ladder ({} rungs total) instead of shedding",
            report.degraded_lanes, report.degrade_rungs
        );
    }
    if report.internal_errors > 0 {
        println!(
            "faults: {} requests answered Internal (quarantined by fault containment)",
            report.internal_errors
        );
    }
    if report.shard_restarts > 0 {
        println!(
            "supervisor: {} supervised shard restart(s) (flap control / watchdog escalation)",
            report.shard_restarts
        );
    }
    if report.watchdog_sheds > 0 {
        println!(
            "supervisor: {} queued jobs shed by the stuck-step watchdog",
            report.watchdog_sheds
        );
    }
    if report.blocklisted > 0 || report.poisoned_rejections > 0 {
        println!(
            "supervisor: {} request id(s) blocklisted as poisoned, {} resubmits rejected ({} counted as SLA misses)",
            report.blocklisted, report.poisoned_rejections, report.poisoned_sheds
        );
    }
    if let Some(n) = &report.net {
        println!(
            "net: {} conns accepted, {} door-shed conns, {} submits ({} completed, {} shed, \
             {} door-shed), {} B in / {} B out",
            n.conns_accepted,
            n.conns_door_shed,
            n.reqs_submitted,
            n.reqs_completed,
            n.reqs_shed,
            n.reqs_door_shed,
            n.bytes_in,
            n.bytes_out
        );
    }
    if let Some(s) = &report.store {
        println!(
            "warm store: {} warm admissions ({} layers) | {} hits / {} misses ({:.1}% hit) | \
             {} inserts, {} evictions | {:.1} KiB / {:.1} KiB budget",
            report.warm_admissions,
            report.warm_layers,
            s.hits,
            s.misses,
            s.hit_rate() * 100.0,
            s.inserts,
            s.evictions,
            s.used_bytes as f64 / 1024.0,
            s.budget_bytes as f64 / 1024.0
        );
    }
    if report.shards.len() > 1 {
        for s in &report.shards {
            println!(
                "  shard {}: {} completed, occupancy {:.2}, padded {:.3} GFLOP",
                s.shard,
                s.completed,
                if s.step_calls == 0 { 0.0 } else { s.lane_steps as f64 / s.step_calls as f64 },
                s.padded_flops as f64 / 1e9
            );
        }
    }
}

/// Built-in remote client: connects to a `serve --listen` front door and
/// drives the same workload shapes as in-process `serve`, over the wire.
///
/// Options: --connect HOST:PORT (required)  --requests N  --steps N
///   --seed S  --motion calm|mixed|stormy  --deadline-every K
///   --deadline-ms D  --progress (stream per-step progress frames)
///   --retries N (retry Busy rejections / connect failures with
///   deterministic backoff; default 0 = fail fast)
fn cmd_client(args: &Args) -> Result<()> {
    use fastcache_dit::api::{Event, GenClient};
    let (_, _, scfg) = parse_common(args)?;
    let addr = args
        .get("connect")
        .context("client needs --connect HOST:PORT")?;
    let n_req: usize = args.parse_num("requests", 4).map_err(anyhow::Error::msg)?;
    let profile = motion_profile(args.get_or("motion", "mixed"))?;
    let deadline_every: usize =
        args.parse_num("deadline-every", 0).map_err(anyhow::Error::msg)?;
    let deadline_ms: f64 =
        args.parse_num("deadline-ms", 60_000.0).map_err(anyhow::Error::msg)?;
    let progress = args.flag("progress");
    let retries: u32 = args.parse_num("retries", 0).map_err(anyhow::Error::msg)?;

    let client = fastcache_dit::net::NetClient::connect_with_retries(addr, retries)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    println!("connected to {addr}, submitting {n_req} requests");

    let mut wl = WorkloadGen::new(scfg.weight_seed ^ 0x5EED);
    let reqs = wl.image_set(n_req, scfg.steps, profile);
    let mut pending = Vec::new();
    for (i, req) in reqs.into_iter().enumerate() {
        let req = if deadline_every > 0 && i % deadline_every == 0 {
            req.into_builder().deadline_ms(deadline_ms).build().unwrap()
        } else {
            req
        };
        let stream = if progress {
            client.submit_streaming(&req)
        } else {
            client.submit(&req)
        };
        match stream {
            Ok(rx) => pending.push(rx),
            Err(e) => println!("  req {:>3}: REJECTED ({}): {}", e.id, e.code, e.detail),
        }
    }
    let mut completed = 0usize;
    for rx in pending {
        let mut ticks = 0u32;
        let outcome = loop {
            match rx.recv_event() {
                Some(Event::Progress(_)) => ticks += 1,
                Some(Event::Done(outcome)) => break outcome,
                None => {
                    break fastcache_dit::api::Outcome::Rejected(
                        fastcache_dit::api::Reject::closed(rx.id(), "stream dropped"),
                    )
                }
            }
        };
        if progress && ticks > 0 {
            println!("  req {:>3}: {} progress frames", rx.id(), ticks);
        }
        if outcome.as_completed().is_some() {
            completed += 1;
        }
        print_outcome(&outcome);
    }
    client.close();
    println!("client done: {completed}/{n_req} completed");
    Ok(())
}

/// One-shot telemetry scrape of a running `serve --listen` front door:
/// sends a single `Stats` frame, prints the returned series as
/// `name kind value` lines, and disconnects.
///
/// Options: --connect HOST:PORT (required)
fn cmd_stats(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .context("stats needs --connect HOST:PORT")?;
    let client = fastcache_dit::net::NetClient::connect(addr)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let series = client
        .stats()
        .map_err(|e| anyhow::anyhow!("stats scrape failed: {e}"))?;
    print!("{}", fastcache_dit::obs::render_series(&series));
    client.close();
    Ok(())
}

/// One-shot liveness probe of a running `serve --listen` front door:
/// sends a single `Health` frame, prints the per-shard states plus the
/// restart / blocklist / drain counters, and disconnects. Exits 0 iff
/// every shard reports Healthy and the server is not draining — usable
/// directly as a readiness check.
///
/// Options: --connect HOST:PORT (required)
fn cmd_health(args: &Args) -> Result<()> {
    use fastcache_dit::server::HealthState;
    let addr = args
        .get("connect")
        .context("health needs --connect HOST:PORT")?;
    let client = fastcache_dit::net::NetClient::connect(addr)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let body = client
        .health()
        .map_err(|e| anyhow::anyhow!("health probe failed: {e}"))?;
    println!(
        "server: {} | restarts {} | blocklisted {}",
        if body.draining { "draining" } else { "serving" },
        body.restarts,
        body.blocklisted
    );
    let mut all_healthy = true;
    for &(shard, code) in &body.shards {
        let state = HealthState::from_code(code);
        all_healthy &= state == HealthState::Healthy;
        println!("  shard {shard}: {}", state.name());
    }
    client.close();
    if !all_healthy || body.draining {
        std::process::exit(1);
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse().map_err(anyhow::Error::msg)?;
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("info");
    match cmd {
        "info" => cmd_info(&args),
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "stats" => cmd_stats(&args),
        "health" => cmd_health(&args),
        other => bail!("unknown command {other} (want info|generate|serve|client|stats|health)"),
    }
}
