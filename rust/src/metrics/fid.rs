//! FID / t-FID / FVD proxies over latent features (substitution documented
//! in DESIGN.md §2 and stats::frechet).
//!
//! Feature extractor: per-sample latent [N, C] (N = 8×8 grid) maps to a
//! 3C-dim feature — per-channel mean, per-channel std, and per-channel
//! spatial-gradient energy on the 8×8 grid. This captures first/second
//! moments and spatial structure, the aspects cache-induced error corrupts.
//! Temporal features (t-FID / FVD) apply the same extractor to the
//! DIFFERENCE of consecutive frames, which is what t-FID's temporal
//! sensitivity measures.

use crate::config::C_IN;
use crate::stats::{frechet_distance, FeatureStats};
use crate::tensor::Tensor;

pub const FEAT_DIM: usize = 3 * C_IN;

/// Latent [N, C] (N a perfect square grid) -> feature vector [3C].
pub fn latent_features(latent: &Tensor) -> Vec<f32> {
    let n = latent.shape()[0];
    let c = latent.shape()[1];
    assert_eq!(c, C_IN);
    let side = (n as f64).sqrt() as usize;
    assert_eq!(side * side, n, "token count must be a square grid");
    let data = latent.data();
    let mut feat = vec![0.0f32; 3 * c];
    for ch in 0..c {
        let mut mean = 0.0f64;
        for i in 0..n {
            mean += data[i * c + ch] as f64;
        }
        mean /= n as f64;
        let mut var = 0.0f64;
        for i in 0..n {
            let d = data[i * c + ch] as f64 - mean;
            var += d * d;
        }
        var /= n as f64;
        // Spatial gradient energy over the grid.
        let mut grad = 0.0f64;
        let mut cnt = 0usize;
        for r in 0..side {
            for q in 0..side {
                let i = r * side + q;
                if q + 1 < side {
                    let d = (data[i * c + ch] - data[(i + 1) * c + ch]) as f64;
                    grad += d * d;
                    cnt += 1;
                }
                if r + 1 < side {
                    let d = (data[i * c + ch] - data[(i + side) * c + ch]) as f64;
                    grad += d * d;
                    cnt += 1;
                }
            }
        }
        grad /= cnt.max(1) as f64;
        feat[ch] = mean as f32;
        feat[c + ch] = var.sqrt() as f32;
        feat[2 * c + ch] = grad.sqrt() as f32;
    }
    feat
}

/// Temporal-difference features between consecutive latents.
pub fn temporal_features(cur: &Tensor, prev: &Tensor) -> Vec<f32> {
    assert_eq!(cur.shape(), prev.shape());
    let diff = Tensor::new(
        cur.data().iter().zip(prev.data()).map(|(a, b)| a - b).collect(),
        cur.shape(),
    );
    latent_features(&diff)
}

/// Accumulator for a generated set's feature statistics.
pub struct FidAccumulator {
    stats: FeatureStats,
}

impl FidAccumulator {
    pub fn new() -> FidAccumulator {
        FidAccumulator { stats: FeatureStats::new(FEAT_DIM) }
    }

    pub fn push_latent(&mut self, latent: &Tensor) {
        self.stats.push(&latent_features(latent));
    }

    pub fn push_temporal(&mut self, cur: &Tensor, prev: &Tensor) {
        self.stats.push(&temporal_features(cur, prev));
    }

    pub fn push_features(&mut self, f: &[f32]) {
        self.stats.push(f);
    }

    pub fn count(&self) -> usize {
        self.stats.count()
    }

    /// Fréchet distance to a reference set's statistics.
    pub fn distance_to(&self, reference: &FidAccumulator) -> f64 {
        frechet_distance(&self.stats, &reference.stats)
    }
}

impl Default for FidAccumulator {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn latents(seed: u64, count: usize, perturb: f32) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|_| {
                let mut t = Tensor::new(rng.normal_vec(64 * C_IN, 1.0), &[64, C_IN]);
                if perturb > 0.0 {
                    for v in t.data_mut().iter_mut() {
                        *v += perturb * rng.normal() + perturb;
                    }
                }
                t
            })
            .collect()
    }

    #[test]
    fn identical_sets_zero_distance() {
        let set = latents(1, 64, 0.0);
        let mut a = FidAccumulator::new();
        let mut b = FidAccumulator::new();
        for l in &set {
            a.push_latent(l);
            b.push_latent(l);
        }
        assert!(a.distance_to(&b) < 1e-9);
    }

    #[test]
    fn distance_grows_with_perturbation() {
        let reference = {
            let mut r = FidAccumulator::new();
            for l in latents(2, 96, 0.0) {
                r.push_latent(&l);
            }
            r
        };
        let mut prev = -1.0f64;
        for (i, p) in [0.05f32, 0.2, 0.8].iter().enumerate() {
            let mut acc = FidAccumulator::new();
            for l in latents(100 + i as u64, 96, *p) {
                acc.push_latent(&l);
            }
            let d = acc.distance_to(&reference);
            assert!(d > prev, "p={p}: d={d} prev={prev}");
            prev = d;
        }
    }

    #[test]
    fn temporal_features_zero_for_static_video() {
        let a = latents(3, 1, 0.0).remove(0);
        let f = temporal_features(&a, &a);
        assert!(f.iter().all(|v| v.abs() < 1e-9));
    }

    #[test]
    fn feature_dim_consistent() {
        let l = latents(4, 1, 0.0).remove(0);
        assert_eq!(latent_features(&l).len(), FEAT_DIM);
    }
}
