//! Paper-style table formatting: fixed-width rows with the ↓/↑ headers the
//! benches print so EXPERIMENTS.md diffs read like the paper's tables.

pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<width$} | ", c, width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format helpers shared by the benches.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

pub fn speedup_pct(base_ms: f64, ms: f64) -> String {
    if ms <= 0.0 {
        return "n/a".into();
    }
    format!("+{:.1}%", (base_ms / ms - 1.0) * 100.0)
}

pub fn ms(v: f64) -> String {
    format!("{v:.0}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Test", &["Method", "FID↓", "Time (ms)↓"]);
        t.row(&["FastCache".into(), "4.46".into(), "15875".into()]);
        t.row(&["FB".into(), "4.48".into(), "1".into()]);
        let s = t.render();
        assert!(s.contains("## Test"));
        assert!(s.contains("| FastCache | 4.46 | 15875"));
        let lines: Vec<&str> = s.lines().collect();
        // All data lines equal display width (chars, not bytes — headers
        // contain multi-byte ↓ arrows).
        assert_eq!(lines[1].chars().count(), lines[3].chars().count());
        assert_eq!(lines[3].chars().count(), lines[4].chars().count());
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn speedup_formatting() {
        assert_eq!(speedup_pct(150.0, 100.0), "+50.0%");
        assert_eq!(pct(0.424), "42.4%");
    }
}
