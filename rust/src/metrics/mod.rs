//! Evaluation metrics: FID-family proxies, CLIP proxy, latency
//! histograms, and paper-style table rendering.

pub mod clip;
pub mod fid;
pub mod latency;
pub mod report;

pub use clip::{clip_display, clip_proxy};
pub use fid::{latent_features, temporal_features, FidAccumulator, FEAT_DIM};
pub use latency::LatencyHistogram;
pub use report::Table;
