//! Latency histogram + throughput counters for the serving layer.

#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// Log-spaced bucket upper bounds in ms.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum_ms: f64,
    max_ms: f64,
    n: u64,
    /// Raw samples kept for exact percentiles (serving runs are small
    /// enough that this is fine; capped to protect long-lived servers).
    samples: Vec<f64>,
}

const SAMPLE_CAP: usize = 100_000;

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        // 0.1ms .. ~100s, 1.6x steps.
        let mut bounds = Vec::new();
        let mut b = 0.1f64;
        while b < 100_000.0 {
            bounds.push(b);
            b *= 1.6;
        }
        let len = bounds.len();
        LatencyHistogram {
            bounds,
            counts: vec![0; len + 1],
            sum_ms: 0.0,
            max_ms: 0.0,
            n: 0,
            samples: Vec::new(),
        }
    }

    pub fn record(&mut self, ms: f64) {
        let idx = self.bounds.partition_point(|&b| b < ms);
        self.counts[idx] += 1;
        self.sum_ms += ms;
        self.max_ms = self.max_ms.max(ms);
        self.n += 1;
        if self.samples.len() < SAMPLE_CAP {
            self.samples.push(ms);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_ms / self.n as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max_ms
    }

    /// Exact percentile from retained samples (p in [0, 100]).
    ///
    /// For more than one percentile of the same histogram, prefer
    /// [`percentiles`](Self::percentiles): this is a convenience wrapper
    /// that pays the sort for a single value.
    pub fn percentile(&self, p: f64) -> f64 {
        self.percentiles(&[p])[0]
    }

    /// All requested percentiles in one pass: the retained samples are
    /// sorted once and every `p` is read off the sorted copy, instead of
    /// clone + sort per call.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        if self.samples.is_empty() {
            return vec![0.0; ps.len()];
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.iter()
            .map(|&p| {
                let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
                s[idx.min(s.len() - 1)]
            })
            .collect()
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum_ms += other.sum_ms;
        self.max_ms = self.max_ms.max(other.max_ms);
        self.n += other.n;
        let total = self.samples.len() + other.samples.len();
        if total <= SAMPLE_CAP {
            self.samples.extend_from_slice(&other.samples);
        } else {
            // Proportional retention: each side keeps a share of the cap
            // proportional to its contribution, thinned by even striding
            // so the survivors span each side's full recording window —
            // never "self keeps everything, donor contributes only its
            // earliest samples".
            let keep_self = self.samples.len() * SAMPLE_CAP / total;
            let keep_other = SAMPLE_CAP - keep_self;
            let thin = |src: &[f64], keep: usize| -> Vec<f64> {
                if src.len() <= keep {
                    return src.to_vec();
                }
                (0..keep).map(|i| src[i * src.len() / keep]).collect()
            };
            let mut merged = thin(&self.samples, keep_self);
            merged.extend(thin(&other.samples, keep_other));
            self.samples = merged;
        }
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let mut h = LatencyHistogram::new();
        for ms in [1.0, 2.0, 3.0, 4.0, 100.0] {
            h.record(ms);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean() - 22.0).abs() < 1e-9);
        assert_eq!(h.max(), 100.0);
        assert!((h.percentile(50.0) - 3.0).abs() < 1e-9);
        assert!(h.percentile(100.0) >= 100.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(1.0);
        b.record(3.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_percentile_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn percentiles_batch_matches_single_calls() {
        let mut h = LatencyHistogram::new();
        for ms in [5.0, 1.0, 4.0, 2.0, 3.0, 100.0, 0.5] {
            h.record(ms);
        }
        let ps = [0.0, 25.0, 50.0, 95.0, 100.0];
        let batch = h.percentiles(&ps);
        for (i, &p) in ps.iter().enumerate() {
            assert_eq!(batch[i], h.percentile(p), "p{p} diverged");
        }
        assert!(h.percentiles(&[]).is_empty());
        let empty = LatencyHistogram::new();
        assert_eq!(empty.percentiles(&[50.0, 95.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn merge_retention_is_proportional_not_first_wins() {
        // Two equally-sized donors near the cap: the old code kept ALL of
        // self and only the donor's EARLIEST leftovers. Both sides must
        // survive in proportion, and the donor's late samples must be
        // represented too.
        let m = 90_000usize;
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for i in 0..m {
            a.record(1.0 + (i as f64) * 1e-6); // ~1ms band
            b.record(1000.0 + i as f64); // 1s band, strictly increasing
        }
        a.merge(&b);
        assert_eq!(a.count(), 2 * m as u64, "counts are exact even when samples thin");
        assert!(a.samples.len() <= SAMPLE_CAP);
        let from_b = a.samples.iter().filter(|&&s| s >= 1000.0).count();
        // Proportional split of a 50/50 merge: each side holds ~half the
        // cap (the old behavior left b with ~10%).
        assert!(
            from_b >= SAMPLE_CAP * 2 / 5,
            "donor under-represented: {from_b}/{} retained",
            a.samples.len()
        );
        // The donor's LAST decile must appear: striding spans the whole
        // window, the old take(front) never got past its earliest 10k.
        let b_last_decile = 1000.0 + (m as f64) * 0.9;
        assert!(
            a.samples.iter().any(|&s| s >= b_last_decile),
            "donor's late samples all dropped"
        );
        // Exact-percentile queries still work on the thinned set, and the
        // median of a 1ms/1s bimodal merge sits between the bands.
        let p50 = a.percentile(50.0);
        assert!((1.0..=91_000.0).contains(&p50), "p50 {p50} outside merged range");
    }

    #[test]
    fn merge_below_cap_keeps_every_sample() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for i in 0..10 {
            a.record(i as f64);
            b.record(100.0 + i as f64);
        }
        a.merge(&b);
        assert_eq!(a.samples.len(), 20);
        assert_eq!(a.percentile(100.0), 109.0);
    }
}
