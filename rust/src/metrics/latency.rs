//! Latency histogram + throughput counters for the serving layer.

#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// Log-spaced bucket upper bounds in ms.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum_ms: f64,
    max_ms: f64,
    n: u64,
    /// Raw samples kept for exact percentiles (serving runs are small
    /// enough that this is fine; capped to protect long-lived servers).
    samples: Vec<f64>,
}

const SAMPLE_CAP: usize = 100_000;

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        // 0.1ms .. ~100s, 1.6x steps.
        let mut bounds = Vec::new();
        let mut b = 0.1f64;
        while b < 100_000.0 {
            bounds.push(b);
            b *= 1.6;
        }
        let len = bounds.len();
        LatencyHistogram {
            bounds,
            counts: vec![0; len + 1],
            sum_ms: 0.0,
            max_ms: 0.0,
            n: 0,
            samples: Vec::new(),
        }
    }

    pub fn record(&mut self, ms: f64) {
        let idx = self.bounds.partition_point(|&b| b < ms);
        self.counts[idx] += 1;
        self.sum_ms += ms;
        self.max_ms = self.max_ms.max(ms);
        self.n += 1;
        if self.samples.len() < SAMPLE_CAP {
            self.samples.push(ms);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_ms / self.n as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max_ms
    }

    /// Exact percentile from retained samples (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum_ms += other.sum_ms;
        self.max_ms = self.max_ms.max(other.max_ms);
        self.n += other.n;
        for &s in other.samples.iter().take(SAMPLE_CAP - self.samples.len().min(SAMPLE_CAP)) {
            self.samples.push(s);
        }
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let mut h = LatencyHistogram::new();
        for ms in [1.0, 2.0, 3.0, 4.0, 100.0] {
            h.record(ms);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean() - 22.0).abs() < 1e-9);
        assert_eq!(h.max(), 100.0);
        assert!((h.percentile(50.0) - 3.0).abs() < 1e-9);
        assert!(h.percentile(100.0) >= 100.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(1.0);
        b.record(3.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_percentile_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }
}
