//! CLIPScore proxy (substitution, DESIGN.md §2): cosine alignment between
//! the generated latent's pooled feature direction and the conditioning
//! vector that steered the generation, mapped through the embed matrix.
//!
//! Real CLIPScore measures text-image agreement; cache-induced error
//! degrades it by washing out the conditioning signal. This proxy measures
//! exactly that washout: project the final latent into hidden space with
//! the model's own embedding, pool over tokens, and compare to the
//! request's conditioning direction. Scores are scaled by 100/0.28-ish to
//! land in CLIPScore's familiar 20-30 range ONLY for table readability —
//! orderings are what we reproduce.

use crate::model::DitModel;
use crate::tensor::Tensor;

/// Cosine similarity of pooled embedded latent vs conditioning vector.
pub fn clip_proxy(model: &DitModel, latent: &Tensor, cond: &[f32]) -> f64 {
    let n = latent.shape()[0];
    let d = model.cfg.d;
    let xb = latent.clone().reshape(&[1, n, latent.shape()[1]]);
    let h = model
        .embed(&xb)
        .expect("embed for clip proxy")
        .reshape(&[n, d]);
    // Mean-pool tokens.
    let mut pooled = vec![0.0f64; d];
    for row in h.data().chunks(d) {
        for (p, v) in pooled.iter_mut().zip(row) {
            *p += *v as f64;
        }
    }
    for p in pooled.iter_mut() {
        *p /= n as f64;
    }
    let dot: f64 = pooled.iter().zip(cond).map(|(a, b)| a * *b as f64).sum();
    let na: f64 = pooled.iter().map(|a| a * a).sum::<f64>().sqrt();
    let nb: f64 = cond.iter().map(|b| (*b as f64) * (*b as f64)).sum::<f64>().sqrt();
    if na < 1e-12 || nb < 1e-12 {
        return 0.0;
    }
    dot / (na * nb)
}

/// Map the raw cosine to the CLIPScore-like display range the paper's
/// tables use (~20-30). Pure affine, order-preserving.
pub fn clip_display(cos: f64) -> f64 {
    25.0 + 10.0 * cos
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Variant, C_IN};
    use crate::model::DitModel;
    use crate::rng::Rng;

    #[test]
    fn proxy_bounded_and_display_monotone() {
        let model = DitModel::native(Variant::S, 1);
        let mut rng = Rng::new(2);
        let latent = Tensor::new(rng.normal_vec(64 * C_IN, 1.0), &[64, C_IN]);
        let cond = rng.normal_vec(96, 1.0);
        let c = clip_proxy(&model, &latent, &cond);
        assert!((-1.0..=1.0).contains(&c));
        assert!(clip_display(0.5) > clip_display(0.1));
    }

    #[test]
    fn aligned_condition_scores_higher() {
        // Construct a latent whose embedding IS the condition direction:
        // cosine must be ~1 vs ~0 for an orthogonal-ish random condition.
        let model = DitModel::native(Variant::S, 1);
        let mut rng = Rng::new(3);
        let latent = Tensor::new(rng.normal_vec(64 * C_IN, 1.0), &[64, C_IN]);
        // Derive the pooled embedding and use it as the "true" condition.
        let d = model.cfg.d;
        let n = 64;
        let h = model
            .embed(&latent.clone().reshape(&[1, n, C_IN]))
            .unwrap()
            .reshape(&[n, d]);
        let mut pooled = vec![0.0f32; d];
        for row in h.data().chunks(d) {
            for (p, v) in pooled.iter_mut().zip(row) {
                *p += v / n as f32;
            }
        }
        let aligned = clip_proxy(&model, &latent, &pooled);
        let random = clip_proxy(&model, &latent, &rng.normal_vec(d, 1.0));
        assert!(aligned > 0.99, "aligned={aligned}");
        assert!(aligned > random + 0.3, "aligned={aligned} random={random}");
    }
}
