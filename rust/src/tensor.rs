//! A minimal dense f32 tensor: contiguous row-major `Vec<f32>` plus shape.
//!
//! This is deliberately NOT a general ndarray — the coordinator only needs
//! 1-3D row-major f32 host buffers to stage data in and out of PJRT and to
//! run the cheap native math (saliency, delta metric, affine fits) that is
//! not worth a device dispatch.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data length {} != shape {:?}",
            data.len(),
            shape
        );
        Self { data, shape: shape.to_vec() }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { data: vec![v; shape.iter().product()], shape: shape.to_vec() }
    }

    /// Identity matrix [n, n].
    pub fn eye(n: usize) -> Self {
        let mut t = Self::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// A zero-element tensor — the placeholder the buffer-recycling
    /// paths (scratch outputs, cache slots) swap through.
    pub fn empty() -> Self {
        Self { data: Vec::new(), shape: vec![0] }
    }

    /// Resize in place to `shape`, reusing the existing allocation (and
    /// the shape vector's capacity) whenever possible. Contents are
    /// UNSPECIFIED afterwards — callers overwrite the whole buffer.
    pub fn ensure_shape(&mut self, shape: &[usize]) {
        let len = shape.iter().product();
        self.data.resize(len, 0.0);
        if self.shape != shape {
            self.shape.clear();
            self.shape.extend_from_slice(shape);
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Number of bytes this tensor occupies on host (and device, f32).
    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(self.data.len(), shape.iter().product::<usize>());
        self.shape = shape.to_vec();
        self
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 2);
        let d = self.shape[1];
        &self.data[i * d..(i + 1) * d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.shape.len(), 2);
        let d = self.shape[1];
        &mut self.data[i * d..(i + 1) * d]
    }

    /// Gather rows of a 2-D tensor into a new [idx.len(), D] tensor.
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let d = self.shape[1];
        let mut out = Vec::with_capacity(idx.len() * d);
        for &i in idx {
            out.extend_from_slice(self.row(i));
        }
        Tensor::new(out, &[idx.len(), d])
    }

    /// Scatter rows of `src` ([idx.len(), D]) back into self at `idx`.
    pub fn scatter_rows(&mut self, idx: &[usize], src: &Tensor) {
        assert_eq!(self.shape.len(), 2);
        assert_eq!(src.shape.len(), 2);
        assert_eq!(src.shape[0], idx.len());
        assert_eq!(src.shape[1], self.shape[1]);
        let d = self.shape[1];
        for (r, &i) in idx.iter().enumerate() {
            self.row_mut(i).copy_from_slice(&src.data[r * d..(r + 1) * d]);
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
    }

    /// Mean of all elements.
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|v| *v as f64).sum::<f64>() / self.data.len() as f64) as f32
    }

    /// Elementwise a*self + b*other (shapes must match).
    pub fn lerp(&self, other: &Tensor, w_self: f32, w_other: f32) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| w_self * a + w_other * b)
            .collect();
        Tensor::new(data, &self.shape)
    }

    /// Max |self - other|.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[", self.shape)?;
        for (i, v) in self.data.iter().take(6).enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v:.4}")?;
        }
        if self.data.len() > 6 {
            write!(f, ", …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        assert_eq!(t.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(t.size_bytes(), 24);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![1.0; 5], &[2, 3]);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let t = Tensor::new((0..12).map(|v| v as f32).collect(), &[4, 3]);
        let g = t.gather_rows(&[2, 0]);
        assert_eq!(g.row(0), &[6.0, 7.0, 8.0]);
        assert_eq!(g.row(1), &[0.0, 1.0, 2.0]);
        let mut t2 = Tensor::zeros(&[4, 3]);
        t2.scatter_rows(&[2, 0], &g);
        assert_eq!(t2.row(2), &[6.0, 7.0, 8.0]);
        assert_eq!(t2.row(0), &[0.0, 1.0, 2.0]);
        assert_eq!(t2.row(1), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn fro_norm_matches_manual() {
        let t = Tensor::new(vec![3.0, 4.0], &[2]);
        assert!((t.fro_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn eye_is_identity() {
        let i = Tensor::eye(3);
        assert_eq!(i.row(0), &[1.0, 0.0, 0.0]);
        assert_eq!(i.row(2), &[0.0, 0.0, 1.0]);
    }

    #[test]
    fn ensure_shape_reuses_capacity() {
        let mut t = Tensor::empty();
        assert_eq!(t.len(), 0);
        t.ensure_shape(&[4, 3]);
        assert_eq!(t.shape(), &[4, 3]);
        assert_eq!(t.len(), 12);
        let cap_ptr = t.data().as_ptr();
        t.ensure_shape(&[2, 3]); // shrink: same allocation
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        t.ensure_shape(&[4, 3]); // grow back within capacity
        assert_eq!(t.data().as_ptr(), cap_ptr, "regrowth within capacity must not realloc");
    }

    #[test]
    fn lerp_blends() {
        let a = Tensor::full(&[4], 1.0);
        let b = Tensor::full(&[4], 3.0);
        let c = a.lerp(&b, 0.5, 0.5);
        assert_eq!(c.data(), &[2.0, 2.0, 2.0, 2.0]);
    }
}
