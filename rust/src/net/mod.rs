//! The network front door: a TCP listener speaking the framed protocol
//! in [`proto`], feeding decoded requests into the unchanged sharded
//! [`crate::server::Dispatcher`] — and a [`NetClient`] implementing the
//! same [`crate::api::GenClient`] trait the in-process [`crate::server::Server`]
//! does, so callers are written once and run over either transport.
//!
//! Threading model (std-only, no async runtime): one nonblocking accept
//! loop, thread-per-connection with an atomic reservation gate (the
//! semaphore), one dedicated writer thread per connection (NO mutex is
//! ever held across a blocking socket write — response producers hand
//! encoded frames to the writer over an mpsc channel), and one short-lived
//! forwarder thread per in-flight request pumping `api::Event`s into
//! frames.
//!
//! Load shedding happens AT THE DOOR: a connection over the
//! `net.max_conns` budget is answered with `Error{Busy}` and closed
//! before it costs a thread, and a `Submit` that every shard queue
//! refuses is answered with `Error{Busy}` without occupying a queue
//! slot. Deadline-tagged door refusals are counted and folded into
//! `ServerReport::deadline_hit_rate()` as SLA misses — shedding at the
//! door must never make the SLA numbers look better.
//!
//! Graceful drain ([`NetServer::shutdown`]): stop accepting, unblock
//! every connection reader (no new submits), let every in-flight lane
//! finish and its terminal frame flush, send `Goodbye`, join all
//! threads, then drain the inner server and fold the door counters into
//! its report. Zero admitted responses are lost.

pub mod client;
pub mod proto;
pub mod server;

pub use client::NetClient;
pub use proto::{Frame, HealthBody, ProtoError, MAGIC, MAX_FRAME_LEN, VERSION};
pub use server::NetServer;
