//! The remote client: speaks the framed protocol to a [`super::NetServer`]
//! and implements the same [`GenClient`] trait as the in-process
//! [`crate::server::Server`], so driver code is transport-agnostic.
//!
//! One reader thread demultiplexes response frames to per-request
//! [`ResponseStream`]s by request id (`Partial` chunks accumulate
//! client-side until the `Completed` stats frame closes the latent); one
//! writer thread owns the socket's write half, fed pre-encoded frames
//! over a channel — the same no-mutex-across-write discipline as the
//! server side.

use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::api::{ErrorCode, Event, GenClient, Outcome, Progress, Reject, ResponseStream};
use crate::obs::Series;
use crate::scheduler::GenRequest;

use super::proto::{self, Frame, HealthBody, VERSION};

/// Client-side state of one in-flight request.
struct Pending {
    tx: mpsc::Sender<Event>,
    /// Latent values accumulated from `Partial` chunks, in offset order.
    latent: Vec<f32>,
}

type PendingMap = Arc<Mutex<HashMap<u64, Pending>>>;

/// In-flight `Stats` scrapes, FIFO: the server answers them in request
/// order on the one TCP stream, so the oldest waiter owns the next
/// `StatsReply`.
type StatsWaiters = Arc<Mutex<VecDeque<mpsc::Sender<Vec<Series>>>>>;

/// In-flight `Health` probes, FIFO — same in-order pairing argument as
/// [`StatsWaiters`], kept as a separate queue because the two reply types
/// interleave freely on one connection.
type HealthWaiters = Arc<Mutex<VecDeque<mpsc::Sender<HealthBody>>>>;

/// A connected remote client. Dropping it tears the connection down
/// (in-flight streams resolve to `Rejected(Closed)`); [`NetClient::close`]
/// says `Goodbye` first for a clean close.
pub struct NetClient {
    wtx: mpsc::Sender<Vec<u8>>,
    pending: PendingMap,
    stats_waiters: StatsWaiters,
    health_waiters: HealthWaiters,
    stream: TcpStream,
    reader: Option<JoinHandle<()>>,
    writer: Option<JoinHandle<()>>,
    /// Bounded retry budget for `generate` (Busy outcomes) — set by
    /// [`NetClient::connect_with_retries`], 0 means fail fast.
    retries: u32,
}

/// Deterministic backoff for 0-based attempt N: 2, 4, 8, … ms capped at
/// 256. No jitter — reproducibility outranks thundering-herd avoidance at
/// this scale, and the chaos harness depends on runs being replayable.
fn backoff_ms(attempt: u32) -> u64 {
    (2u64 << attempt.min(7)).min(256)
}

/// Connection-level failures worth retrying: the peer was unreachable or
/// vanished mid-handshake (`Closed` — includes injected socket resets) or
/// refused us at the door (`Busy`). Version and validation mismatches are
/// permanent and surface immediately.
fn connect_retryable(rej: &Reject) -> bool {
    matches!(rej.code, ErrorCode::Closed | ErrorCode::Busy)
}

impl NetClient {
    /// Connect and handshake. Every failure comes back as a typed
    /// [`Reject`] (connection-level, `id == 0`).
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<NetClient, Reject> {
        Self::connect_with_retries(addr, 0)
    }

    /// [`NetClient::connect`] with a bounded retry budget: up to
    /// `retries` extra attempts on retryable connection failures
    /// (connect refused/reset, door-shed `Busy`), deterministic
    /// exponential backoff between attempts. The final failure is
    /// surfaced unchanged. The budget is also inherited by
    /// [`GenClient::generate`] for `Busy` outcomes.
    pub fn connect_with_retries<A: ToSocketAddrs>(
        addr: A,
        retries: u32,
    ) -> Result<NetClient, Reject> {
        let mut attempt = 0u32;
        loop {
            match Self::connect_once(&addr, retries) {
                Ok(client) => return Ok(client),
                Err(rej) if attempt < retries && connect_retryable(&rej) => {
                    std::thread::sleep(std::time::Duration::from_millis(backoff_ms(attempt)));
                    attempt += 1;
                }
                Err(rej) => return Err(rej),
            }
        }
    }

    fn connect_once<A: ToSocketAddrs>(addr: &A, retries: u32) -> Result<NetClient, Reject> {
        let mut stream = TcpStream::connect(addr)
            .map_err(|e| Reject::closed(0, format!("connect failed: {e}")))?;
        let _ = stream.set_nodelay(true);

        // Handshake synchronously, before any demux thread exists.
        stream
            .write_all(&proto::encode(&Frame::Hello { version: VERSION }))
            .map_err(|e| Reject::closed(0, format!("handshake write failed: {e}")))?;
        match proto::read_frame(&mut stream) {
            Ok(Some((Frame::HelloAck { version }, _))) if version == VERSION => {}
            Ok(Some((Frame::HelloAck { version }, _))) => {
                return Err(Reject::bad_request(
                    0,
                    format!("server speaks protocol version {version}, want {VERSION}"),
                ));
            }
            Ok(Some((Frame::Error { code, detail, .. }, _))) => {
                let code = ErrorCode::from_code(code).unwrap_or(ErrorCode::Closed);
                return Err(Reject { code, id: 0, detail, waited_ms: 0.0, deadline_ms: 0.0 });
            }
            Ok(Some((other, _))) => {
                return Err(Reject::bad_request(0, format!("expected HelloAck, got {other:?}")));
            }
            Ok(None) => return Err(Reject::closed(0, "server closed during handshake")),
            Err(e) => return Err(Reject::closed(0, format!("handshake failed: {e}"))),
        }

        let pending: PendingMap = Arc::new(Mutex::new(HashMap::new()));
        let stats_waiters: StatsWaiters = Arc::new(Mutex::new(VecDeque::new()));
        let health_waiters: HealthWaiters = Arc::new(Mutex::new(VecDeque::new()));
        let (wtx, wrx) = mpsc::channel::<Vec<u8>>();

        let writer = {
            let mut half = stream
                .try_clone()
                .map_err(|e| Reject::closed(0, format!("stream clone failed: {e}")))?;
            std::thread::Builder::new()
                .name("fastcache-client-writer".into())
                .spawn(move || {
                    while let Ok(buf) = wrx.recv() {
                        if half.write_all(&buf).is_err() {
                            while wrx.recv().is_ok() {}
                            return;
                        }
                    }
                    let _ = half.flush();
                })
                .expect("spawning client writer")
        };

        let reader = {
            let mut half = stream
                .try_clone()
                .map_err(|e| Reject::closed(0, format!("stream clone failed: {e}")))?;
            let pending = Arc::clone(&pending);
            let waiters = Arc::clone(&stats_waiters);
            let hwaiters = Arc::clone(&health_waiters);
            std::thread::Builder::new()
                .name("fastcache-client-reader".into())
                .spawn(move || demux_loop(&mut half, &pending, &waiters, &hwaiters))
                .expect("spawning client reader")
        };

        Ok(NetClient {
            wtx,
            pending,
            stats_waiters,
            health_waiters,
            stream,
            reader: Some(reader),
            writer: Some(writer),
            retries,
        })
    }

    /// Scrape the server's live telemetry registry: one `Stats` frame
    /// out, one `StatsReply` back. Blocks until the reply arrives (the
    /// server answers inline on the request path, so this is one
    /// round-trip) or the connection dies.
    pub fn stats(&self) -> Result<Vec<Series>, Reject> {
        let (tx, rx) = mpsc::channel();
        // Enqueue BEFORE writing, mirroring submit_inner: the reply
        // cannot race past its waiter.
        self.stats_waiters.lock().expect("stats waiters poisoned").push_back(tx);
        if self.wtx.send(proto::encode(&Frame::Stats)).is_err() {
            self.stats_waiters.lock().expect("stats waiters poisoned").pop_back();
            return Err(Reject::closed(0, "connection writer gone"));
        }
        rx.recv().map_err(|_| Reject::closed(0, "connection closed before stats reply"))
    }

    /// Probe the server's liveness: one `Health` frame out, one
    /// `HealthReply` back (v4+). Answered even while the server drains —
    /// the whole point of the frame is that it never goes dark before the
    /// socket does.
    pub fn health(&self) -> Result<HealthBody, Reject> {
        let (tx, rx) = mpsc::channel();
        // Enqueue BEFORE writing, mirroring stats(): the reply cannot
        // race past its waiter.
        self.health_waiters.lock().expect("health waiters poisoned").push_back(tx);
        if self.wtx.send(proto::encode(&Frame::Health)).is_err() {
            self.health_waiters.lock().expect("health waiters poisoned").pop_back();
            return Err(Reject::closed(0, "connection writer gone"));
        }
        rx.recv().map_err(|_| Reject::closed(0, "connection closed before health reply"))
    }

    fn submit_inner(&self, req: &GenRequest, progress: bool) -> Result<ResponseStream, Reject> {
        let id = req.id;
        let (tx, rx) = mpsc::channel();
        {
            // Register BEFORE writing: the response cannot race past its
            // demux entry. Ids must be unique among in-flight requests on
            // one connection — the wire has no other correlator.
            let mut map = self.pending.lock().expect("pending map poisoned");
            if map.contains_key(&id) {
                return Err(Reject::bad_request(
                    id,
                    "request id already in flight on this connection",
                ));
            }
            map.insert(id, Pending { tx, latent: Vec::new() });
        }
        let buf = proto::encode(&Frame::Submit { req: req.clone(), progress });
        if self.wtx.send(buf).is_err() {
            self.pending.lock().expect("pending map poisoned").remove(&id);
            return Err(Reject::closed(id, "connection writer gone"));
        }
        Ok(ResponseStream::new(id, rx))
    }

    /// Clean close: `Goodbye`, flush, join the IO threads. In-flight
    /// requests resolve to `Rejected(Closed)`.
    pub fn close(mut self) {
        let _ = self.wtx.send(proto::encode(&Frame::Goodbye));
        self.teardown();
    }

    fn teardown(&mut self) {
        // Replace the sender so the writer's channel disconnects and it
        // drains + exits; then unblock and join the reader.
        let (dead_tx, _) = mpsc::channel();
        drop(std::mem::replace(&mut self.wtx, dead_tx));
        if let Some(w) = self.writer.take() {
            let _ = w.join();
        }
        let _ = self.stream.shutdown(Shutdown::Both);
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

impl Drop for NetClient {
    fn drop(&mut self) {
        self.teardown();
    }
}

impl GenClient for NetClient {
    fn submit(&self, req: &GenRequest) -> Result<ResponseStream, Reject> {
        self.submit_inner(req, false)
    }

    fn submit_streaming(&self, req: &GenRequest) -> Result<ResponseStream, Reject> {
        self.submit_inner(req, true)
    }

    /// Bounded-retry override of the trait default (which retries `Busy`
    /// forever): over the wire a `Busy` arrives as a terminal outcome
    /// after the round-trip, so retry the whole submission up to the
    /// connection's `retries` budget with deterministic backoff, then
    /// surface the final rejection unchanged.
    fn generate(&self, req: &GenRequest) -> Outcome {
        let mut attempt = 0u32;
        loop {
            let outcome = match self.submit(req) {
                Ok(stream) => stream.wait(),
                Err(rej) => Outcome::Rejected(rej),
            };
            match &outcome {
                Outcome::Rejected(rej)
                    if rej.code == ErrorCode::Busy && attempt < self.retries =>
                {
                    std::thread::sleep(std::time::Duration::from_millis(backoff_ms(attempt)));
                    attempt += 1;
                }
                _ => return outcome,
            }
        }
    }
}

/// Route one terminal outcome to its pending stream and forget the id.
fn finish(pending: &PendingMap, id: u64, outcome: Outcome) {
    if let Some(p) = pending.lock().expect("pending map poisoned").remove(&id) {
        let _ = p.tx.send(Event::Done(outcome));
    }
}

/// Connection is gone: every in-flight request resolves to a typed
/// `Closed` rejection — a client must never hang on a dead socket.
/// Pending stats scrapes and health probes unblock too: dropping their
/// senders makes the blocked `recv` fail, which [`NetClient::stats`] and
/// [`NetClient::health`] map to `Closed`.
fn fail_all(pending: &PendingMap, waiters: &StatsWaiters, hwaiters: &HealthWaiters, why: &str) {
    let mut map = pending.lock().expect("pending map poisoned");
    for (id, p) in map.drain() {
        let _ = p.tx.send(Event::Done(Outcome::Rejected(Reject::closed(id, why))));
    }
    waiters.lock().expect("stats waiters poisoned").clear();
    hwaiters.lock().expect("health waiters poisoned").clear();
}

fn demux_loop(
    stream: &mut TcpStream,
    pending: &PendingMap,
    waiters: &StatsWaiters,
    hwaiters: &HealthWaiters,
) {
    loop {
        match proto::read_frame(stream) {
            Ok(Some((Frame::Progress(Progress { id, step, total }), _))) => {
                if let Some(p) = pending.lock().expect("pending map poisoned").get(&id) {
                    let _ = p.tx.send(Event::Progress(Progress { id, step, total }));
                }
            }
            Ok(Some((Frame::Partial { id, offset, total, values }, _))) => {
                let mut map = pending.lock().expect("pending map poisoned");
                let Some(p) = map.get_mut(&id) else { continue };
                // Chunks arrive in offset order on one TCP stream; a gap
                // means the stream is corrupt beyond per-request repair.
                if offset as usize != p.latent.len()
                    || p.latent.len() + values.len() > total as usize
                {
                    drop(map);
                    fail_all(
                        pending,
                        waiters,
                        hwaiters,
                        "partial chunk out of order — stream corrupt",
                    );
                    return;
                }
                p.latent.extend_from_slice(&values);
            }
            Ok(Some((Frame::Completed(c), _))) => {
                let id = c.id;
                let latent = match pending.lock().expect("pending map poisoned").get_mut(&id) {
                    Some(p) => std::mem::take(&mut p.latent),
                    None => continue,
                };
                let outcome = match c.into_response(latent) {
                    Ok(resp) => Outcome::Completed(resp),
                    Err(e) => Outcome::Rejected(Reject::closed(
                        id,
                        format!("response reassembly failed: {e}"),
                    )),
                };
                finish(pending, id, outcome);
            }
            Ok(Some((Frame::Shed { id, waited_ms, deadline_ms }, _))) => {
                finish(pending, id, Outcome::Rejected(Reject::expired(id, waited_ms, deadline_ms)));
            }
            Ok(Some((Frame::Error { id, code, detail }, _))) if id != 0 => {
                let code = ErrorCode::from_code(code).unwrap_or(ErrorCode::Closed);
                finish(
                    pending,
                    id,
                    Outcome::Rejected(Reject { code, id, detail, waited_ms: 0.0, deadline_ms: 0.0 }),
                );
            }
            Ok(Some((Frame::StatsReply(series), _))) => {
                // FIFO pairing: one TCP stream, server answers scrapes
                // in order, so the oldest waiter owns this reply. A
                // missing waiter (caller gave up) is dropped silently.
                let waiter =
                    waiters.lock().expect("stats waiters poisoned").pop_front();
                if let Some(tx) = waiter {
                    let _ = tx.send(series);
                }
            }
            Ok(Some((Frame::HealthReply(body), _))) => {
                // Same FIFO pairing as StatsReply, on the health queue.
                let waiter =
                    hwaiters.lock().expect("health waiters poisoned").pop_front();
                if let Some(tx) = waiter {
                    let _ = tx.send(body);
                }
            }
            // Connection-level error, server Goodbye, clean EOF, or a
            // broken stream: nothing more will arrive.
            Ok(Some((Frame::Error { detail, .. }, _))) => {
                fail_all(pending, waiters, hwaiters, &format!("connection error: {detail}"));
                return;
            }
            Ok(Some((Frame::Goodbye, _))) => {
                fail_all(pending, waiters, hwaiters, "server said goodbye");
                return;
            }
            Ok(Some(_)) => {
                fail_all(pending, waiters, hwaiters, "unexpected frame on response path");
                return;
            }
            Ok(None) => {
                fail_all(pending, waiters, hwaiters, "connection closed");
                return;
            }
            Err(e) => {
                fail_all(pending, waiters, hwaiters, &format!("read failed: {e}"));
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::backoff_ms;

    #[test]
    fn backoff_is_deterministic_exponential_with_a_cap() {
        let ms: Vec<u64> = (0..10).map(backoff_ms).collect();
        assert_eq!(ms, vec![2, 4, 8, 16, 32, 64, 128, 256, 256, 256]);
    }
}
