//! The wire protocol: versioned, length-prefixed binary frames with a
//! zero-dependency codec. The full grammar lives in `docs/PROTOCOL.md`;
//! this file IS the normative implementation.
//!
//! Layout of every frame:
//!
//! ```text
//! [len: u32 LE] [type: u8] [payload: len-1 bytes]
//! ```
//!
//! `len` counts the type byte plus the payload (not itself) and is
//! bounded by [`MAX_FRAME_LEN`] — an oversized length is rejected
//! *before* any body byte is read or buffered, so a hostile peer cannot
//! make the server allocate. All multi-byte integers are little-endian;
//! floats are IEEE-754 bit patterns in LE byte order (latents round-trip
//! bit-identically — the loopback parity guarantee rests on this).
//!
//! Decoding is strict: every payload must consume exactly its `len`
//! (trailing bytes are `Malformed`), unknown type bytes are
//! `UnknownType`, and a `Submit` payload is re-validated through
//! `GenRequest::builder` — a malformed remote request gets the same
//! typed `BadRequest` an in-process caller would.

use std::io::Read;

use crate::api::{GenResponse, Progress, Reject};
use crate::obs::{HistSummary, Series, SeriesValue};
use crate::scheduler::{GenRequest, GenResult, Turbulence};
use crate::tensor::Tensor;

/// `b"FCP1"` interpreted as a little-endian u32 — the first field of the
/// `Hello`/`HelloAck` payload.
pub const MAGIC: u32 = u32::from_le_bytes(*b"FCP1");

/// Protocol version spoken by this build. Version negotiation is
/// exact-match (see docs/PROTOCOL.md): a mismatched `Hello` is answered
/// with `Error{BadRequest}` and the connection closes.
///
/// History: v1 — initial protocol; v2 — adds the `Stats`/`StatsReply`
/// telemetry-scrape pair; v3 — `Completed` carries the degrade-ladder
/// verdict (`degraded` flag + rungs walked) and servers may answer
/// `Error{Internal}` (code 5) for fault-quarantined requests; v4 — adds
/// the `Health`/`HealthReply` liveness pair (answered even while
/// draining) and servers may answer `Error{Poisoned}` (code 6) for
/// blocklisted requests.
pub const VERSION: u16 = 4;

/// Upper bound on `len` (type byte + payload): 16 MiB. Far above any
/// legitimate frame (the largest — `Partial` — is ~64 KiB) while small
/// enough that a hostile length prefix cannot drive allocation.
pub const MAX_FRAME_LEN: u32 = 1 << 24;

/// f32 values per `Partial` chunk (64 KiB of payload). Latents larger
/// than this stream as multiple chunks with increasing `offset`.
pub const PARTIAL_CHUNK_F32: usize = 16 * 1024;

/// Frame type bytes. Requests are < 0x80, responses ≥ 0x80.
const T_HELLO: u8 = 0x01;
const T_SUBMIT: u8 = 0x02;
const T_GOODBYE: u8 = 0x03;
const T_STATS: u8 = 0x04;
const T_HEALTH: u8 = 0x05;
const T_HELLO_ACK: u8 = 0x81;
const T_PROGRESS: u8 = 0x82;
const T_PARTIAL: u8 = 0x83;
const T_COMPLETED: u8 = 0x84;
const T_SHED: u8 = 0x85;
const T_ERROR: u8 = 0x86;
const T_STATS_REPLY: u8 = 0x87;
const T_HEALTH_REPLY: u8 = 0x88;

/// Decode/IO failure modes. `BadRequest` is the one *semantic* rejection:
/// the frame was structurally valid but the request inside failed the
/// same validation an in-process caller goes through.
#[derive(Debug)]
pub enum ProtoError {
    /// The input ended mid-frame.
    Truncated,
    /// Declared length exceeds [`MAX_FRAME_LEN`] (rejected before read).
    Oversized { len: u32 },
    /// `Hello`/`HelloAck` magic mismatch.
    BadMagic(u32),
    /// Peer speaks a protocol version this build does not.
    BadVersion(u16),
    /// Unknown frame type byte.
    UnknownType(u8),
    /// Structurally invalid payload (overrun, trailing bytes, bad UTF-8,
    /// inconsistent counts).
    Malformed(String),
    /// Structurally valid `Submit` whose request failed validation.
    BadRequest(Reject),
    Io(std::io::Error),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Truncated => write!(f, "truncated frame"),
            ProtoError::Oversized { len } => {
                write!(f, "frame length {len} exceeds max {MAX_FRAME_LEN}")
            }
            ProtoError::BadMagic(m) => write!(f, "bad magic {m:#010x}"),
            ProtoError::BadVersion(v) => write!(f, "unsupported protocol version {v}"),
            ProtoError::UnknownType(t) => write!(f, "unknown frame type {t:#04x}"),
            ProtoError::Malformed(why) => write!(f, "malformed frame: {why}"),
            ProtoError::BadRequest(rej) => write!(f, "bad request: {rej}"),
            ProtoError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ProtoError {}

impl From<std::io::Error> for ProtoError {
    fn from(e: std::io::Error) -> ProtoError {
        ProtoError::Io(e)
    }
}

/// The serving-stats body of a `Completed` frame. The latent itself
/// travels in the preceding `Partial` chunks; `shape` here lets the
/// client reassemble the tensor and cross-check the chunk total.
/// Per-step records and the conditioning vector are intentionally NOT
/// shipped (diagnostic payloads, unbounded size) — see docs/PROTOCOL.md.
#[derive(Clone, Debug, PartialEq)]
pub struct Completed {
    pub id: u64,
    pub shape: Vec<u32>,
    pub queued_ms: f64,
    pub e2e_ms: f64,
    pub deadline_met: Option<bool>,
    pub wall_ms: f64,
    pub computed: u64,
    pub approximated: u64,
    pub reused: u64,
    pub token_sites_computed: u64,
    pub token_sites_total: u64,
    pub flops_done: u64,
    pub flops_full: u64,
    pub flops_padded: u64,
    pub cache_bytes_peak: u64,
    pub warm_layers: u64,
    pub degraded: bool,
    pub degrade_rungs: u64,
}

impl Completed {
    /// Project a served response onto the wire stats body.
    pub fn from_response(resp: &GenResponse) -> Completed {
        let r = &resp.result;
        Completed {
            id: r.id,
            shape: r.latent.shape().iter().map(|&d| d as u32).collect(),
            queued_ms: resp.queued_ms,
            e2e_ms: resp.e2e_ms,
            deadline_met: resp.deadline_met,
            wall_ms: r.wall_ms,
            computed: r.computed as u64,
            approximated: r.approximated as u64,
            reused: r.reused as u64,
            token_sites_computed: r.token_sites_computed,
            token_sites_total: r.token_sites_total,
            flops_done: r.flops_done,
            flops_full: r.flops_full,
            flops_padded: r.flops_padded,
            cache_bytes_peak: r.cache_bytes_peak as u64,
            warm_layers: r.warm_layers as u64,
            degraded: r.degraded,
            degrade_rungs: r.degrade_rungs as u64,
        }
    }

    /// Reassemble a client-side `GenResponse` from this stats body plus
    /// the latent values collected from `Partial` chunks. The per-step
    /// records and conditioning vector are not transported, so they come
    /// back empty — everything else round-trips exactly.
    pub fn into_response(self, values: Vec<f32>) -> Result<GenResponse, ProtoError> {
        let shape: Vec<usize> = self.shape.iter().map(|&d| d as usize).collect();
        let expect: usize = shape.iter().product();
        if expect != values.len() {
            return Err(ProtoError::Malformed(format!(
                "latent shape {:?} wants {expect} values, got {}",
                self.shape,
                values.len()
            )));
        }
        Ok(GenResponse {
            result: GenResult {
                id: self.id,
                latent: Tensor::new(values, &shape),
                cond: Vec::new(),
                records: Vec::new(),
                wall_ms: self.wall_ms,
                computed: self.computed as usize,
                approximated: self.approximated as usize,
                reused: self.reused as usize,
                token_sites_computed: self.token_sites_computed,
                token_sites_total: self.token_sites_total,
                flops_done: self.flops_done,
                flops_full: self.flops_full,
                flops_padded: self.flops_padded,
                cache_bytes_peak: self.cache_bytes_peak as usize,
                warm_layers: self.warm_layers as usize,
                degraded: self.degraded,
                degrade_rungs: self.degrade_rungs as u32,
            },
            queued_ms: self.queued_ms,
            e2e_ms: self.e2e_ms,
            deadline_met: self.deadline_met,
        })
    }
}

/// The liveness body of a `HealthReply` frame (v4+). Deliberately tiny —
/// a health probe must stay answerable even when the server is drowning,
/// so the payload is a handful of integers, never a latent or a series
/// dump.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HealthBody {
    /// True once graceful drain has begun. Health probes are still
    /// answered during drain — that is the point of the frame.
    pub draining: bool,
    /// Supervised shard restarts since boot (flap + watchdog escalations).
    pub restarts: u64,
    /// Request ids currently blocklisted as poisoned.
    pub blocklisted: u64,
    /// Per-shard `(shard index, state code)` pairs. State codes stay a
    /// raw u8 so unknown states from newer peers round-trip; map through
    /// `server::HealthState::from_code` to interpret.
    pub shards: Vec<(u32, u8)>,
}

/// One protocol frame. Request frames flow client → server, response
/// frames server → client; `Goodbye` is valid in both directions (clean
/// close / end-of-drain marker).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Client handshake: magic + version, first frame on every
    /// connection.
    Hello { version: u16 },
    /// One generation request; `progress` asks for per-step ticks.
    Submit { req: GenRequest, progress: bool },
    /// Clean close marker.
    Goodbye,
    /// Telemetry scrape request (empty payload, v2+). Valid any time
    /// after the handshake; answered with one `StatsReply`.
    Stats,
    /// Liveness probe (empty payload, v4+). Valid any time after the
    /// handshake and answered with one `HealthReply` — even while the
    /// server is draining.
    Health,
    /// Server handshake answer.
    HelloAck { version: u16 },
    /// Per-step progress tick (streaming submissions only).
    Progress(Progress),
    /// One chunk of a completed latent: `values` starts at f32 index
    /// `offset` of a `total`-element tensor.
    Partial { id: u64, offset: u32, total: u32, values: Vec<f32> },
    /// Terminal: request served (stats body; latent arrived as
    /// `Partial` chunks).
    Completed(Completed),
    /// Terminal: deadline-tagged request dropped unserved.
    Shed { id: u64, waited_ms: f64, deadline_ms: f64 },
    /// Terminal (or connection-level when `id == 0`): typed rejection.
    /// `code` stays a raw u16 so unknown codes from newer peers
    /// round-trip; map through `api::ErrorCode::from_code` to interpret.
    Error { id: u64, code: u16, detail: String },
    /// A registry scrape: every live series at the instant the server
    /// handled the `Stats` frame (v2+).
    StatsReply(Vec<Series>),
    /// Per-shard liveness at the instant the server handled the `Health`
    /// frame (v4+).
    HealthReply(HealthBody),
}

// ---------------------------------------------------------------- encode

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, vs: &[f32]) {
        self.buf.reserve(vs.len() * 4);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn str(&mut self, s: &str) {
        let bytes = s.as_bytes();
        // Detail strings are advisory; clamp instead of erroring so an
        // over-long message can never make a frame unencodable.
        let take = bytes.len().min(u16::MAX as usize);
        self.u16(take as u16);
        self.buf.extend_from_slice(&bytes[..take]);
    }
}

/// Encode one frame: `[len][type][payload]`, ready to write.
pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut e = Enc { buf: Vec::with_capacity(64) };
    // Reserve the length prefix; backfilled below.
    e.u32(0);
    match frame {
        Frame::Hello { version } => {
            e.u8(T_HELLO);
            e.u32(MAGIC);
            e.u16(*version);
        }
        Frame::Submit { req, progress } => {
            e.u8(T_SUBMIT);
            e.u64(req.id);
            e.u64(req.seed);
            e.u64(req.cond_seed);
            e.f32(req.guidance);
            e.u32(req.steps as u32);
            match req.deadline_ms {
                Some(ms) => {
                    e.u8(1);
                    e.f64(ms);
                }
                None => e.u8(0),
            }
            match &req.turbulence {
                Some(t) => {
                    e.u8(1);
                    e.f32(t.amp);
                    e.u64(t.seed);
                    e.u32(t.tokens.len() as u32);
                    for &tok in &t.tokens {
                        e.u32(tok as u32);
                    }
                }
                None => e.u8(0),
            }
            match &req.init_latent {
                Some(t) => {
                    e.u8(1);
                    e.u8(t.shape().len() as u8);
                    for &d in t.shape() {
                        e.u32(d as u32);
                    }
                    e.f32s(t.data());
                }
                None => e.u8(0),
            }
            e.u8(u8::from(*progress));
        }
        Frame::Goodbye => e.u8(T_GOODBYE),
        Frame::Stats => e.u8(T_STATS),
        Frame::Health => e.u8(T_HEALTH),
        Frame::HelloAck { version } => {
            e.u8(T_HELLO_ACK);
            e.u32(MAGIC);
            e.u16(*version);
        }
        Frame::Progress(p) => {
            e.u8(T_PROGRESS);
            e.u64(p.id);
            e.u32(p.step);
            e.u32(p.total);
        }
        Frame::Partial { id, offset, total, values } => {
            e.u8(T_PARTIAL);
            e.u64(*id);
            e.u32(*offset);
            e.u32(*total);
            e.u32(values.len() as u32);
            e.f32s(values);
        }
        Frame::Completed(c) => {
            e.u8(T_COMPLETED);
            e.u64(c.id);
            e.u8(c.shape.len() as u8);
            for &d in &c.shape {
                e.u32(d);
            }
            e.f64(c.queued_ms);
            e.f64(c.e2e_ms);
            e.u8(match c.deadline_met {
                None => 0,
                Some(false) => 1,
                Some(true) => 2,
            });
            e.f64(c.wall_ms);
            e.u64(c.computed);
            e.u64(c.approximated);
            e.u64(c.reused);
            e.u64(c.token_sites_computed);
            e.u64(c.token_sites_total);
            e.u64(c.flops_done);
            e.u64(c.flops_full);
            e.u64(c.flops_padded);
            e.u64(c.cache_bytes_peak);
            e.u64(c.warm_layers);
            e.u8(u8::from(c.degraded));
            e.u64(c.degrade_rungs);
        }
        Frame::Shed { id, waited_ms, deadline_ms } => {
            e.u8(T_SHED);
            e.u64(*id);
            e.f64(*waited_ms);
            e.f64(*deadline_ms);
        }
        Frame::Error { id, code, detail } => {
            e.u8(T_ERROR);
            e.u64(*id);
            e.u16(*code);
            e.str(detail);
        }
        Frame::StatsReply(series) => {
            e.u8(T_STATS_REPLY);
            e.u32(series.len() as u32);
            for s in series {
                e.str(&s.name);
                match &s.value {
                    SeriesValue::Counter(v) => {
                        e.u8(0);
                        e.u64(*v);
                    }
                    SeriesValue::Gauge(v) => {
                        e.u8(1);
                        e.u64(*v);
                    }
                    SeriesValue::Hist(h) => {
                        e.u8(2);
                        e.u64(h.count);
                        e.f64(h.mean_ms);
                        e.f64(h.p50_ms);
                        e.f64(h.p95_ms);
                        e.f64(h.p99_ms);
                        e.f64(h.max_ms);
                    }
                }
            }
        }
        Frame::HealthReply(h) => {
            e.u8(T_HEALTH_REPLY);
            e.u8(u8::from(h.draining));
            e.u64(h.restarts);
            e.u64(h.blocklisted);
            e.u32(h.shards.len() as u32);
            for &(shard, state) in &h.shards {
                e.u32(shard);
                e.u8(state);
            }
        }
    }
    let len = (e.buf.len() - 4) as u32;
    debug_assert!(len <= MAX_FRAME_LEN, "encoded frame exceeds MAX_FRAME_LEN");
    e.buf[0..4].copy_from_slice(&len.to_le_bytes());
    e.buf
}

/// Chunk a completed latent into `Partial` frames of at most
/// [`PARTIAL_CHUNK_F32`] values each, offsets increasing. An empty
/// latent still yields one (empty) chunk so the receiver always sees the
/// declared total at least once.
pub fn partial_frames(id: u64, values: &[f32]) -> Vec<Frame> {
    let total = values.len() as u32;
    if values.is_empty() {
        return vec![Frame::Partial { id, offset: 0, total, values: Vec::new() }];
    }
    values
        .chunks(PARTIAL_CHUNK_F32)
        .enumerate()
        .map(|(i, chunk)| Frame::Partial {
            id,
            offset: (i * PARTIAL_CHUNK_F32) as u32,
            total,
            values: chunk.to_vec(),
        })
        .collect()
}

// ---------------------------------------------------------------- decode

/// Bounds-checked cursor over one frame's payload.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.pos + n > self.buf.len() {
            return Err(ProtoError::Malformed(format!(
                "payload overrun: want {n} bytes at {}, have {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, ProtoError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32, ProtoError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, ProtoError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    /// A u32 count that must be plausible for `elem_bytes`-sized elements
    /// within the remaining payload — checked BEFORE allocating, so a
    /// hostile count cannot drive a huge `Vec::with_capacity`.
    fn count(&mut self, elem_bytes: usize) -> Result<usize, ProtoError> {
        let n = self.u32()? as usize;
        let avail = self.buf.len() - self.pos;
        if n.saturating_mul(elem_bytes) > avail {
            return Err(ProtoError::Malformed(format!(
                "count {n} x {elem_bytes}B exceeds remaining payload {avail}B"
            )));
        }
        Ok(n)
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, ProtoError> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn str(&mut self) -> Result<String, ProtoError> {
        let n = self.u16()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| ProtoError::Malformed("detail string is not UTF-8".into()))
    }
    fn done(&self) -> Result<(), ProtoError> {
        if self.pos != self.buf.len() {
            return Err(ProtoError::Malformed(format!(
                "{} trailing bytes after payload",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn decode_handshake(cur: &mut Cur) -> Result<u16, ProtoError> {
    let magic = cur.u32()?;
    if magic != MAGIC {
        return Err(ProtoError::BadMagic(magic));
    }
    cur.u16()
}

fn decode_submit(cur: &mut Cur) -> Result<Frame, ProtoError> {
    let id = cur.u64()?;
    let seed = cur.u64()?;
    let cond_seed = cur.u64()?;
    let guidance = cur.f32()?;
    let steps = cur.u32()? as usize;
    let deadline = if cur.u8()? != 0 { Some(cur.f64()?) } else { None };
    let turbulence = if cur.u8()? != 0 {
        let amp = cur.f32()?;
        let tseed = cur.u64()?;
        let n = cur.count(4)?;
        let mut tokens = Vec::with_capacity(n);
        for _ in 0..n {
            tokens.push(cur.u32()? as usize);
        }
        Some(Turbulence { tokens, amp, seed: tseed })
    } else {
        None
    };
    let init_latent = if cur.u8()? != 0 {
        let ndims = cur.u8()? as usize;
        let mut shape = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            shape.push(cur.u32()? as usize);
        }
        let want: usize = shape.iter().product();
        let avail = cur.buf.len() - cur.pos;
        if want.saturating_mul(4) > avail {
            return Err(ProtoError::Malformed(format!(
                "init_latent shape {shape:?} wants {want} f32s, payload has {avail} bytes"
            )));
        }
        Some(Tensor::new(cur.f32s(want)?, &shape))
    } else {
        None
    };
    let progress = cur.u8()? != 0;

    // Same validation gate as the in-process path: route the decoded
    // fields through the builder so a hostile frame cannot smuggle a
    // request an in-process caller could not construct.
    let mut b = GenRequest::builder(id, seed).cond_seed(cond_seed).guidance(guidance).steps(steps);
    if let Some(ms) = deadline {
        b = b.deadline_ms(ms);
    }
    if let Some(t) = turbulence {
        b = b.turbulence(t);
    }
    if let Some(t) = init_latent {
        b = b.init_latent(t);
    }
    let req = b.build().map_err(ProtoError::BadRequest)?;
    Ok(Frame::Submit { req, progress })
}

fn decode_completed(cur: &mut Cur) -> Result<Completed, ProtoError> {
    let id = cur.u64()?;
    let ndims = cur.u8()? as usize;
    let mut shape = Vec::with_capacity(ndims);
    for _ in 0..ndims {
        shape.push(cur.u32()?);
    }
    let queued_ms = cur.f64()?;
    let e2e_ms = cur.f64()?;
    let deadline_met = match cur.u8()? {
        0 => None,
        1 => Some(false),
        2 => Some(true),
        other => {
            return Err(ProtoError::Malformed(format!("bad deadline_met tag {other}")));
        }
    };
    Ok(Completed {
        id,
        shape,
        queued_ms,
        e2e_ms,
        deadline_met,
        wall_ms: cur.f64()?,
        computed: cur.u64()?,
        approximated: cur.u64()?,
        reused: cur.u64()?,
        token_sites_computed: cur.u64()?,
        token_sites_total: cur.u64()?,
        flops_done: cur.u64()?,
        flops_full: cur.u64()?,
        flops_padded: cur.u64()?,
        cache_bytes_peak: cur.u64()?,
        warm_layers: cur.u64()?,
        degraded: cur.u8()? != 0,
        degrade_rungs: cur.u64()?,
    })
}

fn decode_stats_reply(cur: &mut Cur) -> Result<Vec<Series>, ProtoError> {
    // Smallest possible series: empty name (2-byte length) + kind byte
    // + one u64 value = 11 bytes — enough to bound the pre-allocation.
    let n = cur.count(11)?;
    let mut series = Vec::with_capacity(n);
    for _ in 0..n {
        let name = cur.str()?;
        let value = match cur.u8()? {
            0 => SeriesValue::Counter(cur.u64()?),
            1 => SeriesValue::Gauge(cur.u64()?),
            2 => SeriesValue::Hist(HistSummary {
                count: cur.u64()?,
                mean_ms: cur.f64()?,
                p50_ms: cur.f64()?,
                p95_ms: cur.f64()?,
                p99_ms: cur.f64()?,
                max_ms: cur.f64()?,
            }),
            other => {
                return Err(ProtoError::Malformed(format!("unknown series kind {other}")));
            }
        };
        series.push(Series { name, value });
    }
    Ok(series)
}

/// Decode one frame from the front of `buf`. Returns the frame and the
/// total bytes consumed (length prefix included). `Truncated` when the
/// buffer ends mid-frame; `Oversized` is raised from the 4-byte prefix
/// alone, before any body inspection.
pub fn decode_slice(buf: &[u8]) -> Result<(Frame, usize), ProtoError> {
    if buf.len() < 4 {
        return Err(ProtoError::Truncated);
    }
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if len > MAX_FRAME_LEN {
        return Err(ProtoError::Oversized { len });
    }
    if len == 0 {
        return Err(ProtoError::Malformed("zero-length frame (missing type byte)".into()));
    }
    let end = 4 + len as usize;
    if buf.len() < end {
        return Err(ProtoError::Truncated);
    }
    let ty = buf[4];
    let mut cur = Cur { buf: &buf[5..end], pos: 0 };
    let frame = match ty {
        T_HELLO => Frame::Hello { version: decode_handshake(&mut cur)? },
        T_SUBMIT => decode_submit(&mut cur)?,
        T_GOODBYE => Frame::Goodbye,
        T_STATS => Frame::Stats,
        T_HEALTH => Frame::Health,
        T_HELLO_ACK => Frame::HelloAck { version: decode_handshake(&mut cur)? },
        T_PROGRESS => {
            let id = cur.u64()?;
            let step = cur.u32()?;
            let total = cur.u32()?;
            Frame::Progress(Progress { id, step, total })
        }
        T_PARTIAL => {
            let id = cur.u64()?;
            let offset = cur.u32()?;
            let total = cur.u32()?;
            let n = cur.count(4)?;
            Frame::Partial { id, offset, total, values: cur.f32s(n)? }
        }
        T_COMPLETED => Frame::Completed(decode_completed(&mut cur)?),
        T_SHED => {
            let id = cur.u64()?;
            let waited_ms = cur.f64()?;
            let deadline_ms = cur.f64()?;
            Frame::Shed { id, waited_ms, deadline_ms }
        }
        T_ERROR => {
            let id = cur.u64()?;
            let code = cur.u16()?;
            let detail = cur.str()?;
            Frame::Error { id, code, detail }
        }
        T_STATS_REPLY => Frame::StatsReply(decode_stats_reply(&mut cur)?),
        T_HEALTH_REPLY => {
            let draining = cur.u8()? != 0;
            let restarts = cur.u64()?;
            let blocklisted = cur.u64()?;
            let n = cur.count(5)?;
            let mut shards = Vec::with_capacity(n);
            for _ in 0..n {
                let shard = cur.u32()?;
                let state = cur.u8()?;
                shards.push((shard, state));
            }
            Frame::HealthReply(HealthBody { draining, restarts, blocklisted, shards })
        }
        other => return Err(ProtoError::UnknownType(other)),
    };
    cur.done()?;
    Ok((frame, end))
}

/// Read one frame from a blocking reader. `Ok(None)` on clean EOF at a
/// frame boundary; `Truncated` on EOF mid-frame. The length prefix is
/// validated BEFORE the body is read, so an oversized declaration costs
/// the peer 4 bytes of our attention and no allocation.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<(Frame, usize)>, ProtoError> {
    let mut hdr = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut hdr[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(ProtoError::Truncated);
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ProtoError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(hdr);
    if len > MAX_FRAME_LEN {
        return Err(ProtoError::Oversized { len });
    }
    if len == 0 {
        return Err(ProtoError::Malformed("zero-length frame (missing type byte)".into()));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            ProtoError::Truncated
        } else {
            ProtoError::Io(e)
        }
    })?;
    // Reuse the strict slice decoder on [len][body] to keep one code path.
    let mut framed = Vec::with_capacity(4 + body.len());
    framed.extend_from_slice(&hdr);
    framed.extend_from_slice(&body);
    let (frame, consumed) = decode_slice(&framed)?;
    debug_assert_eq!(consumed, framed.len());
    Ok(Some((frame, consumed)))
}
