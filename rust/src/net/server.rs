//! The listener side: accept loop, connection tasks, door-level load
//! shedding, graceful drain.
//!
//! Lock discipline (the pelikan checklist, adapted to std threads):
//! - Counters are relaxed atomics — they carry statistics, not
//!   synchronization; the shutdown snapshot happens after `join()`ing
//!   every thread, and the join edge is what orders the final reads.
//! - The connection gate is a `fetch_add` reservation: increment FIRST,
//!   then compare the value we reserved. Two racing accepts can never
//!   both conclude "there is one slot left" (no TOCTOU) because the RMW
//!   is atomic; an over-limit reservation rolls itself back.
//! - No mutex is held across a blocking socket write: each connection
//!   has ONE writer thread owning the socket's write half, fed by an
//!   mpsc channel of pre-encoded frames. Producers (the reader, the
//!   per-request forwarders) only ever block on the channel, never on
//!   the peer's receive window.

use std::io::Write;
use std::net::{Shutdown, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::{ErrorCode, Event, Outcome, ResponseStream};
use crate::obs::{NetMetrics, Registry};
use crate::server::{Server, ServerReport};

use super::proto::{self, Frame, ProtoError, VERSION};

struct Shared {
    server: Server,
    /// The inner server's telemetry registry — serves `Stats` scrapes
    /// and owns the door's own counter series.
    registry: Arc<Registry>,
    /// The door's live counters: the registry's `net.*` series. Counting
    /// here makes them scrapeable mid-flight; the shutdown report
    /// absorbs the final snapshot as before.
    stats: Arc<NetMetrics>,
    /// Set once by `shutdown`; the accept loop stops and connection
    /// readers refuse new `Submit`s. AcqRel is unnecessary — the flag
    /// gates behavior, it does not publish data.
    draining: AtomicBool,
    /// Connection budget and the live reservation count.
    max_conns: usize,
    active_conns: AtomicUsize,
    /// Armed fault plan (chaos harness): `sockreset` specs fire here, in
    /// the accept loop. `None` on every unconfigured server.
    faults: Option<Arc<crate::faults::FaultPlan>>,
    /// 1-based count of accepted connections, matched against
    /// `sockreset conn=N` sites.
    conns_seen: AtomicUsize,
}

/// A running network front door wrapping an in-process [`Server`].
pub struct NetServer {
    local_addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    accept: JoinHandle<()>,
    /// (join handle, read-half handle for drain wakeup) per connection.
    conns: Arc<Mutex<Vec<(JoinHandle<()>, TcpStream)>>>,
}

impl NetServer {
    /// Bind `addr` (port 0 picks an ephemeral port — read it back from
    /// [`NetServer::local_addr`]) and start accepting. `max_conns` is
    /// the door's connection budget; connection number `max_conns + 1`
    /// is answered with `Error{Busy}` and closed.
    pub fn start<A: ToSocketAddrs>(
        server: Server,
        addr: A,
        max_conns: usize,
    ) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        // Nonblocking accept + short sleep: the loop must notice the
        // drain flag without a signal, and std has no select/poll.
        listener.set_nonblocking(true)?;

        let registry = server.registry();
        let stats = Arc::clone(registry.net());
        let faults = server.fault_plan();
        let shared = Arc::new(Shared {
            server,
            registry,
            stats,
            draining: AtomicBool::new(false),
            max_conns: max_conns.max(1),
            active_conns: AtomicUsize::new(0),
            faults,
            conns_seen: AtomicUsize::new(0),
        });
        let conns: Arc<Mutex<Vec<(JoinHandle<()>, TcpStream)>>> =
            Arc::new(Mutex::new(Vec::new()));

        let accept = {
            let shared = Arc::clone(&shared);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("fastcache-accept".into())
                .spawn(move || accept_loop(&listener, &shared, &conns))
                .expect("spawning accept thread")
        };

        Ok(NetServer { local_addr, shared, accept, conns })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Graceful drain: stop accepting, unblock every connection reader
    /// (in-flight requests keep their lanes and deliver terminal frames),
    /// join everything, drain the inner server, and fold the door
    /// counters into its report.
    pub fn shutdown(self) -> ServerReport {
        self.shared.draining.store(true, Ordering::Relaxed);
        self.accept.join().expect("accept thread panicked");
        // Wake blocked readers: shutting down the read half surfaces EOF,
        // which the connection loop treats exactly like a client close —
        // finish in-flight requests, flush terminal frames, Goodbye.
        let conns = std::mem::take(&mut *self.conns.lock().expect("conn registry poisoned"));
        for (_, stream) in &conns {
            let _ = stream.shutdown(Shutdown::Read);
        }
        for (handle, _) in conns {
            handle.join().expect("connection thread panicked");
        }
        let shared = Arc::try_unwrap(self.shared)
            .unwrap_or_else(|_| panic!("connection threads still hold the server"));
        let stats = shared.stats.snapshot();
        let mut report = shared.server.shutdown();
        report.absorb_net(stats);
        report
    }
}

fn accept_loop(
    listener: &TcpListener,
    shared: &Arc<Shared>,
    conns: &Arc<Mutex<Vec<(JoinHandle<()>, TcpStream)>>>,
) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // Chaos harness: an armed `sockreset conn=N` spec resets
                // the N-th accepted connection before the handshake — the
                // client sees a hard peer failure, not a typed refusal.
                let nth = shared.conns_seen.fetch_add(1, Ordering::Relaxed) + 1;
                if let Some(plan) = &shared.faults {
                    if plan.reset_conn(nth as u64) {
                        let _ = stream.shutdown(Shutdown::Both);
                        continue;
                    }
                }
                // Reservation gate: increment first, compare what we
                // reserved, roll back if over budget — atomic RMW, so
                // two racing accepts cannot both take the last slot.
                let prev = shared.active_conns.fetch_add(1, Ordering::Relaxed);
                if prev >= shared.max_conns {
                    shared.active_conns.fetch_sub(1, Ordering::Relaxed);
                    shared.stats.conns_door_shed.inc();
                    shed_connection(stream, &shared.stats);
                    continue;
                }
                shared.stats.conns_accepted.inc();
                let read_half = match stream.try_clone() {
                    Ok(c) => c,
                    Err(_) => {
                        shared.active_conns.fetch_sub(1, Ordering::Relaxed);
                        continue;
                    }
                };
                let sh = Arc::clone(shared);
                let handle = std::thread::Builder::new()
                    .name("fastcache-conn".into())
                    .spawn(move || {
                        conn_loop(stream, &sh);
                        sh.active_conns.fetch_sub(1, Ordering::Relaxed);
                    })
                    .expect("spawning connection thread");
                let mut reg = conns.lock().expect("conn registry poisoned");
                // Reap finished connections so a long-lived door doesn't
                // accumulate dead handles (dropping a finished JoinHandle
                // just detaches it).
                reg.retain(|(h, _)| !h.is_finished());
                reg.push((handle, read_half));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if shared.draining.load(Ordering::Relaxed) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {
                // Transient accept failure (EMFILE, aborted handshake):
                // keep serving the connections we have.
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

/// Refuse an over-budget connection: one `Busy` frame, then close. The
/// peer never cost us a connection thread.
fn shed_connection(mut stream: TcpStream, stats: &NetMetrics) {
    let buf = proto::encode(&Frame::Error {
        id: 0,
        code: ErrorCode::Busy.code(),
        detail: "connection budget exhausted".into(),
    });
    if stream.write_all(&buf).is_ok() {
        stats.bytes_out.add(buf.len() as u64);
        // FIN our side, then absorb whatever the peer already sent (its
        // Hello, typically). Closing with unread bytes in the receive
        // buffer would RST the connection and flush our Busy frame out
        // of the peer's buffer before it could read the refusal. Bounded
        // by a short timeout so a silent peer cannot stall the accept
        // loop.
        let _ = stream.shutdown(Shutdown::Write);
        let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
        let mut sink = [0u8; 256];
        use std::io::Read;
        let _ = stream.read(&mut sink);
    }
    let _ = stream.shutdown(Shutdown::Both);
}

/// Writer-half plumbing: pre-encoded frames go over this channel to the
/// single thread that owns the socket's write half.
type FrameTx = mpsc::Sender<Vec<u8>>;

fn send_frame(wtx: &FrameTx, frame: &Frame) {
    // A dead writer means the connection is gone; producers just stop.
    let _ = wtx.send(proto::encode(frame));
}

/// One connection: handshake, then a Submit loop. Returns when the peer
/// closes, says Goodbye, breaks framing, or drain wakes us.
fn conn_loop(stream: TcpStream, shared: &Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let write_half = match stream.try_clone() {
        Ok(c) => c,
        Err(_) => return,
    };
    let (wtx, wrx) = mpsc::channel::<Vec<u8>>();
    let writer = {
        let stats_bytes = Arc::clone(shared);
        std::thread::Builder::new()
            .name("fastcache-conn-writer".into())
            .spawn(move || writer_loop(write_half, &wrx, &stats_bytes))
            .expect("spawning connection writer")
    };

    let mut reader = stream;
    run_connection(&mut reader, &wtx, shared);

    // Terminal sequence: everything queued behind the forwarders has
    // been sent (run_connection joins them), so Goodbye is the last
    // frame. Dropping wtx lets the writer drain and exit.
    send_frame(&wtx, &Frame::Goodbye);
    drop(wtx);
    writer.join().expect("connection writer panicked");
    let _ = reader.shutdown(Shutdown::Both);
}

fn writer_loop(mut stream: TcpStream, wrx: &mpsc::Receiver<Vec<u8>>, shared: &Arc<Shared>) {
    while let Ok(buf) = wrx.recv() {
        if stream.write_all(&buf).is_err() {
            // Peer gone: drain the channel so producers never block on a
            // full pipe that will not empty.
            while wrx.recv().is_ok() {}
            return;
        }
        shared.stats.bytes_out.add(buf.len() as u64);
    }
    let _ = stream.flush();
}

fn run_connection(reader: &mut TcpStream, wtx: &FrameTx, shared: &Arc<Shared>) {
    // Handshake: exactly one Hello, version must match exactly.
    match proto::read_frame(reader) {
        Ok(Some((Frame::Hello { version }, n))) => {
            shared.stats.bytes_in.add(n as u64);
            if version != VERSION {
                send_frame(
                    wtx,
                    &Frame::Error {
                        id: 0,
                        code: ErrorCode::BadRequest.code(),
                        detail: format!("unsupported protocol version {version} (want {VERSION})"),
                    },
                );
                return;
            }
            send_frame(wtx, &Frame::HelloAck { version: VERSION });
        }
        Ok(Some((_, _))) | Err(_) => {
            send_frame(
                wtx,
                &Frame::Error {
                    id: 0,
                    code: ErrorCode::BadRequest.code(),
                    detail: "expected Hello".into(),
                },
            );
            return;
        }
        Ok(None) => return,
    }

    // One forwarder per in-flight request; joined before Goodbye so no
    // admitted response can be lost to a racing close.
    let mut forwarders: Vec<JoinHandle<()>> = Vec::new();

    loop {
        match proto::read_frame(reader) {
            Ok(Some((frame, n))) => {
                shared.stats.bytes_in.add(n as u64);
                match frame {
                    Frame::Submit { req, progress } => {
                        if shared.draining.load(Ordering::Relaxed) {
                            send_frame(
                                wtx,
                                &Frame::Error {
                                    id: req.id,
                                    code: ErrorCode::Closed.code(),
                                    detail: "server draining".into(),
                                },
                            );
                            continue;
                        }
                        shared.stats.reqs_submitted.inc();
                        let submitted = if progress {
                            shared.server.submit_streaming(&req)
                        } else {
                            shared.server.submit(&req)
                        };
                        match submitted {
                            Ok(stream) => {
                                let fwtx = wtx.clone();
                                let fsh = Arc::clone(shared);
                                let f = std::thread::Builder::new()
                                    .name("fastcache-forward".into())
                                    .spawn(move || forward(stream, &fwtx, &fsh))
                                    .expect("spawning forwarder");
                                forwarders.push(f);
                            }
                            Err(rej) => {
                                // Door shed: refused before any queue
                                // slot. A deadline-tagged refusal is an
                                // SLA miss (absorbed into the report's
                                // hit-rate denominator at shutdown).
                                if rej.code == ErrorCode::Busy {
                                    shared.stats.reqs_door_shed.inc();
                                    if req.deadline_ms.is_some() {
                                        shared.stats.door_sheds_deadline.inc();
                                    }
                                }
                                send_frame(
                                    wtx,
                                    &Frame::Error {
                                        id: rej.id,
                                        code: rej.code.code(),
                                        detail: rej.detail,
                                    },
                                );
                            }
                        }
                    }
                    // Telemetry scrape: answer from the live registry.
                    // Valid even while draining — operators watching a
                    // drain is precisely when the scrape matters.
                    Frame::Stats => {
                        send_frame(wtx, &Frame::StatsReply(shared.registry.series()));
                    }
                    // Liveness probe: answered even while draining — a
                    // probe that goes dark during drain is indistinguishable
                    // from a wedged server, which defeats its purpose.
                    Frame::Health => {
                        let snap = shared.server.health_snapshot();
                        let shards = snap
                            .states
                            .iter()
                            .enumerate()
                            .map(|(i, s)| (i as u32, *s as u8))
                            .collect();
                        send_frame(
                            wtx,
                            &Frame::HealthReply(proto::HealthBody {
                                draining: shared.draining.load(Ordering::Relaxed),
                                restarts: snap.restarts,
                                blocklisted: snap.blocklisted,
                                shards,
                            }),
                        );
                    }
                    Frame::Goodbye => break,
                    other => {
                        send_frame(
                            wtx,
                            &Frame::Error {
                                id: 0,
                                code: ErrorCode::BadRequest.code(),
                                detail: format!("unexpected frame on request path: {other:?}"),
                            },
                        );
                        break;
                    }
                }
            }
            // Structurally valid frame, semantically bad request: the
            // stream is still well-delimited, so answer and keep going.
            Err(ProtoError::BadRequest(rej)) => {
                send_frame(
                    wtx,
                    &Frame::Error { id: rej.id, code: rej.code.code(), detail: rej.detail },
                );
            }
            // EOF (client closed, or drain shut our read half down).
            Ok(None) => break,
            // Framing is lost (malformed/truncated/oversized/io): answer
            // once, then close — we can no longer find frame boundaries.
            Err(e) => {
                send_frame(
                    wtx,
                    &Frame::Error {
                        id: 0,
                        code: ErrorCode::BadRequest.code(),
                        detail: format!("{e}"),
                    },
                );
                break;
            }
        }
    }

    for f in forwarders {
        f.join().expect("forwarder panicked");
    }
}

/// Pump one request's events into frames: Progress ticks, then exactly
/// one terminal frame (Partial chunks + Completed, or Shed, or Error).
fn forward(stream: ResponseStream, wtx: &FrameTx, shared: &Arc<Shared>) {
    let id = stream.id();
    loop {
        match stream.recv_event() {
            Some(Event::Progress(p)) => send_frame(wtx, &Frame::Progress(p)),
            Some(Event::Done(Outcome::Completed(resp))) => {
                shared.stats.reqs_completed.inc();
                for chunk in proto::partial_frames(id, resp.result.latent.data()) {
                    send_frame(wtx, &chunk);
                }
                send_frame(wtx, &Frame::Completed(proto::Completed::from_response(&resp)));
                return;
            }
            Some(Event::Done(Outcome::Rejected(rej))) => {
                if rej.code == ErrorCode::Expired {
                    shared.stats.reqs_shed.inc();
                    send_frame(
                        wtx,
                        &Frame::Shed {
                            id: rej.id,
                            waited_ms: rej.waited_ms,
                            deadline_ms: rej.deadline_ms,
                        },
                    );
                } else {
                    send_frame(
                        wtx,
                        &Frame::Error { id: rej.id, code: rej.code.code(), detail: rej.detail },
                    );
                }
                return;
            }
            // Channel died without a terminal event (shard panic): the
            // client still deserves a typed terminal frame.
            None => {
                send_frame(
                    wtx,
                    &Frame::Error {
                        id,
                        code: ErrorCode::Closed.code(),
                        detail: "response channel closed before terminal event".into(),
                    },
                );
                return;
            }
        }
    }
}
