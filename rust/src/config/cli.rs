//! Minimal CLI argument parser (clap is not vendored in the offline
//! registry). Supports `--key value`, `--key=value`, boolean `--flag`,
//! and positional arguments, with typed getters and error reporting.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Option names that take a value (everything else starting with `--` is a
/// boolean flag). Kept as an explicit list so typos fail loudly.
const VALUE_OPTS: &[&str] = &[
    "model", "policy", "config", "alpha", "tau-s", "gamma", "steps", "guidance",
    "requests", "max-batch", "queue-depth", "artifacts", "seed", "workers",
    "threads", "knn-k", "merge-target", "motion", "frames", "approx", "fb-rdt",
    "tea-threshold", "l2c-threshold", "static-period", "out", "table",
    "warmup", "iters", "quant", "deadline-every", "deadline-ms",
    "warm-budget-mib", "fit-min-updates", "listen", "net-max-conns", "connect",
    "trace-sample-rate", "trace-out", "stats-every", "fault-plan",
    "degrade-rungs", "warm-snapshot", "retries", "warm-snapshot-every",
    "shard-restart-after", "poison-after", "step-stall-ms",
];

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if VALUE_OPTS.contains(&rest) {
                    let v = iter
                        .next()
                        .ok_or_else(|| format!("option --{rest} expects a value"))?;
                    args.opts.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn parse() -> Result<Args, String> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| format!("--{key} {v}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = args(&["--model", "xl", "--alpha=0.01", "pos1"]);
        assert_eq!(a.get("model"), Some("xl"));
        assert_eq!(a.get("alpha"), Some("0.01"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn flags_are_boolean() {
        let a = args(&["--verbose", "--model", "s"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get("model"), Some("s"));
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse_from(vec!["--model".to_string()]);
        assert!(r.is_err());
    }

    #[test]
    fn typed_getters() {
        let a = args(&["--steps", "25", "--gamma=0.7"]);
        assert_eq!(a.parse_num::<usize>("steps", 50).unwrap(), 25);
        assert!((a.parse_num::<f32>("gamma", 0.5).unwrap() - 0.7).abs() < 1e-6);
        assert_eq!(a.parse_num::<usize>("absent", 7).unwrap(), 7);
        assert!(a.parse_num::<usize>("gamma", 1).is_err());
    }
}
