//! Model-variant table — MUST mirror python/compile/configs.py exactly
//! (the AOT manifest is cross-checked against this at load time).

use std::fmt;

pub const N_TOKENS: usize = 64;
pub const C_IN: usize = 4;
pub const MLP_RATIO: usize = 4;
pub const TOKEN_BUCKETS: [usize; 3] = [16, 32, 64];
pub const BATCH_SIZES: [usize; 2] = [1, 4];

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Variant {
    S,
    B,
    L,
    Xl,
}

impl Variant {
    pub const ALL: [Variant; 4] = [Variant::S, Variant::B, Variant::L, Variant::Xl];

    pub fn key(self) -> &'static str {
        match self {
            Variant::S => "s",
            Variant::B => "b",
            Variant::L => "l",
            Variant::Xl => "xl",
        }
    }

    /// Paper-facing name.
    pub fn paper_name(self) -> &'static str {
        match self {
            Variant::S => "DiT-S/2",
            Variant::B => "DiT-B/2",
            Variant::L => "DiT-L/2",
            Variant::Xl => "DiT-XL/2",
        }
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s.to_ascii_lowercase().as_str() {
            "s" | "dit-s" | "dit-s/2" => Some(Variant::S),
            "b" | "dit-b" | "dit-b/2" => Some(Variant::B),
            "l" | "dit-l" | "dit-l/2" => Some(Variant::L),
            "xl" | "dit-xl" | "dit-xl/2" => Some(Variant::Xl),
            _ => None,
        }
    }
}

impl fmt::Display for Variant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.key())
    }
}

#[derive(Clone, Copy, Debug)]
pub struct ModelConfig {
    pub variant: Variant,
    pub layers: usize,
    pub d: usize,
    pub heads: usize,
    pub n_tokens: usize,
    pub c_in: usize,
}

impl ModelConfig {
    pub fn of(variant: Variant) -> ModelConfig {
        let (layers, d, heads) = match variant {
            Variant::S => (3, 96, 3),
            Variant::B => (6, 192, 6),
            Variant::L => (12, 256, 8),
            Variant::Xl => (14, 288, 9),
        };
        ModelConfig { variant, layers, d, heads, n_tokens: N_TOKENS, c_in: C_IN }
    }

    pub fn head_dim(&self) -> usize {
        self.d / self.heads
    }

    /// N·D — the χ² degrees of freedom of the cache test at full tokens.
    pub fn nd(&self) -> usize {
        self.n_tokens * self.d
    }

    /// Approximate parameter count (for reporting).
    pub fn param_count(&self) -> usize {
        let d = self.d;
        let per_block = d * 3 * d + 3 * d   // qkv
            + d * d + d                     // proj
            + d * MLP_RATIO * d + MLP_RATIO * d
            + MLP_RATIO * d * d + d
            + d * 6 * d + 6 * d; // adaLN mod
        let temb = 2 * d * d + 2 * d;
        let final_l = d * 2 * d + 2 * d + d * C_IN + C_IN;
        let embed = C_IN * d + d;
        self.layers * per_block + temb + final_l + embed
    }

    /// FLOPs of one full block forward at `n` tokens (2·mults convention).
    pub fn block_flops(&self, n: usize) -> u64 {
        let d = self.d as u64;
        let n = n as u64;
        let qkv = 2 * n * d * 3 * d;
        let attn = 2 * 2 * self.heads as u64 * n * n * self.head_dim() as u64;
        let proj = 2 * n * d * d;
        let mlp = 2 * 2 * n * d * MLP_RATIO as u64 * d;
        let moddot = 2 * d * 6 * d;
        qkv + attn + proj + mlp + moddot
    }

    /// FLOPs of one full-compute denoise step at full tokens (all layers,
    /// no caching) — the unit the serving dispatcher quotes predicted
    /// load in. Single source of truth for both queued-job pricing
    /// (`server::dispatch`) and active-lane extrapolation
    /// (`Lane::remaining_flops_estimate`); the two are summed, so they
    /// must stay unit-consistent.
    pub fn full_step_flops(&self) -> u64 {
        self.layers as u64 * self.block_flops(self.n_tokens)
    }

    /// FLOPs of the linear approximation at `n` tokens (diag-affine native
    /// path is O(nd); the full-matrix HLO path is 2·n·d²).
    pub fn approx_flops(&self, n: usize, full_matrix: bool) -> u64 {
        let d = self.d as u64;
        let n = n as u64;
        if full_matrix {
            2 * n * d * d
        } else {
            2 * n * d
        }
    }
}

/// Pick the smallest token bucket that holds `n` tokens.
pub fn token_bucket(n: usize) -> usize {
    for &b in TOKEN_BUCKETS.iter() {
        if n <= b {
            return b;
        }
    }
    *TOKEN_BUCKETS.last().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_matches_python_configs() {
        let s = ModelConfig::of(Variant::S);
        assert_eq!((s.layers, s.d, s.heads), (3, 96, 3));
        let b = ModelConfig::of(Variant::B);
        assert_eq!((b.layers, b.d, b.heads), (6, 192, 6));
        let l = ModelConfig::of(Variant::L);
        assert_eq!((l.layers, l.d, l.heads), (12, 256, 8));
        let xl = ModelConfig::of(Variant::Xl);
        assert_eq!((xl.layers, xl.d, xl.heads), (14, 288, 9));
    }

    #[test]
    fn head_dim_uniform_32() {
        for v in Variant::ALL {
            assert_eq!(ModelConfig::of(v).head_dim(), 32, "{v}");
        }
    }

    #[test]
    fn variant_parse_roundtrip() {
        for v in Variant::ALL {
            assert_eq!(Variant::parse(v.key()), Some(v));
            assert_eq!(Variant::parse(v.paper_name()), Some(v));
        }
        assert_eq!(Variant::parse("nope"), None);
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(token_bucket(1), 16);
        assert_eq!(token_bucket(16), 16);
        assert_eq!(token_bucket(17), 32);
        assert_eq!(token_bucket(64), 64);
        assert_eq!(token_bucket(999), 64);
    }

    #[test]
    fn params_scale_with_variant() {
        let mut prev = 0;
        for v in Variant::ALL {
            let p = ModelConfig::of(v).param_count();
            assert!(p > prev, "{v}: {p} <= {prev}");
            prev = p;
        }
    }

    #[test]
    fn flops_monotone_in_tokens() {
        let cfg = ModelConfig::of(Variant::B);
        assert!(cfg.block_flops(64) > cfg.block_flops(32));
        assert!(cfg.block_flops(32) > cfg.block_flops(16));
        assert!(cfg.approx_flops(64, true) > cfg.approx_flops(64, false));
    }
}
