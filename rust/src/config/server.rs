//! Serving-side configuration: batcher, queue, scheduler knobs.

use super::model::Variant;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Model variant served by this worker.
    pub variant: Variant,
    /// Maximum number of concurrently active lanes in the worker (the
    /// continuous-batching window). Full-token Compute sites are batched
    /// through the compiled B=4 block artifact in chunks of 4, so this is
    /// not capped at 4; multiples of 4 chunk with no padded slots when
    /// the active set is full.
    pub max_batch: usize,
    /// Bounded request-queue depth; admission fails beyond this
    /// (backpressure to the client).
    pub queue_depth: usize,
    /// Denoising steps per request (paper default 50).
    pub steps: usize,
    /// Classifier-free-guidance scale (paper default 7.5).
    pub guidance: f32,
    /// Number of worker threads (1-core CPU default 1; kept configurable
    /// for multi-core hosts).
    pub workers: usize,
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
    /// Base seed for weight generation (fixed => reproducible serving).
    pub weight_seed: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            variant: Variant::S,
            max_batch: 4,
            queue_depth: 64,
            steps: 50,
            guidance: 7.5,
            workers: 1,
            artifacts_dir: "artifacts".to_string(),
            weight_seed: 0xD17,
        }
    }
}

impl ServerConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.max_batch == 0 || self.max_batch > 16 {
            return Err(format!(
                "max_batch must be 1..=16 (active lanes; compute chunks through the B=4 artifact), got {}",
                self.max_batch
            ));
        }
        if self.steps == 0 {
            return Err("steps must be >= 1".into());
        }
        if self.queue_depth == 0 {
            return Err("queue_depth must be >= 1".into());
        }
        if self.workers == 0 {
            return Err("workers must be >= 1".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        assert!(ServerConfig::default().validate().is_ok());
    }

    #[test]
    fn rejects_oversized_batch() {
        let mut c = ServerConfig::default();
        c.max_batch = 8; // > 4 lanes is fine now: compute chunks via B=4
        assert!(c.validate().is_ok());
        c.max_batch = 32;
        assert!(c.validate().is_err());
        c.max_batch = 0;
        assert!(c.validate().is_err());
    }
}
