//! Serving-side configuration: batcher, queue, scheduler, and shard knobs.

use super::model::Variant;

/// Upper bound on worker shards. Each shard owns a full model instance
/// (and, in HLO mode, its own device weight uploads), so the useful range
/// is bounded by physical cores and memory — far below this cap.
pub const MAX_WORKERS: usize = 8;

/// Upper bound on the network door's connection budget. The door is
/// thread-per-connection, so the real ceiling is what the host tolerates
/// in mostly-idle threads; this static bound keeps configs sane.
pub const MAX_NET_CONNS: usize = 4096;

/// Upper bound on intra-op kernel threads per shard. The real ceiling is
/// physical cores — [`ServerConfig::effective_threads`] clamps
/// `workers × threads` to the host's parallelism at shard startup — so
/// this static bound only keeps configs sane and host-independent
/// (`validate()` must give the same verdict on CI and on a laptop).
pub const MAX_THREADS: usize = 8;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Model variant served by this worker.
    pub variant: Variant,
    /// Maximum number of concurrently active lanes PER SHARD (each worker
    /// thread owns its own active set, so total in-flight concurrency is
    /// `workers × max_batch`). Full-token Compute sites are batched
    /// through the compiled B=4 block artifact in chunks of 4, so this is
    /// not capped at 4; multiples of 4 chunk with no padded slots when
    /// the active set is full.
    pub max_batch: usize,
    /// Bounded request-queue depth ACROSS the server; admission fails
    /// beyond this (backpressure to the client). Split evenly over the
    /// shards (`max(1, queue_depth / workers)` slots each).
    pub queue_depth: usize,
    /// Denoising steps per request (paper default 50).
    pub steps: usize,
    /// Classifier-free-guidance scale (paper default 7.5).
    pub guidance: f32,
    /// Worker shards. Each spawns a thread owning its own `LaneStepper`
    /// and active lane set; the dispatcher routes jobs to the shard with
    /// the least predicted remaining FLOPs. Throughput scales with
    /// physical cores — on a single-core host extra shards only add
    /// scheduling overhead and shrink per-shard batches.
    pub workers: usize,
    /// Intra-op kernel threads PER SHARD: the native kernels split each
    /// block's token dimension across this many scoped workers
    /// (bit-identical to serial — see rust/tests/threaded_parity.rs).
    /// Complements `workers`: shards scale across requests, intra-op
    /// threads make ONE request saturate idle cores when batch occupancy
    /// is low. Total demand is `workers × threads`, clamped to the
    /// host's cores at shard startup via
    /// [`ServerConfig::effective_threads`].
    pub threads: usize,
    /// Serve the four big matmuls of every block from int8 panels
    /// (per-NR-tile symmetric scales, i32 accumulation, fused f32
    /// dequant). Default OFF: the f32 path is byte-for-byte untouched
    /// unless this opts in. Quality cost is measured by the
    /// `block_int8` row of `bench_tables kernels`.
    pub int8: bool,
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
    /// Base seed for weight generation (fixed => reproducible serving).
    pub weight_seed: u64,
    /// Byte budget of the cross-request warm-start store (split across
    /// its shards; LRU-evicted beyond it). Only consulted when
    /// `FastCacheConfig::warm_start` is on — the store is not built
    /// otherwise.
    pub warm_budget_bytes: usize,
    /// Network front door: bind address for the framed-socket listener
    /// (`--listen 127.0.0.1:7433`, port 0 for ephemeral). `None` (the
    /// default) serves in-process only — no socket is ever opened.
    pub listen: Option<String>,
    /// Connection budget for the network door; connection
    /// `net_max_conns + 1` is refused with a `Busy` frame before it
    /// costs a thread.
    pub net_max_conns: usize,
    /// Flight-recorder sampling: the fraction of lanes (deterministic,
    /// by request-id hash) whose per-(step, layer) cache decisions, STR
    /// partitions, and stage timings are recorded as trace events.
    /// 0.0 (the default) disables the recorder entirely — served latents
    /// are bit-identical to a build without it; 1.0 traces every lane.
    pub trace_sample_rate: f64,
    /// Where `fastcache-serve` dumps the recorded trace at drain:
    /// a `.json` suffix selects Chrome `trace_event` format (load in
    /// `chrome://tracing` / Perfetto), anything else NDJSON. `None`
    /// keeps the ring in memory only.
    pub trace_out: Option<String>,
    /// Period (seconds) for printing a registry scrape to stderr while
    /// serving. 0.0 (the default) disables the ticker.
    pub stats_every: f64,
    /// Deterministic fault-injection plan (`--fault-plan` / `[faults]
    /// plan`), e.g. `"panic step=2 layer=1 req=3; popdelay ms=40"`. `None`
    /// (the default) compiles the chaos harness out of every hot path —
    /// serving is bit-identical to a plan-free build. Grammar:
    /// `crate::faults::FaultPlan::parse`.
    pub fault_plan: Option<String>,
    /// Degrade-instead-of-drop: when a deadline-tagged lane is predicted
    /// to miss its budget, walk the degrade ladder (relax the cache
    /// threshold → tighten the STR keep-ratio → truncate remaining steps)
    /// before ever shedding it. Default OFF; best-effort lanes are never
    /// touched either way.
    pub degrade: bool,
    /// How many ladder rungs a lane may descend (1..=3). Only consulted
    /// when `degrade` is on.
    pub degrade_rungs: usize,
    /// Warm-store snapshot path: loaded (checksummed; corruption degrades
    /// to a cold store) before serving and saved at drain. `None` (the
    /// default) means the store lives and dies with the process.
    pub warm_snapshot: Option<String>,
    /// Period (seconds) for PERIODIC warm-store snapshots from a ticker
    /// thread (atomic tmp-file + rename, so a crash mid-write can never
    /// corrupt the last good snapshot). Requires `warm_snapshot`. 0.0
    /// (the default) keeps the at-drain-only behavior.
    pub warm_snapshot_every: f64,
    /// Supervisor flap control: tear a shard down and restart it cleanly
    /// (fresh stepper + arena, survivors solo-replayed at their exact
    /// step indices) once its quarantine count inside the sliding flap
    /// window reaches this threshold. 0 (the default) disables
    /// supervised restarts — quarantine behavior is exactly PR-9's.
    pub shard_restart_after: usize,
    /// Poisoned-request blocklist: a request id whose lane triggers this
    /// many TYPED quarantines is refused at admission (in-process and at
    /// the net door) with `ErrorCode::Poisoned`. 0 (the default)
    /// disables the blocklist.
    pub poison_after: usize,
    /// Stuck-step watchdog: a shard with active lanes whose step
    /// heartbeat hasn't advanced for this many milliseconds is marked
    /// unhealthy, its queue is shed honestly (sheds count as SLA
    /// misses), and a supervised restart is requested. 0 (the default)
    /// disables the watchdog thread entirely.
    pub step_stall_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        // `workers: 1` is the conservative default for any host — sharding
        // is opt-in via `--workers`/`server.workers` where cores exist.
        ServerConfig {
            variant: Variant::S,
            max_batch: 4,
            queue_depth: 64,
            steps: 50,
            guidance: 7.5,
            workers: 1,
            threads: 1,
            int8: false,
            artifacts_dir: "artifacts".to_string(),
            weight_seed: 0xD17,
            warm_budget_bytes: 8 << 20,
            listen: None,
            net_max_conns: 64,
            trace_sample_rate: 0.0,
            trace_out: None,
            stats_every: 0.0,
            fault_plan: None,
            degrade: false,
            degrade_rungs: 3,
            warm_snapshot: None,
            warm_snapshot_every: 0.0,
            shard_restart_after: 0,
            poison_after: 0,
            step_stall_ms: 0,
        }
    }
}

impl ServerConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.max_batch == 0 || self.max_batch > 16 {
            return Err(format!(
                "max_batch must be 1..=16 (active lanes PER SHARD; compute chunks through the B=4 artifact), got {}",
                self.max_batch
            ));
        }
        if self.steps == 0 {
            return Err("steps must be >= 1".into());
        }
        if self.queue_depth == 0 {
            return Err("queue_depth must be >= 1".into());
        }
        if self.workers == 0 || self.workers > MAX_WORKERS {
            return Err(format!(
                "workers must be 1..={MAX_WORKERS} (each shard owns a model copy and an active set of max_batch lanes), got {}",
                self.workers
            ));
        }
        if self.queue_depth < self.workers {
            return Err(format!(
                "queue_depth {} < workers {} — each shard needs at least one queue slot (queue_depth is split across shards)",
                self.queue_depth, self.workers
            ));
        }
        if self.threads == 0 || self.threads > MAX_THREADS {
            return Err(format!(
                "threads must be 1..={MAX_THREADS} (intra-op kernel threads per shard; workers × threads is clamped to the host's cores at startup), got {}",
                self.threads
            ));
        }
        if self.warm_budget_bytes < 1024 {
            return Err(format!(
                "warm_budget_bytes must be >= 1 KiB (one store entry is a per-layer fit of several KiB), got {}",
                self.warm_budget_bytes
            ));
        }
        if self.net_max_conns == 0 || self.net_max_conns > MAX_NET_CONNS {
            return Err(format!(
                "net_max_conns must be 1..={MAX_NET_CONNS} (thread-per-connection door budget), got {}",
                self.net_max_conns
            ));
        }
        if !self.trace_sample_rate.is_finite()
            || !(0.0..=1.0).contains(&self.trace_sample_rate)
        {
            return Err(format!(
                "trace_sample_rate must be a finite fraction in 0.0..=1.0 (0 disables the flight recorder), got {}",
                self.trace_sample_rate
            ));
        }
        if !self.stats_every.is_finite() || self.stats_every < 0.0 {
            return Err(format!(
                "stats_every must be a finite period in seconds >= 0 (0 disables the ticker), got {}",
                self.stats_every
            ));
        }
        if let Some(plan) = &self.fault_plan {
            crate::faults::FaultPlan::parse(plan)
                .map_err(|e| format!("fault_plan: {e}"))?;
        }
        if self.degrade_rungs == 0 || self.degrade_rungs > 3 {
            return Err(format!(
                "degrade_rungs must be 1..=3 (relax cache -> tighten STR -> truncate steps), got {}",
                self.degrade_rungs
            ));
        }
        if let Some(path) = &self.warm_snapshot {
            if path.is_empty() {
                return Err("warm_snapshot must be a non-empty path".into());
            }
        }
        if !self.warm_snapshot_every.is_finite() || self.warm_snapshot_every < 0.0 {
            return Err(format!(
                "warm_snapshot_every must be a finite period in seconds >= 0 (0 disables the ticker), got {}",
                self.warm_snapshot_every
            ));
        }
        if self.warm_snapshot_every > 0.0 && self.warm_snapshot.is_none() {
            return Err(
                "warm_snapshot_every requires warm_snapshot (a path to snapshot to)".into()
            );
        }
        if self.step_stall_ms > 0 && self.step_stall_ms < 10 {
            return Err(format!(
                "step_stall_ms must be 0 (watchdog off) or >= 10 ms (sub-10ms budgets flag healthy steps as stalls), got {}",
                self.step_stall_ms
            ));
        }
        Ok(())
    }

    /// The intra-op thread count a shard should actually use: the
    /// configured `threads`, capped so `workers × threads` never exceeds
    /// the host's available parallelism (and never below 1). Runtime
    /// clamp rather than a `validate()` error so the same config file
    /// works on CI runners and many-core hosts alike — oversubscribed
    /// configs degrade to fewer threads instead of failing or thrashing.
    pub fn effective_threads(&self) -> usize {
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        self.effective_threads_on(cores)
    }

    /// Core-count-injected form of [`ServerConfig::effective_threads`]
    /// (testable on any host).
    pub fn effective_threads_on(&self, cores: usize) -> usize {
        (cores / self.workers.max(1)).clamp(1, self.threads.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        assert!(ServerConfig::default().validate().is_ok());
    }

    #[test]
    fn rejects_oversized_batch() {
        let mut c = ServerConfig { max_batch: 8, ..ServerConfig::default() };
        // > 4 lanes is fine now: compute chunks via B=4.
        assert!(c.validate().is_ok());
        c.max_batch = 32;
        assert!(c.validate().is_err());
        c.max_batch = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_nonsense_worker_counts() {
        let mut c = ServerConfig { workers: MAX_WORKERS, ..ServerConfig::default() };
        assert!(c.validate().is_ok());
        c.workers = 0;
        assert!(c.validate().is_err());
        c.workers = MAX_WORKERS + 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_nonsense_thread_counts() {
        let mut c = ServerConfig { threads: MAX_THREADS, ..ServerConfig::default() };
        assert!(c.validate().is_ok());
        c.threads = 0;
        assert!(c.validate().is_err());
        c.threads = MAX_THREADS + 1;
        let err = c.validate().unwrap_err();
        assert!(err.contains("intra-op"), "unexpected message: {err}");
    }

    #[test]
    fn effective_threads_caps_shards_times_threads_to_cores() {
        let c = ServerConfig { workers: 2, threads: 4, ..ServerConfig::default() };
        assert_eq!(c.effective_threads_on(8), 4); // fits exactly
        assert_eq!(c.effective_threads_on(4), 2); // halved to fit
        assert_eq!(c.effective_threads_on(1), 1); // never below 1
        let solo = ServerConfig { workers: 1, threads: 3, ..ServerConfig::default() };
        assert_eq!(solo.effective_threads_on(16), 3); // config is the cap
        assert_eq!(solo.effective_threads_on(2), 2);
        // And the live probe agrees with some injected core count >= 1.
        let live = c.effective_threads();
        assert!((1..=c.threads).contains(&live));
    }

    #[test]
    fn int8_defaults_off() {
        assert!(!ServerConfig::default().int8);
        let c = ServerConfig { int8: true, ..ServerConfig::default() };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn rejects_degenerate_warm_budget() {
        let c = ServerConfig { warm_budget_bytes: 100, ..ServerConfig::default() };
        assert!(c.validate().is_err());
        let c = ServerConfig { warm_budget_bytes: 1024, ..ServerConfig::default() };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn rejects_nonsense_net_conn_budgets() {
        assert_eq!(ServerConfig::default().listen, None, "no socket unless asked");
        let c = ServerConfig { net_max_conns: 0, ..ServerConfig::default() };
        assert!(c.validate().is_err());
        let c = ServerConfig { net_max_conns: MAX_NET_CONNS + 1, ..ServerConfig::default() };
        let err = c.validate().unwrap_err();
        assert!(err.contains("net_max_conns"), "unexpected message: {err}");
        let c = ServerConfig {
            listen: Some("127.0.0.1:0".into()),
            net_max_conns: 2,
            ..ServerConfig::default()
        };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn rejects_nonsense_observability_knobs() {
        let d = ServerConfig::default();
        assert_eq!(d.trace_sample_rate, 0.0, "recorder must default OFF");
        assert_eq!(d.trace_out, None);
        assert_eq!(d.stats_every, 0.0, "stats ticker must default OFF");
        for bad in [-0.1, 1.1, f64::NAN, f64::INFINITY] {
            let c = ServerConfig { trace_sample_rate: bad, ..ServerConfig::default() };
            assert!(c.validate().is_err(), "trace_sample_rate {bad} must be rejected");
        }
        let c = ServerConfig { trace_sample_rate: 1.0, ..ServerConfig::default() };
        assert!(c.validate().is_ok());
        for bad in [-1.0, f64::NAN, f64::NEG_INFINITY] {
            let c = ServerConfig { stats_every: bad, ..ServerConfig::default() };
            assert!(c.validate().is_err(), "stats_every {bad} must be rejected");
        }
        let c = ServerConfig { stats_every: 2.5, ..ServerConfig::default() };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn robustness_knobs_default_off_and_are_validated() {
        let d = ServerConfig::default();
        assert_eq!(d.fault_plan, None, "faults must default OFF");
        assert!(!d.degrade, "degrade ladder must default OFF");
        assert_eq!(d.degrade_rungs, 3);
        assert_eq!(d.warm_snapshot, None, "no snapshot I/O unless asked");

        let c = ServerConfig {
            fault_plan: Some("panic step=2 layer=1 req=3; popdelay ms=40".into()),
            ..ServerConfig::default()
        };
        assert!(c.validate().is_ok());
        let bad = ServerConfig { fault_plan: Some("panic layer=1".into()), ..ServerConfig::default() };
        let err = bad.validate().unwrap_err();
        assert!(err.contains("fault_plan"), "unexpected message: {err}");

        for rungs in [0usize, 4] {
            let c = ServerConfig { degrade_rungs: rungs, ..ServerConfig::default() };
            assert!(c.validate().is_err(), "degrade_rungs {rungs} must be rejected");
        }
        let c = ServerConfig { degrade: true, degrade_rungs: 1, ..ServerConfig::default() };
        assert!(c.validate().is_ok());

        let c = ServerConfig { warm_snapshot: Some(String::new()), ..ServerConfig::default() };
        assert!(c.validate().is_err());
        let c = ServerConfig { warm_snapshot: Some("/tmp/warm.fcws".into()), ..ServerConfig::default() };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn supervisor_knobs_default_off_and_are_validated() {
        let d = ServerConfig::default();
        assert_eq!(d.shard_restart_after, 0, "supervised restarts must default OFF");
        assert_eq!(d.poison_after, 0, "blocklist must default OFF");
        assert_eq!(d.step_stall_ms, 0, "watchdog must default OFF");
        assert_eq!(d.warm_snapshot_every, 0.0, "periodic snapshots must default OFF");

        let c = ServerConfig {
            shard_restart_after: 2,
            poison_after: 1,
            step_stall_ms: 250,
            ..ServerConfig::default()
        };
        assert!(c.validate().is_ok());

        let c = ServerConfig { step_stall_ms: 5, ..ServerConfig::default() };
        let err = c.validate().unwrap_err();
        assert!(err.contains("step_stall_ms"), "unexpected message: {err}");

        // Periodic snapshots need a path to snapshot to.
        let c = ServerConfig { warm_snapshot_every: 5.0, ..ServerConfig::default() };
        let err = c.validate().unwrap_err();
        assert!(err.contains("warm_snapshot"), "unexpected message: {err}");
        let c = ServerConfig {
            warm_snapshot: Some("/tmp/warm.fcws".into()),
            warm_snapshot_every: 5.0,
            ..ServerConfig::default()
        };
        assert!(c.validate().is_ok());
        for bad in [-1.0, f64::NAN, f64::INFINITY] {
            let c = ServerConfig {
                warm_snapshot: Some("/tmp/warm.fcws".into()),
                warm_snapshot_every: bad,
                ..ServerConfig::default()
            };
            assert!(c.validate().is_err(), "warm_snapshot_every {bad} must be rejected");
        }
    }

    #[test]
    fn rejects_queue_shallower_than_shard_count() {
        let c = ServerConfig { workers: 4, queue_depth: 3, ..ServerConfig::default() };
        let err = c.validate().unwrap_err();
        assert!(err.contains("queue slot"), "unexpected message: {err}");
        let ok = ServerConfig { workers: 4, queue_depth: 4, ..ServerConfig::default() };
        assert!(ok.validate().is_ok());
    }
}
