//! Serving-side configuration: batcher, queue, scheduler, and shard knobs.

use super::model::Variant;

/// Upper bound on worker shards. Each shard owns a full model instance
/// (and, in HLO mode, its own device weight uploads), so the useful range
/// is bounded by physical cores and memory — far below this cap.
pub const MAX_WORKERS: usize = 8;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Model variant served by this worker.
    pub variant: Variant,
    /// Maximum number of concurrently active lanes PER SHARD (each worker
    /// thread owns its own active set, so total in-flight concurrency is
    /// `workers × max_batch`). Full-token Compute sites are batched
    /// through the compiled B=4 block artifact in chunks of 4, so this is
    /// not capped at 4; multiples of 4 chunk with no padded slots when
    /// the active set is full.
    pub max_batch: usize,
    /// Bounded request-queue depth ACROSS the server; admission fails
    /// beyond this (backpressure to the client). Split evenly over the
    /// shards (`max(1, queue_depth / workers)` slots each).
    pub queue_depth: usize,
    /// Denoising steps per request (paper default 50).
    pub steps: usize,
    /// Classifier-free-guidance scale (paper default 7.5).
    pub guidance: f32,
    /// Worker shards. Each spawns a thread owning its own `LaneStepper`
    /// and active lane set; the dispatcher routes jobs to the shard with
    /// the least predicted remaining FLOPs. Throughput scales with
    /// physical cores — on a single-core host extra shards only add
    /// scheduling overhead and shrink per-shard batches.
    pub workers: usize,
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
    /// Base seed for weight generation (fixed => reproducible serving).
    pub weight_seed: u64,
    /// Byte budget of the cross-request warm-start store (split across
    /// its shards; LRU-evicted beyond it). Only consulted when
    /// `FastCacheConfig::warm_start` is on — the store is not built
    /// otherwise.
    pub warm_budget_bytes: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        // `workers: 1` is the conservative default for any host — sharding
        // is opt-in via `--workers`/`server.workers` where cores exist.
        ServerConfig {
            variant: Variant::S,
            max_batch: 4,
            queue_depth: 64,
            steps: 50,
            guidance: 7.5,
            workers: 1,
            artifacts_dir: "artifacts".to_string(),
            weight_seed: 0xD17,
            warm_budget_bytes: 8 << 20,
        }
    }
}

impl ServerConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.max_batch == 0 || self.max_batch > 16 {
            return Err(format!(
                "max_batch must be 1..=16 (active lanes PER SHARD; compute chunks through the B=4 artifact), got {}",
                self.max_batch
            ));
        }
        if self.steps == 0 {
            return Err("steps must be >= 1".into());
        }
        if self.queue_depth == 0 {
            return Err("queue_depth must be >= 1".into());
        }
        if self.workers == 0 || self.workers > MAX_WORKERS {
            return Err(format!(
                "workers must be 1..={MAX_WORKERS} (each shard owns a model copy and an active set of max_batch lanes), got {}",
                self.workers
            ));
        }
        if self.queue_depth < self.workers {
            return Err(format!(
                "queue_depth {} < workers {} — each shard needs at least one queue slot (queue_depth is split across shards)",
                self.queue_depth, self.workers
            ));
        }
        if self.warm_budget_bytes < 1024 {
            return Err(format!(
                "warm_budget_bytes must be >= 1 KiB (one store entry is a per-layer fit of several KiB), got {}",
                self.warm_budget_bytes
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        assert!(ServerConfig::default().validate().is_ok());
    }

    #[test]
    fn rejects_oversized_batch() {
        let mut c = ServerConfig { max_batch: 8, ..ServerConfig::default() };
        // > 4 lanes is fine now: compute chunks via B=4.
        assert!(c.validate().is_ok());
        c.max_batch = 32;
        assert!(c.validate().is_err());
        c.max_batch = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_nonsense_worker_counts() {
        let mut c = ServerConfig { workers: MAX_WORKERS, ..ServerConfig::default() };
        assert!(c.validate().is_ok());
        c.workers = 0;
        assert!(c.validate().is_err());
        c.workers = MAX_WORKERS + 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_degenerate_warm_budget() {
        let c = ServerConfig { warm_budget_bytes: 100, ..ServerConfig::default() };
        assert!(c.validate().is_err());
        let c = ServerConfig { warm_budget_bytes: 1024, ..ServerConfig::default() };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn rejects_queue_shallower_than_shard_count() {
        let c = ServerConfig { workers: 4, queue_depth: 3, ..ServerConfig::default() };
        let err = c.validate().unwrap_err();
        assert!(err.contains("queue slot"), "unexpected message: {err}");
        let ok = ServerConfig { workers: 4, queue_depth: 4, ..ServerConfig::default() };
        assert!(ok.validate().is_ok());
    }
}
