//! FastCache + baseline cache-policy configuration (the knobs of §5 and
//! Appendix E of the paper, all sweepable from the CLI and the benches).

use std::fmt;

/// Which cache policy the engine runs. Each maps to a `CachePolicy` impl in
/// `crate::cache` and, for the baselines, to the corresponding row label of
/// the paper's tables. (`Hash`: policies key warm-start store entries.)
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum PolicyKind {
    /// Full computation, no reuse — the paper's "No Cache" row.
    NoCache,
    /// The paper's contribution: χ²-gated reuse + learnable linear approx.
    FastCache,
    /// First-block cache (FBCache / ParaAttention-style): the first block's
    /// relative change gates reuse of the whole remaining stack.
    FbCache,
    /// TeaCache: timestep-embedding-modulated accumulated change gate.
    TeaCache,
    /// AdaCache: content-similarity-scheduled reuse rate.
    AdaCache,
    /// Learning-to-Cache: static learned per-(step, layer) skip schedule.
    L2C,
    /// PAB-style fixed-frequency reuse (every k-th step recomputes).
    StaticCache,
}

impl PolicyKind {
    pub const ALL: [PolicyKind; 7] = [
        PolicyKind::NoCache,
        PolicyKind::FastCache,
        PolicyKind::FbCache,
        PolicyKind::TeaCache,
        PolicyKind::AdaCache,
        PolicyKind::L2C,
        PolicyKind::StaticCache,
    ];

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::NoCache => "nocache",
            PolicyKind::FastCache => "fastcache",
            PolicyKind::FbCache => "fbcache",
            PolicyKind::TeaCache => "teacache",
            PolicyKind::AdaCache => "adacache",
            PolicyKind::L2C => "l2c",
            PolicyKind::StaticCache => "static",
        }
    }

    pub fn paper_name(self) -> &'static str {
        match self {
            PolicyKind::NoCache => "No Cache",
            PolicyKind::FastCache => "FastCache (Ours)",
            PolicyKind::FbCache => "FBCache",
            PolicyKind::TeaCache => "TeaCache",
            PolicyKind::AdaCache => "AdaCache",
            PolicyKind::L2C => "Learning-to-Cache",
            PolicyKind::StaticCache => "PAB-Static",
        }
    }

    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "nocache" | "none" | "no-cache" => Some(PolicyKind::NoCache),
            "fastcache" | "fast" => Some(PolicyKind::FastCache),
            "fbcache" | "fb" => Some(PolicyKind::FbCache),
            "teacache" | "tea" => Some(PolicyKind::TeaCache),
            "adacache" | "ada" => Some(PolicyKind::AdaCache),
            "l2c" | "learning-to-cache" => Some(PolicyKind::L2C),
            "static" | "pab" => Some(PolicyKind::StaticCache),
            _ => None,
        }
    }
}

impl fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// How a skipped block's output is approximated.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ApproxMode {
    /// Reuse the cached output verbatim (what FBCache/TeaCache/... do).
    Reuse,
    /// Online per-channel learnable affine fit (FastCache default).
    DiagAffine,
    /// Full D×D matmul through the AOT linear_approx artifact.
    FullMatrix,
}

/// FastCache knobs (paper §5.2 defaults).
#[derive(Clone, Debug)]
pub struct FastCacheConfig {
    pub policy: PolicyKind,
    /// Significance level α of the χ² test (paper: 0.05).
    pub alpha: f64,
    /// Noise-floor relative change δ₀ scaling the χ² rule (see
    /// cache::decision — the paper's literal rule degenerates at serving
    /// sizes; δ₀ is the sliding-window scale it implies).
    pub tau_delta0: f64,
    /// Spatial saliency threshold τ_s for motion/static partition
    /// (paper table 6 sweeps 0.02–0.05; saliency is normalized per-token
    /// mean squared change, see tokens::partition).
    pub tau_s: f64,
    /// Motion-aware blending factor γ (paper: 0.5). 1.0 = pure approx.
    pub gamma: f32,
    /// Spatial token reduction module on/off (ablation STR).
    pub enable_str: bool,
    /// Statistical caching module on/off (ablation SC).
    pub enable_sc: bool,
    /// Motion-aware blending on/off (ablation MB).
    pub enable_mb: bool,
    /// Token merging (Appendix D) on/off, and its kNN K / λ.
    pub enable_merge: bool,
    pub knn_k: usize,
    pub merge_lambda: f32,
    /// Target merged token count (bucketized).
    pub merge_target: usize,
    /// How skipped blocks are approximated.
    pub approx: ApproxMode,
    /// Forgetting factor for the online affine fit.
    pub fit_decay: f64,
    /// FBCache relative-delta threshold (their `rdt` knob, table 6).
    pub fb_rdt: f64,
    /// TeaCache accumulated-delta threshold.
    pub tea_threshold: f64,
    /// AdaCache similarity→rate knee.
    pub ada_knee: f64,
    /// L2C learned-schedule threshold (their cache-threshold knob, table 10).
    pub l2c_threshold: f64,
    /// StaticCache recompute period (PAB broadcast frequency).
    pub static_period: usize,
    /// Cross-request warm start: lanes adopt converged affine fits (and
    /// threshold policies adopt delta profiles) from the fleet-level
    /// `store::WarmStore` at admission, and publish theirs back on
    /// retirement. OFF by default — fixed-seed parity tests and the
    /// default serving path are bit-for-bit unchanged.
    pub warm_start: bool,
    /// Fit-confidence gate: an `Approx` decision is downgraded to
    /// `Compute` until the layer's affine fit has seen this many updates.
    /// 0 (default) disables the gate — legacy behavior where even an
    /// identity fit is substituted. Warm-start deployments set this > 0:
    /// cold lanes then pay compute until their fits converge, while
    /// warm-started lanes (whose adopted fits already carry ≥ this many
    /// updates) approximate from the first skippable site — that gap is
    /// the warm-start FLOPs win `eval_warmstart` measures. Doubles as the
    /// publish threshold: only fits with ≥ max(this, 1) updates are
    /// published to the store.
    pub fit_min_updates: u64,
}

impl Default for FastCacheConfig {
    fn default() -> Self {
        FastCacheConfig {
            policy: PolicyKind::FastCache,
            alpha: 0.05,
            tau_delta0: 0.15,
            tau_s: 0.05,
            gamma: 0.5,
            enable_str: true,
            enable_sc: true,
            enable_mb: true,
            enable_merge: false,
            knn_k: 5,
            merge_lambda: 0.5,
            merge_target: 32,
            approx: ApproxMode::DiagAffine,
            fit_decay: 0.98,
            fb_rdt: 0.25,
            tea_threshold: 1.20,
            ada_knee: 0.30,
            l2c_threshold: 0.10,
            static_period: 2,
            warm_start: false,
            fit_min_updates: 0,
        }
    }
}

impl FastCacheConfig {
    /// Policy-appropriate defaults: STR, MB, and token merging are
    /// FastCache modules — the baselines (and the vanilla NoCache rows)
    /// run without them, exactly as in the paper's comparison tables.
    pub fn with_policy(policy: PolicyKind) -> Self {
        let fastcache = policy == PolicyKind::FastCache;
        FastCacheConfig {
            policy,
            enable_str: fastcache,
            enable_mb: fastcache,
            enable_merge: false,
            ..Default::default()
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(0.0 < self.alpha && self.alpha < 1.0) {
            return Err(format!("alpha out of (0,1): {}", self.alpha));
        }
        if self.tau_delta0 <= 0.0 {
            return Err(format!("tau_delta0 must be > 0: {}", self.tau_delta0));
        }
        if self.tau_s < 0.0 {
            return Err(format!("tau_s must be >= 0: {}", self.tau_s));
        }
        if !(0.0..=1.0).contains(&self.gamma) {
            return Err(format!("gamma out of [0,1]: {}", self.gamma));
        }
        if self.knn_k == 0 {
            return Err("knn_k must be >= 1".into());
        }
        if self.static_period == 0 {
            return Err("static_period must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.fit_decay) {
            return Err(format!("fit_decay out of [0,1]: {}", self.fit_decay));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_defaults() {
        let c = FastCacheConfig::default();
        assert_eq!(c.alpha, 0.05);
        assert_eq!(c.tau_s, 0.05);
        assert_eq!(c.gamma, 0.5);
        assert!(c.enable_str && c.enable_sc && c.enable_mb);
        assert_eq!(c.knn_k, 5);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_values() {
        let c = FastCacheConfig { alpha: 0.0, ..FastCacheConfig::default() };
        assert!(c.validate().is_err());
        let c = FastCacheConfig { gamma: 1.5, ..FastCacheConfig::default() };
        assert!(c.validate().is_err());
        let c = FastCacheConfig { knn_k: 0, ..FastCacheConfig::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn warm_start_is_off_by_default() {
        // The fixed-seed parity suite relies on the default path being
        // byte-identical to the pre-warm-start behavior.
        let c = FastCacheConfig::default();
        assert!(!c.warm_start);
        assert_eq!(c.fit_min_updates, 0);
        for p in PolicyKind::ALL {
            assert!(!FastCacheConfig::with_policy(p).warm_start);
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(p.name()), Some(p));
        }
        assert_eq!(PolicyKind::parse("bogus"), None);
    }
}
