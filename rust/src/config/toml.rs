//! Minimal TOML-subset parser for server config files (the `toml` crate is
//! not vendored in the offline registry). Supports:
//!
//!   [section]
//!   key = "string"            # comments
//!   key = 3.5 | 42 | true
//!
//! No nested tables, arrays, or multi-line strings — exactly what
//! fastcache-serve's config files need (see `--config` in main.rs).

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Float(f64),
    Int(i64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: `section.key` -> value (keys before any section header
/// live under the empty section "").
#[derive(Default, Debug)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.values.insert(full, parse_value(val.trim(), lineno + 1)?);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|k| k.as_str())
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue, String> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| format!("line {lineno}: unterminated string"))?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("line {lineno}: cannot parse value {s:?}"))
}

/// Apply a parsed config file onto (FastCacheConfig, ServerConfig).
/// Recognized keys mirror the CLI options (see main.rs):
///
///   [model]    variant = "xl"
///   [cache]    policy = "fastcache"  alpha = 0.05  tau_s = 0.05 …
///   [server]   steps = 50  max_batch = 4  queue_depth = 64 …
pub fn apply(
    doc: &TomlDoc,
    fc: &mut super::FastCacheConfig,
    scfg: &mut super::ServerConfig,
) -> Result<(), String> {
    use super::{PolicyKind, Variant};
    if let Some(v) = doc.get("model.variant").and_then(|v| v.as_str()) {
        scfg.variant = Variant::parse(v).ok_or_else(|| format!("bad model.variant {v:?}"))?;
    }
    if let Some(v) = doc.get("cache.policy").and_then(|v| v.as_str()) {
        fc.policy = PolicyKind::parse(v).ok_or_else(|| format!("bad cache.policy {v:?}"))?;
    }
    macro_rules! f64_key {
        ($key:literal, $slot:expr) => {
            if let Some(v) = doc.get($key) {
                $slot = v.as_f64().ok_or_else(|| format!("{} must be a number", $key))?;
            }
        };
    }
    macro_rules! usize_key {
        ($key:literal, $slot:expr) => {
            if let Some(v) = doc.get($key) {
                $slot = v.as_usize().ok_or_else(|| format!("{} must be an integer", $key))?;
            }
        };
    }
    macro_rules! bool_key {
        ($key:literal, $slot:expr) => {
            if let Some(v) = doc.get($key) {
                $slot = v.as_bool().ok_or_else(|| format!("{} must be a bool", $key))?;
            }
        };
    }
    f64_key!("cache.alpha", fc.alpha);
    f64_key!("cache.tau_delta0", fc.tau_delta0);
    f64_key!("cache.tau_s", fc.tau_s);
    if let Some(v) = doc.get("cache.gamma") {
        fc.gamma = v.as_f64().ok_or("cache.gamma must be a number")? as f32;
    }
    bool_key!("cache.enable_str", fc.enable_str);
    bool_key!("cache.enable_sc", fc.enable_sc);
    bool_key!("cache.enable_mb", fc.enable_mb);
    bool_key!("cache.enable_merge", fc.enable_merge);
    usize_key!("cache.knn_k", fc.knn_k);
    usize_key!("cache.merge_target", fc.merge_target);
    f64_key!("cache.fb_rdt", fc.fb_rdt);
    f64_key!("cache.tea_threshold", fc.tea_threshold);
    f64_key!("cache.ada_knee", fc.ada_knee);
    f64_key!("cache.l2c_threshold", fc.l2c_threshold);
    usize_key!("cache.static_period", fc.static_period);
    bool_key!("cache.warm_start", fc.warm_start);
    if let Some(v) = doc.get("cache.fit_min_updates") {
        fc.fit_min_updates =
            v.as_usize().ok_or("cache.fit_min_updates must be an integer")? as u64;
    }
    usize_key!("server.steps", scfg.steps);
    usize_key!("server.max_batch", scfg.max_batch);
    usize_key!("server.queue_depth", scfg.queue_depth);
    usize_key!("server.workers", scfg.workers);
    usize_key!("server.threads", scfg.threads);
    bool_key!("server.int8", scfg.int8);
    if let Some(v) = doc.get("server.guidance") {
        scfg.guidance = v.as_f64().ok_or("server.guidance must be a number")? as f32;
    }
    if let Some(v) = doc.get("server.artifacts_dir").and_then(|v| v.as_str()) {
        scfg.artifacts_dir = v.to_string();
    }
    if let Some(v) = doc.get("server.weight_seed") {
        scfg.weight_seed = v.as_usize().ok_or("server.weight_seed must be an integer")? as u64;
    }
    if let Some(v) = doc.get("server.warm_budget_mib") {
        scfg.warm_budget_bytes =
            v.as_usize().ok_or("server.warm_budget_mib must be an integer")? << 20;
    }
    if let Some(v) = doc.get("net.listen").and_then(|v| v.as_str()) {
        scfg.listen = Some(v.to_string());
    }
    usize_key!("net.max_conns", scfg.net_max_conns);
    f64_key!("obs.trace_sample_rate", scfg.trace_sample_rate);
    f64_key!("obs.stats_every", scfg.stats_every);
    if let Some(v) = doc.get("obs.trace_out").and_then(|v| v.as_str()) {
        scfg.trace_out = Some(v.to_string());
    }
    if let Some(v) = doc.get("faults.plan").and_then(|v| v.as_str()) {
        scfg.fault_plan = Some(v.to_string());
    }
    bool_key!("server.degrade", scfg.degrade);
    usize_key!("server.degrade_rungs", scfg.degrade_rungs);
    if let Some(v) = doc.get("server.warm_snapshot").and_then(|v| v.as_str()) {
        scfg.warm_snapshot = Some(v.to_string());
    }
    f64_key!("server.warm_snapshot_every", scfg.warm_snapshot_every);
    usize_key!("server.shard_restart_after", scfg.shard_restart_after);
    usize_key!("server.poison_after", scfg.poison_after);
    if let Some(v) = doc.get("server.step_stall_ms") {
        scfg.step_stall_ms =
            v.as_usize().ok_or("server.step_stall_ms must be an integer")? as u64;
    }
    fc.validate()?;
    scfg.validate()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FastCacheConfig, PolicyKind, ServerConfig, Variant};

    const SAMPLE: &str = r#"
# fastcache-serve config
[model]
variant = "xl"

[cache]
policy = "fbcache"   # a baseline
alpha = 0.01
gamma = 0.7
enable_str = false
knn_k = 7
warm_start = true
fit_min_updates = 6

[server]
steps = 25
max_batch = 2
threads = 2
int8 = true
artifacts_dir = "artifacts"
warm_budget_mib = 4
degrade = true
degrade_rungs = 2
warm_snapshot = "warm.fcws"
warm_snapshot_every = 30.0
shard_restart_after = 3
poison_after = 2
step_stall_ms = 400

[faults]
plan = "panic step=2 layer=1 req=3"

[net]
listen = "127.0.0.1:0"
max_conns = 8

[obs]
trace_sample_rate = 0.25
trace_out = "trace.json"
stats_every = 5
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get("model.variant").unwrap().as_str(), Some("xl"));
        assert_eq!(doc.get("cache.alpha").unwrap().as_f64(), Some(0.01));
        assert_eq!(doc.get("cache.knn_k").unwrap().as_usize(), Some(7));
        assert_eq!(doc.get("cache.enable_str").unwrap().as_bool(), Some(false));
        assert_eq!(doc.get("server.steps").unwrap().as_usize(), Some(25));
    }

    #[test]
    fn applies_onto_configs() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        let mut fc = FastCacheConfig::default();
        let mut scfg = ServerConfig::default();
        apply(&doc, &mut fc, &mut scfg).unwrap();
        assert_eq!(scfg.variant, Variant::Xl);
        assert_eq!(fc.policy, PolicyKind::FbCache);
        assert_eq!(fc.alpha, 0.01);
        assert!((fc.gamma - 0.7).abs() < 1e-6);
        assert!(!fc.enable_str);
        assert!(fc.warm_start);
        assert_eq!(fc.fit_min_updates, 6);
        assert_eq!(scfg.steps, 25);
        assert_eq!(scfg.max_batch, 2);
        assert_eq!(scfg.threads, 2);
        assert!(scfg.int8);
        assert_eq!(scfg.warm_budget_bytes, 4 << 20);
        assert_eq!(scfg.listen.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(scfg.net_max_conns, 8);
        assert_eq!(scfg.trace_sample_rate, 0.25);
        assert_eq!(scfg.trace_out.as_deref(), Some("trace.json"));
        assert_eq!(scfg.stats_every, 5.0);
        assert_eq!(scfg.fault_plan.as_deref(), Some("panic step=2 layer=1 req=3"));
        assert!(scfg.degrade);
        assert_eq!(scfg.degrade_rungs, 2);
        assert_eq!(scfg.warm_snapshot.as_deref(), Some("warm.fcws"));
        assert_eq!(scfg.warm_snapshot_every, 30.0);
        assert_eq!(scfg.shard_restart_after, 3);
        assert_eq!(scfg.poison_after, 2);
        assert_eq!(scfg.step_stall_ms, 400);
    }

    #[test]
    fn rejects_invalid_fault_plan() {
        let doc = TomlDoc::parse("[faults]\nplan = \"panic layer=1\"").unwrap();
        let mut fc = FastCacheConfig::default();
        let mut scfg = ServerConfig::default();
        let err = apply(&doc, &mut fc, &mut scfg).unwrap_err();
        assert!(err.contains("fault_plan"), "unexpected message: {err}");
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("x = \"open").is_err());
        assert!(TomlDoc::parse("x = what").is_err());
    }

    #[test]
    fn rejects_invalid_semantics() {
        let doc = TomlDoc::parse("[cache]\nalpha = 7.0").unwrap();
        let mut fc = FastCacheConfig::default();
        let mut scfg = ServerConfig::default();
        assert!(apply(&doc, &mut fc, &mut scfg).is_err());
        let doc = TomlDoc::parse("[cache]\npolicy = \"bogus\"").unwrap();
        assert!(apply(&doc, &mut fc, &mut scfg).is_err());
    }

    #[test]
    fn comments_and_quotes() {
        let doc = TomlDoc::parse("x = \"a # b\" # trailing").unwrap();
        assert_eq!(doc.get("x").unwrap().as_str(), Some("a # b"));
    }
}
