//! Configuration layer: model-variant table (mirrors python configs.py),
//! FastCache / policy knobs, server knobs, and the CLI parser.

pub mod cli;
pub mod fastcache;
pub mod model;
pub mod server;
pub mod toml;

pub use cli::Args;
pub use fastcache::{ApproxMode, FastCacheConfig, PolicyKind};
pub use model::{
    token_bucket, ModelConfig, Variant, BATCH_SIZES, C_IN, MLP_RATIO, N_TOKENS, TOKEN_BUCKETS,
};
pub use server::{ServerConfig, MAX_NET_CONNS, MAX_WORKERS};
