//! Workload synthesis: deterministic request sets with controllable
//! motion structure (the offline substitution for the paper's
//! ImageNet / MS-COCO / video sampling sets).

pub mod synth;

pub use synth::{MotionProfile, WorkloadGen};
