//! Synthetic workload generation (substitution for ImageNet/MS-COCO
//! sampling sets, DESIGN.md §2): request sets with controllable motion
//! structure — a contiguous "moving region" of tokens receives per-step
//! turbulence, the rest settles like static background. Motion fraction
//! and amplitude are the two knobs the paper's image/video splits vary.

use crate::config::N_TOKENS;
use crate::rng::Rng;
use crate::scheduler::{GenRequest, Turbulence};
use crate::tensor::Tensor;

/// Workload profile: how much of the content moves, how hard.
#[derive(Clone, Copy, Debug)]
pub struct MotionProfile {
    /// Fraction of tokens in the moving region [0, 1].
    pub motion_fraction: f64,
    /// Per-step turbulence amplitude (relative to unit-variance latents).
    pub amplitude: f32,
}

impl MotionProfile {
    /// Mostly-static content (paper's low-motion / image setting).
    pub const CALM: MotionProfile = MotionProfile { motion_fraction: 0.2, amplitude: 0.25 };
    /// Mixed content (default evaluation set).
    pub const MIXED: MotionProfile = MotionProfile { motion_fraction: 0.4, amplitude: 0.4 };
    /// High-motion content (paper's dynamic-video setting).
    pub const STORMY: MotionProfile = MotionProfile { motion_fraction: 0.75, amplitude: 0.8 };
}

/// Deterministic request-set generator.
pub struct WorkloadGen {
    rng: Rng,
    next_id: u64,
}

impl WorkloadGen {
    pub fn new(seed: u64) -> WorkloadGen {
        WorkloadGen { rng: Rng::new(seed), next_id: 0 }
    }

    /// A contiguous square-ish blob of motion tokens on the 8x8 grid.
    fn motion_region(&mut self, fraction: f64) -> Vec<usize> {
        let count = ((N_TOKENS as f64 * fraction).round() as usize).min(N_TOKENS);
        if count == 0 {
            return Vec::new();
        }
        let side = 8usize;
        let w = ((count as f64).sqrt().ceil() as usize).clamp(1, side);
        let h = count.div_ceil(w).clamp(1, side);
        let r0 = self.rng.below(side - h + 1);
        let c0 = self.rng.below(side - w + 1);
        let mut toks = Vec::with_capacity(count);
        'outer: for r in r0..r0 + h {
            for c in c0..c0 + w {
                toks.push(r * side + c);
                if toks.len() == count {
                    break 'outer;
                }
            }
        }
        toks
    }

    /// One image-generation request under a motion profile.
    pub fn image_request(&mut self, steps: usize, profile: MotionProfile) -> GenRequest {
        let id = self.next_id;
        self.next_id += 1;
        let seed = self.rng.next_u64();
        let turb = if profile.motion_fraction > 0.0 && profile.amplitude > 0.0 {
            Some(Turbulence {
                tokens: self.motion_region(profile.motion_fraction),
                amp: profile.amplitude,
                seed: self.rng.next_u64(),
            })
        } else {
            None
        };
        let mut b = GenRequest::builder(id, seed)
            .cond_seed(self.rng.next_u64())
            .steps(steps);
        if let Some(t) = turb {
            b = b.turbulence(t);
        }
        b.build().expect("workload generator emits valid requests")
    }

    /// A batch of image requests.
    pub fn image_set(&mut self, count: usize, steps: usize, profile: MotionProfile) -> Vec<GenRequest> {
        (0..count).map(|_| self.image_request(steps, profile)).collect()
    }

    /// A video clip: `frames` requests sharing a correlated initial latent
    /// (common background + per-frame drift) and a shared motion region, so
    /// consecutive frames differ mostly inside the moving blob.
    pub fn video_clip(
        &mut self,
        frames: usize,
        steps: usize,
        profile: MotionProfile,
    ) -> Vec<GenRequest> {
        let base_seed = self.rng.next_u64();
        let cond_seed = self.rng.next_u64();
        let region = self.motion_region(profile.motion_fraction);
        let mut base_rng = Rng::new(base_seed);
        let base = Tensor::new(base_rng.normal_vec(N_TOKENS * crate::config::C_IN, 1.0),
                               &[N_TOKENS, crate::config::C_IN]);
        (0..frames)
            .map(|f| {
                let id = self.next_id;
                self.next_id += 1;
                // Frame init: background latent + motion-region drift.
                let mut init = base.clone();
                let mut fr = Rng::new(base_seed ^ (0xF00D + f as u64));
                for &tok in &region {
                    for v in init.row_mut(tok) {
                        *v = 0.5 * *v + profile.amplitude * fr.normal();
                    }
                }
                GenRequest::builder(id, base_seed ^ f as u64)
                    .cond_seed(cond_seed)
                    .steps(steps)
                    .turbulence(Turbulence {
                        tokens: region.clone(),
                        amp: profile.amplitude,
                        seed: base_seed ^ (0xBEEF + f as u64),
                    })
                    .init_latent(init)
                    .build()
                    .expect("workload generator emits valid requests")
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = WorkloadGen::new(1);
        let mut b = WorkloadGen::new(1);
        let ra = a.image_request(50, MotionProfile::MIXED);
        let rb = b.image_request(50, MotionProfile::MIXED);
        assert_eq!(ra.seed, rb.seed);
        assert_eq!(
            ra.turbulence.as_ref().unwrap().tokens,
            rb.turbulence.as_ref().unwrap().tokens
        );
    }

    #[test]
    fn motion_region_size_tracks_fraction() {
        let mut g = WorkloadGen::new(2);
        let small = g.motion_region(0.1).len();
        let large = g.motion_region(0.8).len();
        assert!(small < large);
        assert!((large as f64 - 0.8 * 64.0).abs() <= 8.0);
    }

    #[test]
    fn region_tokens_valid_and_unique() {
        let mut g = WorkloadGen::new(3);
        for frac in [0.1, 0.5, 1.0] {
            let r = g.motion_region(frac);
            assert!(r.iter().all(|&t| t < N_TOKENS));
            let mut s = r.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), r.len());
        }
    }

    #[test]
    fn video_frames_share_background() {
        let mut g = WorkloadGen::new(4);
        let clip = g.video_clip(4, 10, MotionProfile::CALM);
        assert_eq!(clip.len(), 4);
        let i0 = clip[0].init_latent.as_ref().unwrap();
        let i1 = clip[1].init_latent.as_ref().unwrap();
        // Background tokens identical, motion tokens differ.
        let region = &clip[0].turbulence.as_ref().unwrap().tokens;
        let mut bg_diff = 0.0f32;
        let mut mo_diff = 0.0f32;
        for t in 0..N_TOKENS {
            let d: f32 = i0
                .row(t)
                .iter()
                .zip(i1.row(t))
                .map(|(a, b)| (a - b).abs())
                .sum();
            if region.contains(&t) {
                mo_diff += d;
            } else {
                bg_diff += d;
            }
        }
        assert_eq!(bg_diff, 0.0);
        assert!(mo_diff > 0.0);
    }

    #[test]
    fn ids_unique() {
        let mut g = WorkloadGen::new(5);
        let set = g.image_set(10, 50, MotionProfile::MIXED);
        let mut ids: Vec<u64> = set.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 10);
    }
}
