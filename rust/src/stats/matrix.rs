//! Small dense symmetric-matrix linear algebra for the Fréchet metric:
//! matmul, Jacobi eigendecomposition, and the symmetric matrix square root.
//!
//! Feature dims here are small (latent channels C=4 up to D≤288 pooled
//! features), so an O(n³) cyclic Jacobi sweep is plenty and has the
//! robustness we want for nearly-PSD empirical covariances.

/// Row-major n×n matmul: C = A·B.
pub fn matmul(a: &[f64], b: &[f64], n: usize) -> Vec<f64> {
    let mut c = vec![0.0; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[k * n..(k + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// Trace of an n×n matrix.
pub fn trace(a: &[f64], n: usize) -> f64 {
    (0..n).map(|i| a[i * n + i]).sum()
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
/// Returns (eigenvalues, eigenvectors-as-columns-rowmajor V) with A = V Λ Vᵀ.
pub fn jacobi_eigh(a_in: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut a = a_in.to_vec();
    let mut v = vec![0.0; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[i * n + j] * a[i * n + j];
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq.abs() < 1e-15 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p, q of A.
                for k in 0..n {
                    let akp = a[k * n + p];
                    let akq = a[k * n + q];
                    a[k * n + p] = c * akp - s * akq;
                    a[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p * n + k];
                    let aqk = a[q * n + k];
                    a[p * n + k] = c * apk - s * aqk;
                    a[q * n + k] = s * apk + c * aqk;
                }
                // Accumulate V.
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let eig = (0..n).map(|i| a[i * n + i]).collect();
    (eig, v)
}

/// Symmetric PSD square root via eigendecomposition, clamping tiny negative
/// eigenvalues from sampling noise to zero.
pub fn sqrtm_psd(a: &[f64], n: usize) -> Vec<f64> {
    let (eig, v) = jacobi_eigh(a, n);
    // S = V diag(sqrt(max(eig,0))) Vᵀ
    let mut s = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0;
            for k in 0..n {
                acc += v[i * n + k] * eig[k].max(0.0).sqrt() * v[j * n + k];
            }
            s[i * n + j] = acc;
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let n = 4;
        let mut i4 = vec![0.0; 16];
        for i in 0..4 {
            i4[i * 4 + i] = 1.0;
        }
        let a: Vec<f64> = (0..16).map(|x| x as f64).collect();
        assert_eq!(matmul(&a, &i4, n), a);
        assert_eq!(matmul(&i4, &a, n), a);
    }

    #[test]
    fn jacobi_diagonalizes_known_matrix() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = vec![2.0, 1.0, 1.0, 2.0];
        let (mut eig, _) = jacobi_eigh(&a, 2);
        eig.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((eig[0] - 1.0).abs() < 1e-10);
        assert!((eig[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn jacobi_reconstructs() {
        // A random-ish symmetric 5x5; check V Λ Vᵀ = A.
        let n = 5;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let v = ((i * 3 + j * 7) % 11) as f64 / 11.0;
                a[i * n + j] = v;
            }
        }
        for i in 0..n {
            for j in 0..i {
                let s = 0.5 * (a[i * n + j] + a[j * n + i]);
                a[i * n + j] = s;
                a[j * n + i] = s;
            }
        }
        let (eig, v) = jacobi_eigh(&a, n);
        let mut recon = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += v[i * n + k] * eig[k] * v[j * n + k];
                }
                recon[i * n + j] = acc;
            }
        }
        for (x, y) in recon.iter().zip(&a) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn sqrtm_squares_back() {
        // SPD matrix: AᵀA + I.
        let n = 3;
        let b = vec![1.0, 2.0, 0.0, 0.5, 1.0, 1.0, 0.0, 0.25, 2.0];
        let mut a = vec![0.0; 9];
        for i in 0..n {
            for j in 0..n {
                let mut acc = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    acc += b[k * n + i] * b[k * n + j];
                }
                a[i * n + j] = acc;
            }
        }
        let s = sqrtm_psd(&a, n);
        let s2 = matmul(&s, &s, n);
        for (x, y) in s2.iter().zip(&a) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn trace_sums_diagonal() {
        let a = vec![1.0, 9.0, 9.0, 2.0];
        assert_eq!(trace(&a, 2), 3.0);
    }
}
