//! Statistical substrate: normal/χ² distributions for the paper's cache
//! decision rule, online moment accumulators for the learnable linear
//! approximation, and the Fréchet machinery behind the FID-family metrics.

pub mod chi2;
pub mod frechet;
pub mod matrix;
pub mod normal;
pub mod welford;

pub use chi2::{cache_error_bound, chi2_cdf, chi2_quantile, delta_sq_threshold};
pub use frechet::{frechet_distance, FeatureStats};
pub use normal::{norm_cdf, norm_quantile};
pub use welford::{PairStats, Welford};
