//! Fréchet distance between Gaussian feature statistics — the metric family
//! behind FID / t-FID / FVD:
//!
//!   d²( N(μ₁,Σ₁), N(μ₂,Σ₂) ) = ‖μ₁−μ₂‖² + tr(Σ₁ + Σ₂ − 2(Σ₁Σ₂)^{1/2})
//!
//! **Substitution note** (DESIGN.md §2): the paper computes FID over
//! Inception-v3 features of decoded images. Offline we have no Inception
//! network, so the same Fréchet functional is evaluated over latent-space
//! features (FID-proxy) and temporal-difference features (t-FID/FVD-proxy).
//! The orderings the paper's tables rely on — more cache error ⇒ larger
//! distance from the NoCache reference distribution — are preserved because
//! the functional is identical, only the feature extractor differs.

use super::matrix::{matmul, sqrtm_psd, trace};

/// Accumulates feature vectors and yields (μ, Σ).
#[derive(Clone, Debug)]
pub struct FeatureStats {
    dim: usize,
    n: usize,
    sum: Vec<f64>,
    /// Upper-triangular-inclusive sum of outer products, row-major full.
    outer: Vec<f64>,
}

impl FeatureStats {
    pub fn new(dim: usize) -> Self {
        Self { dim, n: 0, sum: vec![0.0; dim], outer: vec![0.0; dim * dim] }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn push(&mut self, feat: &[f32]) {
        assert_eq!(feat.len(), self.dim);
        self.n += 1;
        for i in 0..self.dim {
            let fi = feat[i] as f64;
            self.sum[i] += fi;
            let row = &mut self.outer[i * self.dim..(i + 1) * self.dim];
            for j in 0..self.dim {
                row[j] += fi * feat[j] as f64;
            }
        }
    }

    pub fn mean(&self) -> Vec<f64> {
        assert!(self.n > 0);
        self.sum.iter().map(|s| s / self.n as f64).collect()
    }

    /// Biased empirical covariance.
    pub fn cov(&self) -> Vec<f64> {
        let n = self.n as f64;
        let mu = self.mean();
        let d = self.dim;
        let mut c = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..d {
                c[i * d + j] = self.outer[i * d + j] / n - mu[i] * mu[j];
            }
        }
        c
    }
}

/// Squared Fréchet distance between two Gaussian stats.
pub fn frechet_distance(a: &FeatureStats, b: &FeatureStats) -> f64 {
    assert_eq!(a.dim, b.dim, "feature dims must match");
    assert!(a.n > 1 && b.n > 1, "need >=2 samples per side");
    let d = a.dim;
    let (mu1, mu2) = (a.mean(), b.mean());
    let (c1, c2) = (a.cov(), b.cov());

    let mean_term: f64 = mu1.iter().zip(&mu2).map(|(x, y)| (x - y) * (x - y)).sum();

    // tr((Σ1 Σ2)^{1/2}) via sqrtm of the symmetrized product:
    // use S = sqrtm(Σ1); M = S Σ2 S is symmetric PSD with the same
    // eigenvalues as Σ1Σ2, so tr(sqrtm(M)) = tr((Σ1Σ2)^{1/2}).
    let s1 = sqrtm_psd(&c1, d);
    let m = matmul(&matmul(&s1, &c2, d), &s1, d);
    let msqrt = sqrtm_psd(&m, d);

    let val = mean_term + trace(&c1, d) + trace(&c2, d) - 2.0 * trace(&msqrt, d);
    val.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn sample_stats(seed: u64, dim: usize, n: usize, mean: f32, sd: f32) -> FeatureStats {
        let mut rng = Rng::new(seed);
        let mut st = FeatureStats::new(dim);
        for _ in 0..n {
            let v: Vec<f32> = (0..dim).map(|_| mean + sd * rng.normal()).collect();
            st.push(&v);
        }
        st
    }

    #[test]
    fn identical_distributions_near_zero() {
        let a = sample_stats(1, 4, 4000, 0.0, 1.0);
        let b = sample_stats(2, 4, 4000, 0.0, 1.0);
        let d = frechet_distance(&a, &b);
        assert!(d < 0.05, "d={d}");
    }

    #[test]
    fn self_distance_is_zero() {
        let a = sample_stats(3, 6, 500, 0.5, 2.0);
        let d = frechet_distance(&a, &a);
        assert!(d < 1e-9, "d={d}");
    }

    #[test]
    fn mean_shift_equals_squared_norm() {
        // For equal covariances, d² = ‖Δμ‖².
        let a = sample_stats(4, 3, 20000, 0.0, 1.0);
        let b = sample_stats(5, 3, 20000, 1.0, 1.0);
        let d = frechet_distance(&a, &b);
        // Δμ = (1,1,1) => ‖Δμ‖² = 3.
        assert!((d - 3.0).abs() < 0.25, "d={d}");
    }

    #[test]
    fn scale_mismatch_analytic() {
        // 1-D: d² = (σ1−σ2)². dim=1 exercises the degenerate matrix path.
        let a = sample_stats(6, 1, 50000, 0.0, 1.0);
        let b = sample_stats(7, 1, 50000, 0.0, 3.0);
        let d = frechet_distance(&a, &b);
        assert!((d - 4.0).abs() < 0.3, "d={d}");
    }

    #[test]
    fn monotone_in_perturbation() {
        // Larger perturbations of the same base distribution => larger d.
        let base = sample_stats(8, 4, 5000, 0.0, 1.0);
        let mut prev = 0.0;
        for (i, eps) in [0.1f32, 0.5, 1.5].iter().enumerate() {
            let p = sample_stats(100 + i as u64, 4, 5000, *eps, 1.0);
            let d = frechet_distance(&base, &p);
            assert!(d > prev, "eps={eps}: d={d} prev={prev}");
            prev = d;
        }
    }
}
