//! χ² distribution pieces for the statistical cache decision (paper Eq. 5-9).
//!
//! The paper's rule: skip block `l` iff  δ²_{t,l} ≤ χ²_{ND,1−α} / ND, where
//! (ND)·δ² ~ χ²_{ND} under the weak-stationarity null. With ND in the
//! thousands (N=64 tokens × D≥96 channels), the Wilson–Hilferty cube
//! approximation to the χ² quantile is accurate to ~1e-4 relative — far
//! tighter than any sensitivity the decision exhibits (see the α-sweep in
//! bench `fig3`).

use super::normal::{norm_cdf, norm_quantile};

/// χ² quantile at probability `p` with `k` degrees of freedom
/// (Wilson–Hilferty: χ²_{k,p} ≈ k(1 − 2/(9k) + z_p √(2/(9k)))³).
pub fn chi2_quantile(p: f64, k: f64) -> f64 {
    assert!(k > 0.0, "chi2_quantile: dof={k}");
    let z = norm_quantile(p);
    let a = 2.0 / (9.0 * k);
    let c = 1.0 - a + z * a.sqrt();
    k * c * c * c
}

/// χ² CDF via the same normal approximation (inverse of the above).
pub fn chi2_cdf(x: f64, k: f64) -> f64 {
    assert!(k > 0.0);
    if x <= 0.0 {
        return 0.0;
    }
    let a = 2.0 / (9.0 * k);
    let z = ((x / k).powf(1.0 / 3.0) - (1.0 - a)) / a.sqrt();
    norm_cdf(z)
}

/// The paper's cache threshold on δ² (Eq. 7): χ²_{ND,1−α} / ND.
///
/// `nd` is the hidden-state element count N·D; `alpha` the significance
/// level (paper default 0.05).
pub fn delta_sq_threshold(nd: usize, alpha: f64) -> f64 {
    assert!(alpha > 0.0 && alpha < 1.0, "alpha={alpha}");
    chi2_quantile(1.0 - alpha, nd as f64) / nd as f64
}

/// Error bound for a type-II cache use (Eq. 9): √(χ²_{ND,1−α}/ND).
pub fn cache_error_bound(nd: usize, alpha: f64) -> f64 {
    delta_sq_threshold(nd, alpha).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    // scipy.stats.chi2.ppf reference values.
    const CASES: [(f64, f64, f64); 6] = [
        // (p, k, chi2.ppf(p, k))
        (0.95, 10.0, 18.307038053275146),
        (0.95, 100.0, 124.3421134287216),
        (0.99, 1000.0, 1106.9689807976193),
        (0.95, 6144.0, 6327.46401218988), // ND for dit-s full tokens
        (0.90, 18432.0, 18678.48217581182), // ND for dit-xl full tokens
        (0.50, 50.0, 49.33493944581455),
    ];

    #[test]
    fn quantile_close_to_scipy() {
        for (p, k, want) in CASES {
            let got = chi2_quantile(p, k);
            let rel = ((got - want) / want).abs();
            assert!(rel < 2e-3, "p={p} k={k}: got {got} want {want} rel {rel}");
        }
    }

    #[test]
    fn cdf_quantile_roundtrip() {
        for k in [10.0, 100.0, 6144.0] {
            for p in [0.05, 0.5, 0.9, 0.95, 0.99] {
                let x = chi2_quantile(p, k);
                assert!((chi2_cdf(x, k) - p).abs() < 1e-6, "k={k} p={p}");
            }
        }
    }

    #[test]
    fn threshold_decreases_with_alpha() {
        // Larger alpha (less confidence required) => smaller quantile =>
        // stricter threshold; the paper sweeps alpha in [0.01, 0.1].
        let nd = 64 * 288;
        let t01 = delta_sq_threshold(nd, 0.01);
        let t05 = delta_sq_threshold(nd, 0.05);
        let t10 = delta_sq_threshold(nd, 0.10);
        assert!(t01 > t05 && t05 > t10, "{t01} {t05} {t10}");
    }

    #[test]
    fn threshold_near_one_for_large_nd() {
        // χ²_{k,1−α}/k -> 1 as k -> ∞; at serving sizes it's 1 + O(k^-1/2).
        let t = delta_sq_threshold(64 * 288, 0.05);
        assert!(t > 1.0 && t < 1.05, "t={t}");
    }

    #[test]
    fn error_bound_is_sqrt_threshold() {
        let nd = 64 * 96;
        let t = delta_sq_threshold(nd, 0.05);
        assert!((cache_error_bound(nd, 0.05) - t.sqrt()).abs() < 1e-12);
    }
}
