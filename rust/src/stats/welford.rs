//! Welford online mean/variance and an online covariance accumulator.
//!
//! Used by (a) the online learnable affine fit (`cache/linear_fit.rs`),
//! which needs running per-channel cov(in, out)/var(in), and (b) the
//! Fréchet metric's feature statistics.

#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (biased); 0 for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
    }
}

/// Online accumulator for a scalar pair (x, y): running means, variances,
/// and covariance — the sufficient statistics of 1-D least squares
/// y ≈ a·x + b with closed form a = cov/var, b = ȳ − a·x̄.
#[derive(Clone, Debug, Default)]
pub struct PairStats {
    n: u64,
    mean_x: f64,
    mean_y: f64,
    m2_x: f64,
    c_xy: f64,
}

impl PairStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.n += 1;
        let nf = self.n as f64;
        let dx = x - self.mean_x; // vs OLD mean_x
        self.mean_x += dx / nf;
        self.mean_y += (y - self.mean_y) / nf;
        // Welford cross-moment: old-mean dx times NEW-mean y residual.
        self.c_xy += dx * (y - self.mean_y);
        self.m2_x += dx * (x - self.mean_x);
    }

    /// Exponential forgetting: decay all sufficient statistics so the fit
    /// tracks non-stationary hidden-state dynamics (paper Appendix A drift).
    pub fn decay(&mut self, lambda: f64) {
        debug_assert!((0.0..=1.0).contains(&lambda));
        // Effective count shrinks; means stay (they are averages).
        self.n = ((self.n as f64) * lambda).round() as u64;
        self.m2_x *= lambda;
        self.c_xy *= lambda;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// (a, b) of the least-squares fit y ≈ a x + b; identity (1, 0) until
    /// there is enough signal.
    pub fn fit(&self) -> (f32, f32) {
        if self.n < 2 || self.m2_x <= 1e-12 {
            return (1.0, 0.0);
        }
        let a = self.c_xy / self.m2_x;
        let b = self.mean_y - a * self.mean_x;
        (a as f32, b as f32)
    }

    /// The raw sufficient statistics `(n, mean_x, mean_y, m2_x, c_xy)` —
    /// everything needed to reconstruct this accumulator byte-exactly
    /// (warm-store snapshot serialization).
    pub fn raw(&self) -> (u64, f64, f64, f64, f64) {
        (self.n, self.mean_x, self.mean_y, self.m2_x, self.c_xy)
    }

    /// Rebuild an accumulator from [`raw`](Self::raw) output. The decode
    /// path validates finiteness before trusting disk bytes.
    pub fn from_raw(n: u64, mean_x: f64, mean_y: f64, m2_x: f64, c_xy: f64) -> PairStats {
        PairStats { n, mean_x, mean_y, m2_x, c_xy }
    }

    /// Pool two accumulators (pairwise Welford merge of the sufficient
    /// statistics) — pooled regression over both samples. The fleet-level
    /// warm-start store merges fits published by independent lanes with
    /// this.
    pub fn merge(&mut self, other: &PairStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let (n1, n2) = (self.n as f64, other.n as f64);
        let n = n1 + n2;
        let dx = other.mean_x - self.mean_x;
        let dy = other.mean_y - self.mean_y;
        self.mean_x += dx * n2 / n;
        self.mean_y += dy * n2 / n;
        self.m2_x += other.m2_x + dx * dx * n1 * n2 / n;
        self.c_xy += other.c_xy + dx * dy * n1 * n2 / n;
        self.n += other.n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.5, -1.0, 0.25, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.variance() - all.variance()).abs() < 1e-10);
    }

    #[test]
    fn pair_fit_recovers_exact_line() {
        let mut p = PairStats::new();
        for i in 0..50 {
            let x = i as f64 * 0.1;
            p.push(x, 2.5 * x - 1.25);
        }
        let (a, b) = p.fit();
        assert!((a - 2.5).abs() < 1e-5, "a={a}");
        assert!((b + 1.25).abs() < 1e-5, "b={b}");
    }

    #[test]
    fn pair_fit_identity_until_informed() {
        let p = PairStats::new();
        assert_eq!(p.fit(), (1.0, 0.0));
        let mut p2 = PairStats::new();
        p2.push(3.0, 5.0);
        assert_eq!(p2.fit(), (1.0, 0.0)); // single point: underdetermined
    }

    #[test]
    fn pair_merge_equals_sequential() {
        let xs: Vec<(f64, f64)> =
            (0..80).map(|i| ((i as f64).cos() * 2.0, (i as f64).sin() - 0.3)).collect();
        let mut all = PairStats::new();
        for &(x, y) in &xs {
            all.push(x, y);
        }
        let mut a = PairStats::new();
        let mut b = PairStats::new();
        for &(x, y) in &xs[..29] {
            a.push(x, y);
        }
        for &(x, y) in &xs[29..] {
            b.push(x, y);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        let (fa, fb) = a.fit();
        let (ga, gb) = all.fit();
        assert!((fa - ga).abs() < 1e-6 && (fb - gb).abs() < 1e-6, "{fa},{fb} vs {ga},{gb}");
        // Merging into an empty accumulator is a copy; merging an empty one
        // is a no-op.
        let mut e = PairStats::new();
        e.merge(&all);
        assert_eq!(e.fit(), all.fit());
        all.merge(&PairStats::new());
        assert_eq!(e.fit(), all.fit());
    }

    #[test]
    fn pair_fit_tracks_after_decay() {
        let mut p = PairStats::new();
        for i in 0..200 {
            let x = (i % 17) as f64;
            p.push(x, 1.0 * x);
        }
        // Regime change: slope becomes 3. With decay the fit must move.
        for i in 0..200 {
            p.decay(0.95);
            let x = (i % 17) as f64;
            p.push(x, 3.0 * x);
        }
        let (a, _) = p.fit();
        assert!((a - 3.0).abs() < 0.15, "a={a}");
    }
}
