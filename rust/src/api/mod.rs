//! The public request/response API — ONE set of types shared by every
//! transport.
//!
//! Before the network front door existed, the in-process path grew an
//! ad-hoc dialect: `GenOutcome` (completed vs shed) on the response
//! channel, `SubmitError` (queue full vs closed) on the submit call, and
//! `ShedNotice` as a third shape for dropped jobs. A wire protocol cannot
//! afford three overlapping vocabularies, so this module collapses them:
//!
//! - [`ErrorCode`] — the *stable numeric* rejection codes ([`ErrorCode::Busy`],
//!   [`ErrorCode::Expired`], [`ErrorCode::Closed`], [`ErrorCode::BadRequest`]).
//!   The numbers are part of the wire protocol (docs/PROTOCOL.md) and must
//!   never be reassigned.
//! - [`Reject`] — one rejection payload for every path: returned by
//!   `Server::submit` on backpressure, delivered in-band when a queued
//!   job's deadline expires, produced by `GenRequest::builder()` on
//!   validation failure, and encoded verbatim into `Error`/`Shed` frames.
//! - [`Outcome`] — the terminal result of a request: completed or
//!   rejected. One enum, two transports: `server::worker` sends it on the
//!   in-process channel and `net` encodes it onto the socket.
//! - [`Event`] / [`ResponseStream`] — the streaming response surface
//!   (progress ticks, then exactly one terminal [`Outcome`]).
//! - [`GenClient`] — the one client trait implemented by both the
//!   in-process [`crate::server::Server`] and the remote
//!   [`crate::net::NetClient`].

pub mod client;

use crate::scheduler::GenResult;

pub use client::{GenClient, ResponseStream};

/// Stable numeric rejection codes — identical on the in-process path and
/// the wire (`Error` frames carry `code as u16`). Part of the protocol:
/// never renumber, only append.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u16)]
pub enum ErrorCode {
    /// Over capacity — refused at the door (queue full or connection
    /// budget exceeded). Retryable after backoff.
    Busy = 1,
    /// The request's SLA deadline passed before service; it was dropped
    /// unserved. Counts as an SLA miss in `deadline_hit_rate()`.
    Expired = 2,
    /// Server shutting down / connection gone. Not retryable here.
    Closed = 3,
    /// The request itself is invalid (failed `GenRequest` validation or
    /// an undecodable frame). Retrying the same request cannot succeed.
    BadRequest = 4,
    /// The server hit an internal fault (e.g. a panicking kernel) while
    /// serving this request. The lane was quarantined — the shard and
    /// its sibling lanes keep serving. Counts AGAINST
    /// `deadline_hit_rate()` for deadline-tagged requests (the
    /// sheds-count-against-SLA rule: a fault is never a vanished
    /// denominator). Retrying MAY succeed (the fault is per-request).
    Internal = 5,
    /// This request id is on the supervisor's poisoned-request blocklist:
    /// its lane triggered repeated typed quarantines, so re-admitting it
    /// would re-poison a shard batch. Rejected AT ADMISSION (in-process
    /// and at the net door) before it costs a queue slot. Deadline-tagged
    /// rejections still count against `deadline_hit_rate()`. Not
    /// retryable — the same request keeps hitting the same fault.
    Poisoned = 6,
}

impl ErrorCode {
    /// The wire representation.
    pub fn code(self) -> u16 {
        self as u16
    }

    /// Decode a wire code; `None` for codes this version doesn't know
    /// (a newer peer — callers should treat unknown codes as terminal).
    pub fn from_code(c: u16) -> Option<ErrorCode> {
        match c {
            1 => Some(ErrorCode::Busy),
            2 => Some(ErrorCode::Expired),
            3 => Some(ErrorCode::Closed),
            4 => Some(ErrorCode::BadRequest),
            5 => Some(ErrorCode::Internal),
            6 => Some(ErrorCode::Poisoned),
            _ => None,
        }
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let name = match self {
            ErrorCode::Busy => "busy",
            ErrorCode::Expired => "expired",
            ErrorCode::Closed => "closed",
            ErrorCode::BadRequest => "bad-request",
            ErrorCode::Internal => "internal",
            ErrorCode::Poisoned => "poisoned",
        };
        write!(f, "{name}({})", self.code())
    }
}

/// One rejection shape for every path: submit-time backpressure,
/// pop-time deadline sheds, request validation, connection errors. The
/// numeric fields are 0.0 where they carry no information (only
/// `Expired` rejections have meaningful wait/deadline values).
#[derive(Clone, Debug, PartialEq)]
pub struct Reject {
    pub code: ErrorCode,
    /// The request this rejection answers (0 = connection-level).
    pub id: u64,
    /// Human-readable context. NOT part of the stable protocol — match
    /// on `code`, never on this string.
    pub detail: String,
    /// How long the request sat queued before rejection (ms); 0.0 for
    /// door-level rejections that never queued.
    pub waited_ms: f64,
    /// For `Expired`: the deadline budget (ms from submission) that
    /// could no longer be met. 0.0 otherwise.
    pub deadline_ms: f64,
}

impl Reject {
    fn new(code: ErrorCode, id: u64, detail: impl Into<String>) -> Reject {
        Reject { code, id, detail: detail.into(), waited_ms: 0.0, deadline_ms: 0.0 }
    }

    /// Backpressure: the server is at capacity right now.
    pub fn busy(id: u64, detail: impl Into<String>) -> Reject {
        Reject::new(ErrorCode::Busy, id, detail)
    }

    /// The server (or connection) is gone.
    pub fn closed(id: u64, detail: impl Into<String>) -> Reject {
        Reject::new(ErrorCode::Closed, id, detail)
    }

    /// The request failed validation.
    pub fn bad_request(id: u64, detail: impl Into<String>) -> Reject {
        Reject::new(ErrorCode::BadRequest, id, detail)
    }

    /// The server faulted while serving this request (panicking kernel,
    /// poisoned lane). The lane was quarantined; siblings keep serving.
    pub fn internal(id: u64, detail: impl Into<String>) -> Reject {
        Reject::new(ErrorCode::Internal, id, detail)
    }

    /// The request id is on the poisoned-request blocklist — refused at
    /// admission before it can re-poison a shard batch.
    pub fn poisoned(id: u64, detail: impl Into<String>) -> Reject {
        Reject::new(ErrorCode::Poisoned, id, detail)
    }

    /// A queued job whose absolute deadline passed before admission —
    /// dropped unserved (an SLA miss, never a vanished denominator).
    pub fn expired(id: u64, waited_ms: f64, deadline_ms: f64) -> Reject {
        Reject {
            code: ErrorCode::Expired,
            id,
            detail: format!(
                "deadline {deadline_ms:.1} ms expired after {waited_ms:.1} ms queued"
            ),
            waited_ms,
            deadline_ms,
        }
    }
}

impl std::fmt::Display for Reject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} (req {}): {}", self.code, self.id, self.detail)
    }
}

impl std::error::Error for Reject {}

/// What the server returns per served request.
#[derive(Debug)]
pub struct GenResponse {
    pub result: GenResult,
    /// Admission latency: submit → lane admitted into the shard's
    /// active set (ms).
    pub queued_ms: f64,
    /// End-to-end latency: submit → response (ms).
    pub e2e_ms: f64,
    /// For deadline-tagged requests: whether e2e met the deadline.
    /// `None` for best-effort requests.
    pub deadline_met: Option<bool>,
}

/// Terminal outcome of one request — the SAME enum on the in-process
/// response channel and (encoded) on the socket. `Completed` carries the
/// full response; `Rejected` carries the typed code (`Expired` for
/// deadline sheds, `Busy`/`Closed`/`BadRequest` for door rejections).
#[derive(Debug)]
pub enum Outcome {
    Completed(GenResponse),
    Rejected(Reject),
}

impl Outcome {
    /// The completed response; panics on a rejection (tests and drivers
    /// that know their requests are servable).
    pub fn completed(self) -> GenResponse {
        match self {
            Outcome::Completed(r) => r,
            Outcome::Rejected(rej) => panic!("request was rejected: {rej}"),
        }
    }

    pub fn as_completed(&self) -> Option<&GenResponse> {
        match self {
            Outcome::Completed(r) => Some(r),
            Outcome::Rejected(_) => None,
        }
    }

    pub fn rejected(&self) -> Option<&Reject> {
        match self {
            Outcome::Completed(_) => None,
            Outcome::Rejected(r) => Some(r),
        }
    }

    pub fn is_rejected(&self) -> bool {
        matches!(self, Outcome::Rejected(_))
    }

    /// The rejection code, if any.
    pub fn code(&self) -> Option<ErrorCode> {
        self.rejected().map(|r| r.code)
    }
}

/// A mid-flight progress tick: the lane finished `step` of `total`
/// denoise steps. Only emitted for streaming submissions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Progress {
    pub id: u64,
    pub step: u32,
    pub total: u32,
}

/// One element of a response stream: zero or more `Progress` ticks
/// followed by exactly one terminal `Done`.
#[derive(Debug)]
pub enum Event {
    Progress(Progress),
    Done(Outcome),
}

/// Network-door counters, folded into `ServerReport` at shutdown. All
/// counters are monotonic sums over the server's lifetime.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Connections admitted past the concurrency gate.
    pub conns_accepted: u64,
    /// Connections refused at accept time (`Busy` frame) because the
    /// active-connection budget was exhausted.
    pub conns_door_shed: u64,
    /// Submit frames decoded and offered to the dispatcher.
    pub reqs_submitted: u64,
    /// Requests that completed and streamed a full latent back.
    pub reqs_completed: u64,
    /// Requests shed in-band (deadline expired while queued).
    pub reqs_shed: u64,
    /// Requests refused at the door with `Busy` (every shard queue full)
    /// — cheaper than pop-time shedding: no queue slot, no lane, no
    /// wasted wait.
    pub reqs_door_shed: u64,
    /// The subset of `reqs_door_shed` that carried an SLA deadline.
    /// These count AGAINST `deadline_hit_rate()` — refusing a tagged
    /// request at the door is still an SLA miss.
    pub door_sheds_deadline: u64,
    /// Raw socket traffic (framed bytes, both directions).
    pub bytes_in: u64,
    pub bytes_out: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_codes_are_stable() {
        // These numbers are wire protocol — a change here is a protocol
        // version bump, not a refactor.
        assert_eq!(ErrorCode::Busy.code(), 1);
        assert_eq!(ErrorCode::Expired.code(), 2);
        assert_eq!(ErrorCode::Closed.code(), 3);
        assert_eq!(ErrorCode::BadRequest.code(), 4);
        assert_eq!(ErrorCode::Internal.code(), 5);
        assert_eq!(ErrorCode::Poisoned.code(), 6);
        for c in [
            ErrorCode::Busy,
            ErrorCode::Expired,
            ErrorCode::Closed,
            ErrorCode::BadRequest,
            ErrorCode::Internal,
            ErrorCode::Poisoned,
        ] {
            assert_eq!(ErrorCode::from_code(c.code()), Some(c));
        }
        assert_eq!(ErrorCode::from_code(0), None);
        assert_eq!(ErrorCode::from_code(999), None);
    }

    #[test]
    fn reject_constructors_set_codes() {
        assert_eq!(Reject::busy(1, "q").code, ErrorCode::Busy);
        assert_eq!(Reject::closed(2, "c").code, ErrorCode::Closed);
        assert_eq!(Reject::bad_request(3, "b").code, ErrorCode::BadRequest);
        assert_eq!(Reject::internal(5, "panic").code, ErrorCode::Internal);
        assert_eq!(Reject::poisoned(6, "blocklisted").code, ErrorCode::Poisoned);
        let e = Reject::expired(4, 12.5, 10.0);
        assert_eq!(e.code, ErrorCode::Expired);
        assert_eq!(e.id, 4);
        assert_eq!(e.waited_ms, 12.5);
        assert_eq!(e.deadline_ms, 10.0);
    }

    #[test]
    fn outcome_accessors_distinguish_rejections() {
        let rej = Outcome::Rejected(Reject::expired(9, 1.0, 2.0));
        assert!(rej.is_rejected());
        assert!(rej.as_completed().is_none());
        assert_eq!(rej.code(), Some(ErrorCode::Expired));
        assert_eq!(rej.rejected().unwrap().id, 9);
    }

    #[test]
    #[should_panic(expected = "rejected")]
    fn completed_panics_on_rejection() {
        Outcome::Rejected(Reject::busy(1, "full")).completed();
    }
}
