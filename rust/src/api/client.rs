//! The one client surface: [`GenClient`] + [`ResponseStream`].
//!
//! Both the in-process [`crate::server::Server`] and the remote
//! [`crate::net::NetClient`] implement [`GenClient`], so drivers,
//! examples, and tests are written once against the trait and run
//! unchanged over either transport.

use std::sync::mpsc;

use crate::scheduler::GenRequest;

use super::{Event, Outcome, Reject};

/// A handle to one in-flight request: zero or more [`Event::Progress`]
/// ticks followed by exactly one terminal [`Event::Done`].
///
/// Dropping the stream abandons the request (the server still finishes
/// the work; the terminal event is discarded on the closed channel).
#[derive(Debug)]
pub struct ResponseStream {
    id: u64,
    rx: mpsc::Receiver<Event>,
}

impl ResponseStream {
    /// Wrap a receiving channel. The sender side is owned by whichever
    /// transport services the request (shard worker or socket reader).
    pub fn new(id: u64, rx: mpsc::Receiver<Event>) -> ResponseStream {
        ResponseStream { id, rx }
    }

    /// The request id this stream answers.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block for the next event; `None` once the terminal event has been
    /// taken (or the serving side vanished).
    pub fn recv_event(&self) -> Option<Event> {
        self.rx.recv().ok()
    }

    /// Block until the terminal outcome, discarding progress ticks.
    ///
    /// If the serving side disappears without a terminal event (worker
    /// panic, socket torn down), this degrades to a typed
    /// [`ErrorCode::Closed`](super::ErrorCode::Closed) rejection rather
    /// than hanging or panicking.
    pub fn wait(self) -> Outcome {
        loop {
            match self.rx.recv() {
                Ok(Event::Progress(_)) => continue,
                Ok(Event::Done(outcome)) => return outcome,
                Err(_) => {
                    return Outcome::Rejected(Reject::closed(
                        self.id,
                        "response channel closed before terminal event",
                    ))
                }
            }
        }
    }
}

/// The one client API. `submit` answers with a terminal outcome only;
/// `submit_streaming` additionally delivers per-step progress. Both
/// return `Err(Reject)` when the request is refused up front (backpressure,
/// validation, closed transport) — the same [`Reject`] that in-band
/// rejections carry, so callers handle one error shape.
pub trait GenClient {
    /// Submit a request; progress ticks suppressed.
    fn submit(&self, req: &GenRequest) -> Result<ResponseStream, Reject>;

    /// Submit a request with per-step [`Event::Progress`] ticks.
    fn submit_streaming(&self, req: &GenRequest) -> Result<ResponseStream, Reject>;

    /// Submit and block to completion, retrying `Busy` rejections with a
    /// short backoff. Non-retryable rejections (and in-band sheds) come
    /// back as `Outcome::Rejected`.
    fn generate(&self, req: &GenRequest) -> Outcome {
        loop {
            match self.submit(req) {
                Ok(stream) => return stream.wait(),
                Err(rej) if rej.code == super::ErrorCode::Busy => {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                Err(rej) => return Outcome::Rejected(rej),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Progress;

    #[test]
    fn wait_skips_progress_and_returns_terminal() {
        let (tx, rx) = mpsc::channel();
        let stream = ResponseStream::new(7, rx);
        tx.send(Event::Progress(Progress { id: 7, step: 1, total: 2 })).unwrap();
        tx.send(Event::Done(Outcome::Rejected(Reject::expired(7, 3.0, 1.0)))).unwrap();
        let out = stream.wait();
        assert_eq!(out.code(), Some(crate::api::ErrorCode::Expired));
    }

    #[test]
    fn wait_degrades_to_closed_on_dropped_sender() {
        let (tx, rx) = mpsc::channel::<Event>();
        drop(tx);
        let out = ResponseStream::new(3, rx).wait();
        let rej = out.rejected().expect("must be a rejection");
        assert_eq!(rej.code, crate::api::ErrorCode::Closed);
        assert_eq!(rej.id, 3);
    }

    #[test]
    fn recv_event_yields_events_in_order() {
        let (tx, rx) = mpsc::channel();
        let stream = ResponseStream::new(1, rx);
        tx.send(Event::Progress(Progress { id: 1, step: 1, total: 3 })).unwrap();
        tx.send(Event::Progress(Progress { id: 1, step: 2, total: 3 })).unwrap();
        drop(tx);
        match stream.recv_event() {
            Some(Event::Progress(p)) => assert_eq!(p.step, 1),
            other => panic!("expected progress, got {other:?}"),
        }
        match stream.recv_event() {
            Some(Event::Progress(p)) => assert_eq!(p.step, 2),
            other => panic!("expected progress, got {other:?}"),
        }
        assert!(stream.recv_event().is_none());
    }
}
