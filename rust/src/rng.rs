//! Deterministic RNG for weight init, workload synthesis, and tests.
//!
//! The `rand` crate is not vendored in the offline registry, so this is a
//! self-contained xoshiro256++ seeded through SplitMix64 (the reference
//! construction from Blackman & Vigna), plus Box–Muller normals. Every
//! consumer takes an explicit seed so runs are reproducible end to end.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, spare: None }
    }

    /// Derive an independent stream (for per-layer / per-request seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f32::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments_reasonable() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn fork_streams_diverge() {
        let mut base = Rng::new(5);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
