//! PAB-style static caching: a fixed broadcast period — recompute on every
//! k-th step, reuse otherwise, independent of content (the pyramid
//! attention broadcast baseline reduced to its temporal schedule).

use crate::config::PolicyKind;

use super::{BlockAction, BlockCtx, CachePolicy, StepInfo};

pub struct StaticCache {
    period: usize,
    compute_this_step: bool,
}

impl StaticCache {
    pub fn new(period: usize) -> StaticCache {
        assert!(period >= 1);
        StaticCache { period, compute_this_step: true }
    }
}

impl CachePolicy for StaticCache {
    fn kind(&self) -> PolicyKind {
        PolicyKind::StaticCache
    }

    fn begin_step(&mut self, info: &StepInfo) {
        self.compute_this_step = info.step % self.period == 0;
    }

    fn decide(&mut self, ctx: &BlockCtx) -> BlockAction {
        if ctx.delta.is_none() || self.compute_this_step {
            BlockAction::Compute
        } else {
            BlockAction::Reuse
        }
    }

    fn reset(&mut self) {
        self.compute_this_step = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn period_2_alternates() {
        let mut p = StaticCache::new(2);
        let ctx = |step| BlockCtx { layer: 0, num_layers: 3, step, delta: Some(0.2), nd: 64 };
        let mut acts = Vec::new();
        for s in 0..4 {
            p.begin_step(&StepInfo { step: s, num_steps: 50, temb_delta: 0.0, input_delta: 0.0 });
            acts.push(p.decide(&ctx(s)));
        }
        assert_eq!(
            acts,
            vec![
                BlockAction::Compute,
                BlockAction::Reuse,
                BlockAction::Compute,
                BlockAction::Reuse
            ]
        );
    }

    #[test]
    fn period_1_is_nocache() {
        let mut p = StaticCache::new(1);
        for s in 0..5 {
            p.begin_step(&StepInfo { step: s, num_steps: 50, temb_delta: 0.0, input_delta: 0.0 });
            let ctx = BlockCtx { layer: 0, num_layers: 3, step: s, delta: Some(0.0), nd: 64 };
            assert_eq!(p.decide(&ctx), BlockAction::Compute);
        }
    }

    #[test]
    fn cold_cache_always_computes() {
        let mut p = StaticCache::new(4);
        p.begin_step(&StepInfo { step: 1, num_steps: 50, temb_delta: 0.0, input_delta: 0.0 });
        let ctx = BlockCtx { layer: 0, num_layers: 3, step: 1, delta: None, nd: 64 };
        assert_eq!(p.decide(&ctx), BlockAction::Compute);
    }
}
