//! The paper's policy: per-(step, layer) χ² hypothesis test on the relative
//! hidden-state change (Eq. 4–7); on "not significant", substitute the
//! learnable linear approximation (Eq. 6) instead of running the block.
//!
//! SC off (ablation) degrades to always-compute here; the STR and MB
//! modules live in the scheduler/engine (token partition and blending act
//! on tensors, not decisions).

use crate::config::{ApproxMode, FastCacheConfig, PolicyKind};

use super::decision::Chi2Rule;
use super::{BlockAction, BlockCtx, CachePolicy};

pub struct FastCachePolicy {
    rule: Chi2Rule,
    enable_sc: bool,
    approx: ApproxMode,
}

impl FastCachePolicy {
    pub fn new(cfg: &FastCacheConfig) -> FastCachePolicy {
        FastCachePolicy {
            rule: Chi2Rule::new(cfg.alpha, cfg.tau_delta0),
            enable_sc: cfg.enable_sc,
            approx: cfg.approx,
        }
    }

    pub fn error_bound(&mut self, nd: usize) -> f64 {
        self.rule.error_bound(nd)
    }
}

impl CachePolicy for FastCachePolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::FastCache
    }

    fn decide(&mut self, ctx: &BlockCtx) -> BlockAction {
        if !self.enable_sc {
            return BlockAction::Compute;
        }
        let Some(delta) = ctx.delta else {
            return BlockAction::Compute; // first step: nothing cached
        };
        if self.rule.should_skip(delta, ctx.nd) {
            match self.approx {
                ApproxMode::Reuse => BlockAction::Reuse,
                ApproxMode::DiagAffine | ApproxMode::FullMatrix => BlockAction::Approx,
            }
        } else {
            BlockAction::Compute
        }
    }

    fn relax(&mut self, factor: f64) {
        self.rule.relax(factor);
    }

    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(delta: Option<f64>, nd: usize) -> BlockCtx {
        BlockCtx { layer: 2, num_layers: 12, step: 5, delta, nd }
    }

    #[test]
    fn first_step_computes() {
        let mut p = FastCachePolicy::new(&FastCacheConfig::default());
        assert_eq!(p.decide(&ctx(None, 6144)), BlockAction::Compute);
    }

    #[test]
    fn small_delta_approximates_large_computes() {
        let cfg = FastCacheConfig::default(); // delta0=0.15, alpha=0.05
        let mut p = FastCachePolicy::new(&cfg);
        assert_eq!(p.decide(&ctx(Some(0.01), 6144)), BlockAction::Approx);
        assert_eq!(p.decide(&ctx(Some(0.5), 6144)), BlockAction::Compute);
    }

    #[test]
    fn sc_disabled_always_computes() {
        let cfg = FastCacheConfig { enable_sc: false, ..FastCacheConfig::default() };
        let mut p = FastCachePolicy::new(&cfg);
        assert_eq!(p.decide(&ctx(Some(0.0), 6144)), BlockAction::Compute);
    }

    #[test]
    fn reuse_mode_reuses() {
        let cfg = FastCacheConfig { approx: ApproxMode::Reuse, ..FastCacheConfig::default() };
        let mut p = FastCachePolicy::new(&cfg);
        assert_eq!(p.decide(&ctx(Some(0.01), 6144)), BlockAction::Reuse);
    }

    #[test]
    fn relax_widens_the_skip_region() {
        let cfg = FastCacheConfig::default();
        let mut p = FastCachePolicy::new(&cfg);
        let nd = 64 * 96;
        let t = Chi2Rule::new(cfg.alpha, cfg.tau_delta0).threshold_sq(nd).sqrt();
        // Just above the stock threshold: computed...
        assert_eq!(p.decide(&ctx(Some(t * 1.5), nd)), BlockAction::Compute);
        // ...but inside the skip region after a 2x relax (rung 1).
        p.relax(2.0);
        assert_eq!(p.decide(&ctx(Some(t * 1.5), nd)), BlockAction::Approx);
    }

    #[test]
    fn alpha_sweep_changes_skip_region() {
        // delta chosen between the two thresholds.
        let nd = 64 * 288;
        let loose = FastCacheConfig { alpha: 0.01, ..FastCacheConfig::default() };
        let strict = FastCacheConfig { alpha: 0.30, ..FastCacheConfig::default() };
        let mut pl = FastCachePolicy::new(&loose);
        let mut ps = FastCachePolicy::new(&strict);
        let tl = Chi2Rule::new(0.01, 0.15).threshold_sq(nd).sqrt();
        let ts = Chi2Rule::new(0.30, 0.15).threshold_sq(nd).sqrt();
        let mid = 0.5 * (tl + ts);
        assert_eq!(pl.decide(&ctx(Some(mid), nd)), BlockAction::Approx);
        assert_eq!(ps.decide(&ctx(Some(mid), nd)), BlockAction::Compute);
    }
}
