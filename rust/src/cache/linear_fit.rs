//! The *learnable* linear approximation (paper Eq. 3/6): Ĥ = W·H + b.
//!
//! The paper trains a D×D linear layer per block offline. Serving-side we
//! fit the same regression ONLINE, per channel (diagonal W plus bias):
//! whenever a block is actually computed we feed (input, output) token
//! pairs into per-channel sufficient statistics (PairStats), and when the
//! χ² test says "skip" we apply the fitted affine map. Exponential
//! forgetting tracks the temporal drift of hidden dynamics (Appendix A).
//!
//! This is the cheap estimator of the paper's regression: O(D) state per
//! layer, O(N·D) apply cost — and it strictly dominates raw reuse in
//! approximation error (tested below), which is what the paper's FID
//! ordering needs. The full-matrix variant (ApproxMode::FullMatrix) runs
//! the AOT Pallas matmul artifact with a W calibrated from the same
//! statistics lifted to a diagonal matrix.

use crate::stats::PairStats;
use crate::tensor::Tensor;

/// Cap on the pooled per-channel row count after a fleet-store merge:
/// bounds the inertia of a warm-started fit so fresh per-request evidence
/// (which decays old rows at `fit_decay` per update anyway) can still move
/// the coefficients within a few steps.
const MERGE_ROW_CAP: u64 = 16_384;

#[derive(Clone, Debug)]
pub struct AffineFit {
    d: usize,
    chan: Vec<PairStats>,
    decay: f64,
    updates: u64,
}

impl AffineFit {
    pub fn new(d: usize, decay: f64) -> AffineFit {
        AffineFit { d, chan: vec![PairStats::new(); d], decay, updates: 0 }
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn updates(&self) -> u64 {
        self.updates
    }

    pub fn decay_factor(&self) -> f64 {
        self.decay
    }

    /// The per-channel sufficient statistics (snapshot serialization).
    pub fn channels(&self) -> &[PairStats] {
        &self.chan
    }

    /// Rebuild a fit from its serialized parts (warm-store snapshot
    /// restore). `chan.len()` defines D.
    pub fn from_parts(decay: f64, updates: u64, chan: Vec<PairStats>) -> AffineFit {
        AffineFit { d: chan.len(), chan, decay, updates }
    }

    /// Feed a computed (input, output) pair. Shapes [N, D] (or [B, N, D]
    /// flattened — any leading structure collapses to rows of D).
    pub fn update(&mut self, input: &Tensor, output: &Tensor) {
        assert_eq!(input.shape(), output.shape());
        assert_eq!(input.len() % self.d, 0);
        self.updates += 1;
        for c in self.chan.iter_mut() {
            c.decay(self.decay);
        }
        for (ri, ro) in input.data().chunks(self.d).zip(output.data().chunks(self.d)) {
            for j in 0..self.d {
                self.chan[j].push(ri[j] as f64, ro[j] as f64);
            }
        }
    }

    /// Per-channel (a, b) coefficients.
    pub fn coeffs(&self) -> (Vec<f32>, Vec<f32>) {
        let mut a = Vec::with_capacity(self.d);
        let mut b = Vec::with_capacity(self.d);
        for c in &self.chan {
            let (ai, bi) = c.fit();
            a.push(ai);
            b.push(bi);
        }
        (a, b)
    }

    /// Apply the fit: Ĥ[:, j] = a_j·H[:, j] + b_j. Identity before any
    /// update (the conservative fallback).
    pub fn apply(&self, input: &Tensor) -> Tensor {
        let (a, b) = self.coeffs();
        let mut out = input.clone();
        for row in out.data_mut().chunks_mut(self.d) {
            for j in 0..self.d {
                row[j] = a[j] * row[j] + b[j];
            }
        }
        out
    }

    /// Replace this fit's statistics with `source`'s (same D), keeping the
    /// OWN decay factor: a lane warm-starting from the fleet store adopts
    /// the stored evidence but keeps tracking drift at its configured rate.
    /// The source is a snapshot — later store mutations don't reach us.
    pub fn adopt(&mut self, source: &AffineFit) {
        assert_eq!(
            self.d, source.d,
            "warm-start fit dimension mismatch: {} vs {}",
            self.d, source.d
        );
        self.chan = source.chan.clone();
        self.updates = source.updates;
    }

    /// Pool another fit's evidence into this one (channel-wise sufficient-
    /// statistic merge), capping the pooled row count so the merged fit
    /// stays responsive. This is the store's publish path: every retiring
    /// lane folds its converged fit into the fleet entry.
    pub fn merge_from(&mut self, other: &AffineFit) {
        assert_eq!(self.d, other.d, "fit merge dimension mismatch");
        for (c, o) in self.chan.iter_mut().zip(&other.chan) {
            c.merge(o);
            let n = c.count();
            if n > MERGE_ROW_CAP {
                c.decay(MERGE_ROW_CAP as f64 / n as f64);
            }
        }
        self.updates = self.updates.saturating_add(other.updates);
    }

    /// Heap footprint of this fit's state (per-channel sufficient
    /// statistics) — what the byte-budgeted warm-start store accounts per
    /// entry.
    pub fn size_bytes(&self) -> usize {
        self.d * std::mem::size_of::<PairStats>() + std::mem::size_of::<AffineFit>()
    }

    /// Lift the diagonal fit to a full [D, D] matrix + bias (inputs to the
    /// AOT linear_approx artifact).
    pub fn to_full_matrix(&self) -> (Tensor, Tensor) {
        let (a, b) = self.coeffs();
        let mut w = Tensor::zeros(&[self.d, self.d]);
        for j in 0..self.d {
            w.data_mut()[j * self.d + j] = a[j];
        }
        (w, Tensor::new(b, &[self.d]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rnd(seed: u64, shape: &[usize]) -> Tensor {
        let mut r = Rng::new(seed);
        Tensor::new(r.normal_vec(shape.iter().product(), 1.0), shape)
    }

    #[test]
    fn identity_before_updates() {
        let f = AffineFit::new(8, 0.98);
        let x = rnd(1, &[16, 8]);
        assert!(f.apply(&x).max_abs_diff(&x) < 1e-7);
    }

    #[test]
    fn recovers_exact_channelwise_affine() {
        let d = 8;
        let mut f = AffineFit::new(d, 1.0);
        let x = rnd(2, &[64, d]);
        let mut y = x.clone();
        for row in y.data_mut().chunks_mut(d) {
            for j in 0..d {
                row[j] = (j as f32 * 0.25 + 0.5) * row[j] - 1.5 + j as f32 * 0.1;
            }
        }
        f.update(&x, &y);
        let got = f.apply(&x);
        assert!(got.max_abs_diff(&y) < 1e-3, "err={}", got.max_abs_diff(&y));
    }

    #[test]
    fn beats_raw_reuse_on_scaled_dynamics() {
        // Model a block whose output is ~0.9x its input drifting over
        // steps: the affine fit must approximate the CURRENT output better
        // than reusing the PREVIOUS output (the paper's key claim for
        // learnable approximation vs plain caching).
        let d = 16;
        let n = 32;
        let mut f = AffineFit::new(d, 0.95);
        let mut prev_out: Option<Tensor> = None;
        let mut err_fit = 0.0f64;
        let mut err_reuse = 0.0f64;
        for step in 0..30 {
            let x = rnd(100 + step, &[n, d]);
            let mut y = x.clone();
            for v in y.data_mut().iter_mut() {
                *v *= 0.9;
            }
            if step >= 5 {
                let approx = f.apply(&x);
                err_fit += approx.max_abs_diff(&y) as f64;
                if let Some(p) = &prev_out {
                    err_reuse += p.max_abs_diff(&y) as f64;
                }
            }
            f.update(&x, &y);
            prev_out = Some(y);
        }
        assert!(
            err_fit < 0.5 * err_reuse,
            "fit err {err_fit} should beat reuse err {err_reuse}"
        );
    }

    #[test]
    fn adopt_transfers_coefficients_and_keeps_decay() {
        let d = 8;
        let mut teacher = AffineFit::new(d, 1.0);
        let x = rnd(9, &[64, d]);
        let mut y = x.clone();
        for v in y.data_mut().iter_mut() {
            *v = 0.8 * *v + 0.2;
        }
        teacher.update(&x, &y);

        let mut student = AffineFit::new(d, 0.9);
        student.adopt(&teacher);
        assert_eq!(student.updates(), teacher.updates());
        let x2 = rnd(10, &[16, d]);
        assert!(student.apply(&x2).max_abs_diff(&teacher.apply(&x2)) < 1e-7);
        // The student still forgets at its own rate: a regime change must
        // win within a few updates despite the adopted evidence.
        for step in 0..40 {
            let xs = rnd(50 + step, &[64, d]);
            let mut ys = xs.clone();
            for v in ys.data_mut().iter_mut() {
                *v *= -0.5;
            }
            student.update(&xs, &ys);
        }
        let (a, _) = student.coeffs();
        assert!((a[0] + 0.5).abs() < 0.1, "a={}", a[0]);
    }

    #[test]
    fn merge_pools_evidence_from_both_fits() {
        let d = 4;
        // Two fits each see half the sample of y = 2x + 1; the merge must
        // recover the same line as one fit over everything.
        let xa = rnd(11, &[32, d]);
        let xb = rnd(12, &[32, d]);
        let f_of = |x: &Tensor| {
            let mut y = x.clone();
            for v in y.data_mut().iter_mut() {
                *v = 2.0 * *v + 1.0;
            }
            y
        };
        let mut fa = AffineFit::new(d, 1.0);
        fa.update(&xa, &f_of(&xa));
        let mut fb = AffineFit::new(d, 1.0);
        fb.update(&xb, &f_of(&xb));
        fa.merge_from(&fb);
        assert_eq!(fa.updates(), 2);
        let (a, b) = fa.coeffs();
        for j in 0..d {
            assert!((a[j] - 2.0).abs() < 1e-4, "a[{j}]={}", a[j]);
            assert!((b[j] - 1.0).abs() < 1e-4, "b[{j}]={}", b[j]);
        }
        assert!(fa.size_bytes() > 0);
    }

    #[test]
    fn full_matrix_matches_diag_apply() {
        let d = 6;
        let mut f = AffineFit::new(d, 1.0);
        let x = rnd(5, &[32, d]);
        let mut y = x.clone();
        for row in y.data_mut().chunks_mut(d) {
            for j in 0..d {
                row[j] = 1.7 * row[j] + 0.3;
            }
        }
        f.update(&x, &y);
        let (w, b) = f.to_full_matrix();
        let x2 = rnd(6, &[4, d]);
        let diag = f.apply(&x2);
        // x2 @ W + b with diagonal W.
        let mut full = x2.clone();
        for row in full.data_mut().chunks_mut(d) {
            for j in 0..d {
                row[j] = row[j] * w.data()[j * d + j] + b.data()[j];
            }
        }
        assert!(diag.max_abs_diff(&full) < 1e-6);
    }
}
