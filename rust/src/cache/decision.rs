//! The χ² cache decision rule (paper Eq. 4–9), with the scale calibration
//! that makes it operational.
//!
//! **Faithfulness note** (also DESIGN.md §7): the paper states the rule as
//! δ²_{t,l} ≤ χ²_{ND,1−α}/ND. At serving sizes ND ≥ 6144 the right-hand
//! side is ≈ 1.0 — i.e. "skip unless the hidden state changed by ~100%",
//! which would cache *every* block of *any* real trajectory, and the α
//! sweep of the paper's Fig. 3 could not change the caching rate (the
//! quantile moves by <1% across α ∈ [0.01, 0.1]). The rule as written
//! implicitly assumes the per-element change is unit-variance relative to
//! the signal. We therefore scale the test by a noise floor δ₀ (config
//! `tau_delta0`, the paper's "sliding window to track δ_t" remark):
//!
//! ```text
//! skip  ⇔  δ² ≤ δ₀² · χ²_{ND,1−α}/ND
//! ```
//!
//! which preserves the test's form, its α-sensitivity, and the error bound
//! ε_cache = δ₀·√(χ²_{ND,1−α}/ND) (Eq. 9 scaled by the same δ₀).

use crate::stats::chi2::{chi2_quantile, delta_sq_threshold};

#[derive(Clone, Debug)]
pub struct Chi2Rule {
    alpha: f64,
    /// Noise-floor relative change δ₀.
    delta0: f64,
    /// Cached quantile factor per ND (tiny map; ND varies with token
    /// buckets only).
    cached: Vec<(usize, f64)>,
}

impl Chi2Rule {
    pub fn new(alpha: f64, delta0: f64) -> Chi2Rule {
        assert!(alpha > 0.0 && alpha < 1.0);
        assert!(delta0 > 0.0);
        Chi2Rule { alpha, delta0, cached: Vec::new() }
    }

    fn factor(&mut self, nd: usize) -> f64 {
        if let Some((_, f)) = self.cached.iter().find(|(k, _)| *k == nd) {
            return *f;
        }
        let f = delta_sq_threshold(nd, self.alpha);
        self.cached.push((nd, f));
        f
    }

    /// The operational threshold on δ².
    pub fn threshold_sq(&mut self, nd: usize) -> f64 {
        self.delta0 * self.delta0 * self.factor(nd)
    }

    /// Eq. 7 (scaled): should this block be skipped?
    pub fn should_skip(&mut self, delta: f64, nd: usize) -> bool {
        delta * delta <= self.threshold_sq(nd)
    }

    /// Eq. 9 (scaled): bound on the relative deviation of a cached use.
    pub fn error_bound(&mut self, nd: usize) -> f64 {
        self.threshold_sq(nd).sqrt()
    }

    /// Relax the noise floor δ₀ by `factor` (degrade ladder rung 1).
    /// The skip region — and the Eq. 9 error bound — grow with it: this
    /// is an explicit quality-for-latency trade, never applied silently.
    pub fn relax(&mut self, factor: f64) {
        assert!(factor.is_finite() && factor > 0.0);
        self.delta0 *= factor;
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// The literal paper rule (unscaled), kept for the ablation bench that
    /// demonstrates its degeneracy.
    pub fn paper_literal_threshold_sq(nd: usize, alpha: f64) -> f64 {
        chi2_quantile(1.0 - alpha, nd as f64) / nd as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skip_iff_below_threshold() {
        let mut r = Chi2Rule::new(0.05, 0.15);
        let nd = 64 * 96;
        let t = r.threshold_sq(nd).sqrt();
        assert!(r.should_skip(t * 0.99, nd));
        assert!(!r.should_skip(t * 1.01, nd));
    }

    #[test]
    fn alpha_modulates_threshold() {
        let nd = 64 * 288;
        let mut strict = Chi2Rule::new(0.10, 0.15);
        let mut loose = Chi2Rule::new(0.01, 0.15);
        // Smaller alpha => larger quantile => larger skip region.
        assert!(loose.threshold_sq(nd) > strict.threshold_sq(nd));
    }

    #[test]
    fn delta0_scales_quadratically() {
        let nd = 1024;
        let mut a = Chi2Rule::new(0.05, 0.1);
        let mut b = Chi2Rule::new(0.05, 0.2);
        let ratio = b.threshold_sq(nd) / a.threshold_sq(nd);
        assert!((ratio - 4.0).abs() < 1e-9);
    }

    #[test]
    fn paper_literal_rule_is_degenerate_at_serving_sizes() {
        // Documents WHY the scale calibration exists: the literal threshold
        // admits ~100% relative change.
        let t = Chi2Rule::paper_literal_threshold_sq(64 * 288, 0.05);
        assert!(t > 0.95 && t < 1.1, "literal threshold_sq = {t}");
    }

    #[test]
    fn error_bound_consistent() {
        let mut r = Chi2Rule::new(0.05, 0.15);
        let nd = 64 * 192;
        let eb = r.error_bound(nd);
        assert!((eb * eb - r.threshold_sq(nd)).abs() < 1e-12);
        // Bound is close to delta0 (the quantile factor is ~1).
        assert!((eb - 0.15).abs() < 0.01, "eb={eb}");
    }

    #[test]
    fn factor_cache_consistent() {
        let mut r = Chi2Rule::new(0.05, 0.15);
        let a = r.threshold_sq(6144);
        let b = r.threshold_sq(6144);
        assert_eq!(a, b);
        let c = r.threshold_sq(2048);
        assert_ne!(a, c);
    }
}
