//! AdaCache (Kahatapitiya et al. 2024): content-adaptive caching — the
//! distance between the current and cached representations sets a
//! *recompute interval*: similar content stretches the interval (more
//! reuse), dissimilar content shrinks it to 1 (always compute). Decisions
//! are step-granular, matching the published block-skipping-over-time
//! scheme.

use crate::config::PolicyKind;

use super::{BlockAction, BlockCtx, CachePolicy, StepInfo};

pub struct AdaCache {
    /// Distance knee: input_delta at/above which the interval collapses to 1.
    knee: f64,
    /// Steps remaining until the next forced compute.
    until_compute: usize,
    computing_this_step: bool,
    cold: bool,
}

impl AdaCache {
    pub fn new(knee: f64) -> AdaCache {
        AdaCache { knee, until_compute: 0, computing_this_step: true, cold: true }
    }

    /// Map a content distance to a reuse interval (codebook-style rate
    /// schedule: tiny change -> reuse up to 4 steps; large -> none).
    fn interval(&self, dist: f64) -> usize {
        let r = (dist / self.knee).max(0.0);
        if r >= 1.0 {
            0
        } else if r >= 0.5 {
            1
        } else if r >= 0.25 {
            2
        } else {
            4
        }
    }
}

impl CachePolicy for AdaCache {
    fn kind(&self) -> PolicyKind {
        PolicyKind::AdaCache
    }

    fn begin_step(&mut self, info: &StepInfo) {
        if info.step == 0 {
            self.cold = true;
            self.computing_this_step = true;
            self.until_compute = 0;
            return;
        }
        self.cold = false;
        if self.until_compute == 0 {
            self.computing_this_step = true;
            self.until_compute = self.interval(info.input_delta);
        } else {
            self.computing_this_step = false;
            self.until_compute -= 1;
        }
    }

    fn decide(&mut self, ctx: &BlockCtx) -> BlockAction {
        if self.cold || ctx.delta.is_none() {
            return BlockAction::Compute;
        }
        if self.computing_this_step {
            BlockAction::Compute
        } else {
            BlockAction::Reuse
        }
    }

    fn reset(&mut self) {
        self.until_compute = 0;
        self.computing_this_step = true;
        self.cold = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(step: usize, input_delta: f64) -> StepInfo {
        StepInfo { step, num_steps: 50, temb_delta: 0.0, input_delta }
    }

    fn ctx(delta: Option<f64>) -> BlockCtx {
        BlockCtx { layer: 1, num_layers: 6, step: 1, delta, nd: 6144 }
    }

    #[test]
    fn cold_start_computes() {
        let mut p = AdaCache::new(0.05);
        p.begin_step(&info(0, 0.0));
        assert_eq!(p.decide(&ctx(None)), BlockAction::Compute);
    }

    #[test]
    fn static_content_reuses_many_steps() {
        let mut p = AdaCache::new(0.05);
        p.begin_step(&info(0, 0.0));
        let _ = p.decide(&ctx(None));
        let mut reuse_count = 0;
        for s in 1..=10 {
            p.begin_step(&info(s, 0.001)); // near-static
            if p.decide(&ctx(Some(0.001))) == BlockAction::Reuse {
                reuse_count += 1;
            }
        }
        assert!(reuse_count >= 6, "reuse_count={reuse_count}");
    }

    #[test]
    fn dynamic_content_computes_every_step() {
        let mut p = AdaCache::new(0.05);
        p.begin_step(&info(0, 0.0));
        let _ = p.decide(&ctx(None));
        for s in 1..=5 {
            p.begin_step(&info(s, 0.5)); // high motion
            assert_eq!(p.decide(&ctx(Some(0.5))), BlockAction::Compute, "step {s}");
        }
    }

    #[test]
    fn interval_monotone_in_distance() {
        let p = AdaCache::new(0.05);
        assert!(p.interval(0.001) >= p.interval(0.02));
        assert!(p.interval(0.02) >= p.interval(0.04));
        assert_eq!(p.interval(0.1), 0);
    }
}
