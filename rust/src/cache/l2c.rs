//! Learning-to-Cache (Ma et al. 2024): a *learned, static* per-(step,
//! layer) skip schedule. The published method trains a router; here the
//! router is "trained" by a calibration rollout — run one NoCache
//! trajectory, record per-(step, layer) deltas, and skip the sites whose
//! calibration delta falls below the threshold. Uncalibrated, it falls
//! back to a structural prior (later denoising steps and deeper layers are
//! more skippable), matching the shape of the published learned schedules.

use crate::config::PolicyKind;

use super::{BlockAction, BlockCtx, CachePolicy, StepInfo};

pub struct L2C {
    threshold: f64,
    num_layers: usize,
    /// Calibrated per-(step, layer) deltas, if a calibration ran.
    calibrated: Option<Vec<Vec<f64>>>,
    step: usize,
    num_steps: usize,
}

impl L2C {
    pub fn new(threshold: f64, num_layers: usize) -> L2C {
        L2C { threshold, num_layers, calibrated: None, step: 0, num_steps: 50 }
    }

    /// Install a calibration table: deltas[step][layer] recorded from a
    /// full-compute rollout on representative inputs.
    pub fn calibrate(&mut self, deltas: Vec<Vec<f64>>) {
        assert!(deltas.iter().all(|row| row.len() == self.num_layers));
        self.calibrated = Some(deltas);
    }

    pub fn is_calibrated(&self) -> bool {
        self.calibrated.is_some()
    }

    /// Structural prior used when no calibration is available: a smooth
    /// proxy for the learned schedule — progress through denoising lowers
    /// the pseudo-delta, depth lowers it further.
    fn prior_delta(&self, step: usize, num_steps: usize, layer: usize) -> f64 {
        let t = 1.0 - step as f64 / num_steps.max(1) as f64; // 1 -> 0
        let depth = 1.0 - 0.5 * layer as f64 / self.num_layers.max(1) as f64;
        0.3 * t * depth
    }
}

impl CachePolicy for L2C {
    fn kind(&self) -> PolicyKind {
        PolicyKind::L2C
    }

    fn begin_step(&mut self, info: &StepInfo) {
        self.step = info.step;
        self.num_steps = info.num_steps;
    }

    fn decide(&mut self, ctx: &BlockCtx) -> BlockAction {
        if ctx.delta.is_none() {
            return BlockAction::Compute; // cold cache
        }
        let cal = match &self.calibrated {
            Some(table) => table
                .get(ctx.step)
                .and_then(|row| row.get(ctx.layer))
                .copied()
                .unwrap_or(f64::INFINITY),
            None => self.prior_delta(ctx.step, self.num_steps, ctx.layer),
        };
        if cal < self.threshold {
            BlockAction::Reuse
        } else {
            BlockAction::Compute
        }
    }

    fn reset(&mut self) {
        self.step = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(step: usize, layer: usize, delta: Option<f64>) -> BlockCtx {
        BlockCtx { layer, num_layers: 4, step, delta, nd: 6144 }
    }

    #[test]
    fn calibrated_schedule_is_followed() {
        let mut p = L2C::new(0.1, 4);
        // step 0: all large; step 1: layer 2 small.
        p.calibrate(vec![vec![0.5; 4], vec![0.5, 0.5, 0.01, 0.5]]);
        assert_eq!(p.decide(&ctx(1, 2, Some(0.3))), BlockAction::Reuse);
        assert_eq!(p.decide(&ctx(1, 1, Some(0.3))), BlockAction::Compute);
        assert_eq!(p.decide(&ctx(0, 2, Some(0.3))), BlockAction::Compute);
    }

    #[test]
    fn decisions_are_static_wrt_runtime_delta() {
        // The learned schedule ignores the observed delta value (that is
        // what makes L2C fragile — the paper's Tab. 10 story).
        let mut p = L2C::new(0.1, 4);
        p.calibrate(vec![vec![0.01; 4]]);
        assert_eq!(p.decide(&ctx(0, 0, Some(99.0))), BlockAction::Reuse);
    }

    #[test]
    fn cold_cache_computes() {
        let mut p = L2C::new(0.1, 4);
        assert_eq!(p.decide(&ctx(0, 0, None)), BlockAction::Compute);
    }

    #[test]
    fn higher_threshold_skips_more_under_prior() {
        let mk = |thr: f64| {
            let mut p = L2C::new(thr, 4);
            let mut skipped = 0;
            for step in 0..50 {
                for layer in 0..4 {
                    if p.decide(&ctx(step, layer, Some(0.2))) == BlockAction::Reuse {
                        skipped += 1;
                    }
                }
            }
            skipped
        };
        assert!(mk(0.15) > mk(0.05));
    }

    #[test]
    fn out_of_range_step_computes() {
        let mut p = L2C::new(0.1, 4);
        p.calibrate(vec![vec![0.01; 4]]);
        assert_eq!(p.decide(&ctx(7, 0, Some(0.0))), BlockAction::Compute);
    }
}
