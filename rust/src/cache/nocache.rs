//! Baseline: no caching — every block computes every step (the paper's
//! "No Cache" reference rows, and the source of the FID-proxy reference
//! distribution).

use crate::config::PolicyKind;

use super::{BlockAction, BlockCtx, CachePolicy};

pub struct NoCache;

impl CachePolicy for NoCache {
    fn kind(&self) -> PolicyKind {
        PolicyKind::NoCache
    }

    fn decide(&mut self, _ctx: &BlockCtx) -> BlockAction {
        BlockAction::Compute
    }

    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_computes() {
        let mut p = NoCache;
        for layer in 0..20 {
            let ctx = BlockCtx {
                layer,
                num_layers: 20,
                step: 3,
                delta: Some(0.0),
                nd: 6144,
            };
            assert_eq!(p.decide(&ctx), BlockAction::Compute);
        }
    }
}
