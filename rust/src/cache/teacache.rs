//! TeaCache (Liu et al. 2024): "timestep embedding tells" — the relative
//! change of the timestep-embedding-modulated INPUT between steps,
//! accumulated since the last full compute, gates whole-step reuse (the
//! published method rescales this distance with a fitted polynomial, then
//! thresholds the accumulator). When the accumulated modulated change
//! stays under the threshold the entire step reuses the cache; crossing it
//! forces a full compute and resets the accumulator.

use crate::config::PolicyKind;

use super::{BlockAction, BlockCtx, CachePolicy, StepInfo};

pub struct TeaCache {
    threshold: f64,
    accumulated: f64,
    skip_step: bool,
    /// Polynomial rescale of the raw temb delta (TeaCache fits a small
    /// polynomial mapping embedding distance to output distance; we use the
    /// monotone quadratic y = x + 2x², a fixed stand-in with the same
    /// shape).
    had_history: bool,
}

impl TeaCache {
    pub fn new(threshold: f64) -> TeaCache {
        TeaCache { threshold, accumulated: 0.0, skip_step: false, had_history: false }
    }

    fn rescale(x: f64) -> f64 {
        x + 2.0 * x * x
    }
}

impl CachePolicy for TeaCache {
    fn kind(&self) -> PolicyKind {
        PolicyKind::TeaCache
    }

    fn begin_step(&mut self, info: &StepInfo) {
        if info.step == 0 {
            self.skip_step = false;
            self.accumulated = 0.0;
            self.had_history = false;
            return;
        }
        self.had_history = true;
        self.accumulated += Self::rescale(info.input_delta.max(0.0).min(10.0));
        if self.accumulated < self.threshold {
            self.skip_step = true;
        } else {
            self.skip_step = false;
            self.accumulated = 0.0;
        }
    }

    fn decide(&mut self, ctx: &BlockCtx) -> BlockAction {
        if ctx.delta.is_none() || !self.had_history {
            return BlockAction::Compute;
        }
        if self.skip_step {
            BlockAction::Reuse
        } else {
            BlockAction::Compute
        }
    }

    fn relax(&mut self, factor: f64) {
        self.threshold *= factor.max(0.0);
    }

    fn reset(&mut self) {
        self.accumulated = 0.0;
        self.skip_step = false;
        self.had_history = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(step: usize, input_delta: f64) -> StepInfo {
        StepInfo { step, num_steps: 50, temb_delta: input_delta, input_delta }
    }

    fn ctx(delta: Option<f64>) -> BlockCtx {
        BlockCtx { layer: 3, num_layers: 12, step: 1, delta, nd: 6144 }
    }

    #[test]
    fn first_step_computes() {
        let mut p = TeaCache::new(0.15);
        p.begin_step(&info(0, 0.0));
        assert_eq!(p.decide(&ctx(None)), BlockAction::Compute);
    }

    #[test]
    fn small_changes_accumulate_until_threshold() {
        let mut p = TeaCache::new(0.15);
        p.begin_step(&info(0, 0.0));
        let _ = p.decide(&ctx(None));
        // Accumulation: rescale(0.04) = 0.0432 per step -> skips for 3
        // steps (0.0432, 0.0864, 0.1296), computes on the 4th (0.1728).
        let mut actions = Vec::new();
        for s in 1..=4 {
            p.begin_step(&info(s, 0.04));
            actions.push(p.decide(&ctx(Some(0.1))));
        }
        assert_eq!(
            actions,
            vec![
                BlockAction::Reuse,
                BlockAction::Reuse,
                BlockAction::Reuse,
                BlockAction::Compute
            ]
        );
    }

    #[test]
    fn large_change_computes_immediately() {
        let mut p = TeaCache::new(0.15);
        p.begin_step(&info(0, 0.0));
        let _ = p.decide(&ctx(None));
        p.begin_step(&info(1, 0.5));
        assert_eq!(p.decide(&ctx(Some(0.3))), BlockAction::Compute);
    }

    #[test]
    fn reset_clears_accumulator() {
        let mut p = TeaCache::new(0.15);
        p.begin_step(&info(0, 0.0));
        p.begin_step(&info(1, 0.1));
        p.reset();
        p.begin_step(&info(0, 0.0));
        assert_eq!(p.decide(&ctx(None)), BlockAction::Compute);
    }
}
