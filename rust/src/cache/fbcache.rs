//! FBCache (first-block cache, after ParaAttention/FBCache): always compute
//! block 0; if its OUTPUT's relative change vs the previous step is below
//! the `rdt` threshold, reuse the cached outputs of ALL remaining blocks
//! for this step; otherwise compute the whole stack.
//!
//! This is the strongest published training-free baseline in the paper's
//! tables (Tab. 1/5/12) and the one FastCache is contrasted against for
//! threshold robustness (Tab. 6).

use crate::config::PolicyKind;

use super::{BlockAction, BlockCtx, CachePolicy, StepInfo};

pub struct FbCache {
    rdt: f64,
    /// Whether the remainder of the current step is being reused.
    skip_rest: bool,
    seen_first_output: bool,
}

impl FbCache {
    pub fn new(rdt: f64) -> FbCache {
        FbCache { rdt, skip_rest: false, seen_first_output: false }
    }
}

impl CachePolicy for FbCache {
    fn kind(&self) -> PolicyKind {
        PolicyKind::FbCache
    }

    fn begin_step(&mut self, _info: &StepInfo) {
        self.skip_rest = false;
        self.seen_first_output = false;
    }

    fn decide(&mut self, ctx: &BlockCtx) -> BlockAction {
        if ctx.layer == 0 {
            return BlockAction::Compute;
        }
        if ctx.delta.is_none() {
            return BlockAction::Compute; // first step — cache is cold
        }
        if self.skip_rest {
            BlockAction::Reuse
        } else {
            BlockAction::Compute
        }
    }

    fn observe_output(&mut self, layer: usize, delta_out: f64) {
        if layer == 0 && !self.seen_first_output {
            self.seen_first_output = true;
            self.skip_rest = delta_out < self.rdt;
        }
    }

    fn relax(&mut self, factor: f64) {
        self.rdt *= factor.max(0.0);
    }

    fn reset(&mut self) {
        self.skip_rest = false;
        self.seen_first_output = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(layer: usize, delta: Option<f64>) -> BlockCtx {
        BlockCtx { layer, num_layers: 12, step: 4, delta, nd: 6144 }
    }

    #[test]
    fn first_block_always_computes() {
        let mut p = FbCache::new(0.1);
        p.begin_step(&StepInfo { step: 4, num_steps: 50, temb_delta: 0.0, input_delta: 0.0 });
        assert_eq!(p.decide(&ctx(0, Some(0.0))), BlockAction::Compute);
    }

    #[test]
    fn small_first_delta_skips_rest() {
        let mut p = FbCache::new(0.1);
        p.begin_step(&StepInfo { step: 4, num_steps: 50, temb_delta: 0.0, input_delta: 0.0 });
        assert_eq!(p.decide(&ctx(0, Some(0.5))), BlockAction::Compute);
        p.observe_output(0, 0.05); // below rdt
        for l in 1..12 {
            assert_eq!(p.decide(&ctx(l, Some(0.5))), BlockAction::Reuse);
        }
    }

    #[test]
    fn large_first_delta_computes_everything() {
        let mut p = FbCache::new(0.1);
        p.begin_step(&StepInfo { step: 4, num_steps: 50, temb_delta: 0.0, input_delta: 0.0 });
        let _ = p.decide(&ctx(0, Some(0.5)));
        p.observe_output(0, 0.5); // above rdt
        for l in 1..12 {
            assert_eq!(p.decide(&ctx(l, Some(0.001))), BlockAction::Compute);
        }
    }

    #[test]
    fn cold_cache_computes() {
        let mut p = FbCache::new(0.1);
        p.begin_step(&StepInfo { step: 0, num_steps: 50, temb_delta: 0.0, input_delta: 0.0 });
        let _ = p.decide(&ctx(0, None));
        p.observe_output(0, 0.0);
        assert_eq!(p.decide(&ctx(1, None)), BlockAction::Compute);
    }

    #[test]
    fn gate_resets_each_step() {
        let mut p = FbCache::new(0.1);
        p.begin_step(&StepInfo { step: 1, num_steps: 50, temb_delta: 0.0, input_delta: 0.0 });
        let _ = p.decide(&ctx(0, Some(0.5)));
        p.observe_output(0, 0.01);
        assert_eq!(p.decide(&ctx(1, Some(0.5))), BlockAction::Reuse);
        p.begin_step(&StepInfo { step: 2, num_steps: 50, temb_delta: 0.0, input_delta: 0.0 });
        let _ = p.decide(&ctx(0, Some(0.5)));
        p.observe_output(0, 0.9);
        assert_eq!(p.decide(&ctx(1, Some(0.5))), BlockAction::Compute);
    }
}
