//! Hidden-state caching: the paper's FastCache policy and every baseline it
//! is compared against, behind one `CachePolicy` trait the scheduler calls
//! between transformer blocks (Algorithm 1).
//!
//! Action semantics:
//! - `Compute` — run the block program (HLO through PJRT).
//! - `Approx`  — substitute the learnable linear approximation (Eq. 6),
//!   optionally blended with the cached output (motion-aware blending).
//! - `Reuse`   — return the cached previous-step output verbatim (what the
//!   reuse-style baselines do).

pub mod adacache;
pub mod calibrate;
pub mod decision;
pub mod fastcache;
pub mod fbcache;
pub mod l2c;
pub mod linear_fit;
pub mod nocache;
pub mod state;
pub mod static_cache;
pub mod teacache;

pub use decision::Chi2Rule;
pub use linear_fit::AffineFit;
pub use state::CacheState;

use crate::config::{FastCacheConfig, PolicyKind};

/// What to do for one (step, layer) site.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BlockAction {
    Compute,
    Approx,
    Reuse,
}

impl BlockAction {
    /// Stable lower-case label, used by the flight recorder's trace
    /// events and the observability docs.
    pub fn name(self) -> &'static str {
        match self {
            BlockAction::Compute => "compute",
            BlockAction::Approx => "approx",
            BlockAction::Reuse => "reuse",
        }
    }
}

/// Per-step information available before any block runs.
#[derive(Clone, Copy, Debug)]
pub struct StepInfo {
    pub step: usize,
    pub num_steps: usize,
    /// Relative change of the conditioning embedding vs the previous step
    /// (TeaCache's gating signal).
    pub temb_delta: f64,
    /// Relative change of the post-embed hidden state vs the previous step.
    pub input_delta: f64,
}

/// Per-block information at decision time.
#[derive(Clone, Copy, Debug)]
pub struct BlockCtx {
    pub layer: usize,
    pub num_layers: usize,
    pub step: usize,
    /// Relative Frobenius change δ of the pre-block hidden state vs the
    /// cached previous-step value (Eq. 4). `None` on the first step
    /// (nothing cached yet).
    pub delta: Option<f64>,
    /// Degrees of freedom N·D of the hidden state.
    pub nd: usize,
}

/// A cache policy decides per (step, layer) whether to compute, approximate
/// or reuse, and observes the outcome of computed blocks to adapt.
pub trait CachePolicy: Send {
    fn kind(&self) -> PolicyKind;

    /// Called once per denoising step before any block decision.
    fn begin_step(&mut self, _info: &StepInfo) {}

    /// The per-block decision.
    fn decide(&mut self, ctx: &BlockCtx) -> BlockAction;

    /// Feedback after a block was computed: relative change of its OUTPUT
    /// vs the cached previous output (drives FBCache-style gates).
    fn observe_output(&mut self, _layer: usize, _delta_out: f64) {}

    /// Degrade-ladder rung 1: multiply the policy's skip threshold by
    /// `factor` (> 1.0 = more permissive, more Approx/Reuse decisions).
    /// Default is a no-op — policies without a tunable threshold
    /// (NoCache, StaticCache, schedule-driven L2C/AdaCache) cannot
    /// trade quality for latency this way. Only the server's degrade
    /// ladder ever calls this, and only on deadline-tagged lanes.
    fn relax(&mut self, _factor: f64) {}

    /// Reset all adaptive state (new request).
    fn reset(&mut self);
}

/// Instantiate the policy named by the config.
pub fn build_policy(cfg: &FastCacheConfig, num_layers: usize) -> Box<dyn CachePolicy> {
    match cfg.policy {
        PolicyKind::NoCache => Box::new(nocache::NoCache),
        PolicyKind::FastCache => Box::new(fastcache::FastCachePolicy::new(cfg)),
        PolicyKind::FbCache => Box::new(fbcache::FbCache::new(cfg.fb_rdt)),
        PolicyKind::TeaCache => Box::new(teacache::TeaCache::new(cfg.tea_threshold)),
        PolicyKind::AdaCache => Box::new(adacache::AdaCache::new(cfg.ada_knee)),
        PolicyKind::L2C => Box::new(l2c::L2C::new(cfg.l2c_threshold, num_layers)),
        PolicyKind::StaticCache => Box::new(static_cache::StaticCache::new(cfg.static_period)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_policy_matches_kind() {
        for kind in PolicyKind::ALL {
            let cfg = FastCacheConfig::with_policy(kind);
            let p = build_policy(&cfg, 12);
            assert_eq!(p.kind(), kind);
        }
    }
}
