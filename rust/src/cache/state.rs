//! Per-request cache state: the previous step's hidden states (pre-block
//! inputs and block outputs) per layer, the online affine fits, and
//! bookkeeping counters — everything Algorithm 1 needs between timesteps.

use crate::tensor::Tensor;

use super::linear_fit::AffineFit;
use super::BlockAction;

#[derive(Clone, Copy, Default, Debug, PartialEq, Eq)]
pub struct CacheCounters {
    pub computed: usize,
    pub approximated: usize,
    pub reused: usize,
}

impl CacheCounters {
    /// Tally one block-site decision (the lane stepper's canonical
    /// per-request count; `GenResult` reads these back).
    pub fn record(&mut self, action: BlockAction) {
        match action {
            BlockAction::Compute => self.computed += 1,
            BlockAction::Approx => self.approximated += 1,
            BlockAction::Reuse => self.reused += 1,
        }
    }

    pub fn total(&self) -> usize {
        self.computed + self.approximated + self.reused
    }

    /// Fraction of block sites that did NOT run the full block.
    pub fn skip_ratio(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            (self.approximated + self.reused) as f64 / self.total() as f64
        }
    }
}

pub struct CacheState {
    /// H_{t−1, l−1}: pre-block hidden per layer, previous step.
    prev_input: Vec<Option<Tensor>>,
    /// H_{t−1, l}: block output per layer, previous step.
    prev_output: Vec<Option<Tensor>>,
    /// Previous step's conditioning embedding.
    pub prev_temb: Option<Tensor>,
    /// Previous step's post-embed hidden (STR saliency base).
    pub prev_embed: Option<Tensor>,
    /// Online learnable approximations, one per layer. May be seeded
    /// from the cross-request store (warm start).
    fits: Vec<AffineFit>,
    /// THIS request's own evidence only — allocated in warm-start mode,
    /// never seeded from the store. Publishing these (instead of `fits`)
    /// keeps a warm lane from echoing the store's own statistics back
    /// into it at retirement.
    fresh_fits: Option<Vec<AffineFit>>,
    pub counters: CacheCounters,
    /// Cache-state bytes currently held (for the memory accounting the
    /// paper reports).
    bytes: usize,
}

impl CacheState {
    pub fn new(num_layers: usize, d: usize, fit_decay: f64) -> CacheState {
        CacheState {
            prev_input: vec![None; num_layers],
            prev_output: vec![None; num_layers],
            prev_temb: None,
            prev_embed: None,
            fits: (0..num_layers).map(|_| AffineFit::new(d, fit_decay)).collect(),
            fresh_fits: None,
            counters: CacheCounters::default(),
            bytes: 0,
        }
    }

    /// Enable the per-request fresh-evidence accumulators (warm-start
    /// mode). Must be called before any block runs.
    pub fn enable_fresh_fits(&mut self, d: usize, fit_decay: f64) {
        let layers = self.fits.len();
        self.fresh_fits = Some((0..layers).map(|_| AffineFit::new(d, fit_decay)).collect());
    }

    pub fn num_layers(&self) -> usize {
        self.prev_input.len()
    }

    pub fn prev_input(&self, layer: usize) -> Option<&Tensor> {
        self.prev_input[layer].as_ref()
    }

    pub fn prev_output(&self, layer: usize) -> Option<&Tensor> {
        self.prev_output[layer].as_ref()
    }

    pub fn fit(&self, layer: usize) -> &AffineFit {
        &self.fits[layer]
    }

    pub fn fit_mut(&mut self, layer: usize) -> &mut AffineFit {
        &mut self.fits[layer]
    }

    /// All per-layer serving fits (possibly warm-started).
    pub fn fits(&self) -> &[AffineFit] {
        &self.fits
    }

    /// Feed a computed (input, output) pair into layer `layer`'s fit —
    /// and, in warm-start mode, into its fresh-evidence twin. All fit
    /// updates must go through here so the two stay in lockstep.
    pub fn observe_fit(&mut self, layer: usize, input: &Tensor, output: &Tensor) {
        self.fits[layer].update(input, output);
        if let Some(fresh) = &mut self.fresh_fits {
            fresh[layer].update(input, output);
        }
    }

    /// What a retiring lane should publish to the cross-request store:
    /// this request's own evidence (`fresh_fits`) when warm-start mode
    /// recorded it, else the serving fits (which are then purely local —
    /// nothing was adopted). Keeps the store free of evidence echo.
    pub fn publishable_fits(&self) -> &[AffineFit] {
        self.fresh_fits.as_deref().unwrap_or(&self.fits)
    }

    fn track_replace(bytes: &mut usize, slot: &mut Option<Tensor>, t: Tensor) {
        if let Some(old) = slot.take() {
            *bytes -= old.size_bytes();
        }
        *bytes += t.size_bytes();
        *slot = Some(t);
    }

    /// Copy `src` into a slot, REUSING the resident allocation when the
    /// shape matches (the steady-state case: every step replaces each
    /// slot with an identically-shaped tensor). Byte accounting is
    /// unchanged either way.
    fn track_copy(bytes: &mut usize, slot: &mut Option<Tensor>, src: &Tensor) {
        match slot {
            Some(t) if t.shape() == src.shape() => {
                t.data_mut().copy_from_slice(src.data());
            }
            _ => Self::track_replace(bytes, slot, src.clone()),
        }
    }

    /// Move `t` into the layer's input slot and hand the evicted tensor
    /// back for buffer recycling (an empty tensor when the slot was
    /// cold). The zero-copy path of the lane stepper: the pre-block
    /// hidden moves in, last step's buffer becomes the next scratch
    /// output.
    pub fn swap_input(&mut self, layer: usize, t: Tensor) -> Tensor {
        let slot = &mut self.prev_input[layer];
        let old = slot.take();
        if let Some(o) = &old {
            self.bytes -= o.size_bytes();
        }
        self.bytes += t.size_bytes();
        *slot = Some(t);
        old.unwrap_or_else(Tensor::empty)
    }

    /// Copy `src` into the layer's output slot (allocation-free once the
    /// slot holds a same-shape tensor).
    pub fn store_output_from(&mut self, layer: usize, src: &Tensor) {
        Self::track_copy(&mut self.bytes, &mut self.prev_output[layer], src);
    }

    /// Copy `src` into the previous-temb slot (allocation-free once
    /// resident).
    pub fn store_temb_from(&mut self, src: &Tensor) {
        Self::track_copy(&mut self.bytes, &mut self.prev_temb, src);
    }

    /// Copy `src` into the previous-embed slot (allocation-free once
    /// resident).
    pub fn store_embed_from(&mut self, src: &Tensor) {
        Self::track_copy(&mut self.bytes, &mut self.prev_embed, src);
    }

    /// Cache-state footprint in bytes (hidden copies; fits — and their
    /// fresh-evidence twins in warm-start mode — are O(D) and counted at
    /// 3 floats per channel).
    pub fn size_bytes(&self) -> usize {
        let fit_bytes = |fits: &[AffineFit]| fits.iter().map(|f| f.d() * 3 * 8).sum::<usize>();
        self.bytes
            + fit_bytes(&self.fits)
            + self.fresh_fits.as_deref().map(fit_bytes).unwrap_or(0)
    }

    pub fn clear(&mut self) {
        for s in self.prev_input.iter_mut().chain(self.prev_output.iter_mut()) {
            *s = None;
        }
        self.prev_temb = None;
        self.prev_embed = None;
        self.bytes = 0;
        self.counters = CacheCounters::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_ratio() {
        let mut c = CacheCounters { computed: 6, approximated: 3, reused: 1 };
        assert_eq!(c.total(), 10);
        c.record(BlockAction::Compute);
        c.record(BlockAction::Approx);
        c.record(BlockAction::Reuse);
        assert_eq!((c.computed, c.approximated, c.reused), (7, 4, 2));
        let c = CacheCounters { computed: 6, approximated: 3, reused: 1 };
        assert!((c.skip_ratio() - 0.4).abs() < 1e-12);
        assert_eq!(CacheCounters::default().skip_ratio(), 0.0);
    }

    #[test]
    fn byte_accounting_replaces() {
        let mut s = CacheState::new(2, 4, 0.98);
        assert_eq!(s.size_bytes(), 2 * 4 * 3 * 8);
        s.swap_input(0, Tensor::zeros(&[8, 4]));
        let base = s.size_bytes();
        s.swap_input(0, Tensor::zeros(&[8, 4])); // replace, same size
        assert_eq!(s.size_bytes(), base);
        s.store_output_from(1, &Tensor::zeros(&[8, 4]));
        assert!(s.size_bytes() > base);
        s.clear();
        assert_eq!(s.size_bytes(), 2 * 4 * 3 * 8);
        assert!(s.prev_input(0).is_none());
    }

    #[test]
    fn fresh_fits_accumulate_only_local_evidence() {
        // Warm-start mode: the serving fit carries adopted + local rows,
        // the publishable (fresh) fit carries ONLY this request's — so a
        // retiring warm lane cannot echo the store's statistics back.
        let d = 4;
        let mut s = CacheState::new(1, d, 1.0);
        s.enable_fresh_fits(d, 1.0);

        let mut adopted = super::AffineFit::new(d, 1.0);
        let x0 = Tensor::zeros(&[2, d]);
        let mut y0 = x0.clone();
        for v in y0.data_mut().iter_mut() {
            *v += 1.0;
        }
        adopted.update(&x0, &y0);
        s.fit_mut(0).adopt(&adopted);
        assert_eq!(s.fit(0).updates(), 1);
        assert_eq!(s.publishable_fits()[0].updates(), 0, "adoption must not taint fresh");

        s.observe_fit(0, &x0, &y0);
        assert_eq!(s.fit(0).updates(), 2);
        assert_eq!(s.publishable_fits()[0].updates(), 1);

        // Without fresh fits, publishable == serving fits (purely local).
        let mut cold = CacheState::new(1, d, 1.0);
        cold.observe_fit(0, &x0, &y0);
        assert_eq!(cold.publishable_fits()[0].updates(), 1);
    }

    #[test]
    fn copy_in_stores_reuse_allocations_and_keep_accounting() {
        // The zero-allocation serving path: same-shape replacement must
        // reuse the resident buffer (no new allocation), shape changes
        // must fall back to replace — bytes exact in both cases.
        let mut s = CacheState::new(1, 4, 0.98);
        let fits = 4 * 3 * 8;
        s.store_output_from(0, &Tensor::full(&[8, 4], 1.0));
        let ptr = s.prev_output(0).unwrap().data().as_ptr();
        assert_eq!(s.size_bytes(), 8 * 4 * 4 + fits);
        s.store_output_from(0, &Tensor::full(&[8, 4], 2.0));
        let out = s.prev_output(0).unwrap();
        assert_eq!(out.data().as_ptr(), ptr, "same-shape copy-in must reuse the buffer");
        assert!(out.data().iter().all(|&v| v == 2.0));
        assert_eq!(s.size_bytes(), 8 * 4 * 4 + fits);
        // Shape change: replaced, bytes follow.
        s.store_output_from(0, &Tensor::full(&[2, 4], 3.0));
        assert_eq!(s.size_bytes(), 2 * 4 * 4 + fits);

        // swap_input: move in, recycle out.
        let evicted = s.swap_input(0, Tensor::full(&[8, 4], 4.0));
        assert_eq!(evicted.len(), 0, "cold slot recycles an empty tensor");
        assert_eq!(s.size_bytes(), 2 * 4 * 4 + 8 * 4 * 4 + fits);
        let evicted = s.swap_input(0, Tensor::full(&[6, 4], 5.0));
        assert_eq!(evicted.shape(), &[8, 4], "previous resident comes back for reuse");
        assert_eq!(s.size_bytes(), 2 * 4 * 4 + 6 * 4 * 4 + fits);
    }

    #[test]
    fn bytes_track_actual_tensor_allocation() {
        // `bytes` must equal the sum of size_bytes() over every resident
        // tensor at all times — including replacements that GROW or
        // SHRINK a slot (merged hidden states shrink mid-stack; unpooled
        // ones grow back), which simple high-water accounting would miss.
        let fits_overhead = 3 * 4 * 3 * 8;
        let mut s = CacheState::new(3, 4, 0.98);
        let mut expect = 0usize;
        let sz = |n: usize| n * 4 * std::mem::size_of::<f32>();

        s.swap_input(0, Tensor::zeros(&[16, 4]));
        expect += sz(16);
        s.store_output_from(0, &Tensor::zeros(&[16, 4]));
        expect += sz(16);
        s.store_temb_from(&Tensor::zeros(&[1, 4]));
        expect += sz(1);
        s.store_embed_from(&Tensor::zeros(&[16, 4]));
        expect += sz(16);
        assert_eq!(s.size_bytes(), expect + fits_overhead);

        // Shrink layer 0's slots (a merged-resolution step)...
        s.swap_input(0, Tensor::zeros(&[4, 4]));
        s.store_output_from(0, &Tensor::zeros(&[4, 4]));
        expect = expect - 2 * sz(16) + 2 * sz(4);
        assert_eq!(s.size_bytes(), expect + fits_overhead);

        // ...then grow them back past the original size.
        s.swap_input(0, Tensor::zeros(&[32, 4]));
        expect = expect - sz(4) + sz(32);
        assert_eq!(s.size_bytes(), expect + fits_overhead);

        // Untouched layers contribute nothing until written.
        assert!(s.prev_input(2).is_none());
        s.store_output_from(2, &Tensor::zeros(&[8, 4]));
        expect += sz(8);
        assert_eq!(s.size_bytes(), expect + fits_overhead);
    }
}
