//! Per-request cache state: the previous step's hidden states (pre-block
//! inputs and block outputs) per layer, the online affine fits, and
//! bookkeeping counters — everything Algorithm 1 needs between timesteps.

use crate::tensor::Tensor;

use super::linear_fit::AffineFit;
use super::BlockAction;

#[derive(Clone, Copy, Default, Debug, PartialEq, Eq)]
pub struct CacheCounters {
    pub computed: usize,
    pub approximated: usize,
    pub reused: usize,
}

impl CacheCounters {
    /// Tally one block-site decision (the lane stepper's canonical
    /// per-request count; `GenResult` reads these back).
    pub fn record(&mut self, action: BlockAction) {
        match action {
            BlockAction::Compute => self.computed += 1,
            BlockAction::Approx => self.approximated += 1,
            BlockAction::Reuse => self.reused += 1,
        }
    }

    pub fn total(&self) -> usize {
        self.computed + self.approximated + self.reused
    }

    /// Fraction of block sites that did NOT run the full block.
    pub fn skip_ratio(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            (self.approximated + self.reused) as f64 / self.total() as f64
        }
    }
}

pub struct CacheState {
    /// H_{t−1, l−1}: pre-block hidden per layer, previous step.
    prev_input: Vec<Option<Tensor>>,
    /// H_{t−1, l}: block output per layer, previous step.
    prev_output: Vec<Option<Tensor>>,
    /// Previous step's conditioning embedding.
    pub prev_temb: Option<Tensor>,
    /// Previous step's post-embed hidden (STR saliency base).
    pub prev_embed: Option<Tensor>,
    /// Online learnable approximations, one per layer.
    fits: Vec<AffineFit>,
    pub counters: CacheCounters,
    /// Cache-state bytes currently held (for the memory accounting the
    /// paper reports).
    bytes: usize,
}

impl CacheState {
    pub fn new(num_layers: usize, d: usize, fit_decay: f64) -> CacheState {
        CacheState {
            prev_input: vec![None; num_layers],
            prev_output: vec![None; num_layers],
            prev_temb: None,
            prev_embed: None,
            fits: (0..num_layers).map(|_| AffineFit::new(d, fit_decay)).collect(),
            counters: CacheCounters::default(),
            bytes: 0,
        }
    }

    pub fn num_layers(&self) -> usize {
        self.prev_input.len()
    }

    pub fn prev_input(&self, layer: usize) -> Option<&Tensor> {
        self.prev_input[layer].as_ref()
    }

    pub fn prev_output(&self, layer: usize) -> Option<&Tensor> {
        self.prev_output[layer].as_ref()
    }

    pub fn fit(&self, layer: usize) -> &AffineFit {
        &self.fits[layer]
    }

    pub fn fit_mut(&mut self, layer: usize) -> &mut AffineFit {
        &mut self.fits[layer]
    }

    fn track_replace(bytes: &mut usize, slot: &mut Option<Tensor>, t: Tensor) {
        if let Some(old) = slot.take() {
            *bytes -= old.size_bytes();
        }
        *bytes += t.size_bytes();
        *slot = Some(t);
    }

    pub fn store_input(&mut self, layer: usize, t: Tensor) {
        Self::track_replace(&mut self.bytes, &mut self.prev_input[layer], t);
    }

    pub fn store_output(&mut self, layer: usize, t: Tensor) {
        Self::track_replace(&mut self.bytes, &mut self.prev_output[layer], t);
    }

    pub fn store_temb(&mut self, t: Tensor) {
        Self::track_replace(&mut self.bytes, &mut self.prev_temb, t);
    }

    pub fn store_embed(&mut self, t: Tensor) {
        Self::track_replace(&mut self.bytes, &mut self.prev_embed, t);
    }

    /// Cache-state footprint in bytes (hidden copies; fits are O(D) and
    /// counted at 3 floats per channel).
    pub fn size_bytes(&self) -> usize {
        self.bytes + self.fits.iter().map(|f| f.d() * 3 * 8).sum::<usize>()
    }

    pub fn clear(&mut self) {
        for s in self.prev_input.iter_mut().chain(self.prev_output.iter_mut()) {
            *s = None;
        }
        self.prev_temb = None;
        self.prev_embed = None;
        self.bytes = 0;
        self.counters = CacheCounters::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_ratio() {
        let mut c = CacheCounters { computed: 6, approximated: 3, reused: 1 };
        assert_eq!(c.total(), 10);
        c.record(BlockAction::Compute);
        c.record(BlockAction::Approx);
        c.record(BlockAction::Reuse);
        assert_eq!((c.computed, c.approximated, c.reused), (7, 4, 2));
        let c = CacheCounters { computed: 6, approximated: 3, reused: 1 };
        assert!((c.skip_ratio() - 0.4).abs() < 1e-12);
        assert_eq!(CacheCounters::default().skip_ratio(), 0.0);
    }

    #[test]
    fn byte_accounting_replaces() {
        let mut s = CacheState::new(2, 4, 0.98);
        assert_eq!(s.size_bytes(), 2 * 4 * 3 * 8);
        s.store_input(0, Tensor::zeros(&[8, 4]));
        let base = s.size_bytes();
        s.store_input(0, Tensor::zeros(&[8, 4])); // replace, same size
        assert_eq!(s.size_bytes(), base);
        s.store_output(1, Tensor::zeros(&[8, 4]));
        assert!(s.size_bytes() > base);
        s.clear();
        assert_eq!(s.size_bytes(), 2 * 4 * 3 * 8);
        assert!(s.prev_input(0).is_none());
    }
}
