//! Calibration flows: record per-(step, layer) relative hidden-state
//! deltas from a full-compute rollout — the "training" pass behind
//! Learning-to-Cache and a useful diagnostic for every policy's threshold
//! (the per-layer delta profile IS Fig. 1's derivative heat, aggregated).

use anyhow::Result;

use crate::config::{FastCacheConfig, PolicyKind};
use crate::model::DitModel;
use crate::scheduler::{DenoiseEngine, GenRequest};

use super::l2c::L2C;

/// A recorded delta profile: deltas[step][layer], averaged over requests.
#[derive(Clone, Debug)]
pub struct DeltaProfile {
    pub deltas: Vec<Vec<f64>>,
}

impl DeltaProfile {
    pub fn steps(&self) -> usize {
        self.deltas.len()
    }

    /// Mean delta per layer across steps (depth profile).
    pub fn layer_means(&self) -> Vec<f64> {
        if self.deltas.is_empty() {
            return Vec::new();
        }
        let layers = self.deltas[0].len();
        let mut means = vec![0.0; layers];
        let mut counts = vec![0usize; layers];
        for row in &self.deltas {
            for (l, &d) in row.iter().enumerate() {
                if d.is_finite() {
                    means[l] += d;
                    counts[l] += 1;
                }
            }
        }
        for (m, c) in means.iter_mut().zip(counts) {
            if c > 0 {
                *m /= c as f64;
            }
        }
        means
    }

    /// Fraction of ALL sites whose delta falls below `thr` (the skip rate
    /// a threshold policy would achieve on this trajectory). Cold sites
    /// (infinite delta, e.g. the whole first step) count in the
    /// denominator — they are never skippable.
    pub fn skippable_fraction(&self, thr: f64) -> f64 {
        let mut below = 0usize;
        let mut total = 0usize;
        for row in &self.deltas {
            for &d in row {
                total += 1;
                if d.is_finite() && d < thr {
                    below += 1;
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            below as f64 / total as f64
        }
    }
}

/// Run full-compute rollouts over `reqs` and record the mean per-(step,
/// layer) delta profile. This uses the engine's StepRecord mean deltas per
/// step plus a per-layer refinement pass.
pub fn record_profile(model: &DitModel, reqs: &[GenRequest]) -> Result<DeltaProfile> {
    assert!(!reqs.is_empty());
    let steps = reqs[0].steps;
    let layers = model.cfg.layers;
    let mut acc = vec![vec![0.0f64; layers]; steps];
    let mut cnt = vec![vec![0usize; layers]; steps];

    // Recording policy: NoCache with a probe that mirrors the engine's
    // internal deltas. The engine already exposes mean per-step deltas in
    // StepRecord; for the per-layer table we re-run with an instrumented
    // recorder policy.
    for req in reqs {
        let recorder = RecorderPolicy::new(steps, layers);
        let cell = recorder.cells.clone();
        let mut eng = DenoiseEngine::new(
            model,
            FastCacheConfig::with_policy(PolicyKind::NoCache),
        );
        eng.set_policy(Box::new(recorder));
        let _ = eng.generate(req)?;
        let recorded = cell.lock().unwrap();
        for (s, row) in recorded.iter().enumerate() {
            for (l, &d) in row.iter().enumerate() {
                if let Some(d) = d {
                    acc[s][l] += d;
                    cnt[s][l] += 1;
                }
            }
        }
    }
    for s in 0..steps {
        for l in 0..layers {
            if cnt[s][l] > 0 {
                acc[s][l] /= cnt[s][l] as f64;
            } else {
                acc[s][l] = f64::INFINITY; // cold sites are never skippable
            }
        }
    }
    Ok(DeltaProfile { deltas: acc })
}

/// Build a calibrated Learning-to-Cache policy from a delta profile.
pub fn calibrated_l2c(profile: &DeltaProfile, threshold: f64, num_layers: usize) -> L2C {
    let mut p = L2C::new(threshold, num_layers);
    p.calibrate(profile.deltas.clone());
    p
}

/// Internal: a pass-through policy that records every observed delta and
/// always computes.
struct RecorderPolicy {
    cells: std::sync::Arc<std::sync::Mutex<Vec<Vec<Option<f64>>>>>,
    step: usize,
}

impl RecorderPolicy {
    fn new(steps: usize, layers: usize) -> RecorderPolicy {
        RecorderPolicy {
            cells: std::sync::Arc::new(std::sync::Mutex::new(vec![vec![None; layers]; steps])),
            step: 0,
        }
    }
}

impl super::CachePolicy for RecorderPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::NoCache
    }

    fn begin_step(&mut self, info: &super::StepInfo) {
        self.step = info.step;
    }

    fn decide(&mut self, ctx: &super::BlockCtx) -> super::BlockAction {
        if let Some(d) = ctx.delta {
            let mut cells = self.cells.lock().unwrap();
            if let Some(row) = cells.get_mut(ctx.step) {
                if let Some(slot) = row.get_mut(ctx.layer) {
                    *slot = Some(d);
                }
            }
        }
        super::BlockAction::Compute
    }

    fn reset(&mut self) {
        self.step = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{BlockCtx, CachePolicy};
    use crate::config::Variant;
    use crate::scheduler::GenRequest;

    fn profile() -> (DitModel, DeltaProfile) {
        let model = DitModel::native(Variant::S, 5);
        let reqs: Vec<GenRequest> = (0..2).map(|i| GenRequest::builder(i, 30 + i).steps(6).build().unwrap()).collect();
        let p = record_profile(&model, &reqs).unwrap();
        (model, p)
    }

    #[test]
    fn profile_shape_and_monotone_trend() {
        let (model, p) = profile();
        assert_eq!(p.steps(), 6);
        assert_eq!(p.deltas[0].len(), model.cfg.layers);
        // Step 0 has no cache -> infinite (never skippable).
        assert!(p.deltas[0].iter().all(|d| d.is_infinite()));
        // Later steps have smaller deltas than the first cached step (the
        // denoising trajectory settles).
        let early: f64 = p.deltas[1].iter().sum();
        let late: f64 = p.deltas[5].iter().sum();
        assert!(late < early, "late {late} vs early {early}");
    }

    #[test]
    fn skippable_fraction_monotone_in_threshold() {
        let (_, p) = profile();
        assert!(p.skippable_fraction(0.01) <= p.skippable_fraction(0.2));
        assert!(p.skippable_fraction(0.2) <= p.skippable_fraction(10.0));
        assert!(p.skippable_fraction(1e9) < 1.0); // step-0 sites never skip
    }

    #[test]
    fn calibrated_l2c_follows_profile() {
        let (model, p) = profile();
        let mut l2c = calibrated_l2c(&p, 0.15, model.cfg.layers);
        assert!(l2c.is_calibrated());
        // Pick a known-small site and a known-large site.
        let small = p
            .deltas
            .iter()
            .enumerate()
            .flat_map(|(s, row)| row.iter().enumerate().map(move |(l, d)| (s, l, *d)))
            .filter(|(_, _, d)| d.is_finite())
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .unwrap();
        let skip = l2c.decide(&BlockCtx {
            layer: small.1,
            num_layers: model.cfg.layers,
            step: small.0,
            delta: Some(1.0),
            nd: 64,
        });
        if small.2 < 0.15 {
            assert_eq!(skip, crate::cache::BlockAction::Reuse);
        }
        // Step 0 always computes (infinite calibration delta).
        let a0 = l2c.decide(&BlockCtx {
            layer: 0,
            num_layers: model.cfg.layers,
            step: 0,
            delta: Some(0.0),
            nd: 64,
        });
        assert_eq!(a0, crate::cache::BlockAction::Compute);
    }

    #[test]
    fn layer_means_finite_for_cached_steps() {
        let (_, p) = profile();
        let means = p.layer_means();
        assert!(!means.is_empty());
    }
}
