//! Deterministic fault injection — the test harness for the fault
//! containment layer.
//!
//! A [`FaultPlan`] is a parsed, seeded-by-construction list of fault
//! sites that the serving stack consults at well-defined points:
//!
//! - **kernel panics** at a chosen `(shard, step, layer[, req])` site
//!   inside `LaneStepper::step` — exercises the shard's `catch_unwind`
//!   quarantine + survivor-replay path;
//! - **queue-pop delays** — burns a shard's admission clock to force
//!   deadline pressure (drives the degrade-ladder tests without
//!   trusting wall-clock races);
//! - **socket resets** — the Nth accepted connection is torn down
//!   before the handshake, exercising the client's connect retry and
//!   the door's accounting;
//! - **snapshot corruption** — warm-store snapshot bytes are truncated
//!   or bit-flipped at load, exercising the checksum/cold-degrade path;
//! - **step stalls** — a bounded busy-wait at a `(shard, step)` site
//!   inside `LaneStepper::step`, simulating a wedged (not panicking)
//!   kernel so the stuck-step watchdog is deterministically testable.
//!   The wait is bounded because a wedged thread cannot be killed in
//!   safe Rust: the stalled shard must eventually return so the
//!   supervisor's restart can be observed end to end.
//!
//! Every spec is bounded (`count=`, default 1) and every firing is
//! counted, so a chaos run can assert "exactly the planned faults
//! fired". The plan is OFF by default: no `--fault-plan` / `[faults]`
//! config means no `FaultPlan` is ever constructed and none of the
//! injection points execute anything beyond an `Option` check — the
//! "faults never fire when unconfigured" invariant in ROADMAP.md.
//!
//! Grammar (`docs/ROBUSTNESS.md` is the reference):
//!
//! ```text
//! plan  := spec (';' spec)*
//! spec  := kind (key '=' value)*          # whitespace-separated
//! kind  := 'panic' | 'popdelay' | 'sockreset' | 'snapcorrupt' | 'stall'
//! panic       keys: step, layer  (required)  shard, req, count, raw
//! popdelay    keys: ms           (required)  shard, count
//! sockreset   keys: conn         (required)  count
//! snapcorrupt keys: mode=truncate|bitflip (required)  count
//! stall       keys: step, ms     (required)  shard, count
//! ```
//!
//! Determinism: there is no RNG anywhere in this module. A plan string
//! plus a fixed workload reproduces the exact same fault sequence.

use std::sync::atomic::{AtomicU64, Ordering};

/// Typed panic payload carried by injected kernel panics so the shard's
/// `catch_unwind` handler can identify exactly which lane faulted and
/// quarantine only it. A panic WITHOUT this payload (a genuine bug, or
/// an injected `raw=1` panic simulating one) quarantines the whole
/// batch instead — the handler cannot trust any lane's state.
#[derive(Clone, Copy, Debug)]
pub struct FaultPanic {
    /// The request whose lane was executing when the panic fired.
    pub req_id: u64,
}

/// How an injected panic unwinds: `Typed` carries a [`FaultPanic`]
/// payload (per-lane quarantine), `Raw` panics with a plain message
/// (whole-batch quarantine, simulating an unattributed kernel bug).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PanicShape {
    Typed,
    Raw,
}

impl PanicShape {
    /// Unwind now. Called from the kernel site once a spec armed it.
    pub fn fire(self, req_id: u64) -> ! {
        match self {
            PanicShape::Typed => std::panic::panic_any(FaultPanic { req_id }),
            PanicShape::Raw => panic!("injected raw kernel panic (fault plan)"),
        }
    }
}

/// How snapshot bytes are corrupted at load.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorruptMode {
    /// Drop the second half of the byte stream.
    Truncate,
    /// Flip one bit in the middle byte.
    BitFlip,
}

#[derive(Debug, PartialEq)]
enum Site {
    Panic { shard: Option<u32>, step: usize, layer: usize, req: Option<u64>, raw: bool },
    PopDelay { shard: Option<u32>, ms: u64 },
    SockReset { conn: u64 },
    SnapCorrupt { mode: CorruptMode },
    Stall { shard: Option<u32>, step: usize, ms: u64 },
}

#[derive(Debug)]
struct Spec {
    site: Site,
    /// Remaining firings; decremented atomically so concurrent shard
    /// threads can never over-fire a bounded spec.
    remaining: AtomicU64,
}

impl Spec {
    /// Claim one firing if any remain (lock-free decrement-if-positive).
    fn claim(&self) -> bool {
        let mut cur = self.remaining.load(Ordering::Relaxed);
        while cur > 0 {
            match self.remaining.compare_exchange_weak(
                cur,
                cur - 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
        false
    }
}

/// A parsed fault plan plus live fired-counters. Shared as an
/// `Arc<FaultPlan>` across shard threads, the net door, and the warm
/// store; the registry exposes the counters as `faults.*` series.
#[derive(Debug, Default)]
pub struct FaultPlan {
    specs: Vec<Spec>,
    panics: AtomicU64,
    pop_delays: AtomicU64,
    sock_resets: AtomicU64,
    snap_corruptions: AtomicU64,
    stalls: AtomicU64,
}

impl FaultPlan {
    /// Parse a plan string (see module docs for the grammar). An empty
    /// or all-whitespace string parses to an empty plan.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut specs = Vec::new();
        for raw_spec in s.split(';') {
            let tokens: Vec<&str> = raw_spec.split_whitespace().collect();
            let Some((&kind, kvs)) = tokens.split_first() else { continue };
            let mut step = None;
            let mut layer = None;
            let mut shard = None;
            let mut req = None;
            let mut count = 1u64;
            let mut ms = None;
            let mut conn = None;
            let mut mode = None;
            let mut raw = false;
            for kv in kvs {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| format!("fault spec token `{kv}` is not key=value"))?;
                let num = || -> Result<u64, String> {
                    v.parse::<u64>().map_err(|_| format!("fault key {k}={v}: not a number"))
                };
                match k {
                    "step" => step = Some(num()? as usize),
                    "layer" => layer = Some(num()? as usize),
                    "shard" => shard = Some(num()? as u32),
                    "req" => req = Some(num()?),
                    "count" => count = num()?,
                    "ms" => ms = Some(num()?),
                    "conn" => conn = Some(num()?),
                    "raw" => raw = num()? != 0,
                    "mode" => {
                        mode = Some(match v {
                            "truncate" => CorruptMode::Truncate,
                            "bitflip" => CorruptMode::BitFlip,
                            other => {
                                return Err(format!(
                                    "snapcorrupt mode must be truncate|bitflip, got {other}"
                                ))
                            }
                        })
                    }
                    other => return Err(format!("unknown fault key `{other}` in `{kind}` spec")),
                }
            }
            if count == 0 {
                return Err(format!("`{kind}` spec has count=0 (would never fire)"));
            }
            let site = match kind {
                "panic" => Site::Panic {
                    shard,
                    step: step.ok_or("panic spec requires step=")?,
                    layer: layer.ok_or("panic spec requires layer=")?,
                    req,
                    raw,
                },
                "popdelay" => {
                    Site::PopDelay { shard, ms: ms.ok_or("popdelay spec requires ms=")? }
                }
                "sockreset" => {
                    Site::SockReset { conn: conn.ok_or("sockreset spec requires conn=")? }
                }
                "snapcorrupt" => {
                    Site::SnapCorrupt { mode: mode.ok_or("snapcorrupt spec requires mode=")? }
                }
                "stall" => Site::Stall {
                    shard,
                    step: step.ok_or("stall spec requires step=")?,
                    ms: ms.ok_or("stall spec requires ms=")?,
                },
                other => return Err(format!("unknown fault kind `{other}`")),
            };
            specs.push(Spec { site, remaining: AtomicU64::new(count) });
        }
        Ok(FaultPlan { specs, ..FaultPlan::default() })
    }

    /// True when the plan carries no specs at all.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Kernel-panic site check, called per (lane, layer) inside the
    /// stepper. Claims and counts the firing; the caller must then
    /// invoke [`PanicShape::fire`] (split so the counter is already
    /// bumped when the unwind starts).
    pub fn armed_panic(&self, shard: u32, step: usize, layer: usize, req: u64) -> Option<PanicShape> {
        for spec in &self.specs {
            if let Site::Panic { shard: s, step: st, layer: l, req: r, raw } = &spec.site {
                let here = s.map_or(true, |want| want == shard)
                    && *st == step
                    && *l == layer
                    && r.map_or(true, |want| want == req);
                if here && spec.claim() {
                    self.panics.fetch_add(1, Ordering::Relaxed);
                    return Some(if *raw { PanicShape::Raw } else { PanicShape::Typed });
                }
            }
        }
        None
    }

    /// Queue-pop delay for this shard, if one is armed. The caller
    /// sleeps for the returned milliseconds before popping.
    pub fn pop_delay_ms(&self, shard: u32) -> Option<u64> {
        for spec in &self.specs {
            if let Site::PopDelay { shard: s, ms } = &spec.site {
                if s.map_or(true, |want| want == shard) && spec.claim() {
                    self.pop_delays.fetch_add(1, Ordering::Relaxed);
                    return Some(*ms);
                }
            }
        }
        None
    }

    /// Should the `conn`-th accepted connection (1-based, in accept
    /// order) be torn down before its handshake?
    pub fn reset_conn(&self, conn: u64) -> bool {
        for spec in &self.specs {
            if let Site::SockReset { conn: c } = &spec.site {
                if *c == conn && spec.claim() {
                    self.sock_resets.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
            }
        }
        false
    }

    /// Corrupt snapshot bytes in place if a `snapcorrupt` spec is armed.
    /// Returns whether a corruption was applied. Deterministic: truncate
    /// halves the stream, bitflip flips bit 3 of the middle byte.
    pub fn corrupt_snapshot(&self, bytes: &mut Vec<u8>) -> bool {
        for spec in &self.specs {
            if let Site::SnapCorrupt { mode } = &spec.site {
                if spec.claim() {
                    self.snap_corruptions.fetch_add(1, Ordering::Relaxed);
                    match mode {
                        CorruptMode::Truncate => {
                            let keep = bytes.len() / 2;
                            bytes.truncate(keep);
                        }
                        CorruptMode::BitFlip => {
                            if !bytes.is_empty() {
                                let mid = bytes.len() / 2;
                                bytes[mid] ^= 1 << 3;
                            }
                        }
                    }
                    return true;
                }
            }
        }
        false
    }

    /// Step-stall site check, consulted once per (lane, step) inside the
    /// stepper. Returns the busy-wait duration (ms) when a `stall` spec
    /// matches this `(shard, step)` site and still has firings left. The
    /// caller spins for that long — simulating a wedged kernel the
    /// watchdog must detect — then resumes normally (the wait is bounded
    /// so the stalled thread can be supervised back to health).
    pub fn armed_stall(&self, shard: u32, step: usize) -> Option<u64> {
        for spec in &self.specs {
            if let Site::Stall { shard: s, step: st, ms } = &spec.site {
                if s.map_or(true, |want| want == shard) && *st == step && spec.claim() {
                    self.stalls.fetch_add(1, Ordering::Relaxed);
                    return Some(*ms);
                }
            }
        }
        None
    }

    /// Fired-counter snapshots, surfaced as `faults.*` registry series.
    pub fn panics_fired(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    pub fn pop_delays_fired(&self) -> u64 {
        self.pop_delays.load(Ordering::Relaxed)
    }

    pub fn sock_resets_fired(&self) -> u64 {
        self.sock_resets.load(Ordering::Relaxed)
    }

    pub fn snap_corruptions_fired(&self) -> u64 {
        self.snap_corruptions.load(Ordering::Relaxed)
    }

    pub fn stalls_fired(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind_and_counts_firings() {
        let plan = FaultPlan::parse(
            "panic shard=0 step=2 layer=1 req=7; popdelay ms=50 count=2; \
             sockreset conn=1; snapcorrupt mode=truncate; stall shard=1 step=3 ms=40",
        )
        .unwrap();
        assert!(!plan.is_empty());

        // Panic: wrong site never fires, right site fires exactly once.
        assert_eq!(plan.armed_panic(0, 1, 1, 7), None);
        assert_eq!(plan.armed_panic(1, 2, 1, 7), None, "shard filter");
        assert_eq!(plan.armed_panic(0, 2, 1, 9), None, "req filter");
        assert_eq!(plan.armed_panic(0, 2, 1, 7), Some(PanicShape::Typed));
        assert_eq!(plan.armed_panic(0, 2, 1, 7), None, "one-shot");
        assert_eq!(plan.panics_fired(), 1);

        // Pop delay: count=2 then dry.
        assert_eq!(plan.pop_delay_ms(3), Some(50));
        assert_eq!(plan.pop_delay_ms(0), Some(50));
        assert_eq!(plan.pop_delay_ms(0), None);
        assert_eq!(plan.pop_delays_fired(), 2);

        // Socket reset: only the named connection, once.
        assert!(!plan.reset_conn(2));
        assert!(plan.reset_conn(1));
        assert!(!plan.reset_conn(1));
        assert_eq!(plan.sock_resets_fired(), 1);

        // Snapshot corruption: truncation halves the stream, once.
        let mut bytes = vec![0xAAu8; 64];
        assert!(plan.corrupt_snapshot(&mut bytes));
        assert_eq!(bytes.len(), 32);
        assert!(!plan.corrupt_snapshot(&mut bytes));
        assert_eq!(plan.snap_corruptions_fired(), 1);

        // Stall: wrong site never fires, right site fires exactly once.
        assert_eq!(plan.armed_stall(1, 2), None, "step filter");
        assert_eq!(plan.armed_stall(0, 3), None, "shard filter");
        assert_eq!(plan.armed_stall(1, 3), Some(40));
        assert_eq!(plan.armed_stall(1, 3), None, "one-shot");
        assert_eq!(plan.stalls_fired(), 1);
    }

    #[test]
    fn bitflip_touches_exactly_one_bit() {
        let plan = FaultPlan::parse("snapcorrupt mode=bitflip").unwrap();
        let mut bytes = vec![0u8; 9];
        assert!(plan.corrupt_snapshot(&mut bytes));
        let flipped: Vec<usize> =
            bytes.iter().enumerate().filter(|(_, b)| **b != 0).map(|(i, _)| i).collect();
        assert_eq!(flipped, vec![4]);
        assert_eq!(bytes[4].count_ones(), 1);
    }

    #[test]
    fn raw_and_wildcard_specs_parse() {
        let plan = FaultPlan::parse("panic step=0 layer=0 raw=1 count=3").unwrap();
        // No shard/req filter: any shard, any request matches.
        assert_eq!(plan.armed_panic(5, 0, 0, 123), Some(PanicShape::Raw));
        assert_eq!(plan.armed_panic(0, 0, 0, 1), Some(PanicShape::Raw));
        assert_eq!(plan.panics_fired(), 2);
    }

    #[test]
    fn empty_and_invalid_plans() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ;  ; ").unwrap().is_empty());
        assert!(FaultPlan::parse("panic step=1").is_err(), "missing layer=");
        assert!(FaultPlan::parse("popdelay").is_err(), "missing ms=");
        assert!(FaultPlan::parse("sockreset conn=x").is_err(), "non-numeric");
        assert!(FaultPlan::parse("snapcorrupt mode=zero").is_err(), "bad mode");
        assert!(FaultPlan::parse("explode now").is_err(), "unknown kind");
        assert!(FaultPlan::parse("panic step=1 layer=0 count=0").is_err(), "count=0");
        assert!(FaultPlan::parse("panic step=1 layer=0 flavor=mild").is_err(), "unknown key");
        assert!(FaultPlan::parse("stall step=1").is_err(), "missing ms=");
        assert!(FaultPlan::parse("stall ms=50").is_err(), "missing step=");
    }

    #[test]
    fn typed_fire_carries_the_request_id() {
        let err = std::panic::catch_unwind(|| PanicShape::Typed.fire(42)).unwrap_err();
        let fp = err.downcast_ref::<FaultPanic>().expect("typed payload");
        assert_eq!(fp.req_id, 42);
        let err = std::panic::catch_unwind(|| PanicShape::Raw.fire(42)).unwrap_err();
        assert!(err.downcast_ref::<FaultPanic>().is_none(), "raw payload is untyped");
    }
}
