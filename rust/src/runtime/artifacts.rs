//! AOT artifact registry: parses `artifacts/manifest.txt`, verifies shapes
//! against the Rust-side model table, and lazily compiles each HLO text
//! program on first use (compiled executables are cached for the process
//! lifetime — one compile per (program, shape), reused across all layers,
//! steps, and requests).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{ModelConfig, Variant, C_IN};

use super::client::Client;

/// Program kinds emitted by python/compile/aot.py.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum ProgramKind {
    Block,
    Temb,
    Final,
    Embed,
    LinearApprox,
    Saliency,
    KnnDensity,
}

#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct ProgramKey {
    pub kind: ProgramKind,
    pub variant: Variant,
    /// Token count (0 where not applicable, e.g. temb).
    pub n: usize,
    /// Batch size (0 where not applicable, e.g. knn).
    pub b: usize,
}

impl ProgramKey {
    pub fn block(variant: Variant, n: usize, b: usize) -> Self {
        ProgramKey { kind: ProgramKind::Block, variant, n, b }
    }
    pub fn temb(variant: Variant, b: usize) -> Self {
        ProgramKey { kind: ProgramKind::Temb, variant, n: 0, b }
    }
    pub fn final_(variant: Variant, n: usize, b: usize) -> Self {
        ProgramKey { kind: ProgramKind::Final, variant, n, b }
    }
    pub fn embed(variant: Variant, n: usize, b: usize) -> Self {
        ProgramKey { kind: ProgramKind::Embed, variant, n, b }
    }
    pub fn linear_approx(variant: Variant, n: usize) -> Self {
        ProgramKey { kind: ProgramKind::LinearApprox, variant, n, b: 1 }
    }
    pub fn saliency(variant: Variant, n: usize) -> Self {
        ProgramKey { kind: ProgramKind::Saliency, variant, n, b: 1 }
    }
    pub fn knn_density(variant: Variant, n: usize) -> Self {
        ProgramKey { kind: ProgramKind::KnnDensity, variant, n, b: 0 }
    }

    /// Artifact file stem as produced by aot.py.
    pub fn file_stem(&self) -> String {
        let v = self.variant.key();
        match self.kind {
            ProgramKind::Block => format!("block_{v}_n{}_b{}", self.n, self.b),
            ProgramKind::Temb => format!("temb_{v}_b{}", self.b),
            ProgramKind::Final => format!("final_{v}_n{}_b{}", self.n, self.b),
            ProgramKind::Embed => format!("embed_{v}_n{}_b{}", self.n, self.b),
            ProgramKind::LinearApprox => format!("linear_approx_{v}_n{}_b1", self.n),
            ProgramKind::Saliency => format!("saliency_{v}_n{}_b1", self.n),
            ProgramKind::KnnDensity => format!("knn_density_{v}_n{}_k5", self.n),
        }
    }

    /// Output tensor shape of the program.
    pub fn out_shape(&self, cfg: &ModelConfig) -> Vec<usize> {
        match self.kind {
            ProgramKind::Block | ProgramKind::Embed | ProgramKind::LinearApprox => {
                vec![self.b, self.n, cfg.d]
            }
            ProgramKind::Temb => vec![self.b, cfg.d],
            ProgramKind::Final => vec![self.b, self.n, C_IN],
            ProgramKind::Saliency => vec![self.b, self.n],
            ProgramKind::KnnDensity => vec![self.n],
        }
    }
}

/// Parse a `f32[a,b,c]` shape string from the manifest.
fn parse_shape(s: &str) -> Result<Vec<usize>> {
    let inner = s
        .strip_prefix("f32[")
        .and_then(|x| x.strip_suffix(']'))
        .ok_or_else(|| anyhow!("bad shape string {s:?}"))?;
    if inner.is_empty() {
        return Ok(vec![]);
    }
    inner
        .split(',')
        .map(|d| d.parse::<usize>().map_err(|e| anyhow!("bad dim {d:?}: {e}")))
        .collect()
}

#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub param_shapes: Vec<Vec<usize>>,
}

/// The artifact store: manifest + lazily compiled executables.
pub struct ArtifactStore {
    dir: PathBuf,
    entries: HashMap<String, ManifestEntry>,
    compiled: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactStore {
    /// Load and validate the manifest (no compilation yet).
    pub fn open(dir: &Path) -> Result<ArtifactStore> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {} — run `make artifacts` first", manifest.display()))?;
        let mut entries = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("artifact") => {}
                _ => bail!("unexpected manifest line: {line:?}"),
            }
            let name = parts.next().ok_or_else(|| anyhow!("manifest line missing name"))?;
            match parts.next() {
                Some("params") => {}
                _ => bail!("manifest line missing params: {line:?}"),
            }
            let param_shapes = parts.map(parse_shape).collect::<Result<Vec<_>>>()?;
            if !dir.join(format!("{name}.hlo.txt")).exists() {
                bail!("manifest references missing artifact {name}");
            }
            entries.insert(
                name.to_string(),
                ManifestEntry { name: name.to_string(), param_shapes },
            );
        }
        if entries.is_empty() {
            bail!("empty manifest at {}", manifest.display());
        }
        Ok(ArtifactStore { dir: dir.to_path_buf(), entries, compiled: Mutex::new(HashMap::new()) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    pub fn entry(&self, key: &ProgramKey) -> Result<&ManifestEntry> {
        let stem = key.file_stem();
        self.entries
            .get(&stem)
            .ok_or_else(|| anyhow!("artifact {stem} not in manifest (regenerate with `make artifacts`)"))
    }

    pub fn has(&self, key: &ProgramKey) -> bool {
        self.entries.contains_key(&key.file_stem())
    }

    /// Variants present in the manifest (any block artifact counts).
    pub fn variants(&self) -> Vec<Variant> {
        Variant::ALL
            .iter()
            .copied()
            .filter(|v| self.entries.contains_key(&ProgramKey::block(*v, 64, 1).file_stem()))
            .collect()
    }

    /// Compile (or fetch the cached) executable for a program.
    pub fn executable(
        &self,
        client: &Client,
        key: &ProgramKey,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let stem = key.file_stem();
        {
            let cache = self.compiled.lock().unwrap();
            if let Some(exe) = cache.get(&stem) {
                return Ok(exe.clone());
            }
        }
        // Compile outside the lock (single-threaded in practice; harmless
        // duplicate compile under a race, last write wins).
        let _entry = self.entry(key)?;
        let path = self.dir.join(format!("{stem}.hlo.txt"));
        let exe = std::sync::Arc::new(client.compile_file(&path)?);
        self.compiled.lock().unwrap().insert(stem, exe.clone());
        Ok(exe)
    }

    /// Number of compiled programs so far (for perf reporting).
    pub fn compiled_count(&self) -> usize {
        self.compiled.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_shape_ok() {
        assert_eq!(parse_shape("f32[1,64,96]").unwrap(), vec![1, 64, 96]);
        assert_eq!(parse_shape("f32[4]").unwrap(), vec![4]);
        assert!(parse_shape("f64[1]").is_err());
        assert!(parse_shape("f32[1,x]").is_err());
    }

    #[test]
    fn program_key_stems_match_aot_naming() {
        let k = ProgramKey::block(Variant::Xl, 32, 1);
        assert_eq!(k.file_stem(), "block_xl_n32_b1");
        assert_eq!(ProgramKey::temb(Variant::S, 4).file_stem(), "temb_s_b4");
        assert_eq!(
            ProgramKey::linear_approx(Variant::B, 64).file_stem(),
            "linear_approx_b_n64_b1"
        );
        assert_eq!(
            ProgramKey::knn_density(Variant::L, 64).file_stem(),
            "knn_density_l_n64_k5"
        );
    }

    #[test]
    fn out_shapes() {
        let cfg = ModelConfig::of(Variant::S);
        assert_eq!(ProgramKey::block(Variant::S, 64, 4).out_shape(&cfg), vec![4, 64, 96]);
        assert_eq!(ProgramKey::temb(Variant::S, 1).out_shape(&cfg), vec![1, 96]);
        assert_eq!(ProgramKey::final_(Variant::S, 64, 1).out_shape(&cfg), vec![1, 64, 4]);
        assert_eq!(ProgramKey::saliency(Variant::S, 64).out_shape(&cfg), vec![1, 64]);
    }
}
