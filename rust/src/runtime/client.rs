//! Thin wrapper over the `xla` crate's PJRT CPU client, plus host<->device
//! staging helpers and byte-level memory accounting.
//!
//! The pattern follows /opt/xla-example/load_hlo: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute_b`. Weights are uploaded ONCE as
//! `PjRtBuffer`s and reused across every step (the serving hot path only
//! stages activations).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::tensor::Tensor;

/// Global-ish accounting of live device bytes (this process, this client).
#[derive(Default, Debug)]
pub struct MemoryMeter {
    live: AtomicUsize,
    peak: AtomicUsize,
}

impl MemoryMeter {
    pub fn alloc(&self, bytes: usize) {
        let live = self.live.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(live, Ordering::Relaxed);
    }

    pub fn free(&self, bytes: usize) {
        self.live.fetch_sub(bytes, Ordering::Relaxed);
    }

    pub fn live_bytes(&self) -> usize {
        self.live.load(Ordering::Relaxed)
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn reset_peak(&self) {
        self.peak.store(self.live.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// A device buffer together with its logical shape and accounted size.
pub struct DeviceTensor {
    pub buffer: xla::PjRtBuffer,
    pub shape: Vec<usize>,
    bytes: usize,
    meter: Arc<MemoryMeter>,
}

impl DeviceTensor {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }
}

impl Drop for DeviceTensor {
    fn drop(&mut self) {
        self.meter.free(self.bytes);
    }
}

/// PJRT CPU client wrapper.
pub struct Client {
    pub(crate) client: xla::PjRtClient,
    pub meter: Arc<MemoryMeter>,
}

impl Client {
    pub fn cpu() -> Result<Client> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Client { client, meter: Arc::new(MemoryMeter::default()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Stage a host tensor onto the device.
    pub fn upload(&self, t: &Tensor) -> Result<DeviceTensor> {
        let buffer = self
            .client
            .buffer_from_host_buffer::<f32>(t.data(), t.shape(), None)
            .with_context(|| format!("uploading tensor shape {:?}", t.shape()))?;
        let bytes = t.size_bytes();
        self.meter.alloc(bytes);
        Ok(DeviceTensor { buffer, shape: t.shape().to_vec(), bytes, meter: self.meter.clone() })
    }

    /// Compile HLO text from a file path.
    pub fn compile_file(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }
}

/// Read an executable's (single-tuple) output buffer back to the host.
///
/// All AOT artifacts are lowered with `return_tuple=True`, so execution
/// yields one tuple buffer whose first element is the result tensor.
pub fn fetch_tuple1(out: &xla::PjRtBuffer, shape: &[usize]) -> Result<Tensor> {
    let lit = out.to_literal_sync().context("device->host transfer")?;
    let first = lit.to_tuple1().context("unwrapping 1-tuple output")?;
    let data = first.to_vec::<f32>().context("reading f32 payload")?;
    Ok(Tensor::new(data, shape))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_meter_tracks_peak() {
        let m = MemoryMeter::default();
        m.alloc(100);
        m.alloc(50);
        m.free(100);
        m.alloc(10);
        assert_eq!(m.live_bytes(), 60);
        assert_eq!(m.peak_bytes(), 150);
        m.reset_peak();
        assert_eq!(m.peak_bytes(), 60);
    }
}
