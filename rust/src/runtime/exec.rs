//! Execution helper: stages activation tensors, combines them with
//! pre-uploaded weight buffers, runs a compiled program, and fetches the
//! result — the single point where the L3 hot path touches PJRT.

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

use super::client::{fetch_tuple1, Client, DeviceTensor};

/// An argument to a program: either a host tensor staged per call, or a
/// resident device buffer (weights, uploaded once at model load).
pub enum Arg<'a> {
    Host(&'a Tensor),
    Device(&'a DeviceTensor),
}

/// Execute `exe` with mixed host/device args, returning the first tuple
/// element reshaped to `out_shape`.
pub fn run(
    client: &Client,
    exe: &xla::PjRtLoadedExecutable,
    args: &[Arg<'_>],
    out_shape: &[usize],
) -> Result<Tensor> {
    // Stage host args; keep staged buffers alive through execution.
    let mut staged: Vec<Option<DeviceTensor>> = Vec::with_capacity(args.len());
    for a in args {
        staged.push(match a {
            Arg::Host(t) => Some(client.upload(t)?),
            Arg::Device(_) => None,
        });
    }
    // Buffer list in argument order (resident weights pass through).
    let bufs: Vec<&xla::PjRtBuffer> = args
        .iter()
        .zip(&staged)
        .map(|(a, s)| match (a, s) {
            (Arg::Host(_), Some(dt)) => &dt.buffer,
            (Arg::Device(d), _) => &d.buffer,
            _ => unreachable!(),
        })
        .collect();

    let outputs = exe.execute_b(&bufs).context("PJRT execute")?;
    if outputs.is_empty() || outputs[0].is_empty() {
        bail!("program produced no outputs");
    }
    let t = fetch_tuple1(&outputs[0][0], out_shape)?;
    Ok(t)
}

#[cfg(test)]
mod tests {
    // Integration coverage for run() lives in rust/tests/runtime_roundtrip.rs
    // (it needs real artifacts); here we only sanity-check Arg construction.
    use super::*;

    #[test]
    fn arg_host_wraps_tensor() {
        let t = Tensor::zeros(&[2, 2]);
        match Arg::Host(&t) {
            Arg::Host(x) => assert_eq!(x.shape(), &[2, 2]),
            _ => unreachable!(),
        }
    }
}
