//! Runtime layer: PJRT client, AOT artifact registry, and the execute
//! helper. Follows /opt/xla-example/load_hlo — HLO text in, PJRT CPU out.

pub mod artifacts;
pub mod client;
pub mod exec;

pub use artifacts::{ArtifactStore, ProgramKey, ProgramKind};
pub use client::{Client, DeviceTensor, MemoryMeter};
pub use exec::{run, Arg};
