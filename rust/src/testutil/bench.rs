//! In-repo bench harness (criterion is not vendored in the offline
//! registry): warmup + timed iterations with trimmed-mean reporting,
//! printing criterion-style lines the bench binaries and EXPERIMENTS.md
//! capture.

use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct BenchResult {
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub iters: usize,
}

pub struct Bencher {
    warmup: usize,
    iters: usize,
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Bencher {
        assert!(iters >= 1);
        Bencher { warmup, iters }
    }

    /// Environment-tunable default: BENCH_ITERS / BENCH_WARMUP.
    pub fn from_env() -> Bencher {
        let iters = std::env::var("BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
        let warmup = std::env::var("BENCH_WARMUP").ok().and_then(|v| v.parse().ok()).unwrap_or(1);
        Bencher::new(warmup, iters)
    }

    /// Time `f`, returning trimmed statistics and printing a summary line.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Trim one from each end when we have enough samples.
        let trimmed: &[f64] = if times.len() >= 5 { &times[1..times.len() - 1] } else { &times };
        let mean = trimmed.iter().sum::<f64>() / trimmed.len() as f64;
        let res = BenchResult {
            mean_ms: mean,
            min_ms: times[0],
            max_ms: *times.last().unwrap(),
            iters: self.iters,
        };
        println!(
            "{name:<48} time: [{:.3} ms {:.3} ms {:.3} ms]",
            res.min_ms, res.mean_ms, res.max_ms
        );
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_positive_times() {
        let b = Bencher::new(0, 5);
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
        });
        assert!(r.mean_ms >= 0.0);
        assert!(r.min_ms <= r.mean_ms && r.mean_ms <= r.max_ms);
        assert_eq!(r.iters, 5);
    }
}
