//! Test & bench substrate: a mini property-testing harness and a bench
//! timer (proptest/criterion are not vendored in the offline registry).

pub mod bench;
pub mod prop;

pub use bench::{BenchResult, Bencher};
pub use prop::{gens, PropRunner};
