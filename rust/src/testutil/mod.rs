//! Test & bench substrate: a mini property-testing harness, a bench
//! timer (proptest/criterion are not vendored in the offline registry),
//! and the retained scalar oracle the packed/fused kernels are verified
//! against.

pub mod bench;
pub mod oracle;
pub mod prop;

pub use bench::{BenchResult, Bencher};
pub use prop::{gens, PropRunner};
