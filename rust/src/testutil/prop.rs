//! Minimal property-based testing harness (proptest is not vendored in the
//! offline registry). Seeded generation + a forall runner that reports the
//! failing seed, so failures are reproducible with `PROP_SEED=<n>`.

use crate::rng::Rng;
use crate::tensor::Tensor;

pub struct PropRunner {
    seed: u64,
    cases: usize,
}

impl PropRunner {
    pub fn new(cases: usize) -> PropRunner {
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xFA57CACE);
        PropRunner { seed, cases }
    }

    pub fn with_seed(seed: u64, cases: usize) -> PropRunner {
        PropRunner { seed, cases }
    }

    /// Run `prop` on `cases` generated inputs; panics with the case seed on
    /// the first failure.
    pub fn forall<T, G, P>(&self, gen: G, prop: P)
    where
        G: Fn(&mut Rng) -> T,
        P: Fn(&T) -> Result<(), String>,
        T: std::fmt::Debug,
    {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64);
            let mut rng = Rng::new(case_seed);
            let input = gen(&mut rng);
            if let Err(msg) = prop(&input) {
                panic!(
                    "property failed on case {case} (PROP_SEED={case_seed}): {msg}\ninput: {input:?}"
                );
            }
        }
    }
}

/// Common generators.
pub mod gens {
    use super::*;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    pub fn f32_in(rng: &mut Rng, lo: f32, hi: f32) -> f32 {
        rng.range(lo, hi)
    }

    /// Random tensor with dims drawn from the given candidates.
    pub fn tensor2(rng: &mut Rng, ns: &[usize], ds: &[usize], scale: f32) -> Tensor {
        let n = ns[rng.below(ns.len())];
        let d = ds[rng.below(ds.len())];
        Tensor::new(rng.normal_vec(n * d, scale), &[n, d])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        PropRunner::with_seed(1, 50).forall(
            |rng| rng.normal_vec(8, 1.0),
            |v| {
                if v.len() == 8 {
                    Ok(())
                } else {
                    Err("wrong length".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        PropRunner::with_seed(2, 10).forall(
            |rng| rng.uniform(),
            |v| {
                if *v < 0.5 {
                    Ok(())
                } else {
                    Err(format!("{v} >= 0.5"))
                }
            },
        );
    }

    #[test]
    fn generators_in_range() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let u = gens::usize_in(&mut rng, 3, 7);
            assert!((3..=7).contains(&u));
            let t = gens::tensor2(&mut rng, &[4, 8], &[2, 16], 1.0);
            assert!(t.shape()[0] == 4 || t.shape()[0] == 8);
            assert!(t.shape()[1] == 2 || t.shape()[1] == 16);
        }
    }
}
