//! The retained scalar reference implementations of the DiT forward
//! pieces — the pre-kernel `model::native` code, moved here verbatim as
//! the ORACLE the property tests (and the `bench_tables kernels`
//! old-vs-new table) compare the packed/fused/streaming kernels against.
//!
//! Semantics match python/compile/model.py (layer-norm eps 1e-6,
//! tanh-approximate GELU, SiLU, `q|k|v` contiguous split). The packed
//! matmul path is bit-exact against `matmul_bias` below (same
//! k-ascending accumulation; the old `xv == 0.0` skip only ever added
//! exact zeros); the streaming attention differs from `attention` below
//! by float-summation order only, which is why block-level comparisons
//! are tolerance-based. Do NOT optimize this module — its value is being
//! the slow, obviously-correct baseline.

use crate::config::ModelConfig;
use crate::model::kernels::{gelu, silu};
use crate::model::native::timestep_embedding;
use crate::model::weights::{BlockWeights, EmbedWeights, FinalWeights, TembWeights};
use crate::tensor::Tensor;

/// y = x @ w + b, x: [n, k] row-major, w: [k, m], b: [m] or empty — the
/// original scalar loop, data-dependent zero-skip included.
pub fn matmul_bias(x: &[f32], w: &Tensor, b: Option<&Tensor>, n: usize) -> Vec<f32> {
    let (k, m) = (w.shape()[0], w.shape()[1]);
    assert_eq!(x.len(), n * k);
    let mut y = vec![0.0f32; n * m];
    if let Some(b) = b {
        assert_eq!(b.len(), m);
        for r in 0..n {
            y[r * m..(r + 1) * m].copy_from_slice(b.data());
        }
    }
    let wd = w.data();
    for r in 0..n {
        let xr = &x[r * k..(r + 1) * k];
        let yr = &mut y[r * m..(r + 1) * m];
        for (kk, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &wd[kk * m..(kk + 1) * m];
            for (yv, &wv) in yr.iter_mut().zip(wrow) {
                *yv += xv * wv;
            }
        }
    }
    y
}

/// Parameter-free LayerNorm over the last dim (eps = 1e-6).
pub fn layer_norm(x: &mut [f32], d: usize) {
    let eps = 1e-6f32;
    for row in x.chunks_mut(d) {
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for v in row.iter_mut() {
            *v = (*v - mean) * inv;
        }
    }
}

/// Two-pass softmax attention on already-split q, k, v (each [N, D],
/// heads interleaved as D = heads · dh), materializing one logits row
/// per query — the original implementation.
pub fn attention(q: &[f32], k: &[f32], v: &[f32], n: usize, heads: usize, d: usize) -> Vec<f32> {
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = vec![0.0f32; n * d];
    let mut logits = vec![0.0f32; n];
    for h in 0..heads {
        let off = h * dh;
        for i in 0..n {
            let qi = &q[i * d + off..i * d + off + dh];
            let mut maxv = f32::NEG_INFINITY;
            for j in 0..n {
                let kj = &k[j * d + off..j * d + off + dh];
                let mut dot = 0.0f32;
                for c in 0..dh {
                    dot += qi[c] * kj[c];
                }
                let l = dot * scale;
                logits[j] = l;
                if l > maxv {
                    maxv = l;
                }
            }
            let mut denom = 0.0f32;
            for l in logits.iter_mut() {
                *l = (*l - maxv).exp();
                denom += *l;
            }
            let oi = &mut out[i * d + off..i * d + off + dh];
            for j in 0..n {
                let p = logits[j] / denom;
                if p == 0.0 {
                    continue;
                }
                let vj = &v[j * d + off..j * d + off + dh];
                for c in 0..dh {
                    oi[c] += p * vj[c];
                }
            }
        }
    }
    out
}

/// Timestep -> conditioning embedding. Returns [D].
pub fn temb_forward(t: f32, w: &TembWeights) -> Vec<f32> {
    let d = w.w1.shape()[0];
    let e = timestep_embedding(t, d);
    let mut h = matmul_bias(&e, &w.w1, Some(&w.b1), 1);
    for v in h.iter_mut() {
        *v = silu(*v);
    }
    matmul_bias(&h, &w.w2, Some(&w.b2), 1)
}

/// Latent -> hidden embedding. x: [N, C] -> [N, D].
pub fn embed_forward(x: &Tensor, w: &EmbedWeights) -> Tensor {
    let n = x.shape()[0];
    let d = w.w.shape()[1];
    Tensor::new(matmul_bias(x.data(), &w.w, Some(&w.b), n), &[n, d])
}

/// One adaLN-zero DiT block, scalar reference. h: [N, D], c: [D] -> [N, D].
pub fn block_forward(h: &Tensor, c: &[f32], cfg: &ModelConfig, w: &BlockWeights) -> Tensor {
    let (n, d) = (h.shape()[0], h.shape()[1]);
    assert_eq!(d, cfg.d);

    // Modulation: silu(c) @ wmod + bmod -> 6 chunks of D.
    let cs: Vec<f32> = c.iter().map(|&x| silu(x)).collect();
    let mod6 = matmul_bias(&cs, &w.wmod, Some(&w.bmod), 1);
    let (sh1, rest) = mod6.split_at(d);
    let (sc1, rest) = rest.split_at(d);
    let (g1, rest) = rest.split_at(d);
    let (sh2, rest) = rest.split_at(d);
    let (sc2, g2) = rest.split_at(d);

    let mut out = h.clone();

    // Attention branch.
    let mut x = h.data().to_vec();
    layer_norm(&mut x, d);
    for row in x.chunks_mut(d) {
        for j in 0..d {
            row[j] = row[j] * (1.0 + sc1[j]) + sh1[j];
        }
    }
    let qkv = matmul_bias(&x, &w.wqkv, Some(&w.bqkv), n);
    // qkv rows are [3D]: q | k | v contiguous (jnp.split on axis -1).
    let mut q = vec![0.0f32; n * d];
    let mut k = vec![0.0f32; n * d];
    let mut v = vec![0.0f32; n * d];
    for r in 0..n {
        q[r * d..(r + 1) * d].copy_from_slice(&qkv[r * 3 * d..r * 3 * d + d]);
        k[r * d..(r + 1) * d].copy_from_slice(&qkv[r * 3 * d + d..r * 3 * d + 2 * d]);
        v[r * d..(r + 1) * d].copy_from_slice(&qkv[r * 3 * d + 2 * d..r * 3 * d + 3 * d]);
    }
    let a = attention(&q, &k, &v, n, cfg.heads, d);
    let proj = matmul_bias(&a, &w.wo, Some(&w.bo), n);
    for r in 0..n {
        let orow = out.row_mut(r);
        for j in 0..d {
            orow[j] += g1[j] * proj[r * d + j];
        }
    }

    // MLP branch.
    let mut x2 = out.data().to_vec();
    layer_norm(&mut x2, d);
    for row in x2.chunks_mut(d) {
        for j in 0..d {
            row[j] = row[j] * (1.0 + sc2[j]) + sh2[j];
        }
    }
    let mut hidden = matmul_bias(&x2, &w.w1, Some(&w.b1), n);
    for vv in hidden.iter_mut() {
        *vv = gelu(*vv);
    }
    let mlp = matmul_bias(&hidden, &w.w2, Some(&w.b2), n);
    for r in 0..n {
        let orow = out.row_mut(r);
        for j in 0..d {
            orow[j] += g2[j] * mlp[r * d + j];
        }
    }
    out
}

/// Final layer: adaLN -> linear to C channels. h: [N, D] -> [N, C].
pub fn final_forward(h: &Tensor, c: &[f32], w: &FinalWeights) -> Tensor {
    let (n, d) = (h.shape()[0], h.shape()[1]);
    let cch = w.wout.shape()[1];
    let cs: Vec<f32> = c.iter().map(|&x| silu(x)).collect();
    let mod2 = matmul_bias(&cs, &w.wmod, Some(&w.bmod), 1);
    let (sh, sc) = mod2.split_at(d);
    let mut x = h.data().to_vec();
    layer_norm(&mut x, d);
    for row in x.chunks_mut(d) {
        for j in 0..d {
            row[j] = row[j] * (1.0 + sc[j]) + sh[j];
        }
    }
    Tensor::new(matmul_bias(&x, &w.wout, Some(&w.bout), n), &[n, cch])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn layer_norm_normalizes() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0];
        layer_norm(&mut x, 4);
        for row in x.chunks(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn attention_uniform_for_identical_keys() {
        let n = 4;
        let d = 8;
        let mut r = Rng::new(1);
        let q = r.normal_vec(n * d, 1.0);
        let k = vec![0.5f32; n * d]; // identical keys -> uniform weights
        let v = Rng::new(2).normal_vec(n * d, 1.0);
        let out = attention(&q, &k, &v, n, 2, d);
        for j in 0..d {
            let want: f32 = (0..n).map(|r| v[r * d + j]).sum::<f32>() / n as f32;
            for i in 0..n {
                assert!((out[i * d + j] - want).abs() < 1e-5);
            }
        }
    }
}
