//! The flight recorder: an off-by-default, bounded ring buffer of
//! per-lane step events — every (step, layer) cache decision with the
//! relative-change statistic it saw and the threshold it faced, STR
//! token partitions, fit convergence state, and stage timings from
//! queue wait to per-step kernel time.
//!
//! Sampling is per-LANE and deterministic: a request id either records
//! every event of its lifetime or none (`--trace-sample-rate`), decided
//! by a multiplicative hash of the id — no RNG, so reruns trace the
//! same lanes. The ring drops its OLDEST events on overflow (a flight
//! recorder keeps the latest window) and counts what it dropped.
//!
//! Invariant: recording observes decisions, it never makes them. The
//! stepper consults [`FlightRecorder`] only to ask "is this lane
//! sampled?" and to push events — nothing in the denoise loop reads a
//! recorded value back.
//!
//! Dump formats: NDJSON (one event per line, grep/jq-friendly) and
//! Chrome `trace_event` JSON (load in `chrome://tracing` / Perfetto;
//! shards become processes, lanes become tracks).

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

/// Default ring capacity in events (~64k). At S-variant scale one
/// traced request is `steps × layers` decision events plus a handful of
/// stage/partition events, so this holds the last few hundred lanes.
pub const DEFAULT_TRACE_EVENT_CAP: usize = 1 << 16;

/// `layer` value for events that are not layer-scoped (stage timings,
/// STR partitions).
pub const NON_LAYER: u32 = u32::MAX;

/// One recorded event. `ts_us` is µs since recorder construction;
/// `dur_us == 0` marks an instant event, anything else a span.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub ts_us: u64,
    pub dur_us: u64,
    pub shard: u32,
    /// The lane's request id — the same correlator the wire uses.
    pub lane: u64,
    pub step: u32,
    pub layer: u32,
    pub kind: EventKind,
}

#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// One per (step, layer): the cache action taken, the relative-
    /// change statistic that drove it (`delta`; infinite on the first
    /// step, serialized as null), the configured base threshold it was
    /// judged against, and the fit-confidence state (`fit_updates`
    /// observed; `downgraded` when the confidence gate demoted an
    /// Approx to Compute).
    Decision {
        action: &'static str,
        delta: f64,
        threshold: f64,
        fit_updates: u64,
        downgraded: bool,
    },
    /// STR's per-step token split: `motion_tokens` rows recomputed,
    /// the remaining `total_tokens - motion_tokens` served from cache.
    StrPartition { motion_tokens: u32, total_tokens: u32 },
    /// A named stage span: `queue_wait` (submit → admission) and `step`
    /// (one whole denoise step for this lane's batch).
    Stage { stage: &'static str },
}

#[derive(Debug, Default)]
struct Inner {
    events: VecDeque<TraceEvent>,
    dropped: u64,
}

/// The bounded event ring. One per server; shared by every shard via
/// Arc. The mutex is only held for a push or a dump — pushes happen at
/// most a few times per (lane, layer, step), orders of magnitude below
/// the kernel work between them.
#[derive(Debug)]
pub struct FlightRecorder {
    rate: f64,
    cap: usize,
    t0: Instant,
    inner: Mutex<Inner>,
}

impl FlightRecorder {
    pub fn new(rate: f64, cap: usize) -> FlightRecorder {
        FlightRecorder { rate, cap: cap.max(1), t0: Instant::now(), inner: Mutex::default() }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Deterministic per-lane sampling: hash the request id to [0, 1)
    /// and compare against the rate. Same id ⇒ same verdict, across
    /// shards and across reruns.
    pub fn sampled(&self, id: u64) -> bool {
        if self.rate >= 1.0 {
            return true;
        }
        if self.rate <= 0.0 {
            return false;
        }
        let h = id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((h >> 11) as f64 / (1u64 << 53) as f64) < self.rate
    }

    /// µs since recorder construction — the timebase of every event.
    pub fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    pub fn push(&self, ev: TraceEvent) {
        let mut inner = self.inner.lock().expect("recorder lock poisoned");
        if inner.events.len() == self.cap {
            inner.events.pop_front();
            inner.dropped += 1;
        }
        inner.events.push_back(ev);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("recorder lock poisoned").events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted by the ring since construction.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().expect("recorder lock poisoned").dropped
    }

    /// Snapshot the ring, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.inner.lock().expect("recorder lock poisoned").events.iter().cloned().collect()
    }

    /// Decision events currently in the ring, as `[compute, approx,
    /// reuse]` — the reconciliation hook for tests and smoke scripts.
    pub fn decision_counts(&self) -> [u64; 3] {
        let inner = self.inner.lock().expect("recorder lock poisoned");
        let mut t = [0u64; 3];
        for ev in &inner.events {
            if let EventKind::Decision { action, .. } = ev.kind {
                match action {
                    "compute" => t[0] += 1,
                    "approx" => t[1] += 1,
                    _ => t[2] += 1,
                }
            }
        }
        t
    }

    /// One JSON object per line; non-finite floats serialize as null.
    pub fn to_ndjson(&self) -> String {
        let mut out = String::new();
        for ev in self.events() {
            out.push_str(&event_json(&ev));
            out.push('\n');
        }
        out
    }

    /// Chrome `trace_event` format: instants (`ph:"i"`) for decisions
    /// and partitions, complete spans (`ph:"X"`) for stages; shard as
    /// pid, lane as tid so each lane gets its own track.
    pub fn to_chrome_trace(&self) -> String {
        let mut out = String::from("{\"traceEvents\":[");
        let events = self.events();
        for (i, ev) in events.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&chrome_json(ev));
        }
        out.push_str("]}");
        out
    }
}

/// A float for hand-rolled JSON: non-finite becomes null (JSON has no
/// Infinity/NaN literals).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn event_json(ev: &TraceEvent) -> String {
    let head = format!(
        "{{\"ts_us\":{},\"dur_us\":{},\"shard\":{},\"lane\":{},\"step\":{},\"layer\":{}",
        ev.ts_us,
        ev.dur_us,
        ev.shard,
        ev.lane,
        ev.step,
        if ev.layer == NON_LAYER { "null".to_string() } else { ev.layer.to_string() },
    );
    match &ev.kind {
        EventKind::Decision { action, delta, threshold, fit_updates, downgraded } => format!(
            "{head},\"kind\":\"decision\",\"action\":\"{action}\",\"delta\":{},\
             \"threshold\":{},\"fit_updates\":{fit_updates},\"downgraded\":{downgraded}}}",
            json_f64(*delta),
            json_f64(*threshold),
        ),
        EventKind::StrPartition { motion_tokens, total_tokens } => format!(
            "{head},\"kind\":\"str_partition\",\"motion_tokens\":{motion_tokens},\
             \"total_tokens\":{total_tokens}}}"
        ),
        EventKind::Stage { stage } => format!("{head},\"kind\":\"stage\",\"stage\":\"{stage}\"}}"),
    }
}

fn chrome_json(ev: &TraceEvent) -> String {
    let common = format!("\"pid\":{},\"tid\":{},\"ts\":{}", ev.shard, ev.lane, ev.ts_us);
    match &ev.kind {
        EventKind::Decision { action, delta, threshold, fit_updates, downgraded } => format!(
            "{{\"name\":\"decision:{action}\",\"ph\":\"i\",\"s\":\"t\",{common},\
             \"args\":{{\"step\":{},\"layer\":{},\"delta\":{},\"threshold\":{},\
             \"fit_updates\":{fit_updates},\"downgraded\":{downgraded}}}}}",
            ev.step,
            ev.layer,
            json_f64(*delta),
            json_f64(*threshold),
        ),
        EventKind::StrPartition { motion_tokens, total_tokens } => format!(
            "{{\"name\":\"str_partition\",\"ph\":\"i\",\"s\":\"t\",{common},\
             \"args\":{{\"step\":{},\"motion_tokens\":{motion_tokens},\
             \"total_tokens\":{total_tokens}}}}}",
            ev.step,
        ),
        EventKind::Stage { stage } => format!(
            "{{\"name\":\"{stage}\",\"ph\":\"X\",{common},\"dur\":{},\
             \"args\":{{\"step\":{}}}}}",
            ev.dur_us, ev.step,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decision(lane: u64, step: u32, layer: u32, action: &'static str) -> TraceEvent {
        TraceEvent {
            ts_us: 10,
            dur_us: 0,
            shard: 0,
            lane,
            step,
            layer,
            kind: EventKind::Decision {
                action,
                delta: 0.25,
                threshold: 0.1,
                fit_updates: 3,
                downgraded: false,
            },
        }
    }

    #[test]
    fn sampling_is_deterministic_and_rate_faithful() {
        let all = FlightRecorder::new(1.0, 16);
        let none = FlightRecorder::new(0.0, 16);
        let half = FlightRecorder::new(0.5, 16);
        for id in 0..1000u64 {
            assert!(all.sampled(id), "rate 1.0 must trace every lane");
            assert!(!none.sampled(id), "rate 0.0 must trace no lane");
            assert_eq!(half.sampled(id), half.sampled(id), "verdict must be stable");
        }
        let hits = (0..10_000u64).filter(|&id| half.sampled(id)).count();
        assert!(
            (3_000..7_000).contains(&hits),
            "rate 0.5 traced {hits}/10000 — hash badly skewed"
        );
    }

    #[test]
    fn ring_drops_oldest_and_counts_drops() {
        let rec = FlightRecorder::new(1.0, 3);
        for i in 0..5u64 {
            rec.push(decision(i, 0, 0, "compute"));
        }
        assert_eq!(rec.len(), 3);
        assert_eq!(rec.dropped(), 2);
        let lanes: Vec<u64> = rec.events().iter().map(|e| e.lane).collect();
        assert_eq!(lanes, vec![2, 3, 4], "the LATEST window survives");
    }

    #[test]
    fn decision_counts_reconcile() {
        let rec = FlightRecorder::new(1.0, 16);
        rec.push(decision(1, 0, 0, "compute"));
        rec.push(decision(1, 0, 1, "approx"));
        rec.push(decision(1, 1, 0, "reuse"));
        rec.push(decision(1, 1, 1, "reuse"));
        rec.push(TraceEvent {
            ts_us: 99,
            dur_us: 50,
            shard: 0,
            lane: 1,
            step: 1,
            layer: NON_LAYER,
            kind: EventKind::Stage { stage: "step" },
        });
        assert_eq!(rec.decision_counts(), [1, 1, 2]);
    }

    #[test]
    fn ndjson_and_chrome_dumps_are_parseable_shapes() {
        let rec = FlightRecorder::new(1.0, 16);
        rec.push(decision(7, 2, 5, "approx"));
        rec.push(TraceEvent {
            ts_us: 20,
            dur_us: 0,
            shard: 1,
            lane: 7,
            step: 2,
            layer: NON_LAYER,
            kind: EventKind::StrPartition { motion_tokens: 40, total_tokens: 64 },
        });
        rec.push(TraceEvent {
            ts_us: 30,
            dur_us: 1000,
            shard: 1,
            lane: 7,
            step: 2,
            layer: NON_LAYER,
            kind: EventKind::Stage { stage: "queue_wait" },
        });
        // First-step deltas are infinite and must serialize as null,
        // not as an invalid JSON literal.
        rec.push(TraceEvent {
            kind: EventKind::Decision {
                action: "compute",
                delta: f64::INFINITY,
                threshold: 0.1,
                fit_updates: 0,
                downgraded: false,
            },
            ..decision(7, 0, 0, "compute")
        });
        let nd = rec.to_ndjson();
        assert_eq!(nd.lines().count(), 4);
        for line in nd.lines() {
            assert!(line.starts_with('{') && line.ends_with('}'), "bad NDJSON line: {line}");
        }
        assert!(nd.contains("\"kind\":\"decision\""));
        assert!(nd.contains("\"kind\":\"str_partition\""));
        assert!(nd.contains("\"kind\":\"stage\""));
        assert!(nd.contains("\"delta\":null"), "infinite delta must be null");
        assert!(!nd.contains("inf"), "no raw inf in JSON output");
        let chrome = rec.to_chrome_trace();
        assert!(chrome.starts_with("{\"traceEvents\":["));
        assert!(chrome.ends_with("]}"));
        assert!(chrome.contains("\"ph\":\"X\""), "stages must be spans");
        assert!(chrome.contains("\"ph\":\"i\""), "decisions must be instants");
        assert!(chrome.contains("\"dur\":1000"));
        assert_eq!(rec.dropped(), 0);
    }
}
