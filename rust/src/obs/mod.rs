//! Live telemetry plane: a registry of named counters, gauges, and
//! latency histograms that the serving stack updates lock-free on the
//! hot path and operators read WHILE the server runs.
//!
//! Before this module every number funnelled into write-once fields of
//! `ShardReport`/`NetStats` and surfaced only at shutdown. Now the
//! owners hold `Arc<ShardMetrics>` / `Arc<NetMetrics>` and bump atomics
//! as they serve; the shutdown `ServerReport` is just the FINAL snapshot
//! of the same series, and a live snapshot is one [`Registry::series`]
//! call away (scraped over the wire via the `Stats` frame, printed by
//! `--stats-every`, or the `fastcache-serve stats` subcommand).
//!
//! ```text
//!  shard thread ──┐ Relaxed fetch_add            ┌─▶ Stats frame (net)
//!  net door     ──┼─▶ Counter/Gauge/Hist ── series() ─▶ --stats-every text
//!  warm store   ──┘   (Registry)                 └─▶ ServerReport (shutdown)
//! ```
//!
//! Ordering discipline (the Pelikan rule the net door already follows):
//! every atomic is `Relaxed`. Totals are read either after a thread
//! join (shutdown snapshot — the join is the synchronization edge) or
//! as a statistical observation (live scrape), never to establish
//! happens-before. Histograms sit behind a `Mutex` — each is written by
//! exactly one shard thread, so the lock is uncontended in steady state
//! and only fought over during a scrape.
//!
//! The [`recorder`] half holds the flight recorder: an off-by-default
//! bounded ring of per-lane step events (cache decisions, STR
//! partitions, stage timings). Invariant shared by both halves:
//! observation can never change a cache decision or a served latent —
//! recording reads serving state, serving never reads recording state.

pub mod recorder;

pub use recorder::{
    EventKind, FlightRecorder, TraceEvent, DEFAULT_TRACE_EVENT_CAP, NON_LAYER,
};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::api::NetStats;
use crate::faults::FaultPlan;
use crate::metrics::LatencyHistogram;
use crate::server::{ShardReport, Supervisor};
use crate::store::WarmStore;

/// A monotonic event count, updated lock-free.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        if n != 0 {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins instantaneous value (occupancy, high-water marks).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if it is higher (high-water semantics).
    pub fn raise(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A latency histogram behind a mutex. Single-writer by construction
/// (one shard thread records; scrapes clone a snapshot), so the lock is
/// uncontended on the hot path.
#[derive(Debug, Default)]
pub struct Hist(Mutex<LatencyHistogram>);

impl Hist {
    pub fn record(&self, ms: f64) {
        self.0.lock().expect("hist lock poisoned").record(ms);
    }

    pub fn snapshot(&self) -> LatencyHistogram {
        self.0.lock().expect("hist lock poisoned").clone()
    }
}

/// One shard's live series — the in-flight form of [`ShardReport`].
/// The shard thread updates these as it serves; anyone holding the Arc
/// can [`snapshot`](Self::snapshot) a consistent-enough view at any
/// time, and the shutdown report IS the final snapshot.
#[derive(Debug)]
pub struct ShardMetrics {
    pub shard: usize,
    started: Instant,
    /// Wall time at shard exit in µs; 0 while the shard is running.
    /// Lets snapshots taken after drain report the true serving window
    /// instead of ever-growing uptime.
    finished_us: AtomicU64,
    pub completed: Counter,
    pub step_calls: Counter,
    pub lane_steps: Counter,
    pub padded_flops: Counter,
    pub deadline_jobs: Counter,
    pub deadline_hits: Counter,
    pub best_effort_jobs: Counter,
    pub deadline_sheds: Counter,
    pub warm_admissions: Counter,
    pub warm_layers: Counter,
    pub scratch_bytes: Gauge,
    pub threads: Gauge,
    /// Per-(step, layer) cache decisions, by action — the live view of
    /// FastCache's whole value proposition. Counted for EVERY lane
    /// (traced or not): counting reads the decision, never shapes it.
    pub decisions_compute: Counter,
    pub decisions_approx: Counter,
    pub decisions_reuse: Counter,
    /// STR token partition: motion rows recomputed vs static rows served
    /// from cache, summed over (lane, step) prologues.
    pub str_motion_tokens: Counter,
    pub str_static_tokens: Counter,
    /// Fault containment: requests this shard answered `Internal` after
    /// a panic/step-error quarantined their lane.
    pub internal_errors: Counter,
    /// Degrade ladder: deadline lanes touched at least once / total
    /// rungs applied. Both stay 0 unless `ServerConfig::degrade` is on.
    pub degraded_lanes: Counter,
    pub degrade_rungs: Counter,
    /// Supervised restarts: times this shard tore down and rebuilt its
    /// stepper + model after flap-threshold quarantines or a watchdog
    /// escalation. Stays 0 unless the supervisor knobs are armed.
    pub restarts: Counter,
    /// Jobs the stuck-step watchdog shed from this shard's queue while
    /// it was wedged. Deadline-tagged sheds ALSO bump `deadline_sheds`
    /// so they count against the SLA — sheds are never silent.
    pub watchdog_sheds: Counter,
    pub e2e: Hist,
    pub admission_wait: Hist,
}

impl ShardMetrics {
    pub fn new(shard: usize) -> ShardMetrics {
        ShardMetrics {
            shard,
            started: Instant::now(),
            finished_us: AtomicU64::new(0),
            completed: Counter::default(),
            step_calls: Counter::default(),
            lane_steps: Counter::default(),
            padded_flops: Counter::default(),
            deadline_jobs: Counter::default(),
            deadline_hits: Counter::default(),
            best_effort_jobs: Counter::default(),
            deadline_sheds: Counter::default(),
            warm_admissions: Counter::default(),
            warm_layers: Counter::default(),
            scratch_bytes: Gauge::default(),
            threads: Gauge::default(),
            decisions_compute: Counter::default(),
            decisions_approx: Counter::default(),
            decisions_reuse: Counter::default(),
            str_motion_tokens: Counter::default(),
            str_static_tokens: Counter::default(),
            internal_errors: Counter::default(),
            degraded_lanes: Counter::default(),
            degrade_rungs: Counter::default(),
            restarts: Counter::default(),
            watchdog_sheds: Counter::default(),
            e2e: Hist::default(),
            admission_wait: Hist::default(),
        }
    }

    /// Freeze the wall clock: called once when the shard thread exits.
    pub fn mark_finished(&self) {
        let us = self.started.elapsed().as_micros() as u64;
        // A zero-µs shard lifetime is indistinguishable from "running";
        // round up so the sentinel stays unambiguous.
        self.finished_us.store(us.max(1), Ordering::Relaxed);
    }

    /// Shard lifetime in seconds: elapsed-so-far while running, frozen
    /// at the [`mark_finished`](Self::mark_finished) instant after.
    pub fn wall_s(&self) -> f64 {
        match self.finished_us.load(Ordering::Relaxed) {
            0 => self.started.elapsed().as_secs_f64(),
            us => us as f64 / 1e6,
        }
    }

    /// Materialize the classic report struct from the live series.
    pub fn snapshot(&self) -> ShardReport {
        ShardReport {
            shard: self.shard,
            completed: self.completed.get(),
            e2e: self.e2e.snapshot(),
            admission_wait: self.admission_wait.snapshot(),
            wall_s: self.wall_s(),
            step_calls: self.step_calls.get(),
            lane_steps: self.lane_steps.get(),
            padded_flops: self.padded_flops.get(),
            deadline_jobs: self.deadline_jobs.get(),
            deadline_hits: self.deadline_hits.get(),
            best_effort_jobs: self.best_effort_jobs.get(),
            deadline_sheds: self.deadline_sheds.get(),
            warm_admissions: self.warm_admissions.get(),
            warm_layers: self.warm_layers.get(),
            scratch_bytes: self.scratch_bytes.get(),
            threads: self.threads.get().max(1),
            internal_errors: self.internal_errors.get(),
            degraded_lanes: self.degraded_lanes.get(),
            degrade_rungs: self.degrade_rungs.get(),
            restarts: self.restarts.get(),
            watchdog_sheds: self.watchdog_sheds.get(),
        }
    }
}

/// The network door's live series — the in-flight form of [`NetStats`].
#[derive(Debug, Default)]
pub struct NetMetrics {
    pub conns_accepted: Counter,
    pub conns_door_shed: Counter,
    pub reqs_submitted: Counter,
    pub reqs_completed: Counter,
    pub reqs_shed: Counter,
    pub reqs_door_shed: Counter,
    pub door_sheds_deadline: Counter,
    pub bytes_in: Counter,
    pub bytes_out: Counter,
}

impl NetMetrics {
    pub fn snapshot(&self) -> NetStats {
        NetStats {
            conns_accepted: self.conns_accepted.get(),
            conns_door_shed: self.conns_door_shed.get(),
            reqs_submitted: self.reqs_submitted.get(),
            reqs_completed: self.reqs_completed.get(),
            reqs_shed: self.reqs_shed.get(),
            reqs_door_shed: self.reqs_door_shed.get(),
            door_sheds_deadline: self.door_sheds_deadline.get(),
            bytes_in: self.bytes_in.get(),
            bytes_out: self.bytes_out.get(),
        }
    }
}

/// Five-number summary of a histogram, cheap enough for the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl HistSummary {
    pub fn of(h: &LatencyHistogram) -> HistSummary {
        let pcts = h.percentiles(&[50.0, 95.0, 99.0]);
        HistSummary {
            count: h.count(),
            mean_ms: h.mean(),
            p50_ms: pcts[0],
            p95_ms: pcts[1],
            p99_ms: pcts[2],
            max_ms: h.max(),
        }
    }
}

/// One named series in a registry scrape. The name is dot-namespaced
/// by owner (`server.`, `cache.`, `str.`, `latency.`, `shard{i}.`,
/// `store.`, `net.`) — see docs/OBSERVABILITY.md for the full
/// reference.
#[derive(Clone, Debug, PartialEq)]
pub struct Series {
    pub name: String,
    pub value: SeriesValue,
}

#[derive(Clone, Debug, PartialEq)]
pub enum SeriesValue {
    Counter(u64),
    Gauge(u64),
    Hist(HistSummary),
}

impl Series {
    fn counter(name: &str, v: u64) -> Series {
        Series { name: name.to_string(), value: SeriesValue::Counter(v) }
    }

    fn gauge(name: &str, v: u64) -> Series {
        Series { name: name.to_string(), value: SeriesValue::Gauge(v) }
    }

    fn hist(name: &str, h: &LatencyHistogram) -> Series {
        Series { name: name.to_string(), value: SeriesValue::Hist(HistSummary::of(h)) }
    }
}

/// The server's telemetry registry: every live series, scrapeable at
/// any time. Built once by the dispatcher; the net door and the CLI
/// hold clones of the Arc.
#[derive(Debug)]
pub struct Registry {
    shards: Vec<Arc<ShardMetrics>>,
    net: Arc<NetMetrics>,
    store: Option<Arc<WarmStore>>,
    /// The fault plan, when one is armed: its fired-counters scrape as
    /// `faults.*` series so chaos runs can reconcile injected vs
    /// observed faults without a shutdown.
    faults: Option<Arc<FaultPlan>>,
    /// The shard supervisor, when serving: its blocklist counters and
    /// per-shard health states scrape as `supervisor.*` /
    /// `shard{i}.health` series so restarts are never silent.
    supervisor: Option<Arc<Supervisor>>,
    started: Instant,
}

impl Registry {
    pub fn new(shards: Vec<Arc<ShardMetrics>>, store: Option<Arc<WarmStore>>) -> Registry {
        Registry {
            shards,
            net: Arc::new(NetMetrics::default()),
            store,
            faults: None,
            supervisor: None,
            started: Instant::now(),
        }
    }

    /// Attach an armed fault plan so its fired-counters scrape as
    /// `faults.*` series (builder-style, called before the Arc wrap).
    pub fn with_faults(mut self, plan: Arc<FaultPlan>) -> Registry {
        self.faults = Some(plan);
        self
    }

    /// Attach the shard supervisor so blocklist counters and per-shard
    /// health states scrape (builder-style, called before the Arc wrap).
    pub fn with_supervisor(mut self, sup: Arc<Supervisor>) -> Registry {
        self.supervisor = Some(sup);
        self
    }

    pub fn shards(&self) -> &[Arc<ShardMetrics>] {
        &self.shards
    }

    /// The net door's series. The door holds this Arc and bumps it
    /// directly; in-process-only servers simply never touch it.
    pub fn net(&self) -> &Arc<NetMetrics> {
        &self.net
    }

    /// Sum of per-(step, layer) cache decisions across shards, indexed
    /// `[compute, approx, reuse]`.
    pub fn decision_totals(&self) -> [u64; 3] {
        let mut t = [0u64; 3];
        for s in &self.shards {
            t[0] += s.decisions_compute.get();
            t[1] += s.decisions_approx.get();
            t[2] += s.decisions_reuse.get();
        }
        t
    }

    /// Scrape every series. Aggregates mirror `ServerReport::merge`
    /// (sums, except `scratch_bytes`/`threads` which take the max);
    /// per-shard completion counts ride along so operators can see
    /// routing skew without a shutdown.
    pub fn series(&self) -> Vec<Series> {
        let mut out = Vec::new();
        let sum =
            |f: &dyn Fn(&ShardMetrics) -> u64| self.shards.iter().map(|s| f(s)).sum::<u64>();
        let max = |f: &dyn Fn(&ShardMetrics) -> u64| {
            self.shards.iter().map(|s| f(s)).max().unwrap_or(0)
        };
        out.push(Series::gauge(
            "server.uptime_us",
            self.started.elapsed().as_micros() as u64,
        ));
        out.push(Series::gauge("server.shards", self.shards.len() as u64));
        out.push(Series::counter("server.completed", sum(&|s| s.completed.get())));
        out.push(Series::counter("server.step_calls", sum(&|s| s.step_calls.get())));
        out.push(Series::counter("server.lane_steps", sum(&|s| s.lane_steps.get())));
        out.push(Series::counter("server.padded_flops", sum(&|s| s.padded_flops.get())));
        out.push(Series::counter("server.deadline_jobs", sum(&|s| s.deadline_jobs.get())));
        out.push(Series::counter("server.deadline_hits", sum(&|s| s.deadline_hits.get())));
        out.push(Series::counter(
            "server.best_effort_jobs",
            sum(&|s| s.best_effort_jobs.get()),
        ));
        out.push(Series::counter("server.deadline_sheds", sum(&|s| s.deadline_sheds.get())));
        out.push(Series::counter(
            "server.warm_admissions",
            sum(&|s| s.warm_admissions.get()),
        ));
        out.push(Series::counter("server.warm_layers", sum(&|s| s.warm_layers.get())));
        out.push(Series::gauge("server.scratch_bytes", max(&|s| s.scratch_bytes.get())));
        out.push(Series::gauge("server.threads", max(&|s| s.threads.get()).max(1)));
        let [c, a, r] = self.decision_totals();
        out.push(Series::counter("cache.decisions_compute", c));
        out.push(Series::counter("cache.decisions_approx", a));
        out.push(Series::counter("cache.decisions_reuse", r));
        out.push(Series::counter(
            "str.motion_tokens",
            sum(&|s| s.str_motion_tokens.get()),
        ));
        out.push(Series::counter(
            "str.static_tokens",
            sum(&|s| s.str_static_tokens.get()),
        ));
        out.push(Series::counter(
            "server.internal_errors",
            sum(&|s| s.internal_errors.get()),
        ));
        out.push(Series::counter("sla.degraded", sum(&|s| s.degraded_lanes.get())));
        out.push(Series::counter("sla.degrade_rungs", sum(&|s| s.degrade_rungs.get())));
        out.push(Series::counter("shard.restarts", sum(&|s| s.restarts.get())));
        out.push(Series::counter(
            "server.watchdog_sheds",
            sum(&|s| s.watchdog_sheds.get()),
        ));
        if let Some(sup) = &self.supervisor {
            out.push(Series::counter("supervisor.blocklisted", sup.blocklisted()));
            out.push(Series::counter(
                "supervisor.poisoned_rejections",
                sup.poisoned_rejections(),
            ));
            out.push(Series::counter("supervisor.poisoned_sheds", sup.poisoned_sheds()));
            for (i, state) in sup.states().iter().enumerate() {
                out.push(Series::gauge(&format!("shard{i}.health"), *state as u64));
            }
        }
        if let Some(plan) = &self.faults {
            out.push(Series::counter("faults.panics", plan.panics_fired()));
            out.push(Series::counter("faults.pop_delays", plan.pop_delays_fired()));
            out.push(Series::counter("faults.sock_resets", plan.sock_resets_fired()));
            out.push(Series::counter("faults.snap_corruptions", plan.snap_corruptions_fired()));
            out.push(Series::counter("faults.stalls", plan.stalls_fired()));
        }
        let mut e2e = LatencyHistogram::new();
        let mut wait = LatencyHistogram::new();
        for s in &self.shards {
            e2e.merge(&s.e2e.snapshot());
            wait.merge(&s.admission_wait.snapshot());
        }
        out.push(Series::hist("latency.e2e_ms", &e2e));
        out.push(Series::hist("latency.admission_ms", &wait));
        for s in &self.shards {
            out.push(Series::counter(&format!("shard{}.completed", s.shard), s.completed.get()));
        }
        if let Some(store) = &self.store {
            let st = store.stats();
            out.push(Series::counter("store.hits", st.hits));
            out.push(Series::counter("store.misses", st.misses));
            out.push(Series::counter("store.inserts", st.inserts));
            out.push(Series::counter("store.evictions", st.evictions));
            out.push(Series::counter("store.rejected", st.rejected));
            out.push(Series::gauge("store.entries", st.entries as u64));
            out.push(Series::gauge("store.used_bytes", st.used_bytes as u64));
            out.push(Series::gauge("store.budget_bytes", st.budget_bytes as u64));
        }
        out.push(Series::counter("net.conns_accepted", self.net.conns_accepted.get()));
        out.push(Series::counter("net.conns_door_shed", self.net.conns_door_shed.get()));
        out.push(Series::counter("net.reqs_submitted", self.net.reqs_submitted.get()));
        out.push(Series::counter("net.reqs_completed", self.net.reqs_completed.get()));
        out.push(Series::counter("net.reqs_shed", self.net.reqs_shed.get()));
        out.push(Series::counter("net.reqs_door_shed", self.net.reqs_door_shed.get()));
        out.push(Series::counter(
            "net.door_sheds_deadline",
            self.net.door_sheds_deadline.get(),
        ));
        out.push(Series::counter("net.bytes_in", self.net.bytes_in.get()));
        out.push(Series::counter("net.bytes_out", self.net.bytes_out.get()));
        out
    }

    /// The text form of a scrape, for `--stats-every` and the CLI.
    pub fn render_text(&self) -> String {
        render_series(&self.series())
    }
}

/// Render a scrape as aligned text, one series per line.
pub fn render_series(series: &[Series]) -> String {
    let width = series.iter().map(|s| s.name.len()).max().unwrap_or(0);
    let mut out = String::new();
    for s in series {
        let (kind, val) = match &s.value {
            SeriesValue::Counter(v) => ("counter", v.to_string()),
            SeriesValue::Gauge(v) => ("gauge", v.to_string()),
            SeriesValue::Hist(h) => (
                "hist",
                format!(
                    "count={} mean={:.3} p50={:.3} p95={:.3} p99={:.3} max={:.3}",
                    h.count, h.mean_ms, h.p50_ms, h.p95_ms, h.p99_ms, h.max_ms
                ),
            ),
        };
        out.push_str(&format!("{:width$}  {kind:7}  {val}\n", s.name, width = width));
    }
    out
}

/// Everything the lane stepper needs to observe a step: where to count
/// (always) and where to record events (only for traced lanes).
#[derive(Clone)]
pub struct StepObserver {
    pub shard: u32,
    pub metrics: Arc<ShardMetrics>,
    pub recorder: Option<Arc<FlightRecorder>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_accumulate() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        c.add(0);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.set(7);
        g.raise(3);
        assert_eq!(g.get(), 7);
        g.raise(11);
        assert_eq!(g.get(), 11);
    }

    #[test]
    fn shard_metrics_snapshot_matches_live_series() {
        let m = ShardMetrics::new(3);
        m.completed.add(5);
        m.step_calls.add(10);
        m.lane_steps.add(20);
        m.padded_flops.add(1 << 30);
        m.deadline_jobs.add(2);
        m.deadline_hits.inc();
        m.best_effort_jobs.add(3);
        m.deadline_sheds.inc();
        m.warm_admissions.add(4);
        m.warm_layers.add(40);
        m.scratch_bytes.set(4096);
        m.threads.set(2);
        m.e2e.record(12.5);
        m.admission_wait.record(0.5);
        let r = m.snapshot();
        assert_eq!(r.shard, 3);
        assert_eq!(r.completed, 5);
        assert_eq!(r.step_calls, 10);
        assert_eq!(r.lane_steps, 20);
        assert_eq!(r.padded_flops, 1 << 30);
        assert_eq!(r.deadline_jobs, 2);
        assert_eq!(r.deadline_hits, 1);
        assert_eq!(r.best_effort_jobs, 3);
        assert_eq!(r.deadline_sheds, 1);
        assert_eq!(r.warm_admissions, 4);
        assert_eq!(r.warm_layers, 40);
        assert_eq!(r.scratch_bytes, 4096);
        assert_eq!(r.threads, 2);
        assert_eq!(r.e2e.count(), 1);
        assert_eq!(r.admission_wait.count(), 1);
        assert!(r.wall_s > 0.0, "running shard reports elapsed-so-far wall time");
        // Snapshot-after-finish freezes the clock.
        m.mark_finished();
        let frozen = m.snapshot().wall_s;
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert_eq!(m.snapshot().wall_s, frozen, "wall time must freeze at shard exit");
    }

    #[test]
    fn net_metrics_snapshot_round_trips_every_field() {
        let n = NetMetrics::default();
        n.conns_accepted.add(1);
        n.conns_door_shed.add(2);
        n.reqs_submitted.add(3);
        n.reqs_completed.add(4);
        n.reqs_shed.add(5);
        n.reqs_door_shed.add(6);
        n.door_sheds_deadline.add(7);
        n.bytes_in.add(8);
        n.bytes_out.add(9);
        let s = n.snapshot();
        assert_eq!(
            (s.conns_accepted, s.conns_door_shed, s.reqs_submitted, s.reqs_completed),
            (1, 2, 3, 4)
        );
        assert_eq!(
            (s.reqs_shed, s.reqs_door_shed, s.door_sheds_deadline, s.bytes_in, s.bytes_out),
            (5, 6, 7, 8, 9)
        );
    }

    #[test]
    fn registry_series_aggregates_like_report_merge() {
        let shards = vec![Arc::new(ShardMetrics::new(0)), Arc::new(ShardMetrics::new(1))];
        shards[0].completed.add(3);
        shards[1].completed.add(4);
        shards[0].scratch_bytes.set(100);
        shards[1].scratch_bytes.set(250);
        shards[0].decisions_compute.add(10);
        shards[1].decisions_compute.add(5);
        shards[0].decisions_reuse.add(7);
        shards[0].e2e.record(10.0);
        shards[1].e2e.record(30.0);
        let reg = Registry::new(shards, None);
        reg.net().reqs_submitted.add(7);
        let series = reg.series();
        let get = |name: &str| {
            series
                .iter()
                .find(|s| s.name == name)
                .unwrap_or_else(|| panic!("missing series {name}"))
                .value
                .clone()
        };
        assert_eq!(get("server.completed"), SeriesValue::Counter(7));
        // Resource fields take the max across shards, not the sum.
        assert_eq!(get("server.scratch_bytes"), SeriesValue::Gauge(250));
        assert_eq!(get("cache.decisions_compute"), SeriesValue::Counter(15));
        assert_eq!(get("cache.decisions_reuse"), SeriesValue::Counter(7));
        assert_eq!(get("shard0.completed"), SeriesValue::Counter(3));
        assert_eq!(get("shard1.completed"), SeriesValue::Counter(4));
        assert_eq!(get("net.reqs_submitted"), SeriesValue::Counter(7));
        assert_eq!(reg.decision_totals(), [15, 0, 7]);
        match get("latency.e2e_ms") {
            SeriesValue::Hist(h) => {
                assert_eq!(h.count, 2);
                assert!((h.mean_ms - 20.0).abs() < 1e-9);
                assert_eq!(h.max_ms, 30.0);
            }
            other => panic!("e2e must be a histogram, got {other:?}"),
        }
        // No store attached: no store.* series.
        assert!(!series.iter().any(|s| s.name.starts_with("store.")));
        // No fault plan armed: no faults.* series either.
        assert!(!series.iter().any(|s| s.name.starts_with("faults.")));
        let text = render_series(&series);
        assert!(text.contains("server.completed"));
        assert!(text.contains("counter"));
        assert!(text.lines().count() == series.len());
    }

    #[test]
    fn fault_and_degrade_series_scrape() {
        let shards = vec![Arc::new(ShardMetrics::new(0))];
        shards[0].internal_errors.inc();
        shards[0].degraded_lanes.add(2);
        shards[0].degrade_rungs.add(5);
        let plan = Arc::new(FaultPlan::parse("panic step=0 layer=0").expect("plan parses"));
        let reg = Registry::new(shards, None).with_faults(Arc::clone(&plan));
        let series = reg.series();
        let get = |name: &str| {
            series
                .iter()
                .find(|s| s.name == name)
                .unwrap_or_else(|| panic!("missing series {name}"))
                .value
                .clone()
        };
        assert_eq!(get("server.internal_errors"), SeriesValue::Counter(1));
        assert_eq!(get("sla.degraded"), SeriesValue::Counter(2));
        assert_eq!(get("sla.degrade_rungs"), SeriesValue::Counter(5));
        assert_eq!(get("faults.panics"), SeriesValue::Counter(0));
        // Fire the armed panic spec and re-scrape: the counter follows.
        assert!(plan.armed_panic(0, 0, 0, 42).is_some());
        assert_eq!(get("faults.panics"), SeriesValue::Counter(0), "old scrape is a snapshot");
        let series2 = reg.series();
        let fired = series2.iter().find(|s| s.name == "faults.panics").unwrap();
        assert_eq!(fired.value, SeriesValue::Counter(1));
        assert_eq!(
            series2.iter().filter(|s| s.name.starts_with("faults.")).count(),
            5,
            "all five fault classes scrape"
        );
        // The shard snapshot carries the new fields into ShardReport.
        let r = reg.shards()[0].snapshot();
        assert_eq!(r.internal_errors, 1);
        assert_eq!(r.degraded_lanes, 2);
        assert_eq!(r.degrade_rungs, 5);
    }

    #[test]
    fn supervisor_series_scrape_and_shard_restart_counters() {
        use crate::config::ServerConfig;
        let shards = vec![Arc::new(ShardMetrics::new(0)), Arc::new(ShardMetrics::new(1))];
        shards[0].restarts.inc();
        shards[1].restarts.add(2);
        shards[1].watchdog_sheds.add(3);
        let scfg =
            ServerConfig { shard_restart_after: 2, poison_after: 1, ..ServerConfig::default() };
        let sup = Arc::new(Supervisor::new(2, &scfg));
        let reg = Registry::new(shards, None).with_supervisor(Arc::clone(&sup));
        let series = reg.series();
        let get = |name: &str| {
            series
                .iter()
                .find(|s| s.name == name)
                .unwrap_or_else(|| panic!("missing series {name}"))
                .value
                .clone()
        };
        assert_eq!(get("shard.restarts"), SeriesValue::Counter(3));
        assert_eq!(get("server.watchdog_sheds"), SeriesValue::Counter(3));
        assert_eq!(get("supervisor.blocklisted"), SeriesValue::Counter(0));
        assert_eq!(get("supervisor.poisoned_rejections"), SeriesValue::Counter(0));
        assert_eq!(get("shard0.health"), SeriesValue::Gauge(0), "shards start Healthy");
        assert_eq!(get("shard1.health"), SeriesValue::Gauge(0));
        // The shard snapshot carries the counters into ShardReport.
        let r = reg.shards()[1].snapshot();
        assert_eq!(r.restarts, 2);
        assert_eq!(r.watchdog_sheds, 3);
        // Without a supervisor attached, no supervisor.* series scrape
        // (but shard.restarts always does).
        let reg2 = Registry::new(vec![Arc::new(ShardMetrics::new(0))], None);
        let series2 = reg2.series();
        assert!(!series2.iter().any(|s| s.name.starts_with("supervisor.")));
        assert!(series2.iter().any(|s| s.name == "shard.restarts"));
    }
}
