//! The cross-request warm-start store: a sharded, byte-budgeted, evicting
//! cache of learned serving artifacts shared by every dispatcher shard.
//!
//! Two artifact families live here:
//!
//! - **Converged [`AffineFit`]s**, keyed by `(model fingerprint, policy,
//!   steps, layer)`. Retiring lanes publish fits that saw enough updates;
//!   new lanes adopt them at admission, so the learnable linear
//!   approximation (the paper's Eq. 6) stops being relearned from scratch
//!   inside every request. Publishes MERGE sufficient statistics (pooled
//!   regression across lanes) rather than last-writer-wins.
//! - **Delta profiles**, keyed by `(model fingerprint, steps)`. Every
//!   warm-start lane records the per-(step, layer) relative hidden-state
//!   deltas it observed; retiring lanes fold them into a running mean —
//!   the SmoothCache/L2C lesson that the skip structure is a property of
//!   the (model, schedule), not of one request. Threshold policies (L2C)
//!   calibrate from the profile at admission instead of falling back to a
//!   structural prior.
//!
//! Lookups clone the stored value (snapshot-at-admission): once a lane is
//! admitted, later store mutations cannot reach it, so in-flight lanes
//! stay deterministic. Keys hash to one of N mutex-guarded shards, each a
//! [`LruBytes`] with `budget / N` bytes, so the whole store provably never
//! holds more than its configured budget.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::path::Path;
use std::sync::Mutex;

use crate::cache::calibrate::DeltaProfile;
use crate::cache::AffineFit;
use crate::config::{PolicyKind, Variant};
use crate::faults::FaultPlan;
use crate::stats::PairStats;

use super::lru::{ByteSized, LruBytes};

/// What makes two serving processes interchangeable for warm-start
/// purposes: same variant + same weight seed ⇒ bit-identical weights
/// (weight generation is seed-deterministic), hence transferable fits.
///
/// Contract: the server stamps this from `ServerConfig` (`variant`,
/// `weight_seed`), so a model factory that ignores those fields (e.g. a
/// test harness with a hard-coded seed) MUST NOT share a store across
/// differently-weighted servers — the store would transfer fits between
/// models it believes identical. Dimension mismatches are skipped
/// defensively at adoption (`Lane::warm_start_fits`), but same-shape
/// different-weight transfer is undetectable here.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct ModelFingerprint {
    pub variant: Variant,
    pub weight_seed: u64,
}

#[derive(Clone, PartialEq, Eq, Hash, Debug)]
enum StoreKey {
    Fit { fp: ModelFingerprint, policy: PolicyKind, steps: usize, layer: usize },
    Profile { fp: ModelFingerprint, steps: usize },
}

/// Running mean of observed per-(step, layer) deltas; `cnt == 0` cells
/// (e.g. the whole first step) surface as +∞ — never skippable.
struct ProfileStat {
    sum: Vec<Vec<f64>>,
    cnt: Vec<Vec<u32>>,
}

impl ProfileStat {
    fn new(steps: usize, layers: usize) -> ProfileStat {
        ProfileStat { sum: vec![vec![0.0; layers]; steps], cnt: vec![vec![0; layers]; steps] }
    }

    fn fold(&mut self, deltas: &[Vec<f64>]) {
        assert_eq!(deltas.len(), self.sum.len(), "profile step-count mismatch");
        for (s, row) in deltas.iter().enumerate() {
            assert_eq!(row.len(), self.sum[s].len(), "profile layer-count mismatch");
            for (l, &d) in row.iter().enumerate() {
                if d.is_finite() {
                    self.sum[s][l] += d;
                    self.cnt[s][l] += 1;
                }
            }
        }
    }

    fn mean(&self) -> DeltaProfile {
        let deltas = self
            .sum
            .iter()
            .zip(&self.cnt)
            .map(|(srow, crow)| {
                srow.iter()
                    .zip(crow)
                    .map(|(&s, &c)| if c > 0 { s / c as f64 } else { f64::INFINITY })
                    .collect()
            })
            .collect();
        DeltaProfile { deltas }
    }
}

enum StoreValue {
    Fit(AffineFit),
    Profile(ProfileStat),
}

impl ByteSized for StoreValue {
    fn size_bytes(&self) -> usize {
        match self {
            StoreValue::Fit(f) => f.size_bytes(),
            StoreValue::Profile(p) => {
                let cells: usize = p.sum.iter().map(Vec::len).sum();
                cells * (8 + 4) + 2 * std::mem::size_of::<Vec<f64>>() * p.sum.len()
            }
        }
    }
}

/// Aggregate store counters + occupancy, surfaced through `ServerReport`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub rejected: u64,
    pub entries: usize,
    pub used_bytes: usize,
    pub budget_bytes: usize,
}

impl StoreStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counter deltas since `base` (occupancy fields stay absolute) — for
    /// per-phase reporting against one long-lived store.
    pub fn since(&self, base: &StoreStats) -> StoreStats {
        StoreStats {
            hits: self.hits - base.hits,
            misses: self.misses - base.misses,
            inserts: self.inserts - base.inserts,
            evictions: self.evictions - base.evictions,
            rejected: self.rejected - base.rejected,
            entries: self.entries,
            used_bytes: self.used_bytes,
            budget_bytes: self.budget_bytes,
        }
    }
}

/// The fleet cache. Cheap to share: `Arc<WarmStore>` across dispatcher
/// shards (and across server restarts in the experiments).
pub struct WarmStore {
    shards: Vec<Mutex<LruBytes<StoreKey, StoreValue>>>,
    budget: usize,
}

impl WarmStore {
    /// `budget_bytes` is split evenly over `shards` mutex-guarded LRU
    /// maps (keys hash to a shard), so lock contention scales with the
    /// worker count while the aggregate byte bound still holds.
    pub fn new(budget_bytes: usize, shards: usize) -> WarmStore {
        let n = shards.max(1);
        let per = (budget_bytes / n).max(1);
        WarmStore {
            shards: (0..n).map(|_| Mutex::new(LruBytes::new(per))).collect(),
            budget: per * n,
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    fn shard(&self, key: &StoreKey) -> &Mutex<LruBytes<StoreKey, StoreValue>> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// A warm fit for one layer, cloned (snapshot-at-admission).
    pub fn warm_fit(
        &self,
        fp: ModelFingerprint,
        policy: PolicyKind,
        steps: usize,
        layer: usize,
    ) -> Option<AffineFit> {
        let key = StoreKey::Fit { fp, policy, steps, layer };
        let mut shard = self.shard(&key).lock().expect("warm store poisoned");
        match shard.get(&key) {
            Some(StoreValue::Fit(f)) => Some(f.clone()),
            _ => None,
        }
    }

    /// Warm fits for every layer of a stack (each lookup counts its own
    /// hit/miss — partial warmth is normal while traffic ramps).
    pub fn warm_fits(
        &self,
        fp: ModelFingerprint,
        policy: PolicyKind,
        steps: usize,
        layers: usize,
    ) -> Vec<Option<AffineFit>> {
        (0..layers).map(|l| self.warm_fit(fp, policy, steps, l)).collect()
    }

    /// Publish one layer's converged fit: merged into the resident entry
    /// (pooled regression) or inserted fresh under the byte budget.
    pub fn publish_fit(
        &self,
        fp: ModelFingerprint,
        policy: PolicyKind,
        steps: usize,
        layer: usize,
        fit: &AffineFit,
    ) {
        let key = StoreKey::Fit { fp, policy, steps, layer };
        let mut shard = self.shard(&key).lock().expect("warm store poisoned");
        let merged = shard
            .with_mut(&key, |v| {
                if let StoreValue::Fit(resident) = v {
                    resident.merge_from(fit);
                }
            })
            .is_some();
        if !merged {
            shard.insert(key, StoreValue::Fit(fit.clone()));
        }
    }

    /// The mean delta profile for `(model, schedule)`, if any lane
    /// published one.
    pub fn warm_profile(&self, fp: ModelFingerprint, steps: usize) -> Option<DeltaProfile> {
        let key = StoreKey::Profile { fp, steps };
        let mut shard = self.shard(&key).lock().expect("warm store poisoned");
        match shard.get(&key) {
            Some(StoreValue::Profile(p)) => Some(p.mean()),
            _ => None,
        }
    }

    /// Fold one retiring lane's observed deltas (`deltas[step][layer]`,
    /// +∞ = no evidence at that site) into the fleet profile.
    pub fn publish_profile(&self, fp: ModelFingerprint, steps: usize, deltas: &[Vec<f64>]) {
        assert_eq!(deltas.len(), steps, "profile must cover the schedule");
        let key = StoreKey::Profile { fp, steps };
        let layers = deltas.first().map(Vec::len).unwrap_or(0);
        let mut shard = self.shard(&key).lock().expect("warm store poisoned");
        let folded = shard
            .with_mut(&key, |v| {
                if let StoreValue::Profile(p) = v {
                    p.fold(deltas);
                }
            })
            .is_some();
        if !folded {
            let mut p = ProfileStat::new(steps, layers);
            p.fold(deltas);
            shard.insert(key, StoreValue::Profile(p));
        }
    }

    /// Aggregate counters + occupancy over all shards.
    pub fn stats(&self) -> StoreStats {
        let mut s = StoreStats { budget_bytes: self.budget, ..StoreStats::default() };
        for shard in &self.shards {
            let shard = shard.lock().expect("warm store poisoned");
            let c = shard.counters();
            s.hits += c.hits;
            s.misses += c.misses;
            s.inserts += c.inserts;
            s.evictions += c.evictions;
            s.rejected += c.rejected;
            s.entries += shard.len();
            s.used_bytes += shard.used_bytes();
        }
        s
    }

    pub fn used_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("warm store poisoned").used_bytes())
            .sum()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().expect("warm store poisoned").len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    // --- snapshot/restore (FCWS v1, see docs/ROBUSTNESS.md) ---
    //
    // A snapshot is the store's learned evidence serialized to a single
    // checksummed blob: magic "FCWS", version, entry count, sorted
    // entries, trailing FNV-1a-64 over everything before it. Restore
    // verifies the checksum BEFORE parsing a single field, and parses the
    // whole blob before inserting anything, so a corrupt or truncated
    // file degrades to an error (caller stays cold) — never a panic and
    // never a half-restored store.

    fn snapshot_encoded(&self) -> (Vec<u8>, usize) {
        let mut entries: Vec<Vec<u8>> = Vec::new();
        for shard in &self.shards {
            let shard = shard.lock().expect("warm store poisoned");
            for (k, v) in shard.iter() {
                let mut e = Vec::new();
                encode_entry(k, v, &mut e);
                entries.push(e);
            }
        }
        // HashMap iteration order is nondeterministic; sorted encodings
        // make identical contents produce identical bytes.
        entries.sort();
        let mut out = Vec::new();
        out.extend_from_slice(SNAP_MAGIC);
        put_u32(&mut out, SNAP_VERSION);
        put_u32(&mut out, entries.len() as u32);
        for e in &entries {
            out.extend_from_slice(e);
        }
        let sum = fnv1a64(&out);
        put_u64(&mut out, sum);
        (out, entries.len())
    }

    /// The serialized snapshot blob (tests and diagnostics; servers use
    /// [`save_snapshot`](Self::save_snapshot)).
    pub fn snapshot_bytes(&self) -> Vec<u8> {
        self.snapshot_encoded().0
    }

    /// Parse and ingest a snapshot blob. All-or-nothing: any validation
    /// failure (checksum, magic, version, dimensions, non-finite floats)
    /// returns `Err` without touching the store. Returns the number of
    /// entries that fit under the byte budget.
    pub fn restore_bytes(&self, bytes: &[u8]) -> Result<usize, String> {
        if bytes.len() < SNAP_MAGIC.len() + 4 + 4 + 8 {
            return Err(format!("snapshot too short ({} bytes)", bytes.len()));
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let want = u64::from_le_bytes(tail.try_into().expect("8-byte tail"));
        let got = fnv1a64(body);
        if got != want {
            return Err(format!("checksum mismatch (stored {want:#018x}, computed {got:#018x})"));
        }
        let mut r = SnapReader { buf: body, pos: 0 };
        if r.take(SNAP_MAGIC.len())? != SNAP_MAGIC {
            return Err("bad snapshot magic (not an FCWS file)".to_string());
        }
        let version = r.u32()?;
        if version != SNAP_VERSION {
            return Err(format!("unsupported snapshot version {version} (want {SNAP_VERSION})"));
        }
        let count = r.u32()? as usize;
        let mut decoded = Vec::with_capacity(count);
        for i in 0..count {
            decoded.push(decode_entry(&mut r).map_err(|e| format!("entry {i}: {e}"))?);
        }
        if r.pos != body.len() {
            return Err(format!("{} trailing bytes after {count} entries", body.len() - r.pos));
        }
        let mut restored = 0usize;
        for (k, v) in decoded {
            if self.shard(&k).lock().expect("warm store poisoned").insert(k, v) {
                restored += 1;
            }
        }
        Ok(restored)
    }

    /// Serialize every resident entry to `path` (parent directories are
    /// created). The write is ATOMIC: bytes land in a `.tmp` sibling
    /// first and are renamed into place, so a crash mid-write — or a
    /// concurrent reader — can never observe a truncated snapshot; the
    /// last good file survives until the rename commits. Returns the
    /// entry count written.
    pub fn save_snapshot(&self, path: &Path) -> Result<usize, String> {
        let (bytes, n) = self.snapshot_encoded();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
            }
        }
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp);
        std::fs::write(&tmp, &bytes).map_err(|e| format!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path).map_err(|e| {
            // Don't leave the orphan behind on a failed commit.
            let _ = std::fs::remove_file(&tmp);
            format!("rename {} -> {}: {e}", tmp.display(), path.display())
        })?;
        Ok(n)
    }

    /// Read and ingest a snapshot file. When a fault plan with an armed
    /// `snapcorrupt` spec is supplied, the corruption is applied to the
    /// in-memory bytes first (the deterministic chaos harness — the file
    /// on disk is untouched). Returns the number of entries restored.
    pub fn load_snapshot(&self, path: &Path, faults: Option<&FaultPlan>) -> Result<usize, String> {
        let mut bytes =
            std::fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        if let Some(plan) = faults {
            plan.corrupt_snapshot(&mut bytes);
        }
        self.restore_bytes(&bytes)
    }
}

const SNAP_MAGIC: &[u8; 4] = b"FCWS";
const SNAP_VERSION: u32 = 1;
/// Ceiling on decoded `steps * layers` profile cells: bounds the
/// allocation a (checksum-valid but hostile) snapshot can demand.
const SNAP_MAX_CELLS: usize = 1 << 24;

/// FNV-1a 64-bit — tiny, dependency-free, and plenty to catch disk
/// truncation and bit rot (not a cryptographic integrity claim).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Enum → stable wire index via position in the type's `ALL` array (the
/// arrays are append-only, so indexes survive enum reordering in source).
fn variant_index(v: Variant) -> u8 {
    Variant::ALL.iter().position(|&x| x == v).expect("variant listed in ALL") as u8
}

fn policy_index(p: PolicyKind) -> u8 {
    PolicyKind::ALL.iter().position(|&x| x == p).expect("policy listed in ALL") as u8
}

fn encode_entry(key: &StoreKey, value: &StoreValue, out: &mut Vec<u8>) {
    match key {
        StoreKey::Fit { fp, policy, steps, layer } => {
            out.push(0);
            out.push(variant_index(fp.variant));
            put_u64(out, fp.weight_seed);
            out.push(policy_index(*policy));
            put_u64(out, *steps as u64);
            put_u64(out, *layer as u64);
        }
        StoreKey::Profile { fp, steps } => {
            out.push(1);
            out.push(variant_index(fp.variant));
            put_u64(out, fp.weight_seed);
            put_u64(out, *steps as u64);
        }
    }
    match value {
        StoreValue::Fit(f) => {
            put_f64(out, f.decay_factor());
            put_u64(out, f.updates());
            put_u64(out, f.channels().len() as u64);
            for c in f.channels() {
                let (n, mean_x, mean_y, m2_x, c_xy) = c.raw();
                put_u64(out, n);
                put_f64(out, mean_x);
                put_f64(out, mean_y);
                put_f64(out, m2_x);
                put_f64(out, c_xy);
            }
        }
        StoreValue::Profile(p) => {
            let layers = p.sum.first().map(Vec::len).unwrap_or(0);
            put_u64(out, p.sum.len() as u64);
            put_u64(out, layers as u64);
            for row in &p.sum {
                for &v in row {
                    put_f64(out, v);
                }
            }
            for row in &p.cnt {
                for &c in row {
                    put_u32(out, c);
                }
            }
        }
    }
}

struct SnapReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> SnapReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.buf.len() - self.pos < n {
            return Err(format!("snapshot truncated at byte {}", self.pos));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn finite_f64(&mut self, what: &str) -> Result<f64, String> {
        let v = self.f64()?;
        if v.is_finite() {
            Ok(v)
        } else {
            Err(format!("non-finite {what}"))
        }
    }
}

fn decode_entry(r: &mut SnapReader) -> Result<(StoreKey, StoreValue), String> {
    let tag = r.u8()?;
    let vi = r.u8()? as usize;
    let variant = *Variant::ALL.get(vi).ok_or_else(|| format!("unknown variant index {vi}"))?;
    let fp = ModelFingerprint { variant, weight_seed: r.u64()? };
    match tag {
        0 => {
            let pi = r.u8()? as usize;
            let policy =
                *PolicyKind::ALL.get(pi).ok_or_else(|| format!("unknown policy index {pi}"))?;
            let steps = r.u64()? as usize;
            let layer = r.u64()? as usize;
            let decay = r.finite_f64("fit decay")?;
            if !(decay > 0.0 && decay <= 1.0) {
                return Err(format!("fit decay {decay} outside (0, 1]"));
            }
            let updates = r.u64()?;
            let d = r.u64()? as usize;
            if d == 0 || d > SNAP_MAX_CELLS {
                return Err(format!("implausible fit dimension {d}"));
            }
            let mut chan = Vec::with_capacity(d);
            for _ in 0..d {
                let n = r.u64()?;
                let mean_x = r.finite_f64("fit mean_x")?;
                let mean_y = r.finite_f64("fit mean_y")?;
                let m2_x = r.finite_f64("fit m2_x")?;
                let c_xy = r.finite_f64("fit c_xy")?;
                chan.push(PairStats::from_raw(n, mean_x, mean_y, m2_x, c_xy));
            }
            Ok((
                StoreKey::Fit { fp, policy, steps, layer },
                StoreValue::Fit(AffineFit::from_parts(decay, updates, chan)),
            ))
        }
        1 => {
            let steps = r.u64()? as usize;
            let layers = r.u64()? as usize;
            if steps.checked_mul(layers).map_or(true, |c| c > SNAP_MAX_CELLS) {
                return Err(format!("implausible profile dims {steps}x{layers}"));
            }
            let mut p = ProfileStat::new(steps, layers);
            for s in 0..steps {
                for l in 0..layers {
                    p.sum[s][l] = r.finite_f64("profile sum")?;
                }
            }
            for s in 0..steps {
                for l in 0..layers {
                    p.cnt[s][l] = r.u32()?;
                }
            }
            Ok((StoreKey::Profile { fp, steps }, StoreValue::Profile(p)))
        }
        t => Err(format!("unknown entry tag {t}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn fp() -> ModelFingerprint {
        ModelFingerprint { variant: Variant::S, weight_seed: 0xD17 }
    }

    fn trained_fit(d: usize, a: f32, b: f32, seed: u64) -> AffineFit {
        let mut f = AffineFit::new(d, 1.0);
        let mut rng = crate::rng::Rng::new(seed);
        let x = Tensor::new(rng.normal_vec(32 * d, 1.0), &[32, d]);
        let mut y = x.clone();
        for v in y.data_mut().iter_mut() {
            *v = a * *v + b;
        }
        f.update(&x, &y);
        f
    }

    #[test]
    fn fit_roundtrip_and_hit_miss_accounting() {
        let store = WarmStore::new(1 << 20, 2);
        let miss = store.warm_fit(fp(), PolicyKind::FastCache, 20, 0);
        assert!(miss.is_none());
        let f = trained_fit(8, 1.5, -0.25, 1);
        store.publish_fit(fp(), PolicyKind::FastCache, 20, 0, &f);
        let got = store.warm_fit(fp(), PolicyKind::FastCache, 20, 0).expect("hit");
        assert_eq!(got.coeffs(), f.coeffs());
        // Different policy / steps / layer are distinct keys.
        assert!(store.warm_fit(fp(), PolicyKind::L2C, 20, 0).is_none());
        assert!(store.warm_fit(fp(), PolicyKind::FastCache, 10, 0).is_none());
        assert!(store.warm_fit(fp(), PolicyKind::FastCache, 20, 1).is_none());
        let s = store.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 4);
        assert_eq!(s.inserts, 1);
        assert!(s.used_bytes <= s.budget_bytes);
        assert!(s.hit_rate() > 0.0);
    }

    #[test]
    fn publish_merges_instead_of_overwriting() {
        let store = WarmStore::new(1 << 20, 1);
        let a = trained_fit(4, 2.0, 0.0, 2);
        let b = trained_fit(4, 2.0, 0.0, 3);
        store.publish_fit(fp(), PolicyKind::FastCache, 8, 0, &a);
        store.publish_fit(fp(), PolicyKind::FastCache, 8, 0, &b);
        let got = store.warm_fit(fp(), PolicyKind::FastCache, 8, 0).unwrap();
        assert_eq!(got.updates(), a.updates() + b.updates(), "evidence must pool");
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn profile_mean_and_cold_sites() {
        let store = WarmStore::new(1 << 20, 1);
        assert!(store.warm_profile(fp(), 3).is_none());
        let lane1 = vec![vec![f64::INFINITY, f64::INFINITY], vec![0.2, 0.4], vec![0.1, 0.3]];
        let lane2 = vec![vec![f64::INFINITY, f64::INFINITY], vec![0.4, 0.2], vec![0.3, 0.1]];
        store.publish_profile(fp(), 3, &lane1);
        store.publish_profile(fp(), 3, &lane2);
        let p = store.warm_profile(fp(), 3).expect("profile");
        assert!(p.deltas[0].iter().all(|d| d.is_infinite()), "step 0 is never skippable");
        assert!((p.deltas[1][0] - 0.3).abs() < 1e-12);
        assert!((p.deltas[2][1] - 0.2).abs() < 1e-12);
    }

    #[test]
    fn bytes_never_exceed_budget_and_lru_entry_is_evicted() {
        // A budget that holds only a few fit entries: flooding layers must
        // evict the least-recently-used ones, never exceed the budget.
        let one = trained_fit(64, 1.0, 0.0, 4);
        let per_entry = one.size_bytes() + super::super::lru::ENTRY_OVERHEAD;
        let store = WarmStore::new(per_entry * 3, 1);
        for layer in 0..8 {
            store.publish_fit(fp(), PolicyKind::FastCache, 20, layer, &one);
            assert!(store.used_bytes() <= store.budget_bytes());
        }
        let s = store.stats();
        assert!(s.evictions >= 5, "flooding must evict: {s:?}");
        assert!(s.entries <= 3);
        // Early layers were least recently used: layer 0 must be gone.
        assert!(store.warm_fit(fp(), PolicyKind::FastCache, 20, 0).is_none());
        // The most recently published layer survives.
        assert!(store.warm_fit(fp(), PolicyKind::FastCache, 20, 7).is_some());
    }

    #[test]
    fn snapshot_roundtrip_restores_fits_and_profiles() {
        let store = WarmStore::new(1 << 20, 2);
        let f = trained_fit(8, 1.5, -0.25, 21);
        store.publish_fit(fp(), PolicyKind::FastCache, 20, 0, &f);
        store.publish_fit(fp(), PolicyKind::FastCache, 20, 3, &f);
        store.publish_profile(fp(), 3, &[vec![0.25, 0.5], vec![0.1, 0.2], vec![0.3, 0.4]]);
        let dir = std::env::temp_dir().join(format!("fcws_rt_{}", std::process::id()));
        let path = dir.join("warm.fcws");
        let saved = store.save_snapshot(&path).expect("save");
        assert_eq!(saved, 3);
        // Atomic write: the rename committed and left no temp file.
        assert!(path.exists());
        assert!(
            !dir.join("warm.fcws.tmp").exists(),
            "save must rename its temp file into place"
        );
        // Repeated saves (the periodic ticker's pattern) replace the
        // file in place without error.
        assert_eq!(store.save_snapshot(&path).expect("re-save"), 3);

        // Restore into a store with a DIFFERENT shard count: keys re-hash.
        let fresh = WarmStore::new(1 << 20, 4);
        let restored = fresh.load_snapshot(&path, None).expect("load");
        assert_eq!(restored, 3);
        let got = fresh.warm_fit(fp(), PolicyKind::FastCache, 20, 0).expect("fit restored");
        assert_eq!(got.coeffs(), f.coeffs());
        assert_eq!(got.updates(), f.updates());
        let p = fresh.warm_profile(fp(), 3).expect("profile restored");
        let orig = store.warm_profile(fp(), 3).unwrap();
        assert_eq!(p.deltas, orig.deltas);
        // Identical contents serialize to identical bytes regardless of
        // sharding or map iteration order.
        assert_eq!(store.snapshot_bytes(), fresh.snapshot_bytes());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_snapshots_are_rejected_and_the_store_stays_cold() {
        let store = WarmStore::new(1 << 20, 1);
        store.publish_fit(fp(), PolicyKind::FastCache, 12, 0, &trained_fit(8, 2.0, 0.5, 22));
        let bytes = store.snapshot_bytes();
        let cold = WarmStore::new(1 << 20, 1);
        // Truncation (what `snapcorrupt mode=truncate` produces).
        assert!(cold.restore_bytes(&bytes[..bytes.len() / 2]).is_err());
        assert!(cold.is_empty(), "rejected snapshot must leave the store cold");
        // A single flipped bit anywhere in the body fails the checksum.
        let mut flipped = bytes.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 1 << 3;
        let err = cold.restore_bytes(&flipped).unwrap_err();
        assert!(err.contains("checksum"), "{err}");
        // Bad magic with a recomputed (valid) checksum hits the magic check.
        let mut magic = bytes.clone();
        magic[0] = b'X';
        let body = magic.len() - 8;
        let sum = fnv1a64(&magic[..body]).to_le_bytes();
        magic[body..].copy_from_slice(&sum);
        let err = cold.restore_bytes(&magic).unwrap_err();
        assert!(err.contains("magic"), "{err}");
        assert!(cold.is_empty());
        // The store is fully usable cold after every rejection.
        cold.publish_fit(fp(), PolicyKind::FastCache, 12, 0, &trained_fit(8, 2.0, 0.5, 23));
        assert!(cold.warm_fit(fp(), PolicyKind::FastCache, 12, 0).is_some());
    }

    #[test]
    fn fault_plan_corruption_degrades_load_to_cold_then_spends_itself() {
        let store = WarmStore::new(1 << 20, 1);
        store.publish_fit(fp(), PolicyKind::FastCache, 12, 1, &trained_fit(8, 1.1, 0.0, 24));
        let dir = std::env::temp_dir().join(format!("fcws_chaos_{}", std::process::id()));
        let path = dir.join("warm.fcws");
        store.save_snapshot(&path).expect("save");
        let plan = FaultPlan::parse("snapcorrupt mode=bitflip").unwrap();
        let cold = WarmStore::new(1 << 20, 1);
        assert!(cold.load_snapshot(&path, Some(&plan)).is_err());
        assert_eq!(plan.snap_corruptions_fired(), 1);
        assert!(cold.is_empty());
        // The plan's single shot is spent: the retry loads clean. The file
        // itself was never modified.
        assert_eq!(cold.load_snapshot(&path, Some(&plan)).expect("clean retry"), 1);
        assert_eq!(plan.snap_corruptions_fired(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_invariant_under_randomized_publish_get_sequences() {
        use crate::testutil::prop::PropRunner;
        let template = trained_fit(16, 0.9, 0.1, 5);
        PropRunner::new(40).forall(
            |rng| {
                let budget = 512 + rng.below(8192);
                let ops: Vec<(u8, usize, usize)> = (0..rng.below(50) + 5)
                    .map(|_| (rng.below(3) as u8, rng.below(6), rng.below(10)))
                    .collect();
                (budget, ops)
            },
            |(budget, ops)| {
                let store = WarmStore::new(*budget, 2);
                for &(op, steps, layer) in ops {
                    match op {
                        0 => {
                            store.publish_fit(fp(), PolicyKind::FastCache, steps, layer, &template)
                        }
                        1 => {
                            store.warm_fit(fp(), PolicyKind::FastCache, steps, layer);
                        }
                        _ => store.publish_profile(fp(), steps, &vec![vec![0.25; 4]; steps]),
                    }
                    let used = store.used_bytes();
                    if used > store.budget_bytes() {
                        return Err(format!(
                            "stored {used} B exceeds budget {} B",
                            store.budget_bytes()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
