//! The cross-request warm-start store: a sharded, byte-budgeted, evicting
//! cache of learned serving artifacts shared by every dispatcher shard.
//!
//! Two artifact families live here:
//!
//! - **Converged [`AffineFit`]s**, keyed by `(model fingerprint, policy,
//!   steps, layer)`. Retiring lanes publish fits that saw enough updates;
//!   new lanes adopt them at admission, so the learnable linear
//!   approximation (the paper's Eq. 6) stops being relearned from scratch
//!   inside every request. Publishes MERGE sufficient statistics (pooled
//!   regression across lanes) rather than last-writer-wins.
//! - **Delta profiles**, keyed by `(model fingerprint, steps)`. Every
//!   warm-start lane records the per-(step, layer) relative hidden-state
//!   deltas it observed; retiring lanes fold them into a running mean —
//!   the SmoothCache/L2C lesson that the skip structure is a property of
//!   the (model, schedule), not of one request. Threshold policies (L2C)
//!   calibrate from the profile at admission instead of falling back to a
//!   structural prior.
//!
//! Lookups clone the stored value (snapshot-at-admission): once a lane is
//! admitted, later store mutations cannot reach it, so in-flight lanes
//! stay deterministic. Keys hash to one of N mutex-guarded shards, each a
//! [`LruBytes`] with `budget / N` bytes, so the whole store provably never
//! holds more than its configured budget.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::sync::Mutex;

use crate::cache::calibrate::DeltaProfile;
use crate::cache::AffineFit;
use crate::config::{PolicyKind, Variant};

use super::lru::{ByteSized, LruBytes};

/// What makes two serving processes interchangeable for warm-start
/// purposes: same variant + same weight seed ⇒ bit-identical weights
/// (weight generation is seed-deterministic), hence transferable fits.
///
/// Contract: the server stamps this from `ServerConfig` (`variant`,
/// `weight_seed`), so a model factory that ignores those fields (e.g. a
/// test harness with a hard-coded seed) MUST NOT share a store across
/// differently-weighted servers — the store would transfer fits between
/// models it believes identical. Dimension mismatches are skipped
/// defensively at adoption (`Lane::warm_start_fits`), but same-shape
/// different-weight transfer is undetectable here.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct ModelFingerprint {
    pub variant: Variant,
    pub weight_seed: u64,
}

#[derive(Clone, PartialEq, Eq, Hash, Debug)]
enum StoreKey {
    Fit { fp: ModelFingerprint, policy: PolicyKind, steps: usize, layer: usize },
    Profile { fp: ModelFingerprint, steps: usize },
}

/// Running mean of observed per-(step, layer) deltas; `cnt == 0` cells
/// (e.g. the whole first step) surface as +∞ — never skippable.
struct ProfileStat {
    sum: Vec<Vec<f64>>,
    cnt: Vec<Vec<u32>>,
}

impl ProfileStat {
    fn new(steps: usize, layers: usize) -> ProfileStat {
        ProfileStat { sum: vec![vec![0.0; layers]; steps], cnt: vec![vec![0; layers]; steps] }
    }

    fn fold(&mut self, deltas: &[Vec<f64>]) {
        assert_eq!(deltas.len(), self.sum.len(), "profile step-count mismatch");
        for (s, row) in deltas.iter().enumerate() {
            assert_eq!(row.len(), self.sum[s].len(), "profile layer-count mismatch");
            for (l, &d) in row.iter().enumerate() {
                if d.is_finite() {
                    self.sum[s][l] += d;
                    self.cnt[s][l] += 1;
                }
            }
        }
    }

    fn mean(&self) -> DeltaProfile {
        let deltas = self
            .sum
            .iter()
            .zip(&self.cnt)
            .map(|(srow, crow)| {
                srow.iter()
                    .zip(crow)
                    .map(|(&s, &c)| if c > 0 { s / c as f64 } else { f64::INFINITY })
                    .collect()
            })
            .collect();
        DeltaProfile { deltas }
    }
}

enum StoreValue {
    Fit(AffineFit),
    Profile(ProfileStat),
}

impl ByteSized for StoreValue {
    fn size_bytes(&self) -> usize {
        match self {
            StoreValue::Fit(f) => f.size_bytes(),
            StoreValue::Profile(p) => {
                let cells: usize = p.sum.iter().map(Vec::len).sum();
                cells * (8 + 4) + 2 * std::mem::size_of::<Vec<f64>>() * p.sum.len()
            }
        }
    }
}

/// Aggregate store counters + occupancy, surfaced through `ServerReport`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub rejected: u64,
    pub entries: usize,
    pub used_bytes: usize,
    pub budget_bytes: usize,
}

impl StoreStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counter deltas since `base` (occupancy fields stay absolute) — for
    /// per-phase reporting against one long-lived store.
    pub fn since(&self, base: &StoreStats) -> StoreStats {
        StoreStats {
            hits: self.hits - base.hits,
            misses: self.misses - base.misses,
            inserts: self.inserts - base.inserts,
            evictions: self.evictions - base.evictions,
            rejected: self.rejected - base.rejected,
            entries: self.entries,
            used_bytes: self.used_bytes,
            budget_bytes: self.budget_bytes,
        }
    }
}

/// The fleet cache. Cheap to share: `Arc<WarmStore>` across dispatcher
/// shards (and across server restarts in the experiments).
pub struct WarmStore {
    shards: Vec<Mutex<LruBytes<StoreKey, StoreValue>>>,
    budget: usize,
}

impl WarmStore {
    /// `budget_bytes` is split evenly over `shards` mutex-guarded LRU
    /// maps (keys hash to a shard), so lock contention scales with the
    /// worker count while the aggregate byte bound still holds.
    pub fn new(budget_bytes: usize, shards: usize) -> WarmStore {
        let n = shards.max(1);
        let per = (budget_bytes / n).max(1);
        WarmStore {
            shards: (0..n).map(|_| Mutex::new(LruBytes::new(per))).collect(),
            budget: per * n,
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    fn shard(&self, key: &StoreKey) -> &Mutex<LruBytes<StoreKey, StoreValue>> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// A warm fit for one layer, cloned (snapshot-at-admission).
    pub fn warm_fit(
        &self,
        fp: ModelFingerprint,
        policy: PolicyKind,
        steps: usize,
        layer: usize,
    ) -> Option<AffineFit> {
        let key = StoreKey::Fit { fp, policy, steps, layer };
        let mut shard = self.shard(&key).lock().expect("warm store poisoned");
        match shard.get(&key) {
            Some(StoreValue::Fit(f)) => Some(f.clone()),
            _ => None,
        }
    }

    /// Warm fits for every layer of a stack (each lookup counts its own
    /// hit/miss — partial warmth is normal while traffic ramps).
    pub fn warm_fits(
        &self,
        fp: ModelFingerprint,
        policy: PolicyKind,
        steps: usize,
        layers: usize,
    ) -> Vec<Option<AffineFit>> {
        (0..layers).map(|l| self.warm_fit(fp, policy, steps, l)).collect()
    }

    /// Publish one layer's converged fit: merged into the resident entry
    /// (pooled regression) or inserted fresh under the byte budget.
    pub fn publish_fit(
        &self,
        fp: ModelFingerprint,
        policy: PolicyKind,
        steps: usize,
        layer: usize,
        fit: &AffineFit,
    ) {
        let key = StoreKey::Fit { fp, policy, steps, layer };
        let mut shard = self.shard(&key).lock().expect("warm store poisoned");
        let merged = shard
            .with_mut(&key, |v| {
                if let StoreValue::Fit(resident) = v {
                    resident.merge_from(fit);
                }
            })
            .is_some();
        if !merged {
            shard.insert(key, StoreValue::Fit(fit.clone()));
        }
    }

    /// The mean delta profile for `(model, schedule)`, if any lane
    /// published one.
    pub fn warm_profile(&self, fp: ModelFingerprint, steps: usize) -> Option<DeltaProfile> {
        let key = StoreKey::Profile { fp, steps };
        let mut shard = self.shard(&key).lock().expect("warm store poisoned");
        match shard.get(&key) {
            Some(StoreValue::Profile(p)) => Some(p.mean()),
            _ => None,
        }
    }

    /// Fold one retiring lane's observed deltas (`deltas[step][layer]`,
    /// +∞ = no evidence at that site) into the fleet profile.
    pub fn publish_profile(&self, fp: ModelFingerprint, steps: usize, deltas: &[Vec<f64>]) {
        assert_eq!(deltas.len(), steps, "profile must cover the schedule");
        let key = StoreKey::Profile { fp, steps };
        let layers = deltas.first().map(Vec::len).unwrap_or(0);
        let mut shard = self.shard(&key).lock().expect("warm store poisoned");
        let folded = shard
            .with_mut(&key, |v| {
                if let StoreValue::Profile(p) = v {
                    p.fold(deltas);
                }
            })
            .is_some();
        if !folded {
            let mut p = ProfileStat::new(steps, layers);
            p.fold(deltas);
            shard.insert(key, StoreValue::Profile(p));
        }
    }

    /// Aggregate counters + occupancy over all shards.
    pub fn stats(&self) -> StoreStats {
        let mut s = StoreStats { budget_bytes: self.budget, ..StoreStats::default() };
        for shard in &self.shards {
            let shard = shard.lock().expect("warm store poisoned");
            let c = shard.counters();
            s.hits += c.hits;
            s.misses += c.misses;
            s.inserts += c.inserts;
            s.evictions += c.evictions;
            s.rejected += c.rejected;
            s.entries += shard.len();
            s.used_bytes += shard.used_bytes();
        }
        s
    }

    pub fn used_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("warm store poisoned").used_bytes())
            .sum()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().expect("warm store poisoned").len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn fp() -> ModelFingerprint {
        ModelFingerprint { variant: Variant::S, weight_seed: 0xD17 }
    }

    fn trained_fit(d: usize, a: f32, b: f32, seed: u64) -> AffineFit {
        let mut f = AffineFit::new(d, 1.0);
        let mut rng = crate::rng::Rng::new(seed);
        let x = Tensor::new(rng.normal_vec(32 * d, 1.0), &[32, d]);
        let mut y = x.clone();
        for v in y.data_mut().iter_mut() {
            *v = a * *v + b;
        }
        f.update(&x, &y);
        f
    }

    #[test]
    fn fit_roundtrip_and_hit_miss_accounting() {
        let store = WarmStore::new(1 << 20, 2);
        let miss = store.warm_fit(fp(), PolicyKind::FastCache, 20, 0);
        assert!(miss.is_none());
        let f = trained_fit(8, 1.5, -0.25, 1);
        store.publish_fit(fp(), PolicyKind::FastCache, 20, 0, &f);
        let got = store.warm_fit(fp(), PolicyKind::FastCache, 20, 0).expect("hit");
        assert_eq!(got.coeffs(), f.coeffs());
        // Different policy / steps / layer are distinct keys.
        assert!(store.warm_fit(fp(), PolicyKind::L2C, 20, 0).is_none());
        assert!(store.warm_fit(fp(), PolicyKind::FastCache, 10, 0).is_none());
        assert!(store.warm_fit(fp(), PolicyKind::FastCache, 20, 1).is_none());
        let s = store.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 4);
        assert_eq!(s.inserts, 1);
        assert!(s.used_bytes <= s.budget_bytes);
        assert!(s.hit_rate() > 0.0);
    }

    #[test]
    fn publish_merges_instead_of_overwriting() {
        let store = WarmStore::new(1 << 20, 1);
        let a = trained_fit(4, 2.0, 0.0, 2);
        let b = trained_fit(4, 2.0, 0.0, 3);
        store.publish_fit(fp(), PolicyKind::FastCache, 8, 0, &a);
        store.publish_fit(fp(), PolicyKind::FastCache, 8, 0, &b);
        let got = store.warm_fit(fp(), PolicyKind::FastCache, 8, 0).unwrap();
        assert_eq!(got.updates(), a.updates() + b.updates(), "evidence must pool");
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn profile_mean_and_cold_sites() {
        let store = WarmStore::new(1 << 20, 1);
        assert!(store.warm_profile(fp(), 3).is_none());
        let lane1 = vec![vec![f64::INFINITY, f64::INFINITY], vec![0.2, 0.4], vec![0.1, 0.3]];
        let lane2 = vec![vec![f64::INFINITY, f64::INFINITY], vec![0.4, 0.2], vec![0.3, 0.1]];
        store.publish_profile(fp(), 3, &lane1);
        store.publish_profile(fp(), 3, &lane2);
        let p = store.warm_profile(fp(), 3).expect("profile");
        assert!(p.deltas[0].iter().all(|d| d.is_infinite()), "step 0 is never skippable");
        assert!((p.deltas[1][0] - 0.3).abs() < 1e-12);
        assert!((p.deltas[2][1] - 0.2).abs() < 1e-12);
    }

    #[test]
    fn bytes_never_exceed_budget_and_lru_entry_is_evicted() {
        // A budget that holds only a few fit entries: flooding layers must
        // evict the least-recently-used ones, never exceed the budget.
        let one = trained_fit(64, 1.0, 0.0, 4);
        let per_entry = one.size_bytes() + super::super::lru::ENTRY_OVERHEAD;
        let store = WarmStore::new(per_entry * 3, 1);
        for layer in 0..8 {
            store.publish_fit(fp(), PolicyKind::FastCache, 20, layer, &one);
            assert!(store.used_bytes() <= store.budget_bytes());
        }
        let s = store.stats();
        assert!(s.evictions >= 5, "flooding must evict: {s:?}");
        assert!(s.entries <= 3);
        // Early layers were least recently used: layer 0 must be gone.
        assert!(store.warm_fit(fp(), PolicyKind::FastCache, 20, 0).is_none());
        // The most recently published layer survives.
        assert!(store.warm_fit(fp(), PolicyKind::FastCache, 20, 7).is_some());
    }

    #[test]
    fn budget_invariant_under_randomized_publish_get_sequences() {
        use crate::testutil::prop::PropRunner;
        let template = trained_fit(16, 0.9, 0.1, 5);
        PropRunner::new(40).forall(
            |rng| {
                let budget = 512 + rng.below(8192);
                let ops: Vec<(u8, usize, usize)> = (0..rng.below(50) + 5)
                    .map(|_| (rng.below(3) as u8, rng.below(6), rng.below(10)))
                    .collect();
                (budget, ops)
            },
            |(budget, ops)| {
                let store = WarmStore::new(*budget, 2);
                for &(op, steps, layer) in ops {
                    match op {
                        0 => {
                            store.publish_fit(fp(), PolicyKind::FastCache, steps, layer, &template)
                        }
                        1 => {
                            store.warm_fit(fp(), PolicyKind::FastCache, steps, layer);
                        }
                        _ => store.publish_profile(fp(), steps, &vec![vec![0.25; 4]; steps]),
                    }
                    let used = store.used_bytes();
                    if used > store.budget_bytes() {
                        return Err(format!(
                            "stored {used} B exceeds budget {} B",
                            store.budget_bytes()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
