//! Byte-budgeted LRU map — the one eviction/accounting primitive behind
//! every fleet-level cache in this crate (the warm-start store's shards
//! and the bounded `ScheduleCache`).
//!
//! Semantics:
//! - An explicit byte budget. `used_bytes() <= budget()` is an invariant
//!   after every operation (property-tested in `store::warm`).
//! - Entries are sized by [`ByteSized`] plus a fixed per-entry overhead.
//! - Inserting past the budget evicts least-recently-used entries until
//!   the newcomer fits; a value larger than the whole budget is rejected
//!   (counted, not stored) rather than flushing everything for nothing.
//! - `get` refreshes recency; `peek` doesn't (diagnostics/tests).
//! - Hit/miss/insert/eviction/rejection counters are kept inline so every
//!   user of the primitive reports cache behavior the same way.

use std::collections::HashMap;
use std::hash::Hash;

/// Heap footprint of a cached value, in bytes. Implementations should
/// count owned allocations (the fixed per-entry overhead is added by the
/// map itself).
pub trait ByteSized {
    fn size_bytes(&self) -> usize;
}

impl<T: ByteSized> ByteSized for std::sync::Arc<T> {
    fn size_bytes(&self) -> usize {
        T::size_bytes(self)
    }
}

impl ByteSized for crate::tensor::Tensor {
    fn size_bytes(&self) -> usize {
        crate::tensor::Tensor::size_bytes(self)
    }
}

/// Bookkeeping + key storage cost charged per entry on top of the value's
/// own bytes.
pub const ENTRY_OVERHEAD: usize = 96;

/// Cache-behavior counters, aggregated across shards by the callers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LruCounters {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Values bigger than the whole budget (refused outright).
    pub rejected: u64,
}

struct Entry<V> {
    value: V,
    bytes: usize,
    last_used: u64,
}

/// A byte-budgeted LRU map. Not thread-safe by itself — shard it behind
/// mutexes (see `store::warm::WarmStore`) or own it single-threaded (see
/// `scheduler::ddim::ScheduleCache`).
pub struct LruBytes<K, V> {
    budget: usize,
    used: usize,
    seq: u64,
    map: HashMap<K, Entry<V>>,
    counters: LruCounters,
}

impl<K: Eq + Hash + Clone, V: ByteSized> LruBytes<K, V> {
    pub fn new(budget: usize) -> LruBytes<K, V> {
        LruBytes { budget, used: 0, seq: 0, map: HashMap::new(), counters: LruCounters::default() }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn used_bytes(&self) -> usize {
        self.used
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn counters(&self) -> LruCounters {
        self.counters
    }

    fn entry_bytes(v: &V) -> usize {
        v.size_bytes() + ENTRY_OVERHEAD
    }

    /// Look up and refresh recency. Counts a hit or a miss.
    pub fn get(&mut self, k: &K) -> Option<&V> {
        self.seq += 1;
        let seq = self.seq;
        match self.map.get_mut(k) {
            Some(e) => {
                e.last_used = seq;
                self.counters.hits += 1;
                Some(&e.value)
            }
            None => {
                self.counters.misses += 1;
                None
            }
        }
    }

    /// Look up without touching recency or counters.
    pub fn peek(&self, k: &K) -> Option<&V> {
        self.map.get(k).map(|e| &e.value)
    }

    /// Visit every resident entry without touching recency or counters
    /// (iteration order is the map's — callers needing determinism must
    /// sort). Powers the warm store's snapshot writer.
    pub fn iter(&self) -> impl Iterator<Item = (&K, &V)> {
        self.map.iter().map(|(k, e)| (k, &e.value))
    }

    /// The key that would be evicted next (least recently used).
    pub fn lru_key(&self) -> Option<K> {
        self.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| k.clone())
    }

    /// Insert (or replace) under the budget, evicting LRU entries as
    /// needed. Returns false when the value alone exceeds the budget.
    pub fn insert(&mut self, k: K, v: V) -> bool {
        let bytes = Self::entry_bytes(&v);
        if bytes > self.budget {
            self.counters.rejected += 1;
            // A replacement that no longer fits must not leave the old
            // value behind as a stale hit.
            if let Some(old) = self.map.remove(&k) {
                self.used -= old.bytes;
            }
            return false;
        }
        if let Some(old) = self.map.remove(&k) {
            self.used -= old.bytes; // replacement, not an eviction
        }
        self.evict_down_to(self.budget - bytes);
        self.seq += 1;
        self.used += bytes;
        self.counters.inserts += 1;
        self.map.insert(k, Entry { value: v, bytes, last_used: self.seq });
        true
    }

    /// Mutate a resident value in place (refreshing recency), re-measuring
    /// its bytes afterwards and evicting others if it grew past the
    /// budget. Returns `None` when the key is absent. This is the WRITE
    /// path (publish/merge): it does not touch the hit/miss counters,
    /// which track read lookups only — a publisher merging into a
    /// resident entry must not inflate the reported warm-hit rate.
    pub fn with_mut<R>(&mut self, k: &K, f: impl FnOnce(&mut V) -> R) -> Option<R> {
        self.seq += 1;
        let seq = self.seq;
        let e = self.map.get_mut(k)?;
        e.last_used = seq;
        let r = f(&mut e.value);
        let new_bytes = Self::entry_bytes(&e.value);
        self.used = self.used - e.bytes + new_bytes;
        e.bytes = new_bytes;
        if new_bytes > self.budget {
            // The entry outgrew the whole budget: drop it (the invariant
            // outranks the entry).
            self.used -= new_bytes;
            self.map.remove(k);
            self.counters.evictions += 1;
        } else if self.used > self.budget {
            // The touched entry is the most recent, so it survives this.
            self.evict_down_to(self.budget);
        }
        Some(r)
    }

    fn evict_down_to(&mut self, target: usize) {
        while self.used > target {
            let Some(victim) = self.lru_key() else { return };
            if let Some(e) = self.map.remove(&victim) {
                self.used -= e.bytes;
                self.counters.evictions += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    struct Blob(usize);
    impl ByteSized for Blob {
        fn size_bytes(&self) -> usize {
            self.0
        }
    }

    fn entry(bytes: usize) -> usize {
        bytes + ENTRY_OVERHEAD
    }

    #[test]
    fn eviction_frees_the_least_recently_used_entry() {
        let mut c: LruBytes<&str, Blob> = LruBytes::new(entry(100) * 3);
        assert!(c.insert("a", Blob(100)));
        assert!(c.insert("b", Blob(100)));
        assert!(c.insert("c", Blob(100)));
        // Touch "a": "b" becomes the LRU entry.
        assert!(c.get(&"a").is_some());
        assert_eq!(c.lru_key(), Some("b"));
        assert!(c.insert("d", Blob(100)));
        assert!(c.peek(&"b").is_none(), "LRU entry must be the one evicted");
        assert!(c.peek(&"a").is_some() && c.peek(&"c").is_some() && c.peek(&"d").is_some());
        let ct = c.counters();
        assert_eq!((ct.hits, ct.misses, ct.inserts, ct.evictions), (1, 0, 4, 1));
        assert!(c.used_bytes() <= c.budget());
    }

    #[test]
    fn oversized_values_are_rejected_not_thrashed() {
        let mut c: LruBytes<u32, Blob> = LruBytes::new(entry(64) * 2);
        assert!(c.insert(1, Blob(64)));
        assert!(!c.insert(2, Blob(10_000)));
        assert_eq!(c.counters().rejected, 1);
        assert!(c.peek(&1).is_some(), "rejection must not evict residents");
        // Replacing a resident with an oversized value drops the resident
        // (no stale hits) but stores nothing.
        assert!(!c.insert(1, Blob(10_000)));
        assert!(c.peek(&1).is_none());
        assert_eq!(c.used_bytes(), 0);
    }

    #[test]
    fn replacement_reaccounts_bytes() {
        let mut c: LruBytes<u32, Blob> = LruBytes::new(4096);
        c.insert(7, Blob(100));
        assert_eq!(c.used_bytes(), entry(100));
        c.insert(7, Blob(300));
        assert_eq!(c.used_bytes(), entry(300));
        assert_eq!(c.len(), 1);
        assert_eq!(c.counters().evictions, 0, "replacement is not an eviction");
    }

    #[test]
    fn with_mut_reaccounts_growth_and_keeps_invariant() {
        let mut c: LruBytes<u32, Blob> = LruBytes::new(entry(100) * 2);
        c.insert(1, Blob(50));
        c.insert(2, Blob(50));
        // Grow 2 in place: still fits, 1 gets evicted to make room.
        let got = c.with_mut(&2, |b| {
            b.0 = 150;
            b.0
        });
        assert_eq!(got, Some(150));
        assert!(c.used_bytes() <= c.budget());
        assert!(c.peek(&2).is_some());
        // Grow past the whole budget: the entry itself is dropped.
        c.with_mut(&2, |b| b.0 = 10_000);
        assert!(c.peek(&2).is_none());
        assert_eq!(c.used_bytes(), 0);
        assert_eq!(c.with_mut(&99, |_| ()), None);
    }

    #[test]
    fn budget_invariant_under_random_operations() {
        use crate::testutil::prop::PropRunner;
        PropRunner::new(60).forall(
            |rng| {
                let budget = 512 + rng.below(4096);
                let ops: Vec<(u8, u32, usize)> = (0..rng.below(60) + 10)
                    .map(|_| (rng.below(3) as u8, rng.below(12) as u32, rng.below(700)))
                    .collect();
                (budget, ops)
            },
            |(budget, ops)| {
                let mut c: LruBytes<u32, Blob> = LruBytes::new(*budget);
                for &(op, key, sz) in ops {
                    match op {
                        0 => {
                            c.insert(key, Blob(sz));
                        }
                        1 => {
                            c.get(&key);
                        }
                        _ => {
                            c.with_mut(&key, |b| b.0 = sz);
                        }
                    }
                    if c.used_bytes() > c.budget() {
                        return Err(format!(
                            "used {} exceeds budget {} after op {op} key {key} sz {sz}",
                            c.used_bytes(),
                            c.budget()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
