//! Cross-request warm-start store — fleet-level memory for learned
//! serving artifacts, with real cache semantics (byte budget, LRU
//! eviction, hit/miss/eviction accounting).
//!
//! FastCache's learnable linear approximation and the threshold policies'
//! calibration evidence are properties of the *(model, schedule, policy)*,
//! not of one request (the Learning-to-Cache / SmoothCache observation) —
//! so this module persists them across requests instead of relearning
//! them inside every lane:
//!
//! ```text
//!                     ┌────────────── WarmStore ──────────────┐
//!  admission ───────▶ │ shard(hash(key)) ─▶ LruBytes (budget/N)│
//!   warm_fits(fp,…)   │   Fit{fp,policy,steps,layer} → AffineFit│
//!   warm_profile(fp,…)│   Profile{fp,steps}  → mean Δ[step][l] │
//!  retirement ──────▶ │ publish_fit: MERGE sufficient stats    │
//!   publish_*(…)      │ publish_profile: fold running mean     │
//!                     └────────────────────────────────────────┘
//! ```
//!
//! Layout:
//! - [`lru`]  — the byte-budgeted LRU primitive (`LruBytes`), shared with
//!   the scheduler's bounded `ScheduleCache` so every cache in the crate
//!   routes through one accounting/eviction implementation.
//! - [`warm`] — the sharded [`WarmStore`] itself, its keys (model
//!   fingerprint = variant + weight seed), and [`StoreStats`].
//!
//! Determinism: lookups clone (snapshot-at-admission), so in-flight lanes
//! never observe store mutations; warm-start is off by default
//! (`FastCacheConfig::warm_start`), so fixed-seed parity holds unchanged
//! in the default configuration. With warm-start ON, latents depend on
//! what earlier traffic published — that is the point.

pub mod lru;
pub mod warm;

pub use lru::{ByteSized, LruBytes, LruCounters, ENTRY_OVERHEAD};
pub use warm::{ModelFingerprint, StoreStats, WarmStore};
