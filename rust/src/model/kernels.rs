//! Zero-allocation, cache-blocked native kernels for the DiT forward
//! path: packed linear layers, fused layer-norm + adaLN modulation,
//! bias + activation / gated-residual matmul epilogues, and a
//! streaming-softmax attention that reads q/k/v strided directly out of
//! the fused qkv buffer.
//!
//! ## model.py parity contract
//!
//! Semantics MUST match python/compile/model.py exactly: same layer-norm
//! epsilon (1e-6), tanh-approximate GELU (jax.nn.gelu's default), SiLU,
//! and the q|k|v split convention (`jnp.split` on the last axis). The
//! packed matmuls accumulate in the SAME k-ascending order as the
//! retained scalar oracle (`testutil::oracle`), so they are bit-exact
//! against it; only the attention softmax changes float-summation order
//! (online max/denominator instead of a two-pass softmax), which is why
//! block-level parity — and the HLO cross-check in
//! rust/tests/runtime_roundtrip.rs — is a TOLERANCE contract, not a
//! bitwise one. rust/tests/kernel_parity.rs pins both down per kernel.
//!
//! ## Layout
//!
//! A [`PackedLinear`] repacks a row-major `[K, M]` weight at
//! `WeightBank` generate/load time into column tiles of width [`NR`]:
//! tile `t` is a contiguous `[K, NR]` panel (k-major, zero-padded past
//! `M`). The microkernel walks [`MR`] rows of `x` against one panel with
//! an `MR×NR` register accumulator, so the inner loop is a unit-stride,
//! branch-free FMA chain the autovectorizer can lift to SIMD — the
//! data-dependent `x == 0.0` skip of the old scalar path is gone (a
//! separate [`PackedLinear::forward_sparse`] entry point keeps the
//! zero-row short-circuit for STR-style sparsified inputs). Panels fit
//! L2 and are reused across row blocks; the accumulator tile stays in
//! registers — that is the cache blocking.
//!
//! ## Scratch
//!
//! Every intermediate a block forward needs (qkv, normalized input,
//! attention out, MLP hidden, modulation, silu(c)) lives in a
//! [`ScratchArena`] owned by the caller (`LaneStepper`, one per engine /
//! shard worker). Buffers only ever grow, so after the first step the
//! steady-state path performs zero heap allocations per block call; the
//! arena's high-water mark is reported through `ServerReport` and
//! asserted stable in tests.

use crate::tensor::Tensor;

/// Column-tile width of the packed layout (one microkernel accumulator
/// row; 16 f32 = two AVX2 / one AVX-512 vector per unrolled step).
pub const NR: usize = 16;
/// Row-block height of the microkernel (x rows advanced together, so one
/// streamed panel is reused MR times from registers/L1).
pub const MR: usize = 4;

/// Whether this build defaults the microkernel inner loop to the
/// explicit f32x8-lane path (`--features simd`) or the scalar
/// accumulator. Exposed as a function so benches and tests can report
/// the compiled default without repeating the `cfg!` probe (which trips
/// `unexpected_cfgs` in crates that don't declare the feature).
pub fn simd_default() -> bool {
    cfg!(feature = "simd")
}

/// Portable 8-wide f32 vector for the explicit-SIMD microkernel path
/// (`wide`-style fixed-width array, no unstable `std::simd`, no arch
/// intrinsics). Multiply and add stay SEPARATE operations — never a
/// hardware fused mul-add — so every output element sees exactly the
/// summation the scalar path produces and oracle bit-parity holds on
/// both paths.
#[derive(Clone, Copy)]
struct F32x8([f32; 8]);

impl F32x8 {
    #[inline(always)]
    fn from_slice(s: &[f32]) -> F32x8 {
        let mut v = [0.0f32; 8];
        v.copy_from_slice(&s[..8]);
        F32x8(v)
    }

    #[inline(always)]
    fn splat(x: f32) -> F32x8 {
        F32x8([x; 8])
    }

    #[inline(always)]
    fn mul(self, o: F32x8) -> F32x8 {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a *= b;
        }
        F32x8(r)
    }

    #[inline(always)]
    fn add(self, o: F32x8) -> F32x8 {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a += b;
        }
        F32x8(r)
    }

    #[inline(always)]
    fn write(self, out: &mut [f32]) {
        out[..8].copy_from_slice(&self.0);
    }
}

/// `acc[j] += xv · w[j]` across one NR-wide accumulator row as two
/// explicit f32x8 lanes (NR = 16 = 2 × 8; the const assert below pins
/// that). Per-element arithmetic is identical to the scalar loop.
#[inline(always)]
fn axpy_nr_lanes(acc: &mut [f32; NR], xv: f32, w: &[f32]) {
    const _: () = assert!(NR == 16, "lane kernel assumes two f32x8 per tile row");
    let xs = F32x8::splat(xv);
    let lo = F32x8::from_slice(&acc[..8]).add(xs.mul(F32x8::from_slice(&w[..8])));
    let hi = F32x8::from_slice(&acc[8..]).add(xs.mul(F32x8::from_slice(&w[8..16])));
    lo.write(&mut acc[..8]);
    hi.write(&mut acc[8..]);
}

/// Effective intra-op worker count for an n-row kernel: never more
/// workers than `unit`-aligned row blocks, never zero.
fn plan_threads(threads: usize, n: usize, unit: usize) -> usize {
    threads.clamp(1, n.div_ceil(unit).max(1))
}

/// Rows per worker, rounded up to a multiple of `unit` so chunk
/// boundaries stay on microkernel row-block edges. Together with
/// [`plan_threads`] this guarantees `span × workers >= n` and at most
/// `workers` chunks.
fn row_span(n: usize, workers: usize, unit: usize) -> usize {
    n.div_ceil(workers).div_ceil(unit) * unit
}

/// SiLU (x · σ(x)), matching jax.nn.silu.
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// tanh-approximate GELU (jax.nn.gelu default).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Activation fused into the matmul writeback (applied after bias).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Act {
    None,
    Gelu,
    Silu,
}

#[inline]
fn apply_act(act: Act, v: f32) -> f32 {
    match act {
        Act::None => v,
        Act::Gelu => gelu(v),
        Act::Silu => silu(v),
    }
}

/// How the microkernel's accumulator tile leaves the registers.
#[derive(Clone, Copy)]
enum WriteBack<'a> {
    /// `out = act(acc)` (acc is bias-initialized).
    Store(Act),
    /// `out += gate[j] · acc` — the fused residual epilogue of the
    /// attention-proj and MLP-down matmuls (adaLN-zero gating).
    AddGated(&'a [f32]),
}

/// A linear layer repacked for the blocked microkernel: `[K, M]` weights
/// as `ceil(M/NR)` contiguous `[K, NR]` panels plus the bias (zeros when
/// the layer has none). Built once at weight-bank generate/load time;
/// `forward` never touches the original row-major tensor.
#[derive(Clone, Debug)]
pub struct PackedLinear {
    k: usize,
    m: usize,
    data: Vec<f32>,
    bias: Vec<f32>,
}

impl PackedLinear {
    /// Repack a row-major `[K, M]` weight (and optional `[M]` bias).
    pub fn pack(w: &Tensor, b: Option<&Tensor>) -> PackedLinear {
        assert_eq!(w.shape().len(), 2, "PackedLinear wants a [K, M] matrix");
        let (k, m) = (w.shape()[0], w.shape()[1]);
        let tiles = m.div_ceil(NR);
        let mut data = vec![0.0f32; tiles * k * NR];
        let wd = w.data();
        for t in 0..tiles {
            let jb = t * NR;
            let jw = NR.min(m - jb);
            let panel = &mut data[t * k * NR..(t + 1) * k * NR];
            for kk in 0..k {
                panel[kk * NR..kk * NR + jw].copy_from_slice(&wd[kk * m + jb..kk * m + jb + jw]);
            }
        }
        let bias = match b {
            Some(t) => {
                assert_eq!(t.len(), m, "bias length mismatch");
                t.data().to_vec()
            }
            None => vec![0.0; m],
        };
        PackedLinear { k, m, data, bias }
    }

    /// Zero-sized placeholder (a released packed copy).
    fn placeholder() -> PackedLinear {
        PackedLinear { k: 0, m: 0, data: Vec::new(), bias: Vec::new() }
    }

    /// Input features.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output features.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Heap bytes of the packed panels + bias.
    pub fn size_bytes(&self) -> usize {
        (self.data.len() + self.bias.len()) * std::mem::size_of::<f32>()
    }

    /// `out = act(x @ W + b)`, x: `[n, K]`, out: `[n, M]` (overwritten).
    pub fn forward(&self, x: &[f32], n: usize, act: Act, out: &mut [f32]) {
        self.run(x, n, WriteBack::Store(act), out);
    }

    /// [`PackedLinear::forward`] with the token dimension split across
    /// `threads` scoped workers in MR-aligned row chunks. Each worker
    /// owns a disjoint slice of `out` and runs the identical per-row
    /// microkernel — per-row summation never crosses rows, so the result
    /// is BIT-IDENTICAL to `threads == 1` (rust/tests/threaded_parity.rs
    /// pins it).
    pub fn forward_t(&self, x: &[f32], n: usize, act: Act, out: &mut [f32], threads: usize) {
        self.run_t(x, n, WriteBack::Store(act), out, threads);
    }

    /// `out[r, j] += gate[j] · (x @ W + b)[r, j]` — residual accumulation
    /// written in place, no intermediate buffer.
    pub fn forward_add_gated(&self, x: &[f32], n: usize, gate: &[f32], out: &mut [f32]) {
        assert_eq!(gate.len(), self.m, "gate length mismatch");
        self.run(x, n, WriteBack::AddGated(gate), out);
    }

    /// Threaded [`PackedLinear::forward_add_gated`] (same bit-identity
    /// contract as [`PackedLinear::forward_t`]).
    pub fn forward_add_gated_t(
        &self,
        x: &[f32],
        n: usize,
        gate: &[f32],
        out: &mut [f32],
        threads: usize,
    ) {
        assert_eq!(gate.len(), self.m, "gate length mismatch");
        self.run_t(x, n, WriteBack::AddGated(gate), out, threads);
    }

    /// Sparse-row entry point for STR-zeroed inputs: rows of `x` that are
    /// entirely zero short-circuit to `act(bias)` without touching the
    /// panels. Bit-identical to [`PackedLinear::forward`] on the same
    /// input (a zero row contributes exactly `+0·w` per lane), so callers
    /// may switch on sparsity freely. The serving STR path currently
    /// GATHERS motion rows instead of zero-padding, so no production
    /// call site exists yet — this is the contract-preserving
    /// replacement for the dense kernel's removed `x == 0.0` skip,
    /// pinned against dense-with-zeros in rust/tests/kernel_parity.rs
    /// for any zero-padding caller.
    pub fn forward_sparse(&self, x: &[f32], n: usize, act: Act, out: &mut [f32]) {
        assert_eq!(x.len(), n * self.k);
        assert_eq!(out.len(), n * self.m);
        for (xr, orow) in x.chunks(self.k).zip(out.chunks_mut(self.m)) {
            if xr.iter().all(|&v| v == 0.0) {
                for (o, &b) in orow.iter_mut().zip(&self.bias) {
                    *o = apply_act(act, b);
                }
            } else {
                self.run(xr, 1, WriteBack::Store(act), orow);
            }
        }
    }

    /// Threaded [`PackedLinear::forward_sparse`]: each worker applies the
    /// same per-row zero-skip to its own row chunk, so the zero-row
    /// short-circuit and the dense path stay bit-identical under any
    /// thread count.
    pub fn forward_sparse_t(&self, x: &[f32], n: usize, act: Act, out: &mut [f32], threads: usize) {
        assert_eq!(x.len(), n * self.k);
        assert_eq!(out.len(), n * self.m);
        let workers = plan_threads(threads, n, MR);
        if workers <= 1 {
            return self.forward_sparse(x, n, act, out);
        }
        let span = row_span(n, workers, MR);
        std::thread::scope(|s| {
            for (wi, och) in out.chunks_mut(span * self.m).enumerate() {
                let rows = och.len() / self.m;
                let xs = &x[wi * span * self.k..wi * span * self.k + rows * self.k];
                s.spawn(move || self.forward_sparse(xs, rows, act, och));
            }
        });
    }

    /// Bench/test entry point exposing the inner-loop choice explicitly:
    /// `lanes = false` runs the scalar accumulator, `lanes = true` the
    /// explicit f32x8 path. Both share per-element summation order, so
    /// both are bit-exact against the oracle; production `forward*` uses
    /// the `simd` feature's compiled default ([`simd_default`]).
    pub fn forward_kernel(&self, x: &[f32], n: usize, act: Act, out: &mut [f32], lanes: bool) {
        self.run_with(x, n, WriteBack::Store(act), out, lanes);
    }

    fn run(&self, x: &[f32], n: usize, wb: WriteBack<'_>, out: &mut [f32]) {
        self.run_with(x, n, wb, out, simd_default());
    }

    /// Scoped intra-op split of [`PackedLinear::run`]: MR-aligned row
    /// chunks, one scoped worker per chunk, disjoint `out` slices via
    /// `chunks_mut`. Falls back to the serial path when the row count
    /// cannot feed more than one worker.
    fn run_t(&self, x: &[f32], n: usize, wb: WriteBack<'_>, out: &mut [f32], threads: usize) {
        assert_eq!(x.len(), n * self.k, "x length mismatch");
        assert_eq!(out.len(), n * self.m, "out length mismatch");
        let workers = plan_threads(threads, n, MR);
        if workers <= 1 {
            return self.run(x, n, wb, out);
        }
        let span = row_span(n, workers, MR);
        std::thread::scope(|s| {
            for (wi, och) in out.chunks_mut(span * self.m).enumerate() {
                let rows = och.len() / self.m;
                let xs = &x[wi * span * self.k..wi * span * self.k + rows * self.k];
                s.spawn(move || self.run(xs, rows, wb, och));
            }
        });
    }

    fn run_with(&self, x: &[f32], n: usize, wb: WriteBack<'_>, out: &mut [f32], lanes: bool) {
        let (k, m) = (self.k, self.m);
        assert_eq!(x.len(), n * k, "x length mismatch");
        assert_eq!(out.len(), n * m, "out length mismatch");
        let tiles = m.div_ceil(NR);
        let mut r = 0;
        while r < n {
            let mr = MR.min(n - r);
            for t in 0..tiles {
                let jb = t * NR;
                let jw = NR.min(m - jb);
                let panel = &self.data[t * k * NR..(t + 1) * k * NR];
                // Bias-initialized accumulator tile: the sum order
                // (bias, then k ascending) matches the scalar oracle
                // bit-for-bit. Padded columns stay zero and are never
                // written back.
                let mut acc = [[0.0f32; NR]; MR];
                for a in acc.iter_mut().take(mr) {
                    a[..jw].copy_from_slice(&self.bias[jb..jb + jw]);
                }
                if lanes {
                    for (kk, prow) in panel.chunks_exact(NR).enumerate() {
                        for (i, a) in acc.iter_mut().enumerate().take(mr) {
                            axpy_nr_lanes(a, x[(r + i) * k + kk], prow);
                        }
                    }
                } else {
                    for (kk, prow) in panel.chunks_exact(NR).enumerate() {
                        for (i, a) in acc.iter_mut().enumerate().take(mr) {
                            let xv = x[(r + i) * k + kk];
                            for (av, &wv) in a.iter_mut().zip(prow) {
                                *av += xv * wv;
                            }
                        }
                    }
                }
                match wb {
                    WriteBack::Store(act) => {
                        for (i, a) in acc.iter().enumerate().take(mr) {
                            let orow = &mut out[(r + i) * m + jb..(r + i) * m + jb + jw];
                            match act {
                                Act::None => orow.copy_from_slice(&a[..jw]),
                                _ => {
                                    for (o, &v) in orow.iter_mut().zip(a) {
                                        *o = apply_act(act, v);
                                    }
                                }
                            }
                        }
                    }
                    WriteBack::AddGated(gate) => {
                        for (i, a) in acc.iter().enumerate().take(mr) {
                            let orow = &mut out[(r + i) * m + jb..(r + i) * m + jb + jw];
                            let grow = &gate[jb..jb + jw];
                            for ((o, &v), &g) in orow.iter_mut().zip(a).zip(grow) {
                                *o += g * v;
                            }
                        }
                    }
                }
            }
            r += mr;
        }
    }
}

/// Int8-quantized [`PackedLinear`]: the identical `[K, NR]` panel layout
/// with i8 weights plus one symmetric scale per NR column tile (max |w|
/// over the tile / 127, computed at quantize time). Activations are
/// quantized per input row at call time (symmetric max-|x| / 127),
/// products accumulate in i32, and the f32 dequant
/// (`acc · x_scale · tile_scale`) is fused into the same
/// bias/activation/gated-residual epilogues as the f32 path. Opt-in per
/// model (`ServerConfig.int8` / `WeightBank::quantize_int8`); when
/// disabled the f32 kernels are byte-for-byte untouched. Parity against
/// the f32 path is a TOLERANCE tier (rust/tests/kernel_parity.rs); the
/// quality cost is measured by the `block_int8` row of
/// `bench_tables kernels`, not assumed.
#[derive(Clone, Debug)]
pub struct Int8PackedLinear {
    k: usize,
    m: usize,
    data: Vec<i8>,
    /// One symmetric scale per NR column tile.
    scales: Vec<f32>,
    bias: Vec<f32>,
}

impl Int8PackedLinear {
    /// Quantize an existing packed layer. Panels are already tiled, so
    /// each tile's scale falls out of one pass over its panel.
    pub fn quantize(p: &PackedLinear) -> Int8PackedLinear {
        let (k, m) = (p.k, p.m);
        let tiles = m.div_ceil(NR);
        let mut data = vec![0i8; p.data.len()];
        let mut scales = vec![1.0f32; tiles];
        for (t, ts) in scales.iter_mut().enumerate() {
            let panel = &p.data[t * k * NR..(t + 1) * k * NR];
            let max_abs = panel.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let scale = if max_abs > 0.0 { max_abs / 127.0 } else { 1.0 };
            *ts = scale;
            for (q, &v) in data[t * k * NR..(t + 1) * k * NR].iter_mut().zip(panel) {
                *q = (v / scale).round().clamp(-127.0, 127.0) as i8;
            }
        }
        Int8PackedLinear { k, m, data, scales, bias: p.bias.clone() }
    }

    /// Input features.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output features.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Heap bytes of the i8 panels + per-tile scales + f32 bias.
    pub fn size_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<i8>()
            + (self.scales.len() + self.bias.len()) * std::mem::size_of::<f32>()
    }

    /// Int8 counterpart of [`PackedLinear::forward`].
    pub fn forward(&self, x: &[f32], n: usize, act: Act, out: &mut [f32]) {
        self.run(x, n, WriteBack::Store(act), out);
    }

    /// Int8 counterpart of [`PackedLinear::forward_add_gated`].
    pub fn forward_add_gated(&self, x: &[f32], n: usize, gate: &[f32], out: &mut [f32]) {
        assert_eq!(gate.len(), self.m, "gate length mismatch");
        self.run(x, n, WriteBack::AddGated(gate), out);
    }

    fn run(&self, x: &[f32], n: usize, wb: WriteBack<'_>, out: &mut [f32]) {
        let (k, m) = (self.k, self.m);
        assert_eq!(x.len(), n * k, "x length mismatch");
        assert_eq!(out.len(), n * m, "out length mismatch");
        let tiles = m.div_ceil(NR);
        // Per-row symmetric activation quantization. The i8 staging
        // buffer is a per-call allocation: the int8 path is opt-in and
        // trades the zero-alloc steady-state contract for half-width
        // weight panels. Fold it into the ScratchArena if this ever
        // becomes the default serving path.
        let mut qx = vec![0i8; n * k];
        let mut xscale = vec![0.0f32; n];
        for (r, row) in x.chunks(k).enumerate() {
            let max_abs = row.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let s = if max_abs > 0.0 { max_abs / 127.0 } else { 1.0 };
            xscale[r] = s;
            for (q, &v) in qx[r * k..(r + 1) * k].iter_mut().zip(row) {
                *q = (v / s).round().clamp(-127.0, 127.0) as i8;
            }
        }
        let mut r = 0;
        while r < n {
            let mr = MR.min(n - r);
            for t in 0..tiles {
                let jb = t * NR;
                let jw = NR.min(m - jb);
                let panel = &self.data[t * k * NR..(t + 1) * k * NR];
                let mut acc = [[0i32; NR]; MR];
                for (kk, prow) in panel.chunks_exact(NR).enumerate() {
                    for (i, a) in acc.iter_mut().enumerate().take(mr) {
                        let xv = qx[(r + i) * k + kk] as i32;
                        for (av, &wv) in a.iter_mut().zip(prow) {
                            *av += xv * wv as i32;
                        }
                    }
                }
                // Dequant fused straight into the epilogues: bias is
                // added in f32 AFTER dequant (the int8 grid never sees
                // it), then the same act / gated-residual writeback as
                // the f32 path.
                let ts = self.scales[t];
                for (i, a) in acc.iter().enumerate().take(mr) {
                    let deq = xscale[r + i] * ts;
                    let orow = &mut out[(r + i) * m + jb..(r + i) * m + jb + jw];
                    match wb {
                        WriteBack::Store(act) => {
                            for ((o, &av), &b) in
                                orow.iter_mut().zip(&a[..jw]).zip(&self.bias[jb..jb + jw])
                            {
                                *o = apply_act(act, b + av as f32 * deq);
                            }
                        }
                        WriteBack::AddGated(gate) => {
                            let grow = &gate[jb..jb + jw];
                            for (((o, &av), &b), &g) in
                                orow.iter_mut().zip(&a[..jw]).zip(&self.bias[jb..jb + jw]).zip(grow)
                            {
                                *o += g * (b + av as f32 * deq);
                            }
                        }
                    }
                }
            }
            r += mr;
        }
    }
}

/// The four big block matmuls in int8 form. Modulation, temb, embed,
/// and the final layer stay f32 — they are tiny relative to these four
/// and disproportionately quality-critical (adaLN gates scale every
/// residual contribution).
#[derive(Clone, Debug)]
pub struct Int8Quad {
    pub wqkv: Int8PackedLinear,
    pub wo: Int8PackedLinear,
    pub w1: Int8PackedLinear,
    pub w2: Int8PackedLinear,
}

impl Int8Quad {
    /// Heap bytes across the four quantized layers.
    pub fn size_bytes(&self) -> usize {
        self.wqkv.size_bytes()
            + self.wo.size_bytes()
            + self.w1.size_bytes()
            + self.w2.size_bytes()
    }
}

/// Unpacked branch-free matmul for RUNTIME weights (fit matrices that
/// change per call, so repacking would cost as much as the product):
/// `out = x @ W + b`, x `[n, K]` row-major, W `[K, M]`, out overwritten.
/// Same accumulation order as the packed path and the scalar oracle.
pub fn matmul_bias_into(x: &[f32], w: &Tensor, b: Option<&Tensor>, n: usize, out: &mut [f32]) {
    let (k, m) = (w.shape()[0], w.shape()[1]);
    assert_eq!(x.len(), n * k);
    assert_eq!(out.len(), n * m);
    match b {
        Some(b) => {
            assert_eq!(b.len(), m);
            for orow in out.chunks_mut(m) {
                orow.copy_from_slice(b.data());
            }
        }
        None => out.fill(0.0),
    }
    let wd = w.data();
    for (xr, orow) in x.chunks(k).zip(out.chunks_mut(m)) {
        for (&xv, wrow) in xr.iter().zip(wd.chunks(m)) {
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

/// Fused parameter-free LayerNorm + adaLN scale/shift, one pass:
/// `out[r, j] = norm(x)[r, j] · (1 + scale[j]) + shift[j]`
/// (eps = 1e-6, identical arithmetic to the oracle's LN-then-modulate).
pub fn layernorm_mod(x: &[f32], n: usize, d: usize, shift: &[f32], scale: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), n * d);
    assert_eq!(out.len(), n * d);
    assert_eq!(shift.len(), d);
    assert_eq!(scale.len(), d);
    let eps = 1e-6f32;
    for (row, orow) in x.chunks(d).zip(out.chunks_mut(d)) {
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for (((o, &v), &sc), &sh) in orow.iter_mut().zip(row).zip(scale).zip(shift) {
            *o = (v - mean) * inv * (1.0 + sc) + sh;
        }
    }
}

/// [`layernorm_mod`] with rows split across scoped workers (MR-aligned
/// chunks, disjoint output rows). Normalization is strictly per-row, so
/// the threaded result is bit-identical to the serial one.
pub fn layernorm_mod_t(
    x: &[f32],
    n: usize,
    d: usize,
    shift: &[f32],
    scale: &[f32],
    out: &mut [f32],
    threads: usize,
) {
    let workers = plan_threads(threads, n, MR);
    if workers <= 1 {
        return layernorm_mod(x, n, d, shift, scale, out);
    }
    assert_eq!(x.len(), n * d);
    assert_eq!(out.len(), n * d);
    let span = row_span(n, workers, MR);
    std::thread::scope(|s| {
        for (wi, och) in out.chunks_mut(span * d).enumerate() {
            let rows = och.len() / d;
            let xs = &x[wi * span * d..wi * span * d + rows * d];
            s.spawn(move || layernorm_mod(xs, rows, d, shift, scale, och));
        }
    });
}

/// Query-block size of the streaming attention (k/v rows are streamed
/// once per block instead of once per query).
const MQ: usize = 4;

/// Multi-head attention with an online (streaming) softmax, reading
/// q/k/v strided DIRECTLY out of the fused qkv projection buffer — rows
/// of `[3D]` laid out `q | k | v` (the `jnp.split` convention) — so no
/// q/k/v copies exist and per-row logits never materialize. Processing
/// is per head (working set `[n, dh]`) with `MQ`-query blocking; the
/// output head-slice doubles as the online accumulator, so the kernel
/// needs no scratch at all. out: `[n, d]`, overwritten.
pub fn attention_streaming(qkv: &[f32], n: usize, heads: usize, d: usize, out: &mut [f32]) {
    let dh = d / heads;
    assert_eq!(heads * dh, d, "d must split evenly into heads");
    assert_eq!(qkv.len(), n * 3 * d);
    assert_eq!(out.len(), n * d);
    attention_rows(qkv, n, heads, d, 0, n, out);
}

/// [`attention_streaming`] with the QUERY rows split across scoped
/// workers (MQ-aligned chunks). Keys/values still stream over all `n`
/// rows inside every worker — only queries are partitioned, and each
/// query's online-softmax state (max, denominator, accumulator) is
/// private to that query, so regrouping queries across workers cannot
/// change any output bit.
pub fn attention_streaming_t(
    qkv: &[f32],
    n: usize,
    heads: usize,
    d: usize,
    out: &mut [f32],
    threads: usize,
) {
    let dh = d / heads;
    assert_eq!(heads * dh, d, "d must split evenly into heads");
    assert_eq!(qkv.len(), n * 3 * d);
    assert_eq!(out.len(), n * d);
    let workers = plan_threads(threads, n, MQ);
    if workers <= 1 {
        return attention_rows(qkv, n, heads, d, 0, n, out);
    }
    let span = row_span(n, workers, MQ);
    std::thread::scope(|s| {
        for (wi, och) in out.chunks_mut(span * d).enumerate() {
            let rows = och.len() / d;
            s.spawn(move || attention_rows(qkv, n, heads, d, wi * span, rows, och));
        }
    });
}

/// The query-row slice `[r0, r0 + rows)` of the streaming attention,
/// written to `out_rows` (`rows × d`, row 0 = query `r0`). All heads,
/// all `n` key/value rows.
fn attention_rows(
    qkv: &[f32],
    n: usize,
    heads: usize,
    d: usize,
    r0: usize,
    rows: usize,
    out_rows: &mut [f32],
) {
    let dh = d / heads;
    let stride = 3 * d;
    let scale = 1.0 / (dh as f32).sqrt();
    for h in 0..heads {
        let qo = h * dh;
        let ko = d + h * dh;
        let vo = 2 * d + h * dh;
        let mut i0 = 0;
        while i0 < rows {
            let bq = MQ.min(rows - i0);
            let mut mx = [f32::NEG_INFINITY; MQ];
            let mut den = [0.0f32; MQ];
            // The out slices are the accumulators: zero them explicitly
            // (the buffer may be a reused arena allocation).
            for i in i0..i0 + bq {
                out_rows[i * d + qo..i * d + qo + dh].fill(0.0);
            }
            for j in 0..n {
                let kj = &qkv[j * stride + ko..j * stride + ko + dh];
                let vj = &qkv[j * stride + vo..j * stride + vo + dh];
                for i in 0..bq {
                    let q_abs = r0 + i0 + i;
                    let qrow = &qkv[q_abs * stride + qo..q_abs * stride + qo + dh];
                    let mut dot = 0.0f32;
                    for (&qv, &kv) in qrow.iter().zip(kj) {
                        dot += qv * kv;
                    }
                    let logit = dot * scale;
                    let oi = &mut out_rows[(i0 + i) * d + qo..(i0 + i) * d + qo + dh];
                    if logit > mx[i] {
                        // Rescale the running sum to the new max
                        // (exp(-inf) = 0 cleanly initializes the first
                        // touch, wiping any stale accumulator content).
                        let f = (mx[i] - logit).exp();
                        den[i] *= f;
                        for o in oi.iter_mut() {
                            *o *= f;
                        }
                        mx[i] = logit;
                    }
                    let p = (logit - mx[i]).exp();
                    den[i] += p;
                    for (o, &vv) in oi.iter_mut().zip(vj) {
                        *o += p * vv;
                    }
                }
            }
            for i in 0..bq {
                let inv = 1.0 / den[i];
                for o in out_rows[(i0 + i) * d + qo..(i0 + i) * d + qo + dh].iter_mut() {
                    *o *= inv;
                }
            }
            i0 += bq;
        }
    }
}

/// Reused scratch buffers for the fused forward kernels. Owned by the
/// step driver (`LaneStepper`; one per engine / shard worker) and
/// threaded through every native forward, replacing all per-call `Vec`
/// allocations. Buffers only grow, so the steady-state path allocates
/// nothing; [`ScratchArena::high_water_bytes`] is the reporting hook.
#[derive(Default)]
pub struct ScratchArena {
    csilu: Vec<f32>,
    modv: Vec<f32>,
    xnorm: Vec<f32>,
    qkv: Vec<f32>,
    attn: Vec<f32>,
    hidden: Vec<f32>,
    /// Intra-op worker count for kernels driven through this arena
    /// (0 and 1 both mean serial). Lives here because the arena already
    /// flows through every native forward — block/final entry points
    /// read it instead of growing a `threads` parameter on each
    /// signature.
    threads: usize,
}

impl ScratchArena {
    pub fn new() -> ScratchArena {
        ScratchArena::default()
    }

    /// Set the intra-op worker count used by block/final forwards that
    /// run through this arena (bit-identical output at any setting).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads;
    }

    /// Intra-op worker count (always >= 1).
    pub fn threads(&self) -> usize {
        self.threads.max(1)
    }

    /// Total bytes currently reserved across all scratch buffers — the
    /// arena's high-water mark (capacities never shrink).
    pub fn high_water_bytes(&self) -> usize {
        (self.csilu.capacity()
            + self.modv.capacity()
            + self.xnorm.capacity()
            + self.qkv.capacity()
            + self.attn.capacity()
            + self.hidden.capacity())
            * std::mem::size_of::<f32>()
    }
}

/// Grow-only scratch view: resizes the buffer when (and only when) the
/// requested length exceeds what was ever needed before.
pub(crate) fn grab(v: &mut Vec<f32>, len: usize) -> &mut [f32] {
    if v.len() < len {
        v.resize(len, 0.0);
    }
    &mut v[..len]
}

/// The six scratch views of one block forward:
/// (silu(c), modulation, normalized input, qkv, attention out, hidden).
pub(crate) type BlockScratch<'a> =
    (&'a mut [f32], &'a mut [f32], &'a mut [f32], &'a mut [f32], &'a mut [f32], &'a mut [f32]);

/// Split the arena into the six named views a block forward needs.
/// Free function (not a method) so the borrows stay disjoint.
pub(crate) fn block_views(
    a: &mut ScratchArena,
    n: usize,
    d: usize,
    mod_len: usize,
    hidden_len: usize,
) -> BlockScratch<'_> {
    (
        grab(&mut a.csilu, d),
        grab(&mut a.modv, mod_len),
        grab(&mut a.xnorm, n * d),
        grab(&mut a.qkv, n * 3 * d),
        grab(&mut a.attn, n * d),
        grab(&mut a.hidden, hidden_len),
    )
}

/// The three views the final layer needs (silu(c), modulation,
/// normalized input) — it must not size the qkv/attn/hidden buffers a
/// block needs, or a final-only caller pays 4·n·d floats it never reads.
pub(crate) fn final_views(
    a: &mut ScratchArena,
    n: usize,
    d: usize,
) -> (&mut [f32], &mut [f32], &mut [f32]) {
    (grab(&mut a.csilu, d), grab(&mut a.modv, 2 * d), grab(&mut a.xnorm, n * d))
}

/// One DiT block's weights in packed form, calling-convention order
/// preserved conceptually (qkv, proj, mlp up/down, adaLN modulation —
/// biases folded into each [`PackedLinear`]).
#[derive(Clone, Debug)]
pub struct PackedBlock {
    pub wqkv: PackedLinear,
    pub wo: PackedLinear,
    pub w1: PackedLinear,
    pub w2: PackedLinear,
    pub wmod: PackedLinear,
    /// Int8 copies of the four big matmuls; `None` = pure f32 serving
    /// (the default — the f32 path is untouched until
    /// `WeightBank::quantize_int8` opts in).
    pub int8: Option<Int8Quad>,
}

impl PackedBlock {
    /// Build (or refresh) the int8 quad from the current f32 panels.
    pub fn quantize_int8(&mut self) {
        self.int8 = Some(Int8Quad {
            wqkv: Int8PackedLinear::quantize(&self.wqkv),
            wo: Int8PackedLinear::quantize(&self.wo),
            w1: Int8PackedLinear::quantize(&self.w1),
            w2: Int8PackedLinear::quantize(&self.w2),
        });
    }

    /// Heap bytes of the packed f32 layers plus any int8 copies.
    pub fn size_bytes(&self) -> usize {
        self.wqkv.size_bytes()
            + self.wo.size_bytes()
            + self.w1.size_bytes()
            + self.w2.size_bytes()
            + self.wmod.size_bytes()
            + self.int8.as_ref().map_or(0, Int8Quad::size_bytes)
    }
}

#[derive(Clone, Debug)]
pub struct PackedTemb {
    pub w1: PackedLinear,
    pub w2: PackedLinear,
}

#[derive(Clone, Debug)]
pub struct PackedFinal {
    pub wmod: PackedLinear,
    pub wout: PackedLinear,
}

/// The whole bank, packed. Rebuilt by `WeightBank::repack` whenever the
/// row-major tensors are mutated in place (e.g. simulated quantization).
#[derive(Clone, Debug)]
pub struct PackedBank {
    pub blocks: Vec<PackedBlock>,
    pub temb: PackedTemb,
    pub final_: PackedFinal,
    pub embed: PackedLinear,
}

impl PackedBank {
    /// A released (zero-byte) bank. HLO-mode models drop their packed
    /// copy right after the device upload — every native kernel path is
    /// gated on `ExecMode::Native`, so nothing ever reads it — instead
    /// of holding a second full weight copy for the process lifetime.
    pub fn released() -> PackedBank {
        PackedBank {
            blocks: Vec::new(),
            temb: PackedTemb { w1: PackedLinear::placeholder(), w2: PackedLinear::placeholder() },
            final_: PackedFinal {
                wmod: PackedLinear::placeholder(),
                wout: PackedLinear::placeholder(),
            },
            embed: PackedLinear::placeholder(),
        }
    }

    /// Heap bytes held by the packed copies (reported separately from the
    /// row-major bank the HLO path uploads).
    pub fn size_bytes(&self) -> usize {
        let block: usize = self.blocks.iter().map(PackedBlock::size_bytes).sum();
        block
            + self.temb.w1.size_bytes()
            + self.temb.w2.size_bytes()
            + self.final_.wmod.size_bytes()
            + self.final_.wout.size_bytes()
            + self.embed.size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testutil::oracle;

    fn rnd(seed: u64, len: usize) -> Vec<f32> {
        Rng::new(seed).normal_vec(len, 1.0)
    }

    fn rnd_t(seed: u64, shape: &[usize]) -> Tensor {
        Tensor::new(rnd(seed, shape.iter().product()), shape)
    }

    #[test]
    fn packed_forward_matches_scalar_oracle() {
        // Ragged shapes around the NR/MR boundaries, bias on and off.
        for (n, k, m) in [(1, 3, 5), (4, 16, 16), (7, 33, 17), (10, 96, 50)] {
            let w = rnd_t(1000 + n as u64, &[k, m]);
            let b = rnd_t(2000 + n as u64, &[m]);
            let x = rnd(3000 + n as u64, n * k);
            let p = PackedLinear::pack(&w, Some(&b));
            assert_eq!((p.k(), p.m()), (k, m));
            let mut got = vec![0.0f32; n * m];
            p.forward(&x, n, Act::None, &mut got);
            let want = oracle::matmul_bias(&x, &w, Some(&b), n);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-6, "{g} vs {w}");
            }
            let pn = PackedLinear::pack(&w, None);
            let mut got2 = vec![0.0f32; n * m];
            pn.forward(&x, n, Act::None, &mut got2);
            let want2 = oracle::matmul_bias(&x, &w, None, n);
            for (g, w) in got2.iter().zip(&want2) {
                assert!((g - w).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn fused_activation_epilogues_match_separate_pass() {
        let (n, k, m) = (5, 24, 31);
        let w = rnd_t(7, &[k, m]);
        let b = rnd_t(8, &[m]);
        let x = rnd(9, n * k);
        let p = PackedLinear::pack(&w, Some(&b));
        let plain = oracle::matmul_bias(&x, &w, Some(&b), n);
        for act in [Act::Gelu, Act::Silu] {
            let mut got = vec![0.0f32; n * m];
            p.forward(&x, n, act, &mut got);
            for (g, &v) in got.iter().zip(&plain) {
                let want = apply_act(act, v);
                assert!((g - want).abs() < 1e-6, "{act:?}: {g} vs {want}");
            }
        }
    }

    #[test]
    fn gated_residual_epilogue_accumulates_in_place() {
        let (n, k, m) = (6, 16, 20);
        let w = rnd_t(11, &[k, m]);
        let b = rnd_t(12, &[m]);
        let x = rnd(13, n * k);
        let gate = rnd(14, m);
        let base = rnd(15, n * m);
        let p = PackedLinear::pack(&w, Some(&b));
        let mut got = base.clone();
        p.forward_add_gated(&x, n, &gate, &mut got);
        let prod = oracle::matmul_bias(&x, &w, Some(&b), n);
        for r in 0..n {
            for j in 0..m {
                let want = base[r * m + j] + gate[j] * prod[r * m + j];
                let g = got[r * m + j];
                assert!((g - want).abs() < 1e-5, "{g} vs {want}");
            }
        }
    }

    #[test]
    fn sparse_entry_matches_dense_on_zeroed_rows() {
        let (n, k, m) = (8, 32, 24);
        let w = rnd_t(21, &[k, m]);
        let b = rnd_t(22, &[m]);
        let mut x = rnd(23, n * k);
        // STR-style: zero out half the rows.
        for r in [1usize, 3, 4, 7] {
            x[r * k..(r + 1) * k].fill(0.0);
        }
        let p = PackedLinear::pack(&w, Some(&b));
        let mut dense = vec![0.0f32; n * m];
        p.forward(&x, n, Act::Gelu, &mut dense);
        let mut sparse = vec![0.0f32; n * m];
        p.forward_sparse(&x, n, Act::Gelu, &mut sparse);
        assert_eq!(dense, sparse, "sparse-row entry must be bit-identical to dense");
    }

    #[test]
    fn layernorm_mod_matches_ln_then_modulate() {
        let (n, d) = (9, 40);
        let x = rnd(31, n * d);
        let shift = rnd(32, d);
        let scale = rnd(33, d);
        let mut fused = vec![0.0f32; n * d];
        layernorm_mod(&x, n, d, &shift, &scale, &mut fused);
        let mut seq = x.clone();
        oracle::layer_norm(&mut seq, d);
        for row in seq.chunks_mut(d) {
            for (j, v) in row.iter_mut().enumerate() {
                *v = *v * (1.0 + scale[j]) + shift[j];
            }
        }
        assert_eq!(fused, seq, "fused LN+adaLN must match the two-pass oracle bit-for-bit");
    }

    #[test]
    fn streaming_attention_matches_two_pass_oracle() {
        for (n, heads, d) in [(1, 2, 8), (7, 2, 16), (64, 3, 96)] {
            let q = rnd(41, n * d);
            let k = rnd(42, n * d);
            let v = rnd(43, n * d);
            // Interleave into the fused qkv layout the kernel reads.
            let mut qkv = vec![0.0f32; n * 3 * d];
            for r in 0..n {
                qkv[r * 3 * d..r * 3 * d + d].copy_from_slice(&q[r * d..(r + 1) * d]);
                qkv[r * 3 * d + d..r * 3 * d + 2 * d].copy_from_slice(&k[r * d..(r + 1) * d]);
                qkv[r * 3 * d + 2 * d..r * 3 * d + 3 * d].copy_from_slice(&v[r * d..(r + 1) * d]);
            }
            let mut got = rnd(44, n * d); // stale garbage must be wiped
            attention_streaming(&qkv, n, heads, d, &mut got);
            let want = oracle::attention(&q, &k, &v, n, heads, d);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "n={n} heads={heads}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn streaming_attention_uniform_for_identical_keys() {
        let (n, heads, d) = (4, 2, 8);
        let q = rnd(51, n * d);
        let v = rnd(52, n * d);
        let mut qkv = vec![0.0f32; n * 3 * d];
        for r in 0..n {
            qkv[r * 3 * d..r * 3 * d + d].copy_from_slice(&q[r * d..(r + 1) * d]);
            qkv[r * 3 * d + d..r * 3 * d + 2 * d].fill(0.5); // identical keys
            qkv[r * 3 * d + 2 * d..r * 3 * d + 3 * d].copy_from_slice(&v[r * d..(r + 1) * d]);
        }
        let mut out = vec![0.0f32; n * d];
        attention_streaming(&qkv, n, heads, d, &mut out);
        for j in 0..d {
            let want: f32 = (0..n).map(|r| v[r * d + j]).sum::<f32>() / n as f32;
            for i in 0..n {
                assert!((out[i * d + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn matmul_bias_into_matches_oracle() {
        let (n, k, m) = (5, 12, 9);
        let w = rnd_t(61, &[k, m]);
        let b = rnd_t(62, &[m]);
        let mut x = rnd(63, n * k);
        x[0] = 0.0; // the oracle's zero-skip must not change the result
        x[k + 3] = 0.0;
        let mut got = vec![0.0f32; n * m];
        matmul_bias_into(&x, &w, Some(&b), n, &mut got);
        let want = oracle::matmul_bias(&x, &w, Some(&b), n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-7);
        }
    }

    #[test]
    fn arena_high_water_grows_then_stabilizes() {
        let mut a = ScratchArena::new();
        assert_eq!(a.high_water_bytes(), 0);
        let _ = block_views(&mut a, 16, 8, 48, 16 * 32);
        let hw = a.high_water_bytes();
        assert!(hw >= (8 + 48 + 16 * 8 + 16 * 24 + 16 * 8 + 16 * 32) * 4);
        // Smaller and equal requests never grow the arena.
        let _ = block_views(&mut a, 4, 8, 48, 4 * 32);
        let _ = block_views(&mut a, 16, 8, 48, 16 * 32);
        assert_eq!(a.high_water_bytes(), hw);
        // A larger request grows it (and it sticks).
        let _ = block_views(&mut a, 32, 8, 48, 32 * 32);
        assert!(a.high_water_bytes() > hw);
    }

    #[test]
    fn arena_threads_default_serial_and_never_zero() {
        let mut a = ScratchArena::new();
        assert_eq!(a.threads(), 1);
        a.set_threads(0);
        assert_eq!(a.threads(), 1);
        a.set_threads(4);
        assert_eq!(a.threads(), 4);
        // The threads knob must not perturb the memory accounting.
        assert_eq!(a.high_water_bytes(), 0);
    }

    #[test]
    fn row_partition_covers_exactly_once() {
        // span × workers >= n, at most `workers` chunks, unit-aligned
        // boundaries — for every awkward (n, threads) combination.
        for n in [1usize, 3, 4, 5, 7, 8, 63, 64, 65, 256] {
            for threads in [1usize, 2, 3, 4, 8] {
                let workers = plan_threads(threads, n, MR);
                assert!(workers >= 1 && workers <= threads.max(1));
                let span = row_span(n, workers, MR);
                assert_eq!(span % MR, 0);
                assert!(span * workers >= n, "n={n} threads={threads}");
                let chunks = n.div_ceil(span);
                assert!(chunks <= workers, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn lanes_inner_loop_is_bit_identical_to_scalar() {
        // The explicit f32x8 path must be indistinguishable from the
        // scalar accumulator at the bit level: same per-element
        // k-ascending summation, no fused mul-add.
        for (n, k, m) in [(1, 3, 5), (7, 33, 17), (10, 96, 50)] {
            let w = rnd_t(70 + n as u64, &[k, m]);
            let b = rnd_t(71 + n as u64, &[m]);
            let x = rnd(72 + n as u64, n * k);
            let p = PackedLinear::pack(&w, Some(&b));
            let mut scalar = vec![0.0f32; n * m];
            p.forward_kernel(&x, n, Act::Gelu, &mut scalar, false);
            let mut lanes = vec![0.0f32; n * m];
            p.forward_kernel(&x, n, Act::Gelu, &mut lanes, true);
            assert_eq!(scalar, lanes, "n={n} k={k} m={m}");
        }
    }

    #[test]
    fn threaded_forward_bit_identical_to_serial() {
        let (k, m) = (48, 40);
        let w = rnd_t(81, &[k, m]);
        let b = rnd_t(82, &[m]);
        let p = PackedLinear::pack(&w, Some(&b));
        let gate = rnd(83, m);
        for n in [1usize, 7, 64] {
            let x = rnd(84 + n as u64, n * k);
            let mut serial = vec![0.0f32; n * m];
            p.forward(&x, n, Act::Silu, &mut serial);
            let base = rnd(85, n * m);
            let mut serial_gated = base.clone();
            p.forward_add_gated(&x, n, &gate, &mut serial_gated);
            for threads in [2usize, 4] {
                let mut got = vec![0.0f32; n * m];
                p.forward_t(&x, n, Act::Silu, &mut got, threads);
                assert_eq!(serial, got, "forward_t n={n} threads={threads}");
                let mut got_gated = base.clone();
                p.forward_add_gated_t(&x, n, &gate, &mut got_gated, threads);
                assert_eq!(serial_gated, got_gated, "gated n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn int8_quantized_forward_within_tolerance_and_billed() {
        let (n, k, m) = (9, 96, 64);
        let w = rnd_t(91, &[k, m]);
        let b = rnd_t(92, &[m]);
        let x = rnd(93, n * k);
        let p = PackedLinear::pack(&w, Some(&b));
        let q = Int8PackedLinear::quantize(&p);
        assert_eq!((q.k(), q.m()), (k, m));
        // i8 panels + f32 scales + f32 bias, strictly smaller than the
        // f32 packed copy.
        assert!(q.size_bytes() < p.size_bytes());
        let mut f32_out = vec![0.0f32; n * m];
        p.forward(&x, n, Act::None, &mut f32_out);
        let mut q_out = vec![0.0f32; n * m];
        q.forward(&x, n, Act::None, &mut q_out);
        let num: f64 = f32_out.iter().zip(&q_out).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let den: f64 = f32_out.iter().map(|a| (*a as f64).powi(2)).sum();
        let rel = (num / den.max(1e-30)).sqrt();
        assert!(rel > 0.0, "int8 path must actually quantize");
        assert!(rel < 0.05, "int8 matmul drifted too far from f32: rel={rel}");
        // Gated epilogue stays consistent with the Store epilogue.
        let base = rnd(94, n * m);
        let gate = rnd(95, m);
        let mut got = base.clone();
        q.forward_add_gated(&x, n, &gate, &mut got);
        for r in 0..n {
            for j in 0..m {
                let want = base[r * m + j] + gate[j] * q_out[r * m + j];
                assert!((got[r * m + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn int8_zero_and_constant_tiles_survive_quantization() {
        // An all-zero weight column tile must quantize to exact zeros
        // (scale guard), and a zero input row must produce exactly the
        // bias through the int8 path too.
        let (k, m) = (16, NR);
        let w = Tensor::new(vec![0.0f32; k * m], &[k, m]);
        let b = rnd_t(96, &[m]);
        let p = PackedLinear::pack(&w, Some(&b));
        let q = Int8PackedLinear::quantize(&p);
        let x = rnd(97, 2 * k);
        let mut out = vec![1.0f32; 2 * m];
        q.forward(&x, 2, Act::None, &mut out);
        for (r, orow) in out.chunks(m).enumerate() {
            for (o, bb) in orow.iter().zip(b.data()) {
                assert_eq!(o, bb, "row {r}: zero weights must yield exactly the bias");
            }
        }
    }
}
