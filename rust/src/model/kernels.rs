//! Zero-allocation, cache-blocked native kernels for the DiT forward
//! path: packed linear layers, fused layer-norm + adaLN modulation,
//! bias + activation / gated-residual matmul epilogues, and a
//! streaming-softmax attention that reads q/k/v strided directly out of
//! the fused qkv buffer.
//!
//! ## model.py parity contract
//!
//! Semantics MUST match python/compile/model.py exactly: same layer-norm
//! epsilon (1e-6), tanh-approximate GELU (jax.nn.gelu's default), SiLU,
//! and the q|k|v split convention (`jnp.split` on the last axis). The
//! packed matmuls accumulate in the SAME k-ascending order as the
//! retained scalar oracle (`testutil::oracle`), so they are bit-exact
//! against it; only the attention softmax changes float-summation order
//! (online max/denominator instead of a two-pass softmax), which is why
//! block-level parity — and the HLO cross-check in
//! rust/tests/runtime_roundtrip.rs — is a TOLERANCE contract, not a
//! bitwise one. rust/tests/kernel_parity.rs pins both down per kernel.
//!
//! ## Layout
//!
//! A [`PackedLinear`] repacks a row-major `[K, M]` weight at
//! `WeightBank` generate/load time into column tiles of width [`NR`]:
//! tile `t` is a contiguous `[K, NR]` panel (k-major, zero-padded past
//! `M`). The microkernel walks [`MR`] rows of `x` against one panel with
//! an `MR×NR` register accumulator, so the inner loop is a unit-stride,
//! branch-free FMA chain the autovectorizer can lift to SIMD — the
//! data-dependent `x == 0.0` skip of the old scalar path is gone (a
//! separate [`PackedLinear::forward_sparse`] entry point keeps the
//! zero-row short-circuit for STR-style sparsified inputs). Panels fit
//! L2 and are reused across row blocks; the accumulator tile stays in
//! registers — that is the cache blocking.
//!
//! ## Scratch
//!
//! Every intermediate a block forward needs (qkv, normalized input,
//! attention out, MLP hidden, modulation, silu(c)) lives in a
//! [`ScratchArena`] owned by the caller (`LaneStepper`, one per engine /
//! shard worker). Buffers only ever grow, so after the first step the
//! steady-state path performs zero heap allocations per block call; the
//! arena's high-water mark is reported through `ServerReport` and
//! asserted stable in tests.

use crate::tensor::Tensor;

/// Column-tile width of the packed layout (one microkernel accumulator
/// row; 16 f32 = two AVX2 / one AVX-512 vector per unrolled step).
pub const NR: usize = 16;
/// Row-block height of the microkernel (x rows advanced together, so one
/// streamed panel is reused MR times from registers/L1).
pub const MR: usize = 4;

/// SiLU (x · σ(x)), matching jax.nn.silu.
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// tanh-approximate GELU (jax.nn.gelu default).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Activation fused into the matmul writeback (applied after bias).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Act {
    None,
    Gelu,
    Silu,
}

#[inline]
fn apply_act(act: Act, v: f32) -> f32 {
    match act {
        Act::None => v,
        Act::Gelu => gelu(v),
        Act::Silu => silu(v),
    }
}

/// How the microkernel's accumulator tile leaves the registers.
#[derive(Clone, Copy)]
enum WriteBack<'a> {
    /// `out = act(acc)` (acc is bias-initialized).
    Store(Act),
    /// `out += gate[j] · acc` — the fused residual epilogue of the
    /// attention-proj and MLP-down matmuls (adaLN-zero gating).
    AddGated(&'a [f32]),
}

/// A linear layer repacked for the blocked microkernel: `[K, M]` weights
/// as `ceil(M/NR)` contiguous `[K, NR]` panels plus the bias (zeros when
/// the layer has none). Built once at weight-bank generate/load time;
/// `forward` never touches the original row-major tensor.
#[derive(Clone, Debug)]
pub struct PackedLinear {
    k: usize,
    m: usize,
    data: Vec<f32>,
    bias: Vec<f32>,
}

impl PackedLinear {
    /// Repack a row-major `[K, M]` weight (and optional `[M]` bias).
    pub fn pack(w: &Tensor, b: Option<&Tensor>) -> PackedLinear {
        assert_eq!(w.shape().len(), 2, "PackedLinear wants a [K, M] matrix");
        let (k, m) = (w.shape()[0], w.shape()[1]);
        let tiles = m.div_ceil(NR);
        let mut data = vec![0.0f32; tiles * k * NR];
        let wd = w.data();
        for t in 0..tiles {
            let jb = t * NR;
            let jw = NR.min(m - jb);
            let panel = &mut data[t * k * NR..(t + 1) * k * NR];
            for kk in 0..k {
                panel[kk * NR..kk * NR + jw].copy_from_slice(&wd[kk * m + jb..kk * m + jb + jw]);
            }
        }
        let bias = match b {
            Some(t) => {
                assert_eq!(t.len(), m, "bias length mismatch");
                t.data().to_vec()
            }
            None => vec![0.0; m],
        };
        PackedLinear { k, m, data, bias }
    }

    /// Zero-sized placeholder (a released packed copy).
    fn placeholder() -> PackedLinear {
        PackedLinear { k: 0, m: 0, data: Vec::new(), bias: Vec::new() }
    }

    /// Input features.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output features.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Heap bytes of the packed panels + bias.
    pub fn size_bytes(&self) -> usize {
        (self.data.len() + self.bias.len()) * std::mem::size_of::<f32>()
    }

    /// `out = act(x @ W + b)`, x: `[n, K]`, out: `[n, M]` (overwritten).
    pub fn forward(&self, x: &[f32], n: usize, act: Act, out: &mut [f32]) {
        self.run(x, n, WriteBack::Store(act), out);
    }

    /// `out[r, j] += gate[j] · (x @ W + b)[r, j]` — residual accumulation
    /// written in place, no intermediate buffer.
    pub fn forward_add_gated(&self, x: &[f32], n: usize, gate: &[f32], out: &mut [f32]) {
        assert_eq!(gate.len(), self.m, "gate length mismatch");
        self.run(x, n, WriteBack::AddGated(gate), out);
    }

    /// Sparse-row entry point for STR-zeroed inputs: rows of `x` that are
    /// entirely zero short-circuit to `act(bias)` without touching the
    /// panels. Bit-identical to [`PackedLinear::forward`] on the same
    /// input (a zero row contributes exactly `+0·w` per lane), so callers
    /// may switch on sparsity freely. The serving STR path currently
    /// GATHERS motion rows instead of zero-padding, so no production
    /// call site exists yet — this is the contract-preserving
    /// replacement for the dense kernel's removed `x == 0.0` skip,
    /// pinned against dense-with-zeros in rust/tests/kernel_parity.rs
    /// for any zero-padding caller.
    pub fn forward_sparse(&self, x: &[f32], n: usize, act: Act, out: &mut [f32]) {
        assert_eq!(x.len(), n * self.k);
        assert_eq!(out.len(), n * self.m);
        for (xr, orow) in x.chunks(self.k).zip(out.chunks_mut(self.m)) {
            if xr.iter().all(|&v| v == 0.0) {
                for (o, &b) in orow.iter_mut().zip(&self.bias) {
                    *o = apply_act(act, b);
                }
            } else {
                self.run(xr, 1, WriteBack::Store(act), orow);
            }
        }
    }

    fn run(&self, x: &[f32], n: usize, wb: WriteBack<'_>, out: &mut [f32]) {
        let (k, m) = (self.k, self.m);
        assert_eq!(x.len(), n * k, "x length mismatch");
        assert_eq!(out.len(), n * m, "out length mismatch");
        let tiles = m.div_ceil(NR);
        let mut r = 0;
        while r < n {
            let mr = MR.min(n - r);
            for t in 0..tiles {
                let jb = t * NR;
                let jw = NR.min(m - jb);
                let panel = &self.data[t * k * NR..(t + 1) * k * NR];
                // Bias-initialized accumulator tile: the sum order
                // (bias, then k ascending) matches the scalar oracle
                // bit-for-bit. Padded columns stay zero and are never
                // written back.
                let mut acc = [[0.0f32; NR]; MR];
                for a in acc.iter_mut().take(mr) {
                    a[..jw].copy_from_slice(&self.bias[jb..jb + jw]);
                }
                for (kk, prow) in panel.chunks_exact(NR).enumerate() {
                    for (i, a) in acc.iter_mut().enumerate().take(mr) {
                        let xv = x[(r + i) * k + kk];
                        for (av, &wv) in a.iter_mut().zip(prow) {
                            *av += xv * wv;
                        }
                    }
                }
                match wb {
                    WriteBack::Store(act) => {
                        for (i, a) in acc.iter().enumerate().take(mr) {
                            let orow = &mut out[(r + i) * m + jb..(r + i) * m + jb + jw];
                            match act {
                                Act::None => orow.copy_from_slice(&a[..jw]),
                                _ => {
                                    for (o, &v) in orow.iter_mut().zip(a) {
                                        *o = apply_act(act, v);
                                    }
                                }
                            }
                        }
                    }
                    WriteBack::AddGated(gate) => {
                        for (i, a) in acc.iter().enumerate().take(mr) {
                            let orow = &mut out[(r + i) * m + jb..(r + i) * m + jb + jw];
                            let grow = &gate[jb..jb + jw];
                            for ((o, &v), &g) in orow.iter_mut().zip(a).zip(grow) {
                                *o += g * v;
                            }
                        }
                    }
                }
            }
            r += mr;
        }
    }
}

/// Unpacked branch-free matmul for RUNTIME weights (fit matrices that
/// change per call, so repacking would cost as much as the product):
/// `out = x @ W + b`, x `[n, K]` row-major, W `[K, M]`, out overwritten.
/// Same accumulation order as the packed path and the scalar oracle.
pub fn matmul_bias_into(x: &[f32], w: &Tensor, b: Option<&Tensor>, n: usize, out: &mut [f32]) {
    let (k, m) = (w.shape()[0], w.shape()[1]);
    assert_eq!(x.len(), n * k);
    assert_eq!(out.len(), n * m);
    match b {
        Some(b) => {
            assert_eq!(b.len(), m);
            for orow in out.chunks_mut(m) {
                orow.copy_from_slice(b.data());
            }
        }
        None => out.fill(0.0),
    }
    let wd = w.data();
    for (xr, orow) in x.chunks(k).zip(out.chunks_mut(m)) {
        for (&xv, wrow) in xr.iter().zip(wd.chunks(m)) {
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

/// Fused parameter-free LayerNorm + adaLN scale/shift, one pass:
/// `out[r, j] = norm(x)[r, j] · (1 + scale[j]) + shift[j]`
/// (eps = 1e-6, identical arithmetic to the oracle's LN-then-modulate).
pub fn layernorm_mod(x: &[f32], n: usize, d: usize, shift: &[f32], scale: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), n * d);
    assert_eq!(out.len(), n * d);
    assert_eq!(shift.len(), d);
    assert_eq!(scale.len(), d);
    let eps = 1e-6f32;
    for (row, orow) in x.chunks(d).zip(out.chunks_mut(d)) {
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for (((o, &v), &sc), &sh) in orow.iter_mut().zip(row).zip(scale).zip(shift) {
            *o = (v - mean) * inv * (1.0 + sc) + sh;
        }
    }
}

/// Query-block size of the streaming attention (k/v rows are streamed
/// once per block instead of once per query).
const MQ: usize = 4;

/// Multi-head attention with an online (streaming) softmax, reading
/// q/k/v strided DIRECTLY out of the fused qkv projection buffer — rows
/// of `[3D]` laid out `q | k | v` (the `jnp.split` convention) — so no
/// q/k/v copies exist and per-row logits never materialize. Processing
/// is per head (working set `[n, dh]`) with `MQ`-query blocking; the
/// output head-slice doubles as the online accumulator, so the kernel
/// needs no scratch at all. out: `[n, d]`, overwritten.
pub fn attention_streaming(qkv: &[f32], n: usize, heads: usize, d: usize, out: &mut [f32]) {
    let dh = d / heads;
    assert_eq!(heads * dh, d, "d must split evenly into heads");
    let stride = 3 * d;
    assert_eq!(qkv.len(), n * stride);
    assert_eq!(out.len(), n * d);
    let scale = 1.0 / (dh as f32).sqrt();
    for h in 0..heads {
        let qo = h * dh;
        let ko = d + h * dh;
        let vo = 2 * d + h * dh;
        let mut i0 = 0;
        while i0 < n {
            let bq = MQ.min(n - i0);
            let mut mx = [f32::NEG_INFINITY; MQ];
            let mut den = [0.0f32; MQ];
            // The out slices are the accumulators: zero them explicitly
            // (the buffer may be a reused arena allocation).
            for i in i0..i0 + bq {
                out[i * d + qo..i * d + qo + dh].fill(0.0);
            }
            for j in 0..n {
                let kj = &qkv[j * stride + ko..j * stride + ko + dh];
                let vj = &qkv[j * stride + vo..j * stride + vo + dh];
                for i in 0..bq {
                    let qrow = &qkv[(i0 + i) * stride + qo..(i0 + i) * stride + qo + dh];
                    let mut dot = 0.0f32;
                    for (&qv, &kv) in qrow.iter().zip(kj) {
                        dot += qv * kv;
                    }
                    let logit = dot * scale;
                    let oi = &mut out[(i0 + i) * d + qo..(i0 + i) * d + qo + dh];
                    if logit > mx[i] {
                        // Rescale the running sum to the new max
                        // (exp(-inf) = 0 cleanly initializes the first
                        // touch, wiping any stale accumulator content).
                        let f = (mx[i] - logit).exp();
                        den[i] *= f;
                        for o in oi.iter_mut() {
                            *o *= f;
                        }
                        mx[i] = logit;
                    }
                    let p = (logit - mx[i]).exp();
                    den[i] += p;
                    for (o, &vv) in oi.iter_mut().zip(vj) {
                        *o += p * vv;
                    }
                }
            }
            for i in 0..bq {
                let inv = 1.0 / den[i];
                for o in out[(i0 + i) * d + qo..(i0 + i) * d + qo + dh].iter_mut() {
                    *o *= inv;
                }
            }
            i0 += bq;
        }
    }
}

/// Reused scratch buffers for the fused forward kernels. Owned by the
/// step driver (`LaneStepper`; one per engine / shard worker) and
/// threaded through every native forward, replacing all per-call `Vec`
/// allocations. Buffers only grow, so the steady-state path allocates
/// nothing; [`ScratchArena::high_water_bytes`] is the reporting hook.
#[derive(Default)]
pub struct ScratchArena {
    csilu: Vec<f32>,
    modv: Vec<f32>,
    xnorm: Vec<f32>,
    qkv: Vec<f32>,
    attn: Vec<f32>,
    hidden: Vec<f32>,
}

impl ScratchArena {
    pub fn new() -> ScratchArena {
        ScratchArena::default()
    }

    /// Total bytes currently reserved across all scratch buffers — the
    /// arena's high-water mark (capacities never shrink).
    pub fn high_water_bytes(&self) -> usize {
        (self.csilu.capacity()
            + self.modv.capacity()
            + self.xnorm.capacity()
            + self.qkv.capacity()
            + self.attn.capacity()
            + self.hidden.capacity())
            * std::mem::size_of::<f32>()
    }
}

/// Grow-only scratch view: resizes the buffer when (and only when) the
/// requested length exceeds what was ever needed before.
pub(crate) fn grab(v: &mut Vec<f32>, len: usize) -> &mut [f32] {
    if v.len() < len {
        v.resize(len, 0.0);
    }
    &mut v[..len]
}

/// The six scratch views of one block forward:
/// (silu(c), modulation, normalized input, qkv, attention out, hidden).
pub(crate) type BlockScratch<'a> =
    (&'a mut [f32], &'a mut [f32], &'a mut [f32], &'a mut [f32], &'a mut [f32], &'a mut [f32]);

/// Split the arena into the six named views a block forward needs.
/// Free function (not a method) so the borrows stay disjoint.
pub(crate) fn block_views(
    a: &mut ScratchArena,
    n: usize,
    d: usize,
    mod_len: usize,
    hidden_len: usize,
) -> BlockScratch<'_> {
    (
        grab(&mut a.csilu, d),
        grab(&mut a.modv, mod_len),
        grab(&mut a.xnorm, n * d),
        grab(&mut a.qkv, n * 3 * d),
        grab(&mut a.attn, n * d),
        grab(&mut a.hidden, hidden_len),
    )
}

/// The three views the final layer needs (silu(c), modulation,
/// normalized input) — it must not size the qkv/attn/hidden buffers a
/// block needs, or a final-only caller pays 4·n·d floats it never reads.
pub(crate) fn final_views(
    a: &mut ScratchArena,
    n: usize,
    d: usize,
) -> (&mut [f32], &mut [f32], &mut [f32]) {
    (grab(&mut a.csilu, d), grab(&mut a.modv, 2 * d), grab(&mut a.xnorm, n * d))
}

/// One DiT block's weights in packed form, calling-convention order
/// preserved conceptually (qkv, proj, mlp up/down, adaLN modulation —
/// biases folded into each [`PackedLinear`]).
#[derive(Clone, Debug)]
pub struct PackedBlock {
    pub wqkv: PackedLinear,
    pub wo: PackedLinear,
    pub w1: PackedLinear,
    pub w2: PackedLinear,
    pub wmod: PackedLinear,
}

#[derive(Clone, Debug)]
pub struct PackedTemb {
    pub w1: PackedLinear,
    pub w2: PackedLinear,
}

#[derive(Clone, Debug)]
pub struct PackedFinal {
    pub wmod: PackedLinear,
    pub wout: PackedLinear,
}

/// The whole bank, packed. Rebuilt by `WeightBank::repack` whenever the
/// row-major tensors are mutated in place (e.g. simulated quantization).
#[derive(Clone, Debug)]
pub struct PackedBank {
    pub blocks: Vec<PackedBlock>,
    pub temb: PackedTemb,
    pub final_: PackedFinal,
    pub embed: PackedLinear,
}

impl PackedBank {
    /// A released (zero-byte) bank. HLO-mode models drop their packed
    /// copy right after the device upload — every native kernel path is
    /// gated on `ExecMode::Native`, so nothing ever reads it — instead
    /// of holding a second full weight copy for the process lifetime.
    pub fn released() -> PackedBank {
        PackedBank {
            blocks: Vec::new(),
            temb: PackedTemb { w1: PackedLinear::placeholder(), w2: PackedLinear::placeholder() },
            final_: PackedFinal {
                wmod: PackedLinear::placeholder(),
                wout: PackedLinear::placeholder(),
            },
            embed: PackedLinear::placeholder(),
        }
    }

    /// Heap bytes held by the packed copies (reported separately from the
    /// row-major bank the HLO path uploads).
    pub fn size_bytes(&self) -> usize {
        let block: usize = self
            .blocks
            .iter()
            .map(|b| {
                b.wqkv.size_bytes()
                    + b.wo.size_bytes()
                    + b.w1.size_bytes()
                    + b.w2.size_bytes()
                    + b.wmod.size_bytes()
            })
            .sum();
        block
            + self.temb.w1.size_bytes()
            + self.temb.w2.size_bytes()
            + self.final_.wmod.size_bytes()
            + self.final_.wout.size_bytes()
            + self.embed.size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testutil::oracle;

    fn rnd(seed: u64, len: usize) -> Vec<f32> {
        Rng::new(seed).normal_vec(len, 1.0)
    }

    fn rnd_t(seed: u64, shape: &[usize]) -> Tensor {
        Tensor::new(rnd(seed, shape.iter().product()), shape)
    }

    #[test]
    fn packed_forward_matches_scalar_oracle() {
        // Ragged shapes around the NR/MR boundaries, bias on and off.
        for (n, k, m) in [(1, 3, 5), (4, 16, 16), (7, 33, 17), (10, 96, 50)] {
            let w = rnd_t(1000 + n as u64, &[k, m]);
            let b = rnd_t(2000 + n as u64, &[m]);
            let x = rnd(3000 + n as u64, n * k);
            let p = PackedLinear::pack(&w, Some(&b));
            assert_eq!((p.k(), p.m()), (k, m));
            let mut got = vec![0.0f32; n * m];
            p.forward(&x, n, Act::None, &mut got);
            let want = oracle::matmul_bias(&x, &w, Some(&b), n);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-6, "{g} vs {w}");
            }
            let pn = PackedLinear::pack(&w, None);
            let mut got2 = vec![0.0f32; n * m];
            pn.forward(&x, n, Act::None, &mut got2);
            let want2 = oracle::matmul_bias(&x, &w, None, n);
            for (g, w) in got2.iter().zip(&want2) {
                assert!((g - w).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn fused_activation_epilogues_match_separate_pass() {
        let (n, k, m) = (5, 24, 31);
        let w = rnd_t(7, &[k, m]);
        let b = rnd_t(8, &[m]);
        let x = rnd(9, n * k);
        let p = PackedLinear::pack(&w, Some(&b));
        let plain = oracle::matmul_bias(&x, &w, Some(&b), n);
        for act in [Act::Gelu, Act::Silu] {
            let mut got = vec![0.0f32; n * m];
            p.forward(&x, n, act, &mut got);
            for (g, &v) in got.iter().zip(&plain) {
                let want = apply_act(act, v);
                assert!((g - want).abs() < 1e-6, "{act:?}: {g} vs {want}");
            }
        }
    }

    #[test]
    fn gated_residual_epilogue_accumulates_in_place() {
        let (n, k, m) = (6, 16, 20);
        let w = rnd_t(11, &[k, m]);
        let b = rnd_t(12, &[m]);
        let x = rnd(13, n * k);
        let gate = rnd(14, m);
        let base = rnd(15, n * m);
        let p = PackedLinear::pack(&w, Some(&b));
        let mut got = base.clone();
        p.forward_add_gated(&x, n, &gate, &mut got);
        let prod = oracle::matmul_bias(&x, &w, Some(&b), n);
        for r in 0..n {
            for j in 0..m {
                let want = base[r * m + j] + gate[j] * prod[r * m + j];
                let g = got[r * m + j];
                assert!((g - want).abs() < 1e-5, "{g} vs {want}");
            }
        }
    }

    #[test]
    fn sparse_entry_matches_dense_on_zeroed_rows() {
        let (n, k, m) = (8, 32, 24);
        let w = rnd_t(21, &[k, m]);
        let b = rnd_t(22, &[m]);
        let mut x = rnd(23, n * k);
        // STR-style: zero out half the rows.
        for r in [1usize, 3, 4, 7] {
            x[r * k..(r + 1) * k].fill(0.0);
        }
        let p = PackedLinear::pack(&w, Some(&b));
        let mut dense = vec![0.0f32; n * m];
        p.forward(&x, n, Act::Gelu, &mut dense);
        let mut sparse = vec![0.0f32; n * m];
        p.forward_sparse(&x, n, Act::Gelu, &mut sparse);
        assert_eq!(dense, sparse, "sparse-row entry must be bit-identical to dense");
    }

    #[test]
    fn layernorm_mod_matches_ln_then_modulate() {
        let (n, d) = (9, 40);
        let x = rnd(31, n * d);
        let shift = rnd(32, d);
        let scale = rnd(33, d);
        let mut fused = vec![0.0f32; n * d];
        layernorm_mod(&x, n, d, &shift, &scale, &mut fused);
        let mut seq = x.clone();
        oracle::layer_norm(&mut seq, d);
        for row in seq.chunks_mut(d) {
            for (j, v) in row.iter_mut().enumerate() {
                *v = *v * (1.0 + scale[j]) + shift[j];
            }
        }
        assert_eq!(fused, seq, "fused LN+adaLN must match the two-pass oracle bit-for-bit");
    }

    #[test]
    fn streaming_attention_matches_two_pass_oracle() {
        for (n, heads, d) in [(1, 2, 8), (7, 2, 16), (64, 3, 96)] {
            let q = rnd(41, n * d);
            let k = rnd(42, n * d);
            let v = rnd(43, n * d);
            // Interleave into the fused qkv layout the kernel reads.
            let mut qkv = vec![0.0f32; n * 3 * d];
            for r in 0..n {
                qkv[r * 3 * d..r * 3 * d + d].copy_from_slice(&q[r * d..(r + 1) * d]);
                qkv[r * 3 * d + d..r * 3 * d + 2 * d].copy_from_slice(&k[r * d..(r + 1) * d]);
                qkv[r * 3 * d + 2 * d..r * 3 * d + 3 * d].copy_from_slice(&v[r * d..(r + 1) * d]);
            }
            let mut got = rnd(44, n * d); // stale garbage must be wiped
            attention_streaming(&qkv, n, heads, d, &mut got);
            let want = oracle::attention(&q, &k, &v, n, heads, d);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "n={n} heads={heads}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn streaming_attention_uniform_for_identical_keys() {
        let (n, heads, d) = (4, 2, 8);
        let q = rnd(51, n * d);
        let v = rnd(52, n * d);
        let mut qkv = vec![0.0f32; n * 3 * d];
        for r in 0..n {
            qkv[r * 3 * d..r * 3 * d + d].copy_from_slice(&q[r * d..(r + 1) * d]);
            qkv[r * 3 * d + d..r * 3 * d + 2 * d].fill(0.5); // identical keys
            qkv[r * 3 * d + 2 * d..r * 3 * d + 3 * d].copy_from_slice(&v[r * d..(r + 1) * d]);
        }
        let mut out = vec![0.0f32; n * d];
        attention_streaming(&qkv, n, heads, d, &mut out);
        for j in 0..d {
            let want: f32 = (0..n).map(|r| v[r * d + j]).sum::<f32>() / n as f32;
            for i in 0..n {
                assert!((out[i * d + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn matmul_bias_into_matches_oracle() {
        let (n, k, m) = (5, 12, 9);
        let w = rnd_t(61, &[k, m]);
        let b = rnd_t(62, &[m]);
        let mut x = rnd(63, n * k);
        x[0] = 0.0; // the oracle's zero-skip must not change the result
        x[k + 3] = 0.0;
        let mut got = vec![0.0f32; n * m];
        matmul_bias_into(&x, &w, Some(&b), n, &mut got);
        let want = oracle::matmul_bias(&x, &w, Some(&b), n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-7);
        }
    }

    #[test]
    fn arena_high_water_grows_then_stabilizes() {
        let mut a = ScratchArena::new();
        assert_eq!(a.high_water_bytes(), 0);
        let _ = block_views(&mut a, 16, 8, 48, 16 * 32);
        let hw = a.high_water_bytes();
        assert!(hw >= (8 + 48 + 16 * 8 + 16 * 24 + 16 * 8 + 16 * 32) * 4);
        // Smaller and equal requests never grow the arena.
        let _ = block_views(&mut a, 4, 8, 48, 4 * 32);
        let _ = block_views(&mut a, 16, 8, 48, 16 * 32);
        assert_eq!(a.high_water_bytes(), hw);
        // A larger request grows it (and it sticks).
        let _ = block_views(&mut a, 32, 8, 48, 32 * 32);
        assert!(a.high_water_bytes() > hw);
    }
}
