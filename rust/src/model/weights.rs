//! Seeded weight-bank generation for a DiT variant.
//!
//! Layout mirrors python/compile/model.py's BLOCK_PARAM_NAMES calling
//! convention exactly — the order in which weight buffers are passed to the
//! block executable. Serving weights are generated Rust-side (the AOT
//! artifacts are weight-agnostic: weights are runtime parameters), seeded
//! for reproducibility.
//!
//! Init scheme is DiT-faithful where it matters for *dynamics*: matrices
//! ~ N(0, 1/fan_in), biases zero, and adaLN modulation weights SMALL but
//! non-zero (a pretrained DiT has small, structured modulations; exactly
//! zero would make every block the identity and caching trivially perfect).

use crate::config::{ModelConfig, C_IN, MLP_RATIO};
use crate::rng::Rng;
use crate::tensor::Tensor;

use super::kernels::{PackedBank, PackedBlock, PackedFinal, PackedLinear, PackedTemb};

/// Per-block weights, in calling-convention order.
#[derive(Clone, Debug)]
pub struct BlockWeights {
    pub wqkv: Tensor, // [D, 3D]
    pub bqkv: Tensor, // [3D]
    pub wo: Tensor,   // [D, D]
    pub bo: Tensor,   // [D]
    pub w1: Tensor,   // [D, 4D]
    pub b1: Tensor,   // [4D]
    pub w2: Tensor,   // [4D, D]
    pub b2: Tensor,   // [D]
    pub wmod: Tensor, // [D, 6D]
    pub bmod: Tensor, // [6D]
}

impl BlockWeights {
    /// Calling-convention-ordered views (matches BLOCK_PARAM_NAMES).
    pub fn ordered(&self) -> [&Tensor; 10] {
        [
            &self.wqkv, &self.bqkv, &self.wo, &self.bo, &self.w1, &self.b1, &self.w2,
            &self.b2, &self.wmod, &self.bmod,
        ]
    }

    /// Repack into the tiled microkernel layout (`model::kernels`).
    /// Always f32-only; `WeightBank::quantize_int8` (sticky across
    /// `repack`) adds the int8 quad afterwards.
    pub fn pack(&self) -> PackedBlock {
        PackedBlock {
            wqkv: PackedLinear::pack(&self.wqkv, Some(&self.bqkv)),
            wo: PackedLinear::pack(&self.wo, Some(&self.bo)),
            w1: PackedLinear::pack(&self.w1, Some(&self.b1)),
            w2: PackedLinear::pack(&self.w2, Some(&self.b2)),
            wmod: PackedLinear::pack(&self.wmod, Some(&self.bmod)),
            int8: None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TembWeights {
    pub w1: Tensor, // [D, D]
    pub b1: Tensor, // [D]
    pub w2: Tensor, // [D, D]
    pub b2: Tensor, // [D]
}

impl TembWeights {
    pub fn ordered(&self) -> [&Tensor; 4] {
        [&self.w1, &self.b1, &self.w2, &self.b2]
    }

    pub fn pack(&self) -> PackedTemb {
        PackedTemb {
            w1: PackedLinear::pack(&self.w1, Some(&self.b1)),
            w2: PackedLinear::pack(&self.w2, Some(&self.b2)),
        }
    }
}

#[derive(Clone, Debug)]
pub struct FinalWeights {
    pub wmod: Tensor, // [D, 2D]
    pub bmod: Tensor, // [2D]
    pub wout: Tensor, // [D, C]
    pub bout: Tensor, // [C]
}

impl FinalWeights {
    pub fn ordered(&self) -> [&Tensor; 4] {
        [&self.wmod, &self.bmod, &self.wout, &self.bout]
    }

    pub fn pack(&self) -> PackedFinal {
        PackedFinal {
            wmod: PackedLinear::pack(&self.wmod, Some(&self.bmod)),
            wout: PackedLinear::pack(&self.wout, Some(&self.bout)),
        }
    }
}

#[derive(Clone, Debug)]
pub struct EmbedWeights {
    pub w: Tensor, // [C, D]
    pub b: Tensor, // [D]
}

impl EmbedWeights {
    pub fn pack(&self) -> PackedLinear {
        PackedLinear::pack(&self.w, Some(&self.b))
    }
}

/// Full weight bank for one model variant. The row-major tensors are the
/// calling-convention / HLO-upload copy; `packed` is the tiled layout
/// every native kernel reads (built at generate time; call
/// [`WeightBank::repack`] after mutating the tensors in place).
#[derive(Clone, Debug)]
pub struct WeightBank {
    pub cfg: ModelConfig,
    pub embed: EmbedWeights,
    pub temb: TembWeights,
    pub blocks: Vec<BlockWeights>,
    pub final_: FinalWeights,
    pub packed: PackedBank,
    /// Whether [`WeightBank::quantize_int8`] has been applied. Sticky:
    /// `repack()` re-quantizes from the freshly packed panels, so
    /// in-place weight mutation can never silently serve stale int8
    /// copies.
    int8: bool,
}

fn dense(rng: &mut Rng, rows: usize, cols: usize, scale: Option<f32>) -> Tensor {
    let s = scale.unwrap_or(1.0 / (rows as f32).sqrt());
    Tensor::new(rng.normal_vec(rows * cols, s), &[rows, cols])
}

impl WeightBank {
    pub fn generate(cfg: ModelConfig, seed: u64) -> WeightBank {
        let d = cfg.d;
        let mut root = Rng::new(seed ^ (cfg.variant.key().len() as u64) << 32);

        let mut er = root.fork(0xE);
        let embed = EmbedWeights {
            w: dense(&mut er, C_IN, d, None),
            b: Tensor::zeros(&[d]),
        };

        let mut tr = root.fork(0x7);
        let temb = TembWeights {
            w1: dense(&mut tr, d, d, None),
            b1: Tensor::zeros(&[d]),
            w2: dense(&mut tr, d, d, None),
            b2: Tensor::zeros(&[d]),
        };

        let mut blocks = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            let mut br = root.fork(0x100 + l as u64);
            // Small modulation: pretrained-DiT-like gentle conditioning.
            // Depth-dependent scale: later layers modulate slightly less,
            // which produces the paper's "later blocks are more cacheable"
            // structure (Fig. 1 / Fig. 2 narrative).
            let depth_frac = l as f32 / cfg.layers.max(1) as f32;
            let mod_scale = 0.02 * (1.0 - 0.5 * depth_frac) / (d as f32).sqrt();
            blocks.push(BlockWeights {
                wqkv: dense(&mut br, d, 3 * d, None),
                bqkv: Tensor::zeros(&[3 * d]),
                wo: dense(&mut br, d, d, Some(0.5 / (d as f32).sqrt())),
                bo: Tensor::zeros(&[d]),
                w1: dense(&mut br, d, MLP_RATIO * d, None),
                b1: Tensor::zeros(&[MLP_RATIO * d]),
                w2: dense(&mut br, MLP_RATIO * d, d, Some(0.5 / ((MLP_RATIO * d) as f32).sqrt())),
                b2: Tensor::zeros(&[d]),
                wmod: dense(&mut br, d, 6 * d, Some(mod_scale)),
                bmod: Tensor::zeros(&[6 * d]),
            });
        }

        let mut fr = root.fork(0xF);
        let final_ = FinalWeights {
            wmod: dense(&mut fr, d, 2 * d, Some(0.02 / (d as f32).sqrt())),
            bmod: Tensor::zeros(&[2 * d]),
            wout: dense(&mut fr, d, C_IN, None),
            bout: Tensor::zeros(&[C_IN]),
        };

        let packed = PackedBank {
            blocks: blocks.iter().map(BlockWeights::pack).collect(),
            temb: temb.pack(),
            final_: final_.pack(),
            embed: embed.pack(),
        };
        WeightBank { cfg, embed, temb, blocks, final_, packed, int8: false }
    }

    /// Rebuild the packed layout from the row-major tensors — required
    /// after any in-place weight mutation (e.g. the simulated-bf16
    /// quantization bench), or the native path silently serves stale
    /// weights. Re-applies int8 quantization when it was enabled.
    pub fn repack(&mut self) {
        self.packed = PackedBank {
            blocks: self.blocks.iter().map(BlockWeights::pack).collect(),
            temb: self.temb.pack(),
            final_: self.final_.pack(),
            embed: self.embed.pack(),
        };
        if self.int8 {
            for b in self.packed.blocks.iter_mut() {
                b.quantize_int8();
            }
        }
    }

    /// Build int8 copies of every block's four big matmuls from the
    /// current packed f32 panels (per-NR-tile symmetric scales, i32
    /// accumulation at serve time). Opt-in and sticky: `repack()` keeps
    /// the quantization in sync with the f32 panels. The f32 path is
    /// byte-for-byte untouched — the quads live alongside it (and are
    /// billed via `packed.size_bytes()` / `DitModel::weight_bytes`).
    pub fn quantize_int8(&mut self) {
        self.int8 = true;
        for b in self.packed.blocks.iter_mut() {
            b.quantize_int8();
        }
    }

    /// Whether int8 serving copies are enabled on this bank.
    pub fn int8_enabled(&self) -> bool {
        self.int8
    }

    /// Release the packed copy. HLO-mode models call this right after
    /// the device upload: their forwards dispatch compiled programs and
    /// never touch `packed`, so holding a second full weight copy for
    /// the process lifetime would be pure waste.
    pub fn release_packed(&mut self) {
        self.packed = PackedBank::released();
    }

    /// Bytes of the row-major (calling-convention / HLO-upload) tensors
    /// only — the packed kernel copy is accounted separately via
    /// `packed.size_bytes()` (see `DitModel::weight_bytes`).
    pub fn size_bytes(&self) -> usize {
        let block: usize = self
            .blocks
            .iter()
            .map(|b| b.ordered().iter().map(|t| t.size_bytes()).sum::<usize>())
            .sum();
        block
            + self.temb.ordered().iter().map(|t| t.size_bytes()).sum::<usize>()
            + self.final_.ordered().iter().map(|t| t.size_bytes()).sum::<usize>()
            + self.embed.w.size_bytes()
            + self.embed.b.size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Variant;

    #[test]
    fn generation_is_deterministic() {
        let cfg = ModelConfig::of(Variant::S);
        let a = WeightBank::generate(cfg, 42);
        let b = WeightBank::generate(cfg, 42);
        assert_eq!(a.blocks[0].wqkv.data(), b.blocks[0].wqkv.data());
        let c = WeightBank::generate(cfg, 43);
        assert_ne!(a.blocks[0].wqkv.data(), c.blocks[0].wqkv.data());
    }

    #[test]
    fn per_layer_weights_differ() {
        let cfg = ModelConfig::of(Variant::B);
        let w = WeightBank::generate(cfg, 1);
        assert_ne!(w.blocks[0].wqkv.data(), w.blocks[1].wqkv.data());
    }

    #[test]
    fn shapes_match_convention() {
        let cfg = ModelConfig::of(Variant::L);
        let w = WeightBank::generate(cfg, 7);
        let d = cfg.d;
        assert_eq!(w.blocks.len(), cfg.layers);
        let b0 = &w.blocks[0];
        assert_eq!(b0.wqkv.shape(), &[d, 3 * d]);
        assert_eq!(b0.w1.shape(), &[d, MLP_RATIO * d]);
        assert_eq!(b0.wmod.shape(), &[d, 6 * d]);
        assert_eq!(w.final_.wout.shape(), &[d, C_IN]);
        assert_eq!(w.embed.w.shape(), &[C_IN, d]);
    }

    #[test]
    fn packed_bank_follows_mutation_only_after_repack() {
        use crate::model::kernels::Act;
        let cfg = ModelConfig::of(Variant::S);
        let mut bank = WeightBank::generate(cfg, 3);
        let x = vec![0.5f32; C_IN];
        let run = |bank: &WeightBank| {
            let mut out = vec![0.0f32; cfg.d];
            bank.packed.embed.forward(&x, 1, Act::None, &mut out);
            out
        };
        let before = run(&bank);
        for v in bank.embed.w.data_mut().iter_mut() {
            *v *= 2.0;
        }
        assert_eq!(run(&bank), before, "packed layout is a snapshot until repack");
        bank.repack();
        assert_ne!(run(&bank), before, "repack must pick up the mutated tensors");
        assert!(bank.packed.size_bytes() > 0);
    }

    #[test]
    fn int8_is_sticky_across_repack_and_billed() {
        let cfg = ModelConfig::of(Variant::S);
        let mut bank = WeightBank::generate(cfg, 3);
        assert!(!bank.int8_enabled());
        assert!(bank.packed.blocks.iter().all(|b| b.int8.is_none()), "int8 must be opt-in");
        let f32_bytes = bank.packed.size_bytes();
        bank.quantize_int8();
        assert!(bank.int8_enabled());
        assert!(bank.packed.blocks.iter().all(|b| b.int8.is_some()));
        let q_bytes = bank.packed.size_bytes();
        assert!(q_bytes > f32_bytes, "int8 copies must be billed");
        // repack() must rebuild the quads from the fresh panels, not
        // drop them.
        for v in bank.blocks[0].wqkv.data_mut().iter_mut() {
            *v *= 2.0;
        }
        bank.repack();
        assert!(bank.packed.blocks.iter().all(|b| b.int8.is_some()), "int8 sticky across repack");
        assert_eq!(bank.packed.size_bytes(), q_bytes);
    }

    #[test]
    fn size_bytes_close_to_param_count() {
        let cfg = ModelConfig::of(Variant::S);
        let w = WeightBank::generate(cfg, 3);
        let got = w.size_bytes() / 4;
        let want = cfg.param_count();
        // param_count is an estimate of the same layout; allow 1% slack.
        let rel = (got as f64 - want as f64).abs() / want as f64;
        assert!(rel < 0.01, "got {got} want {want}");
    }
}
