//! `DitModel`: a loaded, servable DiT variant — compiled AOT programs plus
//! resident device weights, with a native-math fallback used by tests and
//! artifact-free environments.
//!
//! The model intentionally does NOT own the denoising loop: the scheduler
//! (`crate::scheduler::engine`) drives per-layer execution so the cache
//! policy can intervene between blocks (Algorithm 1 of the paper).

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{ModelConfig, Variant, C_IN, N_TOKENS};
use crate::runtime::{run, ArtifactStore, Arg, Client, DeviceTensor, ProgramKey};
use crate::tensor::Tensor;

use super::native;
use super::weights::WeightBank;

/// How forward ops execute.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ExecMode {
    /// AOT HLO through PJRT (the production path).
    Hlo,
    /// Pure-Rust math (test / no-artifacts path; numerically equivalent,
    /// see rust/tests/runtime_roundtrip.rs).
    Native,
}

struct DeviceBlock {
    params: Vec<DeviceTensor>, // 10, calling-convention order
}

struct DeviceWeights {
    blocks: Vec<DeviceBlock>,
    temb: Vec<DeviceTensor>,   // 4
    final_: Vec<DeviceTensor>, // 4
    embed: Vec<DeviceTensor>,  // 2
}

pub struct DitModel {
    pub cfg: ModelConfig,
    pub mode: ExecMode,
    pub bank: WeightBank,
    client: Option<Arc<Client>>,
    store: Option<Arc<ArtifactStore>>,
    dev: Option<DeviceWeights>,
}

impl DitModel {
    /// Load for HLO execution: uploads all weights to the device once.
    pub fn load(
        client: Arc<Client>,
        store: Arc<ArtifactStore>,
        variant: Variant,
        seed: u64,
    ) -> Result<DitModel> {
        let cfg = ModelConfig::of(variant);
        if !store.has(&ProgramKey::block(variant, N_TOKENS, 1)) {
            bail!("artifacts for variant {variant} missing — run `make artifacts`");
        }
        let bank = WeightBank::generate(cfg, seed);
        let upload_all = |ts: &[&Tensor]| -> Result<Vec<DeviceTensor>> {
            ts.iter().map(|t| client.upload(t)).collect()
        };
        let blocks = bank
            .blocks
            .iter()
            .map(|b| Ok(DeviceBlock { params: upload_all(&b.ordered())? }))
            .collect::<Result<Vec<_>>>()?;
        let dev = DeviceWeights {
            blocks,
            temb: upload_all(&bank.temb.ordered())?,
            final_: upload_all(&bank.final_.ordered())?,
            embed: upload_all(&[&bank.embed.w, &bank.embed.b])?,
        };
        Ok(DitModel {
            cfg,
            mode: ExecMode::Hlo,
            bank,
            client: Some(client),
            store: Some(store),
            dev: Some(dev),
        })
    }

    /// Native-only model (no PJRT), for tests and development.
    pub fn native(variant: Variant, seed: u64) -> DitModel {
        let cfg = ModelConfig::of(variant);
        DitModel {
            cfg,
            mode: ExecMode::Native,
            bank: WeightBank::generate(cfg, seed),
            client: None,
            store: None,
            dev: None,
        }
    }

    fn exec(&self, key: &ProgramKey, args: &[Arg<'_>]) -> Result<Tensor> {
        let client = self.client.as_ref().ok_or_else(|| anyhow!("native model has no client"))?;
        let store = self.store.as_ref().unwrap();
        let exe = store.executable(client, key)?;
        run(client, &exe, args, &key.out_shape(&self.cfg))
            .with_context(|| format!("executing {}", key.file_stem()))
    }

    /// Timestep conditioning: t (len B) -> [B, D].
    pub fn temb(&self, t: &[f32]) -> Result<Tensor> {
        let b = t.len();
        match self.mode {
            ExecMode::Native => {
                let d = self.cfg.d;
                let mut out = Vec::with_capacity(b * d);
                for &tv in t {
                    out.extend(native::temb_forward(tv, &self.bank.temb));
                }
                Ok(Tensor::new(out, &[b, d]))
            }
            ExecMode::Hlo => {
                let key = ProgramKey::temb(self.cfg.variant, b);
                let tt = Tensor::new(t.to_vec(), &[b]);
                let dev = self.dev.as_ref().unwrap();
                let mut args = vec![Arg::Host(&tt)];
                args.extend(dev.temb.iter().map(Arg::Device));
                self.exec(&key, &args)
            }
        }
    }

    /// Latent embedding: x [B, N, C] -> [B, N, D].
    pub fn embed(&self, x: &Tensor) -> Result<Tensor> {
        let (b, n) = (x.shape()[0], x.shape()[1]);
        match self.mode {
            ExecMode::Native => {
                let d = self.cfg.d;
                let mut out = Vec::with_capacity(b * n * d);
                for bi in 0..b {
                    let sl = Tensor::new(
                        x.data()[bi * n * C_IN..(bi + 1) * n * C_IN].to_vec(),
                        &[n, C_IN],
                    );
                    out.extend(native::embed_forward(&sl, &self.bank.embed).into_data());
                }
                Ok(Tensor::new(out, &[b, n, d]))
            }
            ExecMode::Hlo => {
                let key = ProgramKey::embed(self.cfg.variant, n, b);
                let dev = self.dev.as_ref().unwrap();
                let args = vec![
                    Arg::Host(x),
                    Arg::Device(&dev.embed[0]),
                    Arg::Device(&dev.embed[1]),
                ];
                self.exec(&key, &args)
            }
        }
    }

    /// One transformer block. h: [B, N, D], c: [B, D] -> [B, N, D].
    /// (B, N) must match a compiled artifact shape in HLO mode.
    pub fn block(&self, layer: usize, h: &Tensor, c: &Tensor) -> Result<Tensor> {
        let (b, n, d) = (h.shape()[0], h.shape()[1], h.shape()[2]);
        assert_eq!(d, self.cfg.d);
        assert!(layer < self.cfg.layers, "layer {layer} out of range");
        match self.mode {
            ExecMode::Native => {
                let w = &self.bank.blocks[layer];
                let mut out = Vec::with_capacity(b * n * d);
                for bi in 0..b {
                    let hs = Tensor::new(h.data()[bi * n * d..(bi + 1) * n * d].to_vec(), &[n, d]);
                    let cs = &c.data()[bi * d..(bi + 1) * d];
                    out.extend(native::block_forward(&hs, cs, &self.cfg, w).into_data());
                }
                Ok(Tensor::new(out, &[b, n, d]))
            }
            ExecMode::Hlo => {
                let key = ProgramKey::block(self.cfg.variant, n, b);
                let dev = self.dev.as_ref().unwrap();
                let mut args = vec![Arg::Host(h), Arg::Host(c)];
                args.extend(dev.blocks[layer].params.iter().map(Arg::Device));
                self.exec(&key, &args)
            }
        }
    }

    /// Final projection. h: [B, N, D], c: [B, D] -> [B, N, C].
    pub fn final_layer(&self, h: &Tensor, c: &Tensor) -> Result<Tensor> {
        let (b, n, d) = (h.shape()[0], h.shape()[1], h.shape()[2]);
        match self.mode {
            ExecMode::Native => {
                let mut out = Vec::with_capacity(b * n * C_IN);
                for bi in 0..b {
                    let hs = Tensor::new(h.data()[bi * n * d..(bi + 1) * n * d].to_vec(), &[n, d]);
                    let cs = &c.data()[bi * d..(bi + 1) * d];
                    out.extend(native::final_forward(&hs, cs, &self.bank.final_).into_data());
                }
                Ok(Tensor::new(out, &[b, n, C_IN]))
            }
            ExecMode::Hlo => {
                let key = ProgramKey::final_(self.cfg.variant, n, b);
                let dev = self.dev.as_ref().unwrap();
                let mut args = vec![Arg::Host(h), Arg::Host(c)];
                args.extend(dev.final_.iter().map(Arg::Device));
                self.exec(&key, &args)
            }
        }
    }

    /// Full-matrix linear approximation through the AOT Pallas artifact.
    /// h: [1, N, D], w: [D, D], b: [D] -> [1, N, D]. HLO mode only falls
    /// back to native matmul when no client is present.
    pub fn linear_approx_full(&self, h: &Tensor, w: &Tensor, bvec: &Tensor) -> Result<Tensor> {
        let (b, n, d) = (h.shape()[0], h.shape()[1], h.shape()[2]);
        match self.mode {
            ExecMode::Native => {
                let mut out = Vec::with_capacity(b * n * d);
                for bi in 0..b {
                    let hs = &h.data()[bi * n * d..(bi + 1) * n * d];
                    out.extend(native::matmul_bias(hs, w, Some(bvec), n));
                }
                Ok(Tensor::new(out, &[b, n, d]))
            }
            ExecMode::Hlo => {
                let key = ProgramKey::linear_approx(self.cfg.variant, n);
                let args = vec![Arg::Host(h), Arg::Host(w), Arg::Host(bvec)];
                self.exec(&key, &args)
            }
        }
    }

    /// Weight memory footprint in bytes (host copy; device mirrors it).
    pub fn weight_bytes(&self) -> usize {
        self.bank.size_bytes()
    }

    pub fn meter(&self) -> Option<&crate::runtime::MemoryMeter> {
        self.client.as_deref().map(|c| &*c.meter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rnd(seed: u64, shape: &[usize]) -> Tensor {
        let mut r = Rng::new(seed);
        Tensor::new(r.normal_vec(shape.iter().product(), 1.0), shape)
    }

    #[test]
    fn native_model_shapes() {
        let m = DitModel::native(Variant::S, 1);
        let c = m.temb(&[3.0]).unwrap();
        assert_eq!(c.shape(), &[1, 96]);
        let x = rnd(2, &[1, 64, C_IN]);
        let h = m.embed(&x).unwrap();
        assert_eq!(h.shape(), &[1, 64, 96]);
        let h2 = m.block(0, &h, &c).unwrap();
        assert_eq!(h2.shape(), &[1, 64, 96]);
        let eps = m.final_layer(&h2, &c).unwrap();
        assert_eq!(eps.shape(), &[1, 64, C_IN]);
    }

    #[test]
    fn native_batched_matches_single() {
        let m = DitModel::native(Variant::S, 5);
        let c = m.temb(&[3.0, 9.0]).unwrap();
        let x = rnd(7, &[2, 64, C_IN]);
        let h = m.embed(&x).unwrap();
        let out = m.block(1, &h, &c).unwrap();
        // Per-example slices must equal single-example runs.
        for bi in 0..2 {
            let hx = Tensor::new(h.data()[bi * 64 * 96..(bi + 1) * 64 * 96].to_vec(), &[1, 64, 96]);
            let cx = Tensor::new(c.data()[bi * 96..(bi + 1) * 96].to_vec(), &[1, 96]);
            let single = m.block(1, &hx, &cx).unwrap();
            let got = &out.data()[bi * 64 * 96..(bi + 1) * 64 * 96];
            for (a, b) in got.iter().zip(single.data()) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let m1 = DitModel::native(Variant::S, 11);
        let m2 = DitModel::native(Variant::S, 11);
        let x = rnd(3, &[1, 64, C_IN]);
        let c1 = m1.temb(&[5.0]).unwrap();
        let c2 = m2.temb(&[5.0]).unwrap();
        assert_eq!(c1.data(), c2.data());
        let h1 = m1.embed(&x).unwrap();
        let h2 = m2.embed(&x).unwrap();
        assert_eq!(h1.data(), h2.data());
    }

    #[test]
    fn linear_approx_native_identity() {
        let m = DitModel::native(Variant::S, 13);
        let h = rnd(4, &[1, 64, 96]);
        let w = Tensor::eye(96);
        let b = Tensor::zeros(&[96]);
        let out = m.linear_approx_full(&h, &w, &b).unwrap();
        assert!(h.max_abs_diff(&out) < 1e-6);
    }
}
