//! `DitModel`: a loaded, servable DiT variant — compiled AOT programs plus
//! resident device weights, with a native-math fallback used by tests and
//! artifact-free environments.
//!
//! The model intentionally does NOT own the denoising loop: the scheduler
//! (`crate::scheduler::engine`) drives per-layer execution so the cache
//! policy can intervene between blocks (Algorithm 1 of the paper).

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{ModelConfig, Variant, C_IN, N_TOKENS};
use crate::runtime::{run, ArtifactStore, Arg, Client, DeviceTensor, ProgramKey};
use crate::tensor::Tensor;

use super::kernels::ScratchArena;
use super::native;
use super::weights::WeightBank;

/// How forward ops execute.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ExecMode {
    /// AOT HLO through PJRT (the production path).
    Hlo,
    /// Pure-Rust math (test / no-artifacts path; numerically equivalent,
    /// see rust/tests/runtime_roundtrip.rs).
    Native,
}

struct DeviceBlock {
    params: Vec<DeviceTensor>, // 10, calling-convention order
}

struct DeviceWeights {
    blocks: Vec<DeviceBlock>,
    temb: Vec<DeviceTensor>,   // 4
    final_: Vec<DeviceTensor>, // 4
    embed: Vec<DeviceTensor>,  // 2
}

pub struct DitModel {
    pub cfg: ModelConfig,
    pub mode: ExecMode,
    pub bank: WeightBank,
    client: Option<Arc<Client>>,
    store: Option<Arc<ArtifactStore>>,
    dev: Option<DeviceWeights>,
}

impl DitModel {
    /// Load for HLO execution: uploads all weights to the device once.
    pub fn load(
        client: Arc<Client>,
        store: Arc<ArtifactStore>,
        variant: Variant,
        seed: u64,
    ) -> Result<DitModel> {
        let cfg = ModelConfig::of(variant);
        if !store.has(&ProgramKey::block(variant, N_TOKENS, 1)) {
            bail!("artifacts for variant {variant} missing — run `make artifacts`");
        }
        let mut bank = WeightBank::generate(cfg, seed);
        let upload_all = |ts: &[&Tensor]| -> Result<Vec<DeviceTensor>> {
            ts.iter().map(|t| client.upload(t)).collect()
        };
        let blocks = bank
            .blocks
            .iter()
            .map(|b| Ok(DeviceBlock { params: upload_all(&b.ordered())? }))
            .collect::<Result<Vec<_>>>()?;
        let dev = DeviceWeights {
            blocks,
            temb: upload_all(&bank.temb.ordered())?,
            final_: upload_all(&bank.final_.ordered())?,
            embed: upload_all(&[&bank.embed.w, &bank.embed.b])?,
        };
        // Device weights are resident and the HLO path never runs the
        // native kernels — don't hold a second full host copy.
        bank.release_packed();
        Ok(DitModel {
            cfg,
            mode: ExecMode::Hlo,
            bank,
            client: Some(client),
            store: Some(store),
            dev: Some(dev),
        })
    }

    /// Native-only model (no PJRT), for tests and development.
    pub fn native(variant: Variant, seed: u64) -> DitModel {
        let cfg = ModelConfig::of(variant);
        DitModel {
            cfg,
            mode: ExecMode::Native,
            bank: WeightBank::generate(cfg, seed),
            client: None,
            store: None,
            dev: None,
        }
    }

    fn exec(&self, key: &ProgramKey, args: &[Arg<'_>]) -> Result<Tensor> {
        let client = self.client.as_ref().ok_or_else(|| anyhow!("native model has no client"))?;
        let store = self.store.as_ref().unwrap();
        let exe = store.executable(client, key)?;
        run(client, &exe, args, &key.out_shape(&self.cfg))
            .with_context(|| format!("executing {}", key.file_stem()))
    }

    /// Whether forwards run the native kernel path (vs PJRT dispatch).
    pub fn is_native(&self) -> bool {
        self.mode == ExecMode::Native
    }

    /// Rebuild the packed native-kernel weights from the (possibly
    /// mutated) row-major bank. Native mode only affects `bank.packed`;
    /// HLO device weights are uploaded once at load and NOT re-uploaded
    /// here.
    pub fn repack(&mut self) {
        self.bank.repack();
    }

    /// Enable int8 serving for the four big matmuls of every block
    /// (native mode; per-NR-tile symmetric scales, i32 accumulation).
    /// Sticky across [`DitModel::repack`]. The f32 panels stay resident
    /// as the reference path, so [`DitModel::weight_bytes`] grows by the
    /// int8 copy — quantization here buys bandwidth, not capacity.
    pub fn quantize_int8(&mut self) {
        self.bank.quantize_int8();
    }

    /// Timestep conditioning: t (len B) -> [B, D].
    pub fn temb(&self, t: &[f32]) -> Result<Tensor> {
        let b = t.len();
        match self.mode {
            ExecMode::Native => {
                let d = self.cfg.d;
                let mut out = Vec::with_capacity(b * d);
                for &tv in t {
                    out.extend(native::temb_forward(tv, &self.bank.packed.temb));
                }
                Ok(Tensor::new(out, &[b, d]))
            }
            ExecMode::Hlo => {
                let key = ProgramKey::temb(self.cfg.variant, b);
                let tt = Tensor::new(t.to_vec(), &[b]);
                let dev = self.dev.as_ref().unwrap();
                let mut args = vec![Arg::Host(&tt)];
                args.extend(dev.temb.iter().map(Arg::Device));
                self.exec(&key, &args)
            }
        }
    }

    /// Latent embedding: x [B, N, C] -> [B, N, D].
    pub fn embed(&self, x: &Tensor) -> Result<Tensor> {
        let (b, n) = (x.shape()[0], x.shape()[1]);
        match self.mode {
            ExecMode::Native => {
                let d = self.cfg.d;
                // Row-wise linear: all B·N rows go through one call.
                let mut out = vec![0.0f32; b * n * d];
                native::embed_forward_slice(x.data(), b * n, &self.bank.packed.embed, &mut out);
                Ok(Tensor::new(out, &[b, n, d]))
            }
            ExecMode::Hlo => {
                let key = ProgramKey::embed(self.cfg.variant, n, b);
                let dev = self.dev.as_ref().unwrap();
                let args = vec![
                    Arg::Host(x),
                    Arg::Device(&dev.embed[0]),
                    Arg::Device(&dev.embed[1]),
                ];
                self.exec(&key, &args)
            }
        }
    }

    /// One transformer block. h: [B, N, D], c: [B, D] -> [B, N, D].
    /// (B, N) must match a compiled artifact shape in HLO mode. Native
    /// mode builds a transient scratch arena; hot callers should hold
    /// their own and use [`DitModel::block_with`] /
    /// [`DitModel::block_native_into`].
    pub fn block(&self, layer: usize, h: &Tensor, c: &Tensor) -> Result<Tensor> {
        match self.mode {
            ExecMode::Native => {
                let mut arena = ScratchArena::new();
                self.block_with(layer, h, c, &mut arena)
            }
            ExecMode::Hlo => {
                let (b, n, d) = (h.shape()[0], h.shape()[1], h.shape()[2]);
                assert_eq!(d, self.cfg.d);
                assert!(layer < self.cfg.layers, "layer {layer} out of range");
                let key = ProgramKey::block(self.cfg.variant, n, b);
                let dev = self.dev.as_ref().unwrap();
                let mut args = vec![Arg::Host(h), Arg::Host(c)];
                args.extend(dev.blocks[layer].params.iter().map(Arg::Device));
                self.exec(&key, &args)
            }
        }
    }

    /// [`DitModel::block`] with a caller-owned scratch arena (native
    /// mode reuses its buffers; HLO mode ignores it).
    pub fn block_with(
        &self,
        layer: usize,
        h: &Tensor,
        c: &Tensor,
        arena: &mut ScratchArena,
    ) -> Result<Tensor> {
        match self.mode {
            ExecMode::Native => {
                let (b, n, d) = (h.shape()[0], h.shape()[1], h.shape()[2]);
                assert_eq!(d, self.cfg.d);
                assert!(layer < self.cfg.layers, "layer {layer} out of range");
                let w = &self.bank.packed.blocks[layer];
                let mut out = vec![0.0f32; b * n * d];
                for bi in 0..b {
                    native::block_forward_slice(
                        &h.data()[bi * n * d..(bi + 1) * n * d],
                        n,
                        &c.data()[bi * d..(bi + 1) * d],
                        &self.cfg,
                        w,
                        arena,
                        &mut out[bi * n * d..(bi + 1) * n * d],
                    );
                }
                Ok(Tensor::new(out, &[b, n, d]))
            }
            ExecMode::Hlo => self.block(layer, h, c),
        }
    }

    /// Zero-allocation native block forward: one [N, D] example written
    /// into a caller-recycled output tensor. The steady-state serving
    /// path — errors in HLO mode (which has its own dispatch route).
    pub fn block_native_into(
        &self,
        layer: usize,
        h: &Tensor,
        c: &[f32],
        arena: &mut ScratchArena,
        out: &mut Tensor,
    ) -> Result<()> {
        anyhow::ensure!(self.is_native(), "block_native_into is native-mode only");
        let (n, d) = (h.shape()[0], h.shape()[1]);
        assert_eq!(d, self.cfg.d);
        assert!(layer < self.cfg.layers, "layer {layer} out of range");
        out.ensure_shape(&[n, d]);
        native::block_forward_slice(
            h.data(),
            n,
            c,
            &self.cfg,
            &self.bank.packed.blocks[layer],
            arena,
            out.data_mut(),
        );
        Ok(())
    }

    /// Final projection. h: [B, N, D], c: [B, D] -> [B, N, C].
    pub fn final_layer(&self, h: &Tensor, c: &Tensor) -> Result<Tensor> {
        match self.mode {
            ExecMode::Native => {
                let mut arena = ScratchArena::new();
                self.final_layer_with(h, c, &mut arena)
            }
            ExecMode::Hlo => {
                let (b, n) = (h.shape()[0], h.shape()[1]);
                let key = ProgramKey::final_(self.cfg.variant, n, b);
                let dev = self.dev.as_ref().unwrap();
                let mut args = vec![Arg::Host(h), Arg::Host(c)];
                args.extend(dev.final_.iter().map(Arg::Device));
                self.exec(&key, &args)
            }
        }
    }

    /// [`DitModel::final_layer`] with a caller-owned scratch arena.
    pub fn final_layer_with(
        &self,
        h: &Tensor,
        c: &Tensor,
        arena: &mut ScratchArena,
    ) -> Result<Tensor> {
        match self.mode {
            ExecMode::Native => {
                let (b, n, d) = (h.shape()[0], h.shape()[1], h.shape()[2]);
                assert_eq!(d, self.cfg.d);
                let mut out = vec![0.0f32; b * n * C_IN];
                for bi in 0..b {
                    native::final_forward_slice(
                        &h.data()[bi * n * d..(bi + 1) * n * d],
                        n,
                        &c.data()[bi * d..(bi + 1) * d],
                        &self.bank.packed.final_,
                        arena,
                        &mut out[bi * n * C_IN..(bi + 1) * n * C_IN],
                    );
                }
                Ok(Tensor::new(out, &[b, n, C_IN]))
            }
            ExecMode::Hlo => self.final_layer(h, c),
        }
    }

    /// Full-matrix linear approximation through the AOT Pallas artifact.
    /// h: [1, N, D], w: [D, D], b: [D] -> [1, N, D]. HLO mode only falls
    /// back to native matmul when no client is present.
    pub fn linear_approx_full(&self, h: &Tensor, w: &Tensor, bvec: &Tensor) -> Result<Tensor> {
        let (b, n, d) = (h.shape()[0], h.shape()[1], h.shape()[2]);
        match self.mode {
            ExecMode::Native => {
                let mut out = Vec::with_capacity(b * n * d);
                for bi in 0..b {
                    let hs = &h.data()[bi * n * d..(bi + 1) * n * d];
                    out.extend(native::matmul_bias(hs, w, Some(bvec), n));
                }
                Ok(Tensor::new(out, &[b, n, d]))
            }
            ExecMode::Hlo => {
                let key = ProgramKey::linear_approx(self.cfg.variant, n);
                let args = vec![Arg::Host(h), Arg::Host(w), Arg::Host(bvec)];
                self.exec(&key, &args)
            }
        }
    }

    /// Weight memory footprint in bytes: the row-major host copy plus
    /// the packed kernel copy when one is resident (native mode; HLO
    /// models release it at load, and the device mirrors the row-major
    /// bank). This is what the paper-facing memory columns report, so
    /// the packed duplication must not be invisible.
    pub fn weight_bytes(&self) -> usize {
        self.bank.size_bytes() + self.bank.packed.size_bytes()
    }

    pub fn meter(&self) -> Option<&crate::runtime::MemoryMeter> {
        self.client.as_deref().map(|c| &*c.meter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rnd(seed: u64, shape: &[usize]) -> Tensor {
        let mut r = Rng::new(seed);
        Tensor::new(r.normal_vec(shape.iter().product(), 1.0), shape)
    }

    #[test]
    fn native_model_shapes() {
        let m = DitModel::native(Variant::S, 1);
        let c = m.temb(&[3.0]).unwrap();
        assert_eq!(c.shape(), &[1, 96]);
        let x = rnd(2, &[1, 64, C_IN]);
        let h = m.embed(&x).unwrap();
        assert_eq!(h.shape(), &[1, 64, 96]);
        let h2 = m.block(0, &h, &c).unwrap();
        assert_eq!(h2.shape(), &[1, 64, 96]);
        let eps = m.final_layer(&h2, &c).unwrap();
        assert_eq!(eps.shape(), &[1, 64, C_IN]);
    }

    #[test]
    fn native_batched_matches_single() {
        let m = DitModel::native(Variant::S, 5);
        let c = m.temb(&[3.0, 9.0]).unwrap();
        let x = rnd(7, &[2, 64, C_IN]);
        let h = m.embed(&x).unwrap();
        let out = m.block(1, &h, &c).unwrap();
        // Per-example slices must equal single-example runs.
        for bi in 0..2 {
            let hx = Tensor::new(h.data()[bi * 64 * 96..(bi + 1) * 64 * 96].to_vec(), &[1, 64, 96]);
            let cx = Tensor::new(c.data()[bi * 96..(bi + 1) * 96].to_vec(), &[1, 96]);
            let single = m.block(1, &hx, &cx).unwrap();
            let got = &out.data()[bi * 64 * 96..(bi + 1) * 64 * 96];
            for (a, b) in got.iter().zip(single.data()) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let m1 = DitModel::native(Variant::S, 11);
        let m2 = DitModel::native(Variant::S, 11);
        let x = rnd(3, &[1, 64, C_IN]);
        let c1 = m1.temb(&[5.0]).unwrap();
        let c2 = m2.temb(&[5.0]).unwrap();
        assert_eq!(c1.data(), c2.data());
        let h1 = m1.embed(&x).unwrap();
        let h2 = m2.embed(&x).unwrap();
        assert_eq!(h1.data(), h2.data());
    }

    #[test]
    fn native_weight_bytes_bill_the_packed_copy() {
        // The packed kernel layout is a real second weight copy: the
        // memory the paper-facing tables report must include it in
        // native mode, and a released bank must report zero.
        let m = DitModel::native(Variant::S, 1);
        assert!(m.bank.packed.size_bytes() > 0);
        assert_eq!(m.weight_bytes(), m.bank.size_bytes() + m.bank.packed.size_bytes());
        let mut bank = crate::model::WeightBank::generate(m.cfg, 1);
        bank.release_packed();
        assert_eq!(bank.packed.size_bytes(), 0, "released bank must hold no packed bytes");
    }

    #[test]
    fn linear_approx_native_identity() {
        let m = DitModel::native(Variant::S, 13);
        let h = rnd(4, &[1, 64, 96]);
        let w = Tensor::eye(96);
        let b = Tensor::zeros(&[96]);
        let out = m.linear_approx_full(&h, &w, &b).unwrap();
        assert!(h.max_abs_diff(&out) < 1e-6);
    }
}
