//! Native Rust implementation of the DiT forward pieces, built on the
//! packed/fused/streaming kernels in [`super::kernels`].
//!
//! Semantics MUST match python/compile/model.py exactly (same layer-norm
//! epsilon, tanh-approximate GELU — jax.nn.gelu's default — and SiLU);
//! the integration test rust/tests/runtime_roundtrip.rs executes the AOT
//! HLO and this module on identical weights and asserts allclose, and
//! rust/tests/kernel_parity.rs checks every kernel against the retained
//! scalar oracle (`testutil::oracle` — the pre-kernel implementation).
//!
//! Used for (a) cross-validating the artifacts, (b) the cheap non-matmul
//! hot-path math (saliency, delta, affine application) where a PJRT
//! dispatch would cost more than the arithmetic, and (c) running the full
//! test suite without compiled artifacts present.
//!
//! All forwards here take a caller-owned [`ScratchArena`] and packed
//! weights, and write into caller buffers — zero heap allocations on the
//! steady-state path (the allocating `*_forward` wrappers exist for
//! tests and one-shot callers).

use crate::config::{ModelConfig, MLP_RATIO};
use crate::tensor::Tensor;

use super::kernels::{
    self, attention_streaming_t, block_views, final_views, layernorm_mod_t, Act, PackedBlock,
    PackedFinal, PackedTemb, ScratchArena,
};

pub use super::kernels::{gelu, silu};

/// Sinusoidal timestep embedding, matching model.timestep_embedding:
/// freqs = exp(-ln(10000) * arange(half)/half); [cos(t·f), sin(t·f)].
pub fn timestep_embedding(t: f32, d: usize) -> Vec<f32> {
    let half = d / 2;
    let mut e = vec![0.0f32; d];
    for i in 0..half {
        let freq = (-(10000.0f32).ln() * i as f32 / half as f32).exp();
        let arg = t * freq;
        e[i] = arg.cos();
        e[half + i] = arg.sin();
    }
    e
}

/// y = x @ w + b for RUNTIME weights (fit matrices built per call):
/// branch-free blocked loop, same accumulation order as the oracle.
pub fn matmul_bias(x: &[f32], w: &Tensor, b: Option<&Tensor>, n: usize) -> Vec<f32> {
    let m = w.shape()[1];
    let mut y = vec![0.0f32; n * m];
    kernels::matmul_bias_into(x, w, b, n, &mut y);
    y
}

/// Timestep -> conditioning embedding on packed weights. Returns [D].
/// (Pure function of (t, variant, weight seed) — the serving stepper
/// memoizes it in a `TembCache` so co-scheduled lanes share one eval.)
pub fn temb_forward(t: f32, w: &PackedTemb) -> Vec<f32> {
    let d = w.w1.k();
    let e = timestep_embedding(t, d);
    let mut h = vec![0.0f32; w.w1.m()];
    w.w1.forward(&e, 1, Act::Silu, &mut h); // bias + SiLU fused in the epilogue
    let mut out = vec![0.0f32; w.w2.m()];
    w.w2.forward(&h, 1, Act::None, &mut out);
    out
}

/// Latent -> hidden embedding into a caller slice. x: [n·C] -> [n·D].
pub fn embed_forward_slice(x: &[f32], n: usize, w: &kernels::PackedLinear, out: &mut [f32]) {
    w.forward(x, n, Act::None, out);
}

/// One adaLN-zero DiT block on packed weights, fully fused:
/// layer-norm + adaLN scale/shift in one pass, bias + GELU in the matmul
/// epilogue, gated residuals accumulated in place, and streaming-softmax
/// attention indexing strided into the qkv buffer. `out` is overwritten
/// with the block output; `h` is the (read-only) input — together they
/// are the single working copy the residual stream needs.
pub fn block_forward_slice(
    h: &[f32],
    n: usize,
    c: &[f32],
    cfg: &ModelConfig,
    w: &PackedBlock,
    arena: &mut ScratchArena,
    out: &mut [f32],
) {
    let d = cfg.d;
    assert_eq!(h.len(), n * d);
    assert_eq!(c.len(), d);
    assert_eq!(out.len(), n * d);
    let threads = arena.threads();
    let (csilu, modv, xnorm, qkv, attn, hidden) =
        block_views(arena, n, d, 6 * d, n * MLP_RATIO * d);

    // Modulation: silu(c) @ wmod + bmod -> 6 chunks of D. Single-row —
    // stays serial and f32 regardless of threads/int8 (adaLN gates scale
    // every residual contribution, so they are quality-critical).
    for (o, &v) in csilu.iter_mut().zip(c) {
        *o = silu(v);
    }
    w.wmod.forward(csilu, 1, Act::None, modv);
    let (sh1, rest) = modv.split_at(d);
    let (sc1, rest) = rest.split_at(d);
    let (g1, rest) = rest.split_at(d);
    let (sh2, rest) = rest.split_at(d);
    let (sc2, g2) = rest.split_at(d);

    // Residual base: the one full-tensor copy of the block.
    out.copy_from_slice(h);

    // Attention branch: fused LN+adaLN -> qkv -> streaming attention ->
    // proj with the g1-gated residual folded into the matmul writeback.
    // The four big matmuls switch to the int8 quad when the block
    // carries one (serial — the int8 path is opt-in and not yet
    // threaded); everything else splits the token dimension across the
    // arena's intra-op workers, bit-identical to serial.
    layernorm_mod_t(h, n, d, sh1, sc1, xnorm, threads);
    match &w.int8 {
        Some(q) => q.wqkv.forward(xnorm, n, Act::None, qkv),
        None => w.wqkv.forward_t(xnorm, n, Act::None, qkv, threads),
    }
    attention_streaming_t(qkv, n, cfg.heads, d, attn, threads);
    match &w.int8 {
        Some(q) => q.wo.forward_add_gated(attn, n, g1, out),
        None => w.wo.forward_add_gated_t(attn, n, g1, out, threads),
    }

    // MLP branch over the residual-updated stream, same fusions
    // (bias + GELU in the up-projection epilogue, g2-gated residual in
    // the down-projection writeback).
    layernorm_mod_t(out, n, d, sh2, sc2, xnorm, threads);
    match &w.int8 {
        Some(q) => q.w1.forward(xnorm, n, Act::Gelu, hidden),
        None => w.w1.forward_t(xnorm, n, Act::Gelu, hidden, threads),
    }
    match &w.int8 {
        Some(q) => q.w2.forward_add_gated(hidden, n, g2, out),
        None => w.w2.forward_add_gated_t(hidden, n, g2, out, threads),
    }
}

/// Allocating convenience wrapper over [`block_forward_slice`].
pub fn block_forward(
    h: &Tensor,
    c: &[f32],
    cfg: &ModelConfig,
    w: &PackedBlock,
    arena: &mut ScratchArena,
) -> Tensor {
    let (n, d) = (h.shape()[0], h.shape()[1]);
    let mut out = vec![0.0f32; n * d];
    block_forward_slice(h.data(), n, c, cfg, w, arena, &mut out);
    Tensor::new(out, &[n, d])
}

/// Final layer: fused adaLN -> linear to C channels. h: [n·D] -> [n·C].
pub fn final_forward_slice(
    h: &[f32],
    n: usize,
    c: &[f32],
    w: &PackedFinal,
    arena: &mut ScratchArena,
    out: &mut [f32],
) {
    let d = w.wmod.k();
    assert_eq!(h.len(), n * d);
    assert_eq!(out.len(), n * w.wout.m());
    let threads = arena.threads();
    let (csilu, modv, xnorm) = final_views(arena, n, d);
    for (o, &v) in csilu.iter_mut().zip(c) {
        *o = silu(v);
    }
    w.wmod.forward(csilu, 1, Act::None, modv);
    let (sh, sc) = modv.split_at(d);
    layernorm_mod_t(h, n, d, sh, sc, xnorm, threads);
    w.wout.forward_t(xnorm, n, Act::None, out, threads);
}

/// Token-wise saliency ‖x_t − x_{t−1}‖² (paper Eq. 1) — [N, D] x2 -> [N].
pub fn saliency(x_t: &Tensor, x_prev: &Tensor) -> Vec<f32> {
    assert_eq!(x_t.shape(), x_prev.shape());
    let d = x_t.shape()[1];
    x_t.data()
        .chunks(d)
        .zip(x_prev.data().chunks(d))
        .map(|(a, b)| a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum())
        .collect()
}

/// Relative Frobenius change δ (paper Eq. 4).
pub fn delta_rel(h: &Tensor, h_prev: &Tensor) -> f64 {
    assert_eq!(h.shape(), h_prev.shape());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in h.data().iter().zip(h_prev.data()) {
        let d = (*a - *b) as f64;
        num += d * d;
        den += (*b as f64) * (*b as f64);
    }
    (num.sqrt()) / den.sqrt().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Variant;
    use crate::model::weights::WeightBank;
    use crate::rng::Rng;

    fn rnd_tensor(seed: u64, shape: &[usize], scale: f32) -> Tensor {
        let mut r = Rng::new(seed);
        Tensor::new(r.normal_vec(shape.iter().product(), scale), shape)
    }

    #[test]
    fn gelu_matches_reference_points() {
        // Values from jax.nn.gelu (approximate=True).
        assert!((gelu(0.0) - 0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-5);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-5);
        assert!((gelu(3.0) - 2.9963627).abs() < 1e-4);
    }

    #[test]
    fn silu_matches_reference_points() {
        assert!((silu(0.0) - 0.0).abs() < 1e-7);
        assert!((silu(1.0) - 0.7310586).abs() < 1e-6);
        assert!((silu(-1.0) + 0.2689414).abs() < 1e-6);
    }

    #[test]
    fn block_identity_with_zero_modulation() {
        let cfg = ModelConfig::of(Variant::S);
        let mut w = WeightBank::generate(cfg, 9).blocks.remove(0);
        w.wmod = Tensor::zeros(&[cfg.d, 6 * cfg.d]);
        w.bmod = Tensor::zeros(&[6 * cfg.d]);
        let pw = w.pack();
        let h = rnd_tensor(3, &[16, cfg.d], 1.0);
        let c = vec![0.3f32; cfg.d];
        let mut arena = ScratchArena::new();
        let out = block_forward(&h, &c, &cfg, &pw, &mut arena);
        assert!(h.max_abs_diff(&out) < 1e-6);
    }

    #[test]
    fn block_changes_with_modulation() {
        let cfg = ModelConfig::of(Variant::S);
        let bank = WeightBank::generate(cfg, 9);
        let h = rnd_tensor(4, &[16, cfg.d], 1.0);
        let c = rnd_tensor(5, &[cfg.d], 1.0).into_data();
        let mut arena = ScratchArena::new();
        let out = block_forward(&h, &c, &cfg, &bank.packed.blocks[0], &mut arena);
        assert!(h.max_abs_diff(&out) > 1e-5);
    }

    #[test]
    fn block_reuses_arena_without_growth() {
        // Two calls at the same shape: the second must not grow the
        // arena (the zero-allocation steady-state contract), and the
        // result must be identical (stale scratch never leaks through).
        let cfg = ModelConfig::of(Variant::S);
        let bank = WeightBank::generate(cfg, 9);
        let h = rnd_tensor(6, &[32, cfg.d], 1.0);
        let c = rnd_tensor(7, &[cfg.d], 1.0).into_data();
        let mut arena = ScratchArena::new();
        let a = block_forward(&h, &c, &cfg, &bank.packed.blocks[0], &mut arena);
        let hw = arena.high_water_bytes();
        assert!(hw > 0);
        let b = block_forward(&h, &c, &cfg, &bank.packed.blocks[0], &mut arena);
        assert_eq!(arena.high_water_bytes(), hw);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn threaded_arena_block_is_bit_identical_to_serial() {
        let cfg = ModelConfig::of(Variant::S);
        let bank = WeightBank::generate(cfg, 9);
        let h = rnd_tensor(8, &[17, cfg.d], 1.0); // ragged row-block tail
        let c = rnd_tensor(9, &[cfg.d], 1.0).into_data();
        let mut serial = ScratchArena::new();
        let base = block_forward(&h, &c, &cfg, &bank.packed.blocks[0], &mut serial);
        for threads in [2usize, 4] {
            let mut arena = ScratchArena::new();
            arena.set_threads(threads);
            let got = block_forward(&h, &c, &cfg, &bank.packed.blocks[0], &mut arena);
            assert_eq!(base.data(), got.data(), "threads={threads}");
        }
    }

    #[test]
    fn int8_block_engages_and_stays_close_to_f32() {
        let cfg = ModelConfig::of(Variant::S);
        let bank = WeightBank::generate(cfg, 9);
        let h = rnd_tensor(10, &[16, cfg.d], 1.0);
        let c = rnd_tensor(11, &[cfg.d], 1.0).into_data();
        let mut arena = ScratchArena::new();
        let f32_out = block_forward(&h, &c, &cfg, &bank.packed.blocks[0], &mut arena);
        let mut qb = bank.packed.blocks[0].clone();
        qb.quantize_int8();
        let q_out = block_forward(&h, &c, &cfg, &qb, &mut arena);
        let md = f32_out.max_abs_diff(&q_out);
        assert!(md > 0.0, "int8 quad must actually be used");
        assert!(md < 0.5, "int8 block drifted too far from f32: {md}");
    }

    #[test]
    fn saliency_and_delta_basics() {
        let a = rnd_tensor(6, &[8, 4], 1.0);
        let s = saliency(&a, &a);
        assert!(s.iter().all(|&v| v == 0.0));
        assert!(delta_rel(&a, &a) < 1e-12);
        let mut b = a.clone();
        b.row_mut(3)[0] += 2.0;
        let s2 = saliency(&b, &a);
        assert!((s2[3] - 4.0).abs() < 1e-5);
        assert!(s2.iter().enumerate().all(|(i, &v)| i == 3 || v == 0.0));
        assert!(delta_rel(&b, &a) > 0.0);
    }

    #[test]
    fn timestep_embedding_bounded_and_distinct() {
        let a = timestep_embedding(10.0, 96);
        let b = timestep_embedding(11.0, 96);
        assert!(a.iter().all(|v| v.abs() <= 1.0 + 1e-6));
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-3);
    }
}
