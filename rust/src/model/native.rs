//! Native Rust reference implementation of the DiT forward pieces.
//!
//! Semantics MUST match python/compile/model.py exactly (same layer-norm
//! epsilon, tanh-approximate GELU — jax.nn.gelu's default — and SiLU); the
//! integration test rust/tests/runtime_roundtrip.rs executes the AOT HLO
//! and this module on identical weights and asserts allclose.
//!
//! Used for (a) cross-validating the artifacts, (b) the cheap non-matmul
//! hot-path math (saliency, delta, affine application) where a PJRT
//! dispatch would cost more than the arithmetic, and (c) running the full
//! test suite without compiled artifacts present.

use crate::config::ModelConfig;
use crate::tensor::Tensor;

use super::weights::{BlockWeights, EmbedWeights, FinalWeights, TembWeights};

pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// tanh-approximate GELU (jax.nn.gelu default).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// y = x @ w + b, x: [n, k] row-major, w: [k, m], b: [m] or empty.
pub fn matmul_bias(x: &[f32], w: &Tensor, b: Option<&Tensor>, n: usize) -> Vec<f32> {
    let (k, m) = (w.shape()[0], w.shape()[1]);
    assert_eq!(x.len(), n * k);
    let mut y = vec![0.0f32; n * m];
    if let Some(b) = b {
        assert_eq!(b.len(), m);
        for r in 0..n {
            y[r * m..(r + 1) * m].copy_from_slice(b.data());
        }
    }
    let wd = w.data();
    for r in 0..n {
        let xr = &x[r * k..(r + 1) * k];
        let yr = &mut y[r * m..(r + 1) * m];
        for (kk, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &wd[kk * m..(kk + 1) * m];
            for j in 0..m {
                yr[j] += xv * wrow[j];
            }
        }
    }
    y
}

/// Parameter-free LayerNorm over the last dim (eps = 1e-6, matches model.py).
pub fn layer_norm(x: &mut [f32], d: usize) {
    let eps = 1e-6f32;
    for row in x.chunks_mut(d) {
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for v in row.iter_mut() {
            *v = (*v - mean) * inv;
        }
    }
}

/// Sinusoidal timestep embedding, matching model.timestep_embedding:
/// freqs = exp(-ln(10000) * arange(half)/half); [cos(t·f), sin(t·f)].
pub fn timestep_embedding(t: f32, d: usize) -> Vec<f32> {
    let half = d / 2;
    let mut e = vec![0.0f32; d];
    for i in 0..half {
        let freq = (-(10000.0f32).ln() * i as f32 / half as f32).exp();
        let arg = t * freq;
        e[i] = arg.cos();
        e[half + i] = arg.sin();
    }
    e
}

/// Timestep -> conditioning embedding. Returns [D].
pub fn temb_forward(t: f32, w: &TembWeights) -> Vec<f32> {
    let d = w.w1.shape()[0];
    let e = timestep_embedding(t, d);
    let mut h = matmul_bias(&e, &w.w1, Some(&w.b1), 1);
    for v in h.iter_mut() {
        *v = silu(*v);
    }
    matmul_bias(&h, &w.w2, Some(&w.b2), 1)
}

/// Latent -> hidden embedding. x: [N, C] -> [N, D].
pub fn embed_forward(x: &Tensor, w: &EmbedWeights) -> Tensor {
    let n = x.shape()[0];
    let d = w.w.shape()[1];
    Tensor::new(matmul_bias(x.data(), &w.w, Some(&w.b), n), &[n, d])
}

/// Multi-head attention on already-projected q,k,v (each [N, D] with
/// `heads` interleaved as D = heads * dh, token-major like model.py's
/// reshape(n, heads, dh)).
pub fn attention(q: &[f32], k: &[f32], v: &[f32], n: usize, heads: usize, d: usize) -> Vec<f32> {
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = vec![0.0f32; n * d];
    let mut logits = vec![0.0f32; n];
    for h in 0..heads {
        let off = h * dh;
        for i in 0..n {
            let qi = &q[i * d + off..i * d + off + dh];
            let mut maxv = f32::NEG_INFINITY;
            for j in 0..n {
                let kj = &k[j * d + off..j * d + off + dh];
                let mut dot = 0.0f32;
                for c in 0..dh {
                    dot += qi[c] * kj[c];
                }
                let l = dot * scale;
                logits[j] = l;
                if l > maxv {
                    maxv = l;
                }
            }
            let mut denom = 0.0f32;
            for l in logits.iter_mut() {
                *l = (*l - maxv).exp();
                denom += *l;
            }
            let oi = &mut out[i * d + off..i * d + off + dh];
            for j in 0..n {
                let p = logits[j] / denom;
                if p == 0.0 {
                    continue;
                }
                let vj = &v[j * d + off..j * d + off + dh];
                for c in 0..dh {
                    oi[c] += p * vj[c];
                }
            }
        }
    }
    out
}

/// One adaLN-zero DiT block. h: [N, D], c: [D] -> [N, D].
pub fn block_forward(h: &Tensor, c: &[f32], cfg: &ModelConfig, w: &BlockWeights) -> Tensor {
    let (n, d) = (h.shape()[0], h.shape()[1]);
    assert_eq!(d, cfg.d);

    // Modulation: silu(c) @ wmod + bmod -> 6 chunks of D.
    let cs: Vec<f32> = c.iter().map(|&x| silu(x)).collect();
    let mod6 = matmul_bias(&cs, &w.wmod, Some(&w.bmod), 1);
    let (sh1, rest) = mod6.split_at(d);
    let (sc1, rest) = rest.split_at(d);
    let (g1, rest) = rest.split_at(d);
    let (sh2, rest) = rest.split_at(d);
    let (sc2, g2) = rest.split_at(d);

    let mut out = h.clone();

    // Attention branch.
    let mut x = h.data().to_vec();
    layer_norm(&mut x, d);
    for row in x.chunks_mut(d) {
        for j in 0..d {
            row[j] = row[j] * (1.0 + sc1[j]) + sh1[j];
        }
    }
    let qkv = matmul_bias(&x, &w.wqkv, Some(&w.bqkv), n);
    // qkv rows are [3D]: q | k | v contiguous (jnp.split on axis -1).
    let mut q = vec![0.0f32; n * d];
    let mut k = vec![0.0f32; n * d];
    let mut v = vec![0.0f32; n * d];
    for r in 0..n {
        q[r * d..(r + 1) * d].copy_from_slice(&qkv[r * 3 * d..r * 3 * d + d]);
        k[r * d..(r + 1) * d].copy_from_slice(&qkv[r * 3 * d + d..r * 3 * d + 2 * d]);
        v[r * d..(r + 1) * d].copy_from_slice(&qkv[r * 3 * d + 2 * d..r * 3 * d + 3 * d]);
    }
    let a = attention(&q, &k, &v, n, cfg.heads, d);
    let proj = matmul_bias(&a, &w.wo, Some(&w.bo), n);
    for r in 0..n {
        let orow = out.row_mut(r);
        for j in 0..d {
            orow[j] += g1[j] * proj[r * d + j];
        }
    }

    // MLP branch.
    let mut x2 = out.data().to_vec();
    layer_norm(&mut x2, d);
    for row in x2.chunks_mut(d) {
        for j in 0..d {
            row[j] = row[j] * (1.0 + sc2[j]) + sh2[j];
        }
    }
    let mut hidden = matmul_bias(&x2, &w.w1, Some(&w.b1), n);
    for vv in hidden.iter_mut() {
        *vv = gelu(*vv);
    }
    let mlp = matmul_bias(&hidden, &w.w2, Some(&w.b2), n);
    for r in 0..n {
        let orow = out.row_mut(r);
        for j in 0..d {
            orow[j] += g2[j] * mlp[r * d + j];
        }
    }
    out
}

/// Final layer: adaLN -> linear to C channels. h: [N, D] -> [N, C].
pub fn final_forward(h: &Tensor, c: &[f32], w: &FinalWeights) -> Tensor {
    let (n, d) = (h.shape()[0], h.shape()[1]);
    let cch = w.wout.shape()[1];
    let cs: Vec<f32> = c.iter().map(|&x| silu(x)).collect();
    let mod2 = matmul_bias(&cs, &w.wmod, Some(&w.bmod), 1);
    let (sh, sc) = mod2.split_at(d);
    let mut x = h.data().to_vec();
    layer_norm(&mut x, d);
    for row in x.chunks_mut(d) {
        for j in 0..d {
            row[j] = row[j] * (1.0 + sc[j]) + sh[j];
        }
    }
    Tensor::new(matmul_bias(&x, &w.wout, Some(&w.bout), n), &[n, cch])
}

/// Token-wise saliency ‖x_t − x_{t−1}‖² (paper Eq. 1) — [N, D] x2 -> [N].
pub fn saliency(x_t: &Tensor, x_prev: &Tensor) -> Vec<f32> {
    assert_eq!(x_t.shape(), x_prev.shape());
    let d = x_t.shape()[1];
    x_t.data()
        .chunks(d)
        .zip(x_prev.data().chunks(d))
        .map(|(a, b)| a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum())
        .collect()
}

/// Relative Frobenius change δ (paper Eq. 4).
pub fn delta_rel(h: &Tensor, h_prev: &Tensor) -> f64 {
    assert_eq!(h.shape(), h_prev.shape());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in h.data().iter().zip(h_prev.data()) {
        let d = (*a - *b) as f64;
        num += d * d;
        den += (*b as f64) * (*b as f64);
    }
    (num.sqrt()) / den.sqrt().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Variant;
    use crate::model::weights::WeightBank;
    use crate::rng::Rng;

    fn rnd_tensor(seed: u64, shape: &[usize], scale: f32) -> Tensor {
        let mut r = Rng::new(seed);
        Tensor::new(r.normal_vec(shape.iter().product(), scale), shape)
    }

    #[test]
    fn gelu_matches_reference_points() {
        // Values from jax.nn.gelu (approximate=True).
        assert!((gelu(0.0) - 0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-5);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-5);
        assert!((gelu(3.0) - 2.9963627).abs() < 1e-4);
    }

    #[test]
    fn silu_matches_reference_points() {
        assert!((silu(0.0) - 0.0).abs() < 1e-7);
        assert!((silu(1.0) - 0.7310586).abs() < 1e-6);
        assert!((silu(-1.0) + 0.2689414).abs() < 1e-6);
    }

    #[test]
    fn layer_norm_normalizes() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0];
        layer_norm(&mut x, 4);
        for row in x.chunks(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn attention_uniform_for_identical_keys() {
        let n = 4;
        let d = 8;
        let q = rnd_tensor(1, &[n, d], 1.0).into_data();
        let k = vec![0.5f32; n * d]; // identical keys -> uniform weights
        let v = rnd_tensor(2, &[n, d], 1.0).into_data();
        let out = attention(&q, &k, &v, n, 2, d);
        // Each output row should be the mean of v rows.
        for j in 0..d {
            let want: f32 = (0..n).map(|r| v[r * d + j]).sum::<f32>() / n as f32;
            for i in 0..n {
                assert!((out[i * d + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn block_identity_with_zero_modulation() {
        let cfg = ModelConfig::of(Variant::S);
        let mut w = WeightBank::generate(cfg, 9).blocks.remove(0);
        w.wmod = Tensor::zeros(&[cfg.d, 6 * cfg.d]);
        w.bmod = Tensor::zeros(&[6 * cfg.d]);
        let h = rnd_tensor(3, &[16, cfg.d], 1.0);
        let c = vec![0.3f32; cfg.d];
        let out = block_forward(&h, &c, &cfg, &w);
        assert!(h.max_abs_diff(&out) < 1e-6);
    }

    #[test]
    fn block_changes_with_modulation() {
        let cfg = ModelConfig::of(Variant::S);
        let w = &WeightBank::generate(cfg, 9).blocks[0];
        let h = rnd_tensor(4, &[16, cfg.d], 1.0);
        let c = rnd_tensor(5, &[cfg.d], 1.0).into_data();
        let out = block_forward(&h, &c, &cfg, &w);
        assert!(h.max_abs_diff(&out) > 1e-5);
    }

    #[test]
    fn saliency_and_delta_basics() {
        let a = rnd_tensor(6, &[8, 4], 1.0);
        let s = saliency(&a, &a);
        assert!(s.iter().all(|&v| v == 0.0));
        assert!(delta_rel(&a, &a) < 1e-12);
        let mut b = a.clone();
        b.row_mut(3)[0] += 2.0;
        let s2 = saliency(&b, &a);
        assert!((s2[3] - 4.0).abs() < 1e-5);
        assert!(s2.iter().enumerate().all(|(i, &v)| i == 3 || v == 0.0));
        assert!(delta_rel(&b, &a) > 0.0);
    }

    #[test]
    fn timestep_embedding_bounded_and_distinct() {
        let a = timestep_embedding(10.0, 96);
        let b = timestep_embedding(11.0, 96);
        assert!(a.iter().all(|v| v.abs() <= 1.0 + 1e-6));
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-3);
    }
}
