//! Model layer: weight banks (row-major + packed), the servable
//! `DitModel` (HLO or native execution), the zero-allocation native
//! kernels, and the native forward built on them.

pub mod dit;
pub mod kernels;
pub mod native;
pub mod weights;

pub use dit::{DitModel, ExecMode};
pub use kernels::{Int8PackedLinear, Int8Quad, PackedBank, PackedBlock, PackedLinear, ScratchArena};
pub use weights::{BlockWeights, EmbedWeights, FinalWeights, TembWeights, WeightBank};
