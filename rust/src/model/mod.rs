//! Model layer: weight banks, the servable `DitModel` (HLO or native
//! execution), and the native math reference.

pub mod dit;
pub mod native;
pub mod weights;

pub use dit::{DitModel, ExecMode};
pub use weights::{BlockWeights, EmbedWeights, FinalWeights, TembWeights, WeightBank};
