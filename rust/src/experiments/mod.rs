//! Shared experiment runners behind the benches and examples: evaluate a
//! set of cache policies on a workload, producing the rows the paper's
//! tables report (FID/t-FID proxies, CLIP proxy, time, memory, ratios).
//!
//! See DESIGN.md §6 for the experiment index mapping every paper table and
//! figure to a bench target, and EXPERIMENTS.md for recorded outputs.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{FastCacheConfig, ModelConfig, PolicyKind, ServerConfig, Variant};
use crate::metrics::{clip_display, clip_proxy, FidAccumulator};
use crate::model::DitModel;
use crate::scheduler::{DenoiseEngine, GenRequest};
use crate::server::Server;
use crate::store::{StoreStats, WarmStore};
use crate::workload::{MotionProfile, WorkloadGen};

/// One table row: a policy evaluated on a request set.
#[derive(Clone, Debug)]
pub struct EvalRow {
    pub label: String,
    pub policy: PolicyKind,
    /// Fréchet distance to the NoCache reference set (FID-proxy).
    pub fid: f64,
    /// Fréchet distance over temporal-difference features (t-FID proxy).
    pub tfid: f64,
    /// CLIP-proxy display score.
    pub clip: f64,
    /// Total wall time across the request set, ms.
    pub time_ms: f64,
    /// Estimated memory: weights + peak cache state + activations, MiB.
    pub mem_mib: f64,
    /// Block-site skip ratio.
    pub skip_ratio: f64,
    /// Token-site static ratio (Tab. 5).
    pub static_ratio: f64,
    /// Executed / full FLOPs.
    pub flops_ratio: f64,
    /// Speedup vs the NoCache row of the same eval (1.0 for NoCache).
    pub speedup: f64,
}

impl EvalRow {
    pub fn speedup_pct(&self) -> f64 {
        (self.speedup - 1.0) * 100.0
    }
}

/// Evaluation knobs (scaled-down defaults keep single-core runs tractable;
/// BENCH_FULL=1 switches to the paper-faithful 50-step / larger sets).
#[derive(Clone, Debug)]
pub struct EvalConfig {
    pub variant: Variant,
    pub steps: usize,
    pub requests: usize,
    pub profile: MotionProfile,
    pub seed: u64,
    pub guidance: f32,
}

impl EvalConfig {
    pub fn quick(variant: Variant) -> EvalConfig {
        if std::env::var("BENCH_FULL").as_deref() == Ok("1") {
            EvalConfig {
                variant, steps: 50, requests: 24,
                profile: MotionProfile::MIXED, seed: 0xE7A1, guidance: 7.5,
            }
        } else {
            EvalConfig {
                variant, steps: 20, requests: 8,
                profile: MotionProfile::MIXED, seed: 0xE7A1, guidance: 7.5,
            }
        }
    }
}

/// Estimated serving memory in MiB: weights + peak cache + transient
/// activations (a few [N, D] f32 buffers per concurrent request).
fn mem_mib(model: &DitModel, cache_peak: usize) -> f64 {
    let act = 6 * model.cfg.n_tokens * model.cfg.d * 4;
    (model.weight_bytes() + cache_peak + act) as f64 / (1 << 20) as f64
}

/// (row-sans-fid, latents, conditioning vectors) of one policy run.
type PolicyRun = (EvalRow, Vec<crate::tensor::Tensor>, Vec<Vec<f32>>);

/// Run one policy over a request set; returns (row-sans-fid, latents).
fn run_policy(
    model: &DitModel,
    label: &str,
    fc: &FastCacheConfig,
    reqs: &[GenRequest],
) -> Result<PolicyRun> {
    let mut eng = DenoiseEngine::new(model, fc.clone());
    let mut latents = Vec::with_capacity(reqs.len());
    let mut conds = Vec::with_capacity(reqs.len());
    let mut time_ms = 0.0;
    let mut skip_num = 0usize;
    let mut skip_den = 0usize;
    let mut tok_num = 0u64;
    let mut tok_den = 0u64;
    let mut flops_done = 0u64;
    let mut flops_full = 0u64;
    let mut cache_peak = 0usize;
    for req in reqs {
        let r = eng.generate(req)?;
        time_ms += r.wall_ms;
        skip_num += r.approximated + r.reused;
        skip_den += r.computed + r.approximated + r.reused;
        tok_num += r.token_sites_computed;
        tok_den += r.token_sites_total;
        flops_done += r.flops_done;
        flops_full += r.flops_full;
        cache_peak = cache_peak.max(r.cache_bytes_peak);
        conds.push(r.cond.clone());
        latents.push(r.latent);
    }
    let mut clip_sum = 0.0;
    for (l, c) in latents.iter().zip(&conds) {
        clip_sum += clip_display(clip_proxy(model, l, c));
    }
    let row = EvalRow {
        label: label.to_string(),
        policy: fc.policy,
        fid: 0.0,
        tfid: 0.0,
        clip: clip_sum / latents.len().max(1) as f64,
        time_ms,
        mem_mib: mem_mib(model, cache_peak),
        skip_ratio: skip_num as f64 / skip_den.max(1) as f64,
        static_ratio: 1.0 - tok_num as f64 / tok_den.max(1) as f64,
        flops_ratio: flops_done as f64 / flops_full.max(1) as f64,
        speedup: 1.0,
    };
    Ok((row, latents, conds))
}

/// Evaluate labeled policy configs against the NoCache reference on one
/// model: the general engine behind table1/2/6/9/10/13/14.
pub fn eval_policies(
    model: &DitModel,
    policies: &[(String, FastCacheConfig)],
    ecfg: &EvalConfig,
) -> Result<Vec<EvalRow>> {
    let mut wl = WorkloadGen::new(ecfg.seed);
    let reqs: Vec<GenRequest> = wl
        .image_set(ecfg.requests, ecfg.steps, ecfg.profile)
        .into_iter()
        .map(|mut r| {
            r.guidance = ecfg.guidance;
            r
        })
        .collect();

    // Reference: NoCache on the same requests.
    let ref_fc = FastCacheConfig::with_policy(PolicyKind::NoCache);
    let (ref_row, ref_latents, _) = run_policy(model, "No Cache", &ref_fc, &reqs)?;
    let mut ref_fid = FidAccumulator::new();
    let mut ref_tfid = FidAccumulator::new();
    for (i, l) in ref_latents.iter().enumerate() {
        ref_fid.push_latent(l);
        if i > 0 {
            ref_tfid.push_temporal(l, &ref_latents[i - 1]);
        }
    }
    let base_ms = ref_row.time_ms;

    let mut rows = Vec::new();
    for (label, fc) in policies {
        if fc.policy == PolicyKind::NoCache {
            let mut row = ref_row.clone();
            row.label = label.clone();
            rows.push(row);
            continue;
        }
        let (mut row, latents, _) = run_policy(model, label, fc, &reqs)?;
        let mut fid = FidAccumulator::new();
        let mut tfid = FidAccumulator::new();
        for (i, l) in latents.iter().enumerate() {
            fid.push_latent(l);
            if i > 0 {
                tfid.push_temporal(l, &latents[i - 1]);
            }
        }
        row.fid = fid.distance_to(&ref_fid);
        row.tfid = tfid.distance_to(&ref_tfid);
        row.speedup = base_ms / row.time_ms.max(1e-9);
        rows.push(row);
    }
    Ok(rows)
}

/// The paper's baseline set (Tab. 1 / Tab. 12 rows).
pub fn baseline_policies() -> Vec<(String, FastCacheConfig)> {
    [
        PolicyKind::TeaCache,
        PolicyKind::AdaCache,
        PolicyKind::L2C,
        PolicyKind::FbCache,
        PolicyKind::FastCache,
    ]
    .into_iter()
    .map(|p| (FastCacheConfig::with_policy(p).policy.paper_name().to_string(),
              FastCacheConfig::with_policy(p)))
    .collect()
}

/// Video evaluation: a clip's frames through one policy; FVD-proxy over
/// frame-to-frame temporal features vs the NoCache rendering of the SAME
/// clip (Tab. 8).
pub fn eval_video(
    model: &DitModel,
    fc: &FastCacheConfig,
    frames: usize,
    steps: usize,
    profile: MotionProfile,
    seed: u64,
) -> Result<(EvalRow, f64)> {
    let mut wl = WorkloadGen::new(seed);
    let clip = wl.video_clip(frames, steps, profile);

    let ref_fc = FastCacheConfig::with_policy(PolicyKind::NoCache);
    let (ref_row, ref_frames, _) = run_policy(model, "No Cache", &ref_fc, &clip)?;
    let mut ref_acc = FidAccumulator::new();
    for i in 1..ref_frames.len() {
        ref_acc.push_temporal(&ref_frames[i], &ref_frames[i - 1]);
    }

    let (mut row, frames_out, _) = run_policy(model, fc.policy.paper_name(), fc, &clip)?;
    let mut acc = FidAccumulator::new();
    for i in 1..frames_out.len() {
        acc.push_temporal(&frames_out[i], &frames_out[i - 1]);
    }
    let fvd = if fc.policy == PolicyKind::NoCache { 0.0 } else { acc.distance_to(&ref_acc) };
    row.fid = fvd;
    row.speedup = ref_row.time_ms / row.time_ms.max(1e-9);
    Ok((row, fvd))
}

/// Model cards for the cross-variant tables.
pub fn variant_cfgs() -> Vec<ModelConfig> {
    Variant::ALL.iter().map(|v| ModelConfig::of(*v)).collect()
}

/// One serving-mode row: a policy config driven through the
/// continuous-batching server under a burst workload.
#[derive(Clone, Debug)]
pub struct ServeRow {
    pub label: String,
    pub completed: u64,
    pub wall_s: f64,
    pub rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// Mean active lanes per step call (continuous-batching occupancy).
    pub occupancy: f64,
    /// Median submit→admission latency.
    pub admission_p50_ms: f64,
    /// FLOPs burnt in padded B=4 batch slots, in GFLOPs.
    pub padded_gflops: f64,
}

/// Run each labeled config through the continuous-batching server (native
/// model on the worker thread) with a burst of `requests` jobs. Absolute
/// numbers are substrate-bound; the signal is occupancy and the relative
/// throughput/latency of the configs — including that STR/merge configs
/// now batch instead of falling back to single-request serving.
pub fn eval_serving(
    variant: Variant,
    configs: &[(String, FastCacheConfig)],
    requests: usize,
    steps: usize,
    max_batch: usize,
) -> Result<Vec<ServeRow>> {
    let mut rows = Vec::with_capacity(configs.len());
    for (label, fc) in configs {
        let scfg = ServerConfig {
            variant,
            steps,
            max_batch,
            queue_depth: requests.max(1),
            ..ServerConfig::default()
        };
        let server = Server::start(scfg, fc.clone(), move || Ok(DitModel::native(variant, 0xD17)));

        let mut wl = WorkloadGen::new(0x5E11);
        let reqs = wl.image_set(requests, steps, MotionProfile::MIXED);
        let mut rxs = Vec::with_capacity(reqs.len());
        for req in &reqs {
            let rx = server
                .submit_blocking(req)
                .map_err(|e| anyhow::anyhow!("submit failed: {e}"))?;
            rxs.push(rx);
        }
        for rx in rxs {
            let _ = rx.wait();
        }
        let report = server.shutdown();
        rows.push(ServeRow {
            label: label.clone(),
            completed: report.completed,
            wall_s: report.wall_s,
            rps: report.throughput_rps(),
            p50_ms: report.e2e.percentile(50.0),
            p95_ms: report.e2e.percentile(95.0),
            occupancy: report.occupancy(),
            admission_p50_ms: report.admission_wait.percentile(50.0),
            padded_gflops: report.padded_flops as f64 / 1e9,
        });
    }
    Ok(rows)
}

/// Knobs of the sharding experiment (one synthetic burst, replayed per
/// worker count so the rows are directly comparable).
#[derive(Clone, Debug)]
pub struct ShardingEval {
    pub variant: Variant,
    pub requests: usize,
    pub steps: usize,
    /// Active-lane cap PER SHARD.
    pub max_batch: usize,
    /// Worker counts to sweep (one row each).
    pub workers_grid: Vec<usize>,
    /// Every k-th request is deadline-tagged (0 = no SLA traffic).
    pub deadline_every: usize,
    /// Deadline budget for tagged requests, ms from submission.
    pub deadline_ms: f64,
}

impl ShardingEval {
    pub fn quick(variant: Variant) -> ShardingEval {
        let full = std::env::var("BENCH_FULL").as_deref() == Ok("1");
        let (requests, steps) = if full { (32, 20) } else { (12, 6) };
        ShardingEval {
            variant,
            requests,
            steps,
            max_batch: 4,
            workers_grid: vec![1, 2, 4],
            deadline_every: 3,
            deadline_ms: 120_000.0,
        }
    }
}

/// One sharding-sweep row: the same burst served at a given worker count.
#[derive(Clone, Debug)]
pub struct ShardingRow {
    pub workers: usize,
    pub completed: u64,
    pub wall_s: f64,
    pub rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// Mean active lanes per step call (lane-steps / step-calls,
    /// aggregated over all shards).
    pub occupancy: f64,
    /// Fraction of deadline-class jobs served within budget — sheds
    /// count as misses (`None` when the burst carried no SLA traffic).
    pub deadline_hit_rate: Option<f64>,
    /// Deadline-tagged jobs dropped unserved (deadline expired while
    /// queued) — kept visible so a high hit rate can't hide drops.
    pub deadline_sheds: u64,
    pub padded_gflops: f64,
    /// Jobs completed per shard — shows what least-predicted-load
    /// routing actually did with the burst.
    pub shard_completed: Vec<u64>,
}

/// Sharding sweep: replay one synthetic burst (with a slice of
/// deadline-tagged SLA traffic) against the server at each worker count
/// in the grid. On multi-core hosts aggregate throughput should be
/// monotonically non-decreasing from 1 → 4 workers; per-shard batches
/// shrink as workers grow, so padded-slot FLOPs rise — both effects are
/// reported rather than hidden.
pub fn eval_sharding(fc: &FastCacheConfig, e: &ShardingEval) -> Result<Vec<ShardingRow>> {
    let mut rows = Vec::with_capacity(e.workers_grid.len());
    for &workers in &e.workers_grid {
        let scfg = ServerConfig {
            variant: e.variant,
            steps: e.steps,
            max_batch: e.max_batch,
            queue_depth: e.requests.max(workers),
            workers,
            ..ServerConfig::default()
        };
        scfg.validate().map_err(anyhow::Error::msg)?;
        let variant = e.variant;
        let server = Server::start(scfg, fc.clone(), move || Ok(DitModel::native(variant, 0xD17)));

        // The SAME burst for every worker count: workload seeds are fixed
        // and deadline tags land on the same request ids.
        let mut wl = WorkloadGen::new(0x5AAD);
        let reqs: Vec<GenRequest> = wl
            .image_set(e.requests, e.steps, MotionProfile::MIXED)
            .into_iter()
            .enumerate()
            .map(|(i, req)| {
                if e.deadline_every > 0 && i % e.deadline_every == 0 {
                    req.into_builder().deadline_ms(e.deadline_ms).build().unwrap()
                } else {
                    req
                }
            })
            .collect();
        let mut rxs = Vec::with_capacity(reqs.len());
        for req in &reqs {
            let rx = server
                .submit_blocking(req)
                .map_err(|err| anyhow::anyhow!("submit failed: {err}"))?;
            rxs.push(rx);
        }
        for rx in rxs {
            let _ = rx.wait();
        }
        let report = server.shutdown();
        rows.push(ShardingRow {
            workers,
            completed: report.completed,
            wall_s: report.wall_s,
            rps: report.throughput_rps(),
            p50_ms: report.e2e.percentile(50.0),
            p95_ms: report.e2e.percentile(95.0),
            occupancy: report.occupancy(),
            deadline_hit_rate: report.deadline_hit_rate(),
            deadline_sheds: report.deadline_sheds,
            padded_gflops: report.padded_flops as f64 / 1e9,
            shard_completed: report.shards.iter().map(|s| s.completed).collect(),
        });
    }
    Ok(rows)
}

/// Knobs of the warm-start experiment: the SAME fixed-seed burst served
/// twice against one long-lived `WarmStore` — first cold (empty store),
/// then warm (the store holds what the first burst's lanes published).
#[derive(Clone, Debug)]
pub struct WarmstartEval {
    pub variant: Variant,
    pub requests: usize,
    pub steps: usize,
    /// Active-lane cap; ≥ `requests` keeps the first burst fully cold
    /// (every lane admitted before any lane retires and publishes).
    pub max_batch: usize,
    /// Store byte budget (the rows report used bytes against it).
    pub budget_bytes: usize,
    /// Fit-confidence gate (see `FastCacheConfig::fit_min_updates`): the
    /// cold burst pays compute until its fits converge; the warm burst
    /// adopts converged fits and approximates from the first skippable
    /// site.
    pub fit_min_updates: u64,
    /// Permissive χ² noise floor so the χ² test fires from the first
    /// cached step and the confidence gate is the binding constraint —
    /// isolating the warm-start effect. Both phases run the same value,
    /// so per-skip error stays bounded by the same ε = δ₀·√(χ²/ND) in
    /// both rows (the fid column reports the realized cost).
    pub tau_delta0: f64,
}

impl WarmstartEval {
    pub fn quick(variant: Variant) -> WarmstartEval {
        let full = std::env::var("BENCH_FULL").as_deref() == Ok("1");
        let (requests, steps) = if full { (16, 20) } else { (8, 12) };
        WarmstartEval {
            variant,
            requests,
            steps,
            max_batch: 16,
            budget_bytes: 4 << 20,
            fit_min_updates: 6,
            tau_delta0: 1.0,
        }
    }
}

/// One warm-start row: a burst phase against the shared store.
#[derive(Clone, Debug)]
pub struct WarmstartRow {
    pub phase: String,
    pub completed: u64,
    /// Mean executed GFLOPs per lane-step — the cold-vs-warm axis.
    pub flops_per_step_g: f64,
    pub flops_ratio: f64,
    pub skip_ratio: f64,
    /// FID-proxy vs the full-compute (NoCache) rendering of the burst.
    pub fid: f64,
    pub warm_admissions: u64,
    pub warm_layers: u64,
    /// Store counter deltas for this phase + absolute occupancy.
    pub store: StoreStats,
}

/// Serve one fixed-seed burst twice through warm-start-enabled servers
/// sharing one store. The cold phase runs against an empty store (all
/// misses, publishes on retirement); the warm phase warm-starts from it.
/// The headline signal: warm lanes execute fewer FLOPs per step at the
/// same χ²-bounded fidelity, with every store counter reported and
/// `used_bytes ≤ budget` by construction.
pub fn eval_warmstart(fc: &FastCacheConfig, e: &WarmstartEval) -> Result<Vec<WarmstartRow>> {
    let mut fc = fc.clone();
    fc.warm_start = true;
    fc.fit_min_updates = e.fit_min_updates;
    fc.tau_delta0 = e.tau_delta0;
    fc.enable_str = false; // isolate the fit/profile effect from token reduction

    let mut wl = WorkloadGen::new(0x3A9A);
    let reqs = wl.image_set(e.requests, e.steps, MotionProfile::MIXED);

    // Full-compute reference for the fidelity column.
    let variant = e.variant;
    let model = DitModel::native(variant, ServerConfig::default().weight_seed);
    let mut ref_fid = FidAccumulator::new();
    {
        let mut eng = DenoiseEngine::new(&model, FastCacheConfig::with_policy(PolicyKind::NoCache));
        for r in &reqs {
            ref_fid.push_latent(&eng.generate(r)?.latent);
        }
    }

    let store = Arc::new(WarmStore::new(e.budget_bytes, 1));
    let mut rows = Vec::with_capacity(2);
    let mut base_stats = StoreStats::default();
    for phase in ["cold", "warm"] {
        let scfg = ServerConfig {
            variant,
            steps: e.steps,
            max_batch: e.max_batch.min(16),
            queue_depth: e.requests.max(1),
            warm_budget_bytes: e.budget_bytes,
            ..ServerConfig::default()
        };
        scfg.validate().map_err(anyhow::Error::msg)?;
        let server = Server::start_with_store(
            scfg,
            fc.clone(),
            Some(Arc::clone(&store)),
            move || Ok(DitModel::native(variant, ServerConfig::default().weight_seed)),
        );
        let mut rxs = Vec::with_capacity(reqs.len());
        for req in &reqs {
            let rx = server
                .submit_blocking(req)
                .map_err(|err| anyhow::anyhow!("submit failed: {err}"))?;
            rxs.push(rx);
        }
        let mut flops_done = 0u64;
        let mut flops_full = 0u64;
        let mut steps_run = 0usize;
        let mut skip_num = 0usize;
        let mut skip_den = 0usize;
        let mut fid = FidAccumulator::new();
        for rx in rxs {
            let resp = rx.wait().completed();
            flops_done += resp.result.flops_done;
            flops_full += resp.result.flops_full;
            steps_run += resp.result.records.len();
            skip_num += resp.result.approximated + resp.result.reused;
            skip_den += resp.result.computed + resp.result.approximated + resp.result.reused;
            fid.push_latent(&resp.result.latent);
        }
        let report = server.shutdown();
        let now = store.stats();
        rows.push(WarmstartRow {
            phase: phase.to_string(),
            completed: report.completed,
            flops_per_step_g: flops_done as f64 / steps_run.max(1) as f64 / 1e9,
            flops_ratio: flops_done as f64 / flops_full.max(1) as f64,
            skip_ratio: skip_num as f64 / skip_den.max(1) as f64,
            fid: fid.distance_to(&ref_fid),
            warm_admissions: report.warm_admissions,
            warm_layers: report.warm_layers,
            store: now.since(&base_stats),
        });
        base_stats = now;
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_policies_produces_ordered_rows() {
        let model = DitModel::native(Variant::S, 5);
        let mut ecfg = EvalConfig::quick(Variant::S);
        ecfg.steps = 8;
        ecfg.requests = 8;
        let policies = vec![
            ("No Cache".to_string(), FastCacheConfig::with_policy(PolicyKind::NoCache)),
            ("FastCache".to_string(), FastCacheConfig::with_policy(PolicyKind::FastCache)),
        ];
        let rows = eval_policies(&model, &policies, &ecfg).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].fid, 0.0); // reference row
        assert!(rows[1].fid >= 0.0);
        assert!(rows[1].speedup > 1.0, "caching should speed up: {}", rows[1].speedup);
        // At 8 steps the chi-square gate may not fire (per-step deltas are
        // large); token reduction must still produce static token-sites.
        assert!(
            rows[1].static_ratio > 0.0 || rows[1].skip_ratio > 0.0,
            "no compression at all: static {} skip {}",
            rows[1].static_ratio,
            rows[1].skip_ratio
        );
    }

    #[test]
    fn eval_serving_reports_occupancy() {
        let configs = vec![
            ("NoCache".to_string(), FastCacheConfig::with_policy(PolicyKind::NoCache)),
            // FastCache default keeps STR on — must batch anyway.
            ("FastCache+STR".to_string(), FastCacheConfig::with_policy(PolicyKind::FastCache)),
        ];
        let rows = eval_serving(Variant::S, &configs, 8, 4, 4).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.completed, 8, "{}", r.label);
            assert!(r.rps > 0.0);
            assert!(
                r.occupancy > 1.0,
                "{}: burst load should batch (occupancy {})",
                r.label,
                r.occupancy
            );
        }
    }

    #[test]
    fn eval_sharding_sweeps_worker_counts() {
        let fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        let e = ShardingEval {
            variant: Variant::S,
            requests: 6,
            steps: 3,
            max_batch: 2,
            workers_grid: vec![1, 2],
            deadline_every: 2,
            deadline_ms: 120_000.0,
        };
        let rows = eval_sharding(&fc, &e).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.completed, 6, "workers={}", r.workers);
            assert_eq!(r.shard_completed.len(), r.workers);
            assert_eq!(r.shard_completed.iter().sum::<u64>(), 6);
            assert!(r.rps > 0.0);
            // 120s budget on a 6-request burst: every tagged job hits,
            // nothing is shed.
            assert_eq!(r.deadline_hit_rate, Some(1.0), "workers={}", r.workers);
            assert_eq!(r.deadline_sheds, 0, "workers={}", r.workers);
        }
    }

    #[test]
    fn eval_warmstart_shows_fewer_flops_warm_within_budget() {
        let e = WarmstartEval {
            variant: Variant::S,
            requests: 4,
            steps: 10,
            max_batch: 8,
            budget_bytes: 1 << 20,
            fit_min_updates: 5,
            tau_delta0: 1.0,
        };
        let fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        let rows = eval_warmstart(&fc, &e).unwrap();
        assert_eq!(rows.len(), 2);
        let (cold, warm) = (&rows[0], &rows[1]);
        assert_eq!(cold.completed, 4);
        assert_eq!(warm.completed, 4);
        // The acceptance criterion: warm lanes execute fewer FLOPs/step.
        assert!(
            warm.flops_per_step_g < cold.flops_per_step_g,
            "warm {} vs cold {} GFLOP/step",
            warm.flops_per_step_g,
            cold.flops_per_step_g
        );
        assert!(warm.flops_ratio < cold.flops_ratio);
        // Cold phase: empty store — only misses and publishes.
        assert_eq!(cold.warm_admissions, 0);
        assert_eq!(cold.store.hits, 0);
        assert!(cold.store.misses > 0);
        assert!(cold.store.inserts > 0);
        // Warm phase: every lane warm-starts; the store stays in budget.
        assert_eq!(warm.warm_admissions, 4);
        assert!(warm.store.hits > 0);
        assert!(warm.store.used_bytes <= warm.store.budget_bytes);
        // Fidelity stays χ²-bounded (finite, same order) in both phases.
        assert!(cold.fid.is_finite() && warm.fid.is_finite());
    }

    #[test]
    fn eval_video_runs() {
        let model = DitModel::native(Variant::S, 5);
        let fc = FastCacheConfig::default();
        let (row, fvd) = eval_video(&model, &fc, 4, 6, MotionProfile::CALM, 3).unwrap();
        assert!(fvd >= 0.0);
        assert!(row.speedup > 0.5);
    }
}
