//! `DenoiseEngine` — the single-request driver over the unified lane
//! stepper (`scheduler::lane`): one request becomes one [`Lane`] and the
//! batch-of-one case of [`LaneStepper::step`]. Algorithm 1 (and the
//! Algorithm 2 token-merge extension) live in the stepper; this type only
//! owns request-level conveniences (schedule cache, policy override for
//! calibration flows).

use anyhow::Result;

use crate::cache::CachePolicy;
use crate::config::FastCacheConfig;
use crate::model::DitModel;

use super::ddim::ScheduleCache;
use super::lane::{self, LaneStepper};

// Re-exported for path stability: these types historically lived here.
pub use super::lane::{GenRequest, GenResult, StepRecord, Turbulence};

/// The engine: one model + one policy + per-request cache state, executed
/// as a batch-of-one through the shared lane stepper.
pub struct DenoiseEngine<'m> {
    stepper: LaneStepper<'m>,
    /// Caller-installed policy (L2C calibration flows); reused across
    /// generates, reset per request.
    policy_override: Option<Box<dyn CachePolicy>>,
    schedules: ScheduleCache,
}

impl<'m> DenoiseEngine<'m> {
    pub fn new(model: &'m DitModel, fc: FastCacheConfig) -> DenoiseEngine<'m> {
        DenoiseEngine {
            stepper: LaneStepper::new(model, fc),
            policy_override: None,
            schedules: ScheduleCache::new(),
        }
    }

    pub fn fc(&self) -> &FastCacheConfig {
        self.stepper.fc()
    }

    /// Replace the policy (used by L2C calibration flows).
    pub fn set_policy(&mut self, policy: Box<dyn CachePolicy>) {
        self.policy_override = Some(policy);
    }

    /// Build the conditioning vector for a request: unit-normalized random
    /// direction scaled by guidance/7.5 (substitution for CFG text
    /// conditioning — see DESIGN.md §2).
    pub fn make_cond(&self, req: &GenRequest) -> Vec<f32> {
        lane::make_cond(self.stepper.model().cfg.d, req)
    }

    /// Run one full generation.
    pub fn generate(&mut self, req: &GenRequest) -> Result<GenResult> {
        let schedule = self.schedules.get(req.steps);
        let had_override = self.policy_override.is_some();
        let mut lane = match self.policy_override.take() {
            Some(p) => self.stepper.lane_with_policy(req, schedule, p),
            None => self.stepper.make_lane(req, schedule),
        };
        let mut err = None;
        while !lane.is_done() {
            if let Err(e) = self.stepper.step(std::slice::from_mut(&mut lane)) {
                err = Some(e);
                break;
            }
        }
        // Recover the policy even on a failed run, so an installed
        // override survives a retried generate().
        let (result, policy) = lane.finish();
        if had_override {
            self.policy_override = Some(policy);
        }
        match err {
            Some(e) => Err(e),
            None => Ok(result),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyKind, Variant, C_IN};
    use crate::model::DitModel;

    fn run(policy: PolicyKind, steps: usize) -> GenResult {
        let model = DitModel::native(Variant::S, 7);
        let fc = FastCacheConfig::with_policy(policy);
        let mut eng = DenoiseEngine::new(&model, fc);
        eng.generate(&GenRequest::builder(1, 99).steps(steps).build().unwrap()).unwrap()
    }

    #[test]
    fn nocache_computes_every_site() {
        let r = run(PolicyKind::NoCache, 6);
        assert_eq!(r.computed, 6 * 3);
        assert_eq!(r.approximated + r.reused, 0);
        assert_eq!(r.flops_done, r.flops_full);
        assert!(r.latent.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fastcache_skips_some_blocks() {
        let r = run(PolicyKind::FastCache, 12);
        assert!(r.approximated > 0, "no approximations happened");
        assert!(r.computed > 0, "first step must compute");
        assert!(r.flops_done < r.flops_full);
        assert!(r.skip_ratio() > 0.0 && r.skip_ratio() < 1.0);
    }

    #[test]
    fn deterministic_generation() {
        let a = run(PolicyKind::FastCache, 5);
        let b = run(PolicyKind::FastCache, 5);
        assert_eq!(a.latent.data(), b.latent.data());
        assert_eq!(a.computed, b.computed);
    }

    #[test]
    fn fastcache_output_close_to_nocache() {
        // The whole point of bounded-error caching: the generated latent
        // stays near the full-compute trajectory.
        let full = run(PolicyKind::NoCache, 10);
        let fast = run(PolicyKind::FastCache, 10);
        let rel = {
            let diff: f64 = full
                .latent
                .data()
                .iter()
                .zip(fast.latent.data())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            let base: f64 = full
                .latent
                .data()
                .iter()
                .map(|v| (*v as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            diff / base.max(1e-9)
        };
        assert!(rel < 0.5, "relative deviation {rel}");
    }

    #[test]
    fn turbulence_increases_motion_ratio() {
        let model = DitModel::native(Variant::S, 7);
        let fc = FastCacheConfig::default();
        let mut eng = DenoiseEngine::new(&model, fc.clone());
        let calm = eng.generate(&GenRequest::builder(1, 3).steps(8).build().unwrap()).unwrap();
        let mut req = GenRequest::builder(2, 3).steps(8).build().unwrap();
        req.turbulence = Some(Turbulence { tokens: (0..24).collect(), amp: 1.0, seed: 5 });
        let mut eng2 = DenoiseEngine::new(&model, fc);
        let stormy = eng2.generate(&req).unwrap();
        let calm_motion: usize = calm.records.iter().map(|r| r.motion_tokens).sum();
        let stormy_motion: usize = stormy.records.iter().map(|r| r.motion_tokens).sum();
        assert!(
            stormy_motion > calm_motion,
            "turbulence should raise motion tokens: {stormy_motion} vs {calm_motion}"
        );
    }

    #[test]
    fn merge_path_runs_and_restores_resolution() {
        let model = DitModel::native(Variant::B, 7);
        let fc = FastCacheConfig {
            enable_merge: true,
            merge_target: 32,
            enable_str: false,
            ..FastCacheConfig::default()
        };
        let mut eng = DenoiseEngine::new(&model, fc);
        let r = eng.generate(&GenRequest::builder(3, 11).steps(4).build().unwrap()).unwrap();
        assert_eq!(r.latent.shape(), &[64, C_IN]);
        assert!(r.latent.data().iter().all(|v| v.is_finite()));
        // Merged layers ran at 32 tokens: token sites reflect that.
        assert!(r.token_sites_total < 4 * 6 * 64);
    }

    #[test]
    fn guidance_affects_conditioning_strength() {
        let model = DitModel::native(Variant::S, 7);
        let eng = DenoiseEngine::new(&model, FastCacheConfig::default());
        let mut lo = GenRequest::builder(1, 5).steps(4).build().unwrap();
        lo.guidance = 1.0;
        let mut hi = GenRequest::builder(1, 5).steps(4).build().unwrap();
        hi.guidance = 15.0;
        let cl = eng.make_cond(&lo);
        let ch = eng.make_cond(&hi);
        let nl: f32 = cl.iter().map(|v| v * v).sum::<f32>();
        let nh: f32 = ch.iter().map(|v| v * v).sum::<f32>();
        assert!(nh > nl * 9.0);
    }
}
