//! The denoise engine — Algorithm 1 (and the Algorithm 2 token-merge
//! extension) of the paper, driven from Rust between HLO block executions.
//!
//! Per step: embed the latent, partition tokens (STR), then walk the block
//! stack; per block the cache policy decides Compute / Approx / Reuse from
//! the relative hidden-state change (SC, the χ² rule for FastCache), with
//! the learnable linear approximation and motion-aware blending (MB)
//! realizing skipped blocks. The engine owns ALL bookkeeping the paper's
//! tables report: block-site counters, token-site ratios, FLOPs, cache
//! bytes, wall time.

use anyhow::Result;

use crate::cache::{build_policy, BlockAction, BlockCtx, CachePolicy, CacheState, StepInfo};
use crate::config::{ApproxMode, FastCacheConfig, C_IN};
use crate::model::{native, DitModel};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::tokens::{self, partition};

use super::ddim::DdimSchedule;

/// Turbulence: per-step re-noising of selected token rows — the synthetic
/// stand-in for high-motion content regions (DESIGN.md §2): those tokens
/// keep changing between steps, so a content-aware cache must recompute
/// them while the rest of the latent settles.
#[derive(Clone, Debug)]
pub struct Turbulence {
    pub tokens: Vec<usize>,
    pub amp: f32,
    pub seed: u64,
}

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub seed: u64,
    /// Conditioning seed (the "prompt"); drives the CLIP-proxy metric.
    pub cond_seed: u64,
    pub guidance: f32,
    pub steps: usize,
    pub turbulence: Option<Turbulence>,
    /// Optional initial latent (video frames share correlated inits).
    pub init_latent: Option<Tensor>,
}

impl GenRequest {
    pub fn simple(id: u64, seed: u64, steps: usize) -> GenRequest {
        GenRequest {
            id,
            seed,
            cond_seed: seed ^ 0xC04D,
            guidance: 7.5,
            steps,
            turbulence: None,
            init_latent: None,
        }
    }
}

/// Per-step execution record (drives Fig. 1/3 style analyses).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepRecord {
    pub step: usize,
    pub computed: usize,
    pub approximated: usize,
    pub reused: usize,
    pub motion_tokens: usize,
    pub n_tokens: usize,
    pub mean_delta: f64,
}

/// Result of one full generation.
#[derive(Debug)]
pub struct GenResult {
    pub id: u64,
    /// Final denoised latent [N, C].
    pub latent: Tensor,
    /// Conditioning vector used (for the CLIP-proxy metric).
    pub cond: Vec<f32>,
    pub records: Vec<StepRecord>,
    pub wall_ms: f64,
    /// Block-site actions over the whole generation.
    pub computed: usize,
    pub approximated: usize,
    pub reused: usize,
    /// Token-site accounting: computed token-sites vs total token-sites
    /// (Tab. 5's static/dynamic ratios are derived from these).
    pub token_sites_computed: u64,
    pub token_sites_total: u64,
    /// FLOPs actually executed vs the NoCache-equivalent total.
    pub flops_done: u64,
    pub flops_full: u64,
    /// Peak cache-state bytes held for this request.
    pub cache_bytes_peak: usize,
}

impl GenResult {
    pub fn skip_ratio(&self) -> f64 {
        let total = self.computed + self.approximated + self.reused;
        if total == 0 {
            0.0
        } else {
            (self.approximated + self.reused) as f64 / total as f64
        }
    }

    /// Fraction of token-sites NOT computed (the paper's "static ratio").
    pub fn static_ratio(&self) -> f64 {
        if self.token_sites_total == 0 {
            0.0
        } else {
            1.0 - self.token_sites_computed as f64 / self.token_sites_total as f64
        }
    }

    pub fn flops_ratio(&self) -> f64 {
        if self.flops_full == 0 {
            1.0
        } else {
            self.flops_done as f64 / self.flops_full as f64
        }
    }
}

/// The engine: one model + one policy + per-request cache state.
pub struct DenoiseEngine<'m> {
    model: &'m DitModel,
    pub fc: FastCacheConfig,
    policy: Box<dyn CachePolicy>,
    schedule_cache: Option<(usize, DdimSchedule)>,
}

impl<'m> DenoiseEngine<'m> {
    pub fn new(model: &'m DitModel, fc: FastCacheConfig) -> DenoiseEngine<'m> {
        let policy = build_policy(&fc, model.cfg.layers);
        DenoiseEngine { model, fc, policy, schedule_cache: None }
    }

    /// Replace the policy (used by L2C calibration flows).
    pub fn set_policy(&mut self, policy: Box<dyn CachePolicy>) {
        self.policy = policy;
    }

    fn schedule(&mut self, steps: usize) -> DdimSchedule {
        if let Some((s, sched)) = &self.schedule_cache {
            if *s == steps {
                return sched.clone();
            }
        }
        let sched = DdimSchedule::new(steps, 1000);
        self.schedule_cache = Some((steps, sched.clone()));
        sched
    }

    /// Build the conditioning vector for a request: unit-normalized random
    /// direction scaled by guidance/7.5 (substitution for CFG text
    /// conditioning — see DESIGN.md §2).
    pub fn make_cond(&self, req: &GenRequest) -> Vec<f32> {
        let d = self.model.cfg.d;
        let mut rng = Rng::new(req.cond_seed);
        let mut c = rng.normal_vec(d, 1.0);
        let norm = c.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
        let scale = (req.guidance / 7.5) * 0.5 / norm * (d as f32).sqrt();
        for v in c.iter_mut() {
            *v *= scale;
        }
        c
    }

    /// Run one full generation.
    pub fn generate(&mut self, req: &GenRequest) -> Result<GenResult> {
        let cfg = self.model.cfg;
        let (n, d, layers) = (cfg.n_tokens, cfg.d, cfg.layers);
        let schedule = self.schedule(req.steps);
        let cond = self.make_cond(req);

        let mut cache = CacheState::new(layers, d, self.fc.fit_decay);
        self.policy.reset();

        // Initial latent: pure noise (or the provided frame init).
        let mut x = match &req.init_latent {
            Some(t) => {
                assert_eq!(t.shape(), &[n, C_IN]);
                t.clone()
            }
            None => {
                let mut rng = Rng::new(req.seed);
                Tensor::new(rng.normal_vec(n * C_IN, 1.0), &[n, C_IN])
            }
        };
        let mut turb_rng = req.turbulence.as_ref().map(|t| Rng::new(t.seed));

        let mut records = Vec::with_capacity(req.steps);
        let mut computed = 0usize;
        let mut approximated = 0usize;
        let mut reused = 0usize;
        let mut token_sites_computed = 0u64;
        let mut token_sites_total = 0u64;
        let mut flops_done = 0u64;
        let mut flops_full = 0u64;
        let mut cache_bytes_peak = 0usize;

        let t0 = std::time::Instant::now();
        for step in 0..schedule.len() {
            let tval = schedule.timesteps[step];

            // Conditioning embedding c = temb(t) + cond.
            let mut c = self.model.temb(&[tval])?; // [1, D]
            for (cv, cd) in c.data_mut().iter_mut().zip(&cond) {
                *cv += cd;
            }

            // Embed latent -> hidden [N, D].
            let xb = x.clone().reshape(&[1, n, C_IN]);
            let h0 = self.model.embed(&xb)?.reshape(&[n, d]);

            // Step-level deltas for the step-granular policies.
            let temb_delta = cache
                .prev_temb
                .as_ref()
                .map(|p| native::delta_rel(&c, p))
                .unwrap_or(f64::INFINITY);
            let input_delta = cache
                .prev_embed
                .as_ref()
                .map(|p| native::delta_rel(&h0, p))
                .unwrap_or(f64::INFINITY);
            self.policy.begin_step(&StepInfo {
                step,
                num_steps: schedule.len(),
                temb_delta,
                input_delta,
            });

            // STR: motion/static partition on the embedded state.
            let part = if self.fc.enable_str {
                cache.prev_embed.as_ref().map(|p| partition(&h0, p, self.fc.tau_s))
            } else {
                None
            };
            let motion_idx: Option<Vec<usize>> = part.as_ref().map(tokens::pad_to_bucket);
            let motion_tokens = part.as_ref().map(|p| p.motion.len()).unwrap_or(n);

            cache.store_temb(c.clone());
            cache.store_embed(h0.clone());

            let mut h = h0;
            let mut delta_sum = 0.0f64;
            let mut delta_cnt = 0usize;
            let mut rec = StepRecord { step, n_tokens: n, motion_tokens, ..Default::default() };

            // Token-merge extension (Algorithm 2, S=2 stages): merge at the
            // midpoint, run the rest at the merged bucket, unpool at the end.
            let merge_at = if self.fc.enable_merge { layers / 2 } else { usize::MAX };
            let mut merge_ctx: Option<(tokens::MergeMap, Tensor)> = None;

            for l in 0..layers {
                if l == merge_at && l > 0 {
                    // Importance = spatial kNN density x temporal saliency.
                    let rho_sp = tokens::knn_density(&h, self.fc.knn_k.min(h.shape()[0] - 1));
                    let rho_tm: Vec<f32> = match cache.prev_input(l) {
                        Some(p) if p.shape() == h.shape() => tokens::temporal_saliency(&h, p),
                        _ => vec![0.0; h.shape()[0]],
                    };
                    let scores = tokens::importance(&rho_sp, &rho_tm, self.fc.merge_lambda);
                    let (merged, map) = tokens::local_ctm(&h, &scores, self.fc.merge_target);
                    merge_ctx = Some((map, h.clone())); // keep Z for fusion
                    h = merged;
                }

                let cur_n = h.shape()[0];
                let nd = cur_n * d;
                let delta = cache
                    .prev_input(l)
                    .filter(|p| p.shape() == h.shape())
                    .map(|p| native::delta_rel(&h, p));
                if let Some(dv) = delta {
                    delta_sum += dv;
                    delta_cnt += 1;
                }
                let action = self.policy.decide(&BlockCtx {
                    layer: l,
                    num_layers: layers,
                    step,
                    delta,
                    nd,
                });

                let full_block_flops = cfg.block_flops(cur_n);
                flops_full += full_block_flops;
                token_sites_total += cur_n as u64;

                let prev_h = h.clone();
                let h_next = match action {
                    BlockAction::Compute => {
                        rec.computed += 1;
                        computed += 1;
                        let out = match &motion_idx {
                            Some(idx) if idx.len() < cur_n && !idx.is_empty() && merge_ctx.is_none() => {
                                // Bucketed motion-token compute; static rows
                                // bypass through the learnable affine map.
                                let nb = idx.len();
                                let sub = h.gather_rows(idx);
                                let sub_b = sub.clone().reshape(&[1, nb, d]);
                                let out_sub =
                                    self.model.block(l, &sub_b, &c)?.reshape(&[nb, d]);
                                cache.fit_mut(l).update(&sub, &out_sub);
                                let mut out_full = cache.fit(l).apply(&h);
                                out_full.scatter_rows(idx, &out_sub);
                                flops_done += cfg.block_flops(nb)
                                    + cfg.approx_flops(cur_n - nb, false);
                                token_sites_computed += nb as u64;
                                out_full
                            }
                            _ => {
                                let hb = h.clone().reshape(&[1, cur_n, d]);
                                let out =
                                    self.model.block(l, &hb, &c)?.reshape(&[cur_n, d]);
                                cache.fit_mut(l).update(&h, &out);
                                flops_done += full_block_flops;
                                token_sites_computed += cur_n as u64;
                                out
                            }
                        };
                        if let Some(prev_out) = cache.prev_output(l) {
                            if prev_out.shape() == out.shape() {
                                self.policy.observe_output(l, native::delta_rel(&out, prev_out));
                            }
                        }
                        out
                    }
                    BlockAction::Approx => {
                        rec.approximated += 1;
                        approximated += 1;
                        flops_done += cfg.approx_flops(
                            cur_n,
                            self.fc.approx == ApproxMode::FullMatrix,
                        );
                        let approx = match self.fc.approx {
                            ApproxMode::FullMatrix => {
                                let (w, b) = cache.fit(l).to_full_matrix();
                                let hb = h.clone().reshape(&[1, cur_n, d]);
                                self.model
                                    .linear_approx_full(&hb, &w, &b)?
                                    .reshape(&[cur_n, d])
                            }
                            _ => cache.fit(l).apply(&h),
                        };
                        match cache.prev_output(l) {
                            Some(prev_out)
                                if self.fc.enable_mb && prev_out.shape() == approx.shape() =>
                            {
                                approx.lerp(prev_out, self.fc.gamma, 1.0 - self.fc.gamma)
                            }
                            _ => approx,
                        }
                    }
                    BlockAction::Reuse => {
                        rec.reused += 1;
                        reused += 1;
                        match cache.prev_output(l) {
                            Some(prev_out) if prev_out.shape() == h.shape() => prev_out.clone(),
                            _ => h.clone(),
                        }
                    }
                };
                cache.store_input(l, prev_h);
                cache.store_output(l, h_next.clone());
                h = h_next;
            }

            // Unpool + residual fusion if merged (Algorithm 2's MTA phase).
            if let Some((map, z)) = merge_ctx {
                let restored = tokens::unpool(&h, &map);
                h = restored.lerp(&z, 1.0, 1.0); // Unpool(H) + Z
            }

            rec.mean_delta = if delta_cnt > 0 { delta_sum / delta_cnt as f64 } else { 0.0 };
            records.push(rec);

            // Final projection + DDIM update.
            let hb = h.reshape(&[1, n, d]);
            let eps = self.model.final_layer(&hb, &c)?.reshape(&[n, C_IN]);
            schedule.update(step, x.data_mut(), eps.data());

            // Synthetic motion: re-noise the turbulent token rows.
            if let (Some(t), Some(rng)) = (&req.turbulence, &mut turb_rng) {
                for &i in &t.tokens {
                    for v in x.row_mut(i) {
                        *v += t.amp * rng.normal();
                    }
                }
            }

            cache_bytes_peak = cache_bytes_peak.max(cache.size_bytes());
        }
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

        Ok(GenResult {
            id: req.id,
            latent: x,
            cond,
            records,
            wall_ms,
            computed,
            approximated,
            reused,
            token_sites_computed,
            token_sites_total,
            flops_done,
            flops_full,
            cache_bytes_peak,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyKind, Variant};
    use crate::model::DitModel;

    fn run(policy: PolicyKind, steps: usize) -> GenResult {
        let model = DitModel::native(Variant::S, 7);
        let fc = FastCacheConfig::with_policy(policy);
        let mut eng = DenoiseEngine::new(&model, fc);
        eng.generate(&GenRequest::simple(1, 99, steps)).unwrap()
    }

    #[test]
    fn nocache_computes_every_site() {
        let r = run(PolicyKind::NoCache, 6);
        assert_eq!(r.computed, 6 * 3);
        assert_eq!(r.approximated + r.reused, 0);
        assert_eq!(r.flops_done, r.flops_full);
        assert!(r.latent.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fastcache_skips_some_blocks() {
        let r = run(PolicyKind::FastCache, 12);
        assert!(r.approximated > 0, "no approximations happened");
        assert!(r.computed > 0, "first step must compute");
        assert!(r.flops_done < r.flops_full);
        assert!(r.skip_ratio() > 0.0 && r.skip_ratio() < 1.0);
    }

    #[test]
    fn deterministic_generation() {
        let a = run(PolicyKind::FastCache, 5);
        let b = run(PolicyKind::FastCache, 5);
        assert_eq!(a.latent.data(), b.latent.data());
        assert_eq!(a.computed, b.computed);
    }

    #[test]
    fn fastcache_output_close_to_nocache() {
        // The whole point of bounded-error caching: the generated latent
        // stays near the full-compute trajectory.
        let full = run(PolicyKind::NoCache, 10);
        let fast = run(PolicyKind::FastCache, 10);
        let rel = {
            let diff: f64 = full
                .latent
                .data()
                .iter()
                .zip(fast.latent.data())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            let base: f64 = full
                .latent
                .data()
                .iter()
                .map(|v| (*v as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            diff / base.max(1e-9)
        };
        assert!(rel < 0.5, "relative deviation {rel}");
    }

    #[test]
    fn turbulence_increases_motion_ratio() {
        let model = DitModel::native(Variant::S, 7);
        let fc = FastCacheConfig::default();
        let mut eng = DenoiseEngine::new(&model, fc.clone());
        let calm = eng.generate(&GenRequest::simple(1, 3, 8)).unwrap();
        let mut req = GenRequest::simple(2, 3, 8);
        req.turbulence = Some(Turbulence { tokens: (0..24).collect(), amp: 1.0, seed: 5 });
        let mut eng2 = DenoiseEngine::new(&model, fc);
        let stormy = eng2.generate(&req).unwrap();
        let calm_motion: usize = calm.records.iter().map(|r| r.motion_tokens).sum();
        let stormy_motion: usize = stormy.records.iter().map(|r| r.motion_tokens).sum();
        assert!(
            stormy_motion > calm_motion,
            "turbulence should raise motion tokens: {stormy_motion} vs {calm_motion}"
        );
    }

    #[test]
    fn merge_path_runs_and_restores_resolution() {
        let model = DitModel::native(Variant::B, 7);
        let mut fc = FastCacheConfig::default();
        fc.enable_merge = true;
        fc.merge_target = 32;
        fc.enable_str = false;
        let mut eng = DenoiseEngine::new(&model, fc);
        let r = eng.generate(&GenRequest::simple(3, 11, 4)).unwrap();
        assert_eq!(r.latent.shape(), &[64, C_IN]);
        assert!(r.latent.data().iter().all(|v| v.is_finite()));
        // Merged layers ran at 32 tokens: token sites reflect that.
        assert!(r.token_sites_total < 4 * 6 * 64);
    }

    #[test]
    fn guidance_affects_conditioning_strength() {
        let model = DitModel::native(Variant::S, 7);
        let eng = DenoiseEngine::new(&model, FastCacheConfig::default());
        let mut lo = GenRequest::simple(1, 5, 4);
        lo.guidance = 1.0;
        let mut hi = GenRequest::simple(1, 5, 4);
        hi.guidance = 15.0;
        let cl = eng.make_cond(&lo);
        let ch = eng.make_cond(&hi);
        let nl: f32 = cl.iter().map(|v| v * v).sum::<f32>();
        let nh: f32 = ch.iter().map(|v| v * v).sum::<f32>();
        assert!(nh > nl * 9.0);
    }
}
