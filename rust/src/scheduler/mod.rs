//! Denoise scheduling: the DDIM schedule, the single-request engine
//! (Algorithm 1 + the Algorithm 2 token-merge extension), and the
//! step-aligned batched engine.

pub mod batch;
pub mod ddim;
pub mod engine;

pub use batch::BatchEngine;
pub use ddim::DdimSchedule;
pub use engine::{DenoiseEngine, GenRequest, GenResult, StepRecord, Turbulence};
