//! Denoise scheduling: the DDIM schedule, the unified lane-based stepper
//! (Algorithm 1 + the Algorithm 2 token-merge extension, executed once
//! for every serving mode), its two drivers — `DenoiseEngine`
//! (batch-of-one) and `BatchEngine` (lockstep batch) — and the
//! stepper-owned caches (schedules, memoized timestep embeddings). The
//! serving worker drives the stepper directly with continuous batching.

pub mod batch;
pub mod ddim;
pub mod engine;
pub mod lane;
pub mod temb;

pub use batch::BatchEngine;
pub use ddim::{DdimSchedule, ScheduleCache};
pub use engine::DenoiseEngine;
pub use lane::{GenRequest, GenResult, Lane, LaneStepper, StepRecord, Turbulence};
pub use temb::TembCache;
