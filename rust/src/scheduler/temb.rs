//! Memoized timestep-conditioning embeddings.
//!
//! `temb_forward(t)` is a pure function of `(t, variant, weight seed)` —
//! and a stepper serves exactly one (variant, seed) model — yet the old
//! loop recomputed it per lane per step (and re-dispatched it per step in
//! HLO mode). [`TembCache`] memoizes the [1, D] embedding per distinct
//! timestep value through the same byte-budgeted `LruBytes` primitive as
//! `ScheduleCache` and the warm store, so co-scheduled lanes — and
//! successive steps, and successive requests at the same step count —
//! share one evaluation. Owned by the `LaneStepper` (one per engine /
//! shard worker); lanes receive clones, so cached entries are never
//! aliased mutably.

use crate::store::lru::{LruBytes, LruCounters};
use crate::tensor::Tensor;

pub struct TembCache {
    lru: LruBytes<u32, Tensor>,
}

impl Default for TembCache {
    fn default() -> Self {
        TembCache::new()
    }
}

impl TembCache {
    /// Default byte budget: a [1, D] f32 embedding is ≤ ~1.2 KiB at
    /// DiT-XL width, so this comfortably holds the ~100 distinct
    /// timesteps of several coexisting schedules; rarely-used values are
    /// recomputed on demand instead of held forever.
    pub const DEFAULT_BUDGET_BYTES: usize = 128 * 1024;

    pub fn new() -> TembCache {
        TembCache::with_budget(Self::DEFAULT_BUDGET_BYTES)
    }

    pub fn with_budget(budget_bytes: usize) -> TembCache {
        TembCache { lru: LruBytes::new(budget_bytes) }
    }

    /// Cached embedding for a timestep value (keyed by its exact bit
    /// pattern). Counts a hit or a miss and refreshes recency.
    pub fn get(&mut self, t_bits: u32) -> Option<&Tensor> {
        self.lru.get(&t_bits)
    }

    /// Retain a freshly computed embedding (LRU-evicting within budget).
    pub fn insert(&mut self, t_bits: u32, temb: Tensor) {
        self.lru.insert(t_bits, temb);
    }

    pub fn used_bytes(&self) -> usize {
        self.lru.used_bytes()
    }

    pub fn budget_bytes(&self) -> usize {
        self.lru.budget()
    }

    pub fn len(&self) -> usize {
        self.lru.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lru.is_empty()
    }

    /// Hit/miss/eviction counters (same shape as every other cache's).
    pub fn counters(&self) -> LruCounters {
        self.lru.counters()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn emb(v: f32, d: usize) -> Tensor {
        Tensor::full(&[1, d], v)
    }

    #[test]
    fn memoizes_per_timestep_bits() {
        let mut c = TembCache::new();
        assert!(c.get(1.5f32.to_bits()).is_none());
        c.insert(1.5f32.to_bits(), emb(1.5, 8));
        let got = c.get(1.5f32.to_bits()).expect("hit");
        assert_eq!(got.shape(), &[1, 8]);
        assert!(c.get(2.5f32.to_bits()).is_none());
        let ct = c.counters();
        assert_eq!((ct.hits, ct.misses, ct.inserts), (1, 2, 1));
    }

    #[test]
    fn stays_within_byte_budget_under_flood() {
        let one = Tensor::full(&[1, 64], 0.0).size_bytes() + crate::store::lru::ENTRY_OVERHEAD;
        let mut c = TembCache::with_budget(4 * one);
        for i in 0..100u32 {
            c.insert((i as f32).to_bits(), emb(i as f32, 64));
            assert!(c.used_bytes() <= c.budget_bytes());
        }
        assert!(c.len() <= 4);
        assert!(c.counters().evictions > 0);
    }
}
