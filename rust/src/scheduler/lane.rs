//! The unified lane-based stepper — ONE denoise step loop shared by every
//! execution mode (single request, lockstep batch, continuous-batching
//! server).
//!
//! A [`Lane`] is the complete per-request denoise state: latent,
//! conditioning, `CacheState`, cache policy, turbulence RNG, and all the
//! bookkeeping the paper's tables report (block-site counters, token-site
//! ratios, FLOPs, cache bytes, per-lane active wall time). The
//! [`LaneStepper`] advances a *vector* of lanes by one denoise step: per
//! (step, layer) it collects each lane's `BlockAction`, batches the
//! full-token Compute lanes through the compiled B=4 block artifact
//! (chunked, padded when a group is smaller than 4), and routes
//! STR-bucketed, merged, Approx, and Reuse lanes through their per-lane
//! paths. Lanes at *different* step indices coexist in one call — that is
//! what makes continuous batching in `server::worker` possible.
//!
//! `DenoiseEngine` is the batch-of-one driver over this stepper and
//! `BatchEngine` the lockstep driver; neither owns a step/layer loop of
//! its own anymore, so Algorithm 1 (and the Algorithm 2 token-merge
//! extension) exist in exactly one place.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cache::{
    build_policy, AffineFit, BlockAction, BlockCtx, CachePolicy, CacheState, StepInfo,
};
use crate::config::{ApproxMode, FastCacheConfig, PolicyKind, C_IN};
use crate::faults::FaultPlan;
use crate::model::{native, DitModel, ScratchArena};
use crate::obs::{EventKind, StepObserver, TraceEvent, NON_LAYER};
use crate::rng::Rng;
use crate::store::lru::LruCounters;
use crate::tensor::Tensor;
use crate::tokens::{self, partition};

use super::ddim::DdimSchedule;
use super::temb::TembCache;

/// Turbulence: per-step re-noising of selected token rows — the synthetic
/// stand-in for high-motion content regions (DESIGN.md §2): those tokens
/// keep changing between steps, so a content-aware cache must recompute
/// them while the rest of the latent settles.
#[derive(Clone, Debug, PartialEq)]
pub struct Turbulence {
    pub tokens: Vec<usize>,
    pub amp: f32,
    pub seed: u64,
}

/// One generation request.
#[derive(Clone, Debug, PartialEq)]
pub struct GenRequest {
    pub id: u64,
    pub seed: u64,
    /// Conditioning seed (the "prompt"); drives the CLIP-proxy metric.
    pub cond_seed: u64,
    pub guidance: f32,
    pub steps: usize,
    pub turbulence: Option<Turbulence>,
    /// Optional initial latent (video frames share correlated inits).
    pub init_latent: Option<Tensor>,
    /// Optional SLA deadline in ms from submission. `None` = best-effort.
    /// The sharded server admits deadline-tagged jobs ahead of best-effort
    /// ones at step boundaries and reports per-class deadline-hit rates.
    pub deadline_ms: Option<f64>,
}

impl GenRequest {
    /// Start building a request. `id` and `seed` are the only mandatory
    /// fields; everything else has a production default (cond seed
    /// derived from the latent seed, guidance 7.5, 50 steps). Validation
    /// happens once, at [`GenRequestBuilder::build`] — the same checks
    /// guard the in-process path and the wire decoder.
    pub fn builder(id: u64, seed: u64) -> GenRequestBuilder {
        GenRequestBuilder {
            id,
            seed,
            cond_seed: seed ^ 0xC04D,
            guidance: 7.5,
            steps: 50,
            turbulence: None,
            init_latent: None,
            deadline_ms: None,
        }
    }

    /// Re-open a built request for modification (re-validated at the
    /// next `build()`).
    pub fn into_builder(self) -> GenRequestBuilder {
        GenRequestBuilder {
            id: self.id,
            seed: self.seed,
            cond_seed: self.cond_seed,
            guidance: self.guidance,
            steps: self.steps,
            turbulence: self.turbulence,
            init_latent: self.init_latent,
            deadline_ms: self.deadline_ms,
        }
    }

}

/// Builder for [`GenRequest`] — the ONE place request validation lives.
/// Both transports construct requests through it: in-process callers
/// directly, and the wire decoder when it rebuilds a request from a
/// `Submit` frame (so a malformed remote request is rejected with the
/// same `BadRequest` a local caller would get).
#[derive(Clone, Debug)]
pub struct GenRequestBuilder {
    id: u64,
    seed: u64,
    cond_seed: u64,
    guidance: f32,
    steps: usize,
    turbulence: Option<Turbulence>,
    init_latent: Option<Tensor>,
    deadline_ms: Option<f64>,
}

/// Bounds enforced by [`GenRequestBuilder::build`]. Public so the wire
/// protocol docs and tests reference the same numbers.
pub const MAX_STEPS: usize = 4096;
pub const MAX_GUIDANCE: f32 = 100.0;

impl GenRequestBuilder {
    /// Number of denoise steps (1..=[`MAX_STEPS`]).
    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    /// Conditioning seed (the "prompt"). Defaults to `seed ^ 0xC04D`.
    pub fn cond_seed(mut self, cond_seed: u64) -> Self {
        self.cond_seed = cond_seed;
        self
    }

    /// CFG guidance scale (finite, 0..=[`MAX_GUIDANCE`]).
    pub fn guidance(mut self, guidance: f32) -> Self {
        self.guidance = guidance;
        self
    }

    /// SLA deadline in ms from submission (finite, >= 0).
    pub fn deadline_ms(mut self, ms: f64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// Remove any deadline (back to best-effort).
    pub fn best_effort(mut self) -> Self {
        self.deadline_ms = None;
        self
    }

    /// Per-step re-noising of selected token rows (synthetic motion).
    pub fn turbulence(mut self, t: Turbulence) -> Self {
        self.turbulence = Some(t);
        self
    }

    /// Initial latent (video frames share correlated inits). Must be
    /// shaped `[N_TOKENS, C_IN]`.
    pub fn init_latent(mut self, t: Tensor) -> Self {
        self.init_latent = Some(t);
        self
    }

    /// Validate and construct. Every rejection is a typed
    /// `BadRequest` carrying the offending field in its detail string.
    pub fn build(self) -> Result<GenRequest, crate::api::Reject> {
        use crate::config::N_TOKENS;
        let id = self.id;
        let bad = move |detail: String| Err(crate::api::Reject::bad_request(id, detail));
        if self.steps == 0 || self.steps > MAX_STEPS {
            return bad(format!("steps must be 1..={MAX_STEPS}, got {}", self.steps));
        }
        if !self.guidance.is_finite() || !(0.0..=MAX_GUIDANCE).contains(&self.guidance) {
            return bad(format!(
                "guidance must be finite in 0..={MAX_GUIDANCE}, got {}",
                self.guidance
            ));
        }
        if let Some(ms) = self.deadline_ms {
            if !ms.is_finite() || ms < 0.0 {
                return bad(format!("deadline_ms must be finite and >= 0, got {ms}"));
            }
        }
        if let Some(t) = &self.turbulence {
            if !t.amp.is_finite() {
                return bad(format!("turbulence amp must be finite, got {}", t.amp));
            }
            if let Some(&tok) = t.tokens.iter().find(|&&tok| tok >= N_TOKENS) {
                return bad(format!("turbulence token {tok} out of range (< {N_TOKENS})"));
            }
        }
        if let Some(t) = &self.init_latent {
            if t.shape() != [N_TOKENS, C_IN] {
                return bad(format!(
                    "init_latent must be [{N_TOKENS}, {C_IN}], got {:?}",
                    t.shape()
                ));
            }
        }
        Ok(GenRequest {
            id: self.id,
            seed: self.seed,
            cond_seed: self.cond_seed,
            guidance: self.guidance,
            steps: self.steps,
            turbulence: self.turbulence,
            init_latent: self.init_latent,
            deadline_ms: self.deadline_ms,
        })
    }
}

/// Per-step execution record (drives Fig. 1/3 style analyses).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepRecord {
    pub step: usize,
    pub computed: usize,
    pub approximated: usize,
    pub reused: usize,
    pub motion_tokens: usize,
    pub n_tokens: usize,
    pub mean_delta: f64,
}

/// Result of one full generation.
#[derive(Debug)]
pub struct GenResult {
    pub id: u64,
    /// Final denoised latent [N, C].
    pub latent: Tensor,
    /// Conditioning vector used (for the CLIP-proxy metric).
    pub cond: Vec<f32>,
    pub records: Vec<StepRecord>,
    /// Per-lane ACTIVE wall time: the time this request actually occupied
    /// the worker, with batched block calls split evenly across the lanes
    /// sharing them. Lanes in a batch no longer all report the whole
    /// group's wall clock.
    pub wall_ms: f64,
    /// Block-site actions over the whole generation.
    pub computed: usize,
    pub approximated: usize,
    pub reused: usize,
    /// Token-site accounting: computed token-sites vs total token-sites
    /// (Tab. 5's static/dynamic ratios are derived from these).
    pub token_sites_computed: u64,
    pub token_sites_total: u64,
    /// FLOPs actually executed vs the NoCache-equivalent total.
    pub flops_done: u64,
    pub flops_full: u64,
    /// FLOPs burnt in padded B=4 batch slots on this lane's behalf
    /// (serving overhead; NOT included in `flops_done`).
    pub flops_padded: u64,
    /// Peak cache-state bytes held for this request.
    pub cache_bytes_peak: usize,
    /// Layers whose affine fit was warm-started from the cross-request
    /// store at admission (0 on the cold path / with warm-start off).
    pub warm_layers: usize,
    /// Whether the degrade ladder touched this lane (deadline pressure
    /// relaxed its cache threshold, tightened STR, or truncated steps).
    /// Always `false` for best-effort lanes and with the ladder off.
    pub degraded: bool,
    /// How many degrade rungs were applied (0 when `!degraded`).
    pub degrade_rungs: u32,
}

impl GenResult {
    pub fn skip_ratio(&self) -> f64 {
        let total = self.computed + self.approximated + self.reused;
        if total == 0 {
            0.0
        } else {
            (self.approximated + self.reused) as f64 / total as f64
        }
    }

    /// Fraction of token-sites NOT computed (the paper's "static ratio").
    pub fn static_ratio(&self) -> f64 {
        if self.token_sites_total == 0 {
            0.0
        } else {
            1.0 - self.token_sites_computed as f64 / self.token_sites_total as f64
        }
    }

    pub fn flops_ratio(&self) -> f64 {
        if self.flops_full == 0 {
            1.0
        } else {
            self.flops_done as f64 / self.flops_full as f64
        }
    }

}

/// Build the conditioning vector for a request: unit-normalized random
/// direction scaled by guidance/7.5 (substitution for CFG text
/// conditioning — see DESIGN.md §2).
pub fn make_cond(d: usize, req: &GenRequest) -> Vec<f32> {
    let mut rng = Rng::new(req.cond_seed);
    let mut c = rng.normal_vec(d, 1.0);
    let norm = c.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
    let scale = (req.guidance / 7.5) * 0.5 / norm * (d as f32).sqrt();
    for v in c.iter_mut() {
        *v *= scale;
    }
    c
}

/// All per-request denoise state, advanced one step at a time by the
/// [`LaneStepper`]. Block-site counters live in `cache.counters`
/// (`CacheCounters`), the canonical per-request tally.
pub struct Lane {
    req: GenRequest,
    cond: Vec<f32>,
    x: Tensor,
    schedule: Arc<DdimSchedule>,
    cache: CacheState,
    policy: Box<dyn CachePolicy>,
    turb_rng: Option<Rng>,
    step: usize,
    records: Vec<StepRecord>,
    token_sites_computed: u64,
    token_sites_total: u64,
    flops_done: u64,
    flops_full: u64,
    flops_padded: u64,
    cache_bytes_peak: usize,
    active: Duration,
    /// Full-compute cost of one denoise step at full tokens (layers ×
    /// block FLOPs) — the unit of the remaining-work prediction below.
    full_step_flops: u64,
    /// Layers warm-started from the cross-request store at admission.
    warm_layers: usize,
    /// Observed per-(step, layer) relative deltas (+∞ = no evidence at
    /// that site), recorded only when warm-start is on; retiring lanes
    /// publish this into the fleet profile.
    delta_log: Option<Vec<Vec<f64>>>,
    /// Recycled per-lane output buffer: block kernels write into it,
    /// then it rotates through the cache's input slot and back — so the
    /// steady-state compute path allocates nothing. Persisted across
    /// steps (rebuilding it per step would re-allocate at layer 0).
    scratch_out: Tensor,
    /// Whether the flight recorder sampled this lane: decided once at
    /// lane construction from the request id, so a lane records every
    /// event of its lifetime or none. Pure observation — no decision
    /// path ever reads it.
    traced: bool,
    /// Degrade rung 2: the stepper's STR partition uses this tau_s
    /// instead of the config's when set (a larger value keeps fewer
    /// motion tokens). Only the server's degrade ladder ever sets it.
    tau_s_override: Option<f64>,
    /// Degrade rung 3: the lane finishes at this step index instead of
    /// `schedule.len()` (always clamped to the schedule).
    step_limit: Option<usize>,
    /// How many degrade rungs have been applied to this lane.
    degrade_rungs: u8,
}

impl Lane {
    pub fn id(&self) -> u64 {
        self.req.id
    }

    /// The lane's SLA deadline budget (ms from submission), if tagged.
    pub fn deadline_ms(&self) -> Option<f64> {
        self.req.deadline_ms
    }

    /// Predicted FLOPs still ahead of this lane: remaining steps × the
    /// FLOPs this lane has actually *executed* per completed step (full
    /// per-step cost before any step has run). Using executed FLOPs —
    /// not a skip ratio against `flops_full` — captures every source of
    /// per-request compute shift: cache skips (Learning-to-Cache /
    /// SmoothCache-style schedules) AND token reduction (STR buckets,
    /// token merge), where both numerator and denominator of a ratio
    /// would shrink together and cancel the saving. The sharded
    /// dispatcher balances on this estimate, not lane counts.
    pub fn remaining_flops_estimate(&self) -> u64 {
        let rem = self.effective_steps().saturating_sub(self.step) as u64;
        if self.step == 0 {
            return rem * self.full_step_flops;
        }
        let per_step = self.flops_done / self.step as u64;
        rem * per_step.min(self.full_step_flops)
    }

    /// The next step this lane will execute (0-based).
    pub fn step_index(&self) -> usize {
        self.step
    }

    pub fn total_steps(&self) -> usize {
        self.schedule.len()
    }

    /// Steps this lane will actually run: the schedule length, unless
    /// degrade rung 3 truncated it.
    pub fn effective_steps(&self) -> usize {
        self.step_limit.map_or(self.schedule.len(), |l| l.min(self.schedule.len()))
    }

    pub fn is_done(&self) -> bool {
        self.step >= self.effective_steps()
    }

    /// FLOPs this lane has actually executed so far.
    pub fn flops_done(&self) -> u64 {
        self.flops_done
    }

    /// ACTIVE wall time this lane has occupied the worker so far (ms).
    pub fn active_ms(&self) -> f64 {
        self.active.as_secs_f64() * 1e3
    }

    /// Degrade rungs applied so far (0 = untouched).
    pub fn degrade_rungs(&self) -> u32 {
        self.degrade_rungs as u32
    }

    /// Degrade rung 1: relax the cache policy's skip threshold by
    /// `factor` (> 1.0 = more permissive — more Approx/Reuse decisions,
    /// fewer FLOPs). Policies without a tunable threshold ignore it;
    /// the rung is still recorded so accounting stays honest.
    pub fn degrade_relax_policy(&mut self, factor: f64) {
        self.policy.relax(factor);
        self.degrade_rungs = self.degrade_rungs.saturating_add(1);
    }

    /// Degrade rung 2: tighten the STR keep-ratio by raising the
    /// motion/static partition threshold to `tau_s` (more tokens ride
    /// the static bypass). No-op on the decision path when STR is off —
    /// the stepper only reads the override where it reads `fc.tau_s`.
    pub fn degrade_tighten_str(&mut self, tau_s: f64) {
        self.tau_s_override = Some(tau_s);
        self.degrade_rungs = self.degrade_rungs.saturating_add(1);
    }

    /// Degrade rung 3: truncate the lane to at most `remaining` more
    /// steps (floored at one — a lane always runs at least one more
    /// step so its latent reflects SOME denoising past this point).
    pub fn degrade_truncate_steps(&mut self, remaining: usize) {
        let limit = (self.step + remaining.max(1)).min(self.schedule.len());
        self.step_limit = Some(limit);
        self.degrade_rungs = self.degrade_rungs.saturating_add(1);
    }

    /// Whether the flight recorder sampled this lane at construction.
    pub fn traced(&self) -> bool {
        self.traced
    }

    /// Adopt warm fits from the cross-request store, one slot per layer
    /// (`None` = store miss, layer stays cold). Only legal at admission —
    /// the imported fits are a snapshot, so an in-flight lane never
    /// observes store mutations. A fit whose dimension does not match
    /// this lane's model is skipped (stale store entry from a
    /// mis-fingerprinted server must degrade to a cold layer, not panic
    /// the shard). Returns the number of layers warmed.
    pub fn warm_start_fits(&mut self, warm: &[Option<AffineFit>]) -> usize {
        assert_eq!(self.step, 0, "warm-start is admission-only (snapshot semantics)");
        assert_eq!(warm.len(), self.cache.num_layers(), "one warm slot per layer");
        let mut n = 0;
        for (l, w) in warm.iter().enumerate() {
            if let Some(f) = w {
                if f.d() != self.cache.fit(l).d() {
                    continue;
                }
                self.cache.fit_mut(l).adopt(f);
                n += 1;
            }
        }
        self.warm_layers = n;
        n
    }

    /// Per-layer fits that saw at least `min_updates` updates — what a
    /// retiring lane publishes back to the store. In warm-start mode
    /// these are the lane's FRESH accumulators (its own evidence only),
    /// so an adopted fleet fit is never echoed back into the store.
    pub fn converged_fits(&self, min_updates: u64) -> Vec<(usize, &AffineFit)> {
        self.cache
            .publishable_fits()
            .iter()
            .enumerate()
            .filter(|(_, f)| f.updates() >= min_updates)
            .collect()
    }

    /// The observed per-(step, layer) delta log (`None` unless warm-start
    /// recording was on). Complete only once the lane is done.
    pub fn delta_log(&self) -> Option<&[Vec<f64>]> {
        self.delta_log.as_deref()
    }

    pub fn into_result(self) -> GenResult {
        self.finish().0
    }

    /// Consume the lane, returning the result AND the policy (so a caller
    /// that installed a custom policy can keep it across requests).
    pub fn finish(self) -> (GenResult, Box<dyn CachePolicy>) {
        let Lane {
            req,
            cond,
            x,
            cache,
            policy,
            records,
            token_sites_computed,
            token_sites_total,
            flops_done,
            flops_full,
            flops_padded,
            cache_bytes_peak,
            active,
            warm_layers,
            degrade_rungs,
            ..
        } = self;
        let counters = cache.counters;
        (
            GenResult {
                id: req.id,
                latent: x,
                cond,
                records,
                wall_ms: active.as_secs_f64() * 1e3,
                computed: counters.computed,
                approximated: counters.approximated,
                reused: counters.reused,
                token_sites_computed,
                token_sites_total,
                flops_done,
                flops_full,
                flops_padded,
                cache_bytes_peak,
                warm_layers,
                degraded: degrade_rungs > 0,
                degrade_rungs: degrade_rungs as u32,
            },
            policy,
        )
    }
}

/// Per-lane transient state of the step currently being executed.
struct StepCtx {
    /// Current hidden state [cur_n, D] (cur_n shrinks when merged).
    h: Tensor,
    /// Conditioning embedding [1, D].
    c: Tensor,
    /// The lane's recycled output buffer (borrowed from the lane for the
    /// duration of the step, returned in the epilogue).
    out: Tensor,
    /// STR bucket index set (None without STR / before the first step).
    motion_idx: Option<Vec<usize>>,
    /// Token-merge context: (merge map, pre-merge Z for residual fusion).
    merge: Option<(tokens::MergeMap, Tensor)>,
    rec: StepRecord,
    delta_sum: f64,
    delta_cnt: usize,
}

/// The unified stepper: one model + one config, advancing any set of lanes
/// (possibly at different step indices) by one denoise step per call.
/// Owns the kernel scratch arena (zero per-block-call allocations on the
/// steady-state native path; high-water mark surfaces in `ServerReport`)
/// and the memoized timestep-embedding cache co-scheduled lanes share.
pub struct LaneStepper<'m> {
    model: &'m DitModel,
    fc: FastCacheConfig,
    arena: ScratchArena,
    temb: TembCache,
    /// Telemetry sink (decision counters + optional flight recorder).
    /// `None` outside the server — engines and tests step unobserved.
    /// Observation is strictly one-way: the stepper writes, never reads.
    obs: Option<StepObserver>,
    /// Fault-injection hook: `(shard id, plan)`. `None` (the default,
    /// and always outside chaos runs) costs one Option check per
    /// (lane, layer) site and can never fire.
    faults: Option<(u32, Arc<FaultPlan>)>,
}

impl<'m> LaneStepper<'m> {
    pub fn new(model: &'m DitModel, fc: FastCacheConfig) -> LaneStepper<'m> {
        LaneStepper::with_threads(model, fc, 1)
    }

    /// A stepper whose kernel calls split each block's token dimension
    /// across `threads` intra-op workers (1 = serial). Results are
    /// bit-identical at any setting (rust/tests/threaded_parity.rs);
    /// only wall-clock changes. The shard loop sizes this from
    /// `ServerConfig::effective_threads`.
    pub fn with_threads(
        model: &'m DitModel,
        fc: FastCacheConfig,
        threads: usize,
    ) -> LaneStepper<'m> {
        let mut arena = ScratchArena::new();
        arena.set_threads(threads);
        LaneStepper { model, fc, arena, temb: TembCache::new(), obs: None, faults: None }
    }

    /// Attach a telemetry observer (the shard loop installs one).
    /// Counters record for every lane; trace events only for lanes the
    /// recorder sampled at construction.
    pub fn set_observer(&mut self, obs: StepObserver) {
        self.obs = Some(obs);
    }

    /// Detach the telemetry observer (the shard's replay recovery steps
    /// unobserved so recovered work is never double-counted).
    pub fn take_observer(&mut self) -> Option<StepObserver> {
        self.obs.take()
    }

    /// Arm deterministic fault injection for this stepper (chaos runs
    /// only — a stepper without a plan has no injection path).
    pub fn set_fault_plan(&mut self, shard: u32, plan: Arc<FaultPlan>) {
        self.faults = Some((shard, plan));
    }

    pub fn model(&self) -> &'m DitModel {
        self.model
    }

    pub fn fc(&self) -> &FastCacheConfig {
        &self.fc
    }

    /// Kernel-scratch high-water mark in bytes. Stabilizes after the
    /// first step at a given shape envelope — asserted in tests, and
    /// reported per shard by the server.
    pub fn scratch_high_water_bytes(&self) -> usize {
        self.arena.high_water_bytes()
    }

    /// Hit/miss counters of the memoized timestep-embedding cache.
    pub fn temb_cache_counters(&self) -> LruCounters {
        self.temb.counters()
    }

    /// Build a lane with the config's policy.
    pub fn make_lane(&self, req: &GenRequest, schedule: Arc<DdimSchedule>) -> Lane {
        let policy = build_policy(&self.fc, self.model.cfg.layers);
        self.lane_with_policy(req, schedule, policy)
    }

    /// Build a lane around a caller-supplied policy (L2C calibration
    /// flows). The policy is reset before first use.
    pub fn lane_with_policy(
        &self,
        req: &GenRequest,
        schedule: Arc<DdimSchedule>,
        mut policy: Box<dyn CachePolicy>,
    ) -> Lane {
        let cfg = self.model.cfg;
        policy.reset();
        let cond = make_cond(cfg.d, req);
        let x = match &req.init_latent {
            Some(t) => {
                assert_eq!(t.shape(), &[cfg.n_tokens, C_IN]);
                t.clone()
            }
            None => {
                let mut rng = Rng::new(req.seed);
                Tensor::new(rng.normal_vec(cfg.n_tokens * C_IN, 1.0), &[cfg.n_tokens, C_IN])
            }
        };
        // Delta recording feeds the fleet profile. Only the calibration-
        // hungry schedule policies (L2C) ever READ profiles, so only
        // their lanes pay for recording — a FastCache fleet would
        // otherwise fill the store's byte budget with profile entries no
        // admission path looks up, evicting the fits that are the actual
        // warm-start win. Fresh-evidence fit accumulators are the
        // warm-publish side: a lane publishes its own rows only, never
        // the adopted fleet statistics.
        let records_profile = self.fc.warm_start && self.fc.policy == PolicyKind::L2C;
        let delta_log = if records_profile {
            Some(vec![vec![f64::INFINITY; cfg.layers]; schedule.len()])
        } else {
            None
        };
        let mut cache = CacheState::new(cfg.layers, cfg.d, self.fc.fit_decay);
        if self.fc.warm_start {
            cache.enable_fresh_fits(cfg.d, self.fc.fit_decay);
        }
        Lane {
            turb_rng: req.turbulence.as_ref().map(|t| Rng::new(t.seed)),
            cache,
            policy,
            cond,
            x,
            schedule,
            req: req.clone(),
            step: 0,
            records: Vec::new(),
            token_sites_computed: 0,
            token_sites_total: 0,
            flops_done: 0,
            flops_full: 0,
            flops_padded: 0,
            cache_bytes_peak: 0,
            active: Duration::ZERO,
            full_step_flops: cfg.full_step_flops(),
            warm_layers: 0,
            delta_log,
            scratch_out: Tensor::empty(),
            traced: self
                .obs
                .as_ref()
                .and_then(|o| o.recorder.as_deref())
                .is_some_and(|r| r.sampled(req.id)),
            tau_s_override: None,
            step_limit: None,
            degrade_rungs: 0,
        }
    }

    /// Advance every lane by ONE denoise step (its own step index). Per
    /// layer, full-token Compute lanes are batched through the B=4 block
    /// artifact in chunks; everything else runs its per-lane path exactly
    /// as the single-request loop always did.
    pub fn step(&mut self, lanes: &mut [Lane]) -> Result<()> {
        let Self { model, fc, arena, temb, obs, faults } = &mut *self;
        let model: &DitModel = model;
        let obs = obs.as_ref();
        let faults = faults.as_ref();
        let cfg = model.cfg;
        let (n, d, layers) = (cfg.n_tokens, cfg.d, cfg.layers);
        let nl = lanes.len();
        if nl == 0 {
            return Ok(());
        }
        assert!(
            lanes.iter().all(|l| !l.is_done()),
            "stepping a finished lane — retire lanes before calling step()"
        );
        // Telemetry for this call, batched into locals and flushed once
        // at the end — the hot loops touch no atomics. The "step" stage
        // span needs a timestamp in the recorder's timebase.
        let step_t0 = Instant::now();
        let step_ts = obs.and_then(|o| o.recorder.as_deref()).map(|r| r.now_us());
        let mut dec = [0u64; 3];
        let mut str_motion = 0u64;
        let mut str_static = 0u64;

        // ---- Step prologue, per lane: temb + embed + policy + STR. ----
        // temb(t) is pure in (t, variant, weight seed), so the stepper's
        // LRU memo shares one evaluation across co-scheduled lanes AND
        // across steps/requests (in HLO mode each temb is a device
        // dispatch — don't repeat it at all).
        let mut ctxs: Vec<StepCtx> = Vec::with_capacity(nl);
        for lane in lanes.iter_mut() {
            let t0 = Instant::now();
            let step = lane.step;
            // Injected step stall (chaos runs only): a bounded busy-wait
            // simulating a wedged — not panicking — kernel at this
            // (shard, step) site. The shard's heartbeat stops advancing
            // while we spin, which is exactly what the stuck-step
            // watchdog must detect; the wait is bounded so the stalled
            // thread can return and be supervised back to health.
            if let Some((shard, plan)) = faults {
                if let Some(ms) = plan.armed_stall(*shard, step) {
                    let until = Instant::now() + Duration::from_millis(ms);
                    while Instant::now() < until {
                        std::hint::spin_loop();
                    }
                }
            }
            let tval = lane.schedule.timesteps[step];

            // Conditioning embedding c = temb(t) + cond.
            let bits = tval.to_bits();
            let mut c = match temb.get(bits) {
                Some(t) => t.clone(),
                None => {
                    let t = model.temb(&[tval])?; // [1, D]
                    temb.insert(bits, t.clone());
                    t
                }
            };
            for (cv, cd) in c.data_mut().iter_mut().zip(&lane.cond) {
                *cv += cd;
            }

            // Embed latent -> hidden [N, D].
            let xb = lane.x.clone().reshape(&[1, n, C_IN]);
            let h0 = model.embed(&xb)?.reshape(&[n, d]);

            // Step-level deltas for the step-granular policies.
            let temb_delta = lane
                .cache
                .prev_temb
                .as_ref()
                .map(|p| native::delta_rel(&c, p))
                .unwrap_or(f64::INFINITY);
            let input_delta = lane
                .cache
                .prev_embed
                .as_ref()
                .map(|p| native::delta_rel(&h0, p))
                .unwrap_or(f64::INFINITY);
            lane.policy.begin_step(&StepInfo {
                step,
                num_steps: lane.schedule.len(),
                temb_delta,
                input_delta,
            });

            // STR: motion/static partition on the embedded state. The
            // degrade ladder's rung 2 overrides the threshold per lane.
            let tau_s = lane.tau_s_override.unwrap_or(fc.tau_s);
            let part = if fc.enable_str {
                lane.cache.prev_embed.as_ref().map(|p| partition(&h0, p, tau_s))
            } else {
                None
            };
            let motion_idx: Option<Vec<usize>> = part.as_ref().map(tokens::pad_to_bucket);
            let motion_tokens = part.as_ref().map(|p| p.motion.len()).unwrap_or(n);
            if part.is_some() {
                str_motion += motion_tokens as u64;
                str_static += (n - motion_tokens) as u64;
                if lane.traced {
                    if let Some(o) = obs {
                        if let Some(rec) = o.recorder.as_deref() {
                            rec.push(TraceEvent {
                                ts_us: rec.now_us(),
                                dur_us: 0,
                                shard: o.shard,
                                lane: lane.req.id,
                                step: step as u32,
                                layer: NON_LAYER,
                                kind: EventKind::StrPartition {
                                    motion_tokens: motion_tokens as u32,
                                    total_tokens: n as u32,
                                },
                            });
                        }
                    }
                }
            }

            lane.cache.store_temb_from(&c);
            lane.cache.store_embed_from(&h0);
            lane.active += t0.elapsed();

            ctxs.push(StepCtx {
                h: h0,
                c,
                out: std::mem::replace(&mut lane.scratch_out, Tensor::empty()),
                motion_idx,
                merge: None,
                rec: StepRecord { step, n_tokens: n, motion_tokens, ..Default::default() },
                delta_sum: 0.0,
                delta_cnt: 0,
            });
        }

        // Token-merge extension (Algorithm 2, S=2 stages): merge at the
        // midpoint, run the rest at the merged bucket, unpool at the end.
        let merge_at = if fc.enable_merge { layers / 2 } else { usize::MAX };

        // ---- The block stack, one layer at a time across all lanes. ----
        for l in 0..layers {
            // Per-lane: midpoint merge, delta, and the policy decision.
            let mut actions = Vec::with_capacity(nl);
            for (lane, ctx) in lanes.iter_mut().zip(ctxs.iter_mut()) {
                // Injected kernel panic (chaos runs only): unwinds out
                // of step() mid-layer, leaving lanes partially mutated —
                // exactly the state the shard's quarantine-and-replay
                // recovery must handle.
                if let Some((shard, plan)) = faults {
                    if let Some(shape) = plan.armed_panic(*shard, ctx.rec.step, l, lane.req.id)
                    {
                        shape.fire(lane.req.id);
                    }
                }
                let t0 = Instant::now();
                if l == merge_at && l > 0 {
                    // Importance = spatial kNN density x temporal saliency.
                    let rho_sp =
                        tokens::knn_density(&ctx.h, fc.knn_k.min(ctx.h.shape()[0] - 1));
                    let rho_tm: Vec<f32> = match lane.cache.prev_input(l) {
                        Some(p) if p.shape() == ctx.h.shape() => {
                            tokens::temporal_saliency(&ctx.h, p)
                        }
                        _ => vec![0.0; ctx.h.shape()[0]],
                    };
                    let scores = tokens::importance(&rho_sp, &rho_tm, fc.merge_lambda);
                    let (merged, map) = tokens::local_ctm(&ctx.h, &scores, fc.merge_target);
                    let z = std::mem::replace(&mut ctx.h, merged); // keep Z for fusion
                    ctx.merge = Some((map, z));
                }

                let cur_n = ctx.h.shape()[0];
                let delta = lane
                    .cache
                    .prev_input(l)
                    .filter(|p| p.shape() == ctx.h.shape())
                    .map(|p| native::delta_rel(&ctx.h, p));
                if let Some(dv) = delta {
                    ctx.delta_sum += dv;
                    ctx.delta_cnt += 1;
                }
                if let Some(log) = &mut lane.delta_log {
                    log[ctx.rec.step][l] = delta.unwrap_or(f64::INFINITY);
                }
                let mut action = lane.policy.decide(&BlockCtx {
                    layer: l,
                    num_layers: layers,
                    step: ctx.rec.step,
                    delta,
                    nd: cur_n * d,
                });
                // Fit-confidence gate: substituting an unconverged (near-
                // identity) fit is the cold-start quality leak warm-start
                // exists to close — with the gate on, a lane computes
                // until its fit has real evidence, so a warm-started lane
                // (whose adopted fits arrive converged) approximates
                // earlier and executes measurably fewer FLOPs. 0 = legacy
                // behavior, bit-identical to pre-gate serving.
                let mut downgraded = false;
                if action == BlockAction::Approx
                    && fc.fit_min_updates > 0
                    && lane.cache.fit(l).updates() < fc.fit_min_updates
                {
                    action = BlockAction::Compute;
                    downgraded = true;
                }
                lane.flops_full += cfg.block_flops(cur_n);
                lane.token_sites_total += cur_n as u64;
                lane.active += t0.elapsed();
                // Observation only, after the decision is final: count it,
                // and record the full decision context for traced lanes.
                dec[action as usize] += 1;
                if lane.traced {
                    if let Some(o) = obs {
                        if let Some(rec) = o.recorder.as_deref() {
                            rec.push(TraceEvent {
                                ts_us: rec.now_us(),
                                dur_us: 0,
                                shard: o.shard,
                                lane: lane.req.id,
                                step: ctx.rec.step as u32,
                                layer: l as u32,
                                kind: EventKind::Decision {
                                    action: action.name(),
                                    delta: delta.unwrap_or(f64::INFINITY),
                                    threshold: fc.tau_delta0,
                                    fit_updates: lane.cache.fit(l).updates(),
                                    downgraded,
                                },
                            });
                        }
                    }
                }
                actions.push(action);
            }

            // Which Compute lanes can share the B=4 block artifact:
            // full-token hidden, not merged, not on the STR bucketed path.
            let batchable: Vec<usize> = (0..nl)
                .filter(|&i| {
                    actions[i] == BlockAction::Compute
                        && ctxs[i].merge.is_none()
                        && ctxs[i].h.shape()[0] == n
                        && !matches!(&ctxs[i].motion_idx,
                                     Some(idx) if idx.len() < n && !idx.is_empty())
                })
                .collect();

            // Batched dispatch when >=2 lanes align; lone lanes fall back
            // to the per-lane B=1 path below.
            let mut outs: Vec<Option<Tensor>> = vec![None; nl];
            if batchable.len() >= 2 {
                const B: usize = 4;
                for group in batchable.chunks(B) {
                    if group.len() == 1 {
                        // Leftover lane of an odd chunking: let the apply
                        // loop's lone-compute path handle it at B=1 (one
                        // code path for all solo computes).
                        continue;
                    }
                    let t0 = Instant::now();
                    let mut hbatch = Vec::with_capacity(B * n * d);
                    let mut cbatch = Vec::with_capacity(B * d);
                    for slot in 0..B {
                        let li = group.get(slot).copied().unwrap_or(group[0]);
                        hbatch.extend_from_slice(ctxs[li].h.data());
                        cbatch.extend_from_slice(ctxs[li].c.data());
                    }
                    let hb = Tensor::new(hbatch, &[B, n, d]);
                    let cb = Tensor::new(cbatch, &[B, d]);
                    let out = model.block_with(l, &hb, &cb, arena)?;
                    for (slot, &li) in group.iter().enumerate() {
                        outs[li] = Some(Tensor::new(
                            out.data()[slot * n * d..(slot + 1) * n * d].to_vec(),
                            &[n, d],
                        ));
                    }
                    // Padded slots re-ran group[0]'s rows: real FLOPs with
                    // no owner — bill them evenly across the group, and
                    // split the group's wall time the same way.
                    let pad_flops = (B - group.len()) as u64 * cfg.block_flops(n);
                    let share = pad_flops / group.len() as u64;
                    let mut rem = pad_flops % group.len() as u64;
                    let dt = t0.elapsed() / group.len() as u32;
                    for &li in group {
                        let extra = if rem > 0 {
                            rem -= 1;
                            1
                        } else {
                            0
                        };
                        lanes[li].flops_padded += share + extra;
                        lanes[li].active += dt;
                    }
                }
            }

            // Apply per-lane results: batched outputs, bucketed STR
            // compute, lone compute, Approx, Reuse. The lone native
            // compute writes into the lane's recycled `ctx.out` buffer;
            // other paths hand back an owned tensor.
            for li in 0..nl {
                let lane = &mut lanes[li];
                let ctx = &mut ctxs[li];
                let t0 = Instant::now();
                let cur_n = ctx.h.shape()[0];
                lane.cache.counters.record(actions[li]);
                // `None` = the output landed in ctx.out (zero-alloc path).
                let mut owned: Option<Tensor> = None;
                match actions[li] {
                    BlockAction::Compute => {
                        ctx.rec.computed += 1;
                        if let Some(o) = outs[li].take() {
                            // Batched full-token compute.
                            lane.cache.observe_fit(l, &ctx.h, &o);
                            lane.flops_done += cfg.block_flops(cur_n);
                            lane.token_sites_computed += cur_n as u64;
                            owned = Some(o);
                        } else {
                            match &ctx.motion_idx {
                                Some(idx)
                                    if idx.len() < cur_n
                                        && !idx.is_empty()
                                        && ctx.merge.is_none() =>
                                {
                                    // Bucketed motion-token compute; static
                                    // rows bypass through the affine map.
                                    let nb = idx.len();
                                    let sub = ctx.h.gather_rows(idx);
                                    let sub_b = sub.clone().reshape(&[1, nb, d]);
                                    let out_sub = model
                                        .block_with(l, &sub_b, &ctx.c, arena)?
                                        .reshape(&[nb, d]);
                                    lane.cache.observe_fit(l, &sub, &out_sub);
                                    let mut out_full = lane.cache.fit(l).apply(&ctx.h);
                                    out_full.scatter_rows(idx, &out_sub);
                                    lane.flops_done += cfg.block_flops(nb)
                                        + cfg.approx_flops(cur_n - nb, false);
                                    lane.token_sites_computed += nb as u64;
                                    owned = Some(out_full);
                                }
                                _ if model.is_native() => {
                                    // Lone full-token (or merged-size)
                                    // compute — zero-allocation kernel
                                    // path into the recycled buffer.
                                    model.block_native_into(
                                        l, &ctx.h, ctx.c.data(), arena, &mut ctx.out,
                                    )?;
                                    lane.cache.observe_fit(l, &ctx.h, &ctx.out);
                                    lane.flops_done += cfg.block_flops(cur_n);
                                    lane.token_sites_computed += cur_n as u64;
                                }
                                _ => {
                                    // Lone compute through the HLO B=1
                                    // artifact.
                                    let hb = ctx.h.clone().reshape(&[1, cur_n, d]);
                                    let out =
                                        model.block(l, &hb, &ctx.c)?.reshape(&[cur_n, d]);
                                    lane.cache.observe_fit(l, &ctx.h, &out);
                                    lane.flops_done += cfg.block_flops(cur_n);
                                    lane.token_sites_computed += cur_n as u64;
                                    owned = Some(out);
                                }
                            }
                        }
                        let site_out = owned.as_ref().unwrap_or(&ctx.out);
                        let dv = match lane.cache.prev_output(l) {
                            Some(prev_out) if prev_out.shape() == site_out.shape() => {
                                Some(native::delta_rel(site_out, prev_out))
                            }
                            _ => None,
                        };
                        if let Some(dv) = dv {
                            lane.policy.observe_output(l, dv);
                        }
                    }
                    BlockAction::Approx => {
                        ctx.rec.approximated += 1;
                        lane.flops_done +=
                            cfg.approx_flops(cur_n, fc.approx == ApproxMode::FullMatrix);
                        let approx = match fc.approx {
                            ApproxMode::FullMatrix => {
                                let (w, b) = lane.cache.fit(l).to_full_matrix();
                                let hb = ctx.h.clone().reshape(&[1, cur_n, d]);
                                model.linear_approx_full(&hb, &w, &b)?.reshape(&[cur_n, d])
                            }
                            _ => lane.cache.fit(l).apply(&ctx.h),
                        };
                        owned = Some(match lane.cache.prev_output(l) {
                            Some(prev_out)
                                if fc.enable_mb && prev_out.shape() == approx.shape() =>
                            {
                                approx.lerp(prev_out, fc.gamma, 1.0 - fc.gamma)
                            }
                            _ => approx,
                        });
                    }
                    BlockAction::Reuse => {
                        ctx.rec.reused += 1;
                        owned = Some(match lane.cache.prev_output(l) {
                            Some(prev_out) if prev_out.shape() == ctx.h.shape() => {
                                prev_out.clone()
                            }
                            _ => ctx.h.clone(),
                        });
                    }
                }
                // Rotate, allocation-free on the steady-state path: the
                // pre-block hidden MOVES into the cache's input slot, the
                // output becomes ctx.h, and the slot's evicted tensor is
                // recycled as the next site's output buffer. Only the
                // output copy into the cache remains (into a same-shape
                // resident buffer, so it is a memcpy, not an allocation).
                let h_next = match owned {
                    Some(t) => t,
                    None => std::mem::replace(&mut ctx.out, Tensor::empty()),
                };
                let prev = std::mem::replace(&mut ctx.h, h_next);
                let recycled = lane.cache.swap_input(l, prev);
                if ctx.out.len() < recycled.len() {
                    ctx.out = recycled;
                }
                lane.cache.store_output_from(l, &ctx.h);
                lane.active += t0.elapsed();
            }
        }

        // ---- Step epilogue, per lane: unpool, final layer, DDIM. ----
        for (lane, ctx) in lanes.iter_mut().zip(ctxs.into_iter()) {
            let t0 = Instant::now();
            let StepCtx { mut h, c, out, merge, mut rec, delta_sum, delta_cnt, .. } = ctx;
            // Hand the recycled output buffer back to the lane for the
            // next step (so layer 0 of every step stays allocation-free).
            lane.scratch_out = out;

            // Unpool + residual fusion if merged (Algorithm 2's MTA phase).
            if let Some((map, z)) = merge {
                let restored = tokens::unpool(&h, &map);
                h = restored.lerp(&z, 1.0, 1.0); // Unpool(H) + Z
            }

            rec.mean_delta = if delta_cnt > 0 { delta_sum / delta_cnt as f64 } else { 0.0 };

            // Final projection + DDIM update (arena-backed in native mode).
            let hb = h.reshape(&[1, n, d]);
            let eps = model.final_layer_with(&hb, &c, arena)?.reshape(&[n, C_IN]);
            let sched = Arc::clone(&lane.schedule);
            sched.update(lane.step, lane.x.data_mut(), eps.data());

            // Synthetic motion: re-noise the turbulent token rows.
            if let (Some(t), Some(rng)) = (&lane.req.turbulence, &mut lane.turb_rng) {
                for &i in &t.tokens {
                    for v in lane.x.row_mut(i) {
                        *v += t.amp * rng.normal();
                    }
                }
            }

            lane.records.push(rec);
            lane.cache_bytes_peak = lane.cache_bytes_peak.max(lane.cache.size_bytes());
            lane.step += 1;
            lane.active += t0.elapsed();
        }

        // ---- Telemetry flush: one atomic add per series per call. ----
        if let Some(o) = obs {
            o.metrics.decisions_compute.add(dec[0]);
            o.metrics.decisions_approx.add(dec[1]);
            o.metrics.decisions_reuse.add(dec[2]);
            o.metrics.str_motion_tokens.add(str_motion);
            o.metrics.str_static_tokens.add(str_static);
            if let (Some(rec), Some(ts)) = (o.recorder.as_deref(), step_ts) {
                let dur_us = step_t0.elapsed().as_micros() as u64;
                for lane in lanes.iter() {
                    if lane.traced {
                        rec.push(TraceEvent {
                            ts_us: ts,
                            dur_us,
                            shard: o.shard,
                            lane: lane.req.id,
                            // `lane.step` was advanced in the epilogue;
                            // the span covers the step just executed.
                            step: (lane.step - 1) as u32,
                            layer: NON_LAYER,
                            kind: EventKind::Stage { stage: "step" },
                        });
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyKind, Variant};
    use crate::scheduler::ddim::ScheduleCache;

    #[test]
    fn lane_steps_to_completion() {
        let model = DitModel::native(Variant::S, 7);
        let mut stepper =
            LaneStepper::new(&model, FastCacheConfig::with_policy(PolicyKind::NoCache));
        let mut schedules = ScheduleCache::new();
        let mut lane = stepper.make_lane(&GenRequest::builder(1, 3).steps(5).build().unwrap(), schedules.get(5));
        assert_eq!(lane.total_steps(), 5);
        while !lane.is_done() {
            let before = lane.step_index();
            stepper.step(std::slice::from_mut(&mut lane)).unwrap();
            assert_eq!(lane.step_index(), before + 1);
        }
        let r = lane.into_result();
        assert_eq!(r.computed, 5 * model.cfg.layers);
        assert_eq!(r.flops_padded, 0, "single lane never pads");
        assert!(r.wall_ms > 0.0);
        assert!(r.latent.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn lanes_at_different_steps_coexist() {
        // Continuous batching's core property: one lane mid-flight, a new
        // lane admitted later, both stepped together, both finish clean.
        let model = DitModel::native(Variant::S, 7);
        let fc = FastCacheConfig::with_policy(PolicyKind::NoCache);
        let mut stepper = LaneStepper::new(&model, fc.clone());
        let mut schedules = ScheduleCache::new();

        let mut lanes =
            vec![stepper.make_lane(&GenRequest::builder(0, 21).steps(6).build().unwrap(), schedules.get(6))];
        stepper.step(&mut lanes).unwrap();
        stepper.step(&mut lanes).unwrap();
        lanes.push(stepper.make_lane(&GenRequest::builder(1, 22).steps(4).build().unwrap(), schedules.get(4)));
        for _ in 0..4 {
            stepper.step(&mut lanes).unwrap();
        }
        assert!(lanes.iter().all(|l| l.is_done()));

        // The mid-flight-joined lane matches a solo run exactly.
        let solo = {
            let mut l = stepper.make_lane(&GenRequest::builder(1, 22).steps(4).build().unwrap(), schedules.get(4));
            while !l.is_done() {
                stepper.step(std::slice::from_mut(&mut l)).unwrap();
            }
            l.into_result()
        };
        let joined = lanes.pop().unwrap().into_result();
        let md = joined.latent.max_abs_diff(&solo.latent);
        assert!(md < 1e-4, "joined-lane drift: {md}");
    }

    #[test]
    fn remaining_flops_estimate_shrinks_with_progress_and_caching() {
        let model = DitModel::native(Variant::S, 7);
        let mut schedules = ScheduleCache::new();

        // NoCache: before any step the estimate is the full budget; it
        // drains linearly and hits zero at completion.
        let mut stepper =
            LaneStepper::new(&model, FastCacheConfig::with_policy(PolicyKind::NoCache));
        let mut lane = stepper.make_lane(&GenRequest::builder(0, 3).steps(4).build().unwrap(), schedules.get(4));
        let full = lane.remaining_flops_estimate();
        assert_eq!(full, 4 * model.cfg.full_step_flops());
        stepper.step(std::slice::from_mut(&mut lane)).unwrap();
        assert_eq!(lane.remaining_flops_estimate(), full / 4 * 3);
        while !lane.is_done() {
            stepper.step(std::slice::from_mut(&mut lane)).unwrap();
        }
        assert_eq!(lane.remaining_flops_estimate(), 0);

        // A caching policy that skips work predicts LESS remaining work
        // than NoCache at the same step index.
        let mut cached =
            LaneStepper::new(&model, FastCacheConfig::with_policy(PolicyKind::StaticCache));
        let mut cl = cached.make_lane(&GenRequest::builder(1, 3).steps(8).build().unwrap(), schedules.get(8));
        let mut nl = stepper.make_lane(&GenRequest::builder(1, 3).steps(8).build().unwrap(), schedules.get(8));
        for _ in 0..4 {
            cached.step(std::slice::from_mut(&mut cl)).unwrap();
            stepper.step(std::slice::from_mut(&mut nl)).unwrap();
        }
        assert!(
            cl.remaining_flops_estimate() < nl.remaining_flops_estimate(),
            "cache policy should lower the predicted remaining work: {} vs {}",
            cl.remaining_flops_estimate(),
            nl.remaining_flops_estimate()
        );
    }

    #[test]
    fn cache_bytes_peak_matches_allocated_state() {
        // Across Compute/Approx/Reuse transitions the resident cache state
        // is the same set of tensors: per layer the previous step's input
        // and output [n, d], plus temb [1, d], embed [n, d], and the fit
        // statistics. `cache_bytes_peak` must equal exactly that — byte
        // accounting is what the store's budget math stands on.
        let model = DitModel::native(Variant::S, 7);
        let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        fc.enable_str = false;
        let mut stepper = LaneStepper::new(&model, fc);
        let mut schedules = ScheduleCache::new();
        let mut lane = stepper.make_lane(&GenRequest::builder(1, 3).steps(12).build().unwrap(), schedules.get(12));
        while !lane.is_done() {
            stepper.step(std::slice::from_mut(&mut lane)).unwrap();
        }
        let r = lane.into_result();
        assert!(r.computed > 0 && r.approximated > 0, "need action transitions");
        let (n, d, layers) = (model.cfg.n_tokens, model.cfg.d, model.cfg.layers);
        let f32s = std::mem::size_of::<f32>();
        let hidden_copies = 2 * layers * n * d * f32s; // prev_input + prev_output per layer
        let temb = d * f32s; // prev_temb [1, d]
        let embed = n * d * f32s; // prev_embed [n, d]
        let fit_stats = layers * d * 3 * 8;
        assert_eq!(r.cache_bytes_peak, hidden_copies + temb + embed + fit_stats);
        // The block path's only transient working set is the stepper's
        // arena — the per-call clones it replaced (the old residual copy
        // + normalized copy + q/k/v splits + logits + mod/hidden vecs)
        // are gone. Bill it: exactly the six kernel buffers
        // (csilu [d] + mod6 [6d] + xnorm [n,d] + qkv [n,3d] + attn [n,d]
        // + hidden [n,4d]), within allocator rounding.
        let arena_exact = (7 * d + 9 * n * d) * f32s;
        let hw = stepper.scratch_high_water_bytes();
        assert!(
            hw >= arena_exact && hw < arena_exact + 4096,
            "arena high-water {hw} should bill exactly the kernel buffers ({arena_exact})"
        );
    }

    #[test]
    fn warm_started_fits_cut_flops_under_confidence_gate() {
        // The tentpole's core mechanism at lane level: with the fit-
        // confidence gate on, a cold lane computes until each layer's fit
        // has seen `fit_min_updates` updates; a lane warm-started from a
        // retired lane's converged fits approximates from the first
        // skippable site and executes strictly fewer FLOPs.
        let model = DitModel::native(Variant::S, 7);
        let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        fc.enable_str = false;
        fc.warm_start = true;
        fc.fit_min_updates = 6;
        fc.tau_delta0 = 1.0; // permissive χ²: the gate is the binding constraint
        let mut stepper = LaneStepper::new(&model, fc);
        let mut schedules = ScheduleCache::new();
        let steps = 12;

        let mut cold = stepper.make_lane(&GenRequest::builder(0, 9).steps(steps).build().unwrap(), schedules.get(steps));
        while !cold.is_done() {
            stepper.step(std::slice::from_mut(&mut cold)).unwrap();
        }
        // Retirement: every layer computed ≥ 6 sites under the gate, so
        // every fit is publishable.
        let converged = cold.converged_fits(6);
        assert_eq!(converged.len(), model.cfg.layers);
        let mut warm_fits: Vec<Option<AffineFit>> = vec![None; model.cfg.layers];
        for (l, f) in converged {
            warm_fits[l] = Some(f.clone());
        }
        // FastCache lanes don't pay for profile recording (no policy
        // that reads profiles is running).
        assert!(cold.delta_log().is_none());
        let cold_r = cold.into_result();
        assert_eq!(cold_r.warm_layers, 0);

        let mut warm = stepper.make_lane(&GenRequest::builder(1, 9).steps(steps).build().unwrap(), schedules.get(steps));
        assert_eq!(warm.warm_start_fits(&warm_fits), model.cfg.layers);
        while !warm.is_done() {
            stepper.step(std::slice::from_mut(&mut warm)).unwrap();
        }
        let warm_r = warm.into_result();
        assert_eq!(warm_r.warm_layers, model.cfg.layers);
        assert!(
            warm_r.flops_done < cold_r.flops_done,
            "warm lane must execute fewer FLOPs: {} vs {}",
            warm_r.flops_done,
            cold_r.flops_done
        );
        assert!(warm_r.approximated > cold_r.approximated);
    }

    #[test]
    fn delta_log_records_only_for_profile_consumers() {
        // L2C is the policy that calibrates from fleet profiles, so only
        // its warm-start lanes record the per-(step, layer) delta log:
        // step 0 is cold (∞), later steps carry finite evidence.
        let model = DitModel::native(Variant::S, 7);
        let mut fc = FastCacheConfig::with_policy(PolicyKind::L2C);
        fc.warm_start = true;
        let mut stepper = LaneStepper::new(&model, fc);
        let mut schedules = ScheduleCache::new();
        let steps = 5;
        let mut lane = stepper.make_lane(&GenRequest::builder(0, 11).steps(steps).build().unwrap(), schedules.get(steps));
        while !lane.is_done() {
            stepper.step(std::slice::from_mut(&mut lane)).unwrap();
        }
        let log = lane.delta_log().expect("L2C warm lanes record deltas");
        assert_eq!(log.len(), steps);
        assert!(log[0].iter().all(|d| d.is_infinite()));
        assert!(log[1].iter().all(|d| d.is_finite()));
        // Warm-start off: nobody records, L2C or not.
        let off = LaneStepper::new(&model, FastCacheConfig::with_policy(PolicyKind::L2C));
        let lane = off.make_lane(&GenRequest::builder(1, 11).steps(steps).build().unwrap(), schedules.get(steps));
        assert!(lane.delta_log().is_none());
    }

    #[test]
    fn padded_slots_are_billed() {
        // 3 NoCache lanes => every (step, layer) site batches 3 lanes into
        // the B=4 artifact with one padded slot.
        let model = DitModel::native(Variant::S, 7);
        let mut stepper =
            LaneStepper::new(&model, FastCacheConfig::with_policy(PolicyKind::NoCache));
        let mut schedules = ScheduleCache::new();
        let steps = 3;
        let mut lanes: Vec<Lane> = (0..3)
            .map(|i| stepper.make_lane(&GenRequest::builder(i, 50 + i).steps(steps).build().unwrap(), schedules.get(steps)))
            .collect();
        for _ in 0..steps {
            stepper.step(&mut lanes).unwrap();
        }
        let total_padded: u64 =
            lanes.into_iter().map(|l| l.into_result().flops_padded).sum();
        let expected =
            (steps * model.cfg.layers) as u64 * model.cfg.block_flops(model.cfg.n_tokens);
        assert_eq!(total_padded, expected, "one padded slot per site");
    }

    #[test]
    fn scratch_high_water_stabilizes_after_first_step() {
        // The zero-allocation acceptance criterion: all kernel scratch
        // lives in the stepper's arena, which reaches its high-water
        // mark on the first step and never grows again — later steps
        // (including STR-bucketed sub-blocks, which are smaller) run
        // allocation-free.
        let model = DitModel::native(Variant::S, 7);
        let mut stepper =
            LaneStepper::new(&model, FastCacheConfig::with_policy(PolicyKind::FastCache));
        let mut schedules = ScheduleCache::new();
        let mut lane = stepper.make_lane(&GenRequest::builder(1, 3).steps(8).build().unwrap(), schedules.get(8));
        stepper.step(std::slice::from_mut(&mut lane)).unwrap();
        let hw = stepper.scratch_high_water_bytes();
        assert!(hw > 0, "native stepping must exercise the arena");
        while !lane.is_done() {
            stepper.step(std::slice::from_mut(&mut lane)).unwrap();
        }
        assert_eq!(
            stepper.scratch_high_water_bytes(),
            hw,
            "arena grew after the first step — the steady-state path allocated"
        );
    }

    #[test]
    fn degrade_rungs_truncate_and_tighten() {
        let model = DitModel::native(Variant::S, 7);
        let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        fc.enable_str = true;
        let mut stepper = LaneStepper::new(&model, fc);
        let mut schedules = ScheduleCache::new();
        let steps = 8;

        let mut base = stepper
            .make_lane(&GenRequest::builder(0, 5).steps(steps).build().unwrap(), schedules.get(steps));
        while !base.is_done() {
            stepper.step(std::slice::from_mut(&mut base)).unwrap();
        }
        let base_r = base.into_result();
        assert!(!base_r.degraded, "untouched lanes never report degradation");
        assert_eq!(base_r.degrade_rungs, 0);

        // All three rungs after three steps: looser policy threshold,
        // STR threshold way up, two remaining steps. The lane completes
        // early, executes less work, and the accounting records the rungs.
        let mut deg = stepper
            .make_lane(&GenRequest::builder(1, 5).steps(steps).build().unwrap(), schedules.get(steps));
        for _ in 0..3 {
            stepper.step(std::slice::from_mut(&mut deg)).unwrap();
        }
        let before = deg.remaining_flops_estimate();
        deg.degrade_relax_policy(4.0);
        deg.degrade_tighten_str(1e9);
        deg.degrade_truncate_steps(2);
        assert_eq!(deg.effective_steps(), 5);
        assert!(
            deg.remaining_flops_estimate() < before,
            "truncation must shrink the remaining-work prediction"
        );
        while !deg.is_done() {
            stepper.step(std::slice::from_mut(&mut deg)).unwrap();
        }
        let deg_r = deg.into_result();
        assert!(deg_r.degraded);
        assert_eq!(deg_r.degrade_rungs, 3);
        assert_eq!(deg_r.records.len(), 5, "rung 3 truncated 8 steps to 5");
        assert!(deg_r.token_sites_computed < base_r.token_sites_computed);
        assert!(deg_r.latent.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn armed_fault_plan_panics_at_the_exact_site() {
        use crate::faults::{FaultPanic, FaultPlan};
        use std::panic::AssertUnwindSafe;
        let model = DitModel::native(Variant::S, 7);
        let mut stepper =
            LaneStepper::new(&model, FastCacheConfig::with_policy(PolicyKind::NoCache));
        stepper.set_fault_plan(
            0,
            Arc::new(FaultPlan::parse("panic step=1 layer=2 req=9").unwrap()),
        );
        let mut schedules = ScheduleCache::new();
        let mut lane =
            stepper.make_lane(&GenRequest::builder(9, 3).steps(4).build().unwrap(), schedules.get(4));
        stepper.step(std::slice::from_mut(&mut lane)).unwrap(); // step 0: not armed
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let _ = stepper.step(std::slice::from_mut(&mut lane));
        }))
        .unwrap_err();
        assert_eq!(err.downcast_ref::<FaultPanic>().unwrap().req_id, 9);
        // The spec is one-shot: a rebuilt lane steps clean thereafter.
        let mut fresh =
            stepper.make_lane(&GenRequest::builder(9, 3).steps(4).build().unwrap(), schedules.get(4));
        while !fresh.is_done() {
            stepper.step(std::slice::from_mut(&mut fresh)).unwrap();
        }
    }

    #[test]
    fn temb_cache_shares_evaluations_across_lanes_and_steps() {
        // Two co-scheduled lanes at the same step count share every
        // timestep embedding: per step one miss (first lane) and one hit
        // (second lane); a later same-steps request hits for every step.
        let model = DitModel::native(Variant::S, 7);
        let mut stepper =
            LaneStepper::new(&model, FastCacheConfig::with_policy(PolicyKind::NoCache));
        let mut schedules = ScheduleCache::new();
        let steps = 4;
        let mut lanes: Vec<Lane> = (0..2)
            .map(|i| stepper.make_lane(&GenRequest::builder(i, 80 + i).steps(steps).build().unwrap(), schedules.get(steps)))
            .collect();
        for _ in 0..steps {
            stepper.step(&mut lanes).unwrap();
        }
        let ct = stepper.temb_cache_counters();
        assert_eq!(ct.misses as usize, steps, "one eval per distinct timestep value");
        assert_eq!(ct.hits as usize, steps, "co-scheduled lane must share the memo");

        let mut late = stepper.make_lane(&GenRequest::builder(9, 99).steps(steps).build().unwrap(), schedules.get(steps));
        while !late.is_done() {
            stepper.step(std::slice::from_mut(&mut late)).unwrap();
        }
        let ct2 = stepper.temb_cache_counters();
        assert_eq!(ct2.misses as usize, steps, "a later same-schedule request re-uses it all");
        assert_eq!(ct2.hits as usize, 2 * steps);
    }
}
